//! Parallel-training throughput bench: epoch examples/sec of the Hogwild
//! trainer at threads ∈ {1, 2, 4} (threads=1 is the serial path — the
//! honest baseline), plus the mini-batch scoring path at 4 workers. Every
//! configuration starts from the same warmed state (labels assigned, one
//! epoch of updates applied) so the sweep measures steady-state SGD, and
//! every configuration runs through [`ltls::eval::time_epoch`].
//!
//! Emits a machine-readable JSON line for the BENCH trajectory and the CI
//! perf-regression gate (`tools/bench_check.rs` vs `BENCH_BASELINE.json`).
//! `BENCH_FAST=1` trims the dataset and epoch count for smoke runs.

use ltls::data::synthetic::SyntheticSpec;
use ltls::eval::time_epoch;
use ltls::train::{ParallelTrainer, TrainConfig};
use ltls::util::json::Json;

fn main() {
    let fast = std::env::var("BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let n = if fast { 8_000 } else { 30_000 };
    let epochs = if fast { 1usize } else { 2 };

    let ds = SyntheticSpec::multiclass(n, 4_000, 1_024).seed(11).generate();

    // Shared warm start: one serial epoch assigns every label and moves the
    // weights off zero.
    let cfg = TrainConfig { averaging: false, ..TrainConfig::default() };
    let mut base = ParallelTrainer::new(cfg, ds.n_features, ds.n_labels);
    base.fit(&ds, 1);

    println!(
        "== parallel training epoch throughput (C=1024, D=4000, {n} examples, {} cores) ==",
        std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1),
    );

    // (threads, batch, examples/s)
    let mut results: Vec<(usize, usize, f64)> = Vec::new();
    for &(threads, batch) in &[(1usize, 1usize), (2, 1), (4, 1), (4, 16)] {
        let mut tr = base.clone();
        tr.config_mut().threads = threads;
        tr.config_mut().batch = batch;
        let mut total_s = 0.0f64;
        for _ in 0..epochs {
            total_s += time_epoch(&mut tr, &ds).total_s;
        }
        let eps = (epochs * n) as f64 / total_s.max(1e-9);
        let engine = if threads == 1 && batch == 1 { "serial " } else { "hogwild" };
        println!(
            "threads={threads} batch={batch:<3} [{engine}]  {eps:>10.0} examples/s   ({epochs} epoch(s) in {total_s:.2}s)"
        );
        results.push((threads, batch, eps));
    }

    let serial = results[0].2;
    let four = results
        .iter()
        .find(|&&(t, b, _)| t == 4 && b == 1)
        .map(|&(_, _, e)| e)
        .unwrap_or(serial);
    let speedup = four / serial;
    println!("\nspeedup threads=4 / serial = {speedup:.2}x");

    let json = Json::obj(vec![
        ("bench", Json::from("train_parallel")),
        ("examples", Json::from(n)),
        ("epochs", Json::from(epochs)),
        ("speedup_4v1", Json::Num(speedup)),
        (
            "results",
            Json::Arr(
                results
                    .iter()
                    .map(|&(t, b, e)| {
                        Json::obj(vec![
                            ("threads", Json::from(t)),
                            ("batch", Json::from(b)),
                            ("examples_per_s", Json::Num(e)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    println!("json: {}", json.dump());
}
