//! Memory-footprint sweep across the weight-storage backends: dense vs
//! hashed vs q8 on the synthetic dataset — the third axis of the
//! accuracy / speed / **memory** tradeoff, next to `--width`.
//!
//! Prints a human table and a machine-readable `json:` line compatible
//! with `tools/bench_check.rs` (`backend` / `hash_bits` are result
//! discriminators → `memory_footprint.backend=1.hash_bits=9.p1` etc.).
//! `BENCH_FAST=1` trims examples and epochs for CI smoke runs.
//!
//! Hard-asserted shapes (the acceptance claims of the storage subsystem,
//! mirrored as gates in `BENCH_BASELINE.json`):
//!
//! * q8 serving precision@1 within 0.5% (absolute) of the f32 model, at
//!   >3.5× weight-block compression;
//! * hashed training at ≥4× fewer parameters still beats the paper's
//!   naive top-E baseline on the same data.

use ltls::baselines::naive_topk::NaiveTopK;
use ltls::eval::{precision_at_1, time_predictions};
use ltls::graph::{Topology, Trellis};
use ltls::model::{HashedStore, WeightStore};
use ltls::train::{TrainConfig, Trainer};
use ltls::util::bench::Bench;
use ltls::util::json::Json;
use ltls::util::timer::Timer;

fn main() {
    let fast = std::env::var("BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let n = if fast { 5_000 } else { 12_000 };
    let epochs = if fast { 4usize } else { 8 };
    let c = 256usize;
    // D chosen so 2^9 hash buckets are a ≥4x parameter cut (2080/512).
    let d = 2_080usize;
    let hash_bits = 9u32;

    let ds = ltls::data::synthetic::SyntheticSpec::multiclass(n, d, c)
        .teacher(ltls::data::synthetic::TeacherKind::Cluster)
        .seed(41)
        .generate();
    let (train, test) = ltls::data::split::random_split(&ds, 0.2, 7);
    println!(
        "== weight-storage footprint sweep (C={c}, D={d}, {} train / {} test, {epochs} epochs) ==",
        train.n_examples(),
        test.n_examples()
    );

    // ---- dense (the paper's model) ----
    let timer = Timer::new();
    let mut tr = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
    tr.fit(&train, epochs);
    let dense = tr.into_model();
    let dense_train_s = timer.elapsed_s();
    let p1_dense = precision_at_1(&dense, &test);
    let t_dense = time_predictions(&dense, &test, 1);

    // ---- q8 (serve-only, quantized offline from the dense model) ----
    let q8 = dense.quantized();
    let p1_q8 = precision_at_1(&q8, &test);
    let t_q8 = time_predictions(&q8, &test, 1);

    // ---- hashed (trained at 2^bits buckets, independent of D) ----
    let timer = Timer::new();
    let hcfg = TrainConfig { hash_bits, ..TrainConfig::default() };
    let mut htr = Trainer::<Trellis, HashedStore>::with_topology(hcfg, ds.n_features, ds.n_labels)
        .expect("hash-bits config is valid");
    htr.fit(&train, epochs);
    let hashed = htr.into_model();
    let hashed_train_s = timer.elapsed_s();
    let p1_hashed = precision_at_1(&hashed, &test);
    let t_hashed = time_predictions(&hashed, &test, 1);

    // ---- the paper's naive top-E baseline on the same data ----
    let e = Topology::num_edges(&dense.trellis);
    let naive = NaiveTopK::train(&train, e, epochs.min(3), &[1e-5, 1e-3]);
    let p1_naive = precision_at_1(&naive, &test);

    println!(
        "{:<10}{:>10}{:>14}{:>14}{:>10}{:>12}{:>12}",
        "backend", "params", "bytes", "file bytes", "p@1", "train s", "predict µs"
    );
    struct Row {
        backend: u32,
        hash_bits: u32,
        params: usize,
        bytes: usize,
        file_bytes: usize,
        p1: f64,
        train_s: f64,
        predict_us: f64,
    }
    let rows = [
        Row {
            backend: dense.model.backend().tag(),
            hash_bits: 0,
            params: dense.model.param_count(),
            bytes: dense.bytes(),
            file_bytes: ltls::model::io::serialize(&dense).len(),
            p1: p1_dense,
            train_s: dense_train_s,
            predict_us: t_dense.per_example_us,
        },
        Row {
            backend: hashed.model.backend().tag(),
            hash_bits,
            params: hashed.model.param_count(),
            bytes: hashed.bytes(),
            file_bytes: ltls::model::io::serialize(&hashed).len(),
            p1: p1_hashed,
            train_s: hashed_train_s,
            predict_us: t_hashed.per_example_us,
        },
        Row {
            backend: q8.model.backend().tag(),
            hash_bits: 0,
            params: q8.model.param_count(),
            bytes: q8.bytes(),
            file_bytes: ltls::model::io::serialize(&q8).len(),
            p1: p1_q8,
            train_s: 0.0,
            predict_us: t_q8.per_example_us,
        },
    ];
    for (name, r) in ["dense", "hashed", "q8"].iter().zip(&rows) {
        println!(
            "{name:<10}{:>10}{:>14}{:>14}{:>10.4}{:>12.2}{:>12.1}",
            r.params, r.bytes, r.file_bytes, r.p1, r.train_s, r.predict_us
        );
    }
    println!("naive top-{e} LR baseline p@1 = {p1_naive:.4}");

    // The acceptance shapes this subsystem exists for.
    let q8_delta = (p1_dense - p1_q8).abs();
    assert!(
        q8_delta <= 0.005,
        "q8 p@1 {p1_q8:.4} drifted {q8_delta:.4} (> 0.5%) from f32 {p1_dense:.4}"
    );
    let q8_compression = dense.bytes() as f64 / q8.bytes() as f64;
    assert!(q8_compression > 3.5, "q8 compression only {q8_compression:.2}x");
    let param_ratio = dense.model.param_count() as f64 / hashed.model.param_count() as f64;
    assert!(
        param_ratio >= 4.0,
        "hashed store is only {param_ratio:.2}x smaller in parameters (need ≥4x)"
    );
    assert!(
        p1_hashed > p1_naive,
        "hashed LTLS p@1 {p1_hashed:.4} does not beat the naive baseline {p1_naive:.4}"
    );
    println!(
        "\nq8: {q8_compression:.2}x smaller, p@1 delta {q8_delta:+.4}; \
         hashed: {param_ratio:.2}x fewer params, p@1 {p1_hashed:.4} vs naive {p1_naive:.4}"
    );

    // q8 widening-dot kernel microbench: the pinned element-at-a-time
    // scalar oracle vs the dispatched i8→i16→i32 sweep. The speedup ratio
    // is gated; absolutes are record-only.
    let mut kbench = Bench::new();
    Bench::header("q8 widening-dot kernel: scalar oracle vs dispatched i8_axpy");
    let e_strip = 4096usize;
    let qstrip: Vec<i8> = (0..e_strip).map(|i| (((i * 37) % 255) as i32 - 127) as i8).collect();
    let mut acc = vec![0i32; e_strip];
    let k_scalar = kbench.run("i8_axpy scalar oracle E=4096", || {
        ltls::kernel::scalar::i8_axpy(&mut acc, std::hint::black_box(&qstrip), 42);
        acc.len()
    });
    let k_fast = kbench.run("i8_axpy dispatched    E=4096", || {
        ltls::kernel::i8_axpy(&mut acc, std::hint::black_box(&qstrip), 42);
        acc.len()
    });
    let q8_kernel_speedup = k_scalar.mean_ns / k_fast.mean_ns;
    println!(
        "\ni8_axpy kernel speedup = {q8_kernel_speedup:.2}x over the scalar oracle \
         (simd intrinsics active: {})",
        ltls::kernel::simd_active()
    );

    let json = Json::obj(vec![
        ("bench", Json::from("memory_footprint")),
        ("classes", Json::from(c)),
        ("features", Json::from(d)),
        ("epochs", Json::from(epochs)),
        ("q8_p1_delta", Json::Num(q8_delta)),
        ("q8_compression", Json::Num(q8_compression)),
        ("hashed_param_ratio", Json::Num(param_ratio)),
        ("hashed_minus_naive_p1", Json::Num(p1_hashed - p1_naive)),
        ("naive_p1", Json::Num(p1_naive)),
        ("q8_kernel_speedup", Json::Num(q8_kernel_speedup)),
        ("simd_active", Json::from(ltls::kernel::simd_active() as usize)),
        (
            "results",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("backend", Json::from(r.backend as usize)),
                            ("hash_bits", Json::from(r.hash_bits as usize)),
                            ("params", Json::from(r.params)),
                            ("model_bytes", Json::from(r.bytes)),
                            ("file_bytes", Json::from(r.file_bytes)),
                            ("p1", Json::Num(r.p1)),
                            ("train_s", Json::Num(r.train_s)),
                            ("predict_us", Json::Num(r.predict_us)),
                        ])
                    })
                    .chain([
                        // Kernel rows: 0 = scalar oracle, 1 = dispatched
                        // fast path (record-only absolutes).
                        Json::obj(vec![
                            ("kernel", Json::from(0usize)),
                            ("i8_axpy_ns", Json::Num(k_scalar.mean_ns)),
                        ]),
                        Json::obj(vec![
                            ("kernel", Json::from(1usize)),
                            ("i8_axpy_ns", Json::Num(k_fast.mean_ns)),
                        ]),
                    ])
                    .collect(),
            ),
        ),
    ]);
    println!("json: {}", json.dump());
}
