//! Multilabel objective sweep: LTLS with the union-of-gold-paths loss
//! (with and without PLT conditional weighting) against the same trellis
//! trained single-gold-path, and against the equal-memory baselines the
//! paper tables use — NaiveTopK restricted to E = #edges labels (the same
//! parameter count as the dense LTLS model), PLT and FastXML.
//!
//! "Singleton-degenerate" (the `multilabel=0` row) trains the multilabel
//! objective on the same rows truncated to their first label — the run a
//! single-gold-path stack is forced into on multilabel data. The gap to
//! the full-label-set run (`p1_gain_ml_vs_single`) is the payoff of the
//! path-set refactor and is gated in BENCH_BASELINE.json; seeds and the
//! training pipeline are deterministic, so the gain is machine-stable.
//!
//! Prints a human table and a `json:` line for `tools/bench_check.rs`
//! (`multilabel` is a result discriminator: 0 = singleton-degenerate,
//! 1 = union loss, 2 = union loss + PLT weighting). `BENCH_FAST=1` trims
//! sizes and epochs for CI smoke runs.
//!
//! Hard-asserted acceptance shape: multilabel LTLS P@1 strictly beats
//! both the singleton-degenerate run and equal-memory NaiveTopK.

use ltls::baselines::fastxml::FastXmlConfig;
use ltls::baselines::{FastXml, NaiveTopK, Plt};
use ltls::data::synthetic::{SyntheticSpec, TeacherKind};
use ltls::data::Dataset;
use ltls::eval::{evaluate_with, Predictor, Propensities, XcMetrics};
use ltls::graph::Trellis;
use ltls::train::{Objective, TrainConfig, Trainer};
use ltls::util::json::Json;
use ltls::util::timer::Timer;

/// Truncate every label set to its first (lowest-id) label.
fn singleton_degenerate(ds: &Dataset) -> Dataset {
    let mut out = ds.clone();
    for ls in &mut out.labels {
        ls.truncate(1);
    }
    out.detect_multiclass();
    out
}

fn ltls_row(
    train: &Dataset,
    test: &Dataset,
    props: &Propensities,
    objective: Objective,
    epochs: usize,
) -> (XcMetrics, f64, usize) {
    let cfg = TrainConfig { objective, ..TrainConfig::default() };
    let mut tr = Trainer::new(cfg, train.n_features, train.n_labels);
    let timer = Timer::new();
    tr.fit(train, epochs);
    let train_s = timer.elapsed_s();
    let model = tr.into_model();
    let bytes = model.bytes();
    (evaluate_with(&model, test, &[1, 3, 5], Some(props)), train_s, bytes)
}

fn main() {
    let fast = std::env::var("BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let (n, epochs) = if fast { (4_000, 4) } else { (12_000, 8) };
    let (c, d, k) = (128usize, 1_500usize, 3usize);

    let ds = SyntheticSpec::multilabel(n, d, c, k)
        .teacher(TeacherKind::Cluster)
        .seed(47)
        .generate();
    let (train, test) = ltls::data::split::random_split(&ds, 0.2, 11);
    let props = Propensities::from_train(&train);
    let e = Trellis::new(c as u64).num_edges();

    println!(
        "== multilabel sweep (C={c}, D={d}, {}/row, {} train / {} test, {epochs} epochs, E={e}) ==",
        k,
        train.n_examples(),
        test.n_examples()
    );
    println!(
        "{:<26}{:>8}{:>8}{:>8}{:>8}{:>10}{:>12}",
        "method", "P@1", "P@3", "nDCG@3", "PSP@3", "MB", "train s"
    );
    let show = |name: &str, m: &XcMetrics, bytes: usize, train_s: f64| {
        println!(
            "{name:<26}{:>8.4}{:>8.4}{:>8.4}{:>8.4}{:>10.2}{:>12.2}",
            m.precision[0],
            m.precision[1],
            m.ndcg[1],
            m.psp.as_ref().map(|p| p[1]).unwrap_or(0.0),
            bytes as f64 / 1e6,
            train_s
        );
    };

    // LTLS rows: singleton-degenerate (0), union loss (1), union+PLT (2).
    let single_train = singleton_degenerate(&train);
    let (m_single, s_single, b_single) =
        ltls_row(&single_train, &test, &props, Objective::Multilabel { plt_weight: false }, epochs);
    show("LTLS single-gold-path", &m_single, b_single, s_single);
    let (m_ml, s_ml, b_ml) =
        ltls_row(&train, &test, &props, Objective::Multilabel { plt_weight: false }, epochs);
    show("LTLS multilabel", &m_ml, b_ml, s_ml);
    let (m_plt, s_plt, b_plt) =
        ltls_row(&train, &test, &props, Objective::Multilabel { plt_weight: true }, epochs);
    show("LTLS multilabel+plt", &m_plt, b_plt, s_plt);

    // Equal-memory NaiveTopK: E one-vs-all heads ≈ the dense E×D model.
    let timer = Timer::new();
    let naive = NaiveTopK::train(&train, e, epochs.min(3), &[1e-5, 1e-3]);
    let s_naive = timer.elapsed_s();
    let m_naive = evaluate_with(&naive, &test, &[1, 3, 5], Some(&props));
    show("NaiveTopK (top-E LR)", &m_naive, naive.model_bytes(), s_naive);

    // Reference baselines (not memory-matched): PLT tree and FastXML.
    let timer = Timer::new();
    let plt = Plt::train(&train, epochs.min(3), 0.5, 13);
    let s_pltb = timer.elapsed_s();
    let m_pltb = evaluate_with(&plt, &test, &[1, 3, 5], Some(&props));
    show("PLT (tree baseline)", &m_pltb, plt.model_bytes(), s_pltb);
    let timer = Timer::new();
    let fx_cfg = FastXmlConfig { n_trees: if fast { 4 } else { 8 }, ..FastXmlConfig::default() };
    let fx = FastXml::train(&train, &fx_cfg);
    let s_fx = timer.elapsed_s();
    let m_fx = evaluate_with(&fx, &test, &[1, 3, 5], Some(&props));
    show("FastXML", &m_fx, fx.model_bytes(), s_fx);

    let gain_single = m_ml.precision[0] - m_single.precision[0];
    let gain_naive = m_ml.precision[0] - m_naive.precision[0];
    println!("\nP@1 gain, multilabel over single-gold-path: {gain_single:+.4}");
    println!("P@1 gain, multilabel over equal-memory NaiveTopK: {gain_naive:+.4}");

    // The acceptance shape of the path-set refactor.
    assert!(
        gain_single > 0.0,
        "union loss {} must beat the singleton-degenerate run {}",
        m_ml.precision[0],
        m_single.precision[0]
    );
    assert!(
        gain_naive > 0.0,
        "LTLS multilabel {} must beat equal-memory NaiveTopK {} (E={e} labels)",
        m_ml.precision[0],
        m_naive.precision[0]
    );

    let row = |tag: usize, m: &XcMetrics, bytes: usize, train_s: f64| {
        Json::obj(vec![
            ("multilabel", Json::from(tag)),
            ("p1", Json::Num(m.precision[0])),
            ("p3", Json::Num(m.precision[1])),
            ("ndcg3", Json::Num(m.ndcg[1])),
            ("recall3", Json::Num(m.recall[1])),
            ("psp3", Json::Num(m.psp.as_ref().map(|p| p[1]).unwrap_or(0.0))),
            ("model_bytes", Json::from(bytes)),
            ("train_s", Json::Num(train_s)),
        ])
    };
    let json = Json::obj(vec![
        ("bench", Json::from("multilabel_sweep")),
        ("classes", Json::from(c)),
        ("edges", Json::from(e)),
        ("epochs", Json::from(epochs)),
        ("p1_gain_ml_vs_single", Json::Num(gain_single)),
        ("p1_gain_ml_vs_naive", Json::Num(gain_naive)),
        ("naive_p1", Json::Num(m_naive.precision[0])),
        ("plt_baseline_p1", Json::Num(m_pltb.precision[0])),
        ("fastxml_p1", Json::Num(m_fx.precision[0])),
        (
            "results",
            Json::Arr(vec![
                row(0, &m_single, b_single, s_single),
                row(1, &m_ml, b_ml, s_ml),
                row(2, &m_plt, b_plt, s_plt),
            ]),
        ),
    ]);
    println!("json: {}", json.dump());
}
