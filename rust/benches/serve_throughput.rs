//! Serve-throughput bench: requests/sec of the multi-worker prediction
//! server at workers ∈ {1, 2, 4}, with closed-loop clients and the batched
//! LTLS path. Emits a machine-readable JSON line for the BENCH trajectory
//! (EXPERIMENTS.md §Engine).
//!
//! `BENCH_FAST=1` trims the request count for smoke runs.

use ltls::coordinator::{BatchedLtls, BatcherConfig, PredictServer, ServerConfig};
use ltls::data::synthetic::SyntheticSpec;
use ltls::train::{TrainConfig, Trainer};
use ltls::util::json::Json;
use ltls::util::timer::Timer;
use std::sync::Arc;

fn main() {
    let fast = std::env::var("BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let n_requests: usize = if fast { 4_000 } else { 40_000 };
    let clients = 4usize;

    // aloi-like shape: C=1000, sparse rows.
    let ds = SyntheticSpec::multiclass(if fast { 1_500 } else { 4_000 }, 3_000, 1000)
        .seed(5)
        .generate();
    let mut tr = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
    tr.fit(&ds, 2);
    let model = tr.into_model();

    println!(
        "== serve throughput vs workers (C=1000, E={}, {clients} closed-loop clients, {} cores) ==",
        model.trellis.num_edges(),
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    );
    let ds = Arc::new(ds);
    let mut results: Vec<(usize, f64)> = Vec::new();
    for workers in [1usize, 2, 4] {
        let server = Arc::new(PredictServer::start(
            BatchedLtls(model.clone()),
            ServerConfig {
                batcher: BatcherConfig {
                    max_batch: 64,
                    max_wait: std::time::Duration::from_micros(200),
                },
                queue_depth: 2048,
                workers,
            },
        ));
        let timer = Timer::new();
        let per_client = n_requests / clients;
        let handles: Vec<_> = (0..clients)
            .map(|cid| {
                let server = Arc::clone(&server);
                let ds = Arc::clone(&ds);
                std::thread::spawn(move || {
                    let mut pending = std::collections::VecDeque::new();
                    for i in 0..per_client {
                        let row = ds.row((cid * per_client + i) % ds.n_examples());
                        pending.push_back(server.submit(
                            row.indices.to_vec(),
                            row.values.to_vec(),
                            1,
                        ));
                        if pending.len() >= 32 {
                            pending.pop_front().unwrap().recv().unwrap();
                        }
                    }
                    for rx in pending {
                        rx.recv().unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let secs = timer.elapsed_s();
        let rps = (per_client * clients) as f64 / secs;
        let p99_us = server.metrics.request_quantile_ns(0.99) / 1e3;
        println!(
            "workers={workers}  {rps:>10.0} req/s   p99 {p99_us:>7.0}us   ({} requests in {secs:.2}s)",
            per_client * clients
        );
        let server = Arc::try_unwrap(server).ok().expect("all clients joined");
        server.shutdown();
        results.push((workers, rps));
    }

    let base = results[0].1;
    let best = results.iter().map(|r| r.1).fold(0.0f64, f64::max);
    // 4-vs-1 is the gateable number: unlike best/1 (≥ 1.0 by
    // construction, since the 1-worker row is in the max) it actually
    // drops below 1.0 when multi-worker serving regresses.
    let four = results.iter().find(|r| r.0 == 4).map(|r| r.1).unwrap_or(base);
    println!("\nspeedup best/1-worker = {:.2}x, 4/1-worker = {:.2}x", best / base, four / base);

    let json = Json::obj(vec![
        ("bench", Json::from("serve_throughput")),
        ("clients", Json::from(clients)),
        ("requests", Json::from(n_requests)),
        ("speedup_best_v1", Json::Num(best / base)),
        ("speedup_4v1", Json::Num(four / base)),
        (
            "results",
            Json::Arr(
                results
                    .iter()
                    .map(|&(w, r)| {
                        Json::obj(vec![
                            ("workers", Json::from(w)),
                            ("req_per_s", Json::Num(r)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    println!("json: {}", json.dump());
}
