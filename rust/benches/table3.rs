//! Bench target regenerating paper Table 3 (naive top-#edges baseline:
//! oracle upper bound, one-vs-all LR over the E most frequent labels, and
//! LTLS, on all nine dataset analogs).

fn scale() -> f64 {
    if let Ok(s) = std::env::var("LTLS_BENCH_SCALE") {
        return s.parse().unwrap_or(0.15);
    }
    if std::env::var("BENCH_FAST").map(|v| v == "1").unwrap_or(false) {
        0.03
    } else {
        0.15
    }
}

fn main() {
    let epochs = if scale() < 0.05 { 2 } else { 4 };
    let rows = ltls::eval::tables::table3(scale(), epochs, 42);
    print!("{}", ltls::eval::tables::render_table3(&rows));

    // Shape assertions mirroring the paper: the oracle bounds the naive LR,
    // and on the separable analogs LTLS beats the naive baseline (rcv1,
    // sector, aloi rows of the paper).
    for r in &rows {
        assert!(
            r.naive_lr <= r.oracle + 0.02,
            "{}: naive LR {} exceeded its oracle {}",
            r.dataset,
            r.naive_lr,
            r.oracle
        );
    }
    let ltls_wins = rows
        .iter()
        .filter(|r| ["sector", "aloi.bin", "rcv1-regions", "LSHTCwiki"].contains(&r.dataset.as_str()))
        .filter(|r| r.ltls > r.naive_lr)
        .count();
    println!("\nLTLS beats naive top-#edges LR on {ltls_wins}/4 separable analogs (paper: 4/4)");
}
