//! W-LTLS width sweep: the accuracy / parameters / latency tradeoff curve
//! for W ∈ {2, 4, 8, 16} on the synthetic dataset (Evron et al., 2018:
//! widening the trellis trades a modest parameter increase for large
//! accuracy gains — turning the paper's single width-2 point into a dial).
//!
//! Every width trains the same generic stack (`Trainer<WideTrellis>`), so
//! the sweep isolates the topology. Prints a human table and a
//! machine-readable `json:` line compatible with `tools/bench_check.rs`
//! (`width` is a result discriminator → `width_sweep.width=4.p1` etc.).
//! `BENCH_FAST=1` trims examples and epochs for CI smoke runs.
//!
//! Hard-asserted shape (the acceptance claim of the wide subsystem): W=8
//! has strictly more parameters AND strictly higher precision@1 than W=2.

use ltls::data::synthetic::{SyntheticSpec, TeacherKind};
use ltls::eval::{precision_at_1, time_predictions};
use ltls::graph::{Topology, WideTrellis};
use ltls::train::{TrainConfig, Trainer};
use ltls::util::json::Json;
use ltls::util::timer::Timer;

fn main() {
    let fast = std::env::var("BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let n = if fast { 5_000 } else { 15_000 };
    let epochs = if fast { 4usize } else { 8 };
    let c = 256usize;
    let d = 1_500usize;

    let ds = SyntheticSpec::multiclass(n, d, c)
        .teacher(TeacherKind::Cluster)
        .seed(31)
        .generate();
    let (train, test) = ltls::data::split::random_split(&ds, 0.2, 7);

    println!(
        "== W-LTLS width sweep (C={c}, D={d}, {} train / {} test, {epochs} epochs) ==",
        train.n_examples(),
        test.n_examples()
    );
    println!(
        "{:<8}{:>8}{:>8}{:>12}{:>10}{:>12}{:>12}",
        "width", "steps", "edges", "params", "p@1", "train s", "predict µs"
    );

    // (width, steps, edges, params, p1, train_s, predict_us)
    let mut rows: Vec<(u32, u32, usize, usize, f64, f64, f64)> = Vec::new();
    for width in [2u32, 4, 8, 16] {
        let cfg = TrainConfig { width, ..TrainConfig::default() };
        let mut tr = Trainer::<WideTrellis>::with_topology(cfg, ds.n_features, ds.n_labels)
            .expect("width sweep config is valid");
        let timer = Timer::new();
        tr.fit(&train, epochs);
        let train_s = timer.elapsed_s();
        let model = tr.into_model();
        let p1 = precision_at_1(&model, &test);
        let t = time_predictions(&model, &test, 1);
        let (steps, edges, params) = (
            model.trellis.steps(),
            model.trellis.num_edges(),
            model.model.param_count(),
        );
        println!(
            "{width:<8}{steps:>8}{edges:>8}{params:>12}{p1:>10.4}{train_s:>12.2}{:>12.1}",
            t.per_example_us
        );
        rows.push((width, steps, edges, params, p1, train_s, t.per_example_us));
    }

    // The tradeoff shape this subsystem exists for: parameters strictly
    // increase with width, and W=8 buys strictly higher accuracy than the
    // paper's W=2 point.
    for pair in rows.windows(2) {
        assert!(
            pair[1].3 > pair[0].3,
            "params not strictly increasing: W={} has {} vs W={} has {}",
            pair[1].0,
            pair[1].3,
            pair[0].0,
            pair[0].3
        );
    }
    let p1_w2 = rows[0].4;
    let p1_w8 = rows.iter().find(|r| r.0 == 8).unwrap().4;
    assert!(
        p1_w8 > p1_w2,
        "W=8 accuracy {p1_w8} not strictly above W=2 {p1_w2}"
    );
    println!("\naccuracy gain W=8 over W=2: {:+.4} p@1", p1_w8 - p1_w2);
    println!(
        "parameter cost W=8 over W=2: {:.2}x",
        rows.iter().find(|r| r.0 == 8).unwrap().3 as f64 / rows[0].3 as f64
    );

    let json = Json::obj(vec![
        ("bench", Json::from("width_sweep")),
        ("classes", Json::from(c)),
        ("epochs", Json::from(epochs)),
        ("p1_gain_8v2", Json::Num(p1_w8 - p1_w2)),
        (
            "results",
            Json::Arr(
                rows.iter()
                    .map(|&(w, steps, edges, params, p1, train_s, pred_us)| {
                        Json::obj(vec![
                            ("width", Json::from(w as usize)),
                            ("steps", Json::from(steps as usize)),
                            ("edges", Json::from(edges)),
                            ("params", Json::from(params)),
                            ("p1", Json::Num(p1)),
                            ("train_s", Json::Num(train_s)),
                            ("predict_us", Json::Num(pred_us)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    println!("json: {}", json.dump());
}
