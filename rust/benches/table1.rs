//! Bench target regenerating paper Table 1 (multiclass: LTLS vs LOMtree vs
//! FastXML — precision@1, prediction time, model size).
//!
//! `BENCH_FAST=1` or `LTLS_BENCH_SCALE` control the analog scale.

fn scale() -> f64 {
    if let Ok(s) = std::env::var("LTLS_BENCH_SCALE") {
        return s.parse().unwrap_or(0.2);
    }
    if std::env::var("BENCH_FAST").map(|v| v == "1").unwrap_or(false) {
        0.03
    } else {
        0.2
    }
}

fn main() {
    let epochs = if scale() < 0.05 { 2 } else { 5 };
    let report = ltls::eval::tables::table1(scale(), epochs, 42);
    print!("{}", report.render());
    println!("json: {}", report.to_json().dump());
}
