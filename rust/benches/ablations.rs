//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! * §5.1 — label→path assignment policy vs random assignment
//!   (the paper: "significantly better than using random assignment").
//! * §6 — L1 soft-thresholding on the overfitting-prone analogs
//!   (the paper's † rows).
//! * §5 — weight averaging on vs off.

use ltls::assign::AssignPolicy;
use ltls::data::datasets;
use ltls::data::synthetic::SyntheticSpec;
use ltls::eval::precision_at_1;
use ltls::model::l1::{soft_threshold_model, tune_lambda};
use ltls::train::{TrainConfig, Trainer};

fn fast() -> bool {
    std::env::var("BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

fn main() {
    let (n, epochs) = if fast() { (2_000, 3) } else { (8_000, 6) };

    // ---- assignment policy ablation (§5.1) ----
    println!("== assignment policy ablation (C=512, partially separable) ==");
    let ds = SyntheticSpec::multiclass(n, 3000, 512)
        .pool_frac(0.3)
        .noise(0.03)
        .skew(0.8)
        .seed(21)
        .generate();
    let (train, test) = ltls::data::split::random_split(&ds, 0.2, 1);
    for (name, policy) in [("top-ranked (paper)", AssignPolicy::TopRanked), ("random", AssignPolicy::Random)]
    {
        let cfg = TrainConfig { policy, ..Default::default() };
        let mut tr = Trainer::new(cfg, train.n_features, train.n_labels);
        tr.fit(&train, epochs);
        let m = tr.into_model();
        println!(
            "  {name:<22} p@1 = {:.4}  random_fallbacks = {}",
            precision_at_1(&m, &test),
            m.assigner.random_fallbacks
        );
    }

    // ---- L1 soft-thresholding ablation (§6, the † rows) ----
    println!("\n== L1 soft-threshold ablation (LSHTC1 analog) ==");
    let analog = datasets::by_name("LSHTC1").unwrap();
    let (train, test) = analog.generate(if fast() { 0.04 } else { 0.15 }, 22);
    let mut tr = Trainer::new(TrainConfig::default(), train.n_features, train.n_labels);
    tr.fit(&train, epochs.min(4));
    let model = tr.into_model();
    let (best_lambda, _) = tune_lambda(&model.model, &[0.0, 0.005, 0.01, 0.02, 0.05], |m| {
        let candidate = ltls::train::TrainedModel {
            trellis: model.trellis.clone(),
            model: m.clone(),
            assigner: ltls::assign::Assigner::new(
                AssignPolicy::Identity,
                0,
                &model.trellis,
                0,
            ),
        };
        let _ = candidate; // tuning on test here would leak; use zero-frac proxy
        m.zero_fraction()
    });
    for lambda in [0.0f32, 0.005, 0.01, 0.02, 0.05] {
        let thresholded = soft_threshold_model(&model.model, lambda);
        let zf = thresholded.zero_fraction();
        let m2 = ltls::train::TrainedModel {
            trellis: model.trellis.clone(),
            model: thresholded,
            assigner: clone_assigner(&model),
        };
        println!(
            "  λ={lambda:<7} p@1 = {:.4}  zero-weights = {:.1}%{}",
            precision_at_1(&m2, &test),
            zf * 100.0,
            if lambda == best_lambda { "  <- max-sparsity pick" } else { "" }
        );
    }

    // ---- PLT vs LTLS prediction complexity (§1) ----
    // The paper positions LTLS against PLT (ref [5]): PLT trains in
    // O(log C) but its beam-search prediction is not O(log C). Measure
    // per-example predict time for both as C grows.
    println!("\n== PLT vs LTLS predict time (µs/example) ==");
    println!("  {:<10}{:>12}{:>12}", "C", "LTLS", "PLT(beam16)");
    for exp in [7u32, 9, 11, if fast() { 12 } else { 13 }] {
        let c = 1usize << exp;
        let ds = SyntheticSpec::multiclass(if fast() { 1_000 } else { 3_000 }, 2_000, c)
            .seed(exp as u64)
            .generate();
        let mut tr = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
        tr.fit(&ds, 2);
        let ltls_model = tr.into_model();
        let plt = ltls::baselines::Plt::train(&ds, 2, 0.5, exp as u64);
        let time_us = |m: &dyn ltls::eval::Predictor| {
            let t = ltls::util::timer::Timer::new();
            let iters = 400;
            for i in 0..iters {
                std::hint::black_box(m.topk(ds.row(i % ds.n_examples()), 1));
            }
            t.elapsed_us() / iters as f64
        };
        println!(
            "  {:<10}{:>12.1}{:>12.1}",
            c,
            time_us(&ltls_model),
            time_us(&plt)
        );
    }

    // ---- averaging ablation (§5) ----
    println!("\n== weight averaging ablation (sector analog) ==");
    let analog = datasets::by_name("sector").unwrap();
    let (train, test) = analog.generate(if fast() { 0.1 } else { 0.5 }, 23);
    for averaging in [true, false] {
        let cfg = TrainConfig { averaging, ..Default::default() };
        let mut tr = Trainer::new(cfg, train.n_features, train.n_labels);
        tr.fit(&train, epochs.min(4));
        println!(
            "  averaging={averaging:<6} p@1 = {:.4}",
            precision_at_1(&tr.into_model(), &test)
        );
    }
}

/// Rebuild an assigner with the same table contents (ablation helper).
fn clone_assigner(m: &ltls::train::TrainedModel) -> ltls::assign::Assigner {
    let mut a = ltls::assign::Assigner::new(
        AssignPolicy::Identity,
        m.assigner.table.pairs().map(|(l, _)| l as usize + 1).max().unwrap_or(0),
        &m.trellis,
        0,
    );
    for (l, p) in m.assigner.table.pairs() {
        a.table.bind(l, p);
    }
    a
}
