//! Network-serving bench: requests/sec of the TCP frontend
//! (`coordinator::transport` + `coordinator::event_loop`) with closed-loop
//! loopback clients, with and without hot-reload churn, vs the in-process
//! worker pool (the transport tax) — plus a **connection sweep** across
//! both transports (threads vs poll(2) event loop) up to 1000 concurrent
//! connections. Emits a machine-readable JSON line for the CI perf gate
//! (EXPERIMENTS.md §Network serving).
//!
//! Gated metrics:
//!
//! * `reload_ratio` — throughput with a model reload every ~25 ms over
//!   undisturbed throughput: the epoch-handoff design claims reloads land
//!   between micro-batches without stalling the pipeline, so the ratio
//!   should sit near 1.0 on any machine.
//! * `many_conn_ratio` — event-loop throughput at 1000 concurrent
//!   connections over threaded throughput at 100: the event loop claims
//!   holding 10x the connections costs ~nothing (both runs are
//!   pool-bound; connection setup is excluded by a start barrier). The
//!   gate catches the event loop falling over at scale, not noise.
//! * `obs_overhead_ratio` — traced throughput (the default
//!   `--trace-sample 64` plus the always-on slow-request ring) over the
//!   same serving with tracing fully disabled: span stamping is a
//!   handful of relaxed atomic stores, so the ratio should sit near 1.0
//!   and the gate fails only if observability starts taxing the request
//!   hot path.
//! * `shard_scatter_ratio` — coordinator throughput fanning out over 2
//!   label-space shards over the same coordinator proxying a single
//!   shard: the scatter tier claims the fan-out itself (one extra
//!   pooled hop per shard plus the k-way merge) costs ~nothing because
//!   each shard scores fewer terminal edges. Machine-relative, so
//!   gateable; fails only if fanning out starts collapsing throughput.
//!
//! Per-row absolute throughputs (`transport=T.clients=N.req_per_s`,
//! transport 0 = threads, 1 = event-loop) are recorded but not gated
//! (machine-dependent); the two observability phases are also recorded
//! as `transport=1.clients=4.trace={1,0}.req_per_s` rows, and the
//! scatter phase as `shards={1,2,4}.req_per_s` rows.
//!
//! `BENCH_FAST=1` trims the request count for smoke runs.

use ltls::coordinator::{
    BatchedLtls, BatcherConfig, NetConfig, NetServer, PredictServer, ReloadableLtls,
    ScatterConfig, ScatterModel, ServerConfig, Transport,
};
use ltls::data::synthetic::SyntheticSpec;
use ltls::graph::ShardPlan;
use ltls::model::slice_model;
use ltls::train::{TrainConfig, Trainer};
use ltls::util::json::Json;
use ltls::util::timer::Timer;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Barrier};
use std::time::Duration;

fn pool_cfg() -> ServerConfig {
    ServerConfig {
        batcher: BatcherConfig { max_batch: 64, max_wait: Duration::from_micros(200) },
        queue_depth: 2048,
        workers: 2,
    }
}

/// Drive `n` requests through the TCP frontend with `clients` closed-loop
/// connections (window of `window` pipelined requests each); returns
/// req/s. All connections are established **before** the clock starts (a
/// barrier holds the clients), so the number measures steady-state
/// serving, not connect/teardown — which is what makes rows at different
/// connection counts comparable.
fn drive_tcp(
    addr: SocketAddr,
    ds: &Arc<ltls::data::Dataset>,
    clients: usize,
    n: usize,
    window: usize,
) -> f64 {
    let per_client = (n / clients).max(1);
    let start = Arc::new(Barrier::new(clients + 1));
    let handles: Vec<_> = (0..clients)
        .map(|cid| {
            let ds = Arc::clone(ds);
            let start = Arc::clone(&start);
            // Small stacks: at 1000 clients the driver itself must not be
            // the thing that falls over.
            std::thread::Builder::new()
                .stack_size(256 << 10)
                .spawn(move || {
                    let stream = TcpStream::connect(addr).expect("connect");
                    stream.set_nodelay(true).ok();
                    let mut r = BufReader::new(stream.try_clone().expect("clone"));
                    let mut w = stream;
                    start.wait();
                    let mut line = String::new();
                    let mut pending = 0usize;
                    let mut recv = |line: &mut String, pending: &mut usize| {
                        line.clear();
                        r.read_line(line).unwrap();
                        assert!(
                            !line.contains("\"backpressure\""),
                            "bench misconfigured: admission rejected a windowed request"
                        );
                        *pending -= 1;
                    };
                    for i in 0..per_client {
                        let row = ds.row((cid * per_client + i) % ds.n_examples());
                        let mut req = String::with_capacity(16 * row.indices.len() + 2);
                        req.push('1');
                        for (&j, &v) in row.indices.iter().zip(row.values) {
                            req.push_str(&format!(" {j}:{v}"));
                        }
                        req.push('\n');
                        w.write_all(req.as_bytes()).unwrap();
                        pending += 1;
                        while pending >= window {
                            recv(&mut line, &mut pending);
                        }
                    }
                    while pending > 0 {
                        recv(&mut line, &mut pending);
                    }
                })
                .expect("spawn bench client")
        })
        .collect();
    start.wait();
    let timer = Timer::new();
    for h in handles {
        h.join().unwrap();
    }
    (per_client * clients) as f64 / timer.elapsed_s()
}

fn main() {
    let fast = std::env::var("BENCH_FAST").map(|v| v == "1").unwrap_or(false);
    let n_requests: usize = if fast { 6_000 } else { 40_000 };
    let clients = 4usize;

    // aloi-like shape: C=1000, sparse rows.
    let ds = SyntheticSpec::multiclass(if fast { 1_500 } else { 4_000 }, 3_000, 1000)
        .seed(5)
        .generate();
    let mut tr = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
    tr.fit(&ds, 2);
    let model = tr.into_model();
    let dir = std::env::temp_dir().join(format!("ltls_bench_net_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let model_path = dir.join("model.ltls");
    ltls::model::io::save(&model, &model_path).unwrap();

    println!(
        "== network serve throughput (C=1000, E={}, {clients} closed-loop TCP clients) ==",
        model.trellis.num_edges()
    );
    let ds = Arc::new(ds);

    // Reference: the in-process pool, no network hop (same pool shape).
    let inproc = {
        let server = Arc::new(PredictServer::start(BatchedLtls(model.clone()), pool_cfg()));
        let timer = Timer::new();
        let per_client = n_requests / clients;
        let handles: Vec<_> = (0..clients)
            .map(|cid| {
                let server = Arc::clone(&server);
                let ds = Arc::clone(&ds);
                std::thread::spawn(move || {
                    let mut pending = std::collections::VecDeque::new();
                    for i in 0..per_client {
                        let row = ds.row((cid * per_client + i) % ds.n_examples());
                        pending.push_back(server.submit(
                            row.indices.to_vec(),
                            row.values.to_vec(),
                            1,
                        ));
                        if pending.len() >= 16 {
                            pending.pop_front().unwrap().recv().unwrap();
                        }
                    }
                    for rx in pending {
                        rx.recv().unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let rps = n_requests as f64 / timer.elapsed_s();
        let server = Arc::try_unwrap(server).ok().expect("all clients joined");
        server.shutdown();
        rps
    };
    println!("in-process pool        {inproc:>10.0} req/s");

    // Phase 1: plain TCP serving (the default transport: the event loop).
    let reloadable = Arc::new(ReloadableLtls::from_path(&model_path, false).unwrap());
    let server = NetServer::start_reloadable(
        "127.0.0.1:0",
        Arc::clone(&reloadable),
        NetConfig { server: pool_cfg(), ..NetConfig::default() },
    )
    .expect("start net server");
    let addr = server.addr();
    let tcp_plain = drive_tcp(addr, &ds, clients, n_requests, 16);
    let p99_us = server.metrics().request_quantile_ns(0.99) / 1e3;
    println!("tcp frontend           {tcp_plain:>10.0} req/s   p99 {p99_us:>7.0}us");

    // Phase 2: same traffic under hot-reload churn (a swap every ~25 ms).
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let churn = {
        let reloadable = Arc::clone(&reloadable);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut swaps = 0u64;
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                reloadable.reload().expect("reload valid model");
                swaps += 1;
                std::thread::sleep(Duration::from_millis(25));
            }
            swaps
        })
    };
    let tcp_reload = drive_tcp(addr, &ds, clients, n_requests, 16);
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    let swaps = churn.join().unwrap();
    println!("tcp + reload churn     {tcp_reload:>10.0} req/s   ({swaps} hot swaps)");
    assert!(swaps >= 1, "churn thread never swapped");
    assert_eq!(reloadable.epoch(), swaps, "every swap must bump the epoch");

    server.shutdown();

    // Phase 2b: the same serving with request tracing fully disabled —
    // the denominator of the observability-overhead gate. Same reloadable
    // handle, same pool shape, same traffic; the only delta from phase 1
    // is the per-request span stamping and ring-buffer capture, so
    // traced/untraced isolates the tracing tax.
    let notrace_server = NetServer::start_reloadable(
        "127.0.0.1:0",
        Arc::clone(&reloadable),
        NetConfig {
            server: pool_cfg(),
            trace_sample: 0,
            trace_slow_ms: 0,
            ..NetConfig::default()
        },
    )
    .expect("start notrace server");
    let tcp_notrace = drive_tcp(notrace_server.addr(), &ds, clients, n_requests, 16);
    notrace_server.shutdown();
    println!("tcp, tracing off       {tcp_notrace:>10.0} req/s");

    // Phase 3: connection sweep — both transports, up to 1000 concurrent
    // connections on the event loop (the threaded transport is capped at
    // 100: two OS threads per connection does not scale past that, which
    // is the point of the comparison).
    println!("\n== connection sweep (window 4, connect excluded by barrier) ==");
    let sweep_n: usize = if fast { 4_000 } else { 20_000 };
    // Generous pool queue so windowed traffic is never backpressured:
    // 1000 conns x window 4 stays far below both the queue depth and the
    // derived admission bounds.
    let sweep_pool = ServerConfig {
        batcher: BatcherConfig { max_batch: 64, max_wait: Duration::from_micros(200) },
        queue_depth: 16_384,
        workers: 2,
    };
    let sweep_points: &[(Transport, usize)] = &[
        (Transport::Threads, 10),
        (Transport::Threads, 100),
        (Transport::EventLoop, 10),
        (Transport::EventLoop, 100),
        (Transport::EventLoop, 1000),
    ];
    let mut rows: Vec<Json> = Vec::new();
    let mut threads_at_100 = 0.0f64;
    let mut eventloop_at_1000 = 0.0f64;
    for &(transport, n_conns) in sweep_points {
        let server = NetServer::start(
            "127.0.0.1:0",
            BatchedLtls(model.clone()),
            NetConfig { server: sweep_pool.clone(), transport, ..NetConfig::default() },
        )
        .expect("start sweep server");
        let rps = drive_tcp(server.addr(), &ds, n_conns, sweep_n, 4);
        assert_eq!(
            server.accepted_connections(),
            n_conns as u64,
            "sweep server lost connections"
        );
        println!("{transport:<11} {n_conns:>5} conns   {rps:>10.0} req/s");
        server.shutdown();
        if transport == Transport::Threads && n_conns == 100 {
            threads_at_100 = rps;
        }
        if transport == Transport::EventLoop && n_conns == 1000 {
            eventloop_at_1000 = rps;
        }
        let tcode = match transport {
            Transport::Threads => 0usize,
            Transport::EventLoop => 1usize,
        };
        rows.push(Json::obj(vec![
            ("transport", Json::from(tcode)),
            ("clients", Json::from(n_conns)),
            ("req_per_s", Json::Num(rps)),
        ]));
    }
    std::fs::remove_dir_all(&dir).ok();

    // Phase 4: sharded scatter-gather — the coordinator fans each request
    // out over N label-space shards (one in-process replica each) over
    // persistent pooled connections and k-way-merges the partial top-k
    // lists. shards=1 is the pure proxy cost (one coordinator hop, no
    // fan-out); the gated ratio compares 2-shard fan-out against it.
    println!("\n== sharded scatter-gather (coordinator fan-out, {clients} clients) ==");
    let shard_n: usize = if fast { 4_000 } else { 20_000 };
    let mut scatter_rps = [0.0f64; 3];
    for (si, &n_shards) in [1usize, 2, 4].iter().enumerate() {
        let plan = ShardPlan::new(&model.trellis, n_shards as u32).expect("shard plan");
        let mut shard_servers = Vec::new();
        let mut spec: Vec<Vec<String>> = Vec::new();
        for s in 0..n_shards as u32 {
            let slice = slice_model(&model, &plan, s).expect("slice model");
            let srv = NetServer::start(
                "127.0.0.1:0",
                BatchedLtls(slice),
                NetConfig { server: pool_cfg(), ..NetConfig::default() },
            )
            .expect("start shard server");
            spec.push(vec![srv.addr().to_string()]);
            shard_servers.push(srv);
        }
        let scatter = ScatterModel::new(
            spec,
            ScatterConfig { n_features: Some(ds.n_features), ..ScatterConfig::default() },
        )
        .expect("scatter model");
        let stats = scatter.stats();
        let coord = NetServer::start_scatter(
            "127.0.0.1:0",
            scatter,
            NetConfig { server: pool_cfg(), ..NetConfig::default() },
        )
        .expect("start coordinator");
        let rps = drive_tcp(coord.addr(), &ds, clients, shard_n, 16);
        assert_eq!(stats.degraded(), 0, "healthy shards must never degrade a reply");
        println!("coordinator {n_shards:>2} shard(s)   {rps:>10.0} req/s");
        coord.shutdown();
        for srv in shard_servers {
            srv.shutdown();
        }
        scatter_rps[si] = rps;
        rows.push(Json::obj(vec![
            ("shards", Json::from(n_shards)),
            ("req_per_s", Json::Num(rps)),
        ]));
    }
    let shard_scatter_ratio = scatter_rps[1] / scatter_rps[0];

    // The two observability phases as trace-discriminated rows:
    // event-loop transport, 4 clients, tracing on (default sampling) vs
    // fully off.
    rows.push(Json::obj(vec![
        ("transport", Json::from(1usize)),
        ("clients", Json::from(clients)),
        ("trace", Json::from(1usize)),
        ("req_per_s", Json::Num(tcp_plain)),
    ]));
    rows.push(Json::obj(vec![
        ("transport", Json::from(1usize)),
        ("clients", Json::from(clients)),
        ("trace", Json::from(0usize)),
        ("req_per_s", Json::Num(tcp_notrace)),
    ]));

    let reload_ratio = tcp_reload / tcp_plain;
    let net_overhead = tcp_plain / inproc;
    let many_conn_ratio = eventloop_at_1000 / threads_at_100;
    let obs_overhead_ratio = tcp_plain / tcp_notrace;
    println!(
        "\nreload_ratio (churn/plain) = {reload_ratio:.2}   transport ratio (tcp/in-process) = {net_overhead:.2}"
    );
    println!("many_conn_ratio (event-loop@1000 / threads@100) = {many_conn_ratio:.2}");
    println!("obs_overhead_ratio (traced / tracing-off) = {obs_overhead_ratio:.2}");
    println!("shard_scatter_ratio (2-shard fan-out / 1-shard proxy) = {shard_scatter_ratio:.2}");

    let json = Json::obj(vec![
        ("bench", Json::from("serve_network")),
        ("requests", Json::from(n_requests)),
        ("clients", Json::from(clients)),
        ("reload_swaps", Json::from(swaps as usize)),
        ("reload_ratio", Json::Num(reload_ratio)),
        ("net_vs_inproc_ratio", Json::Num(net_overhead)),
        ("many_conn_ratio", Json::Num(many_conn_ratio)),
        ("obs_overhead_ratio", Json::Num(obs_overhead_ratio)),
        ("shard_scatter_ratio", Json::Num(shard_scatter_ratio)),
        ("inproc_req_per_s", Json::Num(inproc)),
        ("tcp_req_per_s", Json::Num(tcp_plain)),
        ("tcp_notrace_req_per_s", Json::Num(tcp_notrace)),
        ("tcp_reload_req_per_s", Json::Num(tcp_reload)),
        ("p99_us", Json::Num(p99_us)),
        ("results", Json::Arr(rows)),
    ]);
    println!("json: {}", json.dump());
}
