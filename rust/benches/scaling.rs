//! The scaling bench: end-to-end prediction latency and model size as C
//! grows 2^8 → 2^24 at fixed D — regenerates the paper's core complexity
//! claims (log-time prediction §1, log-space model §4) as a series.

use ltls::data::synthetic::SyntheticSpec;
use ltls::eval::Predictor;
use ltls::train::{TrainConfig, Trainer};
use ltls::util::bench::Bench;

fn main() {
    let mut bench = Bench::new();
    Bench::header("end-to-end predict latency vs C (trained models, D=2000)");
    let d = 2000;
    let mut sizes = Vec::new();
    for exp in [8u32, 12, 16, 20, 24] {
        let c = 1usize << exp;
        // Keep n modest: we bench prediction, not training.
        let ds = SyntheticSpec::multiclass(1500, d, c).seed(exp as u64).generate();
        let mut tr = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
        tr.fit(&ds, 1);
        let model = tr.into_model();
        sizes.push((c, model.trellis.num_edges(), model.model_bytes()));
        let row = ds.row(0);
        bench.run(&format!("predict top-1  C=2^{exp}"), || model.topk(row, 1));
        bench.run(&format!("predict top-10 C=2^{exp}"), || model.topk(row, 10));
    }
    println!("\nmodel size vs C (log-space claim):");
    println!("{:<12}{:>8}{:>14}{:>16}", "C", "E", "LTLS bytes", "OVA bytes");
    for (c, e, b) in sizes {
        println!("{:<12}{:>8}{:>14}{:>16}", c, e, b, c * d * 4);
    }
}
