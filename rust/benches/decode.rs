//! Decoder micro-benchmarks: Viterbi / list-Viterbi / forward-backward /
//! label scoring across C — the O(log C) prediction claim at the op level —
//! plus the allocating-vs-workspace comparison for the engine's `_into`
//! variants (EXPERIMENTS.md §Engine).

use ltls::engine::DecodeWorkspace;
use ltls::graph::{Topology, Trellis, WideTrellis};
use ltls::util::bench::Bench;
use ltls::util::json::Json;
use ltls::util::rng::Rng;

fn main() {
    let mut bench = Bench::new();
    Bench::header("decode ops vs C (per-op latency must grow ~log C)");
    let mut rng = Rng::new(42);
    for c in [105u64, 1000, 12294, 320338, 1 << 24] {
        let t = Trellis::new(c);
        let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
        bench.run(&format!("viterbi            C={c}"), || {
            ltls::decode::viterbi(&t, std::hint::black_box(&h))
        });
        bench.run(&format!("list_viterbi k=5   C={c}"), || {
            ltls::decode::list_viterbi(&t, std::hint::black_box(&h), 5)
        });
        bench.run(&format!("list_viterbi k=50  C={c}"), || {
            ltls::decode::list_viterbi(&t, std::hint::black_box(&h), 50)
        });
        bench.run(&format!("log_partition      C={c}"), || {
            ltls::decode::log_partition(&t, std::hint::black_box(&h))
        });
        bench.run(&format!("score_label        C={c}"), || {
            ltls::decode::score_label(&t, std::hint::black_box(&h), c / 2)
        });
    }

    // The engine story: same ops on a reused DecodeWorkspace — the delta
    // to the rows above is pure allocator cost.
    Bench::header("alloc vs reused workspace (C=320338)");
    let t = Trellis::new(320338);
    let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
    let mut ws = DecodeWorkspace::new();
    let mut topk = Vec::new();
    let mut marg = Vec::new();
    let mut pairs = Vec::new();
    for k in [5usize, 50] {
        let alloc = bench.run(&format!("list_viterbi k={k:<2}  alloc"), || {
            ltls::decode::list_viterbi(&t, std::hint::black_box(&h), k)
        });
        let reused = bench.run(&format!("list_viterbi k={k:<2}  workspace"), || {
            ltls::decode::list_viterbi_into(&t, std::hint::black_box(&h), k, &mut ws, &mut topk);
            topk.len()
        });
        pairs.push((k, alloc, reused));
    }
    bench.run("log_partition       alloc", || {
        ltls::decode::log_partition(&t, std::hint::black_box(&h))
    });
    bench.run("log_partition       workspace", || {
        ltls::decode::log_partition_ws(&t, std::hint::black_box(&h), &mut ws)
    });
    bench.run("posterior_marginals alloc", || {
        ltls::decode::posterior_marginals(&t, std::hint::black_box(&h))
    });
    bench.run("posterior_marginals workspace", || {
        ltls::decode::posterior_marginals_into(&t, std::hint::black_box(&h), &mut ws, &mut marg);
        marg.len()
    });

    // The log-time check: per-op time ratio across 160x increase in C
    // should be far below linear.
    let r = bench.results();
    let small = r.iter().find(|s| s.name.contains("viterbi            C=105")).unwrap();
    let big = r.iter().find(|s| s.name.contains("viterbi            C=320338")).unwrap();
    let ratio = big.mean_ns / small.mean_ns;
    println!("\nviterbi time ratio C=320338 / C=105 = {ratio:.1}x (C ratio = 3051x; log-time requires << linear)");
    assert!(ratio < 60.0, "decode does not look log-time: {ratio}");

    // The zero-allocation comparison. Advisory only: the two means are
    // close (the DP dominates at this E), so a hard assert would flake on
    // noisy shared runners — correctness parity is asserted by
    // rust/tests/engine_parity.rs instead.
    for (k, alloc, reused) in &pairs {
        let speedup = alloc.mean_ns / reused.mean_ns;
        let note = if speedup < 1.0 { "  (WARNING: slower than alloc — check for a regression)" } else { "" };
        println!("list_viterbi k={k} workspace speedup = {speedup:.2}x{note}");
    }

    // Wide (W-LTLS) decode rows: the generic W-ary kernels at C=320338,
    // W ∈ {4, 8}, on a reused workspace. Wider steps are fewer but each
    // carries W² transition edges, so per-op cost grows with W — these rows
    // are record-only in BENCH_BASELINE.json (absolute ns are
    // machine-dependent).
    Bench::header("wide decode (W-LTLS generic kernels, C=320338)");
    let mut wide_rows: Vec<(u32, f64, f64)> = Vec::new();
    for w in [4u32, 8] {
        let t = WideTrellis::new(320338, w).unwrap();
        let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
        let v = bench.run(&format!("wide viterbi        W={w}"), || {
            ltls::decode::viterbi_ws(&t, std::hint::black_box(&h), &mut ws)
        });
        let lv = bench.run(&format!("wide list_vit k=5   W={w}"), || {
            ltls::decode::list_viterbi_into(&t, std::hint::black_box(&h), 5, &mut ws, &mut topk);
            topk.len()
        });
        wide_rows.push((w, v.mean_ns, lv.mean_ns));
    }

    // Strip-sweep kernel microbench: the pinned scalar oracle vs the
    // dispatched fast path (portable 8-lane sweep, or AVX2/NEON under
    // `--features simd`). One long strip so the sweep dominates; the
    // speedup ratio is the gated acceptance metric, absolutes are
    // record-only.
    Bench::header("strip-sweep kernel: scalar oracle vs dispatched axpy");
    let e_strip = 4096usize;
    let strip: Vec<f32> = (0..e_strip).map(|_| rng.normal()).collect();
    let mut acc = vec![0.0f32; e_strip];
    let k_scalar = bench.run("axpy scalar oracle E=4096", || {
        ltls::kernel::scalar::axpy(&mut acc, std::hint::black_box(&strip), 0.37);
        acc.len()
    });
    let k_fast = bench.run("axpy dispatched    E=4096", || {
        ltls::kernel::axpy(&mut acc, std::hint::black_box(&strip), 0.37);
        acc.len()
    });
    let kernel_speedup = k_scalar.mean_ns / k_fast.mean_ns;
    println!(
        "\naxpy kernel speedup = {kernel_speedup:.2}x over the scalar oracle \
         (simd intrinsics active: {})",
        ltls::kernel::simd_active()
    );

    // Machine-readable line for the CI perf gate (tools/bench_check.rs).
    let mut fields = vec![
        ("bench".to_string(), Json::from("decode")),
        ("viterbi_ratio".to_string(), Json::Num(ratio)),
        ("viterbi_small_ns".to_string(), Json::Num(small.mean_ns)),
        ("viterbi_big_ns".to_string(), Json::Num(big.mean_ns)),
        ("kernel_axpy_speedup".to_string(), Json::Num(kernel_speedup)),
        ("simd_active".to_string(), Json::from(ltls::kernel::simd_active() as usize)),
    ];
    for (k, alloc, reused) in &pairs {
        fields.push((
            format!("list_viterbi_k{k}_ws_speedup"),
            Json::Num(alloc.mean_ns / reused.mean_ns),
        ));
    }
    let mut json = Json::Obj(fields.into_iter().collect());
    if let Json::Obj(map) = &mut json {
        let mut results: Vec<Json> = wide_rows
            .iter()
            .map(|&(w, v_ns, lv_ns)| {
                Json::obj(vec![
                    ("width", Json::from(w as usize)),
                    ("viterbi_ns", Json::Num(v_ns)),
                    ("list_viterbi_k5_ns", Json::Num(lv_ns)),
                ])
            })
            .collect();
        // Kernel rows: 0 = scalar oracle, 1 = dispatched fast path
        // (record-only absolutes; the speedup ratio above is gated).
        results.push(Json::obj(vec![
            ("kernel", Json::from(0usize)),
            ("axpy_ns", Json::Num(k_scalar.mean_ns)),
        ]));
        results.push(Json::obj(vec![
            ("kernel", Json::from(1usize)),
            ("axpy_ns", Json::Num(k_fast.mean_ns)),
        ]));
        map.insert("results".to_string(), Json::Arr(results));
    }
    println!("json: {}", json.dump());
}
