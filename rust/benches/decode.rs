//! Decoder micro-benchmarks: Viterbi / list-Viterbi / forward-backward /
//! label scoring across C — the O(log C) prediction claim at the op level.

use ltls::graph::Trellis;
use ltls::util::bench::Bench;
use ltls::util::rng::Rng;

fn main() {
    let mut bench = Bench::new();
    Bench::header("decode ops vs C (per-op latency must grow ~log C)");
    let mut rng = Rng::new(42);
    for c in [105u64, 1000, 12294, 320338, 1 << 24] {
        let t = Trellis::new(c);
        let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
        bench.run(&format!("viterbi            C={c}"), || {
            ltls::decode::viterbi(&t, std::hint::black_box(&h))
        });
        bench.run(&format!("list_viterbi k=5   C={c}"), || {
            ltls::decode::list_viterbi(&t, std::hint::black_box(&h), 5)
        });
        bench.run(&format!("list_viterbi k=50  C={c}"), || {
            ltls::decode::list_viterbi(&t, std::hint::black_box(&h), 50)
        });
        bench.run(&format!("log_partition      C={c}"), || {
            ltls::decode::log_partition(&t, std::hint::black_box(&h))
        });
        bench.run(&format!("score_label        C={c}"), || {
            ltls::decode::score_label(&t, std::hint::black_box(&h), c / 2)
        });
    }

    // The log-time check: per-op time ratio across 160x increase in C
    // should be far below linear.
    let r = bench.results();
    let small = r.iter().find(|s| s.name.contains("viterbi            C=105")).unwrap();
    let big = r.iter().find(|s| s.name.contains("viterbi            C=320338")).unwrap();
    let ratio = big.mean_ns / small.mean_ns;
    println!("\nviterbi time ratio C=320338 / C=105 = {ratio:.1}x (C ratio = 3051x; log-time requires << linear)");
    assert!(ratio < 60.0, "decode does not look log-time: {ratio}");
}
