//! Training-throughput bench: SGD steps/second across C and nnz — the
//! paper's O(log C) *training* claim (one update touches O(log C) edge
//! models), plus the assignment-policy overhead.

use ltls::data::synthetic::SyntheticSpec;
use ltls::train::{TrainConfig, Trainer};
use ltls::util::bench::Bench;

fn main() {
    let mut bench = Bench::new();
    Bench::header("SGD step latency vs C (D=5000, nnz~20)");
    for exp in [7u32, 10, 14, 17] {
        let c = 1usize << exp;
        let ds = SyntheticSpec::multiclass(4_000, 5_000, c).seed(exp as u64).generate();
        let mut tr = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
        // Warm: assign all labels first so we measure steady-state steps.
        tr.fit(&ds, 1);
        let mut i = 0usize;
        let mut metrics = ltls::train::metrics::EpochMetrics::default();
        let stats = bench.run(&format!("sgd step  C=2^{exp}"), || {
            i = (i + 1) % ds.n_examples();
            tr.step(ds.row(i), ds.labels_of(i), &mut metrics)
        });
        let _ = stats;
    }

    Bench::header("SGD step latency vs nnz (C=4096, D=20000)");
    for nnz in [10usize, 40, 160] {
        let density = nnz as f64 / 20_000.0;
        let ds = SyntheticSpec::multiclass(2_000, 20_000, 4096)
            .teacher(ltls::data::synthetic::TeacherKind::Nonlinear)
            .density(density)
            .seed(9)
            .generate();
        let mut tr = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
        tr.fit(&ds, 1);
        let mut i = 0usize;
        let mut metrics = ltls::train::metrics::EpochMetrics::default();
        bench.run(&format!("sgd step  nnz~{nnz}"), || {
            i = (i + 1) % ds.n_examples();
            tr.step(ds.row(i), ds.labels_of(i), &mut metrics)
        });
    }

    // The engine side of the same model: per-example predict (allocating
    // vs scratch-reusing) and batched edge scoring.
    Bench::header("inference through the engine (C=4096, D=20000, nnz~40)");
    let ds = SyntheticSpec::multiclass(2_000, 20_000, 4096)
        .teacher(ltls::data::synthetic::TeacherKind::Nonlinear)
        .density(40.0 / 20_000.0)
        .seed(10)
        .generate();
    let mut tr = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
    tr.fit(&ds, 1);
    let model = tr.into_model();
    let mut i = 0usize;
    bench.run("predict_topk k=5       (alloc)", || {
        i = (i + 1) % ds.n_examples();
        model.predict_topk(ds.row(i), 5)
    });
    let mut scratch = ltls::engine::PredictScratch::new();
    let mut out = Vec::new();
    bench.run("predict_topk_into k=5  (engine)", || {
        i = (i + 1) % ds.n_examples();
        model.predict_topk_into(ds.row(i), 5, &mut scratch, &mut out);
        out.len()
    });
    let rows: Vec<ltls::sparse::SparseVec> = (0..64).map(|r| ds.row(r)).collect();
    bench.run("edge_scores x64        (per-example)", || {
        let mut acc = 0.0f32;
        for x in &rows {
            model.model.edge_scores(*x, &mut scratch.h);
            acc += scratch.h[0];
        }
        acc
    });
    let mut gather = Vec::new();
    let mut batch_h = Vec::new();
    bench.run("edge_scores_batch B=64 (one sweep)", || {
        model.model.edge_scores_batch(&rows, &mut gather, &mut batch_h);
        batch_h.len()
    });
}
