//! `O(log C)` scoring of known labels (paper §5: "Getting a score
//! F(·, s(ℓ), w) for a given label ℓ is O(E)").

use crate::graph::Topology;

/// Score one label's path: sum of its edge scores. Works over any
/// [`Topology`] through its label → edge-set codec.
pub fn score_label<T: Topology>(t: &T, h: &[f32], label: u64) -> f32 {
    t.edges_of_label(label).iter().map(|&e| h[e as usize]).sum()
}

/// Score several labels (multilabel positives; |P| ≪ C).
pub fn score_labels<T: Topology>(t: &T, h: &[f32], labels: &[u64]) -> Vec<f32> {
    labels.iter().map(|&l| score_label(t, h, l)).collect()
}

/// Out-parameter twin of [`score_labels`]: resolves each label's edge set
/// through the caller-owned `edges` scratch, so repeated calls perform no
/// steady-state allocation (the per-call pattern of the serving loop).
pub fn score_labels_into<T: Topology>(
    t: &T,
    h: &[f32],
    labels: &[u64],
    edges: &mut Vec<u32>,
    out: &mut Vec<f32>,
) {
    out.clear();
    for &l in labels {
        t.edges_of_label_into(l, edges);
        out.push(edges.iter().map(|&e| h[e as usize]).sum());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::pathmat::PathMatrix;
    use crate::graph::Trellis;
    use crate::util::rng::Rng;

    #[test]
    fn matches_dense_scores() {
        let mut rng = Rng::new(31);
        for c in [22u64, 105, 1000] {
            let t = Trellis::new(c);
            let m = PathMatrix::materialize(&t);
            let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
            let f = m.decode(&h);
            for l in 0..c {
                assert!((score_label(&t, &h, l) - f[l as usize]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn batch_scoring_matches_single() {
        let mut rng = Rng::new(32);
        let t = Trellis::new(3956);
        let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
        let labels = [0u64, 7, 1999, 3955];
        let batch = score_labels(&t, &h, &labels);
        for (i, &l) in labels.iter().enumerate() {
            assert_eq!(batch[i], score_label(&t, &h, l));
        }
    }

    /// The `_into` variant is bit-identical to the allocating one and
    /// reuses the caller's scratch.
    #[test]
    fn into_variant_matches_allocating() {
        let mut rng = Rng::new(33);
        let t = Trellis::new(12294);
        let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
        let labels = [0u64, 1, 4095, 4096, 12293];
        let want = score_labels(&t, &h, &labels);
        let (mut edges, mut got) = (Vec::new(), Vec::new());
        score_labels_into(&t, &h, &labels, &mut edges, &mut got);
        assert_eq!(got, want);
        // Second call reuses capacity; results stay identical.
        score_labels_into(&t, &h, &labels, &mut edges, &mut got);
        assert_eq!(got, want);
    }
}
