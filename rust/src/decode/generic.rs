//! Width-generic dynamic-programming decoders over any [`Topology`]
//! (paper §3/§5 generalized to W states per step, after Evron et al. 2018).
//!
//! These are the decode engines for [`crate::graph::WideTrellis`] (and any
//! future topology): top-1 Viterbi in `O(E)`, top-k list-Viterbi in
//! `O(k·E·log(Wk))`, and forward–backward in `O(E)`. The canonical width-2
//! [`crate::graph::Trellis`] keeps its register-specialized kernels (see
//! [`Topology::as_binary`]); `rust/tests/wide_parity.rs` pins the two code
//! paths path-for-path identical at `W = 2`.
//!
//! All DP state lives in the caller's [`DecodeWorkspace`] (the `w*`
//! buffers), so decoding is allocation-free after warm-up — the same
//! engine contract as the width-2 kernels.

use super::Scored;
use crate::engine::DecodeWorkspace;
use crate::graph::topology::{ExitGroup, Topology};
use crate::util::{logaddexp, logsumexp};

/// Order-independent best-candidate fold: max score, smaller label on ties
/// (the ordering of the dense `PathMatrix::topk` oracle).
#[inline]
fn consider(best: &mut Option<(f32, u64)>, score: f32, label: u64) {
    let better = match best {
        None => true,
        Some((s, l)) => score > *s || (score == *s && label < *l),
    };
    if better {
        *best = Some((score, label));
    }
}

/// Label of the exit at `state s` of `group`, given the packed mixed-radix
/// prefix code of the state-s DP cell at the group's step (`pv = W^(step−1)`).
#[inline]
fn exit_label(g: &ExitGroup, s: u32, code: u64, pv: u64) -> u64 {
    let prefix = code - s as u64 * pv;
    debug_assert!(prefix < g.paths_per_state);
    g.label_base + (s as u64 - 1) * g.paths_per_state + prefix
}

/// Top-1 Viterbi over a width-W topology, on the workspace's generic DP
/// registers. Allocation-free after warm-up.
pub fn viterbi_generic<T: Topology>(t: &T, h: &[f32], ws: &mut DecodeWorkspace) -> Scored {
    debug_assert_eq!(h.len(), t.num_edges());
    let w = t.width() as usize;
    let wu = t.width() as u64;
    let b = t.steps();

    ws.wscore.clear();
    ws.wcode.clear();
    for s in 0..w {
        ws.wscore.push(h[t.source(s as u32) as usize]);
        ws.wcode.push(s as u64);
    }

    let groups = t.exit_groups();
    let mut gi = 0usize;
    let mut pv = 1u64; // W^(j−1) while at step j
    let mut best: Option<(f32, u64)> = None;

    if gi < groups.len() && groups[gi].step == 1 {
        let g = &groups[gi];
        for s in 1..=g.digit {
            let label = exit_label(g, s, ws.wcode[s as usize], pv);
            let score = ws.wscore[s as usize] + h[(g.edge_base + s - 1) as usize];
            consider(&mut best, score, label);
        }
        gi += 1;
    }

    for j in 2..=b {
        pv *= wu;
        // Vectorized max+argmax: instead of, per target state, scanning W
        // strided predecessor edges, fold one predecessor at a time across
        // its contiguous target row `h[row..row + W]`
        // ([`Topology::transition_row`] layout contract). Folding
        // predecessors in ascending order with a strict `>` reproduces the
        // scalar loop's tie-break (earliest predecessor wins) exactly.
        ws.wscore_next.clear();
        ws.wscore_next.resize(w, f32::NEG_INFINITY);
        ws.wcode_next.clear();
        ws.wcode_next.resize(w, 0);
        for a in 0..w {
            let row = t.transition_row(j, a as u32) as usize;
            debug_assert_eq!(t.transition(j, a as u32, (w - 1) as u32) as usize, row + w - 1);
            crate::kernel::viterbi_fold(
                &mut ws.wscore_next,
                &mut ws.wcode_next,
                ws.wscore[a],
                ws.wcode[a],
                &h[row..row + w],
            );
        }
        for (ts, c) in ws.wcode_next.iter_mut().enumerate() {
            *c += ts as u64 * pv;
        }
        std::mem::swap(&mut ws.wscore, &mut ws.wscore_next);
        std::mem::swap(&mut ws.wcode, &mut ws.wcode_next);

        if gi < groups.len() && groups[gi].step == j {
            let g = &groups[gi];
            for s in 1..=g.digit {
                let label = exit_label(g, s, ws.wcode[s as usize], pv);
                let score = ws.wscore[s as usize] + h[(g.edge_base + s - 1) as usize];
                consider(&mut best, score, label);
            }
            gi += 1;
        }
    }

    // Full paths: every (aux copy m, final state s) pair.
    let full_per_sink = pv * wu; // W^b
    for m in 0..t.n_aux_sinks() {
        let sink = h[t.aux_sink(m) as usize];
        for s in 0..w {
            let total = ws.wscore[s] + h[t.aux(s as u32) as usize] + sink;
            consider(&mut best, total, m as u64 * full_per_sink + ws.wcode[s]);
        }
    }

    let (score, label) = best.expect("trellis always has paths");
    Scored { label, score }
}

/// Emit the exit completions of the current per-state k-best lists at the
/// group for step `j` (if any) into `out`.
#[allow(clippy::too_many_arguments)]
fn push_exits_generic(
    groups: &[ExitGroup],
    gi: &mut usize,
    j: u32,
    pv: u64,
    h: &[f32],
    k: usize,
    lists: &[Vec<(f32, u64)>],
    out: &mut Vec<Scored>,
) {
    if *gi < groups.len() && groups[*gi].step == j {
        let g = &groups[*gi];
        for s in 1..=g.digit {
            let edge = h[(g.edge_base + s - 1) as usize];
            for &(score, code) in lists[s as usize].iter().take(k) {
                out.push(Scored { label: exit_label(g, s, code, pv), score: score + edge });
            }
        }
        *gi += 1;
    }
}

/// Top-k list-Viterbi over a width-W topology into `out`, descending by
/// score (ties → smaller label). `out` receives `min(k, C)` results.
/// Allocation-free after warm-up.
pub fn list_viterbi_generic<T: Topology>(
    t: &T,
    h: &[f32],
    k: usize,
    ws: &mut DecodeWorkspace,
    out: &mut Vec<Scored>,
) {
    debug_assert_eq!(h.len(), t.num_edges());
    out.clear();
    if k == 0 {
        return;
    }
    let k = k.min(t.c() as usize);
    let w = t.width() as usize;
    let wu = t.width() as u64;
    let b = t.steps();

    if ws.wlists.len() < w {
        ws.wlists.resize_with(w, Vec::new);
    }
    if ws.wnext.len() < w {
        ws.wnext.resize_with(w, Vec::new);
    }
    for s in 0..w {
        ws.wlists[s].clear();
        ws.wlists[s].push((h[t.source(s as u32) as usize], s as u64));
    }

    let groups = t.exit_groups();
    let mut gi = 0usize;
    let mut pv = 1u64;
    push_exits_generic(groups, &mut gi, 1, pv, h, k, &ws.wlists, out);

    for j in 2..=b {
        pv *= wu;
        for ts in 0..w {
            // Gather all predecessor candidates, keep the k best. Sorted by
            // (score desc, code asc) so truncation ties resolve to the
            // smaller prefix code, matching the final output ordering.
            ws.wcand.clear();
            for a in 0..w {
                let e = h[t.transition(j, a as u32, ts as u32) as usize];
                for &(score, code) in ws.wlists[a].iter().take(k) {
                    ws.wcand.push((score + e, code));
                }
            }
            ws.wcand
                .sort_unstable_by(|x, y| y.0.partial_cmp(&x.0).unwrap().then(x.1.cmp(&y.1)));
            ws.wcand.truncate(k);
            let dst = &mut ws.wnext[ts];
            dst.clear();
            dst.extend(ws.wcand.iter().map(|&(score, code)| (score, code + ts as u64 * pv)));
        }
        std::mem::swap(&mut ws.wlists, &mut ws.wnext);
        push_exits_generic(groups, &mut gi, j, pv, h, k, &ws.wlists, out);
    }

    let full_per_sink = pv * wu;
    for m in 0..t.n_aux_sinks() {
        let sink = h[t.aux_sink(m) as usize];
        for s in 0..w {
            let add = h[t.aux(s as u32) as usize] + sink;
            for &(score, code) in ws.wlists[s].iter().take(k) {
                out.push(Scored {
                    label: m as u64 * full_per_sink + code,
                    score: score + add,
                });
            }
        }
    }

    out.sort_by(|a, b| b.score.partial_cmp(&a.score).unwrap().then(a.label.cmp(&b.label)));
    out.dedup_by_key(|s| s.label); // labels are distinct; belt & braces
    out.truncate(k);
}

/// Forward pass over a width-W topology: fills `ws.walpha`
/// (`walpha[(j−1)·W + s]` = log-sum of prefix scores into (step j, state
/// s)), `ws.exit_terms` and `ws.terms` (exit terms then full terms, the
/// width-2 kernel's order); returns `log Z`.
fn forward_generic<T: Topology>(t: &T, h: &[f32], ws: &mut DecodeWorkspace) -> f32 {
    let w = t.width() as usize;
    let b = t.steps() as usize;

    ws.walpha.clear();
    for s in 0..w {
        ws.walpha.push(h[t.source(s as u32) as usize]);
    }
    for j in 2..=b as u32 {
        let base = (j as usize - 2) * w;
        for ts in 0..w {
            ws.wtmp.clear();
            for a in 0..w {
                ws.wtmp
                    .push(ws.walpha[base + a] + h[t.transition(j, a as u32, ts as u32) as usize]);
            }
            let v = logsumexp(&ws.wtmp);
            ws.walpha.push(v);
        }
    }

    ws.exit_terms.clear();
    for g in t.exit_groups() {
        let row = (g.step as usize - 1) * w;
        for s in 1..=g.digit {
            ws.exit_terms
                .push(ws.walpha[row + s as usize] + h[(g.edge_base + s - 1) as usize]);
        }
    }

    ws.terms.clear();
    ws.terms.extend_from_slice(&ws.exit_terms);
    let last = (b - 1) * w;
    for m in 0..t.n_aux_sinks() {
        let sink = h[t.aux_sink(m) as usize];
        for s in 0..w {
            ws.terms.push(ws.walpha[last + s] + h[t.aux(s as u32) as usize] + sink);
        }
    }
    logsumexp(&ws.terms)
}

/// Log-partition function over a width-W topology. Allocation-free after
/// warm-up.
pub fn log_partition_generic<T: Topology>(t: &T, h: &[f32], ws: &mut DecodeWorkspace) -> f32 {
    forward_generic(t, h, ws)
}

/// Posterior edge marginals over a width-W topology into `out` (length E).
/// Allocation-free after warm-up.
pub fn posterior_marginals_generic<T: Topology>(
    t: &T,
    h: &[f32],
    ws: &mut DecodeWorkspace,
    out: &mut Vec<f32>,
) {
    let w = t.width() as usize;
    let b = t.steps() as usize;
    let logz = forward_generic(t, h, ws);

    // Backward pass: wbeta[(j−1)·W + s] = log-sum over suffixes from
    // (step j, state s) to the sink, including terminal edges.
    ws.wbeta.clear();
    ws.wbeta.resize(b * w, f32::NEG_INFINITY);
    ws.wtmp.clear();
    for m in 0..t.n_aux_sinks() {
        ws.wtmp.push(h[t.aux_sink(m) as usize]);
    }
    let sink_sum = logsumexp(&ws.wtmp);
    let last = (b - 1) * w;
    for s in 0..w {
        ws.wbeta[last + s] = h[t.aux(s as u32) as usize] + sink_sum;
    }
    for g in t.exit_groups() {
        let row = (g.step as usize - 1) * w;
        for s in 1..=g.digit {
            let cell = &mut ws.wbeta[row + s as usize];
            *cell = logaddexp(*cell, h[(g.edge_base + s - 1) as usize]);
        }
    }
    for j in (1..b).rev() {
        let step = (j + 1) as u32;
        for a in 0..w {
            ws.wtmp.clear();
            for ts in 0..w {
                ws.wtmp
                    .push(h[t.transition(step, a as u32, ts as u32) as usize] + ws.wbeta[j * w + ts]);
            }
            let v = logsumexp(&ws.wtmp);
            let cell = &mut ws.wbeta[(j - 1) * w + a];
            *cell = logaddexp(*cell, v);
        }
    }

    out.clear();
    out.resize(t.num_edges(), 0.0);
    for s in 0..w {
        let e = t.source(s as u32) as usize;
        out[e] = (h[e] + ws.wbeta[s] - logz).exp();
    }
    for j in 2..=b as u32 {
        for a in 0..w {
            for ts in 0..w {
                let e = t.transition(j, a as u32, ts as u32) as usize;
                out[e] = (ws.walpha[(j as usize - 2) * w + a]
                    + h[e]
                    + ws.wbeta[(j as usize - 1) * w + ts]
                    - logz)
                    .exp();
            }
        }
    }
    // Aux collectors and the parallel aux→sink copies.
    for m in 0..t.n_aux_sinks() {
        let sink_e = t.aux_sink(m) as usize;
        let mut total = 0.0f32;
        for s in 0..w {
            let p = (ws.walpha[last + s] + h[t.aux(s as u32) as usize] + h[sink_e] - logz).exp();
            out[t.aux(s as u32) as usize] += p;
            total += p;
        }
        out[sink_e] = total;
    }
    // Exit edges.
    let mut ti = 0usize;
    for g in t.exit_groups() {
        for s in 1..=g.digit {
            out[(g.edge_base + s - 1) as usize] = (ws.exit_terms[ti] - logz).exp();
            ti += 1;
        }
    }
}
