//! Forward–backward over the trellis (paper §5): the log-partition
//! function `log Σ_ℓ exp(F(x, s(ℓ)))` in `O(E)`, and per-edge posterior
//! marginals `P(e ∈ s | x)` for multinomial-logistic training.
//!
//! The gradient of the trellis-softmax loss w.r.t. edge scores is
//! `∂L/∂h_e = P(e ∈ s) − 1[e ∈ s(y)]`, so these marginals are exactly the
//! backprop signal for the deep variant (the same math `python/compile`
//! gets from JAX autodiff; this rust twin is used for CPU training, for
//! testing the JAX artifact, and for calibrated probability outputs).
//!
//! The cores are [`log_partition_ws`] and [`posterior_marginals_into`],
//! which run on a caller-owned [`DecodeWorkspace`] (alpha/beta tables)
//! and allocate nothing after warm-up; the classic allocating functions
//! are thin wrappers.

use crate::engine::DecodeWorkspace;
use crate::graph::{Topology, Trellis};
use crate::util::{logaddexp, logsumexp};

/// Terminal quantities of the forward pass (alpha and per-exit terms live
/// in the workspace).
struct ForwardTerms {
    /// Log-sum over complete paths.
    logz: f32,
    /// `full_terms[s] = alpha[b-1][s]` + aux edge s + aux_sink.
    full_terms: [f32; 2],
}

/// Forward pass: fills `ws.alpha` (`alpha[j-1][s]` = log-sum of prefix
/// scores into (step j, state s)) and `ws.exit_terms`, returns the
/// terminal sums.
fn forward_into(t: &Trellis, h: &[f32], ws: &mut DecodeWorkspace) -> ForwardTerms {
    let b = t.steps as usize;
    ws.alpha.clear();
    ws.alpha.reserve(b);
    ws.alpha.push([h[t.source_edge(0) as usize], h[t.source_edge(1) as usize]]);
    for j in 2..=b as u32 {
        let prev = *ws.alpha.last().unwrap();
        let a0 = logaddexp(
            prev[0] + h[t.transition_edge(j, 0, 0) as usize],
            prev[1] + h[t.transition_edge(j, 1, 0) as usize],
        );
        let a1 = logaddexp(
            prev[0] + h[t.transition_edge(j, 0, 1) as usize],
            prev[1] + h[t.transition_edge(j, 1, 1) as usize],
        );
        ws.alpha.push([a0, a1]);
    }
    ws.exit_terms.clear();
    for (k, &bit) in t.exit_bits().iter().enumerate() {
        let j = bit as usize; // step = bit+1 → alpha index = bit
        ws.exit_terms.push(ws.alpha[j][1] + h[t.exit_edge(k) as usize]);
    }
    let aux_sink = h[t.aux_sink_edge() as usize];
    let full_terms = [
        ws.alpha[b - 1][0] + h[t.aux_edge(0) as usize] + aux_sink,
        ws.alpha[b - 1][1] + h[t.aux_edge(1) as usize] + aux_sink,
    ];
    ws.terms.clear();
    ws.terms.extend_from_slice(&ws.exit_terms);
    ws.terms.extend_from_slice(&full_terms);
    ForwardTerms { logz: logsumexp(&ws.terms), full_terms }
}

/// Log-partition function `log Σ_paths exp(path score)` reusing the
/// workspace, over any [`Topology`] (width-2 dispatches to the specialized
/// kernel). Allocation-free after warm-up.
pub fn log_partition_ws<T: Topology>(t: &T, h: &[f32], ws: &mut DecodeWorkspace) -> f32 {
    match t.as_binary() {
        Some(bt) => forward_into(bt, h, ws).logz,
        None => super::generic::log_partition_generic(t, h, ws),
    }
}

/// Allocating wrapper over [`log_partition_ws`].
pub fn log_partition<T: Topology>(t: &T, h: &[f32]) -> f32 {
    log_partition_ws(t, h, &mut DecodeWorkspace::new())
}

/// Posterior edge marginals `P(e ∈ s | x)` under the trellis softmax,
/// written into `out` (length `E`, summing per edge-cut to 1), reusing
/// the workspace's alpha/beta tables, over any [`Topology`].
/// Allocation-free after warm-up.
pub fn posterior_marginals_into<T: Topology>(
    t: &T,
    h: &[f32],
    ws: &mut DecodeWorkspace,
    out: &mut Vec<f32>,
) {
    match t.as_binary() {
        Some(bt) => posterior_marginals_binary_into(bt, h, ws, out),
        None => super::generic::posterior_marginals_generic(t, h, ws, out),
    }
}

/// The width-2 specialized backward pass + marginal assembly.
pub(crate) fn posterior_marginals_binary_into(
    t: &Trellis,
    h: &[f32],
    ws: &mut DecodeWorkspace,
    out: &mut Vec<f32>,
) {
    let b = t.steps as usize;
    let f = forward_into(t, h, ws);
    let logz = f.logz;

    // Backward pass: beta[j][s] = log-sum over suffixes from (step j, s)
    // to the sink (including terminal edges), indexed beta[j-1][s].
    ws.beta.clear();
    ws.beta.resize(b, [f32::NEG_INFINITY; 2]);
    let aux_sink = h[t.aux_sink_edge() as usize];
    ws.beta[b - 1] = [
        h[t.aux_edge(0) as usize] + aux_sink,
        h[t.aux_edge(1) as usize] + aux_sink,
    ];
    // Terminal exits contribute to beta at their step.
    for (k, &bit) in t.exit_bits().iter().enumerate() {
        let j = bit as usize; // step bit+1 → beta index bit
        ws.beta[j][1] = logaddexp(ws.beta[j][1], h[t.exit_edge(k) as usize]);
    }
    for j in (1..b).rev() {
        // beta for step j (index j-1) from step j+1 (index j).
        let step = (j + 1) as u32;
        for a in 0..2usize {
            let v = logaddexp(
                h[t.transition_edge(step, a as u8, 0) as usize] + ws.beta[j][0],
                h[t.transition_edge(step, a as u8, 1) as usize] + ws.beta[j][1],
            );
            ws.beta[j - 1][a] = logaddexp(ws.beta[j - 1][a], v);
        }
    }

    out.clear();
    out.resize(t.num_edges(), 0.0);
    // Source edges.
    for s in 0..2usize {
        out[t.source_edge(s as u8) as usize] =
            (h[t.source_edge(s as u8) as usize] + ws.beta[0][s] - logz).exp();
    }
    // Transition edges.
    for j in 2..=b as u32 {
        for a in 0..2usize {
            for s2 in 0..2usize {
                let e = t.transition_edge(j, a as u8, s2 as u8) as usize;
                out[e] =
                    (ws.alpha[j as usize - 2][a] + h[e] + ws.beta[j as usize - 1][s2] - logz).exp();
            }
        }
    }
    // Aux edges + aux_sink.
    let mut aux_total = 0.0;
    for s in 0..2usize {
        let p = (f.full_terms[s] - logz).exp();
        out[t.aux_edge(s as u8) as usize] = p;
        aux_total += p;
    }
    out[t.aux_sink_edge() as usize] = aux_total;
    // Exit edges.
    for k in 0..t.exit_bits().len() {
        out[t.exit_edge(k) as usize] = (ws.exit_terms[k] - logz).exp();
    }
}

/// Allocating wrapper over [`posterior_marginals_into`].
pub fn posterior_marginals<T: Topology>(t: &T, h: &[f32]) -> Vec<f32> {
    let mut out = Vec::new();
    posterior_marginals_into(t, h, &mut DecodeWorkspace::new(), &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::codec::path_of_label;
    use crate::graph::pathmat::PathMatrix;
    use crate::util::rng::Rng;

    /// logZ equals the brute-force log-sum over all C path scores.
    #[test]
    fn log_partition_matches_bruteforce() {
        let mut rng = Rng::new(41);
        for c in [2u64, 3, 22, 105, 159, 1000] {
            let t = Trellis::new(c);
            let m = PathMatrix::materialize(&t);
            for _ in 0..10 {
                let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
                let scores = m.decode(&h);
                let want = crate::util::logsumexp(&scores);
                let got = log_partition(&t, &h);
                assert!((got - want).abs() < 1e-3, "C={c}: {got} vs {want}");
            }
        }
    }

    /// A reused workspace is bit-identical to fresh calls across shapes.
    #[test]
    fn reused_workspace_matches_fresh() {
        let mut rng = Rng::new(44);
        let mut ws = DecodeWorkspace::new();
        let mut out = Vec::new();
        for c in [2u64, 3, 22, 105, 12294, 159] {
            let t = Trellis::new(c);
            let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
            assert_eq!(log_partition_ws(&t, &h, &mut ws), log_partition(&t, &h), "C={c}");
            posterior_marginals_into(&t, &h, &mut ws, &mut out);
            assert_eq!(out, posterior_marginals(&t, &h), "C={c}");
        }
    }

    /// Marginals equal the brute-force posterior Σ_ℓ p(ℓ)·1[e ∈ s(ℓ)].
    #[test]
    fn marginals_match_bruteforce() {
        let mut rng = Rng::new(42);
        for c in [2u64, 3, 22, 105, 159] {
            let t = Trellis::new(c);
            let m = PathMatrix::materialize(&t);
            for _ in 0..5 {
                let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
                let scores = m.decode(&h);
                let logz = crate::util::logsumexp(&scores);
                let probs: Vec<f32> = scores.iter().map(|s| (s - logz).exp()).collect();
                let mut want = vec![0.0f32; t.num_edges()];
                for l in 0..c {
                    for e in path_of_label(&t, l).edges(&t) {
                        want[e as usize] += probs[l as usize];
                    }
                }
                let got = posterior_marginals(&t, &h);
                for e in 0..t.num_edges() {
                    assert!(
                        (got[e] - want[e]).abs() < 1e-3,
                        "C={c} edge {e}: {} vs {}",
                        got[e],
                        want[e]
                    );
                }
            }
        }
    }

    /// Marginals are in [0,1]; source pair sums to 1; aux_sink + exits = 1.
    #[test]
    fn marginals_are_probabilities() {
        let mut rng = Rng::new(43);
        for c in [22u64, 105, 12294] {
            let t = Trellis::new(c);
            let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
            let m = posterior_marginals(&t, &h);
            for &v in &m {
                assert!((-1e-4..=1.0 + 1e-4).contains(&v));
            }
            let src = m[t.source_edge(0) as usize] + m[t.source_edge(1) as usize];
            assert!((src - 1.0).abs() < 1e-3, "C={c} src={src}");
            let mut terminal = m[t.aux_sink_edge() as usize];
            for k in 0..t.exit_bits().len() {
                terminal += m[t.exit_edge(k) as usize];
            }
            assert!((terminal - 1.0).abs() < 1e-3, "C={c} terminal={terminal}");
        }
    }

    /// Softmax probability of the Viterbi winner dominates when its path
    /// score is boosted.
    #[test]
    fn boosted_path_dominates_posterior() {
        let t = Trellis::new(105);
        let mut h = vec![0.0f32; t.num_edges()];
        for e in crate::graph::codec::edges_of_label(&t, 42) {
            h[e as usize] = 8.0;
        }
        let logz = log_partition(&t, &h);
        let p42 = (crate::decode::score_label(&t, &h, 42) - logz).exp();
        assert!(p42 > 0.95, "p={p42}");
    }
}
