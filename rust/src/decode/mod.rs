//! Dynamic-programming decoders over the trellis (paper §3, §5).
//!
//! Given the edge-score vector `h ∈ R^E` produced by the underlying model,
//! these find the best / top-k scoring source→sink paths:
//!
//! * [`viterbi::viterbi`] — top-1 in `O(E)` (the paper's prediction op);
//! * [`list_viterbi::list_viterbi`] — top-k in `O(kE + k log k)` (used for
//!   multilabel prediction, the separation-ranking loss, and the label
//!   assignment policy);
//! * [`forward_backward`] — log-partition function and per-edge posterior
//!   marginals (the multinomial-logistic training mode of §5, and the
//!   gradient signal for the deep variant);
//! * [`score::score_label`] — score one known label's path in `O(log C)`.
//!
//! Each decoder has an `_into` variant ([`list_viterbi_into`],
//! [`posterior_marginals_into`], [`log_partition_ws`], [`viterbi_into`])
//! running on a caller-owned [`crate::engine::DecodeWorkspace`] with zero
//! steady-state allocation; the classic names are thin wrappers over them.
//!
//! Every entry point is generic over [`crate::graph::Topology`]: the
//! canonical width-2 [`crate::graph::Trellis`] dispatches to the
//! register-specialized kernels in this module, while the
//! width-parameterized [`crate::graph::WideTrellis`] (and any other
//! topology) runs the W-ary implementations in [`generic`]. The two code
//! paths are pinned path-for-path identical at `W = 2` by
//! `rust/tests/wide_parity.rs`.

pub mod forward_backward;
pub mod generic;
pub mod list_viterbi;
pub mod score;
pub mod viterbi;

pub use forward_backward::{
    log_partition, log_partition_ws, posterior_marginals, posterior_marginals_into,
};
pub use list_viterbi::{list_viterbi, list_viterbi_into};
pub use score::{score_label, score_labels, score_labels_into};
pub use viterbi::{viterbi, viterbi_into, viterbi_ws};

/// A decoded prediction: label (canonical path id) and its path score.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Scored {
    pub label: u64,
    pub score: f32,
}
