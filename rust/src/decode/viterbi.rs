//! Top-1 Viterbi decoding in `O(E)` (paper §3).
//!
//! The trellis has 2 states per step, so the DP state is just two running
//! scores plus backpointer bits packed in a `u64` — no allocation on the
//! hot path.

use super::Scored;
use crate::engine::DecodeWorkspace;
use crate::graph::codec::{label_of_path, Path};
use crate::graph::{Topology, Trellis};

/// Find the highest-scoring source→sink path for edge scores `h`, over any
/// topology. The canonical width-2 [`Trellis`] dispatches to the
/// register-specialized kernel below; other topologies run the generic
/// W-ary DP ([`crate::decode::generic`]).
///
/// Ties are broken toward the *smaller canonical label* so results are
/// deterministic and match the [`crate::graph::pathmat::PathMatrix::topk`]
/// oracle's ordering.
pub fn viterbi<T: Topology>(t: &T, h: &[f32]) -> Scored {
    viterbi_ws(t, h, &mut DecodeWorkspace::new())
}

/// Workspace variant of [`viterbi`]: the generic W-ary path keeps its DP
/// registers in `ws` and is allocation-free after warm-up (the width-2
/// kernel needs no buffers at all).
pub fn viterbi_ws<T: Topology>(t: &T, h: &[f32], ws: &mut DecodeWorkspace) -> Scored {
    match t.as_binary() {
        Some(bt) => viterbi_binary(bt, h),
        None => super::generic::viterbi_generic(t, h, ws),
    }
}

/// The width-2 specialized kernel: the DP state is two running scores plus
/// backpointer bits packed in a `u64` — no allocation on the hot path.
pub(crate) fn viterbi_binary(t: &Trellis, h: &[f32]) -> Scored {
    debug_assert_eq!(h.len(), t.num_edges());
    let b = t.steps;

    // DP over steps. score[s] = best score of a source→(step j, state s)
    // prefix; code[s] = the state choices of that prefix packed as bits
    // (bit j-1 = state at step j).
    let mut score = [h[t.source_edge(0) as usize], h[t.source_edge(1) as usize]];
    let mut code = [0u64, 1u64];

    // Early-exit candidates are collected as we sweep the steps.
    let mut best: Option<(f32, u64)> = None; // (score, label)
    let consider = |cand_score: f32, cand_label: u64, best: &mut Option<(f32, u64)>| {
        let better = match best {
            None => true,
            // Strict >: on ties keep the earlier candidate. We feed
            // candidates in ascending label order, so ties resolve to the
            // smaller label.
            Some((s, l)) => cand_score > *s || (cand_score == *s && cand_label < *l),
        };
        if better {
            *best = Some((cand_score, cand_label));
        }
    };

    let mut exit_rank = 0usize;
    // Exit at step 1 (bit 0), if present.
    if t.exit_bits().first() == Some(&0) {
        let lbl = t.exit_label_base(0); // zero free bits
        consider(score[1] + h[t.exit_edge(0) as usize], lbl, &mut best);
        exit_rank = 1;
    }

    for j in 2..=b {
        // The gap's four edges are contiguous and target-ordered
        // ([`Topology::transition_row`] layout contract): one 4-wide load
        // instead of four strided gathers. tr = [e00, e01, e10, e11].
        let base = t.transition_edge(j, 0, 0) as usize;
        debug_assert_eq!(t.transition_edge(j, 1, 1) as usize, base + 3);
        let tr: &[f32; 4] = h[base..base + 4].try_into().unwrap();
        // Branchless selects: `>=` keeps predecessor 0 on ties (the
        // smaller-label tie-break).
        let (v00, v01, v10, v11) =
            (score[0] + tr[0], score[0] + tr[1], score[1] + tr[2], score[1] + tr[3]);
        let take0 = v00 >= v10;
        let take1 = v01 >= v11;
        let hi = 1u64 << (j - 1);
        score = [if take0 { v00 } else { v10 }, if take1 { v01 } else { v11 }];
        code = [
            if take0 { code[0] } else { code[1] },
            if take1 { code[0] } else { code[1] } | hi,
        ];

        // Early exit leaving (step j, state 1) == exit bit j-1.
        if exit_rank < t.exit_bits().len() && t.exit_bits()[exit_rank] == j - 1 {
            let base = t.exit_label_base(exit_rank);
            // Free bits of the exit label = prefix states 1..j-1 = code
            // without bit j-1.
            let lbl = base + (code[1] & !(1u64 << (j - 1)));
            consider(score[1] + h[t.exit_edge(exit_rank) as usize], lbl, &mut best);
            exit_rank += 1;
        }
    }

    // Full paths through auxiliary → sink.
    let aux_sink = h[t.aux_sink_edge() as usize];
    for s in 0..2usize {
        let total = score[s] + h[t.aux_edge(s as u8) as usize] + aux_sink;
        consider(total, code[s], &mut best);
    }

    let (s, l) = best.expect("trellis always has paths");
    Scored { label: l, score: s }
}

/// Out-parameter twin of [`viterbi`] for API symmetry with the other
/// `_into` decoders. The width-2 kernel is allocation-free here (its DP
/// state is two score registers plus packed backpointer bits); wide
/// topologies need DP buffers, so hot loops over a `WideTrellis` should
/// call [`viterbi_ws`] with a reused workspace instead.
#[inline]
pub fn viterbi_into<T: Topology>(t: &T, h: &[f32], out: &mut Scored) {
    *out = viterbi(t, h);
}

/// Decode the best path object (states + exit) rather than just the label.
pub fn viterbi_path(t: &Trellis, h: &[f32]) -> (Path, f32) {
    let Scored { label, score } = viterbi(t, h);
    (crate::graph::codec::path_of_label(t, label), score)
}

/// Convenience wrapper asserting label round-trip in debug builds.
pub fn viterbi_label_checked(t: &Trellis, h: &[f32]) -> Scored {
    let r = viterbi(t, h);
    debug_assert_eq!(label_of_path(t, &crate::graph::codec::path_of_label(t, r.label)), r.label);
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::pathmat::PathMatrix;
    use crate::util::rng::Rng;

    /// Viterbi == dense-decode argmax oracle on random scores, many C.
    #[test]
    fn matches_dense_oracle() {
        let mut rng = Rng::new(11);
        for c in [2u64, 3, 4, 5, 22, 31, 32, 33, 105, 159, 255, 1000] {
            let t = Trellis::new(c);
            let m = PathMatrix::materialize(&t);
            for _ in 0..40 {
                let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
                let got = viterbi(&t, &h);
                let want = m.topk(&h, 1)[0];
                assert_eq!(got.label, want.0, "C={c}");
                assert!((got.score - want.1).abs() < 1e-4, "C={c}");
            }
        }
    }

    /// The returned score equals the direct path-sum of the label.
    #[test]
    fn score_is_path_sum() {
        let mut rng = Rng::new(12);
        for c in [22u64, 105, 12294, 320338] {
            let t = Trellis::new(c);
            for _ in 0..20 {
                let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
                let r = viterbi(&t, &h);
                let direct: f32 = crate::graph::codec::edges_of_label(&t, r.label)
                    .iter()
                    .map(|&e| h[e as usize])
                    .sum();
                assert!((r.score - direct).abs() < 1e-4, "C={c}");
            }
        }
    }

    /// Uniform zero scores tie-break to label 0.
    #[test]
    fn zero_scores_pick_label_zero() {
        for c in [2u64, 22, 1000] {
            let t = Trellis::new(c);
            let h = vec![0.0; t.num_edges()];
            // All full paths tie at 0; exits tie lower edge count... also 0.
            // Deterministic tie-break must still yield a valid label;
            // the dense oracle breaks ties to the smallest label = 0.
            let m = PathMatrix::materialize(&t);
            assert_eq!(viterbi(&t, &h).label, m.topk(&h, 1)[0].0, "C={c}");
        }
    }

    /// Boosting one label's edges makes it win.
    #[test]
    fn boosted_label_wins() {
        let mut rng = Rng::new(13);
        for c in [22u64, 105, 1000] {
            let t = Trellis::new(c);
            for _ in 0..50 {
                let target = rng.below(c);
                let mut h = vec![0.0f32; t.num_edges()];
                for e in crate::graph::codec::edges_of_label(&t, target) {
                    h[e as usize] = 10.0 + rng.f32();
                }
                assert_eq!(viterbi(&t, &h).label, target, "C={c}");
            }
        }
    }

    /// Runs at extreme scale (C = 2^40-ish) in microseconds — log-time.
    #[test]
    fn extreme_scale_smoke() {
        let c = (1u64 << 40) + 12345;
        let t = Trellis::new(c);
        assert!(t.num_edges() < 200);
        let h: Vec<f32> = (0..t.num_edges()).map(|i| (i as f32 * 0.37).sin()).collect();
        let r = viterbi(&t, &h);
        assert!(r.label < c);
    }
}
