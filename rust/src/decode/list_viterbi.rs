//! Top-k decoding (list Viterbi, paper §3).
//!
//! Keeps the k best prefixes per (step, state); merging two sorted
//! predecessor lists per state is `O(k)` per step, so the total cost is
//! `O(k·E)` plus an `O(k log k)` final selection — the paper's
//! `O(k log(k) log(C))` bound.
//!
//! The core is [`list_viterbi_into`], which runs on a caller-owned
//! [`DecodeWorkspace`] and allocates nothing after warm-up; the classic
//! allocating [`list_viterbi`] is a thin wrapper over it.

use super::Scored;
use crate::engine::DecodeWorkspace;
use crate::graph::{Topology, Trellis};

/// Merge two descending `(score, code)` lists, each first adding
/// `add0` / `add1`, keeping the best `k`.
fn merge_topk(
    a: &[(f32, u64)],
    add0: f32,
    b: &[(f32, u64)],
    add1: f32,
    k: usize,
    out: &mut Vec<(f32, u64)>,
) {
    out.clear();
    let (mut i, mut j) = (0, 0);
    while out.len() < k && (i < a.len() || j < b.len()) {
        let ta = a.get(i).map(|e| e.0 + add0);
        let tb = b.get(j).map(|e| e.0 + add1);
        match (ta, tb) {
            (Some(sa), Some(sb)) => {
                if sa >= sb {
                    out.push((sa, a[i].1));
                    i += 1;
                } else {
                    out.push((sb, b[j].1));
                    j += 1;
                }
            }
            (Some(sa), None) => {
                out.push((sa, a[i].1));
                i += 1;
            }
            (None, Some(sb)) => {
                out.push((sb, b[j].1));
                j += 1;
            }
            (None, None) => unreachable!(),
        }
    }
}

/// If step `j` carries an early exit, emit the exit completions of the
/// current state-1 prefix list into `finals`.
fn push_exits(
    t: &Trellis,
    h: &[f32],
    k: usize,
    j: u32,
    list1: &[(f32, u64)],
    exit_rank: &mut usize,
    finals: &mut Vec<Scored>,
) {
    if *exit_rank < t.exit_bits().len() && t.exit_bits()[*exit_rank] == j - 1 {
        let base = t.exit_label_base(*exit_rank);
        let edge = h[t.exit_edge(*exit_rank) as usize];
        for &(score, code) in list1.iter().take(k) {
            // Free bits exclude the forced state-1 at step j.
            let label = base + (code & !(1u64 << (j - 1)));
            finals.push(Scored { label, score: score + edge });
        }
        *exit_rank += 1;
    }
}

/// Top-k highest-scoring paths for edge scores `h` into `out`, descending
/// by score (ties → smaller label), reusing the workspace buffers.
/// `out` receives `min(k, C)` results. Allocation-free after warm-up.
///
/// Works over any [`Topology`]: the width-2 [`Trellis`] dispatches to the
/// two-list merge kernel below; other widths run the generic W-ary beam
/// ([`crate::decode::generic`]).
pub fn list_viterbi_into<T: Topology>(
    t: &T,
    h: &[f32],
    k: usize,
    ws: &mut DecodeWorkspace,
    out: &mut Vec<Scored>,
) {
    match t.as_binary() {
        Some(bt) => list_viterbi_binary_into(bt, h, k, ws, out),
        None => super::generic::list_viterbi_generic(t, h, k, ws, out),
    }
}

/// The width-2 specialized kernel (two sorted per-state lists, O(k) merge
/// per step).
pub(crate) fn list_viterbi_binary_into(
    t: &Trellis,
    h: &[f32],
    k: usize,
    ws: &mut DecodeWorkspace,
    out: &mut Vec<Scored>,
) {
    debug_assert_eq!(h.len(), t.num_edges());
    out.clear();
    if k == 0 {
        return;
    }
    let k = k.min(t.c as usize);
    let b = t.steps;

    // Per-state k-best prefix lists.
    ws.list0.clear();
    ws.list0.push((h[t.source_edge(0) as usize], 0));
    ws.list1.clear();
    ws.list1.push((h[t.source_edge(1) as usize], 1));
    let mut exit_rank = 0usize;

    push_exits(t, h, k, 1, &ws.list1, &mut exit_rank, out);

    for j in 2..=b {
        let e00 = h[t.transition_edge(j, 0, 0) as usize];
        let e01 = h[t.transition_edge(j, 0, 1) as usize];
        let e10 = h[t.transition_edge(j, 1, 0) as usize];
        let e11 = h[t.transition_edge(j, 1, 1) as usize];
        merge_topk(&ws.list0, e00, &ws.list1, e10, k, &mut ws.next0);
        merge_topk(&ws.list0, e01, &ws.list1, e11, k, &mut ws.next1);
        for e in ws.next1.iter_mut() {
            e.1 |= 1 << (j - 1);
        }
        std::mem::swap(&mut ws.list0, &mut ws.next0);
        std::mem::swap(&mut ws.list1, &mut ws.next1);
        push_exits(t, h, k, j, &ws.list1, &mut exit_rank, out);
    }

    // Full paths: through aux state edges + aux→sink.
    let aux_sink = h[t.aux_sink_edge() as usize];
    for (list, s) in [(&ws.list0, 0u8), (&ws.list1, 1u8)] {
        let add = h[t.aux_edge(s) as usize] + aux_sink;
        for &(score, code) in list.iter().take(k) {
            out.push(Scored { label: code, score: score + add });
        }
    }

    out.sort_by(|a, b| {
        b.score.partial_cmp(&a.score).unwrap().then(a.label.cmp(&b.label))
    });
    out.dedup_by_key(|s| s.label); // codes are distinct; belt & braces
    out.truncate(k);
}

/// Allocating wrapper over [`list_viterbi_into`]: top-k highest-scoring
/// paths, descending by score (ties → smaller label). Returns
/// `min(k, C)` results.
pub fn list_viterbi<T: Topology>(t: &T, h: &[f32], k: usize) -> Vec<Scored> {
    let mut ws = DecodeWorkspace::new();
    let mut out = Vec::new();
    list_viterbi_into(t, h, k, &mut ws, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::pathmat::PathMatrix;
    use crate::util::rng::Rng;

    /// list_viterbi == dense top-k oracle on random scores, many (C, k).
    #[test]
    fn matches_dense_oracle() {
        let mut rng = Rng::new(21);
        for c in [2u64, 3, 5, 22, 105, 159, 255, 256, 1000] {
            let t = Trellis::new(c);
            let m = PathMatrix::materialize(&t);
            for &k in &[1usize, 2, 5, 16] {
                for _ in 0..15 {
                    let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
                    let got = list_viterbi(&t, &h, k);
                    let want = m.topk(&h, k);
                    assert_eq!(got.len(), want.len(), "C={c} k={k}");
                    for (g, w) in got.iter().zip(&want) {
                        assert_eq!(g.label, w.0, "C={c} k={k}");
                        assert!((g.score - w.1).abs() < 1e-4, "C={c} k={k}");
                    }
                }
            }
        }
    }

    /// A reused workspace produces bit-identical results to fresh calls,
    /// across interleaved (C, k) shapes.
    #[test]
    fn reused_workspace_matches_fresh() {
        let mut rng = Rng::new(25);
        let mut ws = DecodeWorkspace::new();
        let mut out = Vec::new();
        for _ in 0..30 {
            let c = 2 + rng.below(5000);
            let t = Trellis::new(c);
            let k = 1 + rng.index(20);
            let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
            list_viterbi_into(&t, &h, k, &mut ws, &mut out);
            assert_eq!(out, list_viterbi(&t, &h, k), "C={c} k={k}");
        }
    }

    /// k ≥ C returns all C paths, each exactly once.
    #[test]
    fn k_at_least_c_enumerates_all() {
        let mut rng = Rng::new(22);
        for c in [2u64, 3, 22, 105] {
            let t = Trellis::new(c);
            let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
            let got = list_viterbi(&t, &h, c as usize + 10);
            assert_eq!(got.len(), c as usize);
            let mut labels: Vec<u64> = got.iter().map(|s| s.label).collect();
            labels.sort_unstable();
            labels.dedup();
            assert_eq!(labels.len(), c as usize, "C={c}");
        }
    }

    /// Top-1 of list equals plain Viterbi.
    #[test]
    fn top1_consistent_with_viterbi() {
        let mut rng = Rng::new(23);
        for c in [22u64, 1000, 12294] {
            let t = Trellis::new(c);
            for _ in 0..20 {
                let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
                let lv = list_viterbi(&t, &h, 4);
                let v = super::super::viterbi(&t, &h);
                assert_eq!(lv[0].label, v.label, "C={c}");
                assert!((lv[0].score - v.score).abs() < 1e-4);
            }
        }
    }

    /// Scores are non-increasing.
    #[test]
    fn scores_sorted_descending() {
        let mut rng = Rng::new(24);
        let t = Trellis::new(320338);
        let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
        let got = list_viterbi(&t, &h, 50);
        assert_eq!(got.len(), 50);
        for w in got.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    /// k=0 is empty (and clears a dirty out-buffer).
    #[test]
    fn k_zero_is_empty() {
        let t = Trellis::new(22);
        assert!(list_viterbi(&t, &vec![0.0; t.num_edges()], 0).is_empty());
        let mut ws = DecodeWorkspace::new();
        let mut out = vec![Scored { label: 9, score: 9.0 }];
        list_viterbi_into(&t, &vec![0.0; t.num_edges()], 0, &mut ws, &mut out);
        assert!(out.is_empty());
    }
}
