//! Top-k decoding (list Viterbi, paper §3).
//!
//! Keeps the k best prefixes per (step, state); merging two sorted
//! predecessor lists per state is `O(k)` per step, so the total cost is
//! `O(k·E)` plus an `O(k log k)` final selection — the paper's
//! `O(k log(k) log(C))` bound.

use super::Scored;
use crate::graph::Trellis;

/// A DP entry: prefix score + packed state choices (bit j−1 = state at
/// step j).
#[derive(Clone, Copy, Debug)]
struct Entry {
    score: f32,
    code: u64,
}

/// Merge two descending entry lists, each first adding `add0` / `add1`,
/// keeping the best `k`.
fn merge_topk(a: &[Entry], add0: f32, b: &[Entry], add1: f32, k: usize, out: &mut Vec<Entry>) {
    out.clear();
    let (mut i, mut j) = (0, 0);
    while out.len() < k && (i < a.len() || j < b.len()) {
        let ta = a.get(i).map(|e| e.score + add0);
        let tb = b.get(j).map(|e| e.score + add1);
        match (ta, tb) {
            (Some(sa), Some(sb)) => {
                if sa >= sb {
                    out.push(Entry { score: sa, code: a[i].code });
                    i += 1;
                } else {
                    out.push(Entry { score: sb, code: b[j].code });
                    j += 1;
                }
            }
            (Some(sa), None) => {
                out.push(Entry { score: sa, code: a[i].code });
                i += 1;
            }
            (None, Some(sb)) => {
                out.push(Entry { score: sb, code: b[j].code });
                j += 1;
            }
            (None, None) => unreachable!(),
        }
    }
}

/// Top-k highest-scoring paths for edge scores `h`, descending by score
/// (ties → smaller label). Returns `min(k, C)` results.
pub fn list_viterbi(t: &Trellis, h: &[f32], k: usize) -> Vec<Scored> {
    debug_assert_eq!(h.len(), t.num_edges());
    if k == 0 {
        return Vec::new();
    }
    let k = k.min(t.c as usize);
    let b = t.steps;

    // Per-state k-best prefix lists.
    let mut list0 = vec![Entry { score: h[t.source_edge(0) as usize], code: 0 }];
    let mut list1 = vec![Entry { score: h[t.source_edge(1) as usize], code: 1 }];
    let mut finals: Vec<Scored> = Vec::new();
    let mut exit_rank = 0usize;

    let push_exits =
        |j: u32, list1: &[Entry], exit_rank: &mut usize, finals: &mut Vec<Scored>| {
            if *exit_rank < t.exit_bits().len() && t.exit_bits()[*exit_rank] == j - 1 {
                let base = t.exit_label_base(*exit_rank);
                let edge = h[t.exit_edge(*exit_rank) as usize];
                for e in list1.iter().take(k) {
                    // Free bits exclude the forced state-1 at step j.
                    let label = base + (e.code & !(1u64 << (j - 1)));
                    finals.push(Scored { label, score: e.score + edge });
                }
                *exit_rank += 1;
            }
        };

    push_exits(1, &list1, &mut exit_rank, &mut finals);

    let (mut next0, mut next1) = (Vec::with_capacity(k), Vec::with_capacity(k));
    for j in 2..=b {
        let e00 = h[t.transition_edge(j, 0, 0) as usize];
        let e01 = h[t.transition_edge(j, 0, 1) as usize];
        let e10 = h[t.transition_edge(j, 1, 0) as usize];
        let e11 = h[t.transition_edge(j, 1, 1) as usize];
        merge_topk(&list0, e00, &list1, e10, k, &mut next0);
        merge_topk(&list0, e01, &list1, e11, k, &mut next1);
        for e in next1.iter_mut() {
            e.code |= 1 << (j - 1);
        }
        std::mem::swap(&mut list0, &mut next0);
        std::mem::swap(&mut list1, &mut next1);
        push_exits(j, &list1, &mut exit_rank, &mut finals);
    }

    // Full paths: through aux state edges + aux→sink.
    let aux_sink = h[t.aux_sink_edge() as usize];
    for (list, s) in [(&list0, 0u8), (&list1, 1u8)] {
        let add = h[t.aux_edge(s) as usize] + aux_sink;
        for e in list.iter().take(k) {
            finals.push(Scored { label: e.code, score: e.score + add });
        }
    }

    finals.sort_by(|a, b| {
        b.score.partial_cmp(&a.score).unwrap().then(a.label.cmp(&b.label))
    });
    finals.dedup_by_key(|s| s.label); // codes are distinct; belt & braces
    finals.truncate(k);
    finals
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::pathmat::PathMatrix;
    use crate::util::rng::Rng;

    /// list_viterbi == dense top-k oracle on random scores, many (C, k).
    #[test]
    fn matches_dense_oracle() {
        let mut rng = Rng::new(21);
        for c in [2u64, 3, 5, 22, 105, 159, 255, 256, 1000] {
            let t = Trellis::new(c);
            let m = PathMatrix::materialize(&t);
            for &k in &[1usize, 2, 5, 16] {
                for _ in 0..15 {
                    let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
                    let got = list_viterbi(&t, &h, k);
                    let want = m.topk(&h, k);
                    assert_eq!(got.len(), want.len(), "C={c} k={k}");
                    for (g, w) in got.iter().zip(&want) {
                        assert_eq!(g.label, w.0, "C={c} k={k}");
                        assert!((g.score - w.1).abs() < 1e-4, "C={c} k={k}");
                    }
                }
            }
        }
    }

    /// k ≥ C returns all C paths, each exactly once.
    #[test]
    fn k_at_least_c_enumerates_all() {
        let mut rng = Rng::new(22);
        for c in [2u64, 3, 22, 105] {
            let t = Trellis::new(c);
            let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
            let got = list_viterbi(&t, &h, c as usize + 10);
            assert_eq!(got.len(), c as usize);
            let mut labels: Vec<u64> = got.iter().map(|s| s.label).collect();
            labels.sort_unstable();
            labels.dedup();
            assert_eq!(labels.len(), c as usize, "C={c}");
        }
    }

    /// Top-1 of list equals plain Viterbi.
    #[test]
    fn top1_consistent_with_viterbi() {
        let mut rng = Rng::new(23);
        for c in [22u64, 1000, 12294] {
            let t = Trellis::new(c);
            for _ in 0..20 {
                let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
                let lv = list_viterbi(&t, &h, 4);
                let v = super::super::viterbi(&t, &h);
                assert_eq!(lv[0].label, v.label, "C={c}");
                assert!((lv[0].score - v.score).abs() < 1e-4);
            }
        }
    }

    /// Scores are non-increasing.
    #[test]
    fn scores_sorted_descending() {
        let mut rng = Rng::new(24);
        let t = Trellis::new(320338);
        let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
        let got = list_viterbi(&t, &h, 50);
        assert_eq!(got.len(), 50);
        for w in got.windows(2) {
            assert!(w[0].score >= w[1].score);
        }
    }

    /// k=0 is empty.
    #[test]
    fn k_zero_is_empty() {
        let t = Trellis::new(22);
        assert!(list_viterbi(&t, &vec![0.0; t.num_edges()], 0).is_empty());
    }
}
