//! Sparse linear-algebra substrate: sparse feature vectors and CSR example
//! matrices. Extreme-classification datasets are extremely sparse (e.g.
//! LSHTC1 has ~347k features with ~100 active per example), so the entire
//! training hot path operates on index/value pairs.

pub mod csr;
pub mod vec;

pub use csr::CsrMatrix;
pub use vec::SparseVec;
