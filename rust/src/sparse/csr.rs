//! Compressed sparse row matrix: the storage for datasets (`n × D`
//! examples) shared by LTLS and every baseline.

use super::vec::SparseVec;

/// CSR matrix with u32 column indices and f32 values.
#[derive(Clone, Debug, Default)]
pub struct CsrMatrix {
    pub n_cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl CsrMatrix {
    pub fn new(n_cols: usize) -> Self {
        CsrMatrix { n_cols, indptr: vec![0], indices: Vec::new(), values: Vec::new() }
    }

    pub fn n_rows(&self) -> usize {
        self.indptr.len() - 1
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Append a row given ascending (index, value) pairs.
    pub fn push_row(&mut self, indices: &[u32], values: &[f32]) {
        debug_assert_eq!(indices.len(), values.len());
        debug_assert!(indices.windows(2).all(|w| w[0] < w[1]));
        debug_assert!(indices.iter().all(|&i| (i as usize) < self.n_cols));
        self.indices.extend_from_slice(indices);
        self.values.extend_from_slice(values);
        self.indptr.push(self.indices.len());
    }

    /// Borrow row `i` as a sparse vector.
    #[inline]
    pub fn row(&self, i: usize) -> SparseVec<'_> {
        let (s, e) = (self.indptr[i], self.indptr[i + 1]);
        SparseVec { indices: &self.indices[s..e], values: &self.values[s..e] }
    }

    /// Mean nnz per row (dataset density diagnostic).
    pub fn mean_nnz(&self) -> f64 {
        if self.n_rows() == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.n_rows() as f64
        }
    }

    /// Bytes of storage (model/dataset size accounting).
    pub fn bytes(&self) -> usize {
        self.indices.len() * 4 + self.values.len() * 4 + self.indptr.len() * 8
    }

    /// Select a subset of rows into a new matrix (train/test splits).
    pub fn select_rows(&self, rows: &[usize]) -> CsrMatrix {
        let mut out = CsrMatrix::new(self.n_cols);
        for &r in rows {
            let v = self.row(r);
            out.push_row(v.indices, v.values);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsrMatrix {
        let mut m = CsrMatrix::new(10);
        m.push_row(&[0, 5], &[1.0, 2.0]);
        m.push_row(&[], &[]);
        m.push_row(&[9], &[3.0]);
        m
    }

    #[test]
    fn rows_roundtrip() {
        let m = sample();
        assert_eq!(m.n_rows(), 3);
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.row(0).indices, &[0, 5]);
        assert_eq!(m.row(1).nnz(), 0);
        assert_eq!(m.row(2).values, &[3.0]);
        assert!((m.mean_nnz() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn select_rows_reorders() {
        let m = sample();
        let s = m.select_rows(&[2, 0]);
        assert_eq!(s.n_rows(), 2);
        assert_eq!(s.row(0).indices, &[9]);
        assert_eq!(s.row(1).indices, &[0, 5]);
    }

    #[test]
    fn bytes_positive() {
        assert!(sample().bytes() > 0);
    }
}
