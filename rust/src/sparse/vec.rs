//! Sparse vector view and ops used by the SGD hot loop.

/// A borrowed sparse vector: parallel (index, value) slices, indices
/// strictly ascending.
#[derive(Clone, Copy, Debug)]
pub struct SparseVec<'a> {
    pub indices: &'a [u32],
    pub values: &'a [f32],
}

impl<'a> SparseVec<'a> {
    pub fn new(indices: &'a [u32], values: &'a [f32]) -> Self {
        debug_assert_eq!(indices.len(), values.len());
        debug_assert!(indices.windows(2).all(|w| w[0] < w[1]), "indices must ascend");
        SparseVec { indices, values }
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Dot product with a dense vector.
    #[inline]
    pub fn dot_dense(&self, w: &[f32]) -> f32 {
        let mut acc = 0.0f32;
        for (&i, &v) in self.indices.iter().zip(self.values) {
            acc += w[i as usize] * v;
        }
        acc
    }

    /// `w += scale * self` into a dense vector.
    #[inline]
    pub fn axpy_into(&self, scale: f32, w: &mut [f32]) {
        for (&i, &v) in self.indices.iter().zip(self.values) {
            w[i as usize] += scale * v;
        }
    }

    /// Squared L2 norm.
    pub fn norm2(&self) -> f32 {
        self.values.iter().map(|v| v * v).sum()
    }

    /// Materialize as a dense vector of length `d`.
    pub fn to_dense(&self, d: usize) -> Vec<f32> {
        let mut out = vec![0.0; d];
        for (&i, &v) in self.indices.iter().zip(self.values) {
            out[i as usize] = v;
        }
        out
    }

    /// Sparse-sparse dot product (two-pointer merge).
    pub fn dot_sparse(&self, other: &SparseVec) -> f32 {
        let (mut i, mut j, mut acc) = (0usize, 0usize, 0.0f32);
        while i < self.indices.len() && j < other.indices.len() {
            match self.indices[i].cmp(&other.indices[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    acc += self.values[i] * other.values[j];
                    i += 1;
                    j += 1;
                }
            }
        }
        acc
    }
}

/// An owned sparse vector (builder for synthetic data and tests).
#[derive(Clone, Debug, Default)]
pub struct SparseVecOwned {
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl SparseVecOwned {
    pub fn view(&self) -> SparseVec<'_> {
        SparseVec::new(&self.indices, &self.values)
    }

    pub fn push(&mut self, i: u32, v: f32) {
        debug_assert!(self.indices.last().map(|&l| l < i).unwrap_or(true));
        self.indices.push(i);
        self.values.push(v);
    }

    /// L2-normalize in place (no-op on zero vectors).
    pub fn l2_normalize(&mut self) {
        let n = self.view().norm2().sqrt();
        if n > 0.0 {
            for v in &mut self.values {
                *v /= n;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_dense_and_axpy() {
        let idx = [1u32, 3, 5];
        let val = [1.0f32, 2.0, -1.0];
        let sv = SparseVec::new(&idx, &val);
        let mut w = vec![0.5f32; 6];
        assert!((sv.dot_dense(&w) - (0.5 + 1.0 - 0.5)).abs() < 1e-6);
        sv.axpy_into(2.0, &mut w);
        assert_eq!(w[1], 2.5);
        assert_eq!(w[3], 4.5);
        assert_eq!(w[5], -1.5);
        assert_eq!(w[0], 0.5);
    }

    #[test]
    fn sparse_sparse_dot() {
        let a = SparseVecOwned { indices: vec![0, 2, 4], values: vec![1.0, 2.0, 3.0] };
        let b = SparseVecOwned { indices: vec![2, 3, 4], values: vec![5.0, 7.0, 11.0] };
        assert_eq!(a.view().dot_sparse(&b.view()), 2.0 * 5.0 + 3.0 * 11.0);
    }

    #[test]
    fn normalize_and_dense_roundtrip() {
        let mut v = SparseVecOwned { indices: vec![0, 3], values: vec![3.0, 4.0] };
        v.l2_normalize();
        assert!((v.view().norm2() - 1.0).abs() < 1e-6);
        let d = v.view().to_dense(5);
        assert_eq!(d.len(), 5);
        assert!((d[0] - 0.6).abs() < 1e-6);
        assert!((d[3] - 0.8).abs() < 1e-6);
        assert_eq!(d[1], 0.0);
    }

    #[test]
    fn empty_vector_ops() {
        let sv = SparseVec::new(&[], &[]);
        assert_eq!(sv.nnz(), 0);
        assert_eq!(sv.dot_dense(&[1.0, 2.0]), 0.0);
        let mut w = vec![1.0f32; 2];
        sv.axpy_into(5.0, &mut w);
        assert_eq!(w, vec![1.0, 1.0]);
    }
}
