//! Separation ranking loss (paper §5, after Crammer & Singer):
//!
//! `L(w, y) = max_{ℓn ∈ N(y)} max_{ℓp ∈ P(y)} (1 + F(s(ℓn)) − F(s(ℓp)))₊`
//!
//! Finding it needs only two labels: the *lowest-scoring positive* ℓp and
//! the *highest-scoring negative* ℓn. ℓp is found by scoring the |P|
//! positive paths directly (`O(|P|·log C)`); ℓn by taking the top
//! `|P| + 1` paths with list-Viterbi and picking the best one that is not
//! positive — exactly the procedure of §5.

use crate::decode::{list_viterbi_into, score_label, Scored};
use crate::engine::DecodeWorkspace;
use crate::graph::Topology;

/// What the loss computation found.
#[derive(Clone, Debug)]
pub struct SeparationOutcome {
    /// Hinge value `(1 + F(ℓn) − F(ℓp))₊`.
    pub loss: f32,
    /// Lowest-scoring positive path (label id in path space).
    pub pos: u64,
    pub pos_score: f32,
    /// Highest-scoring negative path.
    pub neg: u64,
    pub neg_score: f32,
}

/// Compute the separation ranking loss for an example whose positive
/// labels map to trellis paths `positive_paths` (non-empty, sorted or not).
///
/// `h` is the edge-score vector for the example. Returns `None` when every
/// path in the top-(|P|+1) list is positive (can only happen if |P| = C).
pub fn separation_loss<T: Topology>(
    t: &T,
    h: &[f32],
    positive_paths: &[u64],
) -> Option<SeparationOutcome> {
    let mut ws = DecodeWorkspace::new();
    let mut topk = Vec::new();
    separation_loss_ws(t, h, positive_paths, &mut ws, &mut topk)
}

/// Engine variant of [`separation_loss`]: the list-Viterbi runs on a reused
/// [`DecodeWorkspace`] and top-k buffer, so the loss computation performs
/// no heap allocation after warm-up. Bit-identical to [`separation_loss`]
/// (the `_into` decoder is pinned bit-identical by
/// `rust/tests/engine_parity.rs`). This is the form the training hot loops
/// — serial and Hogwild — call with their per-worker
/// [`crate::engine::TrainScratch`] buffers.
pub fn separation_loss_ws<T: Topology>(
    t: &T,
    h: &[f32],
    positive_paths: &[u64],
    ws: &mut DecodeWorkspace,
    topk: &mut Vec<Scored>,
) -> Option<SeparationOutcome> {
    debug_assert!(!positive_paths.is_empty());
    // Lowest-scoring positive: direct O(|P| log C) scoring.
    let (mut pos, mut pos_score) = (positive_paths[0], f32::INFINITY);
    for &p in positive_paths {
        let s = score_label(t, h, p);
        if s < pos_score {
            pos = p;
            pos_score = s;
        }
    }
    // Highest-scoring negative: top-(|P|+1) must contain at least one
    // negative path.
    list_viterbi_into(t, h, positive_paths.len() + 1, ws, topk);
    let neg = topk.iter().find(|s| !positive_paths.contains(&s.label))?;
    let margin = 1.0 + neg.score - pos_score;
    Some(SeparationOutcome {
        loss: margin.max(0.0),
        pos,
        pos_score,
        neg: neg.label,
        neg_score: neg.score,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decode::list_viterbi;
    use crate::graph::pathmat::PathMatrix;
    use crate::graph::Trellis;
    use crate::util::rng::Rng;

    /// Against brute force over all (ℓp, ℓn) pairs.
    #[test]
    fn matches_bruteforce() {
        let mut rng = Rng::new(61);
        for c in [8u64, 22, 105] {
            let t = Trellis::new(c);
            let m = PathMatrix::materialize(&t);
            for trial in 0..30 {
                let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
                let np = 1 + (trial % 3);
                let pos: Vec<u64> =
                    rng.sample_distinct(c as usize, np).into_iter().map(|v| v as u64).collect();
                let f = m.decode(&h);
                let worst_pos = pos
                    .iter()
                    .map(|&p| f[p as usize])
                    .fold(f32::INFINITY, f32::min);
                let best_neg = (0..c)
                    .filter(|l| !pos.contains(l))
                    .map(|l| f[l as usize])
                    .fold(f32::NEG_INFINITY, f32::max);
                let want = (1.0 + best_neg - worst_pos).max(0.0);
                let got = separation_loss(&t, &h, &pos).unwrap();
                assert!(
                    (got.loss - want).abs() < 1e-4,
                    "C={c} trial={trial}: {} vs {want}",
                    got.loss
                );
                assert!((got.pos_score - worst_pos).abs() < 1e-4);
                assert!((got.neg_score - best_neg).abs() < 1e-4);
            }
        }
    }

    /// Zero loss when the positive is far ahead.
    #[test]
    fn zero_when_separated() {
        let t = Trellis::new(22);
        let mut h = vec![0.0f32; t.num_edges()];
        for e in crate::graph::codec::edges_of_label(&t, 5) {
            h[e as usize] = 10.0;
        }
        let out = separation_loss(&t, &h, &[5]).unwrap();
        assert_eq!(out.loss, 0.0);
        assert_eq!(out.pos, 5);
        assert_ne!(out.neg, 5);
    }

    /// The workspace variant is bit-identical to the allocating one, also
    /// when the buffers are reused across calls of different |P|.
    #[test]
    fn workspace_variant_matches_allocating() {
        let mut rng = Rng::new(63);
        let t = Trellis::new(105);
        let mut ws = DecodeWorkspace::new();
        let mut topk = Vec::new();
        for trial in 0..20 {
            let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
            let np = 1 + (trial % 3);
            let pos: Vec<u64> =
                rng.sample_distinct(105, np).into_iter().map(|v| v as u64).collect();
            let a = separation_loss(&t, &h, &pos).unwrap();
            let b = separation_loss_ws(&t, &h, &pos, &mut ws, &mut topk).unwrap();
            assert_eq!(a.loss, b.loss);
            assert_eq!(a.pos, b.pos);
            assert_eq!(a.neg, b.neg);
            assert_eq!(a.pos_score, b.pos_score);
            assert_eq!(a.neg_score, b.neg_score);
        }
    }

    /// Multiclass (|P| = 1): ℓn is the runner-up of the top-2.
    #[test]
    fn multiclass_uses_top2() {
        let mut rng = Rng::new(62);
        let t = Trellis::new(105);
        for _ in 0..20 {
            let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
            let y = rng.below(105);
            let out = separation_loss(&t, &h, &[y]).unwrap();
            let top2 = list_viterbi(&t, &h, 2);
            let expect_neg = if top2[0].label == y { top2[1].label } else { top2[0].label };
            assert_eq!(out.neg, expect_neg);
        }
    }
}
