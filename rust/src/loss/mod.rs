//! Training losses (paper §5).
//!
//! * [`separation`] — the separation ranking loss used for all linear
//!   experiments: hinge on the margin between the lowest-scoring positive
//!   path and the highest-scoring negative path.
//! * [`trellis_softmax`] — multinomial logistic over all C paths via the
//!   trellis log-partition function (the deep-variant loss; its gradient
//!   w.r.t. edge scores is `posterior − indicator`).

pub mod separation;
pub mod trellis_softmax;

pub use separation::{separation_loss, separation_loss_ws, SeparationOutcome};
pub use trellis_softmax::{trellis_softmax_grad, trellis_softmax_loss};
