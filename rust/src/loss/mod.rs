//! Training losses (paper §5).
//!
//! * [`separation`] — the separation ranking loss used for all linear
//!   multiclass experiments: hinge on the margin between the
//!   lowest-scoring positive path and the highest-scoring negative path.
//! * [`multilabel`] — the union-of-gold-paths generalization: every
//!   positive path hinges against the shared best negative, averaged over
//!   the positive set (reduces bitwise to [`separation`] at |P| = 1).
//! * [`trellis_softmax`] — multinomial logistic over all C paths via the
//!   trellis log-partition function (the deep-variant loss; its gradient
//!   w.r.t. edge scores is `posterior − indicator`).

pub mod multilabel;
pub mod separation;
pub mod trellis_softmax;

pub use multilabel::{union_separation, union_separation_ws, UnionOutcome};
pub use separation::{separation_loss, separation_loss_ws, SeparationOutcome};
pub use trellis_softmax::{trellis_softmax_grad, trellis_softmax_loss};
