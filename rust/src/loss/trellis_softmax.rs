//! Trellis softmax (multinomial logistic over all C paths, paper §5).
//!
//! `L = log Σ_ℓ exp(F(s(ℓ))) − F(s(y))` computed in `O(E)` via the
//! forward algorithm; the gradient w.r.t. the edge scores is
//! `∂L/∂h = posterior_marginals(h) − indicator(s(y))` — what
//! backpropagation ("forward–backward in this context") produces.

use crate::decode::{log_partition, posterior_marginals, score_label};
use crate::graph::Topology;

/// Negative log-likelihood of path `y` under the trellis softmax, over any
/// [`Topology`].
pub fn trellis_softmax_loss<T: Topology>(t: &T, h: &[f32], y: u64) -> f32 {
    log_partition(t, h) - score_label(t, h, y)
}

/// Gradient of the loss w.r.t. the edge-score vector `h` (length E).
pub fn trellis_softmax_grad<T: Topology>(t: &T, h: &[f32], y: u64) -> Vec<f32> {
    let mut g = posterior_marginals(t, h);
    for e in t.edges_of_label(y) {
        g[e as usize] -= 1.0;
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::codec::edges_of_label;
    use crate::graph::Trellis;
    use crate::util::rng::Rng;

    /// Loss is a proper NLL: ≥ 0, and → 0 when y's path dominates.
    #[test]
    fn loss_nonnegative_and_converges() {
        let t = Trellis::new(105);
        let mut rng = Rng::new(71);
        let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
        assert!(trellis_softmax_loss(&t, &h, 13) >= 0.0);

        let mut boosted = vec![0.0f32; t.num_edges()];
        for e in edges_of_label(&t, 13) {
            boosted[e as usize] = 12.0;
        }
        assert!(trellis_softmax_loss(&t, &boosted, 13) < 1e-2);
    }

    /// Analytic gradient matches finite differences.
    #[test]
    fn grad_matches_finite_difference() {
        let mut rng = Rng::new(72);
        for c in [8u64, 22, 105] {
            let t = Trellis::new(c);
            let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal() * 0.5).collect();
            let y = rng.below(c);
            let g = trellis_softmax_grad(&t, &h, y);
            let eps = 1e-3f32;
            for e in (0..t.num_edges()).step_by(3) {
                let mut hp = h.clone();
                hp[e] += eps;
                let mut hm = h.clone();
                hm[e] -= eps;
                let fd = (trellis_softmax_loss(&t, &hp, y) - trellis_softmax_loss(&t, &hm, y))
                    / (2.0 * eps);
                assert!(
                    (g[e] - fd).abs() < 2e-2,
                    "C={c} e={e}: analytic {} vs fd {fd}",
                    g[e]
                );
            }
        }
    }

    /// Gradient sums to ~0 over each "cut" (probability conservation −
    /// path indicator conservation).
    #[test]
    fn grad_source_cut_sums_to_zero() {
        let t = Trellis::new(159);
        let mut rng = Rng::new(73);
        let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
        let g = trellis_softmax_grad(&t, &h, 42);
        let cut = g[t.source_edge(0) as usize] + g[t.source_edge(1) as usize];
        assert!(cut.abs() < 1e-4, "source cut {cut}");
    }
}
