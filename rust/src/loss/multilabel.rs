//! Union-of-gold-paths separation loss — the multilabel generalization of
//! [`super::separation`] (paper §5 extended to label *sets*, following the
//! per-label decomposition of the PLT line of work, Jasinska et al.).
//!
//! Where the multiclass loss hinges only the single worst (positive,
//! negative) pair, the multilabel objective hinges **every** positive path
//! against the shared best negative and averages:
//!
//! `L(w, Y) = (1/|P|) Σ_{ℓp ∈ P(Y)} (1 + F(s(ℓn*)) − F(s(ℓp)))₊`
//!
//! with `ℓn* = argmax_{ℓn ∉ P} F(s(ℓn))`. The best negative is found
//! exactly as in the multiclass loss — list-Viterbi top-`(|P| + 1)` must
//! contain at least one non-positive path — and each positive is scored
//! directly in `O(log C)`. At `|P| = 1` the expression reduces, term for
//! term and float-op for float-op, to [`super::separation_loss_ws`]'s
//! margin, which is what makes the singleton-target bit-identity guarantee
//! of the objective refactor provable (pinned by
//! `rust/tests/multilabel_parity.rs`).

use crate::decode::{list_viterbi_into, score_label, Scored};
use crate::engine::DecodeWorkspace;
use crate::graph::Topology;

/// What the union loss found.
#[derive(Clone, Debug)]
pub struct UnionOutcome {
    /// Mean hinged margin over the positive set,
    /// `(1/|P|) Σ (1 + F(ℓn*) − F(ℓp))₊`.
    pub loss: f32,
    /// Best negative path (shared by every positive's hinge).
    pub neg: u64,
    pub neg_score: f32,
    /// How many positives have an active hinge (margin > 0).
    pub active: usize,
}

/// Allocating variant of [`union_separation_ws`] (tests/tools).
pub fn union_separation<T: Topology>(
    t: &T,
    h: &[f32],
    positive_paths: &[u64],
) -> Option<(UnionOutcome, Vec<(u64, f32)>)> {
    let mut ws = DecodeWorkspace::new();
    let mut topk = Vec::new();
    let mut margins = Vec::new();
    let out = union_separation_ws(t, h, positive_paths, &mut ws, &mut topk, &mut margins)?;
    Some((out, margins))
}

/// Compute the union-of-gold-paths loss for an example whose positive
/// labels map to trellis paths `positive_paths` (non-empty).
///
/// `margins` is filled with one `(path, hinged margin)` entry per positive
/// (clamped at 0; entries with margin > 0 are the active hinges whose
/// symmetric-difference updates the objective kernel applies). Runs on
/// reused decode buffers, so the hot loops stay allocation-free. Returns
/// `None` when every path in the top-`(|P|+1)` list is positive (only
/// possible at |P| = C).
pub fn union_separation_ws<T: Topology>(
    t: &T,
    h: &[f32],
    positive_paths: &[u64],
    ws: &mut DecodeWorkspace,
    topk: &mut Vec<Scored>,
    margins: &mut Vec<(u64, f32)>,
) -> Option<UnionOutcome> {
    debug_assert!(!positive_paths.is_empty());
    margins.clear();
    // Highest-scoring negative: the top-(|P|+1) list must contain at least
    // one negative path (same search as the multiclass loss).
    list_viterbi_into(t, h, positive_paths.len() + 1, ws, topk);
    let neg = topk.iter().find(|s| !positive_paths.contains(&s.label))?;
    let (neg_path, neg_score) = (neg.label, neg.score);
    let mut sum = 0.0f32;
    let mut active = 0usize;
    for &p in positive_paths {
        // Same float-op order as the multiclass margin:
        // (1 + neg − pos).max(0).
        let margin = 1.0 + neg_score - score_label(t, h, p);
        let hinged = margin.max(0.0);
        if hinged > 0.0 {
            active += 1;
        }
        sum += hinged;
        margins.push((p, hinged));
    }
    Some(UnionOutcome {
        loss: sum / positive_paths.len() as f32,
        neg: neg_path,
        neg_score,
        active,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::pathmat::PathMatrix;
    use crate::graph::Trellis;
    use crate::loss::separation_loss;
    use crate::util::rng::Rng;

    /// Against brute force: dense-decode all C paths, hinge every positive
    /// against the global best negative, average.
    #[test]
    fn matches_bruteforce() {
        let mut rng = Rng::new(171);
        for c in [8u64, 22, 105] {
            let t = Trellis::new(c);
            let m = PathMatrix::materialize(&t);
            for trial in 0..30 {
                let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
                let np = 1 + (trial % 4);
                let pos: Vec<u64> =
                    rng.sample_distinct(c as usize, np).into_iter().map(|v| v as u64).collect();
                let f = m.decode(&h);
                let best_neg = (0..c)
                    .filter(|l| !pos.contains(l))
                    .map(|l| f[l as usize])
                    .fold(f32::NEG_INFINITY, f32::max);
                let want: f32 = pos
                    .iter()
                    .map(|&p| (1.0 + best_neg - f[p as usize]).max(0.0))
                    .sum::<f32>()
                    / pos.len() as f32;
                let (got, margins) = union_separation(&t, &h, &pos).unwrap();
                assert!(
                    (got.loss - want).abs() < 1e-4,
                    "C={c} trial={trial}: {} vs {want}",
                    got.loss
                );
                assert!((got.neg_score - best_neg).abs() < 1e-4);
                assert_eq!(margins.len(), pos.len());
                assert_eq!(got.active, margins.iter().filter(|(_, m)| *m > 0.0).count());
            }
        }
    }

    /// At |P| = 1 the union loss IS the separation loss, bit for bit.
    #[test]
    fn singleton_is_bitwise_separation_loss() {
        let mut rng = Rng::new(172);
        let t = Trellis::new(105);
        for _ in 0..40 {
            let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
            let y = rng.below(105);
            let mc = separation_loss(&t, &h, &[y]).unwrap();
            let (ml, margins) = union_separation(&t, &h, &[y]).unwrap();
            assert_eq!(mc.loss.to_bits(), ml.loss.to_bits());
            assert_eq!(mc.neg, ml.neg);
            assert_eq!(mc.neg_score.to_bits(), ml.neg_score.to_bits());
            assert_eq!(margins, vec![(y, mc.loss)]);
        }
    }

    /// Zero loss when every positive is far ahead of all negatives.
    #[test]
    fn zero_when_separated() {
        let t = Trellis::new(22);
        let mut h = vec![0.0f32; t.num_edges()];
        for y in [3u64, 11] {
            for e in crate::graph::codec::edges_of_label(&t, y) {
                h[e as usize] += 10.0;
            }
        }
        let (out, margins) = union_separation(&t, &h, &[3, 11]).unwrap();
        assert_eq!(out.loss, 0.0);
        assert_eq!(out.active, 0);
        assert!(margins.iter().all(|(_, m)| *m == 0.0));
        assert!(out.neg != 3 && out.neg != 11);
    }
}
