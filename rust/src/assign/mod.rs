//! Label→path assignment (paper §5.1).
//!
//! The decompression matrix `M_G` is fixed, so *which* dataset label gets
//! *which* trellis path matters. The paper's online policy: when a training
//! example arrives with an unseen label, list-Viterbi the top `m = O(log C)`
//! paths for that example and assign the label to the highest-ranked free
//! path; if none of the m are free, assign a random free path. The
//! path-occupancy table costs `O(C)` memory but holds no model parameters.

pub mod policy;
pub mod table;

pub use policy::{AssignPolicy, Assigner};
pub use table::AssignmentTable;
