//! Bidirectional label ↔ path table with O(1) random free-path sampling.

use crate::util::rng::Rng;

const UNASSIGNED: u64 = u64::MAX;

/// Bijective (partial) mapping between dataset labels and trellis paths.
#[derive(Clone, Debug)]
pub struct AssignmentTable {
    /// label → path (UNASSIGNED if none yet).
    label_to_path: Vec<u64>,
    /// path → label (UNASSIGNED if free).
    path_to_label: Vec<u64>,
    /// Free paths as a swap-remove pool + position index for O(1) claims.
    free_pool: Vec<u64>,
    free_pos: Vec<usize>,
}

impl AssignmentTable {
    /// `n_labels` dataset labels over `c` trellis paths (`n_labels ≤ c`).
    pub fn new(n_labels: usize, c: u64) -> Self {
        assert!(n_labels as u64 <= c, "need at least as many paths as labels");
        AssignmentTable {
            label_to_path: vec![UNASSIGNED; n_labels],
            path_to_label: vec![UNASSIGNED; c as usize],
            free_pool: (0..c).collect(),
            free_pos: (0..c as usize).collect(),
        }
    }

    pub fn n_free(&self) -> usize {
        self.free_pool.len()
    }

    /// Path assigned to `label`, if any.
    #[inline]
    pub fn path_of(&self, label: u32) -> Option<u64> {
        let p = self.label_to_path[label as usize];
        (p != UNASSIGNED).then_some(p)
    }

    /// Label assigned to `path`, if any.
    #[inline]
    pub fn label_of(&self, path: u64) -> Option<u32> {
        let l = self.path_to_label[path as usize];
        (l != UNASSIGNED).then_some(l as u32)
    }

    #[inline]
    pub fn is_free(&self, path: u64) -> bool {
        self.path_to_label[path as usize] == UNASSIGNED
    }

    /// Claim `path` for `label`. Panics if either side is already bound
    /// (callers check first).
    pub fn bind(&mut self, label: u32, path: u64) {
        assert!(self.label_to_path[label as usize] == UNASSIGNED, "label already bound");
        assert!(self.is_free(path), "path already bound");
        self.label_to_path[label as usize] = path;
        self.path_to_label[path as usize] = label as u64;
        // Swap-remove from the free pool.
        let pos = self.free_pos[path as usize];
        let last = *self.free_pool.last().unwrap();
        self.free_pool.swap_remove(pos);
        if pos < self.free_pool.len() {
            self.free_pos[last as usize] = pos;
        }
    }

    /// A uniformly random free path (None if full).
    pub fn random_free(&self, rng: &mut Rng) -> Option<u64> {
        if self.free_pool.is_empty() {
            None
        } else {
            Some(self.free_pool[rng.index(self.free_pool.len())])
        }
    }

    /// Number of labels already assigned.
    pub fn n_assigned(&self) -> usize {
        self.path_to_label.len() - self.free_pool.len()
    }

    /// Grow the label side to at least `n_labels` (paths and existing
    /// bindings unchanged). Model/checkpoint files record only the *bound*
    /// (label, path) pairs, so a table restored from disk may cover fewer
    /// labels than the dataset a resumed training run sees.
    pub fn ensure_labels(&mut self, n_labels: usize) {
        if self.label_to_path.len() < n_labels {
            assert!(
                n_labels <= self.path_to_label.len(),
                "need at least as many paths as labels"
            );
            self.label_to_path.resize(n_labels, UNASSIGNED);
        }
    }

    /// Iterate (label, path) pairs.
    pub fn pairs(&self) -> impl Iterator<Item = (u32, u64)> + '_ {
        self.label_to_path
            .iter()
            .enumerate()
            .filter(|(_, &p)| p != UNASSIGNED)
            .map(|(l, &p)| (l as u32, p))
    }

    /// Memory used (the paper's "O(C) but not model parameters" note).
    pub fn bytes(&self) -> usize {
        (self.label_to_path.len() + self.path_to_label.len() + self.free_pool.len()) * 8
            + self.free_pos.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bind_and_lookup() {
        let mut t = AssignmentTable::new(3, 10);
        assert_eq!(t.n_free(), 10);
        t.bind(1, 7);
        assert_eq!(t.path_of(1), Some(7));
        assert_eq!(t.label_of(7), Some(1));
        assert!(!t.is_free(7));
        assert_eq!(t.n_free(), 9);
        assert_eq!(t.n_assigned(), 1);
        assert_eq!(t.path_of(0), None);
    }

    #[test]
    #[should_panic]
    fn double_bind_label_panics() {
        let mut t = AssignmentTable::new(2, 4);
        t.bind(0, 1);
        t.bind(0, 2);
    }

    #[test]
    #[should_panic]
    fn double_bind_path_panics() {
        let mut t = AssignmentTable::new(2, 4);
        t.bind(0, 1);
        t.bind(1, 1);
    }

    #[test]
    fn random_free_never_returns_bound() {
        let mut t = AssignmentTable::new(8, 8);
        let mut rng = Rng::new(81);
        for l in 0..7u32 {
            let p = t.random_free(&mut rng).unwrap();
            t.bind(l, p);
        }
        assert_eq!(t.n_free(), 1);
        let last = t.random_free(&mut rng).unwrap();
        assert!(t.is_free(last));
        t.bind(7, last);
        assert!(t.random_free(&mut rng).is_none());
        // All bound paths distinct.
        let mut paths: Vec<u64> = t.pairs().map(|(_, p)| p).collect();
        paths.sort_unstable();
        paths.dedup();
        assert_eq!(paths.len(), 8);
    }

    #[test]
    fn ensure_labels_grows_without_touching_bindings() {
        let mut t = AssignmentTable::new(2, 10);
        t.bind(1, 7);
        t.ensure_labels(5);
        assert_eq!(t.path_of(1), Some(7));
        assert_eq!(t.path_of(4), None);
        assert_eq!(t.n_assigned(), 1);
        // Shrinking is a no-op.
        t.ensure_labels(1);
        assert_eq!(t.path_of(1), Some(7));
        t.bind(4, 2);
        assert_eq!(t.path_of(4), Some(2));
    }

    #[test]
    #[should_panic]
    fn ensure_labels_rejects_more_labels_than_paths() {
        let mut t = AssignmentTable::new(2, 4);
        t.ensure_labels(5);
    }

    /// Free-pool positional index stays consistent under many binds.
    #[test]
    fn free_pool_consistency_fuzz() {
        let mut t = AssignmentTable::new(100, 150);
        let mut rng = Rng::new(82);
        for l in 0..100u32 {
            let p = t.random_free(&mut rng).unwrap();
            t.bind(l, p);
            // Invariant: every pool entry's recorded position is correct.
            for (pos, &path) in t.free_pool.iter().enumerate() {
                assert_eq!(t.free_pos[path as usize], pos);
            }
        }
        assert_eq!(t.n_free(), 50);
    }
}
