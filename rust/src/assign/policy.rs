//! The online assignment policies of §5.1.

use super::table::AssignmentTable;
use crate::decode::list_viterbi;
use crate::graph::Topology;
use crate::util::rng::Rng;

/// Which policy to use when an unseen label arrives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AssignPolicy {
    /// Paper policy: top-m list-Viterbi, first free path wins; random free
    /// fallback. `m = O(log C)` (we use `4·⌈log₂C⌉`, capped at 64).
    TopRanked,
    /// Ablation: always a random free path (the paper reports this is
    /// significantly worse).
    Random,
    /// Identity: label ℓ ↔ path ℓ (only valid when n_labels ≤ C; used by
    /// tests and by the deep variant where JAX fixes the mapping).
    Identity,
}

/// Stateful assigner owned by the trainer.
#[derive(Clone)]
pub struct Assigner {
    pub policy: AssignPolicy,
    pub table: AssignmentTable,
    m: usize,
    rng: Rng,
    /// Count of assignments that fell back to random (telemetry).
    pub random_fallbacks: u64,
}

impl Assigner {
    /// New assigner over any [`Topology`] (the policy only needs the path
    /// count and a top-m decode).
    pub fn new<T: Topology>(policy: AssignPolicy, n_labels: usize, t: &T, seed: u64) -> Self {
        let c = t.c();
        let m = (4 * crate::util::ceil_log2(c) as usize).clamp(4, 64);
        Assigner {
            policy,
            table: AssignmentTable::new(n_labels, c),
            m,
            rng: Rng::new(seed ^ 0xA551_6E),
            random_fallbacks: 0,
        }
    }

    /// Path for `label`, assigning it now (using the example's edge scores
    /// `h`) if it was never seen before.
    pub fn path_for<T: Topology>(&mut self, t: &T, h: &[f32], label: u32) -> u64 {
        if let Some(p) = self.table.path_of(label) {
            return p;
        }
        let path = match self.policy {
            AssignPolicy::Identity => {
                let p = label as u64;
                assert!(
                    self.table.is_free(p),
                    "identity policy requires free path per label"
                );
                p
            }
            AssignPolicy::Random => {
                self.random_fallbacks += 1;
                self.table.random_free(&mut self.rng).expect("paths exhausted")
            }
            AssignPolicy::TopRanked => {
                let top = list_viterbi(t, h, self.m);
                match top.iter().find(|s| self.table.is_free(s.label)) {
                    Some(s) => s.label,
                    None => {
                        self.random_fallbacks += 1;
                        self.table.random_free(&mut self.rng).expect("paths exhausted")
                    }
                }
            }
        };
        self.table.bind(label, path);
        path
    }

    /// Paths for a label set (multilabel): assigns any unseen ones.
    pub fn paths_for<T: Topology>(&mut self, t: &T, h: &[f32], labels: &[u32]) -> Vec<u64> {
        labels.iter().map(|&l| self.path_for(t, h, l)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Trellis;
    use crate::util::rng::Rng as TRng;

    fn scores(t: &Trellis, seed: u64) -> Vec<f32> {
        let mut r = TRng::new(seed);
        (0..t.num_edges()).map(|_| r.normal()).collect()
    }

    #[test]
    fn top_ranked_assigns_best_free_path() {
        let t = Trellis::new(22);
        let mut a = Assigner::new(AssignPolicy::TopRanked, 22, &t, 1);
        let h = scores(&t, 5);
        let best = crate::decode::viterbi(&t, &h).label;
        let p0 = a.path_for(&t, &h, 3);
        assert_eq!(p0, best, "first unseen label gets the Viterbi path");
        // Second distinct label with same scores gets the runner-up.
        let top = list_viterbi(&t, &h, 2);
        let p1 = a.path_for(&t, &h, 9);
        assert_eq!(p1, top[1].label);
        // Stable: repeated lookups don't reassign.
        assert_eq!(a.path_for(&t, &h, 3), p0);
    }

    #[test]
    fn random_policy_counts_fallbacks() {
        let t = Trellis::new(105);
        let mut a = Assigner::new(AssignPolicy::Random, 105, &t, 2);
        let h = scores(&t, 6);
        for l in 0..50u32 {
            a.path_for(&t, &h, l);
        }
        assert_eq!(a.random_fallbacks, 50);
        assert_eq!(a.table.n_assigned(), 50);
    }

    #[test]
    fn identity_policy_maps_straight_through() {
        let t = Trellis::new(22);
        let mut a = Assigner::new(AssignPolicy::Identity, 22, &t, 3);
        let h = scores(&t, 7);
        for l in [0u32, 7, 21] {
            assert_eq!(a.path_for(&t, &h, l), l as u64);
        }
    }

    #[test]
    fn exhaustion_falls_back_to_random_free() {
        // C = n_labels: once top-m are taken, fallback must still succeed.
        let t = Trellis::new(8);
        let mut a = Assigner::new(AssignPolicy::TopRanked, 8, &t, 4);
        let h = scores(&t, 8);
        let mut paths: Vec<u64> = (0..8u32).map(|l| a.path_for(&t, &h, l)).collect();
        paths.sort_unstable();
        paths.dedup();
        assert_eq!(paths.len(), 8, "all labels got distinct paths");
    }

    #[test]
    fn multilabel_assignment() {
        let t = Trellis::new(159);
        let mut a = Assigner::new(AssignPolicy::TopRanked, 159, &t, 5);
        let h = scores(&t, 9);
        let ps = a.paths_for(&t, &h, &[3, 14, 15]);
        assert_eq!(ps.len(), 3);
        let mut d = ps.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 3);
    }
}
