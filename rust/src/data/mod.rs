//! Dataset substrate: the in-memory dataset type, a libsvm-format loader
//! (so the real XC benchmark files can be dropped in), synthetic dataset
//! generators that clone the *shape statistics* of the paper's nine
//! datasets (DESIGN.md §3 documents the substitution), and splits/stats.

pub mod datasets;
pub mod libsvm;
pub mod split;
pub mod stats;
pub mod synthetic;

use crate::sparse::{CsrMatrix, SparseVec};

/// A multiclass or multilabel dataset.
///
/// Labels are `Vec<u32>` per example: length 1 for multiclass, arbitrary
/// (sorted, distinct) for multilabel.
#[derive(Clone, Debug, Default)]
pub struct Dataset {
    pub name: String,
    pub features: CsrMatrix,
    pub labels: Vec<Vec<u32>>,
    pub n_features: usize,
    pub n_labels: usize,
    /// True if every example has exactly one label.
    pub multiclass: bool,
}

impl Dataset {
    pub fn n_examples(&self) -> usize {
        self.features.n_rows()
    }

    /// Feature row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> SparseVec<'_> {
        self.features.row(i)
    }

    /// Label set of example `i`.
    #[inline]
    pub fn labels_of(&self, i: usize) -> &[u32] {
        &self.labels[i]
    }

    /// Recompute the `multiclass` flag from the labels.
    pub fn detect_multiclass(&mut self) {
        self.multiclass = self.labels.iter().all(|l| l.len() == 1);
    }

    /// Label frequencies (used by the Table 3 naive baseline and stats).
    pub fn label_frequencies(&self) -> Vec<u64> {
        let mut f = vec![0u64; self.n_labels];
        for ls in &self.labels {
            for &l in ls {
                f[l as usize] += 1;
            }
        }
        f
    }

    /// Select examples into a new dataset (splits).
    pub fn select(&self, rows: &[usize]) -> Dataset {
        Dataset {
            name: self.name.clone(),
            features: self.features.select_rows(rows),
            labels: rows.iter().map(|&r| self.labels[r].clone()).collect(),
            n_features: self.n_features,
            n_labels: self.n_labels,
            multiclass: self.multiclass,
        }
    }

    /// Sanity checks used across tests and loaders.
    pub fn validate(&self) -> Result<(), String> {
        if self.features.n_rows() != self.labels.len() {
            return Err(format!(
                "rows {} != labels {}",
                self.features.n_rows(),
                self.labels.len()
            ));
        }
        if self.features.n_cols != self.n_features {
            return Err("n_features mismatch".into());
        }
        for (i, ls) in self.labels.iter().enumerate() {
            if ls.windows(2).any(|w| w[0] >= w[1]) {
                return Err(format!("labels of example {i} not sorted/distinct"));
            }
            if ls.iter().any(|&l| l as usize >= self.n_labels) {
                return Err(format!("label out of range in example {i}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_accessors_and_validate() {
        let mut f = CsrMatrix::new(4);
        f.push_row(&[0, 2], &[1.0, 1.0]);
        f.push_row(&[1], &[2.0]);
        let mut ds = Dataset {
            name: "t".into(),
            features: f,
            labels: vec![vec![0], vec![1, 2]],
            n_features: 4,
            n_labels: 3,
            multiclass: false,
        };
        assert!(ds.validate().is_ok());
        ds.detect_multiclass();
        assert!(!ds.multiclass);
        assert_eq!(ds.label_frequencies(), vec![1, 1, 1]);
        let s = ds.select(&[1]);
        assert_eq!(s.n_examples(), 1);
        assert_eq!(s.labels_of(0), &[1, 2]);
    }

    #[test]
    fn validate_rejects_bad_labels() {
        let mut f = CsrMatrix::new(2);
        f.push_row(&[0], &[1.0]);
        let ds = Dataset {
            name: "bad".into(),
            features: f,
            labels: vec![vec![5]],
            n_features: 2,
            n_labels: 3,
            multiclass: true,
        };
        assert!(ds.validate().is_err());
    }
}
