//! Registry of the paper's nine evaluation datasets as synthetic analogs
//! (DESIGN.md §3). Shape statistics (C, density, multiclass/multilabel,
//! teacher regime) follow the paper's Tables 1–2; `n` and `D` are scaled
//! down (documented per entry) so every table regenerates on a laptop-class
//! box. Pass `scale = 1.0` for the full analog sizes used in
//! EXPERIMENTS.md, smaller for smoke tests.

use super::synthetic::{SyntheticSpec, TeacherKind};
use super::Dataset;

/// One paper dataset analog.
#[derive(Clone, Debug)]
pub struct AnalogSpec {
    pub paper_name: &'static str,
    /// Paper's (n, D, C) for reference.
    pub paper_n: usize,
    pub paper_d: usize,
    pub paper_c: usize,
    /// Our scaled (n, D) at scale=1.0 (C is never scaled: it drives E).
    pub n: usize,
    pub d: usize,
    pub density: f64,
    pub multiclass: bool,
    pub labels_per_example: usize,
    pub teacher: TeacherKind,
    pub noise: f64,
    pub skew: f64,
    /// Cluster-pool fraction: 1.0 = separable clusters (LTLS fits),
    /// small = heavy collisions (LTLS degrades through the E bottleneck).
    pub pool_frac: f64,
}

impl AnalogSpec {
    /// Build the generator spec at a given scale factor (scales n only;
    /// D and C define the learning problem's shape and stay fixed).
    pub fn spec(&self, scale: f64, seed: u64) -> SyntheticSpec {
        let n = ((self.n as f64 * scale).round() as usize).max(200);
        SyntheticSpec {
            name: self.paper_name.to_string(),
            n_examples: n,
            n_features: self.d,
            n_labels: self.paper_c,
            density: self.density,
            labels_per_example: self.labels_per_example,
            teacher: self.teacher,
            noise: self.noise,
            skew: self.skew,
            cluster_size: 12,
            active_per_label: 8,
            background: 4,
            pool_frac: self.pool_frac,
            seed,
        }
    }

    /// Generate train and test splits (80/20). A single generator call
    /// produces both so the planted teacher (cluster salt) is identical;
    /// the split itself is i.i.d.
    pub fn generate(&self, scale: f64, seed: u64) -> (Dataset, Dataset) {
        let mut spec = self.spec(scale * 1.25, seed);
        spec.n_examples = (spec.n_examples).max(250);
        let all = spec.generate();
        crate::data::split::random_split(&all, 0.2, seed ^ 0xDEAD)
    }
}

/// The five multiclass datasets of Table 1.
pub fn multiclass_analogs() -> Vec<AnalogSpec> {
    vec![
        // sector: small C, high-dim sparse text; LTLS fits well (0.88).
        AnalogSpec {
            paper_name: "sector",
            paper_n: 8658,
            paper_d: 55197,
            paper_c: 105,
            n: 8658,
            d: 4000,
            density: 0.01,
            multiclass: true,
            labels_per_example: 1,
            teacher: TeacherKind::Cluster,
            noise: 0.03,
            skew: 0.0,
            pool_frac: 1.0,
        },
        // aloi.bin: C=1000, sparse; LTLS competitive (0.82).
        AnalogSpec {
            paper_name: "aloi.bin",
            paper_n: 100_000,
            paper_d: 636_911,
            paper_c: 1000,
            n: 20_000,
            d: 8000,
            density: 0.004,
            multiclass: true,
            labels_per_example: 1,
            teacher: TeacherKind::Cluster,
            noise: 0.05,
            skew: 0.0,
            pool_frac: 1.0,
        },
        // LSHTC1: C=12294 long-tail text; LTLS overfits/underperforms (0.095†).
        AnalogSpec {
            paper_name: "LSHTC1",
            paper_n: 83_805,
            paper_d: 347_255,
            paper_c: 12_294,
            n: 20_000,
            d: 10_000,
            density: 0.004,
            multiclass: true,
            labels_per_example: 1,
            teacher: TeacherKind::Cluster,
            noise: 0.05,
            skew: 1.1,
            pool_frac: 0.04,
        },
        // ImageNet: dense small feature space; linear LTLS fails (0.0075*).
        AnalogSpec {
            paper_name: "imageNet",
            paper_n: 1_261_404,
            paper_d: 1000,
            paper_c: 1000,
            n: 30_000,
            d: 1000,
            density: 0.308,
            multiclass: true,
            labels_per_example: 1,
            teacher: TeacherKind::Nonlinear,
            noise: 0.02,
            skew: 0.0,
            pool_frac: 1.0,
        },
        // Dmoz: C=11947 text; LTLS mid (0.23†).
        AnalogSpec {
            paper_name: "Dmoz",
            paper_n: 345_068,
            paper_d: 833_484,
            paper_c: 11_947,
            n: 25_000,
            d: 10_000,
            density: 0.003,
            multiclass: true,
            labels_per_example: 1,
            teacher: TeacherKind::Cluster,
            noise: 0.05,
            skew: 0.9,
            pool_frac: 0.06,
        },
    ]
}

/// The four multilabel datasets of Table 2.
pub fn multilabel_analogs() -> Vec<AnalogSpec> {
    vec![
        // Bibtex: tiny; LTLS notably below LEML/FastXML (0.27).
        AnalogSpec {
            paper_name: "bibtex",
            paper_n: 5991,
            paper_d: 1837,
            paper_c: 159,
            n: 5991,
            d: 1837,
            density: 0.04,
            multiclass: false,
            labels_per_example: 2,
            teacher: TeacherKind::Cluster,
            noise: 0.08,
            skew: 0.7,
            pool_frac: 0.08,
        },
        // rcv1-regions: LTLS strong (0.90).
        AnalogSpec {
            paper_name: "rcv1-regions",
            paper_n: 20_835,
            paper_d: 47_236,
            paper_c: 225,
            n: 20_835,
            d: 5000,
            density: 0.015,
            multiclass: false,
            labels_per_example: 2,
            teacher: TeacherKind::Cluster,
            noise: 0.03,
            skew: 0.0,
            pool_frac: 1.0,
        },
        // Eur-Lex: LTLS underfits badly (0.056*).
        AnalogSpec {
            paper_name: "Eur-Lex",
            paper_n: 15_643,
            paper_d: 5000,
            paper_c: 3956,
            n: 15_643,
            d: 5000,
            density: 0.05,
            multiclass: false,
            labels_per_example: 3,
            teacher: TeacherKind::Cluster,
            noise: 0.05,
            skew: 1.0,
            pool_frac: 0.05,
        },
        // LSHTCwiki: C=320k; LTLS decent given tiny model (0.22).
        AnalogSpec {
            paper_name: "LSHTCwiki",
            paper_n: 2_355_436,
            paper_d: 2_085_167,
            paper_c: 320_338,
            n: 40_000,
            d: 20_000,
            density: 0.002,
            multiclass: false,
            labels_per_example: 2,
            teacher: TeacherKind::Cluster,
            noise: 0.06,
            skew: 1.1,
            pool_frac: 1.0,
        },
    ]
}

/// All nine analogs (Table 3 runs over every dataset).
pub fn all_analogs() -> Vec<AnalogSpec> {
    let mut v = multiclass_analogs();
    v.extend(multilabel_analogs());
    v
}

/// Look up an analog by paper name (case-insensitive). The extra names
/// `"synthetic"` (multiclass) and `"synthetic-ml"` (its multilabel twin,
/// ~3 labels per example over the same teacher) resolve to small generic
/// analogs used by CI smoke runs
/// (`ltls train --dataset synthetic --epochs 1`); they are not part of
/// the paper registry and do not appear in [`all_analogs`].
pub fn by_name(name: &str) -> Option<AnalogSpec> {
    if name.eq_ignore_ascii_case("synthetic") || name.eq_ignore_ascii_case("synthetic-ml") {
        let multilabel = name.eq_ignore_ascii_case("synthetic-ml");
        return Some(AnalogSpec {
            paper_name: if multilabel { "synthetic-ml" } else { "synthetic" },
            paper_n: 4_000,
            paper_d: 1_000,
            paper_c: 64,
            n: 4_000,
            d: 1_000,
            density: 0.01,
            multiclass: !multilabel,
            labels_per_example: if multilabel { 3 } else { 1 },
            teacher: TeacherKind::Cluster,
            noise: 0.02,
            skew: 0.0,
            pool_frac: 1.0,
        });
    }
    all_analogs().into_iter().find(|a| a.paper_name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Trellis;

    #[test]
    fn registry_is_complete() {
        assert_eq!(multiclass_analogs().len(), 5);
        assert_eq!(multilabel_analogs().len(), 4);
        assert_eq!(all_analogs().len(), 9);
    }

    /// The paper's Table 3 "#edges" column emerges from our C values.
    #[test]
    fn edge_counts_match_paper_table3() {
        let expect = [
            ("sector", 28usize),
            ("aloi.bin", 42),
            ("LSHTC1", 56),
            ("imageNet", 42),
            ("Dmoz", 61),
            ("bibtex", 34),
            ("Eur-Lex", 52),
            ("LSHTCwiki", 81),
        ];
        for (name, e) in expect {
            let a = by_name(name).unwrap();
            assert_eq!(Trellis::new(a.paper_c as u64).num_edges(), e, "{name}");
        }
    }

    #[test]
    fn small_scale_generation_works() {
        for a in all_analogs() {
            if a.paper_c > 50_000 {
                continue; // LSHTCwiki covered in integration tests
            }
            let (train, test) = a.generate(0.02, 1);
            assert!(train.validate().is_ok(), "{}", a.paper_name);
            assert!(test.n_examples() > 0);
            assert_eq!(train.multiclass, a.multiclass, "{}", a.paper_name);
        }
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("SECTOR").is_some());
        assert!(by_name("nope").is_none());
    }

    /// The CI smoke alias generates quickly and stays out of the registry.
    #[test]
    fn synthetic_smoke_alias() {
        let a = by_name("synthetic").unwrap();
        let (train, test) = a.generate(0.1, 1);
        assert!(train.validate().is_ok() && test.n_examples() > 0);
        assert!(all_analogs().iter().all(|x| x.paper_name != "synthetic"));
    }

    /// The multilabel smoke alias: same shape, genuinely multi-label rows.
    #[test]
    fn synthetic_ml_smoke_alias() {
        let a = by_name("synthetic-ml").unwrap();
        assert!(!a.multiclass && a.labels_per_example > 1);
        let (train, test) = a.generate(0.1, 1);
        assert!(train.validate().is_ok() && test.n_examples() > 0);
        assert!(!train.multiclass, "label sets must survive generation");
        let multi = (0..train.n_examples()).filter(|&i| train.labels_of(i).len() > 1).count();
        assert!(multi * 2 > train.n_examples(), "most rows carry >1 label: {multi}");
        assert!(all_analogs().iter().all(|x| x.paper_name != "synthetic-ml"));
    }
}
