//! Synthetic dataset generation (DESIGN.md §3 substitution).
//!
//! The paper evaluates on nine public XC datasets we cannot ship. What the
//! tables actually measure is driven by *shape statistics* — number of
//! classes C, feature dimension D, sparsity, label-prior skew — and by
//! whether the class concepts are linearly separable through an `E`-edge
//! bottleneck. The generators plant a ground-truth teacher and sample
//! examples from it:
//!
//! * [`TeacherKind::Cluster`] — generative "topic" teacher: each label owns
//!   a cluster of characteristic features (think class-specific
//!   vocabulary); an example draws its labels first, then features from
//!   their clusters plus background noise. With a roomy feature pool the
//!   clusters are near-disjoint and the problem is LTLS-realizable under
//!   *any* label→path assignment (each edge scorer learns the union of the
//!   clusters of labels routed through it) — the sector/aloi/rcv1 regime.
//!   Shrinking [`SyntheticSpec::pool_frac`] forces heavy cluster collision,
//!   which breaks realizability through the E-dim bottleneck and
//!   reproduces the regime where LTLS trails (LSHTC1 / Dmoz / Eur-Lex /
//!   bibtex).
//! * [`TeacherKind::Nonlinear`] — dense features + a random 2-layer MLP
//!   teacher: linear LTLS fails but the deep variant works (the ImageNet
//!   regime, paper §6).

use super::Dataset;
use crate::sparse::CsrMatrix;
use crate::util::rng::{Rng, ZipfTable};

/// What concept generates the labels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TeacherKind {
    /// Label-first generative cluster teacher (sparse text-like data).
    Cluster,
    /// Dense nonlinear teacher (feature-first; the ImageNet analog).
    Nonlinear,
}

/// Declarative spec for a synthetic dataset.
#[derive(Clone, Debug)]
pub struct SyntheticSpec {
    pub name: String,
    pub n_examples: usize,
    pub n_features: usize,
    pub n_labels: usize,
    /// For [`TeacherKind::Nonlinear`]: fraction of nonzero features.
    pub density: f64,
    /// Labels per example (1 = multiclass).
    pub labels_per_example: usize,
    pub teacher: TeacherKind,
    /// Label-flip noise rate.
    pub noise: f64,
    /// Zipf exponent for the label prior (0 = uniform).
    pub skew: f64,
    /// Cluster teacher: size of each label's feature cluster.
    pub cluster_size: usize,
    /// Cluster teacher: cluster features active per example per label.
    pub active_per_label: usize,
    /// Cluster teacher: background (non-informative) features per example.
    pub background: usize,
    /// Cluster teacher: clusters are drawn from the first
    /// `pool_frac · D` features. 1.0 → near-disjoint clusters (easy);
    /// small → heavy collisions (hard through a log-C bottleneck).
    pub pool_frac: f64,
    pub seed: u64,
}

impl SyntheticSpec {
    /// Multiclass dataset shorthand.
    pub fn multiclass(n: usize, d: usize, c: usize) -> Self {
        SyntheticSpec {
            name: format!("synthetic-mc-{c}"),
            n_examples: n,
            n_features: d,
            n_labels: c,
            density: 0.05,
            labels_per_example: 1,
            teacher: TeacherKind::Cluster,
            noise: 0.0,
            skew: 0.0,
            cluster_size: 12,
            active_per_label: 8,
            background: 4,
            pool_frac: 1.0,
            seed: 1,
        }
    }

    /// Multilabel dataset shorthand.
    pub fn multilabel(n: usize, d: usize, c: usize, k: usize) -> Self {
        let mut s = Self::multiclass(n, d, c);
        s.name = format!("synthetic-ml-{c}");
        s.labels_per_example = k;
        s
    }

    pub fn named(mut self, name: &str) -> Self {
        self.name = name.to_string();
        self
    }
    pub fn density(mut self, v: f64) -> Self {
        self.density = v;
        self
    }
    pub fn teacher(mut self, t: TeacherKind) -> Self {
        self.teacher = t;
        self
    }
    pub fn noise(mut self, v: f64) -> Self {
        self.noise = v;
        self
    }
    pub fn skew(mut self, v: f64) -> Self {
        self.skew = v;
        self
    }
    pub fn pool_frac(mut self, v: f64) -> Self {
        self.pool_frac = v;
        self
    }
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    /// Generate the dataset.
    pub fn generate(&self) -> Dataset {
        match self.teacher {
            TeacherKind::Cluster => self.generate_cluster(),
            TeacherKind::Nonlinear => self.generate_nonlinear(),
        }
    }

    /// Deterministic cluster membership: feature `slot` of label `l`.
    /// Derived by hashing so clusters for C=320k labels need no storage.
    fn cluster_feature(&self, label: u32, slot: usize, salt: u64) -> u32 {
        let pool = ((self.n_features as f64 * self.pool_frac) as usize)
            .clamp(1, self.n_features);
        let mut h = (label as u64)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(slot as u64)
            .wrapping_mul(0xBF58_476D_1CE4_E5B9)
            .wrapping_add(salt);
        h ^= h >> 31;
        h = h.wrapping_mul(0x94D0_49BB_1331_11EB);
        h ^= h >> 29;
        (h % pool as u64) as u32
    }

    fn draw_labels(&self, rng: &mut Rng, zipf: Option<&ZipfTable>, perm: &[u32]) -> Vec<u32> {
        let mut ls: Vec<u32> = Vec::with_capacity(self.labels_per_example);
        let mut guard = 0;
        while ls.len() < self.labels_per_example && guard < 100 {
            guard += 1;
            let l = match zipf {
                Some(z) => perm[z.sample(rng)],
                None => rng.below(self.n_labels as u64) as u32,
            };
            if !ls.contains(&l) {
                ls.push(l);
            }
        }
        ls
    }

    fn generate_cluster(&self) -> Dataset {
        let mut rng = Rng::new(self.seed ^ 0x5EED_0001);
        let salt = self.seed.wrapping_mul(0x2545_F491_4F6C_DD1D);
        let zipf = (self.skew > 0.0).then(|| ZipfTable::new(self.n_labels, self.skew));
        let mut perm: Vec<u32> = (0..self.n_labels as u32).collect();
        rng.shuffle(&mut perm);

        let mut features = CsrMatrix::new(self.n_features);
        let mut labels: Vec<Vec<u32>> = Vec::with_capacity(self.n_examples);
        let mut pairs: Vec<(u32, f32)> = Vec::new();
        for _ in 0..self.n_examples {
            let mut ls = self.draw_labels(&mut rng, zipf.as_ref(), &perm);
            pairs.clear();
            // Cluster features for each true label. The first label is the
            // document's *primary* topic and dominates the feature mass
            // (realistic for multilabel text: rcv1 region tags etc.) —
            // secondary labels contribute at reduced weight.
            for (li, &l) in ls.iter().enumerate() {
                let picks = rng.sample_distinct(self.cluster_size, self.active_per_label.min(self.cluster_size));
                let topic_weight = if li == 0 { 1.0 } else { 0.45 };
                for slot in picks {
                    let f = self.cluster_feature(l, slot as usize, salt);
                    pairs.push((f, (1.0 + rng.f32()) * topic_weight));
                }
            }
            // Background features over the full range.
            for _ in 0..self.background {
                let f = rng.below(self.n_features as u64) as u32;
                pairs.push((f, 0.5 + 0.5 * rng.f32()));
            }
            // Merge duplicates, sort, L2-normalize.
            pairs.sort_by_key(|p| p.0);
            let mut idx: Vec<u32> = Vec::with_capacity(pairs.len());
            let mut val: Vec<f32> = Vec::with_capacity(pairs.len());
            for &(i, v) in pairs.iter() {
                if idx.last() == Some(&i) {
                    *val.last_mut().unwrap() += v;
                } else {
                    idx.push(i);
                    val.push(v);
                }
            }
            let norm = val.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-12);
            for v in &mut val {
                *v /= norm;
            }
            features.push_row(&idx, &val);

            // Label noise: flip to a random label (features stay).
            for l in ls.iter_mut() {
                if self.noise > 0.0 && rng.coin(self.noise) {
                    *l = rng.below(self.n_labels as u64) as u32;
                }
            }
            ls.sort_unstable();
            ls.dedup();
            labels.push(ls);
        }
        self.finish(features, labels)
    }

    /// Antipodal-direction teacher (the ImageNet analog): each class `l`
    /// owns a dense direction `g_l` over 64 hashed coordinates; an example
    /// of class `l` is `x = ±6·g_l + 0.5·noise` (noise on `density·D`
    /// random coords), L2-normalized. The ± sign makes every class mean
    /// zero, so **no linear model can separate the classes** (scores are
    /// antisymmetric in x) while an MLP learns `|g_l·x|` easily — the
    /// provable version of the paper's §6 observation that linear LTLS
    /// fails on dense ImageNet features but a deep edge scorer works.
    fn generate_nonlinear(&self) -> Dataset {
        let mut rng = Rng::new(self.seed ^ 0x5EED_0002);
        let salt = self.seed.wrapping_mul(0x9E6D_5C4B_3A29_1807);
        let (d, c) = (self.n_features, self.n_labels);
        let sig_coords = 64.min(d);
        // Per-class direction values (deterministic from (l, slot)).
        let gval = |l: u32, slot: usize| -> f32 {
            let mut h = (l as u64)
                .wrapping_mul(0xD6E8_FEB8_6659_FD93)
                .wrapping_add(slot as u64 ^ salt);
            h ^= h >> 29;
            h = h.wrapping_mul(0xBF58_476D_1CE4_E5B9);
            h ^= h >> 32;
            // Roughly N(0,1) via sum of uniforms.
            let u1 = (h & 0xFFFF_FFFF) as f32 / u32::MAX as f32;
            let u2 = (h >> 32) as f32 / u32::MAX as f32;
            (u1 + u2 - 1.0) * 3.46 // var ≈ 1
        };
        let noise_nnz = ((d as f64 * self.density).round() as usize).clamp(1, d);

        let mut features = CsrMatrix::new(d);
        let mut labels: Vec<Vec<u32>> = Vec::with_capacity(self.n_examples);
        let mut pairs: Vec<(u32, f32)> = Vec::new();
        for _ in 0..self.n_examples {
            let l = rng.below(c as u64) as u32;
            let sign = if rng.coin(0.5) { 6.0f32 } else { -6.0 };
            pairs.clear();
            // Signal coords (hashed per class; like cluster_feature).
            let mut gnorm = 0.0f32;
            for slot in 0..sig_coords {
                gnorm += gval(l, slot) * gval(l, slot);
            }
            let gnorm = gnorm.sqrt().max(1e-6);
            for slot in 0..sig_coords {
                let f = self.cluster_feature(l, slot, salt);
                pairs.push((f, sign * gval(l, slot) / gnorm));
            }
            // Dense background noise.
            for f in rng.sample_distinct(d, noise_nnz) {
                pairs.push((f, 0.5 * rng.normal()));
            }
            pairs.sort_by_key(|p| p.0);
            let mut idx: Vec<u32> = Vec::with_capacity(pairs.len());
            let mut val: Vec<f32> = Vec::with_capacity(pairs.len());
            for &(i, v) in pairs.iter() {
                if idx.last() == Some(&i) {
                    *val.last_mut().unwrap() += v;
                } else {
                    idx.push(i);
                    val.push(v);
                }
            }
            let norm = val.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-12);
            for v in &mut val {
                *v /= norm;
            }
            features.push_row(&idx, &val);

            let mut ls = vec![if self.noise > 0.0 && rng.coin(self.noise) {
                rng.below(c as u64) as u32
            } else {
                l
            }];
            // Multilabel nonlinear (unused by the paper analogs; sampled
            // uniformly beyond the first label).
            while ls.len() < self.labels_per_example {
                let extra = rng.below(c as u64) as u32;
                if !ls.contains(&extra) {
                    ls.push(extra);
                }
            }
            ls.sort_unstable();
            ls.dedup();
            labels.push(ls);
        }
        self.finish(features, labels)
    }

    fn finish(&self, features: CsrMatrix, labels: Vec<Vec<u32>>) -> Dataset {
        let mut ds = Dataset {
            name: self.name.clone(),
            features,
            labels,
            n_features: self.n_features,
            n_labels: self.n_labels,
            multiclass: self.labels_per_example == 1,
        };
        ds.detect_multiclass();
        debug_assert!(ds.validate().is_ok());
        ds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_valid_multiclass() {
        let ds = SyntheticSpec::multiclass(200, 2000, 50).seed(3).generate();
        assert_eq!(ds.n_examples(), 200);
        assert!(ds.multiclass);
        assert!(ds.validate().is_ok());
        let used = ds.label_frequencies().iter().filter(|&&f| f > 0).count();
        assert!(used > 10, "only {used} labels used");
    }

    #[test]
    fn generates_valid_multilabel() {
        let ds = SyntheticSpec::multilabel(100, 1500, 40, 3).seed(4).generate();
        assert!(!ds.multiclass);
        assert!(ds.validate().is_ok());
        let max_k = ds.labels.iter().map(|l| l.len()).max().unwrap();
        assert!(max_k <= 3);
        assert!(ds.labels.iter().any(|l| l.len() > 1));
    }

    #[test]
    fn deterministic_given_seed() {
        let a = SyntheticSpec::multiclass(50, 600, 20).seed(9).generate();
        let b = SyntheticSpec::multiclass(50, 600, 20).seed(9).generate();
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.features.values, b.features.values);
        let c = SyntheticSpec::multiclass(50, 600, 20).seed(10).generate();
        assert_ne!(a.labels, c.labels);
    }

    #[test]
    fn nonlinear_density_controls_nnz() {
        // nnz ≈ 64 signal coords + density·D noise coords (minus overlaps).
        let ds = SyntheticSpec::multiclass(50, 200, 10)
            .teacher(TeacherKind::Nonlinear)
            .density(0.1)
            .seed(5)
            .generate();
        let nnz = ds.features.mean_nnz();
        assert!(nnz > 55.0 && nnz < 86.0, "nnz={nnz}");
    }

    /// The antipodal teacher's classes have (near-)zero mean — the property
    /// that makes them unlearnable for any linear scorer.
    #[test]
    fn nonlinear_classes_have_zero_mean() {
        let ds = SyntheticSpec::multiclass(2000, 300, 4)
            .teacher(TeacherKind::Nonlinear)
            .density(0.05)
            .seed(6)
            .generate();
        let mut mean = vec![0.0f64; 300];
        let mut count = 0usize;
        for i in 0..ds.n_examples() {
            if ds.labels_of(i)[0] == 0 {
                let row = ds.row(i);
                for (&fi, &v) in row.indices.iter().zip(row.values) {
                    mean[fi as usize] += v as f64;
                }
                count += 1;
            }
        }
        let max_abs =
            mean.iter().map(|m| (m / count as f64).abs()).fold(0.0f64, f64::max);
        // Each coordinate's class-conditional mean is ~0 (± sampling noise),
        // even though signal coordinates have |value| up to ~0.6.
        assert!(max_abs < 0.1, "max |class mean| = {max_abs}");
    }

    #[test]
    fn teacher_kinds_all_generate() {
        for t in [TeacherKind::Cluster, TeacherKind::Nonlinear] {
            let ds = SyntheticSpec::multiclass(30, 500, 16).teacher(t).seed(6).generate();
            assert!(ds.validate().is_ok(), "{t:?}");
        }
    }

    #[test]
    fn skew_produces_long_tail() {
        let ds = SyntheticSpec::multiclass(2000, 2000, 100).skew(1.1).seed(7).generate();
        let mut f = ds.label_frequencies();
        f.sort_unstable_by(|a, b| b.cmp(a));
        assert!(f[0] > 3 * f[50].max(1), "head {} vs median {}", f[0], f[50]);
    }

    /// Cluster features are deterministic per (label, slot) and live in the
    /// pool prefix.
    #[test]
    fn cluster_features_deterministic_and_pooled() {
        let spec = SyntheticSpec::multiclass(1, 1000, 50).pool_frac(0.2);
        for l in 0..50u32 {
            for s in 0..12usize {
                let a = spec.cluster_feature(l, s, 7);
                let b = spec.cluster_feature(l, s, 7);
                assert_eq!(a, b);
                assert!(a < 200, "pooled feature out of prefix: {a}");
            }
        }
    }

    /// Small pool_frac yields heavy cluster collisions (the hard regime).
    #[test]
    fn pool_frac_controls_collisions() {
        let easy = SyntheticSpec::multiclass(1, 10_000, 100);
        let hard = SyntheticSpec::multiclass(1, 10_000, 100).pool_frac(0.01);
        let distinct = |s: &SyntheticSpec| {
            let mut f: Vec<u32> = (0..100u32)
                .flat_map(|l| (0..12).map(move |slot| (l, slot)))
                .map(|(l, slot)| s.cluster_feature(l, slot, 3))
                .collect();
            f.sort_unstable();
            f.dedup();
            f.len()
        };
        assert!(distinct(&easy) > 2 * distinct(&hard));
    }
}
