//! libsvm / XMLC-repository format loader.
//!
//! Lines look like `l1,l2,... f1:v1 f2:v2 ...` (multilabel) or
//! `l f1:v1 ...` (multiclass). An optional header line `n d c` (three bare
//! integers, the XMLC repository convention) is auto-detected and used to
//! size the dataset; when present, the stated example count `n` is
//! validated against the rows actually read (a mismatch is an error — it
//! means rows were lost or the file was truncated). Feature ids may be 0-
//! or 1-based; the loader keeps them as-is and sizes `n_features` to the
//! max seen (or header value).
//!
//! Unlabeled examples are legal (XMLC allows them): a row may start with
//! a bare `,` (no labels), and [`dump`] writes unlabeled rows that way so
//! even a featureless, unlabeled example survives a dump→parse roundtrip
//! instead of collapsing into a blank line that [`parse`] would skip.

use super::Dataset;
use crate::sparse::CsrMatrix;
use std::io::{BufRead, BufReader, Read};
use std::path::Path;

/// Parse a dataset from a reader.
pub fn parse<R: Read>(name: &str, reader: R) -> Result<Dataset, String> {
    let mut lines = BufReader::new(reader).lines();
    let mut first: Option<String> = None;
    // Header detection: "n d c" of bare integers.
    let mut header: Option<(usize, usize, usize)> = None;
    if let Some(Ok(line)) = lines.next() {
        let toks: Vec<&str> = line.split_whitespace().collect();
        if toks.len() == 3 && toks.iter().all(|t| t.parse::<usize>().is_ok()) {
            header = Some((
                toks[0].parse().unwrap(),
                toks[1].parse().unwrap(),
                toks[2].parse().unwrap(),
            ));
        } else {
            first = Some(line);
        }
    }

    let mut rows: Vec<(Vec<u32>, Vec<u32>, Vec<f32>)> = Vec::new();
    let mut max_feat = 0u32;
    let mut max_label = 0u32;
    let mut handle = |line: &str, lineno: usize| -> Result<(), String> {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            return Ok(());
        }
        let mut parts = line.split_whitespace();
        let label_tok = parts.next().ok_or(format!("line {lineno}: empty"))?;
        let mut labels: Vec<u32> = Vec::new();
        // A first token with ':' means "no labels" (XMLC allows it) — treat
        // the token as a feature and the example as unlabeled.
        let mut feature_toks: Vec<&str> = Vec::new();
        if label_tok.contains(':') {
            feature_toks.push(label_tok);
        } else {
            for l in label_tok.split(',') {
                if l.is_empty() {
                    continue;
                }
                let v: u32 = l.parse().map_err(|e| format!("line {lineno}: label {l:?}: {e}"))?;
                labels.push(v);
                max_label = max_label.max(v);
            }
        }
        labels.sort_unstable();
        labels.dedup();
        let mut idx: Vec<u32> = Vec::new();
        let mut val: Vec<f32> = Vec::new();
        for tok in feature_toks.into_iter().map(Some).chain(parts.map(Some)).flatten() {
            let (i, v) = tok
                .split_once(':')
                .ok_or(format!("line {lineno}: bad feature token {tok:?}"))?;
            let i: u32 = i.parse().map_err(|e| format!("line {lineno}: {e}"))?;
            let v: f32 = v.parse().map_err(|e| format!("line {lineno}: {e}"))?;
            idx.push(i);
            val.push(v);
            max_feat = max_feat.max(i);
        }
        // Sort features by index (some files are unsorted).
        let mut order: Vec<usize> = (0..idx.len()).collect();
        order.sort_by_key(|&k| idx[k]);
        let idx: Vec<u32> = order.iter().map(|&k| idx[k]).collect();
        let val: Vec<f32> = order.iter().map(|&k| val[k]).collect();
        if idx.windows(2).any(|w| w[0] == w[1]) {
            return Err(format!("line {lineno}: duplicate feature index"));
        }
        rows.push((labels, idx, val));
        Ok(())
    };

    let mut lineno = if header.is_some() { 1 } else { 0 };
    if let Some(line) = first {
        lineno += 1;
        handle(&line, lineno)?;
    }
    for line in lines {
        lineno += 1;
        handle(&line.map_err(|e| e.to_string())?, lineno)?;
    }

    // The header's example count is a checksum against silent row loss
    // (truncated files, blank-line-collapsed rows): reject a mismatch.
    if let Some((n, _, _)) = header {
        if n != rows.len() {
            return Err(format!(
                "header says {n} examples but {} row(s) were read",
                rows.len()
            ));
        }
    }
    let (n_features, n_labels) = match header {
        Some((_, d, c)) => (d.max(max_feat as usize + 1), c.max(max_label as usize + 1)),
        None => (max_feat as usize + 1, max_label as usize + 1),
    };
    let mut features = CsrMatrix::new(n_features);
    let mut labels = Vec::with_capacity(rows.len());
    for (ls, idx, val) in rows {
        features.push_row(&idx, &val);
        labels.push(ls);
    }
    let mut ds = Dataset {
        name: name.to_string(),
        features,
        labels,
        n_features,
        n_labels,
        multiclass: false,
    };
    ds.detect_multiclass();
    ds.validate()?;
    Ok(ds)
}

/// Load a dataset from a file path.
pub fn load(path: &Path) -> Result<Dataset, String> {
    let f = std::fs::File::open(path).map_err(|e| format!("{}: {e}", path.display()))?;
    let name = path.file_stem().and_then(|s| s.to_str()).unwrap_or("dataset").to_string();
    parse(&name, f)
}

/// Serialize a dataset back to libsvm text (round-trip tests, exporting
/// synthetic analogs for external tools).
pub fn dump(ds: &Dataset) -> String {
    let mut out = String::new();
    out.push_str(&format!("{} {} {}\n", ds.n_examples(), ds.n_features, ds.n_labels));
    for i in 0..ds.n_examples() {
        let ls: Vec<String> = ds.labels_of(i).iter().map(|l| l.to_string()).collect();
        if ls.is_empty() {
            // A bare `,` marks "no labels": without it a featureless
            // unlabeled row would dump as a blank line, which `parse`
            // skips — silently changing n_examples on roundtrip.
            out.push(',');
        } else {
            out.push_str(&ls.join(","));
        }
        let row = ds.row(i);
        for (&j, &v) in row.indices.iter().zip(row.values) {
            out.push_str(&format!(" {j}:{v}"));
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_multiclass() {
        let text = "3 0:1.5 4:2\n1 2:0.5\n0 1:1 3:1\n";
        let ds = parse("mc", text.as_bytes()).unwrap();
        assert_eq!(ds.n_examples(), 3);
        assert!(ds.multiclass);
        assert_eq!(ds.n_labels, 4);
        assert_eq!(ds.n_features, 5);
        assert_eq!(ds.labels_of(0), &[3]);
        assert_eq!(ds.row(0).values, &[1.5, 2.0]);
    }

    #[test]
    fn parses_multilabel_with_header() {
        let text = "2 6 10\n1,5,3 0:1\n7 5:2.5\n";
        let ds = parse("ml", text.as_bytes()).unwrap();
        assert!(!ds.multiclass);
        assert_eq!(ds.n_labels, 10);
        assert_eq!(ds.n_features, 6);
        assert_eq!(ds.labels_of(0), &[1, 3, 5]); // sorted
    }

    #[test]
    fn unsorted_features_get_sorted() {
        let ds = parse("u", "0 5:1 2:2 7:3\n".as_bytes()).unwrap();
        assert_eq!(ds.row(0).indices, &[2, 5, 7]);
        assert_eq!(ds.row(0).values, &[2.0, 1.0, 3.0]);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("b", "1 nocolon\n".as_bytes()).is_err());
        assert!(parse("b", "x 0:1\n".as_bytes()).is_err());
        assert!(parse("b", "0 1:1 1:2\n".as_bytes()).is_err());
    }

    #[test]
    fn dump_parse_roundtrip() {
        let text = "1,2 0:1.5 3:2\n0 1:1\n";
        let ds = parse("rt", text.as_bytes()).unwrap();
        let dumped = dump(&ds);
        let again = parse("rt2", dumped.as_bytes()).unwrap();
        assert_eq!(again.n_examples(), ds.n_examples());
        assert_eq!(again.n_labels, ds.n_labels);
        for i in 0..ds.n_examples() {
            assert_eq!(again.labels_of(i), ds.labels_of(i));
            assert_eq!(again.row(i).indices, ds.row(i).indices);
        }
    }

    /// The row-loss regression: unlabeled rows — even ones with no
    /// features at all — must survive a dump→parse roundtrip. The old
    /// dump emitted such a row as a blank line, which parse skipped,
    /// silently shrinking `n_examples`.
    #[test]
    fn dump_parse_roundtrip_preserves_unlabeled_and_empty_rows() {
        // Row 0: labeled+features; row 1: unlabeled with features;
        // row 2: unlabeled AND featureless; row 3: labeled, featureless.
        let text = "1 0:1.5\n, 2:0.5\n,\n3\n";
        let ds = parse("er", text.as_bytes()).unwrap();
        assert_eq!(ds.n_examples(), 4);
        assert_eq!(ds.labels_of(1), &[] as &[u32]);
        assert_eq!(ds.labels_of(2), &[] as &[u32]);
        assert_eq!(ds.row(2).indices.len(), 0);
        assert_eq!(ds.labels_of(3), &[3]);
        let dumped = dump(&ds);
        let again = parse("er2", dumped.as_bytes()).unwrap();
        assert_eq!(again.n_examples(), ds.n_examples(), "roundtrip dropped rows:\n{dumped}");
        for i in 0..ds.n_examples() {
            assert_eq!(again.labels_of(i), ds.labels_of(i), "row {i}");
            assert_eq!(again.row(i).indices, ds.row(i).indices, "row {i}");
            assert_eq!(again.row(i).values, ds.row(i).values, "row {i}");
        }
        // The unlabeled-but-featured form without the comma still parses
        // (first token containing ':' means "no labels").
        let ds2 = parse("nf", "0:1 1:2\n".as_bytes()).unwrap();
        assert_eq!(ds2.n_examples(), 1);
        assert_eq!(ds2.labels_of(0), &[] as &[u32]);
    }

    /// Full label *sets* survive dump→parse — nothing collapses a
    /// multi-label row to its first label. Duplicates in the input are
    /// deduped once at parse time and the header's C survives even when
    /// no row touches the top label ids.
    #[test]
    fn dump_parse_roundtrip_preserves_full_label_sets() {
        let text = "3 6 40\n7,2,19,2 0:1 3:0.5\n4 1:1\n, 2:2\n";
        let ds = parse("mls", text.as_bytes()).unwrap();
        assert!(!ds.multiclass);
        assert_eq!(ds.labels_of(0), &[2, 7, 19], "sorted + deduped");
        assert_eq!(ds.n_labels, 40, "header C wins over max label seen");
        let dumped = dump(&ds);
        let again = parse("mls2", dumped.as_bytes()).unwrap();
        assert_eq!(again.n_labels, 40);
        assert!(!again.multiclass);
        for i in 0..ds.n_examples() {
            assert_eq!(again.labels_of(i), ds.labels_of(i), "row {i}");
        }
    }

    /// The header's example count is validated against the rows read.
    #[test]
    fn header_row_count_mismatch_is_an_error() {
        // Header claims 3 examples, file has 2.
        let err = parse("hc", "3 6 10\n1 0:1\n2 1:1\n".as_bytes()).unwrap_err();
        assert!(err.contains("3 examples"), "{err}");
        assert!(err.contains("2 row(s)"), "{err}");
        // Exact count parses fine; blank lines don't count as rows.
        let ds = parse("hc2", "2 6 10\n1 0:1\n\n2 1:1\n".as_bytes()).unwrap();
        assert_eq!(ds.n_examples(), 2);
    }

    #[test]
    fn empty_lines_and_comments_skipped() {
        let ds = parse("c", "# comment\n0 0:1\n\n1 1:1\n".as_bytes()).unwrap();
        assert_eq!(ds.n_examples(), 2);
    }
}
