//! Train/test splitting utilities.

use super::Dataset;
use crate::util::rng::Rng;

/// Random split into (train, test) with `test_frac` of examples held out.
pub fn random_split(ds: &Dataset, test_frac: f64, seed: u64) -> (Dataset, Dataset) {
    assert!((0.0..1.0).contains(&test_frac));
    let n = ds.n_examples();
    let mut order: Vec<usize> = (0..n).collect();
    let mut rng = Rng::new(seed ^ 0x5917);
    rng.shuffle(&mut order);
    let n_test = ((n as f64) * test_frac).round() as usize;
    let (test_rows, train_rows) = order.split_at(n_test);
    let mut train_rows = train_rows.to_vec();
    let mut test_rows = test_rows.to_vec();
    train_rows.sort_unstable();
    test_rows.sort_unstable();
    (ds.select(&train_rows), ds.select(&test_rows))
}

/// Deterministic k-fold iterator: returns the rows of fold `i` of `k`.
pub fn fold_rows(n: usize, k: usize, i: usize) -> (Vec<usize>, Vec<usize>) {
    assert!(i < k && k >= 2);
    let mut train = Vec::new();
    let mut test = Vec::new();
    for r in 0..n {
        if r % k == i {
            test.push(r);
        } else {
            train.push(r);
        }
    }
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;

    #[test]
    fn split_partitions_examples() {
        let ds = SyntheticSpec::multiclass(100, 50, 10).seed(2).generate();
        let (tr, te) = random_split(&ds, 0.2, 7);
        assert_eq!(tr.n_examples() + te.n_examples(), 100);
        assert_eq!(te.n_examples(), 20);
        assert!(tr.validate().is_ok() && te.validate().is_ok());
    }

    #[test]
    fn split_is_deterministic() {
        let ds = SyntheticSpec::multiclass(60, 30, 8).seed(3).generate();
        let (a, _) = random_split(&ds, 0.25, 1);
        let (b, _) = random_split(&ds, 0.25, 1);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn folds_cover_all_rows() {
        let k = 5;
        let mut seen = vec![0; 23];
        for i in 0..k {
            let (tr, te) = fold_rows(23, k, i);
            assert_eq!(tr.len() + te.len(), 23);
            for r in te {
                seen[r] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1));
    }
}
