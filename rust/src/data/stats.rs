//! Dataset statistics: the numbers the paper's tables report in their
//! left-hand columns (#examples, #features, #classes) plus density and
//! label-prior diagnostics used to verify our analogs match the regime.

use super::Dataset;

/// Summary statistics of a dataset.
#[derive(Clone, Debug)]
pub struct DatasetStats {
    pub name: String,
    pub n_examples: usize,
    pub n_features: usize,
    pub n_labels: usize,
    pub mean_nnz: f64,
    pub density: f64,
    pub mean_labels_per_example: f64,
    /// Fraction of label mass on the 10% most frequent labels.
    pub head_mass: f64,
    /// Number of labels that never appear.
    pub unused_labels: usize,
}

/// Compute stats.
pub fn stats(ds: &Dataset) -> DatasetStats {
    let freqs = ds.label_frequencies();
    let total: u64 = freqs.iter().sum();
    let mut sorted = freqs.clone();
    sorted.sort_unstable_by(|a, b| b.cmp(a));
    let head_n = (sorted.len() / 10).max(1);
    let head: u64 = sorted.iter().take(head_n).sum();
    DatasetStats {
        name: ds.name.clone(),
        n_examples: ds.n_examples(),
        n_features: ds.n_features,
        n_labels: ds.n_labels,
        mean_nnz: ds.features.mean_nnz(),
        density: ds.features.mean_nnz() / ds.n_features.max(1) as f64,
        mean_labels_per_example: total as f64 / ds.n_examples().max(1) as f64,
        head_mass: if total == 0 { 0.0 } else { head as f64 / total as f64 },
        unused_labels: freqs.iter().filter(|&&f| f == 0).count(),
    }
}

impl std::fmt::Display for DatasetStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}: n={} D={} C={} nnz/row={:.1} density={:.4} labels/ex={:.2} head10%={:.2} unused={}",
            self.name,
            self.n_examples,
            self.n_features,
            self.n_labels,
            self.mean_nnz,
            self.density,
            self.mean_labels_per_example,
            self.head_mass,
            self.unused_labels
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;

    #[test]
    fn stats_reflect_spec() {
        let ds = SyntheticSpec::multiclass(500, 100, 20).density(0.1).seed(1).generate();
        let s = stats(&ds);
        assert_eq!(s.n_examples, 500);
        assert_eq!(s.n_labels, 20);
        assert!((s.density - 0.1).abs() < 0.02);
        assert!((s.mean_labels_per_example - 1.0).abs() < 1e-9);
        assert!(!format!("{s}").is_empty());
    }

    #[test]
    fn skewed_data_has_head_mass() {
        let flat = SyntheticSpec::multiclass(2000, 100, 100).seed(2).generate();
        let skewed = SyntheticSpec::multiclass(2000, 100, 100).skew(1.2).seed(2).generate();
        assert!(stats(&skewed).head_mass > stats(&flat).head_mass);
    }
}
