//! LEML-style low-rank embedding baseline (Yu et al., ICML 2014),
//! simplified.
//!
//! LEML factorizes the label matrix: `Y ≈ X·W·Hᵀ` with `W ∈ R^{D×r}`,
//! `H ∈ R^{C×r}`. Here:
//!
//! 1. `H` — top-r label embedding by randomized power iteration on the
//!    implicit Gram matrix `YᵀY` (never materialized; applied through the
//!    sparse label lists).
//! 2. `W` — ridge regression from features to the example's mean label
//!    embedding, by SGD.
//! 3. Prediction scores all labels: `f = H·(Wᵀx)` — `O(C·r)`, which
//!    reproduces the paper's observation that embedding methods stay
//!    linear-in-C at prediction time (LEML's big prediction-time column).

use crate::data::Dataset;
use crate::eval::Predictor;
use crate::sparse::SparseVec;
use crate::util::rng::Rng;

/// Trained LEML model.
pub struct Leml {
    pub r: usize,
    pub c: usize,
    pub d: usize,
    /// C × r label embedding (row-major).
    h: Vec<f32>,
    /// D × r regressor (row-major).
    w: Vec<f32>,
    name: String,
}

/// Hyperparameters.
#[derive(Clone, Debug)]
pub struct LemlConfig {
    pub rank: usize,
    pub power_iters: usize,
    pub epochs: usize,
    pub lr: f32,
    pub l2: f32,
    pub seed: u64,
}

impl Default for LemlConfig {
    fn default() -> Self {
        LemlConfig { rank: 32, power_iters: 3, epochs: 5, lr: 0.3, l2: 1e-5, seed: 5 }
    }
}

impl Leml {
    pub fn train(ds: &Dataset, cfg: &LemlConfig) -> Self {
        let (c, d, r) = (ds.n_labels, ds.n_features, cfg.rank.min(ds.n_labels));
        let mut rng = Rng::new(cfg.seed);

        // --- 1. Label embedding H: power iteration on YᵀY. ---
        let mut h: Vec<f32> = (0..c * r).map(|_| rng.normal()).collect();
        orthonormalize(&mut h, c, r);
        let mut buf = vec![0.0f32; r];
        for _ in 0..cfg.power_iters {
            // G = Yᵀ(Y·H): accumulate per example.
            let mut g = vec![0.0f32; c * r];
            for i in 0..ds.n_examples() {
                let ls = ds.labels_of(i);
                if ls.is_empty() {
                    continue;
                }
                buf.iter_mut().for_each(|v| *v = 0.0);
                for &l in ls {
                    let row = &h[l as usize * r..(l as usize + 1) * r];
                    for (b, &v) in buf.iter_mut().zip(row) {
                        *b += v;
                    }
                }
                for &l in ls {
                    let row = &mut g[l as usize * r..(l as usize + 1) * r];
                    for (rv, &b) in row.iter_mut().zip(&buf) {
                        *rv += b;
                    }
                }
            }
            h = g;
            orthonormalize(&mut h, c, r);
        }

        // --- 2. Ridge regression W: x ↦ mean label embedding. ---
        let mut w = vec![0.0f32; d * r];
        let mut t = 0u64;
        let mut target = vec![0.0f32; r];
        let mut pred = vec![0.0f32; r];
        let mut order: Vec<usize> = (0..ds.n_examples()).collect();
        for _ in 0..cfg.epochs {
            rng.shuffle(&mut order);
            for &i in &order {
                let ls = ds.labels_of(i);
                if ls.is_empty() {
                    continue;
                }
                t += 1;
                let lr = cfg.lr / (1.0 + 1e-4 * t as f32).sqrt();
                let x = ds.row(i);
                // target = mean embedding of the true labels.
                target.iter_mut().for_each(|v| *v = 0.0);
                for &l in ls {
                    let row = &h[l as usize * r..(l as usize + 1) * r];
                    for (tv, &v) in target.iter_mut().zip(row) {
                        *tv += v / ls.len() as f32;
                    }
                }
                // pred = Wᵀx.
                pred.iter_mut().for_each(|v| *v = 0.0);
                for (&fi, &fv) in x.indices.iter().zip(x.values) {
                    let row = &w[fi as usize * r..(fi as usize + 1) * r];
                    for (pv, &wv) in pred.iter_mut().zip(row) {
                        *pv += fv * wv;
                    }
                }
                // SGD on ||pred − target||² + l2||W||².
                for (&fi, &fv) in x.indices.iter().zip(x.values) {
                    let row = &mut w[fi as usize * r..(fi as usize + 1) * r];
                    for q in 0..r {
                        row[q] -= lr * ((pred[q] - target[q]) * fv + cfg.l2 * row[q]);
                    }
                }
            }
        }
        Leml { r, c, d, h, w, name: "LEML".into() }
    }

    /// Embed a feature vector `u = Wᵀx` into `out` (r-dim).
    fn embed_into(&self, x: SparseVec, out: &mut Vec<f32>) {
        out.clear();
        out.resize(self.r, 0.0);
        for (&fi, &fv) in x.indices.iter().zip(x.values) {
            let row = &self.w[fi as usize * self.r..(fi as usize + 1) * self.r];
            for (uv, &wv) in out.iter_mut().zip(row) {
                *uv += fv * wv;
            }
        }
    }
}

/// Gram–Schmidt over the columns of a row-major `c × r` matrix.
fn orthonormalize(m: &mut [f32], c: usize, r: usize) {
    for col in 0..r {
        // Subtract projections on previous columns.
        for prev in 0..col {
            let mut dot = 0.0f32;
            for row in 0..c {
                dot += m[row * r + col] * m[row * r + prev];
            }
            for row in 0..c {
                m[row * r + col] -= dot * m[row * r + prev];
            }
        }
        let mut norm = 0.0f32;
        for row in 0..c {
            norm += m[row * r + col] * m[row * r + col];
        }
        let norm = norm.sqrt().max(1e-12);
        for row in 0..c {
            m[row * r + col] /= norm;
        }
    }
}

impl Predictor for Leml {
    fn topk(&self, x: SparseVec, k: usize) -> Vec<(u32, f32)> {
        let mut out = Vec::with_capacity(k + 1);
        self.topk_into(x, k, &mut crate::engine::PredictScratch::new(), &mut out);
        out
    }

    fn topk_into(
        &self,
        x: SparseVec,
        k: usize,
        scratch: &mut crate::engine::PredictScratch,
        out: &mut Vec<(u32, f32)>,
    ) {
        // Embed `u = Wᵀx` into the scratch's edge-score buffer (r-dim).
        self.embed_into(x, &mut scratch.h);
        let u = &scratch.h;
        // O(C·r) decode — intentionally linear in C (see module docs).
        out.clear();
        for l in 0..self.c {
            let row = &self.h[l * self.r..(l + 1) * self.r];
            let s: f32 = row.iter().zip(u).map(|(a, b)| a * b).sum();
            if out.len() < k || s > out.last().unwrap().1 {
                out.push((l as u32, s));
                out.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
                out.truncate(k);
            }
        }
    }

    fn model_bytes(&self) -> usize {
        (self.h.len() + self.w.len()) * 4
    }

    fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;
    use crate::eval::precision_at_1;

    #[test]
    fn orthonormalize_produces_orthonormal_columns() {
        let (c, r) = (20usize, 4usize);
        let mut rng = Rng::new(14);
        let mut m: Vec<f32> = (0..c * r).map(|_| rng.normal()).collect();
        orthonormalize(&mut m, c, r);
        for a in 0..r {
            for b in 0..=a {
                let dot: f32 = (0..c).map(|row| m[row * r + a] * m[row * r + b]).sum();
                let want = if a == b { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-4, "({a},{b}) dot={dot}");
            }
        }
    }

    #[test]
    fn learns_multilabel() {
        let ds = SyntheticSpec::multilabel(2000, 600, 40, 2).seed(15).generate();
        let (train, test) = crate::data::split::random_split(&ds, 0.2, 4);
        let leml = Leml::train(&train, &LemlConfig::default());
        let p1 = precision_at_1(&leml, &test);
        assert!(p1 > 0.3, "LEML p@1 = {p1} (chance ≈ 0.05)");
    }

    #[test]
    fn model_size_is_rank_linear() {
        let ds = SyntheticSpec::multiclass(300, 200, 50).seed(16).generate();
        let small = Leml::train(&ds, &LemlConfig { rank: 8, epochs: 1, ..Default::default() });
        let large = Leml::train(&ds, &LemlConfig { rank: 32, epochs: 1, ..Default::default() });
        assert_eq!(small.model_bytes() * 4, large.model_bytes());
    }
}
