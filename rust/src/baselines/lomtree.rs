//! LOMtree-style logarithmic-time multiclass tree (Choromanska & Langford,
//! NIPS 2015), simplified for this reproduction.
//!
//! A balanced binary tree with `C` leaves. Each internal node holds an
//! online linear router trained toward the LOMtree objective: class `y`
//! should go right iff the running mean router margin *conditioned on `y`*
//! exceeds the node's overall running mean — this simultaneously balances
//! the split and purifies the children. Leaves accumulate class
//! histograms. Prediction routes by router sign in `O(depth·nnz)`.
//!
//! Space: routers are stored sparsely (only features actually seen at the
//! node), matching the original implementation's hashed weights. The
//! paper's Table 1 reports LOMtree models ~3–7× larger than LTLS, which
//! this reproduces qualitatively.

use crate::data::Dataset;
use crate::eval::Predictor;
use crate::sparse::SparseVec;
use crate::util::rng::Rng;
use std::collections::HashMap;

struct Node {
    /// Sparse router weights.
    w: HashMap<u32, f32>,
    /// Running mean margin per class (EMA).
    class_mean: HashMap<u32, f32>,
    /// Overall running mean margin (EMA).
    mean: f32,
    seen: u64,
}

impl Node {
    fn new() -> Self {
        Node { w: HashMap::new(), class_mean: HashMap::new(), mean: 0.0, seen: 0 }
    }

    fn margin(&self, x: SparseVec) -> f32 {
        let mut acc = 0.0;
        for (&i, &v) in x.indices.iter().zip(x.values) {
            if let Some(w) = self.w.get(&i) {
                acc += w * v;
            }
        }
        acc
    }
}

/// The trained tree.
pub struct LomTree {
    nodes: Vec<Node>,
    /// Leaf class histograms, indexed by leaf id.
    leaf_hist: Vec<HashMap<u32, u32>>,
    depth: u32,
    name: String,
}

impl LomTree {
    /// Train online for `epochs` passes.
    pub fn train(ds: &Dataset, epochs: usize, lr: f32, seed: u64) -> Self {
        let depth = crate::util::ceil_log2(ds.n_labels.max(2) as u64);
        let n_internal = (1usize << depth) - 1;
        let mut t = LomTree {
            nodes: (0..n_internal).map(|_| Node::new()).collect(),
            leaf_hist: vec![HashMap::new(); 1 << depth],
            depth,
            name: "LOMtree".into(),
        };
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..ds.n_examples()).collect();
        let mut step = 0u64;
        for _ in 0..epochs {
            rng.shuffle(&mut order);
            for &r in &order {
                let ls = ds.labels_of(r);
                if ls.is_empty() {
                    continue;
                }
                step += 1;
                t.update(ds.row(r), ls[0], lr, step);
            }
        }
        // Final pass: fill leaf histograms with the trained routers.
        for r in 0..ds.n_examples() {
            let ls = ds.labels_of(r);
            if ls.is_empty() {
                continue;
            }
            let leaf = t.route(ds.row(r));
            for &l in ls {
                *t.leaf_hist[leaf].entry(l).or_insert(0) += 1;
            }
        }
        t
    }

    /// One online update: walk the tree, training each router.
    fn update(&mut self, x: SparseVec, y: u32, lr: f32, step: u64) {
        let eta = lr / (1.0 + 1e-4 * step as f32).sqrt();
        let mut node = 0usize;
        for _ in 0..self.depth {
            let m = self.nodes[node].margin(x);
            let n = &mut self.nodes[node];
            n.seen += 1;
            // EMA updates of the balancing statistics.
            let a = 0.01f32;
            n.mean = (1.0 - a) * n.mean + a * m;
            let cm = n.class_mean.entry(y).or_insert(0.0);
            *cm = (1.0 - a) * *cm + a * m;
            // LOMtree-style target: send y toward the side it already
            // leans relative to the node average (purity), ±1 regression.
            let target = if *cm >= n.mean { 1.0f32 } else { -1.0 };
            let err = m - target;
            for (&i, &v) in x.indices.iter().zip(x.values) {
                *n.w.entry(i).or_insert(0.0) -= eta * err * v;
            }
            // Route by the *current* margin.
            node = 2 * node + if m >= 0.0 { 2 } else { 1 };
        }
    }

    /// Leaf index reached by routing `x`.
    fn route(&self, x: SparseVec) -> usize {
        let mut node = 0usize;
        for _ in 0..self.depth {
            let m = self.nodes[node].margin(x);
            node = 2 * node + if m >= 0.0 { 2 } else { 1 };
        }
        node - self.nodes.len()
    }
}

impl Predictor for LomTree {
    fn topk(&self, x: SparseVec, k: usize) -> Vec<(u32, f32)> {
        let mut out = Vec::new();
        self.topk_into(x, k, &mut crate::engine::PredictScratch::new(), &mut out);
        out
    }

    fn topk_into(
        &self,
        x: SparseVec,
        k: usize,
        _scratch: &mut crate::engine::PredictScratch,
        out: &mut Vec<(u32, f32)>,
    ) {
        let hist = &self.leaf_hist[self.route(x)];
        let total: u32 = hist.values().sum();
        out.clear();
        out.extend(hist.iter().map(|(&l, &c)| (l, c as f32 / total.max(1) as f32)));
        out.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        out.truncate(k);
    }

    fn model_bytes(&self) -> usize {
        let router: usize = self.nodes.iter().map(|n| n.w.len() * 8).sum();
        let hist: usize = self.leaf_hist.iter().map(|h| h.len() * 8).sum();
        router + hist
    }

    fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;
    use crate::eval::precision_at_1;

    #[test]
    fn learns_separable_multiclass() {
        let ds = SyntheticSpec::multiclass(3000, 800, 16).noise(0.02).seed(7).generate();
        let (train, test) = crate::data::split::random_split(&ds, 0.2, 1);
        let tree = LomTree::train(&train, 6, 0.3, 11);
        let p1 = precision_at_1(&tree, &test);
        assert!(p1 > 0.4, "LOMtree p@1 = {p1}");
    }

    #[test]
    fn routing_is_deterministic_and_in_range() {
        let ds = SyntheticSpec::multiclass(300, 200, 32).seed(8).generate();
        let tree = LomTree::train(&ds, 2, 0.3, 12);
        for i in 0..20 {
            let a = tree.route(ds.row(i));
            let b = tree.route(ds.row(i));
            assert_eq!(a, b);
            assert!(a < tree.leaf_hist.len());
        }
    }

    #[test]
    fn model_bytes_grows_with_training() {
        let ds = SyntheticSpec::multiclass(500, 400, 16).seed(9).generate();
        let t1 = LomTree::train(&ds, 1, 0.3, 13);
        assert!(t1.model_bytes() > 0);
    }
}
