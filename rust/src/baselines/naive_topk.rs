//! Table 3's naive baseline: "a 1-vs-All classifier for E most frequent
//! labels in each dataset ... L2-regularized Logistic Regression with
//! tuned regularization constant", plus the *oracle* upper bound (best
//! achievable by any predictor restricted to those E labels).

use super::logistic::BinaryLogistic;
use crate::data::Dataset;
use crate::eval::Predictor;
use crate::sparse::SparseVec;

/// The `E` most frequent labels of a dataset, most frequent first.
pub fn top_e_labels(ds: &Dataset, e: usize) -> Vec<u32> {
    let freqs = ds.label_frequencies();
    let mut order: Vec<u32> = (0..ds.n_labels as u32).collect();
    order.sort_by_key(|&l| std::cmp::Reverse(freqs[l as usize]));
    order.truncate(e);
    order
}

/// OVA logistic regression restricted to the top-E labels.
pub struct NaiveTopK {
    pub labels: Vec<u32>,
    models: Vec<BinaryLogistic>,
}

impl NaiveTopK {
    /// Train with `epochs` SGD passes; `l2` candidates are tuned on a
    /// held-out fifth of the training data (paper: "tuned regularization
    /// constant").
    pub fn train(ds: &Dataset, e: usize, epochs: usize, l2_candidates: &[f32]) -> Self {
        let labels = top_e_labels(ds, e);
        let (tr_rows, va_rows) = crate::data::split::fold_rows(ds.n_examples(), 5, 0);
        let mut best: Option<(f64, Vec<BinaryLogistic>)> = None;
        for &l2 in l2_candidates {
            let models = Self::fit(ds, &labels, &tr_rows, epochs, l2);
            let acc = Self::validate(ds, &labels, &models, &va_rows);
            if best.as_ref().map(|(b, _)| acc > *b).unwrap_or(true) {
                best = Some((acc, models));
            }
        }
        // Refit on everything with the winning λ is skipped (the paper's
        // baseline is intentionally naive); keep the tuned models.
        NaiveTopK { labels, models: best.unwrap().1 }
    }

    fn fit(
        ds: &Dataset,
        labels: &[u32],
        rows: &[usize],
        epochs: usize,
        l2: f32,
    ) -> Vec<BinaryLogistic> {
        let mut models: Vec<BinaryLogistic> =
            labels.iter().map(|_| BinaryLogistic::new(ds.n_features, l2, 0.5)).collect();
        let mut t = 0u64;
        for _ in 0..epochs {
            for &r in rows {
                t += 1;
                let x = ds.row(r);
                let ls = ds.labels_of(r);
                for (mi, &l) in labels.iter().enumerate() {
                    models[mi].step(x, ls.contains(&l), t);
                }
            }
        }
        models
    }

    fn validate(
        ds: &Dataset,
        labels: &[u32],
        models: &[BinaryLogistic],
        rows: &[usize],
    ) -> f64 {
        if rows.is_empty() {
            return 0.0;
        }
        let mut hits = 0usize;
        for &r in rows {
            let x = ds.row(r);
            let best = models
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.margin(x).partial_cmp(&b.1.margin(x)).unwrap())
                .map(|(i, _)| labels[i])
                .unwrap();
            if ds.labels_of(r).contains(&best) {
                hits += 1;
            }
        }
        hits as f64 / rows.len() as f64
    }
}

impl Predictor for NaiveTopK {
    fn topk(&self, x: SparseVec, k: usize) -> Vec<(u32, f32)> {
        let mut out = Vec::new();
        self.topk_into(x, k, &mut crate::engine::PredictScratch::new(), &mut out);
        out
    }
    fn topk_into(
        &self,
        x: SparseVec,
        k: usize,
        _scratch: &mut crate::engine::PredictScratch,
        out: &mut Vec<(u32, f32)>,
    ) {
        out.clear();
        out.extend(self.labels.iter().zip(&self.models).map(|(&l, m)| (l, m.margin(x))));
        out.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        out.truncate(k);
    }
    fn model_bytes(&self) -> usize {
        self.models.iter().map(|m| m.bytes()).sum()
    }
    fn name(&self) -> &str {
        "top-#edges LR"
    }
}

/// The Table 3 "oracle": for each example, counts a hit if *any* true
/// label is inside the top-E frequent set — the ceiling for any predictor
/// restricted to those labels. Not a real predictor (it peeks at the
/// labels), so it is exposed as a direct scoring function.
pub struct OracleTopK {
    pub labels: Vec<u32>,
}

impl OracleTopK {
    pub fn from_train(ds: &Dataset, e: usize) -> Self {
        OracleTopK { labels: top_e_labels(ds, e) }
    }

    /// Upper-bound precision@1 on a test set.
    pub fn precision_at_1(&self, test: &Dataset) -> f64 {
        if test.n_examples() == 0 {
            return 0.0;
        }
        let inset: std::collections::HashSet<u32> = self.labels.iter().copied().collect();
        let hits = (0..test.n_examples())
            .filter(|&i| test.labels_of(i).iter().any(|l| inset.contains(l)))
            .count();
        hits as f64 / test.n_examples() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;
    use crate::eval::precision_at_1;

    #[test]
    fn top_e_labels_are_most_frequent() {
        let ds = SyntheticSpec::multiclass(1000, 500, 30).skew(1.2).seed(1).generate();
        let freqs = ds.label_frequencies();
        let top = top_e_labels(&ds, 5);
        assert_eq!(top.len(), 5);
        let min_top = top.iter().map(|&l| freqs[l as usize]).min().unwrap();
        let max_rest = (0..30u32)
            .filter(|l| !top.contains(l))
            .map(|l| freqs[l as usize])
            .max()
            .unwrap();
        assert!(min_top >= max_rest);
    }

    #[test]
    fn oracle_bounds_naive_lr() {
        let ds = SyntheticSpec::multiclass(2000, 800, 40).skew(1.0).noise(0.02).seed(2).generate();
        let (train, test) = crate::data::split::random_split(&ds, 0.25, 3);
        let e = 12;
        let naive = NaiveTopK::train(&train, e, 3, &[1e-5, 1e-3]);
        let oracle = OracleTopK::from_train(&train, e);
        let p_naive = precision_at_1(&naive, &test);
        let p_oracle = oracle.precision_at_1(&test);
        assert!(p_naive <= p_oracle + 1e-9, "naive {p_naive} > oracle {p_oracle}");
        assert!(p_oracle < 1.0, "restricting to 12/40 labels must lose something");
        assert!(p_naive > 0.08, "LR should beat chance: {p_naive}");
    }

    #[test]
    fn oracle_is_coverage() {
        let ds = SyntheticSpec::multiclass(500, 300, 10).seed(4).generate();
        let oracle = OracleTopK { labels: (0..10).collect() };
        assert!((oracle.precision_at_1(&ds) - 1.0).abs() < 1e-12);
        let none = OracleTopK { labels: vec![] };
        assert_eq!(none.precision_at_1(&ds), 0.0);
    }
}
