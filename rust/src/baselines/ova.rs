//! Full One-Vs-All linear classifier — the `O(C·D)` reference point the
//! paper positions LTLS against (§1). Trained with the multiclass
//! perceptron-style hinge (positive vs best-violating negative), which is
//! the OVA analogue of the separation ranking loss.

use crate::data::Dataset;
use crate::eval::Predictor;
use crate::sparse::SparseVec;
use crate::util::rng::Rng;

/// Dense `C × D` OVA model. Only feasible for the smaller analogs.
pub struct Ova {
    pub c: usize,
    pub d: usize,
    w: Vec<f32>,
}

impl Ova {
    pub fn train(ds: &Dataset, epochs: usize, lr: f32, seed: u64) -> Self {
        let (c, d) = (ds.n_labels, ds.n_features);
        let mut w = vec![0.0f32; c * d];
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..ds.n_examples()).collect();
        let mut t = 0u64;
        for _ in 0..epochs {
            rng.shuffle(&mut order);
            for &r in &order {
                t += 1;
                let x = ds.row(r);
                let ls = ds.labels_of(r);
                if ls.is_empty() {
                    continue;
                }
                let eta = lr / (1.0 + 1e-4 * t as f32).powf(0.75);
                // Scores of all classes: O(C·nnz).
                let (mut best_neg, mut best_neg_s) = (usize::MAX, f32::NEG_INFINITY);
                let (mut worst_pos, mut worst_pos_s) = (usize::MAX, f32::INFINITY);
                for l in 0..c {
                    let s = x.dot_dense(&w[l * d..(l + 1) * d]);
                    if ls.contains(&(l as u32)) {
                        if s < worst_pos_s {
                            worst_pos = l;
                            worst_pos_s = s;
                        }
                    } else if s > best_neg_s {
                        best_neg = l;
                        best_neg_s = s;
                    }
                }
                if worst_pos != usize::MAX
                    && best_neg != usize::MAX
                    && 1.0 + best_neg_s - worst_pos_s > 0.0
                {
                    x.axpy_into(eta, &mut w[worst_pos * d..(worst_pos + 1) * d]);
                    x.axpy_into(-eta, &mut w[best_neg * d..(best_neg + 1) * d]);
                }
            }
        }
        Ova { c, d, w }
    }
}

impl Predictor for Ova {
    fn topk(&self, x: SparseVec, k: usize) -> Vec<(u32, f32)> {
        let mut best = Vec::with_capacity(k + 1);
        self.topk_into(x, k, &mut crate::engine::PredictScratch::new(), &mut best);
        best
    }
    fn topk_into(
        &self,
        x: SparseVec,
        k: usize,
        _scratch: &mut crate::engine::PredictScratch,
        out: &mut Vec<(u32, f32)>,
    ) {
        out.clear();
        for l in 0..self.c {
            let s = x.dot_dense(&self.w[l * self.d..(l + 1) * self.d]);
            if out.len() < k || s > out.last().unwrap().1 {
                out.push((l as u32, s));
                out.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
                out.truncate(k);
            }
        }
    }
    fn model_bytes(&self) -> usize {
        self.w.len() * 4
    }
    fn name(&self) -> &str {
        "OVA"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;
    use crate::eval::precision_at_1;

    #[test]
    fn ova_learns_separable_data() {
        let ds = SyntheticSpec::multiclass(1500, 600, 24).noise(0.02).seed(5).generate();
        let (train, test) = crate::data::split::random_split(&ds, 0.2, 1);
        let ova = Ova::train(&train, 4, 0.5, 7);
        let p1 = precision_at_1(&ova, &test);
        assert!(p1 > 0.85, "OVA p@1 = {p1}");
    }

    #[test]
    fn model_size_is_c_times_d() {
        let ds = SyntheticSpec::multiclass(200, 100, 10).seed(6).generate();
        let ova = Ova::train(&ds, 1, 0.5, 8);
        assert_eq!(ova.model_bytes(), 10 * 100 * 4);
    }
}
