//! FastXML-style tree ensemble (Prabhu & Varma, KDD 2014), simplified.
//!
//! Each tree recursively splits its training rows with a data-aware random
//! hyperplane (the normalized difference of two example centroids seeded
//! from random rows — a cheap surrogate for FastXML's nDCG-optimized
//! split) until a node holds few examples, then stores the node's top
//! label distribution. Prediction averages the leaf distributions of all
//! trees. Model size is the stored hyperplanes + leaf distributions,
//! which reproduces the paper's "FastXML models are large" column.

use crate::data::Dataset;
use crate::eval::Predictor;
use crate::sparse::SparseVec;
use crate::util::rng::Rng;
use std::collections::HashMap;

/// A split hyperplane stored sparsely.
struct Split {
    idx: Vec<u32>,
    val: Vec<f32>,
    bias: f32,
}

impl Split {
    fn side(&self, x: SparseVec) -> bool {
        // Sparse-sparse dot.
        let (mut i, mut j, mut acc) = (0usize, 0usize, self.bias);
        while i < x.indices.len() && j < self.idx.len() {
            match x.indices[i].cmp(&self.idx[j]) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => j += 1,
                std::cmp::Ordering::Equal => {
                    acc += x.values[i] * self.val[j];
                    i += 1;
                    j += 1;
                }
            }
        }
        acc >= 0.0
    }
}

enum TreeNode {
    Internal { split: Split, left: usize, right: usize },
    Leaf { dist: Vec<(u32, f32)> },
}

struct Tree {
    nodes: Vec<TreeNode>,
}

/// Hyperparameters.
#[derive(Clone, Debug)]
pub struct FastXmlConfig {
    pub n_trees: usize,
    pub max_leaf: usize,
    pub max_depth: u32,
    /// Labels kept per leaf distribution.
    pub leaf_topk: usize,
    pub seed: u64,
}

impl Default for FastXmlConfig {
    fn default() -> Self {
        FastXmlConfig { n_trees: 8, max_leaf: 10, max_depth: 24, leaf_topk: 10, seed: 7 }
    }
}

/// The trained ensemble.
pub struct FastXml {
    trees: Vec<Tree>,
    name: String,
}

impl FastXml {
    pub fn train(ds: &Dataset, cfg: &FastXmlConfig) -> Self {
        let mut trees = Vec::with_capacity(cfg.n_trees);
        for t in 0..cfg.n_trees {
            let mut rng = Rng::new(cfg.seed ^ (t as u64).wrapping_mul(0x9E37_79B9));
            // Bagging: sample rows with replacement.
            let n = ds.n_examples();
            let rows: Vec<usize> = (0..n).map(|_| rng.index(n)).collect();
            let mut tree = Tree { nodes: Vec::new() };
            build(&mut tree, ds, rows, 0, cfg, &mut rng);
            trees.push(tree);
        }
        FastXml { trees, name: "FastXML".into() }
    }
}

/// Recursively build; returns node index.
fn build(
    tree: &mut Tree,
    ds: &Dataset,
    rows: Vec<usize>,
    depth: u32,
    cfg: &FastXmlConfig,
    rng: &mut Rng,
) -> usize {
    if rows.len() <= cfg.max_leaf || depth >= cfg.max_depth {
        return make_leaf(tree, ds, &rows, cfg);
    }
    // Data-aware random hyperplane: difference of two random rows.
    let split = make_split(ds, &rows, rng);
    let (mut left_rows, mut right_rows) = (Vec::new(), Vec::new());
    for &r in &rows {
        if split.side(ds.row(r)) {
            right_rows.push(r);
        } else {
            left_rows.push(r);
        }
    }
    if left_rows.is_empty() || right_rows.is_empty() {
        return make_leaf(tree, ds, &rows, cfg);
    }
    let id = tree.nodes.len();
    tree.nodes.push(TreeNode::Leaf { dist: Vec::new() }); // placeholder
    let left = build(tree, ds, left_rows, depth + 1, cfg, rng);
    let right = build(tree, ds, right_rows, depth + 1, cfg, rng);
    tree.nodes[id] = TreeNode::Internal { split, left, right };
    id
}

fn make_split(ds: &Dataset, rows: &[usize], rng: &mut Rng) -> Split {
    let a = ds.row(rows[rng.index(rows.len())]);
    let b = ds.row(rows[rng.index(rows.len())]);
    // w = a − b, sparse merge.
    let mut map: HashMap<u32, f32> = HashMap::new();
    for (&i, &v) in a.indices.iter().zip(a.values) {
        *map.entry(i).or_insert(0.0) += v;
    }
    for (&i, &v) in b.indices.iter().zip(b.values) {
        *map.entry(i).or_insert(0.0) -= v;
    }
    let mut pairs: Vec<(u32, f32)> = map.into_iter().filter(|(_, v)| *v != 0.0).collect();
    if pairs.is_empty() {
        // Degenerate identical rows: random axis.
        pairs.push((rng.below(ds.n_features as u64) as u32, 1.0));
    }
    pairs.sort_by_key(|p| p.0);
    let norm = pairs.iter().map(|(_, v)| v * v).sum::<f32>().sqrt().max(1e-12);
    Split {
        idx: pairs.iter().map(|p| p.0).collect(),
        val: pairs.iter().map(|p| p.1 / norm).collect(),
        bias: (rng.f32() - 0.5) * 0.1,
    }
}

fn make_leaf(tree: &mut Tree, ds: &Dataset, rows: &[usize], cfg: &FastXmlConfig) -> usize {
    let mut hist: HashMap<u32, u32> = HashMap::new();
    for &r in rows {
        for &l in ds.labels_of(r) {
            *hist.entry(l).or_insert(0) += 1;
        }
    }
    let total: u32 = hist.values().sum();
    let mut dist: Vec<(u32, f32)> =
        hist.into_iter().map(|(l, c)| (l, c as f32 / total.max(1) as f32)).collect();
    dist.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    dist.truncate(cfg.leaf_topk);
    let id = tree.nodes.len();
    tree.nodes.push(TreeNode::Leaf { dist });
    id
}

impl Tree {
    fn leaf_dist(&self, x: SparseVec) -> &[(u32, f32)] {
        let mut id = 0usize;
        loop {
            match &self.nodes[id] {
                TreeNode::Internal { split, left, right } => {
                    id = if split.side(x) { *right } else { *left };
                }
                TreeNode::Leaf { dist } => return dist,
            }
        }
    }
}

impl Predictor for FastXml {
    fn topk(&self, x: SparseVec, k: usize) -> Vec<(u32, f32)> {
        let mut out = Vec::new();
        self.topk_into(x, k, &mut crate::engine::PredictScratch::new(), &mut out);
        out
    }

    fn topk_into(
        &self,
        x: SparseVec,
        k: usize,
        _scratch: &mut crate::engine::PredictScratch,
        out: &mut Vec<(u32, f32)>,
    ) {
        let mut agg: HashMap<u32, f32> = HashMap::new();
        for t in &self.trees {
            for &(l, p) in t.leaf_dist(x) {
                *agg.entry(l).or_insert(0.0) += p;
            }
        }
        out.clear();
        out.extend(agg.into_iter().map(|(l, p)| (l, p / self.trees.len() as f32)));
        out.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        out.truncate(k);
    }

    fn model_bytes(&self) -> usize {
        self.trees
            .iter()
            .map(|t| {
                t.nodes
                    .iter()
                    .map(|n| match n {
                        TreeNode::Internal { split, .. } => split.idx.len() * 8 + 12,
                        TreeNode::Leaf { dist } => dist.len() * 8,
                    })
                    .sum::<usize>()
            })
            .sum()
    }

    fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;
    use crate::eval::precision_at_1;

    #[test]
    fn learns_multiclass() {
        let ds = SyntheticSpec::multiclass(2000, 600, 20).noise(0.02).seed(10).generate();
        let (train, test) = crate::data::split::random_split(&ds, 0.2, 2);
        let fx = FastXml::train(&train, &FastXmlConfig::default());
        let p1 = precision_at_1(&fx, &test);
        assert!(p1 > 0.5, "FastXML p@1 = {p1}");
    }

    #[test]
    fn learns_multilabel() {
        let ds = SyntheticSpec::multilabel(1500, 500, 30, 2).seed(11).generate();
        let (train, test) = crate::data::split::random_split(&ds, 0.2, 3);
        let fx = FastXml::train(&train, &FastXmlConfig { n_trees: 5, ..Default::default() });
        let p1 = precision_at_1(&fx, &test);
        assert!(p1 > 0.3, "FastXML multilabel p@1 = {p1}");
    }

    #[test]
    fn ensemble_size_grows_model() {
        let ds = SyntheticSpec::multiclass(300, 200, 10).seed(12).generate();
        let small = FastXml::train(&ds, &FastXmlConfig { n_trees: 2, ..Default::default() });
        let large = FastXml::train(&ds, &FastXmlConfig { n_trees: 8, ..Default::default() });
        assert!(large.model_bytes() > small.model_bytes());
    }

    #[test]
    fn topk_is_sorted_probabilities() {
        let ds = SyntheticSpec::multiclass(400, 300, 12).seed(13).generate();
        let fx = FastXml::train(&ds, &FastXmlConfig { n_trees: 3, ..Default::default() });
        let top = fx.topk(ds.row(0), 5);
        assert!(!top.is_empty());
        for w in top.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
        for (_, p) in &top {
            assert!((0.0..=1.0 + 1e-6).contains(p));
        }
    }
}
