//! Every method the paper compares against (§6, Tables 1–3), implemented
//! from scratch on the same [`crate::data::Dataset`] substrate and exposed
//! through the same [`crate::eval::Predictor`] trait so the table
//! harnesses are generic:
//!
//! * [`logistic`] — binary L2-regularized logistic regression by SGD (the
//!   building block of the naive baseline).
//! * [`naive_topk`] — Table 3: one-vs-all LR over the `E` most frequent
//!   labels, plus the frequency "oracle" upper bound.
//! * [`ova`] — full One-Vs-All linear (the reference point of §1; `O(C·D)`
//!   space, `O(C)` predict).
//! * [`lomtree`] — LOMtree-style online logarithmic-time multiclass tree
//!   (Choromanska & Langford, 2015), simplified: online balanced router
//!   training, `O(C)` nodes.
//! * [`fastxml`] — FastXML-style ensemble of balanced random-hyperplane
//!   trees with label-distribution leaves (Prabhu & Varma, 2014).
//! * [`leml`] — LEML-style low-rank embedding (Yu et al., 2014):
//!   rank-r label embedding + ridge regression, `O(C·r)` decode.

pub mod fastxml;
pub mod leml;
pub mod logistic;
pub mod lomtree;
pub mod naive_topk;
pub mod ova;
pub mod plt;

pub use fastxml::FastXml;
pub use leml::Leml;
pub use lomtree::LomTree;
pub use naive_topk::{NaiveTopK, OracleTopK};
pub use ova::Ova;
pub use plt::Plt;
