//! Binary L2-regularized logistic regression trained by SGD — the unit the
//! Table 3 naive baseline ("L2-regularized Logistic Regression with tuned
//! regularization constant") builds on.

use crate::sparse::SparseVec;

/// A binary logistic model over sparse inputs.
#[derive(Clone, Debug)]
pub struct BinaryLogistic {
    pub w: Vec<f32>,
    pub bias: f32,
    pub l2: f32,
    pub lr: f32,
}

impl BinaryLogistic {
    pub fn new(d: usize, l2: f32, lr: f32) -> Self {
        BinaryLogistic { w: vec![0.0; d], bias: 0.0, l2, lr }
    }

    /// Raw margin `w·x + b`.
    #[inline]
    pub fn margin(&self, x: SparseVec) -> f32 {
        x.dot_dense(&self.w) + self.bias
    }

    /// Probability `σ(w·x + b)`.
    pub fn prob(&self, x: SparseVec) -> f32 {
        sigmoid(self.margin(x))
    }

    /// One SGD step on (x, y ∈ {0,1}) at step `t`; returns the log-loss.
    pub fn step(&mut self, x: SparseVec, y: bool, t: u64) -> f32 {
        let lr = self.lr / (1.0 + 1e-4 * t as f32).sqrt();
        let p = self.prob(x);
        let err = p - if y { 1.0 } else { 0.0 };
        // Lazy-ish L2: shrink only touched coordinates (standard sparse
        // approximation; exact for the tuned range of l2 used here).
        for (&i, &v) in x.indices.iter().zip(x.values) {
            let wi = &mut self.w[i as usize];
            *wi -= lr * (err * v + self.l2 * *wi);
        }
        self.bias -= lr * err;
        let eps = 1e-7f32;
        if y {
            -(p.max(eps)).ln()
        } else {
            -((1.0 - p).max(eps)).ln()
        }
    }

    pub fn bytes(&self) -> usize {
        (self.w.len() + 1) * 4
    }
}

/// Numerically-stable sigmoid.
#[inline]
pub fn sigmoid(z: f32) -> f32 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn sigmoid_properties() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-6);
        assert!(sigmoid(50.0) > 0.999);
        assert!(sigmoid(-50.0) < 0.001);
        assert!((sigmoid(2.0) + sigmoid(-2.0) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn learns_linearly_separable() {
        let mut rng = Rng::new(91);
        let mut m = BinaryLogistic::new(10, 1e-5, 0.5);
        // y = 1 iff feature 3 present.
        let mut t = 0;
        for _ in 0..2000 {
            t += 1;
            let y = rng.coin(0.5);
            let (idx, val): (Vec<u32>, Vec<f32>) = if y {
                (vec![3, 7], vec![1.0, rng.f32()])
            } else {
                (vec![1, 7], vec![1.0, rng.f32()])
            };
            m.step(SparseVec::new(&idx, &val), y, t);
        }
        assert!(m.prob(SparseVec::new(&[3], &[1.0])) > 0.8);
        assert!(m.prob(SparseVec::new(&[1], &[1.0])) < 0.2);
    }

    #[test]
    fn l2_shrinks_weights() {
        let mut strong = BinaryLogistic::new(4, 0.5, 0.3);
        let mut weak = BinaryLogistic::new(4, 0.0, 0.3);
        let idx = [0u32];
        let val = [1.0f32];
        for t in 1..500 {
            strong.step(SparseVec::new(&idx, &val), true, t);
            weak.step(SparseVec::new(&idx, &val), true, t);
        }
        assert!(strong.w[0].abs() < weak.w[0].abs());
    }
}
