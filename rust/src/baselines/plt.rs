//! PLT — Probabilistic Label Tree baseline (Jasinska et al., ICML 2016 —
//! the paper's reference [5], called out in §1 as having `O(log C)`
//! *training* but not `O(log C)` prediction).
//!
//! A complete binary tree over labels; every node `v` holds a binary
//! probabilistic classifier estimating `P(v | parent(v), x)`. Training is
//! logarithmic (an example updates the nodes on its labels' root→leaf
//! paths plus their siblings); prediction does beam search / threshold
//! expansion down the tree, which in the worst case is **not**
//! logarithmic — exactly the complexity contrast the paper draws with
//! LTLS, which this implementation lets the benches demonstrate.

use super::logistic::sigmoid;
use crate::data::Dataset;
use crate::eval::Predictor;
use crate::sparse::SparseVec;
use crate::util::rng::Rng;
use std::collections::HashMap;

/// Probabilistic label tree with sparse node weights.
pub struct Plt {
    /// Leaf offset: leaves occupy ids `n_internal ..` in heap order.
    n_internal: usize,
    depth: u32,
    /// Sparse weights per node.
    w: Vec<HashMap<u32, f32>>,
    bias: Vec<f32>,
    /// Heap leaf index → dataset label (identity here; labels ≤ leaves).
    n_labels: usize,
    /// Beam width at prediction time.
    pub beam: usize,
    name: String,
}

impl Plt {
    /// Train with `epochs` online passes.
    pub fn train(ds: &Dataset, epochs: usize, lr: f32, seed: u64) -> Self {
        let depth = crate::util::ceil_log2(ds.n_labels.max(2) as u64);
        let n_internal = (1usize << depth) - 1;
        let n_nodes = n_internal + (1usize << depth);
        let mut plt = Plt {
            n_internal,
            depth,
            w: (0..n_nodes).map(|_| HashMap::new()).collect(),
            bias: vec![0.0; n_nodes],
            n_labels: ds.n_labels,
            beam: 16,
            name: "PLT".into(),
        };
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..ds.n_examples()).collect();
        let mut t = 0u64;
        for _ in 0..epochs {
            rng.shuffle(&mut order);
            for &r in &order {
                t += 1;
                plt.update(ds.row(r), ds.labels_of(r), lr, t);
            }
        }
        plt
    }

    fn leaf_of(&self, label: u32) -> usize {
        self.n_internal + label as usize
    }

    fn margin(&self, node: usize, x: SparseVec) -> f32 {
        let mut acc = self.bias[node];
        for (&i, &v) in x.indices.iter().zip(x.values) {
            if let Some(w) = self.w[node].get(&i) {
                acc += w * v;
            }
        }
        acc
    }

    fn sgd(&mut self, node: usize, x: SparseVec, y: bool, lr: f32, t: u64) {
        let eta = lr / (1.0 + 1e-4 * t as f32).sqrt();
        let p = sigmoid(self.margin(node, x));
        let err = p - if y { 1.0 } else { 0.0 };
        for (&i, &v) in x.indices.iter().zip(x.values) {
            *self.w[node].entry(i).or_insert(0.0) -= eta * err * v;
        }
        self.bias[node] -= eta * err;
    }

    /// PLT update rule: positive nodes = union of root→leaf paths of the
    /// true labels; negative nodes = siblings of positive nodes that are
    /// not positive themselves.
    fn update(&mut self, x: SparseVec, labels: &[u32], lr: f32, t: u64) {
        if labels.is_empty() {
            return;
        }
        let mut positive = std::collections::HashSet::new();
        for &l in labels {
            let mut v = self.leaf_of(l);
            loop {
                positive.insert(v);
                if v == 0 {
                    break;
                }
                v = (v - 1) / 2;
            }
        }
        let mut negatives = Vec::new();
        for &v in &positive {
            if v == 0 {
                continue;
            }
            let sib = if v % 2 == 1 { v + 1 } else { v - 1 };
            if !positive.contains(&sib) {
                negatives.push(sib);
            }
        }
        for &v in &positive {
            self.sgd(v, x, true, lr, t);
        }
        for v in negatives {
            self.sgd(v, x, false, lr, t);
        }
    }
}

impl Predictor for Plt {
    /// Beam search down the tree by path probability.
    fn topk(&self, x: SparseVec, k: usize) -> Vec<(u32, f32)> {
        let mut out = Vec::new();
        self.topk_into(x, k, &mut crate::engine::PredictScratch::new(), &mut out);
        out
    }

    fn topk_into(
        &self,
        x: SparseVec,
        k: usize,
        _scratch: &mut crate::engine::PredictScratch,
        out: &mut Vec<(u32, f32)>,
    ) {
        // (log-prob, node)
        let mut frontier: Vec<(f32, usize)> = vec![(0.0, 0)];
        for _ in 0..self.depth {
            let mut next: Vec<(f32, usize)> = Vec::with_capacity(frontier.len() * 2);
            for &(lp, v) in &frontier {
                let (l, r) = (2 * v + 1, 2 * v + 2);
                let pl = sigmoid(self.margin(l, x)).clamp(1e-6, 1.0 - 1e-6);
                let pr = sigmoid(self.margin(r, x)).clamp(1e-6, 1.0 - 1e-6);
                next.push((lp + pl.ln(), l));
                next.push((lp + pr.ln(), r));
            }
            next.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
            next.truncate(self.beam.max(k));
            frontier = next;
        }
        out.clear();
        out.extend(
            frontier
                .into_iter()
                .filter_map(|(lp, v)| {
                    let label = (v - self.n_internal) as u32;
                    ((label as usize) < self.n_labels).then_some((label, lp.exp()))
                })
                .take(k),
        );
    }

    fn model_bytes(&self) -> usize {
        self.w.iter().map(|m| m.len() * 8).sum::<usize>() + self.bias.len() * 4
    }

    fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;
    use crate::eval::precision_at_1;

    #[test]
    fn learns_multiclass() {
        let ds = SyntheticSpec::multiclass(2500, 700, 24).noise(0.02).seed(71).generate();
        let (train, test) = crate::data::split::random_split(&ds, 0.2, 1);
        let plt = Plt::train(&train, 5, 0.5, 3);
        let p1 = precision_at_1(&plt, &test);
        assert!(p1 > 0.6, "PLT p@1 = {p1}");
    }

    #[test]
    fn learns_multilabel() {
        let ds = SyntheticSpec::multilabel(2000, 600, 32, 2).seed(72).generate();
        let (train, test) = crate::data::split::random_split(&ds, 0.2, 2);
        let plt = Plt::train(&train, 5, 0.5, 4);
        let p1 = precision_at_1(&plt, &test);
        assert!(p1 > 0.35, "PLT multilabel p@1 = {p1}");
    }

    #[test]
    fn topk_probabilities_descend_and_are_valid() {
        let ds = SyntheticSpec::multiclass(500, 300, 16).seed(73).generate();
        let plt = Plt::train(&ds, 2, 0.5, 5);
        let top = plt.topk(ds.row(0), 5);
        assert!(!top.is_empty());
        for w in top.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
        for (l, p) in &top {
            assert!((*l as usize) < 16);
            assert!((0.0..=1.0).contains(p));
        }
    }

    #[test]
    fn training_touches_log_many_nodes() {
        // One example with one label updates ≤ 2·(depth+1) node models.
        let ds = SyntheticSpec::multiclass(1, 50, 64).seed(74).generate();
        let plt = Plt::train(&ds, 1, 0.5, 6);
        let touched = plt.w.iter().filter(|m| !m.is_empty()).count();
        assert!(touched <= 2 * (plt.depth as usize + 1), "touched {touched}");
    }
}
