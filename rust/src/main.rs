//! `ltls` — the LTLS command-line launcher.
//!
//! Subcommands:
//!
//! * `trellis --c N [--dot]` — print the trellis structure (paper Fig. 1).
//! * `graph [--c N] [--width W] [--dot] [--trace P,N]` — print any-width
//!   trellis structure (`to_ascii`), optionally the Graphviz DOT and a
//!   Figure-2-style update trace for a (positive, negative) label pair.
//! * `gen-data --dataset <analog> [--scale S] [--out F]` — emit a synthetic
//!   analog in libsvm format.
//! * `train --dataset <analog|path.svm> [--epochs N] [--lr η] [--policy
//!   top|random] [--l1 λ] [--width W] [--hash-bits B] [--threads N]
//!   [--batch B] [--multilabel [--plt-weight]] [--checkpoint-dir D]
//!   [--resume]` — train linear LTLS (serially, or Hogwild-parallel with
//!   `--threads`; `--batch` scores B examples per strip sweep; `--width`
//!   trains the W-LTLS wide trellis; `--hash-bits` trains the
//!   feature-hashed weight store, bounding model memory at `2^B·E` floats
//!   independently of D; `--multilabel` switches the objective to the
//!   union-of-gold-paths margin loss over each example's full label set,
//!   with `--plt-weight` adding PLT-style conditional weighting), report
//!   precision@1, prediction time, model size and the top-k metric suite
//!   (P@k, nDCG@k, recall@k, propensity-scored P@k). With
//!   `--checkpoint-dir` a checkpoint is written after every epoch and
//!   `--resume` continues from the latest one (same width / hash-bits /
//!   seed / objective).
//! * `quantize --model in.ltls --out out.ltls` — convert a trained dense
//!   model file to the serve-only q8 backend (per-edge i8 weights, ~4×
//!   smaller; format v3 carries the backend tag).
//! * `tables --which 1|2|3 [--scale S] [--epochs N]` — regenerate the
//!   paper's tables on the synthetic analogs.
//! * `deep [--epochs N] [--steps N]` — the §6 deep-network ImageNet
//!   experiment through the AOT PJRT runtime.
//! * `serve [--model m.ltls [--mmap]] [--requests N] [--batch B]
//!   [--workers W] [--width N]` — run the batching multi-worker prediction
//!   server and print latency/throughput metrics incl. per-worker. With
//!   `--model` it serves a saved model of any width/backend (dense,
//!   hashed, q8); `--mmap` memory-maps the weight block zero-copy instead
//!   of materializing it. Without `--model` it trains a fresh model on
//!   `--dataset` first (the original smoke path).
//! * `serve --listen HOST:PORT [--model m.ltls [--mmap]] [--watch-model F]
//!   [--watch-poll-ms MS] [--transport threads|event-loop]
//!   [--poll-threads N] [--conn-buf-bytes N] [--write-stall-ms MS]
//!   [--max-inflight N] [--max-inflight-per-conn N] [--queue-depth N]
//!   [--batch B] [--workers W] [--max-wait-us U] [--trace-sample N]
//!   [--trace-slow-ms MS]` (knob table: `docs/OBSERVABILITY.md`) —
//!   the **network** frontend: newline-delimited requests
//!   (`<k> <i:v> <i:v> ...`) answered with JSON lines, plus the
//!   `PING` / `METRICS` / `TRACE` / `RELOAD [path]` / `SHUTDOWN` control
//!   commands (the wire contract is `docs/PROTOCOL.md`). `METRICS` is a
//!   conformant Prometheus scrape (full cumulative histograms); `TRACE`
//!   dumps per-request stage timelines — every `--trace-sample`-th
//!   request plus any slower than `--trace-slow-ms` — as JSON lines
//!   (0 disables either; see `docs/OBSERVABILITY.md`). Connections are
//!   multiplexed by a poll(2) event loop over a fixed pool of
//!   `--poll-threads` threads by default — thousands of concurrent
//!   clients on a handful of threads; `--transport threads` selects the
//!   two-threads-per-connection oracle instead. With `--model` the model
//!   is hot-reloadable (atomic swap between micro-batches, zero dropped
//!   requests); `--watch-model F` polls `F` and swaps it in whenever the
//!   file changes and validates. Admission is bounded globally
//!   (`--max-inflight`) and per connection (`--max-inflight-per-conn`,
//!   so one greedy client cannot pin the whole budget): overload returns
//!   a backpressure error instead of queueing unboundedly. Runs until a
//!   client sends `SHUTDOWN`, then drains gracefully.
//! * `shard --model m.ltls --shards N [--out-prefix P]` — slice a trained
//!   model into `N` label-space shard files (format v4, any backend,
//!   mmap-servable) for the scatter tier: each slice keeps every body
//!   edge plus its own share of terminal edges, so a shard answers the
//!   exact global top-k restricted to its labels.
//! * `coordinator --listen HOST:PORT --shards "h:p,h:p;h:p,h:p"
//!   [--shard-timeout-ms MS] [--connect-timeout-ms MS] [--features D]` —
//!   the scatter-gather frontend: speaks the same wire protocol as
//!   `serve --listen`, fans each micro-batch out to every shard
//!   (replicas comma-separated, shards semicolon-separated), k-way-merges
//!   the partial top-k lists back into the global answer, and fails over
//!   between replicas; replies carry `"partial":true` only while every
//!   replica of some shard is down. All `serve --listen` transport /
//!   admission / trace flags apply unchanged.
//! * `scaling [--kmax K]` — prediction-time scaling in C (the log-time
//!   claim).

use ltls::graph::Topology;
use ltls::model::{TrainableStore, WeightStore};
use ltls::util::args::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "trellis" => cmd_trellis(&args),
        "graph" => cmd_graph(&args),
        "gen-data" => cmd_gen_data(&args),
        "train" => cmd_train(&args),
        "quantize" => cmd_quantize(&args),
        "tables" => cmd_tables(&args),
        "deep" => cmd_deep(&args),
        "serve" => cmd_serve(&args),
        "shard" => cmd_shard(&args),
        "coordinator" => cmd_coordinator(&args),
        "eval" => cmd_eval(&args),
        "scaling" => cmd_scaling(&args),
        _ => {
            print!("{}", HELP);
            0
        }
    };
    std::process::exit(code);
}

const HELP: &str = "\
ltls — Log-time and Log-space Extreme Classification (reproduction)

USAGE: ltls <trellis|graph|gen-data|train|quantize|eval|tables|deep|serve|shard|coordinator|scaling> [--flags]
Run with a subcommand; see the crate docs / README for flag details.
";

/// Validated `--width` (default 2): rejects anything below 2 or above the
/// supported maximum with a usage error instead of a panic.
fn parse_width(args: &Args) -> Result<u32, String> {
    let raw = args.get_str("width", "2");
    let w: u64 = raw
        .parse()
        .map_err(|_| format!("--width {raw:?} is not a number"))?;
    if w < 2 {
        return Err(format!("--width must be at least 2, got {w}"));
    }
    if w > ltls::graph::wide::MAX_WIDTH as u64 {
        return Err(format!(
            "--width must be at most {}, got {w}",
            ltls::graph::wide::MAX_WIDTH
        ));
    }
    Ok(w as u32)
}

/// Validated `--hash-bits` (default 0 = dense storage): 0 or the hashed
/// store's supported bucket-exponent range.
fn parse_hash_bits(args: &Args) -> Result<u32, String> {
    let raw = args.get_str("hash-bits", "0");
    let b: u64 = raw
        .parse()
        .map_err(|_| format!("--hash-bits {raw:?} is not a number"))?;
    if b == 0 {
        return Ok(0);
    }
    let (lo, hi) = (
        ltls::model::hashed::MIN_HASH_BITS as u64,
        ltls::model::hashed::MAX_HASH_BITS as u64,
    );
    if !(lo..=hi).contains(&b) {
        return Err(format!("--hash-bits must be 0 (dense) or in {lo}..={hi}, got {b}"));
    }
    Ok(b as u32)
}

/// Warn (stderr) when the width is degenerate for this class count.
fn warn_width_vs_classes(width: u32, c: u64) {
    if (width as u64) >= c {
        eprintln!(
            "warning: --width {width} ≥ C={c}; clamping to a 1-step fan-out \
             (one-vs-all shape, no log-space savings)"
        );
    }
}

fn load_dataset(args: &Args) -> Result<(ltls::data::Dataset, ltls::data::Dataset), String> {
    let name = args.get_str("dataset", "sector");
    let scale = args.get_f32("scale", 0.2) as f64;
    let seed = args.get_u64("seed", 42);
    if name.ends_with(".svm") || name.ends_with(".txt") {
        let ds = ltls::data::libsvm::load(std::path::Path::new(name))?;
        Ok(ltls::data::split::random_split(&ds, 0.2, seed))
    } else {
        let analog = ltls::data::datasets::by_name(name)
            .ok_or(format!("unknown dataset {name:?} (try: synthetic, synthetic-ml, sector, aloi.bin, LSHTC1, imageNet, Dmoz, bibtex, rcv1-regions, Eur-Lex, LSHTCwiki)"))?;
        Ok(analog.generate(scale, seed))
    }
}

fn cmd_trellis(args: &Args) -> i32 {
    let c = args.get_u64("c", 22);
    let t = match ltls::graph::Trellis::try_new(c) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    print!("{}", ltls::graph::dot::to_ascii(&t));
    if args.get_bool("dot") {
        print!("{}", ltls::graph::dot::to_dot(&t, &[]));
    }
    println!(
        "paths={} edges={} (4·⌊log₂C⌋+popcount) upper bound 5⌈log₂C⌉+1 = {}",
        c,
        Topology::num_edges(&t),
        5 * ltls::util::ceil_log2(c) + 1
    );
    0
}

/// `ltls graph [--c N] [--width W] [--dot] [--trace POS,NEG]`: dump the
/// (possibly wide) trellis structure for inspection — the `to_ascii` /
/// `to_dot` / `update_trace` renderers, reachable from the binary.
fn cmd_graph(args: &Args) -> i32 {
    let c = args.get_u64("c", 22);
    let width = match parse_width(args) {
        Ok(w) => w,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    warn_width_vs_classes(width, c);
    if width == 2 {
        match ltls::graph::Trellis::try_new(c) {
            Ok(t) => print_graph(args, &t),
            Err(e) => {
                eprintln!("error: {e}");
                1
            }
        }
    } else {
        match ltls::graph::WideTrellis::new(c, width) {
            Ok(t) => print_graph(args, &t),
            Err(e) => {
                eprintln!("error: {e}");
                1
            }
        }
    }
}

fn print_graph<T: Topology>(args: &Args, t: &T) -> i32 {
    print!("{}", ltls::graph::dot::to_ascii(t));
    if args.get_bool("dot") {
        print!("{}", ltls::graph::dot::to_dot(t, &[]));
    }
    if let Some(pair) = args.get("trace") {
        let labels: Vec<u64> = pair.split(',').filter_map(|s| s.trim().parse().ok()).collect();
        match labels.as_slice() {
            [p, n] if *p < t.c() && *n < t.c() && p != n => {
                print!("{}", ltls::graph::dot::update_trace(t, *p, *n));
            }
            _ => {
                eprintln!("error: --trace wants two distinct labels below C, e.g. --trace 3,17");
                return 1;
            }
        }
    }
    let exits: u32 = t.exit_groups().iter().map(|g| g.digit).sum();
    println!(
        "C={} W={} steps={} edges={} (aux-sink copies={}, exit edges={}); linear model = E·D = {}·D params",
        t.c(),
        t.width(),
        t.steps(),
        t.num_edges(),
        t.n_aux_sinks(),
        exits,
        t.num_edges()
    );
    0
}

fn cmd_gen_data(args: &Args) -> i32 {
    match load_dataset(args) {
        Ok((train, test)) => {
            let out = args.get_str("out", "dataset.svm").to_string();
            std::fs::write(&out, ltls::data::libsvm::dump(&train)).expect("write dataset");
            std::fs::write(format!("{out}.test"), ltls::data::libsvm::dump(&test))
                .expect("write test split");
            println!("{}", ltls::data::stats::stats(&train));
            println!("wrote {out} and {out}.test");
            0
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_train(args: &Args) -> i32 {
    let (train, test) = match load_dataset(args) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    println!("{}", ltls::data::stats::stats(&train));
    if train.n_labels < 2 {
        eprintln!("error: LTLS needs at least 2 classes, dataset has {}", train.n_labels);
        return 1;
    }
    let width = match parse_width(args) {
        Ok(w) => w,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    warn_width_vs_classes(width, train.n_labels as u64);
    let hash_bits = match parse_hash_bits(args) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    if hash_bits > 0 && (1usize << hash_bits) >= train.n_features {
        eprintln!(
            "warning: --hash-bits {hash_bits} gives {} buckets ≥ D={}; no memory saving \
             over the dense store",
            1usize << hash_bits,
            train.n_features
        );
    }
    let policy = match args.get_str("policy", "top") {
        "random" => ltls::assign::AssignPolicy::Random,
        _ => ltls::assign::AssignPolicy::TopRanked,
    };
    let multilabel = args.get_bool("multilabel");
    let plt_weight = args.get_bool("plt-weight");
    if plt_weight && !multilabel {
        eprintln!("error: --plt-weight only applies to the multilabel objective; add --multilabel");
        return 1;
    }
    let objective = if multilabel {
        ltls::train::Objective::Multilabel { plt_weight }
    } else {
        ltls::train::Objective::Multiclass
    };
    let cfg = ltls::train::TrainConfig {
        lr: args.get_f32("lr", 0.5),
        l1_lambda: args.get_f32("l1", 0.0),
        policy,
        seed: args.get_u64("seed", 42),
        log_every: args.get_usize("log-every", 0),
        threads: args.get_usize("threads", 1),
        batch: args.get_usize("batch", 1),
        width,
        hash_bits,
        objective,
        ..Default::default()
    };
    // The stored width picks the topology (2 runs the register-specialized
    // width-2 kernels, anything else the generic wide path) and the
    // hash-bits flag picks the weight store. Training, checkpointing and
    // evaluation below are one generic body over both.
    match (width == 2, hash_bits == 0) {
        (true, true) => {
            run_train::<ltls::graph::Trellis, ltls::model::DenseStore>(args, &train, &test, cfg)
        }
        (true, false) => {
            run_train::<ltls::graph::Trellis, ltls::model::HashedStore>(args, &train, &test, cfg)
        }
        (false, true) => {
            run_train::<ltls::graph::WideTrellis, ltls::model::DenseStore>(args, &train, &test, cfg)
        }
        (false, false) => run_train::<ltls::graph::WideTrellis, ltls::model::HashedStore>(
            args, &train, &test, cfg,
        ),
    }
}

fn run_train<T: Topology, S: TrainableStore>(
    args: &Args,
    train: &ltls::data::Dataset,
    test: &ltls::data::Dataset,
    cfg: ltls::train::TrainConfig,
) -> i32 {
    let epochs = args.get_usize("epochs", 5);
    let l1_lambda = cfg.l1_lambda;
    let ckpt_dir = args.get("checkpoint-dir").map(std::path::PathBuf::from);
    let timer = ltls::util::timer::Timer::new();

    let fresh = |cfg: ltls::train::TrainConfig| {
        ltls::train::ParallelTrainer::<T, S>::with_topology(cfg, train.n_features, train.n_labels)
    };
    // Fresh trainer, or resume from the latest checkpoint in the dir. An
    // empty or not-yet-created directory starts fresh, so rerunning the
    // same command after a crash is always safe.
    let mut tr = if args.get_bool("resume") {
        let Some(dir) = &ckpt_dir else {
            eprintln!("error: --resume requires --checkpoint-dir");
            return 1;
        };
        let latest = if dir.is_dir() {
            ltls::model::io::latest_checkpoint(dir)
        } else {
            Ok(None)
        };
        match latest {
            Ok(Some((epoch, path))) => match ltls::model::io::load_checkpoint::<T, S>(&path)
                .and_then(|ck| ltls::train::ParallelTrainer::<T, S>::resume(cfg.clone(), ck))
            {
                Ok(tr) => {
                    println!(
                        "resuming from {} (epoch {epoch}, step {})",
                        path.display(),
                        tr.global_step()
                    );
                    tr
                }
                Err(e) => {
                    eprintln!("error resuming checkpoint: {e}");
                    return 1;
                }
            },
            Ok(None) => {
                println!("no checkpoint in {}; starting fresh", dir.display());
                match fresh(cfg) {
                    Ok(tr) => tr,
                    Err(e) => {
                        eprintln!("error: {e}");
                        return 1;
                    }
                }
            }
            Err(e) => {
                eprintln!("error scanning {}: {e}", dir.display());
                return 1;
            }
        }
    } else {
        // Fresh run: clear any older run's checkpoints from the dir, so a
        // later --resume can't pick up stale higher-numbered epochs.
        if let Some(dir) = &ckpt_dir {
            if dir.is_dir() {
                match ltls::model::io::clear_checkpoints(dir) {
                    Ok(0) => {}
                    Ok(n) => println!("cleared {n} stale checkpoint file(s) in {}", dir.display()),
                    Err(e) => {
                        eprintln!("error clearing {}: {e}", dir.display());
                        return 1;
                    }
                }
            }
        }
        match fresh(cfg) {
            Ok(tr) => tr,
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        }
    };
    println!(
        "training: {} thread(s), batch {}, objective {}",
        tr.n_threads(),
        tr.config().batch.max(1),
        tr.config().objective,
    );
    if (tr.n_threads() > 1 || tr.config().batch > 1) && tr.config().averaging {
        println!("note: weight averaging is serial-only and is disabled on the Hogwild path");
    }

    // `--epochs` is the *total* target: a resumed run trains only the
    // remaining epochs, so rerunning the interrupted command converges
    // instead of compounding.
    let epoch_offset = tr.epochs_done() as usize;
    let remaining = epochs.saturating_sub(epoch_offset);
    if remaining < epochs {
        println!("{epoch_offset} epoch(s) already trained; {remaining} remaining of {epochs}");
    }
    let ms = match &ckpt_dir {
        Some(dir) => match tr.fit_with_checkpoints(train, remaining, dir) {
            Ok(ms) => ms,
            Err(e) => {
                eprintln!("error writing checkpoint: {e}");
                return 1;
            }
        },
        None => tr.fit(train, remaining),
    };
    for (i, m) in ms.iter().enumerate() {
        println!("epoch {}: {}", epoch_offset + i + 1, m);
    }
    let train_s = timer.elapsed_s();
    let model = tr.into_model();
    let p1 = ltls::eval::precision_at_1(&model, test);
    let t = ltls::eval::time_predictions(&model, test, 1);
    println!(
        "precision@1 = {:.4}   train {:.2}s   predict {:.3}s ({:.1} µs/ex)   model {:.2} MB (W={}, E={}, backend={})",
        p1,
        train_s,
        t.total_s,
        t.per_example_us,
        model.bytes() as f64 / 1e6,
        model.trellis.width(),
        model.trellis.num_edges(),
        model.model.backend().name(),
    );
    if model.model.hash_bits() > 0 {
        let e = model.trellis.num_edges();
        let dense_equiv_bytes = ((model.model.n_features() * e + e) * 4) as f64;
        println!(
            "hashed storage: 2^{} buckets, {:.2} MB vs dense-equivalent {:.2} MB ({:.1}x smaller)",
            model.model.hash_bits(),
            model.bytes() as f64 / 1e6,
            dense_equiv_bytes / 1e6,
            dense_equiv_bytes / model.bytes() as f64,
        );
    }
    if l1_lambda > 0.0 {
        // One weight scan feeds both derived metrics.
        let zeros = model.model.zero_weights();
        let zf = zeros as f64 / model.model.weight_count().max(1) as f64;
        let eff = model.bytes() - zeros * model.model.weight_elem_bytes();
        println!(
            "l1 (λ={l1_lambda}): zero-fraction {zf:.4} → effective {:.2} MB of {:.2} MB stored",
            eff as f64 / 1e6,
            model.bytes() as f64 / 1e6,
        );
    }
    // Full XC metric sweep (propensities fitted on the train split, as in
    // Jain et al.) + optional model persistence.
    let props = ltls::eval::Propensities::from_train(train);
    let metrics = ltls::eval::evaluate_with(&model, test, &[1, 3, 5], Some(&props));
    println!("{metrics}");
    if let Some(path) = args.get("save") {
        match ltls::model::io::save(&model, std::path::Path::new(path)) {
            Ok(()) => println!("saved model to {path}"),
            Err(e) => {
                eprintln!("error saving model: {e}");
                return 1;
            }
        }
    }
    0
}

/// `ltls quantize --model in.ltls --out out.ltls`: convert a trained dense
/// model file to the serve-only q8 backend (~4× smaller weight block).
fn cmd_quantize(args: &Args) -> i32 {
    let Some(input) = args.get("model") else {
        eprintln!("error: --model <file> is required");
        return 1;
    };
    let out = args.get_str("out", "model.q8.ltls");
    let loaded = match ltls::model::io::load_any(std::path::Path::new(input)) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    fn write_q8<T: Topology>(
        q8: ltls::train::TrainedModel<T, ltls::model::Q8Store>,
        dense_bytes: usize,
        out: &str,
    ) -> i32 {
        match ltls::model::io::save(&q8, std::path::Path::new(out)) {
            Ok(()) => {
                println!(
                    "quantized: {:.2} MB (f32) → {:.2} MB (q8), {:.2}x smaller; wrote {out}",
                    dense_bytes as f64 / 1e6,
                    q8.bytes() as f64 / 1e6,
                    dense_bytes as f64 / q8.bytes() as f64,
                );
                0
            }
            Err(e) => {
                eprintln!("error saving quantized model: {e}");
                1
            }
        }
    }
    match loaded {
        ltls::model::io::AnyModel::Binary(m) => write_q8(m.quantized(), m.bytes(), out),
        ltls::model::io::AnyModel::Wide(m) => write_q8(m.quantized(), m.bytes(), out),
        other => {
            eprintln!(
                "error: quantize expects a dense model file, {input} stores backend={}",
                other.backend().name()
            );
            1
        }
    }
}

fn cmd_tables(args: &Args) -> i32 {
    let scale = args.get_f32("scale", 0.2) as f64;
    let epochs = args.get_usize("epochs", 5);
    let seed = args.get_u64("seed", 42);
    let which = args.get_str("which", "all");
    if which == "1" || which == "all" {
        print!("{}", ltls::eval::tables::table1(scale, epochs, seed).render());
    }
    if which == "2" || which == "all" {
        print!("{}", ltls::eval::tables::table2(scale, epochs, seed).render());
    }
    if which == "3" || which == "all" {
        let rows = ltls::eval::tables::table3(scale, epochs, seed);
        print!("{}", ltls::eval::tables::render_table3(&rows));
    }
    0
}

fn cmd_deep(args: &Args) -> i32 {
    let epochs = args.get_usize("epochs", 3);
    let steps = args.get_usize("steps", 0);
    match run_deep(epochs, steps, args.get_f32("lr", 0.4), args.get_f32("scale", 1.0) as f64) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn run_deep(epochs: usize, step_cap: usize, lr: f32, scale: f64) -> Result<(), String> {
    use ltls::runtime::{artifacts, ArtifactMeta, DeepLtls, Engine};
    let meta = ArtifactMeta::load(&artifacts::default_dir())?;
    println!(
        "artifacts: C={} D={} hidden={} batch={} E={}",
        meta.c, meta.d, meta.hidden, meta.batch, meta.e
    );
    let engine = Engine::cpu()?;
    println!("PJRT platform: {}", engine.platform());
    let mut deep = DeepLtls::load(&engine, meta.clone())?;

    // The imageNet analog at the artifact's dimensions. Routed through the
    // CLI error path (no unwrap): a registry rename must print a usage
    // error, not panic.
    let analog = ltls::data::datasets::by_name("imageNet")
        .ok_or("unknown dataset \"imageNet\" (the deep path needs the imageNet analog; \
                was the dataset registry renamed?)")?;
    let (train, test) = analog.generate(scale, 7);
    let b = meta.batch;
    let n = train.n_examples();
    let mut order: Vec<usize> = (0..n).collect();
    let mut rng = ltls::util::rng::Rng::new(3);
    let mut step = 0usize;
    for epoch in 0..epochs {
        rng.shuffle(&mut order);
        let mut loss_sum = 0.0f64;
        let mut batches = 0;
        for chunk in order.chunks(b) {
            loss_sum += deep.train_batch(&train, chunk, lr)? as f64;
            batches += 1;
            step += 1;
            if step_cap > 0 && step >= step_cap {
                break;
            }
        }
        let p1 = deep.precision_at_1(&test)?;
        println!(
            "epoch {}: mean loss {:.4}  test p@1 {:.4}",
            epoch + 1,
            loss_sum / batches.max(1) as f64,
            p1
        );
        if step_cap > 0 && step >= step_cap {
            break;
        }
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> i32 {
    if args.get("listen").is_some() {
        return serve_network(args);
    }
    if let Some(path) = args.get("model") {
        let path = path.to_string();
        return serve_saved(args, &path);
    }
    if args.get_bool("mmap") {
        eprintln!("error: --mmap requires --model <file> (a saved v3 model to map)");
        return 1;
    }
    let (train, test) = match load_dataset(args) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let width = match parse_width(args) {
        Ok(w) => w,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    warn_width_vs_classes(width, train.n_labels as u64);
    if width == 2 {
        run_serve::<ltls::graph::Trellis>(args, &train, &test, width)
    } else {
        run_serve::<ltls::graph::WideTrellis>(args, &train, &test, width)
    }
}

/// `ltls serve --listen HOST:PORT ...`: the network frontend (see the
/// crate docs at the top of this file for the flag set and the module
/// docs of `ltls::coordinator::transport` for the wire protocol). With
/// `--model` the served model is hot-reloadable — by the `RELOAD`
/// control command, and by `--watch-model F` which polls `F` and swaps
/// it in when it changes and validates. Runs until a client sends
/// `SHUTDOWN`, then drains gracefully and prints the serving metrics.
fn serve_network(args: &Args) -> i32 {
    use ltls::coordinator::{ModelWatcher, NetServer, ReloadableLtls};
    let listen = args.get_str("listen", "127.0.0.1:7878").to_string();
    let cfg = match net_config(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    // The served model: a saved file (hot-reloadable from its path), or a
    // fresh train on --dataset (reloadable only via `RELOAD <path>`).
    let reloadable = if let Some(path) = args.get("model") {
        match ReloadableLtls::from_path(std::path::Path::new(path), args.get_bool("mmap")) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        }
    } else {
        if args.get_bool("mmap") {
            eprintln!("error: --mmap requires --model <file> (a saved v3 model to map)");
            return 1;
        }
        let (train, _) = match load_dataset(args) {
            Ok(x) => x,
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        };
        let width = match parse_width(args) {
            Ok(w) => w,
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        };
        warn_width_vs_classes(width, train.n_labels as u64);
        let epochs = args.get_usize("epochs", 3);
        let tcfg = ltls::train::TrainConfig { width, ..Default::default() };
        let any = if width == 2 {
            let mut tr = match ltls::train::Trainer::<ltls::graph::Trellis>::with_topology(
                tcfg,
                train.n_features,
                train.n_labels,
            ) {
                Ok(tr) => tr,
                Err(e) => {
                    eprintln!("error: {e}");
                    return 1;
                }
            };
            tr.fit(&train, epochs);
            ltls::model::io::AnyModel::Binary(tr.into_model())
        } else {
            let mut tr = match ltls::train::Trainer::<ltls::graph::WideTrellis>::with_topology(
                tcfg,
                train.n_features,
                train.n_labels,
            ) {
                Ok(tr) => tr,
                Err(e) => {
                    eprintln!("error: {e}");
                    return 1;
                }
            };
            tr.fit(&train, epochs);
            ltls::model::io::AnyModel::Wide(tr.into_model())
        };
        ReloadableLtls::new(any)
    };
    let reloadable = std::sync::Arc::new(reloadable);
    {
        let snap = reloadable.snapshot();
        println!(
            "serving model: C={} W={} E={} backend={} size={:.2} MB mmap={}",
            snap.c(),
            snap.width(),
            snap.num_edges(),
            snap.backend().name(),
            snap.bytes() as f64 / 1e6,
            if snap.is_mapped() { "yes" } else { "no" },
        );
    }
    let watcher = args.get("watch-model").map(|p| {
        println!("watching {p} for model updates");
        ModelWatcher::spawn(
            std::sync::Arc::clone(&reloadable),
            std::path::PathBuf::from(p),
            std::time::Duration::from_millis(args.get_u64("watch-poll-ms", 500)),
        )
    });
    let server =
        match NetServer::start_reloadable(&listen, std::sync::Arc::clone(&reloadable), cfg) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        };
    println!(
        "listening on {} ({} transport) with {} worker(s) — protocol: \
         `<k> <i:v> <i:v> ...` | PING | METRICS | TRACE | RELOAD [path] | SHUTDOWN",
        server.addr(),
        server.transport(),
        server.n_workers(),
    );
    server.wait_for_shutdown_request();
    println!("SHUTDOWN received; draining in-flight requests...");
    let metrics = server.metrics();
    server.shutdown();
    if let Some(w) = watcher {
        w.stop();
    }
    println!("{}", metrics.summary());
    println!("drained cleanly");
    0
}

/// The `--listen` transport / admission / trace flag set, shared verbatim
/// between `serve --listen` and `coordinator` (the knob table with
/// defaults and interactions is `docs/OBSERVABILITY.md`).
fn net_config(args: &Args) -> Result<ltls::coordinator::NetConfig, String> {
    let transport = match args.get("transport") {
        None => ltls::coordinator::Transport::default(),
        Some(s) => s.parse::<ltls::coordinator::Transport>()?,
    };
    Ok(ltls::coordinator::NetConfig {
        server: ltls::coordinator::ServerConfig {
            batcher: ltls::coordinator::BatcherConfig {
                max_batch: args.get_usize("batch", 64),
                max_wait: std::time::Duration::from_micros(args.get_u64("max-wait-us", 500)),
            },
            queue_depth: args.get_usize("queue-depth", 1024),
            workers: args.get_usize("workers", 0),
        },
        max_inflight: args.get_usize("max-inflight", 0),
        max_inflight_per_conn: args.get_usize("max-inflight-per-conn", 0),
        transport,
        poll_threads: args.get_usize("poll-threads", 0),
        conn_buf_bytes: args.get_usize("conn-buf-bytes", 0),
        write_stall_ms: args.get_u64("write-stall-ms", 0),
        trace_sample: args.get_u64("trace-sample", 64),
        trace_slow_ms: args.get_u64("trace-slow-ms", 100),
    })
}

/// `ltls shard --model m.ltls --shards N [--out-prefix P]`: slice a
/// trained model into `N` v4 shard files for the scatter tier. The
/// default output stem is the input path without its `.ltls` suffix, so
/// `model.ltls` yields `model.shard0.ltls .. model.shard{N-1}.ltls`.
fn cmd_shard(args: &Args) -> i32 {
    let Some(input) = args.get("model") else {
        eprintln!("error: --model <file> is required");
        return 1;
    };
    let n_shards = args.get_u64("shards", 2);
    if n_shards == 0 || n_shards > u32::MAX as u64 {
        eprintln!("error: --shards must be a positive 32-bit count, got {n_shards}");
        return 1;
    }
    let n_shards = n_shards as u32;
    let stem = args.get_str("out-prefix", input.strip_suffix(".ltls").unwrap_or(input));
    let loaded = match ltls::model::io::load_any(std::path::Path::new(input)) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    println!(
        "slicing {input}: C={} W={} E={} backend={} into {n_shards} shard(s)",
        loaded.c(),
        loaded.width(),
        loaded.num_edges(),
        loaded.backend().name(),
    );
    fn write_slices<T: Topology, S: WeightStore>(
        m: &ltls::train::TrainedModel<T, S>,
        n_shards: u32,
        stem: &str,
    ) -> i32 {
        let plan = match ltls::graph::ShardPlan::new(&m.trellis, n_shards) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        };
        for shard in 0..n_shards {
            let sliced = match ltls::model::slice_model(m, &plan, shard) {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("error slicing shard {shard}: {e}");
                    return 1;
                }
            };
            let path = format!("{stem}.shard{shard}.ltls");
            if let Err(e) = ltls::model::io::save_shard(&sliced, std::path::Path::new(&path)) {
                eprintln!("error saving {path}: {e}");
                return 1;
            }
            println!(
                "shard {shard}/{n_shards}: {} labels, {} of {} edges, {:.2} MB → {path}",
                plan.owned_label_count(shard),
                sliced.model.owned_edges().len(),
                m.trellis.num_edges(),
                sliced.model.bytes() as f64 / 1e6,
            );
        }
        0
    }
    ltls::with_any_model!(&loaded, m => write_slices(m, n_shards, stem))
}

/// `ltls coordinator --listen HOST:PORT --shards SPEC ...`: the
/// scatter-gather frontend (see the crate docs at the top of this file).
/// Runs until a client sends `SHUTDOWN`, then drains gracefully.
fn cmd_coordinator(args: &Args) -> i32 {
    use ltls::coordinator::{NetServer, ScatterConfig, ScatterModel};
    let Some(spec) = args.get("shards") else {
        eprintln!(
            "error: --shards \"host:port,host:port;host:port,host:port\" is required \
             (replicas of one shard comma-separated, shards semicolon-separated)"
        );
        return 1;
    };
    let listen = args.get_str("listen", "127.0.0.1:7979").to_string();
    let cfg = match net_config(args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let features = args.get_usize("features", 0);
    let scfg = ScatterConfig {
        shard_timeout_ms: args.get_u64("shard-timeout-ms", 0),
        connect_timeout_ms: args.get_u64("connect-timeout-ms", 0),
        n_features: if features == 0 { None } else { Some(features) },
    };
    let model = match ScatterModel::from_spec(spec, scfg) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let n_shards = model.n_shards();
    let server = match NetServer::start_scatter(&listen, model, cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    println!(
        "coordinator on {} ({} transport, {} worker(s)) fanning out over {n_shards} shard(s) — \
         protocol: `<k> <i:v> <i:v> ...` | PING | METRICS | TRACE | SHUTDOWN",
        server.addr(),
        server.transport(),
        server.n_workers(),
    );
    server.wait_for_shutdown_request();
    println!("SHUTDOWN received; draining in-flight requests...");
    let metrics = server.metrics();
    server.shutdown();
    println!("{}", metrics.summary());
    println!("drained cleanly");
    0
}

/// `ltls serve --model m.ltls [--mmap]`: serve a saved model of any
/// (width, backend) pair; `--mmap` borrows the weight block zero-copy
/// from the mapped file instead of materializing it on the heap.
fn serve_saved(args: &Args, path: &str) -> i32 {
    let mmap = args.get_bool("mmap");
    let p = std::path::Path::new(path);
    let loaded = if mmap {
        ltls::model::io::load_any_mmap(p)
    } else {
        ltls::model::io::load_any(p)
    };
    let loaded = match loaded {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    println!(
        "serving model {path}: C={} W={} E={} backend={} size={:.2} MB mmap={}",
        loaded.c(),
        loaded.width(),
        loaded.num_edges(),
        loaded.backend().name(),
        loaded.bytes() as f64 / 1e6,
        if loaded.is_mapped() { "yes" } else { "no" },
    );
    // Request traffic comes from the dataset's test split.
    let (_, test) = match load_dataset(args) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    if test.n_features > loaded.n_features() {
        eprintln!(
            "error: dataset has {} features but the model was trained on {} — serve the \
             dataset the model was trained for",
            test.n_features,
            loaded.n_features()
        );
        return 1;
    }
    ltls::with_any_model!(loaded, m => drive_server(args, ltls::coordinator::BatchedLtls(m), &test))
}

fn run_serve<T: Topology>(
    args: &Args,
    train: &ltls::data::Dataset,
    test: &ltls::data::Dataset,
    width: u32,
) -> i32 {
    let tcfg = ltls::train::TrainConfig { width, ..Default::default() };
    let mut tr =
        match ltls::train::Trainer::<T>::with_topology(tcfg, train.n_features, train.n_labels) {
            Ok(tr) => tr,
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        };
    tr.fit(train, args.get_usize("epochs", 3));
    let model = tr.into_model();
    drive_server(args, ltls::coordinator::BatchedLtls(model), test)
}

/// Start the worker pool on `model`, pump `--requests` requests from the
/// test split through it, and print the serving metrics.
fn drive_server<M: ltls::coordinator::server::BatchModel>(
    args: &Args,
    model: M,
    test: &ltls::data::Dataset,
) -> i32 {
    use ltls::coordinator::{PredictServer, ServerConfig};
    let cfg = ServerConfig {
        batcher: ltls::coordinator::BatcherConfig {
            max_batch: args.get_usize("batch", 64),
            max_wait: std::time::Duration::from_micros(args.get_u64("max-wait-us", 500)),
        },
        queue_depth: 1024,
        // 0 → one worker per available core.
        workers: args.get_usize("workers", 0),
    };
    let server = PredictServer::start(model, cfg);
    println!("serving with {} workers (batched LTLS path)", server.n_workers());
    let n_req = args.get_usize("requests", 20_000);
    let timer = ltls::util::timer::Timer::new();
    let mut pending = std::collections::VecDeque::new();
    for i in 0..n_req {
        let row = test.row(i % test.n_examples());
        pending.push_back(server.submit(row.indices.to_vec(), row.values.to_vec(), 1));
        if pending.len() >= 256 {
            pending.pop_front().unwrap().recv().unwrap();
        }
    }
    for rx in pending {
        rx.recv().unwrap();
    }
    let secs = timer.elapsed_s();
    println!("{}", server.metrics.summary());
    println!("throughput: {:.0} req/s", n_req as f64 / secs);
    server.shutdown();
    0
}

/// `ltls eval --model m.ltls --dataset <analog|file.svm>`: load a saved
/// model (any width and backend — the file records both) and report the
/// full XC metric suite on the test split, plus the memory footprint.
fn cmd_eval(args: &Args) -> i32 {
    let Some(path) = args.get("model") else {
        eprintln!("error: --model <file> is required");
        return 1;
    };
    let model = match ltls::model::io::load_any(std::path::Path::new(path)) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    let (train, test) = match load_dataset(args) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    println!(
        "loaded {path}: C={} W={} E={} backend={} size={:.2} MB (effective {:.2} MB, zero-fraction {:.4})",
        model.c(),
        model.width(),
        model.num_edges(),
        model.backend().name(),
        model.bytes() as f64 / 1e6,
        model.effective_bytes() as f64 / 1e6,
        model.zero_fraction(),
    );
    fn report<T: Topology, S: WeightStore>(
        m: &ltls::train::TrainedModel<T, S>,
        train: &ltls::data::Dataset,
        test: &ltls::data::Dataset,
    ) {
        let props = ltls::eval::Propensities::from_train(train);
        let r = ltls::eval::evaluate_with(m, test, &[1, 3, 5], Some(&props));
        println!(
            "{} (C={}, W={}, E={}, backend={})",
            r,
            m.trellis.c(),
            m.trellis.width(),
            m.trellis.num_edges(),
            m.model.backend().name()
        );
    }
    ltls::with_any_model!(&model, m => report(m, &train, &test));
    0
}

fn cmd_scaling(args: &Args) -> i32 {
    use ltls::util::rng::Rng;
    let kmax = args.get_usize("kmax", 20);
    println!("{:<14}{:>8}{:>14}{:>14}{:>16}", "C", "E", "viterbi", "top-10", "model KB (D=1k)");
    let mut rng = Rng::new(9);
    // Engine workspace reused across every C — the decode loop below is
    // allocation-free.
    let mut ws = ltls::engine::DecodeWorkspace::new();
    let mut topk = Vec::new();
    for exp in (4..=kmax.min(40)).step_by(4) {
        let c = (1u64 << exp) + 12345 % (1 << exp);
        let t = ltls::graph::Trellis::new(c);
        let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
        let timer = ltls::util::timer::Timer::new();
        let iters = 20_000;
        for _ in 0..iters {
            std::hint::black_box(ltls::decode::viterbi(&t, std::hint::black_box(&h)));
        }
        let v_ns = timer.elapsed_s() * 1e9 / iters as f64;
        let timer = ltls::util::timer::Timer::new();
        for _ in 0..iters / 10 {
            ltls::decode::list_viterbi_into(&t, std::hint::black_box(&h), 10, &mut ws, &mut topk);
            std::hint::black_box(topk.len());
        }
        let l_ns = timer.elapsed_s() * 1e9 / (iters / 10) as f64;
        println!(
            "{:<14}{:>8}{:>12.0}ns{:>12.0}ns{:>16.1}",
            c,
            t.num_edges(),
            v_ns,
            l_ns,
            (t.num_edges() * 1000 * 4) as f64 / 1024.0
        );
    }
    println!("(prediction cost grows with E = O(log C); model size is E·D floats)");
    0
}
