//! Reusable decode/predict buffers (see module docs in [`super`]).

use crate::decode::Scored;
use crate::model::ScoreScratch;

/// Buffers for the trellis dynamic-programming decoders.
///
/// Holds the list-Viterbi per-state k-best prefix lists (entries are
/// `(score, packed state code)` pairs) and the forward–backward
/// alpha/beta tables. After the first call at a given `(C, k)` every
/// subsequent `_into` decode performs no heap allocation.
#[derive(Clone, Debug, Default)]
pub struct DecodeWorkspace {
    /// list-Viterbi: k-best prefixes ending in state 0 / state 1.
    pub(crate) list0: Vec<(f32, u64)>,
    pub(crate) list1: Vec<(f32, u64)>,
    /// list-Viterbi: merge targets for the next step (swapped each step).
    pub(crate) next0: Vec<(f32, u64)>,
    pub(crate) next1: Vec<(f32, u64)>,
    /// Forward pass: `alpha[j-1][s]` = log-sum of prefix scores into
    /// (step j, state s).
    pub(crate) alpha: Vec<[f32; 2]>,
    /// Backward pass: `beta[j-1][s]` = log-sum over suffixes from
    /// (step j, state s) to the sink.
    pub(crate) beta: Vec<[f32; 2]>,
    /// Per-terminal forward contributions (one per early exit).
    pub(crate) exit_terms: Vec<f32>,
    /// Terminal-term gather buffer for the log-partition logsumexp.
    pub(crate) terms: Vec<f32>,

    // ---- Width-generic (W-LTLS) decoder buffers. The width-2 kernels ----
    // ---- above keep their fixed-arity state; a topology of width W    ----
    // ---- runs the generic decoders in `crate::decode::generic`, which ----
    // ---- keep their per-state DP registers here.                      ----
    /// Generic Viterbi: per-state best score / packed mixed-radix code.
    pub(crate) wscore: Vec<f32>,
    pub(crate) wcode: Vec<u64>,
    pub(crate) wscore_next: Vec<f32>,
    pub(crate) wcode_next: Vec<u64>,
    /// Generic list-Viterbi: per-state k-best prefix lists (W lists) and
    /// their next-step targets (swapped each step).
    pub(crate) wlists: Vec<Vec<(f32, u64)>>,
    pub(crate) wnext: Vec<Vec<(f32, u64)>>,
    /// Generic list-Viterbi: merge candidate buffer (up to W·k entries).
    pub(crate) wcand: Vec<(f32, u64)>,
    /// Generic forward/backward tables, `steps × W` row-major.
    pub(crate) walpha: Vec<f32>,
    pub(crate) wbeta: Vec<f32>,
    /// Generic logsumexp gather scratch (W entries).
    pub(crate) wtmp: Vec<f32>,
}

impl DecodeWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-size for a trellis with `steps` steps and top-`k` decoding, so
    /// even the first decode is allocation-free.
    pub fn reserve(&mut self, steps: usize, k: usize) {
        for l in [&mut self.list0, &mut self.list1, &mut self.next0, &mut self.next1] {
            l.reserve(k);
        }
        self.alpha.reserve(steps);
        self.beta.reserve(steps);
        self.exit_terms.reserve(steps);
        self.terms.reserve(steps + 2);
    }

    /// Pre-size the width-generic buffers for a `width × steps` topology
    /// and top-`k` decoding, so even the first generic decode is
    /// allocation-free.
    pub fn reserve_wide(&mut self, width: usize, steps: usize, k: usize) {
        for v in [&mut self.wscore, &mut self.wscore_next, &mut self.wtmp] {
            v.reserve(width);
        }
        self.wcode.reserve(width);
        self.wcode_next.reserve(width);
        if self.wlists.len() < width {
            self.wlists.resize_with(width, Vec::new);
        }
        if self.wnext.len() < width {
            self.wnext.resize_with(width, Vec::new);
        }
        for l in self.wlists.iter_mut().chain(self.wnext.iter_mut()) {
            l.reserve(k);
        }
        self.wcand.reserve(width * k);
        self.walpha.reserve(width * steps);
        self.wbeta.reserve(width * steps);
        self.exit_terms.reserve(steps * width);
        self.terms.reserve(steps * width + width);
    }
}

/// A full per-worker prediction scratchpad: everything a consumer needs to
/// run `x → edge scores → decode → top-k` (and the batched variant) with
/// zero steady-state allocation.
#[derive(Clone, Debug, Default)]
pub struct PredictScratch {
    /// Edge-score vector `h = Wx + b` for the current example.
    pub h: Vec<f32>,
    /// Decoder buffers.
    pub ws: DecodeWorkspace,
    /// Decoded (path, score) list before label resolution.
    pub paths: Vec<Scored>,
    /// Batched edge scores (`B × E`, row-major), written by
    /// [`crate::model::LinearEdgeModel::edge_scores_batch`].
    pub batch_h: Vec<f32>,
    /// Scoring-kernel scratch: the batched scorer's `(feature, row,
    /// value)` gather buffer and the q8 backend's typed i32 accumulator.
    pub score: ScoreScratch,
}

impl PredictScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Buffers owned by the shared objective kernel
/// ([`crate::train::objective::objective_step`]): the loss decode state
/// plus the symmetric-difference update sets. Split out of
/// [`TrainScratch`] so the kernel can borrow them as one unit while the
/// caller keeps the edge-score buffers (whose slices feed the kernel as
/// plain `&[f32]`) borrowed separately.
#[derive(Clone, Debug, Default)]
pub struct StepScratch {
    /// Decoder buffers for the loss's list-Viterbi.
    pub ws: DecodeWorkspace,
    /// Decoded (path, score) list used by
    /// [`crate::loss::separation_loss_ws`] /
    /// [`crate::loss::union_separation_ws`].
    pub paths: Vec<Scored>,
    /// Full edge sets of the current loss pair (positive / negative path),
    /// filled by [`crate::graph::Topology::edges_of_label_into`].
    pub pos_edges: Vec<u32>,
    pub neg_edges: Vec<u32>,
    /// Symmetric-difference edge sets of the current loss pair.
    pub pos_only: Vec<u32>,
    pub neg_only: Vec<u32>,
    /// Per-positive `(path, hinged margin)` list of the multilabel
    /// union-of-gold-paths objective (empty on the multiclass path).
    pub pos_margins: Vec<(u64, f32)>,
}

/// A full per-worker *training* scratchpad: everything one SGD worker needs
/// to run `x → edge scores → objective loss → sparse update` (and the
/// mini-batch variant) with zero steady-state allocation. One of these is
/// owned by the serial [`crate::train::Trainer`] and by every worker of the
/// Hogwild [`crate::train::ParallelTrainer`].
#[derive(Clone, Debug, Default)]
pub struct TrainScratch {
    /// Edge-score vector `h = Wx + b` for the current example.
    pub h: Vec<f32>,
    /// Positive paths of the current example (labels resolved via the
    /// assignment table).
    pub pos: Vec<u64>,
    /// Batched edge scores (`B × E`, row-major) for the mini-batch path.
    pub batch_h: Vec<f32>,
    /// Scoring-kernel scratch (gather triples + q8 i32 accumulator).
    pub score: ScoreScratch,
    /// The objective kernel's loss/update buffers.
    pub step: StepScratch,
}

impl TrainScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workspace_reserve_is_idempotent() {
        let mut ws = DecodeWorkspace::new();
        ws.reserve(40, 16);
        let cap = ws.list0.capacity();
        ws.reserve(40, 16);
        assert!(ws.list0.capacity() >= 16);
        assert_eq!(ws.list0.capacity(), cap);
        assert!(ws.alpha.capacity() >= 40);
    }

    #[test]
    fn scratch_constructs_empty() {
        let s = PredictScratch::new();
        assert!(s.h.is_empty() && s.batch_h.is_empty() && s.paths.is_empty());
    }

    #[test]
    fn train_scratch_constructs_empty() {
        let s = TrainScratch::new();
        assert!(s.h.is_empty() && s.pos.is_empty() && s.batch_h.is_empty());
        assert!(s.step.pos_only.is_empty() && s.step.neg_only.is_empty());
        assert!(s.step.pos_edges.is_empty() && s.step.neg_edges.is_empty());
        assert!(s.step.pos_margins.is_empty());
    }

    #[test]
    fn wide_reserve_is_idempotent_and_sizes_lists() {
        let mut ws = DecodeWorkspace::new();
        ws.reserve_wide(8, 12, 16);
        assert_eq!(ws.wlists.len(), 8);
        assert_eq!(ws.wnext.len(), 8);
        assert!(ws.wlists.iter().all(|l| l.capacity() >= 16));
        assert!(ws.walpha.capacity() >= 8 * 12);
        let cap = ws.wcand.capacity();
        ws.reserve_wide(8, 12, 16);
        assert_eq!(ws.wcand.capacity(), cap);
        // Narrower re-reserve never shrinks.
        ws.reserve_wide(4, 6, 8);
        assert_eq!(ws.wlists.len(), 8);
    }
}
