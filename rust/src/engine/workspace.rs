//! Reusable decode/predict buffers (see module docs in [`super`]).

use crate::decode::Scored;

/// Buffers for the trellis dynamic-programming decoders.
///
/// Holds the list-Viterbi per-state k-best prefix lists (entries are
/// `(score, packed state code)` pairs) and the forward–backward
/// alpha/beta tables. After the first call at a given `(C, k)` every
/// subsequent `_into` decode performs no heap allocation.
#[derive(Clone, Debug, Default)]
pub struct DecodeWorkspace {
    /// list-Viterbi: k-best prefixes ending in state 0 / state 1.
    pub(crate) list0: Vec<(f32, u64)>,
    pub(crate) list1: Vec<(f32, u64)>,
    /// list-Viterbi: merge targets for the next step (swapped each step).
    pub(crate) next0: Vec<(f32, u64)>,
    pub(crate) next1: Vec<(f32, u64)>,
    /// Forward pass: alpha[j-1][s] = log-sum of prefix scores into
    /// (step j, state s).
    pub(crate) alpha: Vec<[f32; 2]>,
    /// Backward pass: beta[j-1][s] = log-sum over suffixes from
    /// (step j, state s) to the sink.
    pub(crate) beta: Vec<[f32; 2]>,
    /// Per-terminal forward contributions (one per early exit).
    pub(crate) exit_terms: Vec<f32>,
    /// Terminal-term gather buffer for the log-partition logsumexp.
    pub(crate) terms: Vec<f32>,
}

impl DecodeWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-size for a trellis with `steps` steps and top-`k` decoding, so
    /// even the first decode is allocation-free.
    pub fn reserve(&mut self, steps: usize, k: usize) {
        for l in [&mut self.list0, &mut self.list1, &mut self.next0, &mut self.next1] {
            l.reserve(k);
        }
        self.alpha.reserve(steps);
        self.beta.reserve(steps);
        self.exit_terms.reserve(steps);
        self.terms.reserve(steps + 2);
    }
}

/// A full per-worker prediction scratchpad: everything a consumer needs to
/// run `x → edge scores → decode → top-k` (and the batched variant) with
/// zero steady-state allocation.
#[derive(Clone, Debug, Default)]
pub struct PredictScratch {
    /// Edge-score vector `h = Wx + b` for the current example.
    pub h: Vec<f32>,
    /// Decoder buffers.
    pub ws: DecodeWorkspace,
    /// Decoded (path, score) list before label resolution.
    pub paths: Vec<Scored>,
    /// Batched edge scores (`B × E`, row-major), written by
    /// [`crate::model::LinearEdgeModel::edge_scores_batch`].
    pub batch_h: Vec<f32>,
    /// Gather buffer `(feature, row, value)` for the batched scorer's
    /// one-sweep-per-feature-strip schedule.
    pub batch_gather: Vec<(u32, u32, f32)>,
}

impl PredictScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// A full per-worker *training* scratchpad: everything one SGD worker needs
/// to run `x → edge scores → separation loss → sparse update` (and the
/// mini-batch variant) with zero steady-state allocation. One of these is
/// owned by the serial [`crate::train::Trainer`] and by every worker of the
/// Hogwild [`crate::train::ParallelTrainer`].
#[derive(Clone, Debug, Default)]
pub struct TrainScratch {
    /// Edge-score vector `h = Wx + b` for the current example.
    pub h: Vec<f32>,
    /// Decoder buffers for the loss's list-Viterbi.
    pub ws: DecodeWorkspace,
    /// Decoded (path, score) list used by
    /// [`crate::loss::separation_loss_ws`].
    pub paths: Vec<Scored>,
    /// Positive paths of the current example (labels resolved via the
    /// assignment table).
    pub pos: Vec<u64>,
    /// Symmetric-difference edge sets of the loss pair.
    pub pos_only: Vec<u32>,
    pub neg_only: Vec<u32>,
    /// Batched edge scores (`B × E`, row-major) for the mini-batch path.
    pub batch_h: Vec<f32>,
    /// Gather buffer `(feature, row, value)` for the batched scorer.
    pub batch_gather: Vec<(u32, u32, f32)>,
}

impl TrainScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workspace_reserve_is_idempotent() {
        let mut ws = DecodeWorkspace::new();
        ws.reserve(40, 16);
        let cap = ws.list0.capacity();
        ws.reserve(40, 16);
        assert!(ws.list0.capacity() >= 16);
        assert_eq!(ws.list0.capacity(), cap);
        assert!(ws.alpha.capacity() >= 40);
    }

    #[test]
    fn scratch_constructs_empty() {
        let s = PredictScratch::new();
        assert!(s.h.is_empty() && s.batch_h.is_empty() && s.paths.is_empty());
    }

    #[test]
    fn train_scratch_constructs_empty() {
        let s = TrainScratch::new();
        assert!(s.h.is_empty() && s.pos.is_empty() && s.batch_h.is_empty());
        assert!(s.pos_only.is_empty() && s.neg_only.is_empty());
    }
}
