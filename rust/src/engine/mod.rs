//! The unified zero-allocation inference engine.
//!
//! LTLS's value proposition is `O(log C)` *decode* work per example — which
//! means allocator traffic and queueing, not arithmetic, dominate the hot
//! path unless the whole inference stack reuses its buffers. This layer
//! owns the reusable state and every inference consumer threads through it:
//!
//! * [`DecodeWorkspace`] — the buffers of the dynamic-programming decoders
//!   (list-Viterbi per-state k-best lists, forward–backward alpha/beta
//!   tables). The `_into` variants in [`crate::decode`]
//!   ([`crate::decode::list_viterbi_into`],
//!   [`crate::decode::posterior_marginals_into`],
//!   [`crate::decode::log_partition_ws`]) take one of these and perform
//!   **zero heap allocation** after warm-up; the classic allocating
//!   functions remain as thin wrappers.
//! * [`PredictScratch`] — a full per-worker prediction scratchpad: the
//!   edge-score buffer `h`, a [`DecodeWorkspace`], the decoded-path list,
//!   and the gather/output buffers of the batched edge scorer
//!   ([`crate::model::LinearEdgeModel::edge_scores_batch`]). One of these
//!   is owned by each consumer with a hot loop: every worker of the
//!   [`crate::coordinator`] prediction server, the timing harness
//!   ([`crate::eval::timing`]), and the decode benches.
//!
//! The [`crate::eval::Predictor`] trait exposes the engine to generic
//! callers through `topk_into(&self, x, k, &mut PredictScratch, &mut Vec)`;
//! LTLS ([`crate::train::TrainedModel`]) and every baseline implement it.
//!
//! Invariant (enforced by `rust/tests/engine_parity.rs`): the engine paths
//! are **bit-identical** to the allocating paths — same float-op order,
//! same tie-breaks — so the choice is purely a performance dial.

//! The training side mirrors this: [`TrainScratch`] is the per-worker SGD
//! scratchpad (edge scores, loss decode buffers, symmetric-difference edge
//! sets, mini-batch gather/output buffers) owned by the serial trainer and
//! by every Hogwild worker of [`crate::train::ParallelTrainer`].

pub mod workspace;

pub use workspace::{DecodeWorkspace, PredictScratch, StepScratch, TrainScratch};
