//! Request-lifecycle tracing: per-stage span timelines, sampling, and
//! the always-on slow-request ring buffer.
//!
//! A [`Span`] is born when a prediction line comes off the socket and is
//! stamped at each pipeline [`Stage`] it passes through — accept →
//! parse → admit → enqueue → batch_form → score → decode → serialize →
//! write. Stamps are relaxed atomic stores of nanosecond offsets from
//! the span's start, so a span can be stamped concurrently from the
//! transport thread, the worker pool, and the writer without locks.
//!
//! The [`Tracer`] decides which spans are kept: every `sample_every`-th
//! request is recorded unconditionally, and *any* request slower than
//! `slow_ns` lands in a separate slow ring regardless of sampling. Both
//! rings are bounded ([`TRACE_RING_CAP`]) and drained over the wire by
//! the `TRACE` command as JSON lines (`docs/PROTOCOL.md`).

use crate::obs::registry::Counter;
use crate::util::json::Json;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Pipeline stages a request is stamped through, in causal order.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Stage {
    /// Request line lifted off the socket — the span's anchor (offset 0).
    Accept = 0,
    /// Request text parsed and validated.
    Parse = 1,
    /// Admission control (global + per-connection bounds) passed.
    Admit = 2,
    /// Request handed to the worker pool's bounded queue.
    Enqueue = 3,
    /// Micro-batch containing this request formed by a worker.
    BatchForm = 4,
    /// Edge scores computed for the batch.
    Score = 5,
    /// Top-k paths decoded for this request.
    Decode = 6,
    /// Reply rendered to its JSON line.
    Serialize = 7,
    /// Reply bytes handed to the socket write path.
    Write = 8,
}

pub const N_STAGES: usize = 9;

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::Accept => "accept",
            Stage::Parse => "parse",
            Stage::Admit => "admit",
            Stage::Enqueue => "enqueue",
            Stage::BatchForm => "batch_form",
            Stage::Score => "score",
            Stage::Decode => "decode",
            Stage::Serialize => "serialize",
            Stage::Write => "write",
        }
    }

    fn all() -> [Stage; N_STAGES] {
        [
            Stage::Accept,
            Stage::Parse,
            Stage::Admit,
            Stage::Enqueue,
            Stage::BatchForm,
            Stage::Score,
            Stage::Decode,
            Stage::Serialize,
            Stage::Write,
        ]
    }
}

/// Shared, concurrently-stampable span state. Stamps are stored as
/// `offset_ns + 1` so zero means "stage never reached".
pub struct SpanState {
    id: u64,
    sampled: bool,
    start: Instant,
    stamps: [AtomicU64; N_STAGES],
}

/// Handle threaded through `Request` and both transports.
pub type Span = Arc<SpanState>;

impl SpanState {
    /// Stamp `stage` at "now".
    pub fn stamp(&self, stage: Stage) {
        self.stamp_at(stage, Instant::now());
    }

    /// Stamp `stage` at an already-taken instant (lets one clock reading
    /// stamp a whole micro-batch).
    pub fn stamp_at(&self, stage: Stage, at: Instant) {
        let ns = at.checked_duration_since(self.start).unwrap_or_default().as_nanos() as u64;
        self.stamps[stage as usize].store(ns + 1, Ordering::Relaxed);
    }

    /// Span length so far: the latest stamped offset.
    fn total_ns(&self) -> u64 {
        self.stamps
            .iter()
            .map(|s| s.load(Ordering::Relaxed).saturating_sub(1))
            .max()
            .unwrap_or(0)
    }

    /// Stamped `(stage, offset_ns)` pairs in causal (offset) order.
    fn timeline(&self) -> Vec<(&'static str, u64)> {
        let mut v: Vec<(&'static str, u64)> = Stage::all()
            .iter()
            .filter_map(|&st| {
                let raw = self.stamps[st as usize].load(Ordering::Relaxed);
                if raw == 0 {
                    None
                } else {
                    Some((st.name(), raw - 1))
                }
            })
            .collect();
        v.sort_by_key(|&(_, ns)| ns);
        v
    }
}

/// A finished span as captured into a ring buffer.
pub struct TraceRecord {
    pub id: u64,
    pub kind: &'static str,
    pub total_ns: u64,
    pub stages: Vec<(&'static str, u64)>,
}

impl TraceRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::from(self.id as usize)),
            ("kind", Json::from(self.kind)),
            ("total_ns", Json::Num(self.total_ns as f64)),
            (
                "stages",
                Json::Arr(
                    self.stages
                        .iter()
                        .map(|&(name, ns)| {
                            Json::obj(vec![
                                ("stage", Json::from(name)),
                                ("ns", Json::Num(ns as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Capacity of each capture ring (sampled and slow).
pub const TRACE_RING_CAP: usize = 128;

/// Decides which spans exist and which finished spans are kept.
pub struct Tracer {
    /// Record every Nth request unconditionally; 0 disables sampling.
    sample_every: u64,
    /// Record any request slower than this; 0 disables slow capture.
    slow_ns: u64,
    seq: AtomicU64,
    next_id: AtomicU64,
    /// Spans recorded via sampling (scrape-visible counter).
    pub sampled_total: Counter,
    /// Spans recorded via the slow threshold (scrape-visible counter).
    pub slow_total: Counter,
    sampled: Mutex<VecDeque<TraceRecord>>,
    slow: Mutex<VecDeque<TraceRecord>>,
}

impl Tracer {
    pub fn new(sample_every: u64, slow_ns: u64) -> Tracer {
        Tracer {
            sample_every,
            slow_ns,
            seq: AtomicU64::new(0),
            next_id: AtomicU64::new(0),
            sampled_total: Counter::new(),
            slow_total: Counter::new(),
            sampled: Mutex::new(VecDeque::new()),
            slow: Mutex::new(VecDeque::new()),
        }
    }

    /// A tracer that never produces spans (tracing fully off).
    pub fn disabled() -> Tracer {
        Tracer::new(0, 0)
    }

    pub fn enabled(&self) -> bool {
        self.sample_every > 0 || self.slow_ns > 0
    }

    /// Start a span for the next request, if this request needs one:
    /// either it is the Nth sampled request, or slow capture is on (any
    /// request might turn out slow, so all of them carry a span). The
    /// `accept` stage is stamped at creation as the anchor.
    pub fn begin(&self) -> Option<Span> {
        if !self.enabled() {
            return None;
        }
        let n = self.seq.fetch_add(1, Ordering::Relaxed);
        let sampled = self.sample_every > 0 && n % self.sample_every == 0;
        if !sampled && self.slow_ns == 0 {
            return None;
        }
        let span = Arc::new(SpanState {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            sampled,
            start: Instant::now(),
            stamps: std::array::from_fn(|_| AtomicU64::new(0)),
        });
        span.stamps[Stage::Accept as usize].store(1, Ordering::Relaxed); // offset 0
        Some(span)
    }

    /// Finish a span (call after the `write` stamp): captures it into
    /// the sampled ring and/or — when it crossed the threshold — the
    /// slow ring. Unrecorded spans cost nothing here.
    pub fn finish(&self, span: &SpanState) {
        let total = span.total_ns();
        let slow = self.slow_ns > 0 && total >= self.slow_ns;
        if !span.sampled && !slow {
            return;
        }
        let stages = span.timeline();
        if span.sampled {
            self.sampled_total.inc();
            push_ring(
                &self.sampled,
                TraceRecord {
                    id: span.id,
                    kind: "sampled",
                    total_ns: total,
                    stages: stages.clone(),
                },
            );
        }
        if slow {
            self.slow_total.inc();
            push_ring(
                &self.slow,
                TraceRecord { id: span.id, kind: "slow", total_ns: total, stages },
            );
        }
    }

    /// Drain both rings as newline-separated JSON objects (sampled
    /// first, then slow). Returns an empty string when nothing was
    /// captured since the last dump.
    pub fn dump_json_lines(&self) -> String {
        let mut out = String::new();
        for ring in [&self.sampled, &self.slow] {
            let drained: Vec<TraceRecord> = ring.lock().unwrap().drain(..).collect();
            for rec in drained {
                out.push_str(&rec.to_json().dump());
                out.push('\n');
            }
        }
        out
    }
}

fn push_ring(ring: &Mutex<VecDeque<TraceRecord>>, rec: TraceRecord) {
    let mut r = ring.lock().unwrap();
    if r.len() >= TRACE_RING_CAP {
        r.pop_front();
    }
    r.push_back(rec);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn sampling_keeps_every_nth_span() {
        let t = Tracer::new(4, 0);
        let spans: Vec<_> = (0..8).map(|_| t.begin()).collect();
        let kept: Vec<bool> = spans.iter().map(|s| s.is_some()).collect();
        assert_eq!(kept, [true, false, false, false, true, false, false, false]);
        for s in spans.into_iter().flatten() {
            s.stamp(Stage::Write);
            t.finish(&s);
        }
        assert_eq!(t.sampled_total.get(), 2);
        let dump = t.dump_json_lines();
        assert_eq!(dump.lines().count(), 2);
        // Drained: a second dump is empty.
        assert!(t.dump_json_lines().is_empty());
    }

    #[test]
    fn slow_ring_captures_only_over_threshold() {
        let t = Tracer::new(0, 1_000_000); // 1ms threshold, no sampling
        let fast = t.begin().expect("slow capture spans every request");
        fast.stamp_at(Stage::Write, fast.start + Duration::from_micros(10));
        t.finish(&fast);
        let slow = t.begin().unwrap();
        slow.stamp_at(Stage::Parse, slow.start + Duration::from_micros(5));
        slow.stamp_at(Stage::Write, slow.start + Duration::from_millis(3));
        t.finish(&slow);
        assert_eq!(t.slow_total.get(), 1);
        let dump = t.dump_json_lines();
        assert_eq!(dump.lines().count(), 1);
        let j = Json::parse(dump.lines().next().unwrap()).unwrap();
        assert_eq!(j.get("kind").and_then(|k| k.as_str()), Some("slow"));
        assert!(j.get("total_ns").and_then(|t| t.as_f64()).unwrap() >= 3e6);
    }

    #[test]
    fn timeline_is_causal_and_json_parseable() {
        let t = Tracer::new(1, 0);
        let s = t.begin().unwrap();
        // Stamp out of order; the timeline must come back sorted.
        s.stamp_at(Stage::Decode, s.start + Duration::from_micros(30));
        s.stamp_at(Stage::Parse, s.start + Duration::from_micros(1));
        s.stamp_at(Stage::Write, s.start + Duration::from_micros(50));
        t.finish(&s);
        let dump = t.dump_json_lines();
        let j = Json::parse(dump.trim()).unwrap();
        let stages = j.get("stages").and_then(|s| s.as_arr()).unwrap();
        assert_eq!(stages.len(), 4); // accept + the three stamps
        let offs: Vec<f64> =
            stages.iter().map(|e| e.get("ns").and_then(|n| n.as_f64()).unwrap()).collect();
        assert!(offs.windows(2).all(|w| w[0] <= w[1]), "not causal: {offs:?}");
        assert_eq!(stages[0].get("stage").and_then(|s| s.as_str()), Some("accept"));
    }

    #[test]
    fn disabled_tracer_is_free() {
        let t = Tracer::disabled();
        assert!(t.begin().is_none());
        assert!(t.dump_json_lines().is_empty());
    }
}
