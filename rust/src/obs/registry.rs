//! Lock-free metrics primitives and the registry that renders them.
//!
//! Everything on the hot path is a relaxed atomic: [`Counter`] and
//! [`Gauge`] are single `AtomicU64`s, [`Histogram`] is a fixed
//! 64-bucket log2 nanosecond scale sharded across cache-line-padded
//! per-thread slots (writers never contend with readers; shards are
//! merged only at scrape time). The [`Registry`] owns the metric
//! families and renders the full Prometheus exposition — `# HELP` /
//! `# TYPE` headers, cumulative `_bucket{le="..."}` series, `_sum` and
//! `_count` — so every scrape surface in the repo (the `METRICS` wire
//! command, trainer stats) emits the same conformant text.
//!
//! Registration takes a `Mutex` (it happens a handful of times at
//! startup, or when the worker table grows); recording never does.

use crate::util::bench::fmt_ns;
use crate::util::timer::{log2_bucket_of, log2_bucket_upper_ns, LOG2_BUCKETS};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Monotone event count. Relaxed atomics: per-metric totals are exact,
/// cross-metric skew of a few events during a scrape is acceptable.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Counter(AtomicU64::new(0))
    }
    pub fn inc(&self) {
        self.add(1);
    }
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A value that can go up and down. Decrement is **saturating**: a
/// double-decrement race (e.g. two teardown paths both reporting a
/// connection close) pins the gauge at zero instead of wrapping to
/// `u64::MAX`.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn new() -> Self {
        Gauge(AtomicU64::new(0))
    }
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }
    /// Saturating decrement: never drops below zero.
    pub fn dec_saturating(&self) {
        let mut cur = self.0.load(Ordering::Relaxed);
        while cur != 0 {
            match self.0.compare_exchange_weak(cur, cur - 1, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(now) => cur = now,
            }
        }
    }
    /// Ratchet the gauge up to `v` if `v` is larger (peak tracking).
    pub fn set_max(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Number of write shards per histogram. Threads are dealt to shards
/// round-robin at first touch; 8 shards keeps false sharing off the
/// worker/poll threads without bloating the scrape merge.
const HIST_SHARDS: usize = 8;

static NEXT_THREAD_SHARD: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static THREAD_SHARD: usize = NEXT_THREAD_SHARD.fetch_add(1, Ordering::Relaxed);
}

/// One cache-line-aligned shard of a histogram: its own bucket array,
/// sum, and count, so concurrent recorders on different threads never
/// bounce a line between cores.
#[repr(align(64))]
struct HistShard {
    buckets: [AtomicU64; LOG2_BUCKETS],
    sum_ns: AtomicU64,
    count: AtomicU64,
    max_ns: AtomicU64,
}

impl HistShard {
    fn new() -> Self {
        HistShard {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_ns: AtomicU64::new(0),
            count: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }
}

/// Lock-free latency histogram on the fixed 64-bucket log2 nanosecond
/// scale (`util::timer::log2_bucket_of`): bucket `b` counts values in
/// `(2^(b-1), 2^b]` ns, bucket 0 holds `[0, 1]`, the top bucket is the
/// overflow catch-all. Recording is two relaxed `fetch_add`s on the
/// calling thread's shard; [`Histogram::snapshot`] merges the shards.
pub struct Histogram {
    shards: Box<[HistShard]>,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram { shards: (0..HIST_SHARDS).map(|_| HistShard::new()).collect() }
    }

    pub fn record_ns(&self, ns: u64) {
        let shard = &self.shards[THREAD_SHARD.with(|s| *s) % self.shards.len()];
        shard.buckets[log2_bucket_of(ns)].fetch_add(1, Ordering::Relaxed);
        shard.sum_ns.fetch_add(ns, Ordering::Relaxed);
        shard.count.fetch_add(1, Ordering::Relaxed);
        shard.max_ns.fetch_max(ns, Ordering::Relaxed);
    }

    pub fn record_duration(&self, d: std::time::Duration) {
        self.record_ns(d.as_nanos() as u64);
    }

    /// Merge every shard into one consistent-enough view (relaxed loads:
    /// counts recorded mid-scrape may or may not be included).
    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut snap = HistogramSnapshot::default();
        for shard in self.shards.iter() {
            for (acc, b) in snap.buckets.iter_mut().zip(shard.buckets.iter()) {
                *acc += b.load(Ordering::Relaxed);
            }
            snap.sum_ns += shard.sum_ns.load(Ordering::Relaxed);
            snap.count += shard.count.load(Ordering::Relaxed);
            snap.max_ns = snap.max_ns.max(shard.max_ns.load(Ordering::Relaxed));
        }
        snap
    }
}

/// Point-in-time merged view of a [`Histogram`].
#[derive(Clone)]
pub struct HistogramSnapshot {
    pub buckets: [u64; LOG2_BUCKETS],
    pub sum_ns: u64,
    pub count: u64,
    pub max_ns: u64,
}

impl Default for HistogramSnapshot {
    fn default() -> Self {
        HistogramSnapshot { buckets: [0; LOG2_BUCKETS], sum_ns: 0, count: 0, max_ns: 0 }
    }
}

impl HistogramSnapshot {
    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    /// Approximate quantile (`q` in [0,1]) in nanoseconds: the geometric
    /// midpoint of the bucket the quantile lands in (log2 buckets bound
    /// the error to ~1.41x either way).
    pub fn quantile_ns(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut acc = 0u64;
        for (b, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if c > 0 && acc >= target {
                let upper = log2_bucket_upper_ns(b) as f64;
                return if b == 0 { upper } else { upper / std::f64::consts::SQRT_2 };
            }
        }
        log2_bucket_upper_ns(LOG2_BUCKETS - 1) as f64
    }

    /// One-line human summary (same shape as the pre-registry
    /// `LatencyHistogram::summary`).
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={} p50={} p99={} max={}",
            self.count,
            fmt_ns(self.mean_ns()),
            fmt_ns(self.quantile_ns(0.50)),
            fmt_ns(self.quantile_ns(0.99)),
            fmt_ns(self.max_ns as f64),
        )
    }
}

// ---------------------------------------------------------------------------
// Exposition rendering
// ---------------------------------------------------------------------------

fn push_header(out: &mut String, name: &str, help: &str, kind: &str) {
    out.push_str("# HELP ");
    out.push_str(name);
    out.push(' ');
    out.push_str(help);
    out.push('\n');
    out.push_str("# TYPE ");
    out.push_str(name);
    out.push(' ');
    out.push_str(kind);
    out.push('\n');
}

/// Render one `counter` sample with its headers.
pub fn render_counter(out: &mut String, name: &str, help: &str, value: u64) {
    push_header(out, name, help, "counter");
    out.push_str(&format!("{name} {value}\n"));
}

/// Render one `gauge` sample with its headers. Whole numbers print
/// without a decimal point (Rust's shortest `f64` display).
pub fn render_gauge(out: &mut String, name: &str, help: &str, value: f64) {
    push_header(out, name, help, "gauge");
    out.push_str(&format!("{name} {value}\n"));
}

/// Upper edge of bucket `b` as a Prometheus `le` value in **seconds**.
/// The top bucket is the overflow catch-all, so its edge is `+Inf`.
fn le_seconds(b: usize) -> String {
    if b == LOG2_BUCKETS - 1 {
        "+Inf".to_string()
    } else {
        format!("{}", log2_bucket_upper_ns(b) as f64 / 1e9)
    }
}

/// Render a full cumulative histogram — `_bucket{le="..."}` for every
/// edge ending in `+Inf`, then `_sum` (seconds) and `_count`.
pub fn render_histogram(out: &mut String, name: &str, help: &str, snap: &HistogramSnapshot) {
    push_header(out, name, help, "histogram");
    let mut cum = 0u64;
    for (b, &c) in snap.buckets.iter().enumerate() {
        cum += c;
        out.push_str(&format!("{name}_bucket{{le=\"{}\"}} {cum}\n", le_seconds(b)));
    }
    out.push_str(&format!("{name}_sum {}\n", snap.sum_ns as f64 / 1e9));
    out.push_str(&format!("{name}_count {}\n", snap.count));
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

impl Metric {
    fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "histogram",
        }
    }
}

struct Family {
    name: String,
    help: String,
    /// `(label, metric)` — the label is rendered verbatim inside the
    /// braces (e.g. `worker="0"`); `None` renders a bare sample.
    metrics: Vec<(Option<String>, Metric)>,
}

/// Named metric families in registration order. Handles returned by the
/// `counter`/`gauge`/`histogram` constructors are plain `Arc`s — the
/// hot path records through them without ever touching the registry
/// lock, which is only taken to register and to render.
#[derive(Default)]
pub struct Registry {
    families: Mutex<Vec<Family>>,
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    fn register(&self, name: &str, help: &str, label: Option<String>, metric: Metric) {
        let mut fams = self.families.lock().unwrap();
        if let Some(f) = fams.iter_mut().find(|f| f.name == name) {
            f.metrics.push((label, metric));
        } else {
            fams.push(Family {
                name: name.to_string(),
                help: help.to_string(),
                metrics: vec![(label, metric)],
            });
        }
    }

    pub fn counter(&self, name: &str, help: &str) -> Arc<Counter> {
        let c = Arc::new(Counter::new());
        self.register(name, help, None, Metric::Counter(Arc::clone(&c)));
        c
    }

    /// A counter sample inside a labeled family (`name{label} value`).
    /// Repeated registrations under one `name` share the family header.
    pub fn counter_labeled(&self, name: &str, help: &str, label: String) -> Arc<Counter> {
        let c = Arc::new(Counter::new());
        self.register(name, help, Some(label), Metric::Counter(Arc::clone(&c)));
        c
    }

    pub fn gauge(&self, name: &str, help: &str) -> Arc<Gauge> {
        let g = Arc::new(Gauge::new());
        self.register(name, help, None, Metric::Gauge(Arc::clone(&g)));
        g
    }

    pub fn histogram(&self, name: &str, help: &str) -> Arc<Histogram> {
        let h = Arc::new(Histogram::new());
        self.register(name, help, None, Metric::Histogram(Arc::clone(&h)));
        h
    }

    /// Render every family in registration order.
    pub fn render(&self, out: &mut String) {
        let fams = self.families.lock().unwrap();
        for f in fams.iter() {
            match &f.metrics[0].1 {
                Metric::Histogram(_) => {
                    // Histogram families are single-sample (no labels).
                    for (_, m) in &f.metrics {
                        if let Metric::Histogram(h) = m {
                            render_histogram(out, &f.name, &f.help, &h.snapshot());
                        }
                    }
                }
                first => {
                    push_header(out, &f.name, &f.help, first.kind());
                    for (label, m) in &f.metrics {
                        let v = match m {
                            Metric::Counter(c) => c.get(),
                            Metric::Gauge(g) => g.get(),
                            Metric::Histogram(_) => continue,
                        };
                        match label {
                            Some(l) => out.push_str(&format!("{}{{{l}}} {v}\n", f.name)),
                            None => out.push_str(&format!("{} {v}\n", f.name)),
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gauge_decrement_saturates_at_zero() {
        let g = Gauge::new();
        g.inc();
        g.dec_saturating();
        g.dec_saturating(); // double-close race: must not wrap
        assert_eq!(g.get(), 0);
        g.inc();
        assert_eq!(g.get(), 1);
    }

    #[test]
    fn histogram_snapshot_merges_shards_and_orders_quantiles() {
        let h = Arc::new(Histogram::new());
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 1..=2_500u64 {
                        h.record_ns((t * 2_500 + i) * 1_000); // 1us..10ms
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let snap = h.snapshot();
        assert_eq!(snap.count, 10_000);
        let p50 = snap.quantile_ns(0.5);
        let p99 = snap.quantile_ns(0.99);
        assert!(p50 < p99);
        // log2 buckets: the estimate is within ~1.5x of the true value.
        assert!(p50 > 5e6 / 2.0 && p50 < 5e6 * 2.0, "p50={p50}");
        assert!((snap.mean_ns() - 5.0005e6).abs() < 1e3);
    }

    #[test]
    fn histogram_export_is_cumulative_and_monotone() {
        let h = Histogram::new();
        for ns in [1u64, 100, 100, 5_000, 1 << 40] {
            h.record_ns(ns);
        }
        let mut out = String::new();
        render_histogram(&mut out, "t_seconds", "test", &h.snapshot());
        let mut prev = 0u64;
        let mut bucket_lines = 0;
        for line in out.lines().filter(|l| l.starts_with("t_seconds_bucket")) {
            let v: u64 = line.split_whitespace().nth(1).unwrap().parse().unwrap();
            assert!(v >= prev, "non-monotone: {line}");
            prev = v;
            bucket_lines += 1;
        }
        assert_eq!(bucket_lines, LOG2_BUCKETS);
        assert!(out.contains("le=\"+Inf\"} 5"));
        assert!(out.contains("t_seconds_count 5"));
        assert!(out.contains("# TYPE t_seconds histogram"));
    }

    #[test]
    fn registry_renders_families_with_headers() {
        let r = Registry::new();
        let c = r.counter("t_total", "a counter");
        let w0 = r.counter_labeled("t_worker_total", "per-worker", "worker=\"0\"".into());
        let w1 = r.counter_labeled("t_worker_total", "per-worker", "worker=\"1\"".into());
        let g = r.gauge("t_depth", "a gauge");
        c.add(3);
        w0.inc();
        w1.add(2);
        g.set(7);
        let mut out = String::new();
        r.render(&mut out);
        assert!(out.contains("# HELP t_total a counter\n# TYPE t_total counter\nt_total 3\n"));
        assert!(out.contains("t_worker_total{worker=\"0\"} 1\n"));
        assert!(out.contains("t_worker_total{worker=\"1\"} 2\n"));
        // One header per family even with several labeled samples.
        assert_eq!(out.matches("# TYPE t_worker_total").count(), 1);
        assert!(out.contains("t_depth 7\n"));
    }
}
