//! Observability: lock-free metrics and request-lifecycle tracing.
//!
//! This module is the single sink for everything the server measures
//! about itself:
//!
//! * [`registry`] — relaxed-atomic [`Counter`]s, saturating [`Gauge`]s,
//!   and sharded 64-bucket log2 latency [`Histogram`]s, grouped into a
//!   [`Registry`] that renders the conformant Prometheus exposition
//!   (`# HELP`/`# TYPE`, cumulative `_bucket{le=...}`/`_sum`/`_count`)
//!   behind the `METRICS` wire command. Serving, transport, admission,
//!   reload, and trainer-epoch metrics all flow through these
//!   primitives; nothing on a hot path takes a lock.
//! * [`trace`] — [`Span`]/[`Stage`] timelines stamped through the nine
//!   request pipeline stages (accept → … → write), sampled every Nth
//!   request plus an always-on slow-request ring, dumped over the wire
//!   by the `TRACE` command as JSON lines.
//!
//! The metric catalog, span stages, and knobs are documented in
//! `docs/OBSERVABILITY.md`.

pub mod registry;
pub mod trace;

pub use registry::{render_counter, render_gauge, render_histogram};
pub use registry::{Counter, Gauge, Histogram, HistogramSnapshot, Registry};
pub use trace::{Span, SpanState, Stage, TraceRecord, Tracer, N_STAGES, TRACE_RING_CAP};
