//! Path ↔ label bijection (paper §4: "each label ℓ is exclusively assigned
//! to a path s(ℓ)").
//!
//! A path is encoded by its state choices `z_1 … z_k` (one bit per visited
//! step) plus whether it exits early. Canonical label indexing:
//!
//! * **Full paths** (all `b` steps → auxiliary → sink): index
//!   `Σ_j z_j · 2^(j−1)` ∈ `[0, 2^b)`.
//! * **Early-exit paths** at step `k = i+1` (exit bit `i`, requires
//!   `z_k = 1`): index `base_i + Σ_{j<k} z_j · 2^(j−1)`, where the bases
//!   pack exit groups after `2^b` in ascending-bit order.
//!
//! Note this canonical index is the *path id*; the mapping from dataset
//! labels to path ids is learned online by [`crate::assign`].

use super::trellis::Trellis;

/// A decoded path through the trellis.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Path {
    /// State choice per visited step (length `k ≤ b`).
    pub states: Vec<u8>,
    /// `Some(bit)` if the path exits early via the exit edge for `bit`
    /// (then `states.len() == bit + 1`), `None` for full paths.
    pub exit_bit: Option<u32>,
}

impl Path {
    /// Number of trellis steps this path visits.
    pub fn len(&self) -> usize {
        self.states.len()
    }

    pub fn is_empty(&self) -> bool {
        self.states.is_empty()
    }

    /// Edge indices of this path, in source→sink order.
    pub fn edges(&self, t: &Trellis) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.states.len() + 2);
        out.push(t.source_edge(self.states[0]));
        for j in 2..=self.states.len() as u32 {
            out.push(t.transition_edge(j, self.states[j as usize - 2], self.states[j as usize - 1]));
        }
        match self.exit_bit {
            Some(bit) => {
                debug_assert_eq!(self.states.len() as u32, bit + 1);
                debug_assert_eq!(*self.states.last().unwrap(), 1);
                out.push(t.exit_edge(t.exit_rank(bit).expect("bit is an exit bit")));
            }
            None => {
                debug_assert_eq!(self.states.len() as u32, t.steps);
                out.push(t.aux_edge(self.states[t.steps as usize - 1]));
                out.push(t.aux_sink_edge());
            }
        }
        out
    }

    /// Dense {0,1}^E indicator (a row of the decompression matrix `M_G`).
    pub fn indicator(&self, t: &Trellis) -> Vec<f32> {
        let mut row = vec![0.0; t.num_edges()];
        for e in self.edges(t) {
            row[e as usize] = 1.0;
        }
        row
    }
}

/// Encode: canonical label index of a path.
pub fn label_of_path(t: &Trellis, p: &Path) -> u64 {
    let mut bits = 0u64;
    match p.exit_bit {
        None => {
            debug_assert_eq!(p.states.len() as u32, t.steps);
            for (j, &z) in p.states.iter().enumerate() {
                bits |= (z as u64) << j;
            }
            bits
        }
        Some(bit) => {
            let k = t.exit_rank(bit).expect("bit is an exit bit");
            debug_assert_eq!(p.states.len() as u32, bit + 1);
            debug_assert_eq!(*p.states.last().unwrap(), 1, "exit requires state 1");
            for (j, &z) in p.states.iter().take(bit as usize).enumerate() {
                bits |= (z as u64) << j;
            }
            t.exit_label_base(k) + bits
        }
    }
}

/// Decode: path of a canonical label index `l ∈ [0, C)`.
pub fn path_of_label(t: &Trellis, l: u64) -> Path {
    debug_assert!(l < t.c, "label {l} out of range C={}", t.c);
    let full = 1u64 << t.steps;
    if l < full {
        let states = (0..t.steps).map(|j| ((l >> j) & 1) as u8).collect();
        return Path { states, exit_bit: None };
    }
    let mut r = l - full;
    for (k, &bit) in t.exit_bits().iter().enumerate() {
        let cnt = t.exit_path_count(k);
        if r < cnt {
            let mut states: Vec<u8> = (0..bit).map(|j| ((r >> j) & 1) as u8).collect();
            states.push(1); // exit edges leave state 1
            return Path { states, exit_bit: Some(bit) };
        }
        r -= cnt;
    }
    unreachable!("label {l} not covered; C={}", t.c)
}

/// Edge indices for a label — the `O(log C)` scoring primitive of §5.
pub fn edges_of_label(t: &Trellis, l: u64) -> Vec<u32> {
    path_of_label(t, l).edges(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn all_paths(t: &Trellis) -> Vec<Path> {
        // Enumerate every source→sink path by walking the edge structure.
        let mut out = Vec::new();
        let b = t.steps;
        // Full paths: all 2^b state sequences.
        for code in 0..(1u64 << b) {
            let states: Vec<u8> = (0..b).map(|j| ((code >> j) & 1) as u8).collect();
            out.push(Path { states, exit_bit: None });
        }
        // Early exits: prefix choices ending at state 1 of step bit+1.
        for &bit in t.exit_bits() {
            for code in 0..(1u64 << bit) {
                let mut states: Vec<u8> = (0..bit).map(|j| ((code >> j) & 1) as u8).collect();
                states.push(1);
                out.push(Path { states, exit_bit: Some(bit) });
            }
        }
        out
    }

    /// label_of_path ∘ path_of_label = id on [0, C) for many C.
    #[test]
    fn codec_roundtrip_exhaustive() {
        for c in (2u64..130).chain([159, 256, 1000, 1024, 3956]) {
            let t = Trellis::new(c);
            for l in 0..c {
                let p = path_of_label(&t, l);
                assert_eq!(label_of_path(&t, &p), l, "C={c} l={l}");
            }
        }
    }

    /// Every enumerated path maps to a distinct label in [0, C).
    #[test]
    fn paths_biject_labels() {
        for c in [2u64, 3, 22, 105, 159, 1000] {
            let t = Trellis::new(c);
            let paths = all_paths(&t);
            assert_eq!(paths.len() as u64, c, "C={c}");
            let mut seen = vec![false; c as usize];
            for p in &paths {
                let l = label_of_path(&t, p);
                assert!(l < c);
                assert!(!seen[l as usize], "duplicate label {l} (C={c})");
                seen[l as usize] = true;
            }
        }
    }

    /// Path edges are valid, connected source→sink walks.
    #[test]
    fn path_edges_form_connected_walk() {
        for c in [22u64, 105, 1000, 12294] {
            let t = Trellis::new(c);
            let mut rng = Rng::new(c);
            for _ in 0..200 {
                let l = rng.below(c);
                let edges = edges_of_label(&t, l);
                let elist = t.edges();
                assert_eq!(elist[edges[0] as usize].from, 0, "starts at source");
                for w in edges.windows(2) {
                    assert_eq!(
                        elist[w[0] as usize].to,
                        elist[w[1] as usize].from,
                        "C={c} l={l} disconnected"
                    );
                }
                let last = elist[*edges.last().unwrap() as usize];
                assert_eq!(last.to as usize, t.num_vertices() - 1, "ends at sink");
            }
        }
    }

    /// Path length: full paths have b+2 edges, exit at bit i has i+2 edges.
    #[test]
    fn path_edge_counts() {
        let t = Trellis::new(22); // b=4, exits at bits 1,2
        for l in 0..22u64 {
            let p = path_of_label(&t, l);
            let ne = p.edges(&t).len();
            match p.exit_bit {
                None => assert_eq!(ne, 4 + 2),
                Some(bit) => assert_eq!(ne as u32, bit + 2),
            }
        }
    }

    /// The indicator rows are exactly the M_G rows: distinct per label.
    #[test]
    fn indicators_distinct() {
        let t = Trellis::new(105);
        let mut rows: Vec<Vec<f32>> = (0..105).map(|l| path_of_label(&t, l).indicator(&t)).collect();
        let before = rows.len();
        rows.sort_by(|a, b| a.partial_cmp(b).unwrap());
        rows.dedup();
        assert_eq!(rows.len(), before);
    }

    /// Row sums of M_G equal path edge counts (≤ b+2).
    #[test]
    fn indicator_row_sums() {
        let t = Trellis::new(1000);
        for l in (0..1000).step_by(37) {
            let p = path_of_label(&t, l);
            let row = p.indicator(&t);
            let sum: f32 = row.iter().sum();
            assert_eq!(sum as usize, p.edges(&t).len());
            assert!(sum as u32 <= t.steps + 2);
        }
    }
}
