//! The decompression matrix `M_G ∈ {0,1}^{C×E}` (paper §4): all paths
//! stacked. Materializing it is `O(C·E)` — only used for small-C tests,
//! oracle decoding in unit tests, and the naive "decode by matmul"
//! reference that the log-time decoders are validated against.

use super::topology::Topology;

/// Dense path matrix with row-major storage.
pub struct PathMatrix {
    pub c: usize,
    pub e: usize,
    data: Vec<f32>,
}

impl PathMatrix {
    /// Materialize `M_G` for any topology (width-2 or wide). `O(C·E)`
    /// memory — test scale only.
    pub fn materialize<T: Topology>(t: &T) -> Self {
        let (c, e) = (Topology::c(t) as usize, t.num_edges());
        let mut data = vec![0.0f32; c * e];
        let mut edges = Vec::new();
        for l in 0..c {
            t.edges_of_label_into(l as u64, &mut edges);
            for &edge in &edges {
                data[l * e + edge as usize] = 1.0;
            }
        }
        PathMatrix { c, e, data }
    }

    /// Row for label `l`.
    pub fn row(&self, l: usize) -> &[f32] {
        &self.data[l * self.e..(l + 1) * self.e]
    }

    /// Dense decode `f = M_G · h`: score of every label. `O(C·E)` — this is
    /// exactly what LTLS avoids at prediction time; kept as the oracle.
    pub fn decode(&self, h: &[f32]) -> Vec<f32> {
        assert_eq!(h.len(), self.e);
        (0..self.c)
            .map(|l| self.row(l).iter().zip(h).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// Oracle top-k labels by full enumeration (descending score, ties by
    /// smaller label id — the same order the decoders must produce).
    pub fn topk(&self, h: &[f32], k: usize) -> Vec<(u64, f32)> {
        let scores = self.decode(h);
        let mut idx: Vec<usize> = (0..self.c).collect();
        idx.sort_by(|&a, &b| {
            scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b))
        });
        idx.into_iter().take(k).map(|l| (l as u64, scores[l])).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn matrix_shape_and_row_sums() {
        let t = Trellis::new(22);
        let m = PathMatrix::materialize(&t);
        assert_eq!(m.c, 22);
        assert_eq!(m.e, t.num_edges());
        for l in 0..22 {
            let s: f32 = m.row(l).iter().sum();
            assert!(s >= 2.0 && s <= (t.steps + 2) as f32);
        }
    }

    #[test]
    fn decode_equals_per_label_scoring() {
        let t = Trellis::new(105);
        let m = PathMatrix::materialize(&t);
        let mut rng = Rng::new(9);
        let h: Vec<f32> = (0..m.e).map(|_| rng.normal()).collect();
        let f = m.decode(&h);
        for l in (0..105u64).step_by(7) {
            let direct: f32 = super::super::codec::edges_of_label(&t, l)
                .iter()
                .map(|&e| h[e as usize])
                .sum();
            assert!((f[l as usize] - direct).abs() < 1e-5);
        }
    }

    #[test]
    fn topk_is_sorted_and_distinct() {
        let t = Trellis::new(159);
        let m = PathMatrix::materialize(&t);
        let mut rng = Rng::new(10);
        let h: Vec<f32> = (0..m.e).map(|_| rng.normal()).collect();
        let top = m.topk(&h, 10);
        assert_eq!(top.len(), 10);
        for w in top.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
        let mut labels: Vec<u64> = top.iter().map(|x| x.0).collect();
        labels.dedup();
        assert_eq!(labels.len(), 10);
    }
}
