//! Render a trellis as Graphviz DOT and as a terminal ASCII sketch —
//! reproduces the paper's Figure 1 (graph for C=22) and the Figure 2
//! update-trace visualization (positive/negative path edges). Generic over
//! [`Topology`], so wide (W-LTLS) graphs render too — reachable from the
//! binary via `ltls graph --dot [--width W]`.

use super::topology::Topology;
use super::trellis::EdgeKind;

/// Graphviz DOT of the trellis. Optional highlighted paths: (label, color).
pub fn to_dot<T: Topology>(t: &T, highlights: &[(u64, &str)]) -> String {
    let mut s = String::new();
    s.push_str("digraph ltls {\n  rankdir=LR;\n  node [shape=circle];\n");
    let name = |v: u32| format!("v{v}");
    // Color map edge->color from highlighted paths (later wins).
    let mut color = vec![None; t.num_edges()];
    for (l, c) in highlights {
        for e in t.edges_of_label(*l) {
            color[e as usize] = Some(*c);
        }
    }
    for e in t.edge_list() {
        let attr = match color[e.index as usize] {
            Some(c) => format!(" [label=\"e{}\", color={c}, penwidth=2]", e.index),
            None => format!(" [label=\"e{}\"]", e.index),
        };
        s.push_str(&format!("  {} -> {}{};\n", name(e.from), name(e.to), attr));
    }
    s.push_str("}\n");
    s
}

/// Compact ASCII rendering of the trellis structure (one line per layer).
pub fn to_ascii<T: Topology>(t: &T) -> String {
    let w = t.width();
    let mut s = String::new();
    s.push_str(&format!(
        "LTLS trellis: C={} W={} steps={} edges={} vertices={}\n",
        Topology::c(t),
        w,
        t.steps(),
        t.num_edges(),
        t.num_vertices()
    ));
    s.push_str("  source v0\n");
    for j in 1..=t.steps() {
        let v0 = 1 + w * (j - 1);
        let states = if w <= 8 {
            (0..w).map(|i| format!("v{}", v0 + i)).collect::<Vec<_>>().join(" ")
        } else {
            format!("v{}..v{}", v0, v0 + w - 1)
        };
        let exit = match t.exit_groups().iter().find(|g| g.step == j) {
            Some(g) if g.digit == 1 => "  [state1 -> sink]".to_string(),
            Some(g) => format!("  [states 1..={} -> sink]", g.digit),
            None => String::new(),
        };
        s.push_str(&format!("  step {j}: {states}{exit}\n"));
    }
    let copies = match t.n_aux_sinks() {
        1 => String::new(),
        m => format!(" ({m} parallel edges)"),
    };
    s.push_str(&format!(
        "  aux v{} -> sink v{}{}\n",
        1 + w * t.steps(),
        2 + w * t.steps(),
        copies
    ));
    s
}

/// Figure-2 style update trace: which edges get positive / negative /
/// no updates for a (positive path, negative path) pair — the symmetric
/// difference logic of §5.
pub fn update_trace<T: Topology>(t: &T, pos_label: u64, neg_label: u64) -> String {
    let pos = t.edges_of_label(pos_label);
    let neg = t.edges_of_label(neg_label);
    let mut s = format!("positive path (label {pos_label}): edges {pos:?}\n");
    s.push_str(&format!("negative path (label {neg_label}): edges {neg:?}\n"));
    let only_pos: Vec<_> = pos.iter().filter(|e| !neg.contains(e)).collect();
    let only_neg: Vec<_> = neg.iter().filter(|e| !pos.contains(e)).collect();
    let shared: Vec<_> = pos.iter().filter(|e| neg.contains(e)).collect();
    s.push_str(&format!("positive update (+x): {only_pos:?}\n"));
    s.push_str(&format!("negative update (−x): {only_neg:?}\n"));
    s.push_str(&format!("untouched (shared):   {shared:?}\n"));
    s
}

/// Edge-kind label for diagnostics.
pub fn kind_name(k: &EdgeKind) -> &'static str {
    match k {
        EdgeKind::Source { .. } => "source",
        EdgeKind::Transition { .. } => "transition",
        EdgeKind::Aux { .. } => "aux",
        EdgeKind::AuxSink => "aux_sink",
        EdgeKind::EarlyExit { .. } => "early_exit",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Trellis, WideTrellis};

    #[test]
    fn dot_contains_all_edges() {
        let t = Trellis::new(22);
        let dot = to_dot(&t, &[(0, "green"), (21, "red")]);
        assert!(dot.starts_with("digraph"));
        for e in t.edges() {
            assert!(dot.contains(&format!("e{}", e.index)));
        }
        assert!(dot.contains("green") && dot.contains("red"));
    }

    #[test]
    fn ascii_mentions_structure() {
        let t = Trellis::new(22);
        let a = to_ascii(&t);
        assert!(a.contains("C=22"));
        assert!(a.contains("step 4"));
        assert!(a.contains("v9") && a.contains("v10"));
    }

    #[test]
    fn update_trace_partitions_edges() {
        let t = Trellis::new(22);
        let tr = update_trace(&t, 3, 17);
        assert!(tr.contains("positive update"));
        assert!(tr.contains("negative update"));
    }

    /// Wide graphs render: every edge appears in the DOT, the ASCII names
    /// the width and multi-state exits.
    #[test]
    fn wide_graph_renders() {
        let t = WideTrellis::new(1000, 4).unwrap();
        let dot = to_dot(&t, &[(0, "green"), (999, "red")]);
        for e in t.edge_list() {
            assert!(dot.contains(&format!("e{}", e.index)));
        }
        let a = to_ascii(&t);
        assert!(a.contains("C=1000"), "{a}");
        assert!(a.contains("W=4"), "{a}");
        let tr = update_trace(&t, 1, 998);
        assert!(tr.contains("positive update"));
    }
}
