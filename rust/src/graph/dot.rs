//! Render a trellis as Graphviz DOT and as a terminal ASCII sketch —
//! reproduces the paper's Figure 1 (graph for C=22) and the Figure 2
//! update-trace visualization (positive/negative path edges).

use super::codec::path_of_label;
use super::trellis::{EdgeKind, Trellis};

/// Graphviz DOT of the trellis. Optional highlighted paths: (label, color).
pub fn to_dot(t: &Trellis, highlights: &[(u64, &str)]) -> String {
    let mut s = String::new();
    s.push_str("digraph ltls {\n  rankdir=LR;\n  node [shape=circle];\n");
    let name = |v: u32| format!("v{v}");
    // Color map edge->color from highlighted paths (later wins).
    let mut color = vec![None; t.num_edges()];
    for (l, c) in highlights {
        for e in path_of_label(t, *l).edges(t) {
            color[e as usize] = Some(*c);
        }
    }
    for e in t.edges() {
        let attr = match color[e.index as usize] {
            Some(c) => format!(" [label=\"e{}\", color={c}, penwidth=2]", e.index),
            None => format!(" [label=\"e{}\"]", e.index),
        };
        s.push_str(&format!("  {} -> {}{};\n", name(e.from), name(e.to), attr));
    }
    s.push_str("}\n");
    s
}

/// Compact ASCII rendering of the trellis structure (one line per layer).
pub fn to_ascii(t: &Trellis) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "LTLS trellis: C={} steps={} edges={} vertices={}\n",
        t.c,
        t.steps,
        t.num_edges(),
        t.num_vertices()
    ));
    s.push_str("  source v0\n");
    for j in 1..=t.steps {
        let v0 = 1 + 2 * (j - 1);
        let exit = t
            .exit_bits()
            .iter()
            .any(|&bit| bit + 1 == j)
            .then(|| "  [state1 -> sink]")
            .unwrap_or("");
        s.push_str(&format!("  step {j}: v{} v{}{}\n", v0, v0 + 1, exit));
    }
    s.push_str(&format!("  aux v{} -> sink v{}\n", 1 + 2 * t.steps, 2 + 2 * t.steps));
    s
}

/// Figure-2 style update trace: which edges get positive / negative /
/// no updates for a (positive path, negative path) pair — the symmetric
/// difference logic of §5.
pub fn update_trace(t: &Trellis, pos_label: u64, neg_label: u64) -> String {
    let pos = path_of_label(t, pos_label).edges(t);
    let neg = path_of_label(t, neg_label).edges(t);
    let mut s = format!("positive path (label {pos_label}): edges {pos:?}\n");
    s.push_str(&format!("negative path (label {neg_label}): edges {neg:?}\n"));
    let only_pos: Vec<_> = pos.iter().filter(|e| !neg.contains(e)).collect();
    let only_neg: Vec<_> = neg.iter().filter(|e| !pos.contains(e)).collect();
    let shared: Vec<_> = pos.iter().filter(|e| neg.contains(e)).collect();
    s.push_str(&format!("positive update (+x): {only_pos:?}\n"));
    s.push_str(&format!("negative update (−x): {only_neg:?}\n"));
    s.push_str(&format!("untouched (shared):   {shared:?}\n"));
    s
}

/// Edge-kind label for diagnostics.
pub fn kind_name(k: &EdgeKind) -> &'static str {
    match k {
        EdgeKind::Source { .. } => "source",
        EdgeKind::Transition { .. } => "transition",
        EdgeKind::Aux { .. } => "aux",
        EdgeKind::AuxSink => "aux_sink",
        EdgeKind::EarlyExit { .. } => "early_exit",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_contains_all_edges() {
        let t = Trellis::new(22);
        let dot = to_dot(&t, &[(0, "green"), (21, "red")]);
        assert!(dot.starts_with("digraph"));
        for e in t.edges() {
            assert!(dot.contains(&format!("e{}", e.index)));
        }
        assert!(dot.contains("green") && dot.contains("red"));
    }

    #[test]
    fn ascii_mentions_structure() {
        let t = Trellis::new(22);
        let a = to_ascii(&t);
        assert!(a.contains("C=22"));
        assert!(a.contains("step 4"));
        assert!(a.contains("v9") && a.contains("v10"));
    }

    #[test]
    fn update_trace_partitions_edges() {
        let t = Trellis::new(22);
        let tr = update_trace(&t, 3, 17);
        assert!(tr.contains("positive update"));
        assert!(tr.contains("negative update"));
    }
}
