//! Label-space sharding: partition a topology's labels into `N` disjoint
//! shards so that each shard can score and decode **exactly** its share of
//! the label space while reusing the unmodified decoders.
//!
//! ## Why masking terminal edges is exact
//!
//! The list-Viterbi decoders build per-state k-best *prefix* lists from
//! source and transition edges only; terminal edges — the exit edge of an
//! [`ExitGroup`] state and the `aux(s)`/`aux_sink(m)` pair of a full path —
//! are added once, at emission. Setting a terminal edge's score to `−∞`
//! therefore removes precisely the labels routed through it, without
//! perturbing any surviving path's score: prefix scores (and their
//! tie-breaks) are computed over body edges, identically on every shard.
//! A shard that owns a subset of terminal edges produces the global top-k
//! *restricted to its labels*, bit-identical to the single-process model,
//! so merging per-shard top-k lists reconstructs the global answer.
//!
//! ## Ownership units
//!
//! The finest ownership grain is one terminal edge:
//!
//! * **Full units** — one per last-step state `s < W`, discriminated by
//!   `aux(s)`, covering the `n_aux_sinks · W^(b−1)` full-path labels whose
//!   final state is `s` (the `aux_sink` edges are shared across all full
//!   units and stay body edges);
//! * **Exit units** — one per early-exit edge, i.e. per
//!   (group, state `s ∈ 1..=digit`), discriminated by
//!   `edge_base + (s−1)`, covering that state's `paths_per_state` labels.
//!
//! Units are enumerated in a canonical order (full units by state, then
//! exit units in ascending-group, ascending-state order) and assigned to
//! shards **contiguously**, greedily balanced by label count. The plan is
//! a pure function of `(topology, n_shards)` — every process that builds
//! it agrees on the partition.

use super::topology::Topology;

/// One indivisible ownership unit: a terminal edge and the labels routed
/// through it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardUnit {
    /// The terminal edge whose score is `−∞` on every shard that does not
    /// own this unit (`aux(s)` for full units, an exit edge otherwise).
    pub discriminator: u32,
    /// Number of canonical labels routed through this unit.
    pub labels: u64,
}

/// A deterministic, contiguous, label-balanced assignment of ownership
/// units to `n_shards` shards.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    n_shards: u32,
    num_edges: usize,
    units: Vec<ShardUnit>,
    /// `assignment[i]` = shard owning `units[i]`; non-decreasing.
    assignment: Vec<u32>,
}

impl ShardPlan {
    /// Build the plan for `t` over `n_shards` shards. Errors when
    /// `n_shards` is zero or exceeds the number of ownership units (the
    /// finest partition the topology supports).
    pub fn new<T: Topology>(t: &T, n_shards: u32) -> Result<ShardPlan, String> {
        if n_shards == 0 {
            return Err("--shards must be at least 1".into());
        }
        let w = t.width();
        let mut units = Vec::new();
        // Full units: W^b full paths split by last-step state. `steps ≥ 1`
        // and the W states partition them evenly.
        let per_state = t.full_label_count() / w as u64;
        for s in 0..w {
            units.push(ShardUnit { discriminator: t.aux(s), labels: per_state });
        }
        // Exit units: one per exit edge, ascending groups then states.
        for g in t.exit_groups() {
            for s in 1..=g.digit {
                units.push(ShardUnit {
                    discriminator: g.edge_base + (s - 1),
                    labels: g.paths_per_state,
                });
            }
        }
        if n_shards as usize > units.len() {
            return Err(format!(
                "--shards {n_shards} exceeds the {} ownership units of this topology \
                 (C={}, width={w}); use at most {} shards",
                units.len(),
                t.c(),
                units.len()
            ));
        }

        // Greedy contiguous split balanced by label count. `must` keeps a
        // unit for every remaining shard; `want` advances once the current
        // shard reached its proportional share of the label space.
        let total: u64 = units.iter().map(|u| u.labels).sum();
        debug_assert_eq!(total, t.c());
        let mut assignment = Vec::with_capacity(units.len());
        let mut shard = 0u32;
        let mut cum = 0u64;
        for (i, u) in units.iter().enumerate() {
            if shard + 1 < n_shards && i > 0 {
                let units_left = units.len() - i;
                let must = units_left <= (n_shards - 1 - shard) as usize;
                let want = cum.saturating_mul(n_shards as u64)
                    >= total.saturating_mul(shard as u64 + 1);
                if must || want {
                    shard += 1;
                }
            }
            assignment.push(shard);
            cum += u.labels;
        }
        debug_assert_eq!(*assignment.last().unwrap(), n_shards - 1);

        Ok(ShardPlan { n_shards, num_edges: t.num_edges(), units, assignment })
    }

    /// Number of shards this plan partitions the label space into.
    pub fn n_shards(&self) -> u32 {
        self.n_shards
    }

    /// Number of ownership units (= the maximum shard count).
    pub fn n_units(&self) -> usize {
        self.units.len()
    }

    /// The ownership units in canonical order.
    pub fn units(&self) -> &[ShardUnit] {
        &self.units
    }

    /// Shard owning unit `i`.
    pub fn shard_of_unit(&self, i: usize) -> u32 {
        self.assignment[i]
    }

    /// Ascending edge indices `shard` owns: every body edge plus the
    /// discriminators of its own units — i.e. all edges except foreign
    /// discriminators.
    pub fn owned_edges(&self, shard: u32) -> Vec<u32> {
        assert!(shard < self.n_shards, "shard {shard} out of range");
        let mut owned = vec![true; self.num_edges];
        for (u, &s) in self.units.iter().zip(&self.assignment) {
            if s != shard {
                owned[u.discriminator as usize] = false;
            }
        }
        (0..self.num_edges as u32).filter(|&e| owned[e as usize]).collect()
    }

    /// Number of canonical labels `shard` owns.
    pub fn owned_label_count(&self, shard: u32) -> u64 {
        assert!(shard < self.n_shards, "shard {shard} out of range");
        self.units
            .iter()
            .zip(&self.assignment)
            .filter(|&(_, &s)| s == shard)
            .map(|(u, _)| u.labels)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Trellis, WideTrellis};

    fn check_plan<T: Topology>(t: &T, n_shards: u32) {
        let plan = ShardPlan::new(t, n_shards).unwrap();
        assert_eq!(plan.n_shards(), n_shards);
        // Unit label counts partition [0, C).
        let total: u64 = plan.units().iter().map(|u| u.labels).sum();
        assert_eq!(total, t.c());
        // Assignment is contiguous, covers every shard, partitions C.
        assert!(plan.assignment.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(plan.assignment[0], 0);
        assert_eq!(*plan.assignment.last().unwrap(), n_shards - 1);
        let label_sum: u64 = (0..n_shards).map(|s| plan.owned_label_count(s)).sum();
        assert_eq!(label_sum, t.c());
        for s in 0..n_shards {
            assert!(plan.owned_label_count(s) > 0, "shard {s} owns no labels");
        }
        // Every discriminator is owned by exactly one shard; body edges by
        // all of them.
        let discs: std::collections::BTreeSet<u32> =
            plan.units().iter().map(|u| u.discriminator).collect();
        assert_eq!(discs.len(), plan.n_units(), "discriminators must be distinct");
        let mut owners = vec![0usize; t.num_edges()];
        for s in 0..n_shards {
            for e in plan.owned_edges(s) {
                owners[e as usize] += 1;
            }
        }
        for e in 0..t.num_edges() as u32 {
            let want = if discs.contains(&e) { 1 } else { n_shards as usize };
            assert_eq!(owners[e as usize], want, "edge {e} owner count");
        }
    }

    #[test]
    fn plans_partition_labels_and_edges() {
        for c in [22u64, 105, 159, 1000, 12294] {
            let t = Trellis::new(c);
            let max = ShardPlan::new(&t, 1).unwrap().n_units() as u32;
            for n in [1u32, 2, 3, 4, max] {
                check_plan(&t, n);
            }
        }
        for (c, w) in [(105u64, 4u32), (1000, 8), (730, 3), (4096, 16)] {
            let t = WideTrellis::new(c, w).unwrap();
            let max = ShardPlan::new(&t, 1).unwrap().n_units() as u32;
            for n in [1u32, 2, 4, max] {
                check_plan(&t, n);
            }
        }
    }

    /// The greedy split is label-balanced: no shard exceeds twice its
    /// proportional share plus the largest single unit.
    #[test]
    fn split_is_roughly_balanced() {
        let t = Trellis::new(12294);
        for n in [2u32, 3, 4] {
            let plan = ShardPlan::new(&t, n).unwrap();
            let largest = plan.units().iter().map(|u| u.labels).max().unwrap();
            let share = t.c / n as u64;
            for s in 0..n {
                assert!(
                    plan.owned_label_count(s) <= share + largest,
                    "shard {s}/{n} owns {} labels (share {share}, largest unit {largest})",
                    plan.owned_label_count(s)
                );
            }
        }
    }

    #[test]
    fn plan_is_deterministic() {
        let t = WideTrellis::new(3000, 4).unwrap();
        let a = ShardPlan::new(&t, 3).unwrap();
        let b = ShardPlan::new(&t, 3).unwrap();
        assert_eq!(a.assignment, b.assignment);
        assert_eq!(a.units, b.units);
    }

    #[test]
    fn rejects_invalid_shard_counts() {
        let t = Trellis::new(105);
        assert!(ShardPlan::new(&t, 0).is_err());
        let max = ShardPlan::new(&t, 1).unwrap().n_units() as u32;
        assert!(ShardPlan::new(&t, max).is_ok());
        assert!(ShardPlan::new(&t, max + 1).is_err());
    }
}
