//! The [`Topology`] trait: the structural interface shared by every
//! trellis-shaped graph the system can decode, train and serve over.
//!
//! LTLS fixes the trellis at 2 states per step; W-LTLS (Evron et al., 2018)
//! widens it to `W` states, trading a modest parameter increase for large
//! accuracy gains. Everything above graph construction — the dynamic-
//! programming decoders, the separation loss, the §5.1 assignment policy,
//! the serial and Hogwild trainers, model persistence and the prediction
//! server — only needs the *shape* of the graph, never its width. This
//! trait captures that shape:
//!
//! * mixed-radix layout (`width`, `steps`, aux-sink multiplicity, early-exit
//!   groups) with O(1) edge-index arithmetic, and
//! * the label ↔ edge-set codec (`edges_of_label`).
//!
//! Two implementations exist: the paper's width-2 [`Trellis`] (with its
//! hand-specialized register-based decoders — see
//! [`Topology::as_binary`]) and the width-parameterized
//! [`WideTrellis`](super::wide::WideTrellis), which runs on the generic
//! W-ary decoders in [`crate::decode::generic`]. `WideTrellis` at `W = 2`
//! is edge-for-edge and label-for-label identical to `Trellis` — pinned by
//! `rust/tests/wide_parity.rs`.

use super::trellis::{Edge, Trellis};

/// One early-exit group: for digit `d_i > 0` at mixed-radix position
/// `i = step − 1` of `C`, states `1..=d_i` of `step` each get a direct
/// edge to the sink, adding `d_i · W^i` paths.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExitGroup {
    /// The trellis step the exits leave (exits leave states `1..=digit`).
    pub step: u32,
    /// The mixed-radix digit `d_i` — how many exit states/edges this group
    /// has.
    pub digit: u32,
    /// Edge index of the exit leaving state `s` is `edge_base + (s − 1)`.
    pub edge_base: u32,
    /// First canonical label routed through this group. The exit at state
    /// `s` with prefix code `p ∈ [0, paths_per_state)` has label
    /// `label_base + (s − 1)·paths_per_state + p`.
    pub label_base: u64,
    /// Source→(step, state) path count: `W^(step−1)` prefix choices.
    pub paths_per_state: u64,
}

impl ExitGroup {
    /// Total paths routed through this group: `digit · paths_per_state`.
    #[inline]
    pub fn path_count(&self) -> u64 {
        self.digit as u64 * self.paths_per_state
    }
}

/// The structural interface of a trellis-shaped topology with `C`
/// source→sink paths: `steps` fully-connected layers of `width` states,
/// an auxiliary collector with `n_aux_sinks` parallel aux→sink edges
/// (carrying `n_aux_sinks · width^steps` "full" paths), and early-exit
/// groups for the lower mixed-radix digits of `C`.
///
/// Canonical label space: labels `[0, full_label_count())` are full paths
/// (`label = m·W^b + Σ_j z_j·W^(j−1)` for aux copy `m` and state choices
/// `z`); exit-group labels follow in ascending-step order.
pub trait Topology: Clone + Send + Sync + std::fmt::Debug + 'static {
    /// Build the topology for `c ≥ 2` classes at trellis width `width`.
    /// Implementations reject widths they cannot represent (the width-2
    /// [`Trellis`] errors on anything but 2; `WideTrellis` clamps
    /// `width > c` down to `c`).
    fn build(c: u64, width: u32) -> Result<Self, String>;

    /// Number of classes / source→sink paths.
    fn c(&self) -> u64;

    /// States per trellis step.
    fn width(&self) -> u32;

    /// Number of trellis steps `b = ⌊log_W C⌋`.
    fn steps(&self) -> u32;

    /// Number of learnable edges `E`.
    fn num_edges(&self) -> usize;

    /// Number of vertices (source + W·steps + auxiliary + sink).
    fn num_vertices(&self) -> usize {
        3 + self.width() as usize * self.steps() as usize
    }

    /// All edges in index order.
    fn edge_list(&self) -> &[Edge];

    /// Edge index: source → (step 1, state s).
    fn source(&self, s: u32) -> u32;

    /// Edge index: (step j−1, a) → (step j, t), for `2 ≤ j ≤ steps`.
    fn transition(&self, j: u32, a: u32, t: u32) -> u32;

    /// Edge index of `transition(j, a, 0)`, with the layout contract that
    /// predecessor `a`'s `width()` outgoing transition edges at step `j`
    /// are contiguous and target-ordered:
    /// `transition(j, a, t) == transition_row(j, a) + t` for all `t`.
    ///
    /// Both concrete topologies lay edges out this way (`Trellis`:
    /// `2 + 4(j−2) + 2a + t`; `WideTrellis`: `W + W²(j−2) + W·a + t`), and
    /// the vectorized Viterbi inner step ([`crate::kernel::viterbi_fold`])
    /// relies on it to sweep one predecessor's whole target row as a
    /// contiguous `&h[row..row + W]` slice. Implementations with a
    /// non-contiguous layout must not override this without also avoiding
    /// the row-sliced decoders; the generic decoder debug-asserts the
    /// contract on every row.
    #[inline]
    fn transition_row(&self, j: u32, a: u32) -> u32 {
        self.transition(j, a, 0)
    }

    /// Edge index: (step b, state s) → auxiliary.
    fn aux(&self, s: u32) -> u32;

    /// Number of parallel auxiliary→sink edges (`d_b`, the leading
    /// mixed-radix digit of C; 1 for the width-2 trellis).
    fn n_aux_sinks(&self) -> u32;

    /// Edge index of auxiliary→sink copy `m < n_aux_sinks()`.
    fn aux_sink(&self, m: u32) -> u32;

    /// Early-exit groups in ascending-step (= ascending label-base) order.
    fn exit_groups(&self) -> &[ExitGroup];

    /// Number of labels decoded through the auxiliary collector:
    /// `n_aux_sinks · width^steps`. Labels at or above this index route
    /// through an exit group.
    fn full_label_count(&self) -> u64;

    /// Edge indices of label `l`'s path, source→sink order, into `out`.
    fn edges_of_label_into(&self, label: u64, out: &mut Vec<u32>);

    /// Allocating wrapper over [`Self::edges_of_label_into`].
    fn edges_of_label(&self, label: u64) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.steps() as usize + 2);
        self.edges_of_label_into(label, &mut out);
        out
    }

    /// Learnable parameters for a linear edge model with `d` features
    /// (the paper's "model size `[M]`" accounting).
    fn linear_param_count(&self, d: usize) -> usize {
        self.num_edges() * d
    }

    /// Downcast to the canonical width-2 [`Trellis`], if that is what this
    /// topology is. The decoders use this to dispatch to the
    /// register-specialized width-2 kernels; every other topology runs the
    /// generic W-ary implementations in [`crate::decode::generic`].
    fn as_binary(&self) -> Option<&Trellis> {
        None
    }
}

impl Topology for Trellis {
    fn build(c: u64, width: u32) -> Result<Self, String> {
        if width != 2 {
            return Err(format!(
                "the width-2 Trellis cannot represent width {width}; use WideTrellis (--width)"
            ));
        }
        Trellis::try_new(c)
    }

    fn c(&self) -> u64 {
        self.c
    }

    fn width(&self) -> u32 {
        2
    }

    fn steps(&self) -> u32 {
        self.steps
    }

    fn num_edges(&self) -> usize {
        Trellis::num_edges(self)
    }

    fn num_vertices(&self) -> usize {
        Trellis::num_vertices(self)
    }

    fn edge_list(&self) -> &[Edge] {
        self.edges()
    }

    fn source(&self, s: u32) -> u32 {
        self.source_edge(s as u8)
    }

    fn transition(&self, j: u32, a: u32, t: u32) -> u32 {
        self.transition_edge(j, a as u8, t as u8)
    }

    fn aux(&self, s: u32) -> u32 {
        self.aux_edge(s as u8)
    }

    fn n_aux_sinks(&self) -> u32 {
        1
    }

    fn aux_sink(&self, _m: u32) -> u32 {
        self.aux_sink_edge()
    }

    fn exit_groups(&self) -> &[ExitGroup] {
        Trellis::exit_groups(self)
    }

    fn full_label_count(&self) -> u64 {
        1u64 << self.steps
    }

    /// Direct edge-index walk (no intermediate `Path`), bit-identical to
    /// [`super::codec::edges_of_label`] — the training hot loops call this
    /// through caller-owned scratch buffers, so it must not allocate.
    fn edges_of_label_into(&self, label: u64, out: &mut Vec<u32>) {
        debug_assert!(label < self.c, "label {label} out of range C={}", self.c);
        out.clear();
        let full = 1u64 << self.steps;
        if label < full {
            out.push(self.source_edge((label & 1) as u8));
            for j in 2..=self.steps {
                let a = ((label >> (j - 2)) & 1) as u8;
                let t = ((label >> (j - 1)) & 1) as u8;
                out.push(self.transition_edge(j, a, t));
            }
            out.push(self.aux_edge(((label >> (self.steps - 1)) & 1) as u8));
            out.push(self.aux_sink_edge());
            return;
        }
        let mut r = label - full;
        for (k, &bit) in self.exit_bits().iter().enumerate() {
            let cnt = 1u64 << bit;
            if r < cnt {
                // State bits: the free prefix `r` (bits < bit) with the
                // forced state 1 at step bit+1.
                let code = r | (1u64 << bit);
                out.push(self.source_edge((code & 1) as u8));
                for j in 2..=bit + 1 {
                    let a = ((code >> (j - 2)) & 1) as u8;
                    let t = ((code >> (j - 1)) & 1) as u8;
                    out.push(self.transition_edge(j, a, t));
                }
                out.push(self.exit_edge(k));
                return;
            }
            r -= cnt;
        }
        unreachable!("label {label} not covered; C={}", self.c)
    }

    fn as_binary(&self) -> Option<&Trellis> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The Trellis Topology view agrees with its inherent accessors.
    #[test]
    fn trellis_topology_view_is_consistent() {
        for c in [2u64, 3, 22, 105, 159, 1000, 12294] {
            let t = Trellis::new(c);
            assert_eq!(Topology::c(&t), c);
            assert_eq!(t.width(), 2);
            assert_eq!(Topology::steps(&t), t.steps);
            assert_eq!(Topology::num_edges(&t), t.edges().len());
            assert_eq!(t.n_aux_sinks(), 1);
            assert_eq!(t.aux_sink(0), t.aux_sink_edge());
            assert_eq!(t.full_label_count(), 1u64 << t.steps);
            for s in 0..2u32 {
                assert_eq!(t.source(s), t.source_edge(s as u8));
                assert_eq!(t.aux(s), t.aux_edge(s as u8));
            }
            for l in (0..c).step_by(1 + c as usize / 50) {
                assert_eq!(
                    Topology::edges_of_label(&t, l),
                    super::super::codec::edges_of_label(&t, l),
                    "C={c} l={l}"
                );
            }
        }
    }

    /// Exit groups mirror the exit-bit view: one group per set bit, digit 1,
    /// bases matching `exit_label_base`.
    #[test]
    fn trellis_exit_groups_match_exit_bits() {
        for c in [22u64, 105, 159, 3956, 12294] {
            let t = Trellis::new(c);
            let groups = Topology::exit_groups(&t);
            assert_eq!(groups.len(), t.exit_bits().len());
            for (k, (&bit, g)) in t.exit_bits().iter().zip(groups).enumerate() {
                assert_eq!(g.step, bit + 1);
                assert_eq!(g.digit, 1);
                assert_eq!(g.edge_base, t.exit_edge(k));
                assert_eq!(g.label_base, t.exit_label_base(k));
                assert_eq!(g.paths_per_state, 1u64 << bit);
                assert_eq!(g.path_count(), t.exit_path_count(k));
            }
        }
    }

    /// Exit-group label bases partition [full_label_count, C).
    #[test]
    fn exit_groups_partition_label_space() {
        for c in [22u64, 105, 159, 1000, 12294] {
            let t = Trellis::new(c);
            let mut next = t.full_label_count();
            for g in Topology::exit_groups(&t) {
                assert_eq!(g.label_base, next, "C={c}");
                next += g.path_count();
            }
            assert_eq!(next, c, "C={c}");
        }
    }

    /// Transition rows are contiguous and target-ordered:
    /// `transition(j, a, t) == transition_row(j, a) + t` (the layout
    /// contract the row-sliced Viterbi kernels rely on).
    #[test]
    fn transition_rows_are_contiguous() {
        for c in [4u64, 22, 105, 1000, 12294] {
            let t = Trellis::new(c);
            for j in 2..=Topology::steps(&t) {
                for a in 0..2u32 {
                    let row = t.transition_row(j, a);
                    for s in 0..2u32 {
                        assert_eq!(t.transition(j, a, s), row + s, "C={c} j={j} a={a}");
                    }
                }
            }
        }
    }

    /// build() enforces the width and the C floor as errors, not panics.
    #[test]
    fn build_validates() {
        assert!(<Trellis as Topology>::build(22, 2).is_ok());
        assert!(<Trellis as Topology>::build(22, 4).is_err());
        assert!(<Trellis as Topology>::build(1, 2).is_err());
    }
}
