//! Trellis construction: vertices, edges, step layout, and O(1) edge-index
//! arithmetic. See module docs in [`super`] for the topology.

/// What role an edge plays in the trellis.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EdgeKind {
    /// Source → (step 1, state s).
    Source { state: u8 },
    /// (step j−1, state a) → (step j, state b), `j ≥ 2`.
    Transition { step: u32, from: u8, to: u8 },
    /// (step b, state s) → auxiliary.
    Aux { state: u8 },
    /// Auxiliary → sink (carries the 2^b "full" paths).
    AuxSink,
    /// (step i+1, state 1) → sink for set bit `i < b` of C (2^i paths).
    EarlyExit { bit: u32 },
}

/// A trellis edge: endpoints are vertex ids, `kind` gives the structural
/// role, `index` is the position of its learnable scorer `h_e` in the
/// edge-score vector.
#[derive(Clone, Copy, Debug)]
pub struct Edge {
    pub index: u32,
    pub from: u32,
    pub to: u32,
    pub kind: EdgeKind,
}

/// The LTLS trellis for `C` classes.
///
/// Vertex numbering (matches the paper's Figure 1 for C=22):
/// source = 0; (step j, state s) = `1 + 2(j−1) + s` for `j ∈ 1..=b`;
/// auxiliary = `1 + 2b`; sink = `2 + 2b`.
#[derive(Clone, Debug)]
pub struct Trellis {
    /// Number of classes / paths.
    pub c: u64,
    /// Number of trellis steps, `⌊log₂ C⌋`.
    pub steps: u32,
    /// All edges in index order.
    edges: Vec<Edge>,
    /// Set bits of C below the msb, ascending — the early-exit bits.
    exit_bits: Vec<u32>,
    /// exit_edge_index[k] = edge index of the early exit for `exit_bits[k]`.
    exit_edge_base: u32,
    /// The exit structure in the width-generic [`super::topology::ExitGroup`]
    /// form (each bit is a digit-1 group).
    exit_groups: Vec<super::topology::ExitGroup>,
}

impl Trellis {
    /// Build the trellis for `c ≥ 2` classes. Panics on `c < 2`; callers
    /// that must not panic (the CLI) use [`Self::try_new`].
    pub fn new(c: u64) -> Self {
        Self::try_new(c).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Build the trellis for `c` classes, rejecting `c < 2` as an error.
    pub fn try_new(c: u64) -> Result<Self, String> {
        if c < 2 {
            return Err(format!("LTLS needs at least 2 classes, got {c}"));
        }
        let b = crate::util::floor_log2(c);
        let mut edges = Vec::new();
        let vsource = 0u32;
        let vstate = |j: u32, s: u8| 1 + 2 * (j - 1) + s as u32;
        let vaux = 1 + 2 * b;
        let vsink = 2 + 2 * b;

        // 2 source edges.
        for s in 0..2u8 {
            edges.push(Edge {
                index: edges.len() as u32,
                from: vsource,
                to: vstate(1, s),
                kind: EdgeKind::Source { state: s },
            });
        }
        // 4 transition edges per step gap, order (from, to) row-major.
        for j in 2..=b {
            for a in 0..2u8 {
                for t in 0..2u8 {
                    edges.push(Edge {
                        index: edges.len() as u32,
                        from: vstate(j - 1, a),
                        to: vstate(j, t),
                        kind: EdgeKind::Transition { step: j, from: a, to: t },
                    });
                }
            }
        }
        // 2 auxiliary-collector edges.
        for s in 0..2u8 {
            edges.push(Edge {
                index: edges.len() as u32,
                from: vstate(b, s),
                to: vaux,
                kind: EdgeKind::Aux { state: s },
            });
        }
        // Auxiliary → sink.
        edges.push(Edge { index: edges.len() as u32, from: vaux, to: vsink, kind: EdgeKind::AuxSink });
        // Early exits for set bits below the msb, ascending.
        let exit_edge_base = edges.len() as u32;
        let mut exit_bits = Vec::new();
        for i in 0..b {
            if (c >> i) & 1 == 1 {
                edges.push(Edge {
                    index: edges.len() as u32,
                    from: vstate(i + 1, 1),
                    to: vsink,
                    kind: EdgeKind::EarlyExit { bit: i },
                });
                exit_bits.push(i);
            }
        }
        let mut t = Trellis { c, steps: b, edges, exit_bits, exit_edge_base, exit_groups: Vec::new() };
        t.exit_groups = (0..t.exit_bits.len())
            .map(|k| super::topology::ExitGroup {
                step: t.exit_bits[k] + 1,
                digit: 1,
                edge_base: t.exit_edge(k),
                label_base: t.exit_label_base(k),
                paths_per_state: t.exit_path_count(k),
            })
            .collect();
        Ok(t)
    }

    /// Number of learnable edges `E = 4·⌊log₂C⌋ + popcount(C)`.
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Number of vertices (source + 2·steps + auxiliary + sink).
    #[inline]
    pub fn num_vertices(&self) -> usize {
        3 + 2 * self.steps as usize
    }

    /// All edges in index order.
    #[inline]
    pub fn edges(&self) -> &[Edge] {
        &self.edges
    }

    /// Set bits of C below the msb (ascending) — one early exit each.
    #[inline]
    pub fn exit_bits(&self) -> &[u32] {
        &self.exit_bits
    }

    /// The exit structure as width-generic digit-1 groups (the form the
    /// [`super::topology::Topology`] consumers use).
    #[inline]
    pub fn exit_groups(&self) -> &[super::topology::ExitGroup] {
        &self.exit_groups
    }

    // ---- O(1) edge-index arithmetic (the decoder hot path uses these; ----
    // ---- they are checked against the edge list in tests).            ----

    /// Edge index: source → (step 1, state s).
    #[inline]
    pub fn source_edge(&self, s: u8) -> u32 {
        s as u32
    }

    /// Edge index: (step j−1, a) → (step j, t), for `2 ≤ j ≤ steps`.
    #[inline]
    pub fn transition_edge(&self, j: u32, a: u8, t: u8) -> u32 {
        debug_assert!((2..=self.steps).contains(&j));
        2 + 4 * (j - 2) + 2 * a as u32 + t as u32
    }

    /// Edge index: (step b, state s) → auxiliary.
    #[inline]
    pub fn aux_edge(&self, s: u8) -> u32 {
        self.aux_edge_base() + s as u32
    }

    #[inline]
    fn aux_edge_base(&self) -> u32 {
        2 + 4 * (self.steps - 1)
    }

    /// Edge index: auxiliary → sink.
    #[inline]
    pub fn aux_sink_edge(&self) -> u32 {
        self.aux_edge_base() + 2
    }

    /// Edge index of the early exit at (step i+1, state 1) for exit-bit
    /// rank `k` (position of `i` in [`Self::exit_bits`]).
    #[inline]
    pub fn exit_edge(&self, k: usize) -> u32 {
        self.exit_edge_base + k as u32
    }

    /// Rank of `bit` in [`Self::exit_bits`], if it is an exit bit.
    pub fn exit_rank(&self, bit: u32) -> Option<usize> {
        self.exit_bits.binary_search(&bit).ok()
    }

    /// Paths entering the sink through the early exit with rank `k`: `2^bit`.
    #[inline]
    pub fn exit_path_count(&self, k: usize) -> u64 {
        1u64 << self.exit_bits[k]
    }

    /// First label index routed through exit rank `k` (labels `< 2^steps`
    /// are full-trellis paths; exits follow in ascending-bit order).
    pub fn exit_label_base(&self, k: usize) -> u64 {
        let mut base = 1u64 << self.steps;
        for kk in 0..k {
            base += self.exit_path_count(kk);
        }
        base
    }

    /// Model-size accounting: learnable parameters for a linear edge model
    /// with `d` features (paper's "model size `[M]`" columns).
    pub fn linear_param_count(&self, d: usize) -> usize {
        self.num_edges() * d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// E = 4·⌊log₂C⌋ + popcount(C) — and the paper's Table 3 edge counts.
    #[test]
    fn edge_count_formula_and_paper_values() {
        for c in 2u64..=4096 {
            let t = Trellis::new(c);
            let expect = 4 * crate::util::floor_log2(c) as usize + c.count_ones() as usize;
            assert_eq!(t.num_edges(), expect, "C={c}");
        }
        // Paper Table 3 "#edges" column:
        for (c, e) in [
            (105u64, 28usize),   // sector
            (1000, 42),          // aloi.bin, imageNet
            (12294, 56),         // LSHTC1
            (11947, 61),         // Dmoz
            (159, 34),           // bibtex
            (3956, 52),          // Eur-Lex
        ] {
            assert_eq!(Trellis::new(c).num_edges(), e, "C={c}");
        }
    }

    /// Paper's upper bound: E ≤ 5⌈log₂C⌉ + 1.
    #[test]
    fn edge_count_upper_bound() {
        for c in 2u64..=10_000 {
            let t = Trellis::new(c);
            assert!(t.num_edges() <= 5 * crate::util::ceil_log2(c) as usize + 1, "C={c}");
        }
    }

    /// Figure 1: C=22 has source v0, steps v1..v8 (4 steps), aux v9, sink v10.
    #[test]
    fn figure1_layout_c22() {
        let t = Trellis::new(22);
        assert_eq!(t.steps, 4);
        assert_eq!(t.num_vertices(), 11);
        // 22 = 10110₂ → early exits at bits 1 and 2 → steps 2 and 3.
        assert_eq!(t.exit_bits(), &[1, 2]);
        let exits: Vec<_> = t
            .edges()
            .iter()
            .filter_map(|e| match e.kind {
                EdgeKind::EarlyExit { bit } => Some((bit, e.from, e.to)),
                _ => None,
            })
            .collect();
        assert_eq!(exits.len(), 2);
        // exit at bit 1 leaves (step 2, state 1) = vertex 1 + 2*1 + 1 = 4
        assert_eq!(exits[0], (1, 4, 10));
        // exit at bit 2 leaves (step 3, state 1) = vertex 1 + 2*2 + 1 = 6
        assert_eq!(exits[1], (2, 6, 10));
    }

    /// Edge-index arithmetic matches the edge list for many C.
    #[test]
    fn edge_index_arithmetic_consistent() {
        for c in [2u64, 3, 4, 7, 22, 105, 159, 1000, 12294] {
            let t = Trellis::new(c);
            for e in t.edges() {
                let computed = match e.kind {
                    EdgeKind::Source { state } => t.source_edge(state),
                    EdgeKind::Transition { step, from, to } => t.transition_edge(step, from, to),
                    EdgeKind::Aux { state } => t.aux_edge(state),
                    EdgeKind::AuxSink => t.aux_sink_edge(),
                    EdgeKind::EarlyExit { bit } => t.exit_edge(t.exit_rank(bit).unwrap()),
                };
                assert_eq!(computed, e.index, "C={c} kind={:?}", e.kind);
            }
        }
    }

    /// The number of source→sink paths is exactly C (DP path count).
    #[test]
    fn path_count_is_c() {
        for c in (2u64..200).chain([255, 256, 257, 1000, 1024, 12294]) {
            let t = Trellis::new(c);
            // Count paths by DP over vertices in topological (id) order.
            let mut count = vec![0u64; t.num_vertices()];
            count[0] = 1;
            for e in t.edges() {
                let add = count[e.from as usize];
                count[e.to as usize] += add;
            }
            assert_eq!(count[t.num_vertices() - 1], c, "C={c}");
        }
    }

    /// Power-of-two C has no early exits.
    #[test]
    fn power_of_two_has_no_exits() {
        for b in 1..16 {
            let t = Trellis::new(1 << b);
            assert!(t.exit_bits().is_empty());
            assert_eq!(t.num_edges(), 4 * b as usize + 1);
        }
    }

    /// Exit label bases partition the label range [2^b, C).
    #[test]
    fn exit_label_bases_partition() {
        for c in [22u64, 105, 159, 3956, 12294] {
            let t = Trellis::new(c);
            let mut next = 1u64 << t.steps;
            for k in 0..t.exit_bits().len() {
                assert_eq!(t.exit_label_base(k), next);
                next += t.exit_path_count(k);
            }
            assert_eq!(next, c, "C={c}");
        }
    }

    #[test]
    #[should_panic]
    fn c_below_two_panics() {
        Trellis::new(1);
    }

    /// try_new reports the same condition as a proper error (the CLI path).
    #[test]
    fn try_new_rejects_c_below_two() {
        for c in [0u64, 1] {
            let err = Trellis::try_new(c).unwrap_err();
            assert!(err.contains("at least 2 classes"), "{err}");
        }
        assert!(Trellis::try_new(2).is_ok());
    }

    /// Edges are topologically ordered (from-vertex < to-vertex in id order
    /// works because vertex ids increase along every path).
    #[test]
    fn edges_are_topologically_ordered() {
        for c in [22u64, 1000, 12294] {
            let t = Trellis::new(c);
            for e in t.edges() {
                assert!(e.from < e.to, "edge {e:?}");
            }
            // And edge indices respect from-vertex order (needed by the
            // one-pass Viterbi the paper describes in §3).
            for w in t.edges().windows(2) {
                assert!(w[0].from <= w[1].from || matches!(w[1].kind, EdgeKind::EarlyExit { .. }));
            }
        }
    }
}
