//! The LTLS trellis graph (paper §3–§4).
//!
//! A directed acyclic graph with exactly `C` source→sink paths and
//! `E = 4·⌊log₂C⌋ + popcount(C)` edges:
//!
//! * `b = ⌊log₂ C⌋` trellis *steps*, each with 2 states;
//! * the source connects to both states of step 1 (2 edges);
//! * consecutive steps are completely connected (4 edges per gap);
//! * both states of step `b` connect to an *auxiliary* vertex (2 edges),
//!   and the auxiliary connects to the sink (1 edge) — this subgraph
//!   carries exactly `2^b` paths;
//! * for every set bit `i < b` of `C`, state 1 of step `i+1` gets a direct
//!   *early-exit* edge to the sink, adding exactly `2^i` paths.
//!
//! Since `C = 2^b + Σ_{i<b, bit i set} 2^i`, the path count is exactly `C`.
//! This reproduces the paper's edge counts precisely: sector (C=105) → 28,
//! aloi/imagenet (C=1000) → 42, LSHTC1 (C=12294) → 56, Dmoz (C=11947) → 61,
//! bibtex (C=159) → 34, Eur-Lex (C=3956) → 52 (paper Table 3).
//!
//! The width-2 trellis is one point on an accuracy/size curve: the
//! [`topology::Topology`] trait abstracts the graph shape, and
//! [`wide::WideTrellis`] generalizes the construction to `W` states per
//! step (W-LTLS), with `W = 2` reproducing [`Trellis`] exactly.

pub mod codec;
pub mod dot;
pub mod pathmat;
pub mod shardmap;
pub mod topology;
pub mod trellis;
pub mod wide;

pub use codec::Path;
pub use shardmap::{ShardPlan, ShardUnit};
pub use topology::{ExitGroup, Topology};
pub use trellis::{Edge, EdgeKind, Trellis};
pub use wide::{WidePath, WideTrellis};
