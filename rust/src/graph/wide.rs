//! The width-parameterized W-LTLS trellis (Evron et al., 2018: *Efficient
//! Loss-Based Decoding on Graphs for Extreme Classification*).
//!
//! Generalizes the paper's width-2 construction to `W` states per step by
//! writing `C` in mixed radix `W`:
//!
//! ```text
//! C = d_b·W^b + Σ_{i<b} d_i·W^i,   b = ⌊log_W C⌋, 1 ≤ d_b ≤ W−1
//! ```
//!
//! * `b` trellis *steps* of `W` states; the source connects to all states
//!   of step 1 (`W` edges) and consecutive steps are completely connected
//!   (`W²` edges per gap);
//! * every state of step `b` connects to an auxiliary vertex (`W` edges),
//!   and the auxiliary connects to the sink through `d_b` **parallel**
//!   edges — this subgraph carries exactly `d_b·W^b` paths;
//! * for every non-zero lower digit `d_i`, states `1..=d_i` of step `i+1`
//!   get a direct *early-exit* edge to the sink, adding `d_i·W^i` paths.
//!
//! Total: exactly `C` source→sink paths over
//! `E = 2W + (b−1)·W² + d_b + Σ_{i<b} d_i` learnable edges — the width
//! dial between the paper's `O(log C)` point (`W = 2`, where this
//! construction is edge-for-edge identical to [`Trellis`] — pinned by
//! `rust/tests/wide_parity.rs`) and flat one-vs-all (`W = C`).

use super::topology::{ExitGroup, Topology};
use super::trellis::{Edge, EdgeKind};

/// Maximum supported trellis width (states are stored as `u8` in
/// [`EdgeKind`]; realistic W-LTLS widths are ≤ 64).
pub const MAX_WIDTH: u32 = 256;

/// A W-state-per-step trellis with exactly `c` source→sink paths.
#[derive(Clone, Debug)]
pub struct WideTrellis {
    c: u64,
    /// Effective width (the requested width clamped to `c`).
    width: u32,
    /// Number of steps `b = ⌊log_W C⌋ ≥ 1`.
    steps: u32,
    /// All edges in index order.
    edges: Vec<Edge>,
    /// Parallel aux→sink edges (`d_b`).
    n_aux_sinks: u32,
    /// Early-exit groups, ascending step.
    exit_groups: Vec<ExitGroup>,
    /// `W^b` — paths per aux-sink copy.
    paths_per_sink: u64,
    /// Edge index of the first aux-collector edge.
    aux_base: u32,
}

impl WideTrellis {
    /// Build the width-`w` trellis for `c ≥ 2` classes. `w` must be in
    /// `2..=MAX_WIDTH`; a width above `c` is clamped to `c` (callers that
    /// care warn — see the CLI).
    pub fn new(c: u64, w: u32) -> Result<Self, String> {
        if c < 2 {
            return Err(format!("LTLS needs at least 2 classes, got {c}"));
        }
        if w < 2 {
            return Err(format!("trellis width must be at least 2, got {w}"));
        }
        if w > MAX_WIDTH {
            return Err(format!("trellis width must be at most {MAX_WIDTH}, got {w}"));
        }
        let width = (w as u64).min(c) as u32;
        let wu = width as u64;

        // b = ⌊log_W c⌋ (≥ 1 since width ≤ c), and W^b without overflow.
        let mut steps = 1u32;
        let mut paths_per_sink = wu;
        while paths_per_sink <= c / wu {
            paths_per_sink *= wu;
            steps += 1;
        }
        let n_aux_sinks = (c / paths_per_sink) as u32; // d_b ∈ 1..=W−1
        let mut rem = c - n_aux_sinks as u64 * paths_per_sink;

        // Lower mixed-radix digits d_0..d_{b-1} of the remainder.
        let mut digits = vec![0u32; steps as usize];
        for d in digits.iter_mut() {
            *d = (rem % wu) as u32;
            rem /= wu;
        }
        debug_assert_eq!(rem, 0);

        let vsource = 0u32;
        let vstate = |j: u32, s: u32| 1 + width * (j - 1) + s;
        let vaux = 1 + width * steps;
        let vsink = 2 + width * steps;

        let mut edges = Vec::new();
        for s in 0..width {
            edges.push(Edge {
                index: edges.len() as u32,
                from: vsource,
                to: vstate(1, s),
                kind: EdgeKind::Source { state: s as u8 },
            });
        }
        for j in 2..=steps {
            for a in 0..width {
                for t in 0..width {
                    edges.push(Edge {
                        index: edges.len() as u32,
                        from: vstate(j - 1, a),
                        to: vstate(j, t),
                        kind: EdgeKind::Transition { step: j, from: a as u8, to: t as u8 },
                    });
                }
            }
        }
        let aux_base = edges.len() as u32;
        for s in 0..width {
            edges.push(Edge {
                index: edges.len() as u32,
                from: vstate(steps, s),
                to: vaux,
                kind: EdgeKind::Aux { state: s as u8 },
            });
        }
        for _m in 0..n_aux_sinks {
            edges.push(Edge { index: edges.len() as u32, from: vaux, to: vsink, kind: EdgeKind::AuxSink });
        }

        let mut exit_groups = Vec::new();
        let mut label_base = n_aux_sinks as u64 * paths_per_sink;
        let mut paths_per_state = 1u64;
        for (i, &d) in digits.iter().enumerate() {
            if d > 0 {
                let step = i as u32 + 1;
                let edge_base = edges.len() as u32;
                for s in 1..=d {
                    edges.push(Edge {
                        index: edges.len() as u32,
                        from: vstate(step, s),
                        to: vsink,
                        kind: EdgeKind::EarlyExit { bit: i as u32 },
                    });
                }
                exit_groups.push(ExitGroup {
                    step,
                    digit: d,
                    edge_base,
                    label_base,
                    paths_per_state,
                });
                label_base += d as u64 * paths_per_state;
            }
            paths_per_state *= wu;
        }
        debug_assert_eq!(label_base, c, "label groups must partition [0, C)");

        Ok(WideTrellis {
            c,
            width,
            steps,
            edges,
            n_aux_sinks,
            exit_groups,
            paths_per_sink,
            aux_base,
        })
    }

    /// Decode label `l` into its path: state choices + terminal.
    pub fn path_of_label(&self, l: u64) -> WidePath {
        debug_assert!(l < self.c, "label {l} out of range C={}", self.c);
        let wu = self.width as u64;
        let full = self.full_label_count();
        if l < full {
            let aux_copy = (l / self.paths_per_sink) as u32;
            let mut code = l % self.paths_per_sink;
            let states = (0..self.steps)
                .map(|_| {
                    let z = (code % wu) as u32;
                    code /= wu;
                    z
                })
                .collect();
            return WidePath { states, exit_step: None, aux_copy };
        }
        let mut r = l - full;
        for g in &self.exit_groups {
            let cap = g.path_count();
            if r < cap {
                let exit_state = 1 + (r / g.paths_per_state) as u32;
                let mut prefix = r % g.paths_per_state;
                let mut states: Vec<u32> = (1..g.step)
                    .map(|_| {
                        let z = (prefix % wu) as u32;
                        prefix /= wu;
                        z
                    })
                    .collect();
                states.push(exit_state);
                return WidePath { states, exit_step: Some(g.step), aux_copy: 0 };
            }
            r -= cap;
        }
        unreachable!("label {l} not covered; C={}", self.c)
    }

    /// Encode a path back into its canonical label (inverse of
    /// [`Self::path_of_label`]).
    pub fn label_of_path(&self, p: &WidePath) -> u64 {
        let wu = self.width as u64;
        match p.exit_step {
            None => {
                debug_assert_eq!(p.states.len() as u32, self.steps);
                let mut code = 0u64;
                for &z in p.states.iter().rev() {
                    code = code * wu + z as u64;
                }
                p.aux_copy as u64 * self.paths_per_sink + code
            }
            Some(step) => {
                debug_assert_eq!(p.states.len() as u32, step);
                let g = self
                    .exit_groups
                    .iter()
                    .find(|g| g.step == step)
                    .expect("step has an exit group");
                let s = *p.states.last().unwrap();
                debug_assert!(s >= 1 && s <= g.digit, "exit state {s} out of 1..={}", g.digit);
                let mut prefix = 0u64;
                for &z in p.states[..step as usize - 1].iter().rev() {
                    prefix = prefix * wu + z as u64;
                }
                g.label_base + (s as u64 - 1) * g.paths_per_state + prefix
            }
        }
    }
}

/// A decoded path through a [`WideTrellis`]: the state choice per visited
/// step plus which terminal it takes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WidePath {
    /// State per visited step (length `steps`, or `step` when exiting).
    pub states: Vec<u32>,
    /// `Some(step)` if the path leaves via the early exit at `step`
    /// (then `states.len() == step` and the last state is in
    /// `1..=digit`); `None` for full paths.
    pub exit_step: Option<u32>,
    /// Which parallel aux→sink edge a full path takes (0 when exiting).
    pub aux_copy: u32,
}

impl Topology for WideTrellis {
    fn build(c: u64, width: u32) -> Result<Self, String> {
        WideTrellis::new(c, width)
    }

    fn c(&self) -> u64 {
        self.c
    }

    fn width(&self) -> u32 {
        self.width
    }

    fn steps(&self) -> u32 {
        self.steps
    }

    fn num_edges(&self) -> usize {
        self.edges.len()
    }

    fn edge_list(&self) -> &[Edge] {
        &self.edges
    }

    #[inline]
    fn source(&self, s: u32) -> u32 {
        s
    }

    #[inline]
    fn transition(&self, j: u32, a: u32, t: u32) -> u32 {
        debug_assert!((2..=self.steps).contains(&j));
        self.width + self.width * self.width * (j - 2) + self.width * a + t
    }

    #[inline]
    fn aux(&self, s: u32) -> u32 {
        self.aux_base + s
    }

    #[inline]
    fn n_aux_sinks(&self) -> u32 {
        self.n_aux_sinks
    }

    #[inline]
    fn aux_sink(&self, m: u32) -> u32 {
        debug_assert!(m < self.n_aux_sinks);
        self.aux_base + self.width + m
    }

    fn exit_groups(&self) -> &[ExitGroup] {
        &self.exit_groups
    }

    #[inline]
    fn full_label_count(&self) -> u64 {
        self.n_aux_sinks as u64 * self.paths_per_sink
    }

    fn edges_of_label_into(&self, label: u64, out: &mut Vec<u32>) {
        out.clear();
        let p = self.path_of_label(label);
        out.push(self.source(p.states[0]));
        for j in 2..=p.states.len() as u32 {
            out.push(self.transition(j, p.states[j as usize - 2], p.states[j as usize - 1]));
        }
        match p.exit_step {
            Some(step) => {
                let g = self
                    .exit_groups
                    .iter()
                    .find(|g| g.step == step)
                    .expect("step has an exit group");
                out.push(g.edge_base + p.states[step as usize - 1] - 1);
            }
            None => {
                out.push(self.aux(p.states[self.steps as usize - 1]));
                out.push(self.aux_sink(p.aux_copy));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Trellis;

    /// Path counts: DP over the edge list sums to exactly C, for many (C, W).
    #[test]
    fn path_count_is_c() {
        for w in [2u32, 3, 4, 7, 8, 16, 30] {
            for c in [2u64, 3, 22, 105, 159, 255, 256, 257, 1000, 1024, 12294] {
                let t = WideTrellis::new(c, w).unwrap();
                let mut count = vec![0u64; t.num_vertices()];
                count[0] = 1;
                for e in t.edge_list() {
                    count[e.to as usize] += count[e.from as usize];
                }
                assert_eq!(count[t.num_vertices() - 1], c, "C={c} W={w}");
            }
        }
    }

    /// At W=2 the construction is edge-for-edge identical to `Trellis`
    /// (index, endpoints, and kind all match).
    #[test]
    fn width_two_matches_trellis_edges() {
        for c in [2u64, 3, 22, 105, 159, 1000, 1024, 12294] {
            let narrow = Trellis::new(c);
            let wide = WideTrellis::new(c, 2).unwrap();
            assert_eq!(wide.num_edges(), narrow.num_edges(), "C={c}");
            assert_eq!(Topology::num_vertices(&wide), narrow.num_vertices());
            for (a, b) in wide.edge_list().iter().zip(narrow.edges()) {
                assert_eq!((a.index, a.from, a.to), (b.index, b.from, b.to), "C={c}");
            }
            for l in 0..c.min(600) {
                assert_eq!(
                    Topology::edges_of_label(&wide, l),
                    super::super::codec::edges_of_label(&narrow, l),
                    "C={c} l={l}"
                );
            }
        }
    }

    /// Codec bijection: label → path → label is the identity on [0, C).
    #[test]
    fn codec_roundtrip_exhaustive() {
        for w in [2u32, 3, 4, 5, 8, 16] {
            for c in (2u64..80).chain([105, 256, 1000, 1024]) {
                let t = WideTrellis::new(c, w).unwrap();
                let mut seen = vec![false; c as usize];
                for l in 0..c {
                    let p = t.path_of_label(l);
                    assert_eq!(t.label_of_path(&p), l, "C={c} W={w}");
                    assert!(!seen[l as usize]);
                    seen[l as usize] = true;
                }
            }
        }
    }

    /// Every label's edge set is a connected source→sink walk.
    #[test]
    fn label_edges_form_connected_walk() {
        for (c, w) in [(22u64, 4u32), (105, 3), (1000, 8), (12294, 16), (4096, 4)] {
            let t = WideTrellis::new(c, w).unwrap();
            let elist = t.edge_list();
            for l in (0..c).step_by(1 + c as usize / 200) {
                let edges = Topology::edges_of_label(&t, l);
                assert_eq!(elist[edges[0] as usize].from, 0, "starts at source");
                for pair in edges.windows(2) {
                    assert_eq!(
                        elist[pair[0] as usize].to,
                        elist[pair[1] as usize].from,
                        "C={c} W={w} l={l} disconnected"
                    );
                }
                let last = elist[*edges.last().unwrap() as usize];
                assert_eq!(last.to as usize, t.num_vertices() - 1, "ends at sink");
            }
        }
    }

    /// Edge-index arithmetic matches the materialized edge list.
    #[test]
    fn edge_index_arithmetic_consistent() {
        for (c, w) in [(22u64, 2u32), (105, 4), (1000, 8), (3956, 3), (12294, 16)] {
            let t = WideTrellis::new(c, w).unwrap();
            let width = Topology::width(&t);
            for e in t.edge_list() {
                let computed = match e.kind {
                    EdgeKind::Source { state } => t.source(state as u32),
                    EdgeKind::Transition { step, from, to } => {
                        t.transition(step, from as u32, to as u32)
                    }
                    EdgeKind::Aux { state } => t.aux(state as u32),
                    EdgeKind::AuxSink => {
                        // Parallel copies share a kind; recover m from index.
                        let m = e.index - t.aux_sink(0);
                        t.aux_sink(m)
                    }
                    EdgeKind::EarlyExit { bit } => {
                        let g = t
                            .exit_groups()
                            .iter()
                            .find(|g| g.step == bit + 1)
                            .unwrap();
                        // Recover the exit state from the source vertex:
                        // (step, state s) = 1 + W·(step−1) + s.
                        let s = e.from - (1 + width as u32 * bit);
                        assert!(s >= 1 && s <= g.digit);
                        g.edge_base + s - 1
                    }
                };
                assert_eq!(computed, e.index, "C={c} W={w} kind={:?}", e.kind);
            }
            assert!(width >= 2);
        }
    }

    /// Edge-count formula: E = 2W + (b−1)W² + d_b + Σ d_i.
    #[test]
    fn edge_count_formula() {
        for w in [2u32, 3, 4, 8, 16] {
            for c in [5u64, 22, 105, 256, 1000, 12294] {
                let t = WideTrellis::new(c, w).unwrap();
                let width = Topology::width(&t) as usize;
                let b = Topology::steps(&t) as usize;
                let exits: usize = t.exit_groups().iter().map(|g| g.digit as usize).sum();
                let expect =
                    2 * width + (b - 1) * width * width + t.n_aux_sinks() as usize + exits;
                assert_eq!(t.num_edges(), expect, "C={c} W={w}");
            }
        }
    }

    /// Exact powers of W have zero early exits and one aux→sink edge.
    #[test]
    fn power_of_width_has_no_exits() {
        for w in [2u32, 4, 8, 16] {
            let mut c = w as u64;
            for _ in 0..4 {
                let t = WideTrellis::new(c, w).unwrap();
                assert!(t.exit_groups().is_empty(), "C={c} W={w}");
                assert_eq!(t.n_aux_sinks(), 1);
                assert_eq!(t.full_label_count(), c);
                c *= w as u64;
            }
        }
    }

    /// Width above C clamps to C: a 1-step fan-out with C paths.
    #[test]
    fn width_above_c_clamps() {
        let t = WideTrellis::new(10, 64).unwrap();
        assert_eq!(Topology::width(&t), 10);
        assert_eq!(Topology::steps(&t), 1);
        assert_eq!(t.n_aux_sinks(), 1);
        assert!(t.exit_groups().is_empty());
        assert_eq!(t.num_edges(), 21); // 10 source + 10 aux + 1 sink
    }

    /// Construction rejects bad parameters with errors, not panics.
    #[test]
    fn invalid_parameters_are_errors() {
        assert!(WideTrellis::new(1, 2).is_err());
        assert!(WideTrellis::new(100, 1).is_err());
        assert!(WideTrellis::new(100, 0).is_err());
        assert!(WideTrellis::new(100, MAX_WIDTH + 1).is_err());
        assert!(WideTrellis::new(100, MAX_WIDTH).is_ok());
    }

    /// Wider is (weakly) shallower and has more parameters on real sizes.
    #[test]
    fn width_trades_depth_for_parameters() {
        let c = 12294u64;
        let mut prev_edges = 0usize;
        let mut prev_steps = u32::MAX;
        for w in [2u32, 4, 8, 16] {
            let t = WideTrellis::new(c, w).unwrap();
            assert!(t.num_edges() > prev_edges, "W={w} edges {}", t.num_edges());
            assert!(Topology::steps(&t) <= prev_steps, "W={w}");
            prev_edges = t.num_edges();
            prev_steps = Topology::steps(&t);
        }
    }
}
