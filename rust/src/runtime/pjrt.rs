//! Thin typed wrapper over the PJRT client.
//!
//! The real backend (cargo feature `pjrt` **plus** an `xla` entry added to
//! rust/Cargo.toml `[dependencies]` — the crate is deliberately not
//! declared there, even optionally, because offline builds cannot resolve
//! it) follows the pattern from /opt/xla-example/load_hlo:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`. All
//! artifact programs were lowered with `return_tuple=True`, so outputs
//! always decompose as a tuple.
//!
//! The default (offline) build has neither `xla` nor `anyhow` vendored, so
//! it compiles a **stub backend** with the identical API: [`Engine::cpu`]
//! returns an error, and every caller that needs artifacts (the
//! integration suite, `ltls deep`, `examples/deep_imagenet.rs`) skips or
//! reports cleanly. [`Tensor`] is std-only and always available.

use std::path::Path;

/// Runtime result type (`anyhow` is not vendored in the offline build).
pub type RtResult<T> = Result<T, String>;

/// A host tensor: f32 or i32 data + shape. The minimal currency between
/// rust and the compiled programs.
#[derive(Clone, Debug)]
pub enum Tensor {
    F32 { data: Vec<f32>, shape: Vec<usize> },
    I32 { data: Vec<i32>, shape: Vec<usize> },
}

impl Tensor {
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Tensor {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        Tensor::F32 { data, shape: shape.to_vec() }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::F32 { data: vec![v], shape: vec![] }
    }

    pub fn as_f32(&self) -> RtResult<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => Err("tensor is not f32".to_string()),
        }
    }

    pub fn as_i32(&self) -> RtResult<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => Err("tensor is not i32".to_string()),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }
}

/// Real PJRT backend (requires the vendored `xla` crate).
#[cfg(feature = "pjrt")]
mod backend {
    use super::{RtResult, Tensor};
    use std::path::Path;

    impl Tensor {
        fn to_literal(&self) -> RtResult<xla::Literal> {
            match self {
                Tensor::F32 { data, shape } => {
                    let lit = xla::Literal::vec1(data);
                    if shape.is_empty() {
                        // rank-0: reshape to scalar
                        lit.reshape(&[]).map_err(|e| e.to_string())
                    } else {
                        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                        lit.reshape(&dims).map_err(|e| e.to_string())
                    }
                }
                Tensor::I32 { data, shape } => {
                    let lit = xla::Literal::vec1(data);
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    lit.reshape(&dims).map_err(|e| e.to_string())
                }
            }
        }

        fn from_literal(lit: &xla::Literal) -> RtResult<Tensor> {
            let shape: Vec<usize> = lit
                .array_shape()
                .map_err(|e| e.to_string())?
                .dims()
                .iter()
                .map(|&d| d as usize)
                .collect();
            match lit.ty().map_err(|e| e.to_string())? {
                xla::ElementType::F32 => Ok(Tensor::F32 {
                    data: lit.to_vec::<f32>().map_err(|e| e.to_string())?,
                    shape,
                }),
                xla::ElementType::S32 => Ok(Tensor::I32 {
                    data: lit.to_vec::<i32>().map_err(|e| e.to_string())?,
                    shape,
                }),
                other => Err(format!("unsupported output element type {other:?}")),
            }
        }
    }

    /// The PJRT engine: one CPU client shared by all executables.
    pub struct Engine {
        client: xla::PjRtClient,
    }

    impl Engine {
        /// Create the CPU PJRT client.
        pub fn cpu() -> RtResult<Engine> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| format!("creating PJRT CPU client: {e}"))?;
            Ok(Engine { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load an HLO-text artifact and compile it.
        pub fn load_hlo(&self, path: &Path) -> RtResult<Executable> {
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| format!("parsing HLO text {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| format!("compiling {}: {e}", path.display()))?;
            Ok(Executable { exe, name: path.display().to_string() })
        }
    }

    /// A compiled program.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        pub name: String,
    }

    impl Executable {
        /// Execute with host tensors; returns the decomposed output tuple.
        pub fn run(&self, inputs: &[Tensor]) -> RtResult<Vec<Tensor>> {
            let lits: Vec<xla::Literal> =
                inputs.iter().map(|t| t.to_literal()).collect::<RtResult<_>>()?;
            let out = self
                .exe
                .execute::<xla::Literal>(&lits)
                .map_err(|e| format!("executing {}: {e}", self.name))?;
            let result = out[0][0].to_literal_sync().map_err(|e| e.to_string())?;
            let parts = result.to_tuple().map_err(|e| e.to_string())?;
            parts.iter().map(Tensor::from_literal).collect()
        }
    }

    #[cfg(test)]
    mod tests {
        use super::super::Tensor;

        #[test]
        fn tensor_roundtrip_f32() {
            let t = Tensor::f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
            let lit = t.to_literal().unwrap();
            let back = Tensor::from_literal(&lit).unwrap();
            assert_eq!(back.shape(), &[2, 2]);
            assert_eq!(back.as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
        }

        #[test]
        fn scalar_tensor() {
            let t = Tensor::scalar_f32(0.5);
            assert!(t.shape().is_empty());
            let lit = t.to_literal().unwrap();
            assert_eq!(lit.to_vec::<f32>().unwrap(), vec![0.5]);
        }
    }
}

/// Stub backend: the same API surface as the real one, failing at
/// [`Engine::cpu`] with an actionable message. Keeps every caller of the
/// runtime compiling in the offline build without `xla`.
#[cfg(not(feature = "pjrt"))]
mod backend {
    use super::{RtResult, Tensor};
    use std::path::Path;

    const UNAVAILABLE: &str = "PJRT backend unavailable: this build has no `pjrt` feature. \
         To enable it, add the vendored `xla` crate to rust/Cargo.toml \
         [dependencies] (it is deliberately not declared — offline builds \
         cannot resolve it) and rebuild with `--features pjrt`";

    /// Stub engine (cannot be constructed; `cpu()` always errors).
    pub struct Engine {
        _private: (),
    }

    impl Engine {
        pub fn cpu() -> RtResult<Engine> {
            Err(UNAVAILABLE.to_string())
        }

        pub fn platform(&self) -> String {
            "pjrt-unavailable".to_string()
        }

        pub fn load_hlo(&self, path: &Path) -> RtResult<Executable> {
            Err(format!("{UNAVAILABLE} (loading {})", path.display()))
        }
    }

    /// Stub compiled program.
    pub struct Executable {
        pub name: String,
    }

    impl Executable {
        pub fn run(&self, _inputs: &[Tensor]) -> RtResult<Vec<Tensor>> {
            Err(format!("{UNAVAILABLE} (running {})", self.name))
        }
    }
}

pub use backend::{Engine, Executable};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn type_mismatch_errors() {
        let t = Tensor::f32(vec![1.0], &[1]);
        assert!(t.as_i32().is_err());
        assert!(t.as_f32().is_ok());
    }

    #[test]
    fn shape_accessors() {
        let t = Tensor::f32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        assert_eq!(t.shape(), &[2, 3]);
        assert!(Tensor::scalar_f32(0.5).shape().is_empty());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_engine_errors_actionably() {
        let err = Engine::cpu().err().unwrap();
        assert!(err.contains("pjrt"), "{err}");
    }
}
