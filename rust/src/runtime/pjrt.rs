//! Thin typed wrapper over the `xla` crate's PJRT client.
//!
//! Pattern (from /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. All artifact programs were lowered with
//! `return_tuple=True`, so outputs always decompose as a tuple.

use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// A host tensor: f32 or i32 data + shape. The minimal currency between
/// rust and the compiled programs.
#[derive(Clone, Debug)]
pub enum Tensor {
    F32 { data: Vec<f32>, shape: Vec<usize> },
    I32 { data: Vec<i32>, shape: Vec<usize> },
}

impl Tensor {
    pub fn f32(data: Vec<f32>, shape: &[usize]) -> Tensor {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        Tensor::F32 { data, shape: shape.to_vec() }
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::F32 { data: vec![v], shape: vec![] }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is not i32")),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        match self {
            Tensor::F32 { data, shape } => {
                let lit = xla::Literal::vec1(data);
                if shape.is_empty() {
                    // rank-0: reshape to scalar
                    Ok(lit.reshape(&[])?)
                } else {
                    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                    Ok(lit.reshape(&dims)?)
                }
            }
            Tensor::I32 { data, shape } => {
                let lit = xla::Literal::vec1(data);
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                Ok(lit.reshape(&dims)?)
            }
        }
    }

    fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape: Vec<usize> = lit.array_shape()?.dims().iter().map(|&d| d as usize).collect();
        match lit.ty()? {
            xla::ElementType::F32 => Ok(Tensor::F32 { data: lit.to_vec::<f32>()?, shape }),
            xla::ElementType::S32 => Ok(Tensor::I32 { data: lit.to_vec::<i32>()?, shape }),
            other => Err(anyhow!("unsupported output element type {other:?}")),
        }
    }
}

/// The PJRT engine: one CPU client shared by all executables.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Engine> {
        Ok(Engine { client: xla::PjRtClient::cpu().context("creating PJRT CPU client")? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load_hlo(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe, name: path.display().to_string() })
    }
}

/// A compiled program.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with host tensors; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let out = self
            .exe
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("executing {}", self.name))?;
        let result = out[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        parts.iter().map(Tensor::from_literal).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_roundtrip_f32() {
        let t = Tensor::f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(back.shape(), &[2, 2]);
        assert_eq!(back.as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn scalar_tensor() {
        let t = Tensor::scalar_f32(0.5);
        assert!(t.shape().is_empty());
        let lit = t.to_literal().unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![0.5]);
    }

    #[test]
    fn type_mismatch_errors() {
        let t = Tensor::f32(vec![1.0], &[1]);
        assert!(t.as_i32().is_err());
        assert!(t.as_f32().is_ok());
    }
}
