//! PJRT runtime: loads the AOT-compiled HLO artifacts emitted by
//! `python/compile/aot.py` and executes them from the rust request path.
//!
//! Python never runs at serving or training time — `make artifacts` lowers
//! the JAX/Pallas programs to HLO *text* once (see DESIGN.md §5 for why
//! text, not serialized protos), and [`Engine`] compiles them with the
//! local PJRT CPU client.
//!
//! Layout:
//! * [`artifacts`] — meta.json parsing + trellis-layout cross-check.
//! * [`pjrt`] — thin typed wrapper over the `xla` crate (load → compile →
//!   execute with f32/i32 tensors).
//! * [`deep`] — the deep LTLS model driver: parameter state, train steps,
//!   batched inference (the paper's §6 ImageNet experiment, from rust).

//! The PJRT client itself lives behind the `pjrt` cargo feature (the `xla`
//! crate is not vendored in the default offline build); without it a stub
//! backend with the same API compiles and `Engine::cpu()` errors, so the
//! sparse serving path and all tests stay fully functional.

pub mod artifacts;
pub mod deep;
pub mod pjrt;

pub use artifacts::ArtifactMeta;
pub use deep::DeepLtls;
pub use pjrt::{Engine, Executable, RtResult, Tensor};
