//! The deep LTLS variant driven from rust (paper §6: "we have used LTLS
//! with a deep network ... a network with 2 layers, 500 hidden units in
//! each, and ReLU nonlinearities").
//!
//! Holds the MLP parameters as host tensors, executes the AOT'd
//! `mlp_train_step` for SGD and `ltls_infer` / `mlp_fwd` for prediction.
//! Label ↔ path mapping for the deep variant is the identity (fixed at
//! lowering time).

use super::artifacts::ArtifactMeta;
use super::pjrt::{Engine, Executable, RtResult, Tensor};
use crate::data::Dataset;

/// Deep LTLS model state + compiled programs.
pub struct DeepLtls {
    pub meta: ArtifactMeta,
    params: Vec<Tensor>, // w1,b1,w2,b2,w3,b3
    train_step: Executable,
    infer: Executable,
    fwd: Executable,
    /// Path indicators per label, cached (C × E bitmap rows as f32).
    path_rows: Vec<Vec<f32>>,
}

impl DeepLtls {
    /// Load artifacts and the He-initialized parameters dumped by aot.py.
    pub fn load(engine: &Engine, meta: ArtifactMeta) -> RtResult<DeepLtls> {
        let mut params = Vec::new();
        for (name, shape) in meta.param_shapes() {
            let data = meta.init_param(name)?;
            if data.len() != shape.iter().product::<usize>() {
                return Err(format!("param {name}: {} elems, want {:?}", data.len(), shape));
            }
            params.push(Tensor::f32(data, &shape));
        }
        let train_step = engine.load_hlo(&meta.hlo_path("mlp_train_step"))?;
        let infer = engine.load_hlo(&meta.hlo_path("ltls_infer"))?;
        let fwd = engine.load_hlo(&meta.hlo_path("mlp_fwd"))?;
        let t = crate::graph::Trellis::new(meta.c as u64);
        let path_rows = (0..meta.c as u64)
            .map(|l| crate::graph::codec::path_of_label(&t, l).indicator(&t))
            .collect();
        Ok(DeepLtls { meta, params, train_step, infer, fwd, path_rows })
    }

    /// One SGD step on a batch (rows of `ds`); returns the loss.
    /// Short batches are padded by repeating rows (averaging over dupes is
    /// harmless for SGD).
    pub fn train_batch(&mut self, ds: &Dataset, rows: &[usize], lr: f32) -> RtResult<f32> {
        let b = self.meta.batch;
        let d = self.meta.d;
        let e = self.meta.e;
        let mut x = vec![0.0f32; b * d];
        let mut s = vec![0.0f32; b * e];
        for i in 0..b {
            let r = rows[i % rows.len()];
            let row = ds.row(r);
            for (&fi, &fv) in row.indices.iter().zip(row.values) {
                x[i * d + fi as usize] = fv;
            }
            let label = ds.labels_of(r)[0] as usize;
            for (j, &v) in self.path_rows[label].iter().enumerate() {
                s[i * e + j] = v;
            }
        }
        let mut inputs = self.params.clone();
        inputs.push(Tensor::f32(x, &[b, d]));
        inputs.push(Tensor::f32(s, &[b, e]));
        inputs.push(Tensor::scalar_f32(lr));
        let mut out = self.train_step.run(&inputs)?;
        let loss = out.pop().ok_or_else(|| "train_step returned nothing".to_string())?;
        self.params = out;
        Ok(loss.as_f32()?[0])
    }

    /// Batched top-1 prediction (pads the final short batch).
    pub fn predict(&self, ds: &Dataset, rows: &[usize]) -> RtResult<Vec<u32>> {
        let b = self.meta.batch;
        let d = self.meta.d;
        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(b) {
            let mut x = vec![0.0f32; b * d];
            for (i, &r) in chunk.iter().enumerate() {
                let row = ds.row(r);
                for (&fi, &fv) in row.indices.iter().zip(row.values) {
                    x[i * d + fi as usize] = fv;
                }
            }
            let mut inputs = self.params.clone();
            inputs.push(Tensor::f32(x, &[b, d]));
            let res = self.infer.run(&inputs)?;
            let labels = res[0].as_i32()?;
            out.extend(labels.iter().take(chunk.len()).map(|&l| l as u32));
        }
        Ok(out)
    }

    /// Raw edge scores for a dense batch (used by the coordinator's dense
    /// path and the runtime micro-benches).
    /// `rows` must equal the lowered batch size (`meta.batch`).
    pub fn edge_scores(&self, x: Vec<f32>, rows: usize) -> RtResult<Vec<f32>> {
        let d = self.meta.d;
        debug_assert_eq!(rows, self.meta.batch, "mlp_fwd is lowered for a fixed batch");
        debug_assert_eq!(x.len(), rows * d);
        let mut inputs = self.params.clone();
        inputs.push(Tensor::f32(x, &[rows, d]));
        let res = self.fwd.run(&inputs)?;
        Ok(res[0].as_f32()?.to_vec())
    }

    /// Precision@1 on a dataset (batched over the whole set).
    pub fn precision_at_1(&self, ds: &Dataset) -> RtResult<f64> {
        let rows: Vec<usize> = (0..ds.n_examples()).collect();
        let preds = self.predict(ds, &rows)?;
        let hits = preds
            .iter()
            .zip(rows.iter())
            .filter(|(p, &r)| ds.labels_of(r).contains(p))
            .count();
        Ok(hits as f64 / rows.len().max(1) as f64)
    }

    pub fn param_count(&self) -> usize {
        self.params.iter().map(|t| t.shape().iter().product::<usize>()).sum()
    }
}
