//! Artifact metadata: parses `artifacts/meta.json` and validates the
//! cross-language trellis-layout contract against the rust implementation.

use crate::graph::Trellis;
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Parsed meta.json.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub c: usize,
    pub d: usize,
    pub hidden: usize,
    pub batch: usize,
    pub e: usize,
    pub dir: PathBuf,
}

impl ArtifactMeta {
    /// Load and validate from an artifacts directory.
    pub fn load(dir: &Path) -> Result<ArtifactMeta, String> {
        let text = std::fs::read_to_string(dir.join("meta.json"))
            .map_err(|e| format!("{}/meta.json: {e} (run `make artifacts`)", dir.display()))?;
        let j = Json::parse(&text)?;
        let get = |k: &str| -> Result<usize, String> {
            j.get(k).and_then(|v| v.as_usize()).ok_or(format!("meta.json missing {k}"))
        };
        let meta = ArtifactMeta {
            c: get("c")?,
            d: get("d")?,
            hidden: get("hidden")?,
            batch: get("batch")?,
            e: get("e")?,
            dir: dir.to_path_buf(),
        };
        meta.validate_trellis(&j)?;
        Ok(meta)
    }

    /// The cross-language contract: python's trellis layout must equal the
    /// rust one (same E, steps, exit bits, aux-sink index).
    fn validate_trellis(&self, j: &Json) -> Result<(), String> {
        let t = Trellis::new(self.c as u64);
        if t.num_edges() != self.e {
            return Err(format!("E mismatch: rust {} vs meta {}", t.num_edges(), self.e));
        }
        let tj = j.get("trellis").ok_or("meta.json missing trellis")?;
        let steps = tj.get("steps").and_then(|v| v.as_usize()).ok_or("trellis.steps")?;
        if steps != t.steps as usize {
            return Err(format!("steps mismatch: rust {} vs meta {steps}", t.steps));
        }
        let exit_bits = tj
            .get("exit_bits")
            .and_then(|v| v.as_usize_arr())
            .ok_or("trellis.exit_bits")?;
        let rust_bits: Vec<usize> = t.exit_bits().iter().map(|&b| b as usize).collect();
        if exit_bits != rust_bits {
            return Err(format!("exit_bits mismatch: rust {rust_bits:?} vs meta {exit_bits:?}"));
        }
        let aux = tj.get("aux_sink_edge").and_then(|v| v.as_usize()).ok_or("aux_sink_edge")?;
        if aux != t.aux_sink_edge() as usize {
            return Err(format!("aux_sink mismatch: rust {} vs meta {aux}", t.aux_sink_edge()));
        }
        Ok(())
    }

    /// Path of an artifact HLO file.
    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// Read an init-params tensor dumped by aot.py (raw little-endian f32).
    pub fn init_param(&self, name: &str) -> Result<Vec<f32>, String> {
        let p = self.dir.join("init_params").join(format!("{name}.f32"));
        let bytes = std::fs::read(&p).map_err(|e| format!("{}: {e}", p.display()))?;
        if bytes.len() % 4 != 0 {
            return Err(format!("{}: length {} not divisible by 4", p.display(), bytes.len()));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Parameter shapes in artifact order (w1,b1,w2,b2,w3,b3).
    pub fn param_shapes(&self) -> Vec<(&'static str, Vec<usize>)> {
        vec![
            ("w1", vec![self.d, self.hidden]),
            ("b1", vec![self.hidden]),
            ("w2", vec![self.hidden, self.hidden]),
            ("b2", vec![self.hidden]),
            ("w3", vec![self.hidden, self.e]),
            ("b3", vec![self.e]),
        ]
    }
}

/// Locate the artifacts directory: $LTLS_ARTIFACTS or ./artifacts upward.
pub fn default_dir() -> PathBuf {
    if let Ok(p) = std::env::var("LTLS_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.join("meta.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_meta(dir: &Path, c: usize, tweak: impl Fn(&mut String)) {
        let t = Trellis::new(c as u64);
        let bits: Vec<String> = t.exit_bits().iter().map(|b| b.to_string()).collect();
        let mut s = format!(
            r#"{{"c": {c}, "d": 10, "hidden": 4, "batch": 2, "e": {e},
                "trellis": {{"c": {c}, "steps": {st}, "num_edges": {e},
                              "exit_bits": [{bits}], "aux_sink_edge": {aux}}}}}"#,
            e = t.num_edges(),
            st = t.steps,
            bits = bits.join(","),
            aux = t.aux_sink_edge(),
        );
        tweak(&mut s);
        std::fs::write(dir.join("meta.json"), s).unwrap();
    }

    #[test]
    fn loads_valid_meta_and_rejects_mismatch() {
        let dir = std::env::temp_dir().join("ltls_meta_test");
        std::fs::create_dir_all(&dir).unwrap();
        write_meta(&dir, 105, |_| {});
        let m = ArtifactMeta::load(&dir).unwrap();
        assert_eq!(m.c, 105);
        assert_eq!(m.e, 28);
        assert_eq!(m.param_shapes()[0].1, vec![10, 4]);

        // Corrupt the exit bits → must fail the contract.
        write_meta(&dir, 105, |s| {
            *s = s.replace("\"exit_bits\": [0,3,5]", "\"exit_bits\": [1,3,5]");
        });
        assert!(ArtifactMeta::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_errors_helpfully() {
        let err = ArtifactMeta::load(Path::new("/nonexistent/abc")).unwrap_err();
        assert!(err.contains("make artifacts"));
    }
}
