//! Minimal JSON: enough to read `artifacts/meta.json` written by
//! `python/compile/aot.py` and to emit structured reports. Not a general
//! JSON library (no escapes beyond the basics, no unicode surrogate pairs).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    /// Array of numbers as usize (errors collapsed to None).
    pub fn as_usize_arr(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Convenience: build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number {s:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.b.get(self.i + 1..self.i + 5).ok_or("bad \\u")?,
                            )
                            .map_err(|_| "bad \\u")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a UTF-8 run verbatim.
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"a": 1, "b": [1.5, -2, "x\ny"], "c": {"d": true, "e": null}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().get("d"), Some(&Json::Bool(true)));
        // dump → parse fixpoint
        let again = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn parse_numbers() {
        assert_eq!(Json::parse("3.25").unwrap().as_f64(), Some(3.25));
        assert_eq!(Json::parse("-7").unwrap().as_f64(), Some(-7.0));
        assert_eq!(Json::parse("1e3").unwrap().as_f64(), Some(1000.0));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("\"abc").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn usize_arr_helper() {
        let v = Json::parse("[1,2,3]").unwrap();
        assert_eq!(v.as_usize_arr(), Some(vec![1, 2, 3]));
        let bad = Json::parse("[1,\"x\"]").unwrap();
        assert_eq!(bad.as_usize_arr(), None);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""aA\t\"""#).unwrap();
        assert_eq!(v.as_str(), Some("aA\t\""));
        let d = Json::Str("q\"\\\n".into()).dump();
        assert_eq!(Json::parse(&d).unwrap().as_str(), Some("q\"\\\n"));
    }
}
