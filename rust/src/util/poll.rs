//! A thin shim over `poll(2)` and `pipe(2)` — the readiness substrate of
//! the event-loop network transport (`coordinator/event_loop.rs`).
//!
//! Mirrors the raw-syscall style of [`crate::model::mmap`]: the build is
//! fully offline and std-only, so instead of vendoring `libc`/`mio` the
//! handful of constants and `extern "C"` declarations the transport needs
//! are written out here for the targets we support. Everything is
//! unix-only (`#[cfg(unix)]`); on other platforms the event-loop
//! transport is unavailable and the serving frontend falls back to the
//! thread-per-connection transport (see `coordinator/transport.rs`).
//!
//! Two primitives are exported:
//!
//! * [`poll`] — readiness over a set of [`PollFd`]s with a millisecond
//!   timeout, the single blocking point of each poll thread.
//! * [`WakePipe`] — a nonblocking self-pipe used to interrupt a `poll`
//!   from another thread (worker-pool completion notifications, new
//!   connections handed to a poll thread, shutdown). Wakes are coalesced
//!   by the caller; the pipe itself just carries "something changed".

#![cfg(unix)]

use std::io;
use std::os::unix::io::RawFd;

/// Readable: data available (or EOF — a read returning 0 disambiguates).
pub const POLLIN: i16 = 0x001;
/// Writable without blocking.
pub const POLLOUT: i16 = 0x004;
/// Error condition (revents only).
pub const POLLERR: i16 = 0x008;
/// Peer hung up (revents only). Data may still be readable until EOF.
pub const POLLHUP: i16 = 0x010;
/// Invalid fd (revents only).
pub const POLLNVAL: i16 = 0x020;

/// `struct pollfd` — identical layout on every supported unix.
#[repr(C)]
#[derive(Clone, Copy, Debug)]
pub struct PollFd {
    pub fd: RawFd,
    pub events: i16,
    pub revents: i16,
}

impl PollFd {
    pub fn new(fd: RawFd, events: i16) -> PollFd {
        PollFd { fd, events, revents: 0 }
    }

    /// Any readable-ish readiness: data, error or hangup (the latter two
    /// are reported so the owner can read to EOF / collect the error).
    pub fn readable(&self) -> bool {
        self.revents & (POLLIN | POLLERR | POLLHUP | POLLNVAL) != 0
    }

    /// Writable readiness (errors included — a write collects them).
    pub fn writable(&self) -> bool {
        self.revents & (POLLOUT | POLLERR | POLLHUP | POLLNVAL) != 0
    }
}

#[cfg(all(unix, target_pointer_width = "64", not(target_os = "macos")))]
type NFds = u64; // nfds_t = unsigned long on linux
#[cfg(any(not(target_pointer_width = "64"), target_os = "macos"))]
type NFds = u32; // nfds_t = unsigned int on macOS / 32-bit

mod sys {
    use std::ffi::c_void;
    extern "C" {
        pub fn poll(fds: *mut super::PollFd, nfds: super::NFds, timeout: i32) -> i32;
        pub fn pipe(fds: *mut i32) -> i32;
        pub fn close(fd: i32) -> i32;
        pub fn read(fd: i32, buf: *mut c_void, count: usize) -> isize;
        pub fn write(fd: i32, buf: *const c_void, count: usize) -> isize;
        pub fn fcntl(fd: i32, cmd: i32, arg: i32) -> i32;
    }
    pub const F_GETFL: i32 = 3;
    pub const F_SETFL: i32 = 4;
    #[cfg(any(target_os = "macos", target_os = "ios", target_os = "freebsd"))]
    pub const O_NONBLOCK: i32 = 0x0004;
    #[cfg(not(any(target_os = "macos", target_os = "ios", target_os = "freebsd")))]
    pub const O_NONBLOCK: i32 = 0x0800;
}

/// Block until a registered fd is ready, the timeout elapses, or a signal
/// interrupts. Returns the number of entries with nonzero `revents`
/// (0 on timeout). `EINTR` is reported as `Ok(0)` — poll loops always
/// rescan their state on wake-up anyway, so a spurious zero is harmless.
pub fn poll(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
    let rc = unsafe { sys::poll(fds.as_mut_ptr(), fds.len() as NFds, timeout_ms) };
    if rc >= 0 {
        return Ok(rc as usize);
    }
    let err = io::Error::last_os_error();
    if err.kind() == io::ErrorKind::Interrupted {
        Ok(0)
    } else {
        Err(err)
    }
}

fn set_nonblocking(fd: RawFd) -> io::Result<()> {
    let flags = unsafe { sys::fcntl(fd, sys::F_GETFL, 0) };
    if flags < 0 {
        return Err(io::Error::last_os_error());
    }
    if unsafe { sys::fcntl(fd, sys::F_SETFL, flags | sys::O_NONBLOCK) } < 0 {
        return Err(io::Error::last_os_error());
    }
    Ok(())
}

/// A nonblocking self-pipe: `wake` from any thread makes the read end
/// `POLLIN`-ready; the poll loop `drain`s it and rescans its queues.
///
/// Both ends are nonblocking, so a full pipe never blocks a waker (the
/// pending byte already guarantees a wake-up — additional wakes coalesce)
/// and `drain` never blocks the poll thread.
#[derive(Debug)]
pub struct WakePipe {
    read_fd: RawFd,
    write_fd: RawFd,
}

impl WakePipe {
    pub fn new() -> io::Result<WakePipe> {
        let mut fds = [0i32; 2];
        if unsafe { sys::pipe(fds.as_mut_ptr()) } < 0 {
            return Err(io::Error::last_os_error());
        }
        let (r, w) = (fds[0], fds[1]);
        let pipe = WakePipe { read_fd: r, write_fd: w }; // closes on early-return drop
        set_nonblocking(r)?;
        set_nonblocking(w)?;
        Ok(pipe)
    }

    /// The fd to register with [`POLLIN`] in the poll set.
    pub fn poll_fd(&self) -> RawFd {
        self.read_fd
    }

    /// Make the read end readable. Never blocks: a full pipe (wake
    /// already pending) is success by definition.
    pub fn wake(&self) {
        let b = 1u8;
        unsafe { sys::write(self.write_fd, (&b as *const u8).cast(), 1) };
    }

    /// Consume every pending wake byte (until the pipe would block).
    pub fn drain(&self) {
        let mut buf = [0u8; 64];
        loop {
            let n = unsafe { sys::read(self.read_fd, buf.as_mut_ptr().cast(), buf.len()) };
            if n <= 0 {
                break;
            }
        }
    }
}

impl Drop for WakePipe {
    fn drop(&mut self) {
        unsafe {
            sys::close(self.read_fd);
            sys::close(self.write_fd);
        }
    }
}

// SAFETY: both fds stay valid for the pipe's lifetime and the kernel
// serializes pipe reads/writes; `wake` and `drain` are racing-safe by
// design (a lost race only means an extra or a coalesced wake).
unsafe impl Send for WakePipe {}
unsafe impl Sync for WakePipe {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wake_pipe_roundtrip_and_poll_readiness() {
        let p = WakePipe::new().unwrap();
        // Nothing pending: poll times out immediately.
        let mut fds = [PollFd::new(p.poll_fd(), POLLIN)];
        assert_eq!(poll(&mut fds, 0).unwrap(), 0);
        assert!(!fds[0].readable());
        // A wake makes the read end ready.
        p.wake();
        let mut fds = [PollFd::new(p.poll_fd(), POLLIN)];
        assert_eq!(poll(&mut fds, 1000).unwrap(), 1);
        assert!(fds[0].readable());
        // Drained: quiet again.
        p.drain();
        let mut fds = [PollFd::new(p.poll_fd(), POLLIN)];
        assert_eq!(poll(&mut fds, 0).unwrap(), 0);
    }

    #[test]
    fn wakes_coalesce_and_never_block() {
        let p = WakePipe::new().unwrap();
        // Far more wakes than the pipe buffer holds: all must return.
        for _ in 0..100_000 {
            p.wake();
        }
        let mut fds = [PollFd::new(p.poll_fd(), POLLIN)];
        assert_eq!(poll(&mut fds, 1000).unwrap(), 1);
        p.drain();
        let mut fds = [PollFd::new(p.poll_fd(), POLLIN)];
        assert_eq!(poll(&mut fds, 0).unwrap(), 0);
    }

    #[test]
    fn poll_reports_writable_sockets() {
        use std::net::{TcpListener, TcpStream};
        use std::os::unix::io::AsRawFd;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let stream = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let mut fds = [PollFd::new(stream.as_raw_fd(), POLLOUT)];
        assert_eq!(poll(&mut fds, 1000).unwrap(), 1);
        assert!(fds[0].writable());
        assert!(!fds[0].readable(), "nothing was sent yet");
    }

    #[test]
    fn wake_from_another_thread_interrupts_poll() {
        use std::sync::Arc;
        let p = Arc::new(WakePipe::new().unwrap());
        let waker = Arc::clone(&p);
        let t0 = std::time::Instant::now();
        let h = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(30));
            waker.wake();
        });
        let mut fds = [PollFd::new(p.poll_fd(), POLLIN)];
        let n = poll(&mut fds, 5_000).unwrap();
        h.join().unwrap();
        assert_eq!(n, 1);
        assert!(t0.elapsed() < std::time::Duration::from_secs(4), "poll waited out the timeout");
    }
}
