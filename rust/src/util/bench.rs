//! Micro-benchmark harness (criterion is not vendored in this offline
//! environment, so `cargo bench` targets use this instead with
//! `harness = false`).
//!
//! Reports mean / p50 / p99 wall time per iteration and derived throughput.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl BenchStats {
    /// Iterations per second implied by the mean.
    pub fn per_sec(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

/// Benchmark runner: warms up, then samples `f` until both a minimum
/// iteration count and a minimum measured duration are reached.
pub struct Bench {
    warmup: Duration,
    measure: Duration,
    min_iters: usize,
    results: Vec<BenchStats>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        // BENCH_FAST=1 trims times for smoke runs (used by `make bench-fast`).
        let fast = std::env::var("BENCH_FAST").map(|v| v == "1").unwrap_or(false);
        Bench {
            warmup: if fast { Duration::from_millis(50) } else { Duration::from_millis(300) },
            measure: if fast { Duration::from_millis(200) } else { Duration::from_secs(1) },
            min_iters: if fast { 5 } else { 20 },
            results: Vec::new(),
        }
    }

    /// Time `f`, which should perform one logical iteration and return a
    /// value (kept opaque to the optimizer via `std::hint::black_box`).
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> BenchStats {
        // Warmup.
        let t0 = Instant::now();
        while t0.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // Measure.
        let mut samples_ns: Vec<f64> = Vec::new();
        let t1 = Instant::now();
        while t1.elapsed() < self.measure || samples_ns.len() < self.min_iters {
            let s = Instant::now();
            std::hint::black_box(f());
            samples_ns.push(s.elapsed().as_nanos() as f64);
            if samples_ns.len() >= 1_000_000 {
                break;
            }
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples_ns.len();
        let stats = BenchStats {
            name: name.to_string(),
            iters: n,
            mean_ns: samples_ns.iter().sum::<f64>() / n as f64,
            p50_ns: samples_ns[n / 2],
            p99_ns: samples_ns[((n as f64 * 0.99) as usize).min(n - 1)],
            min_ns: samples_ns[0],
        };
        println!(
            "{:<48} {:>12} {:>12} {:>12} {:>14}",
            stats.name,
            fmt_ns(stats.mean_ns),
            fmt_ns(stats.p50_ns),
            fmt_ns(stats.p99_ns),
            format!("{:.0}/s", stats.per_sec()),
        );
        self.results.push(stats.clone());
        stats
    }

    /// Print the header row for the table `run` emits.
    pub fn header(title: &str) {
        println!("\n== {title} ==");
        println!(
            "{:<48} {:>12} {:>12} {:>12} {:>14}",
            "case", "mean", "p50", "p99", "throughput"
        );
    }

    /// All collected results.
    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }
}

/// Human-format a duration in ns.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        std::env::set_var("BENCH_FAST", "1");
        let mut b = Bench::new();
        let s = b.run("noop-ish", || 1 + 1);
        assert!(s.iters >= 5);
        assert!(s.mean_ns > 0.0);
        assert!(s.p50_ns <= s.p99_ns);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(1500.0), "1.50us");
        assert_eq!(fmt_ns(2.5e6), "2.50ms");
        assert_eq!(fmt_ns(3.0e9), "3.00s");
    }
}
