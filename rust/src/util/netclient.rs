//! A small pipelined newline-protocol TCP client with deadline support.
//!
//! The serving frontend (`coordinator/transport.rs`) speaks a line
//! protocol: one JSON request per line in, one JSON reply per line out,
//! replies in request order on each connection. Both the network tests
//! and the scatter-gather coordinator (`coordinator/scatter.rs`) need the
//! same client shape — connect, pipeline several lines, read replies back
//! in order, never hang forever — so it lives here instead of being
//! re-implemented ad hoc per call site.
//!
//! Design notes:
//!
//! * The stream stays in **blocking** mode; deadlines are enforced by
//!   setting `SO_RCVTIMEO`/`SO_SNDTIMEO` to the remaining time before
//!   every read/write. This keeps the client portable (no raw fds needed
//!   for the common path) while still guaranteeing an upper bound on
//!   every call.
//! * Received bytes accumulate in an internal buffer and are handed out
//!   line by line, so pipelining N requests then reading N replies works
//!   even when the server coalesces replies into one TCP segment.
//! * For multiplexed use the coordinator polls the [`raw_fd`] of several
//!   clients at once (via [`crate::util::poll`]) and calls [`fill_ready`]
//!   on whichever is readable — a blocking read after `POLLIN` cannot
//!   block, so the event loop stays responsive without `O_NONBLOCK`
//!   state juggling.
//!
//! [`raw_fd`]: NetClient::raw_fd
//! [`fill_ready`]: NetClient::fill_ready

use std::io::{self, Read, Write};
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

/// Upper bound on a single protocol line (request or reply), matching the
/// server's own line cap. A peer that streams more than this without a
/// newline is broken or hostile; fail the read instead of buffering
/// without bound.
pub const MAX_LINE: usize = 1 << 20;

/// Compact the drained prefix away once it crosses this many bytes.
const COMPACT_AT: usize = 64 * 1024;

/// A pipelined line-protocol client over one TCP connection.
#[derive(Debug)]
pub struct NetClient {
    stream: TcpStream,
    /// Received-but-undelivered bytes; `[start..]` is live.
    buf: Vec<u8>,
    start: usize,
}

/// Remaining time before `deadline`, or a `TimedOut` error if it passed.
fn remaining(deadline: Instant) -> io::Result<Duration> {
    let now = Instant::now();
    if now >= deadline {
        return Err(io::Error::new(io::ErrorKind::TimedOut, "deadline elapsed"));
    }
    // `set_read_timeout(Some(ZERO))` is an error in std; clamp up.
    Ok((deadline - now).max(Duration::from_millis(1)))
}

fn is_timeout(e: &io::Error) -> bool {
    matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut)
}

impl NetClient {
    /// Connect to `addr` (first resolvable candidate) within `timeout`,
    /// with `TCP_NODELAY` set — the protocol is request/response over
    /// small lines, where Nagle only adds latency.
    pub fn connect<A: ToSocketAddrs>(addr: A, timeout: Duration) -> io::Result<NetClient> {
        let mut last = None;
        for sa in addr.to_socket_addrs()? {
            match TcpStream::connect_timeout(&sa, timeout) {
                Ok(stream) => return NetClient::from_stream(stream),
                Err(e) => last = Some(e),
            }
        }
        Err(last.unwrap_or_else(|| {
            io::Error::new(io::ErrorKind::InvalidInput, "address resolved to nothing")
        }))
    }

    /// Wrap an already-connected stream (sets `TCP_NODELAY`).
    pub fn from_stream(stream: TcpStream) -> io::Result<NetClient> {
        stream.set_nodelay(true)?;
        Ok(NetClient { stream, buf: Vec::new(), start: 0 })
    }

    /// Send one line (terminating `\n` appended) before `deadline`.
    ///
    /// Lines may be pipelined: the server answers in order, so `N` sends
    /// followed by `N` [`recv_line`]s is the canonical batched exchange.
    ///
    /// [`recv_line`]: NetClient::recv_line
    pub fn send_line(&mut self, line: &str, deadline: Instant) -> io::Result<()> {
        let mut msg = Vec::with_capacity(line.len() + 1);
        msg.extend_from_slice(line.as_bytes());
        msg.push(b'\n');
        let mut sent = 0;
        while sent < msg.len() {
            self.stream.set_write_timeout(Some(remaining(deadline)?))?;
            match self.stream.write(&msg[sent..]) {
                Ok(0) => return Err(io::Error::new(io::ErrorKind::WriteZero, "peer closed")),
                Ok(n) => sent += n,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) if is_timeout(&e) => {
                    // Re-check the deadline (partial progress may have
                    // reset the kernel timer) and retry what's left.
                    remaining(deadline)?;
                }
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }

    /// Receive the next line (without its `\n`) before `deadline`.
    ///
    /// Errors with `TimedOut` when the deadline passes, `UnexpectedEof`
    /// when the peer closes mid-line, and `InvalidData` when a line
    /// exceeds [`MAX_LINE`].
    pub fn recv_line(&mut self, deadline: Instant) -> io::Result<String> {
        loop {
            if let Some(line) = self.take_line()? {
                return Ok(line);
            }
            self.stream.set_read_timeout(Some(remaining(deadline)?))?;
            let mut chunk = [0u8; 4096];
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    return Err(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        "connection closed before a full line arrived",
                    ))
                }
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) if is_timeout(&e) => {
                    remaining(deadline)?; // converts to TimedOut once elapsed
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Pop one complete buffered line, if any, without touching the
    /// socket. `Ok(None)` means "need more bytes".
    pub fn take_line(&mut self) -> io::Result<Option<String>> {
        let live = &self.buf[self.start..];
        match live.iter().position(|&b| b == b'\n') {
            Some(pos) => {
                let line = String::from_utf8_lossy(&live[..pos]).into_owned();
                self.start += pos + 1;
                if self.start >= COMPACT_AT {
                    self.buf.drain(..self.start);
                    self.start = 0;
                }
                Ok(Some(line))
            }
            None => {
                if live.len() > MAX_LINE {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        "reply line exceeds MAX_LINE",
                    ));
                }
                Ok(None)
            }
        }
    }

    /// One read into the line buffer, for callers that established
    /// readiness externally (e.g. `poll(2)` said `POLLIN`, so this will
    /// not block). Returns the byte count; `Ok(0)` is EOF.
    pub fn fill_ready(&mut self) -> io::Result<usize> {
        let mut chunk = [0u8; 4096];
        loop {
            match self.stream.read(&mut chunk) {
                Ok(n) => {
                    self.buf.extend_from_slice(&chunk[..n]);
                    return Ok(n);
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
    }

    /// Half-close the write side; buffered replies keep flowing until the
    /// server drains what it owes.
    pub fn shutdown_write(&self) -> io::Result<()> {
        self.stream.shutdown(Shutdown::Write)
    }

    /// The raw fd, for registering this client in a `poll(2)` set.
    #[cfg(unix)]
    pub fn raw_fd(&self) -> std::os::unix::io::RawFd {
        use std::os::unix::io::AsRawFd;
        self.stream.as_raw_fd()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader};
    use std::net::TcpListener;
    use std::time::{Duration, Instant};

    fn far() -> Instant {
        Instant::now() + Duration::from_secs(30)
    }

    /// An echo server that reads `n` lines then replies to all of them in
    /// one write — exercises pipelining and reply coalescing.
    fn coalescing_echo(n: usize) -> (std::net::SocketAddr, std::thread::JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut r = BufReader::new(stream.try_clone().unwrap());
            let mut lines = Vec::new();
            for _ in 0..n {
                let mut line = String::new();
                r.read_line(&mut line).unwrap();
                lines.push(line);
            }
            let mut out = String::new();
            for l in &lines {
                out.push_str("echo:");
                out.push_str(l);
            }
            (&stream).write_all(out.as_bytes()).unwrap();
        });
        (addr, h)
    }

    #[test]
    fn pipelined_lines_come_back_in_order() {
        let (addr, h) = coalescing_echo(3);
        let mut c = NetClient::connect(addr, Duration::from_secs(5)).unwrap();
        for i in 0..3 {
            c.send_line(&format!("req-{i}"), far()).unwrap();
        }
        for i in 0..3 {
            assert_eq!(c.recv_line(far()).unwrap(), format!("echo:req-{i}"));
        }
        h.join().unwrap();
    }

    #[test]
    fn recv_times_out_when_server_is_silent() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut c = NetClient::connect(addr, Duration::from_secs(5)).unwrap();
        let t0 = Instant::now();
        let err = c.recv_line(Instant::now() + Duration::from_millis(50)).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::TimedOut);
        assert!(t0.elapsed() < Duration::from_secs(5), "timeout not honored");
        drop(listener);
    }

    #[test]
    fn eof_mid_line_is_an_error() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            (&stream).write_all(b"no newline here").unwrap();
            // drop → FIN
        });
        let mut c = NetClient::connect(addr, Duration::from_secs(5)).unwrap();
        let err = c.recv_line(far()).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
        h.join().unwrap();
    }

    #[test]
    fn take_line_drains_buffered_replies_without_reading() {
        let (addr, h) = coalescing_echo(2);
        let mut c = NetClient::connect(addr, Duration::from_secs(5)).unwrap();
        c.send_line("a", far()).unwrap();
        c.send_line("b", far()).unwrap();
        // First recv_line pulls whatever the kernel has; the second reply
        // is usually already buffered and must come out via take_line.
        assert_eq!(c.recv_line(far()).unwrap(), "echo:a");
        let second = match c.take_line().unwrap() {
            Some(line) => line,
            None => c.recv_line(far()).unwrap(),
        };
        assert_eq!(second, "echo:b");
        h.join().unwrap();
    }

    #[cfg(unix)]
    #[test]
    fn poll_readiness_then_fill_ready_yields_lines() {
        use crate::util::poll::{poll, PollFd, POLLIN};
        let (addr, h) = coalescing_echo(1);
        let mut c = NetClient::connect(addr, Duration::from_secs(5)).unwrap();
        c.send_line("ping", far()).unwrap();
        let deadline = far();
        loop {
            if let Some(line) = c.take_line().unwrap() {
                assert_eq!(line, "echo:ping");
                break;
            }
            let mut fds = [PollFd::new(c.raw_fd(), POLLIN)];
            poll(&mut fds, 1000).unwrap();
            if fds[0].readable() {
                assert!(c.fill_ready().unwrap() > 0, "unexpected EOF");
            }
            assert!(Instant::now() < deadline, "no reply within deadline");
        }
        h.join().unwrap();
    }
}
