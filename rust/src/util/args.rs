//! Tiny CLI argument parser (clap is not vendored offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Subcommands are handled by the caller peeling off the first
//! positional.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argument strings (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the process args.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f32(&self, key: &str, default: f32) -> f32 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        // NOTE: bare boolean flags must come last or use `--flag=true`,
        // since `--key value` greedily consumes the next token.
        let a = parse("train --lr 0.1 --epochs=5 data.svm --verbose");
        assert_eq!(a.positional, vec!["train", "data.svm"]);
        assert_eq!(a.get_f32("lr", 0.0), 0.1);
        assert_eq!(a.get_usize("epochs", 0), 5);
        assert!(a.get_bool("verbose"));
        assert!(!a.get_bool("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("x");
        assert_eq!(a.get_usize("missing", 7), 7);
        assert_eq!(a.get_str("name", "d"), "d");
    }

    #[test]
    fn flag_followed_by_flag_is_boolean() {
        let a = parse("--a --b 3");
        assert_eq!(a.get("a"), Some("true"));
        assert_eq!(a.get_usize("b", 0), 3);
    }

    #[test]
    fn negative_number_as_value() {
        // `--key value` form: a following token starting with '-' (not '--')
        // is consumed as the value.
        let a = parse("--bias -0.5");
        assert_eq!(a.get_f32("bias", 0.0), -0.5);
    }
}
