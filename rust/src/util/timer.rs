//! Wall-clock timing helpers and latency histograms for the coordinator's
//! serving metrics (P50/P99 reporting in `examples/serve_batched.rs`),
//! plus the fixed log2 bucket scale shared with the lock-free
//! observability histograms in [`crate::obs`].

use std::time::Instant;

/// Number of buckets in the fixed log2 nanosecond scale used by the
/// `obs` histograms and their Prometheus export.
pub const LOG2_BUCKETS: usize = 64;

/// Bucket index for `ns` on the fixed log2 scale: bucket `b` counts
/// values in `(2^(b-1), 2^b]` ns, bucket 0 holds `[0, 1]`, and the top
/// bucket is the overflow catch-all.
pub fn log2_bucket_of(ns: u64) -> usize {
    if ns <= 1 {
        return 0;
    }
    ((64 - (ns - 1).leading_zeros()) as usize).min(LOG2_BUCKETS - 1)
}

/// Upper edge (inclusive) of log2 bucket `b` in nanoseconds.
pub fn log2_bucket_upper_ns(b: usize) -> u64 {
    1u64 << b.min(63)
}

/// Simple scope timer.
pub struct Timer {
    start: Instant,
}

impl Default for Timer {
    fn default() -> Self {
        Self::new()
    }
}

impl Timer {
    pub fn new() -> Self {
        Timer { start: Instant::now() }
    }
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }
    pub fn elapsed_us(&self) -> f64 {
        self.elapsed_s() * 1e6
    }
}

/// Log-scale latency histogram: buckets at ~8% resolution from 100ns to ~100s.
/// Constant memory, O(1) record, approximate quantiles — the standard shape
/// for serving-latency metrics.
#[derive(Clone)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum_ns: u64,
    max_ns: u64,
}

const BUCKETS: usize = 256;
const BASE_NS: f64 = 100.0;
const GROWTH: f64 = 1.085;

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram { buckets: vec![0; BUCKETS], count: 0, sum_ns: 0, max_ns: 0 }
    }

    fn bucket_of(ns: u64) -> usize {
        if ns as f64 <= BASE_NS {
            return 0;
        }
        let b = ((ns as f64 / BASE_NS).ln() / GROWTH.ln()) as usize;
        b.min(BUCKETS - 1)
    }

    /// Lower edge of bucket `b` in ns.
    fn bucket_value(b: usize) -> f64 {
        BASE_NS * GROWTH.powi(b as i32)
    }

    pub fn record_ns(&mut self, ns: u64) {
        self.buckets[Self::bucket_of(ns)] += 1;
        self.count += 1;
        self.sum_ns += ns;
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn record_duration(&mut self, d: std::time::Duration) {
        self.record_ns(d.as_nanos() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    /// Approximate quantile (`q` in [0,1]) in nanoseconds.
    pub fn quantile_ns(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut acc = 0u64;
        for (b, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return Self::bucket_value(b);
            }
        }
        self.max_ns as f64
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    /// One-line summary.
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={} p50={} p99={} max={}",
            self.count,
            super::bench::fmt_ns(self.mean_ns()),
            super::bench::fmt_ns(self.quantile_ns(0.50)),
            super::bench::fmt_ns(self.quantile_ns(0.99)),
            super::bench::fmt_ns(self.max_ns as f64),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_are_ordered() {
        let mut h = LatencyHistogram::new();
        for i in 1..=10_000u64 {
            h.record_ns(i * 1_000); // 1us..10ms uniform
        }
        let p50 = h.quantile_ns(0.5);
        let p99 = h.quantile_ns(0.99);
        assert!(p50 < p99);
        // ~8% bucket resolution: p50 should be near 5ms.
        assert!((p50 - 5e6).abs() / 5e6 < 0.15, "p50={p50}");
        assert!((p99 - 9.9e6).abs() / 9.9e6 < 0.15, "p99={p99}");
    }

    #[test]
    fn histogram_merge_adds_counts() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record_ns(1_000);
        b.record_ns(2_000);
        b.record_ns(3_000);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert!(a.mean_ns() > 1_000.0);
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile_ns(0.99), 0.0);
        assert_eq!(h.mean_ns(), 0.0);
    }

    #[test]
    fn log2_buckets_partition_the_axis() {
        assert_eq!(log2_bucket_of(0), 0);
        assert_eq!(log2_bucket_of(1), 0);
        assert_eq!(log2_bucket_of(2), 1);
        assert_eq!(log2_bucket_of(3), 2);
        assert_eq!(log2_bucket_of(4), 2);
        assert_eq!(log2_bucket_of(5), 3);
        assert_eq!(log2_bucket_of(u64::MAX), LOG2_BUCKETS - 1);
        // Every value lands in the bucket whose edges bracket it.
        for ns in [1u64, 7, 100, 1_000, 123_456, 1 << 33] {
            let b = log2_bucket_of(ns);
            assert!(ns <= log2_bucket_upper_ns(b), "ns={ns} b={b}");
            if b > 0 {
                assert!(ns > log2_bucket_upper_ns(b - 1), "ns={ns} b={b}");
            }
        }
    }

    #[test]
    fn timer_advances() {
        let t = Timer::new();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.elapsed_ms() >= 1.0);
    }
}
