//! Small self-contained utilities: deterministic RNG, micro-bench harness,
//! minimal JSON, CLI argument parsing, timers and numeric helpers.
//!
//! The build environment is fully offline and the default build is
//! std-only (the PJRT runtime's `xla` dependency sits behind the `pjrt`
//! cargo feature), so the usual ecosystem crates (rand, criterion,
//! serde_json, clap, anyhow) are reimplemented here at the scale this
//! project needs.

pub mod args;
pub mod bench;
pub mod json;
pub mod netclient;
pub mod poll;
pub mod rng;
pub mod timer;

/// Numerically stable `log(sum(exp(xs)))` over a slice.
///
/// Returns `f32::NEG_INFINITY` for an empty slice (the identity of
/// log-space addition), which is what the trellis forward pass wants for
/// "no incoming path yet".
pub fn logsumexp(xs: &[f32]) -> f32 {
    let m = xs.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return m;
    }
    let s: f32 = xs.iter().map(|&x| (x - m).exp()).sum();
    m + s.ln()
}

/// Log-space addition of two values: `log(exp(a) + exp(b))`.
pub fn logaddexp(a: f32, b: f32) -> f32 {
    let (hi, lo) = if a >= b { (a, b) } else { (b, a) };
    if !hi.is_finite() {
        return hi;
    }
    hi + (lo - hi).exp().ln_1p()
}

/// `ceil(log2(c))` for `c >= 1`.
pub fn ceil_log2(c: u64) -> u32 {
    debug_assert!(c >= 1);
    64 - (c - 1).leading_zeros().max(0)
}

/// `floor(log2(c))` for `c >= 1`.
pub fn floor_log2(c: u64) -> u32 {
    debug_assert!(c >= 1);
    63 - c.leading_zeros()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logsumexp_matches_naive() {
        let xs = [0.5f32, -1.0, 2.0, 0.0];
        let naive = xs.iter().map(|x| x.exp()).sum::<f32>().ln();
        assert!((logsumexp(&xs) - naive).abs() < 1e-6);
    }

    #[test]
    fn logsumexp_empty_is_neg_inf() {
        assert_eq!(logsumexp(&[]), f32::NEG_INFINITY);
    }

    #[test]
    fn logsumexp_large_values_stable() {
        let xs = [1000.0f32, 1000.0];
        let v = logsumexp(&xs);
        assert!((v - (1000.0 + 2f32.ln())).abs() < 1e-3);
    }

    #[test]
    fn logaddexp_matches_logsumexp() {
        for (a, b) in [(0.0f32, 1.0f32), (-5.0, 3.0), (2.0, 2.0)] {
            assert!((logaddexp(a, b) - logsumexp(&[a, b])).abs() < 1e-6);
        }
        assert_eq!(logaddexp(f32::NEG_INFINITY, f32::NEG_INFINITY), f32::NEG_INFINITY);
        assert!((logaddexp(f32::NEG_INFINITY, 1.0) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn log2_helpers() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(1024), 10);
        assert_eq!(ceil_log2(1025), 11);
        assert_eq!(floor_log2(1), 0);
        assert_eq!(floor_log2(2), 1);
        assert_eq!(floor_log2(3), 1);
        assert_eq!(floor_log2(22), 4);
        assert_eq!(floor_log2(1000), 9);
    }
}
