//! Deterministic pseudo-random number generation (xoshiro256**).
//!
//! Everything in this crate that draws randomness — synthetic dataset
//! generation, weight init, random path assignment, property tests — goes
//! through [`Rng`] so runs are exactly reproducible from a seed.

/// xoshiro256** — fast, high-quality, 256-bit state PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 expansion so that any `u64` seed (including 0)
    /// yields a well-mixed state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in `[0, 1)` with f64 precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64) * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. `n` must be nonzero.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free-enough reduction; bias is
        // negligible for the n (< 2^32) used here.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize index into a slice of length `n`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f32 {
        let u1 = (1.0 - self.f64()).max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn coin(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Draw `k` distinct indices from `[0, n)` (k << n assumed; rejection).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<u32> {
        assert!(k <= n);
        if k * 3 > n {
            let mut all: Vec<u32> = (0..n as u32).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all.sort_unstable();
            return all;
        }
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let v = self.below(n as u64) as u32;
            if !out.contains(&v) {
                out.push(v);
            }
        }
        out.sort_unstable();
        out
    }

    /// Zipf-distributed integer in `[0, n)` with exponent `a` (via inverse
    /// CDF on a precomputed table — see [`ZipfTable`] for the fast path).
    pub fn zipf(&mut self, table: &ZipfTable) -> usize {
        table.sample(self)
    }
}

/// Precomputed cumulative Zipf distribution over `[0, n)`.
pub struct ZipfTable {
    cdf: Vec<f64>,
}

impl ZipfTable {
    /// Build a Zipf(a) table over `n` items (`p(i) ∝ (i+1)^-a`).
    pub fn new(n: usize, a: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for i in 0..n {
            acc += ((i + 1) as f64).powf(-a);
            cdf.push(acc);
        }
        let z = acc;
        for v in &mut cdf {
            *v /= z;
        }
        ZipfTable { cdf }
    }

    /// Sample an index.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self.cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(4);
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let (mut m, mut v) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            m += x;
            v += x * x;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Rng::new(6);
        for _ in 0..50 {
            let n = 1 + r.index(200);
            let k = r.index(n + 1);
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            assert!(s.windows(2).all(|w| w[0] < w[1]), "sorted distinct");
            assert!(s.iter().all(|&v| (v as usize) < n));
        }
    }

    #[test]
    fn zipf_is_skewed() {
        let t = ZipfTable::new(1000, 1.2);
        let mut r = Rng::new(7);
        let mut head = 0;
        for _ in 0..10_000 {
            if t.sample(&mut r) < 10 {
                head += 1;
            }
        }
        // Zipf(1.2) puts far more than uniform (1%) mass on the top 10.
        assert!(head > 2_000, "head mass {head}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
