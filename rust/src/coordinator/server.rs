//! The prediction server: request channel → dynamic batcher → worker
//! threads → response channels.
//!
//! Routing: sparse requests go to the rust-native LTLS path (per-example
//! `O(E·nnz + log C)`, batching only amortizes queueing); dense requests
//! can be routed to the AOT deep model, where batching amortizes the PJRT
//! dispatch. The server is generic over a [`BatchModel`] so both paths —
//! and test mocks — plug in.

use super::batcher::{next_batch, BatcherConfig};
use super::metrics::ServingMetrics;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, SyncSender};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// A prediction request: sparse feature vector + top-k + reply channel.
pub struct Request {
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
    pub k: usize,
    pub enqueued: Instant,
    reply: Sender<Response>,
}

/// The server's answer.
#[derive(Clone, Debug)]
pub struct Response {
    pub topk: Vec<(u32, f32)>,
}

/// Anything that can answer a batch of requests at once.
pub trait BatchModel: Send + Sync + 'static {
    /// Answer each request (same order as the input).
    fn predict_batch(&self, batch: &[Request]) -> Vec<Response>;
    fn name(&self) -> &str;
}

/// Adapter: any [`crate::eval::Predictor`] serves per-example (the sparse
/// LTLS path — batching only helps queueing, which is the honest story
/// for a per-example O(log C) model).
pub struct SparsePath<P>(pub P);

impl<P: crate::eval::Predictor + Send + Sync + 'static> BatchModel for SparsePath<P> {
    fn predict_batch(&self, batch: &[Request]) -> Vec<Response> {
        batch
            .iter()
            .map(|r| Response {
                topk: self.0.topk(crate::sparse::SparseVec::new(&r.indices, &r.values), r.k),
            })
            .collect()
    }
    fn name(&self) -> &str {
        self.0.name()
    }
}

/// Server configuration.
#[derive(Clone, Debug, Default)]
pub struct ServerConfig {
    pub batcher: BatcherConfig,
    pub queue_depth: usize,
}

/// Handle to a running server.
pub struct PredictServer {
    tx: SyncSender<Request>,
    pub metrics: Arc<ServingMetrics>,
    worker: Option<JoinHandle<()>>,
    stopping: Arc<AtomicBool>,
}

impl PredictServer {
    /// Spawn the worker thread.
    pub fn start<M: BatchModel>(model: M, cfg: ServerConfig) -> PredictServer {
        let depth = if cfg.queue_depth == 0 { 1024 } else { cfg.queue_depth };
        let (tx, rx) = mpsc::sync_channel::<Request>(depth);
        let metrics = Arc::new(ServingMetrics::new());
        let stopping = Arc::new(AtomicBool::new(false));
        let m = Arc::clone(&metrics);
        let rx = Mutex::new(rx);
        let bcfg = cfg.batcher.clone();
        let worker = std::thread::Builder::new()
            .name("ltls-server".into())
            .spawn(move || {
                let rx: Receiver<Request> = rx.into_inner().unwrap();
                while let Some(batch) = next_batch(&rx, &bcfg) {
                    let queue_ns = batch.oldest.elapsed().as_nanos() as u64;
                    let t0 = Instant::now();
                    let responses = model.predict_batch(&batch.items);
                    let exec_ns = t0.elapsed().as_nanos() as u64;
                    m.record_batch(batch.items.len(), queue_ns, exec_ns);
                    for (req, resp) in batch.items.into_iter().zip(responses) {
                        m.record_request_latency(req.enqueued.elapsed().as_nanos() as u64);
                        let _ = req.reply.send(resp);
                    }
                }
            })
            .expect("spawn server worker");
        PredictServer { tx, metrics, worker: Some(worker), stopping }
    }

    /// Submit a request; returns a receiver for the response.
    /// Blocks when the bounded queue is full (backpressure).
    pub fn submit(&self, indices: Vec<u32>, values: Vec<f32>, k: usize) -> Receiver<Response> {
        let (reply, rx) = channel();
        let req = Request { indices, values, k, enqueued: Instant::now(), reply };
        self.tx.send(req).expect("server stopped");
        rx
    }

    /// Blocking convenience call.
    pub fn predict(&self, indices: Vec<u32>, values: Vec<f32>, k: usize) -> Response {
        self.submit(indices, values, k).recv().expect("server dropped reply")
    }

    /// Graceful shutdown: close the queue, join the worker.
    pub fn shutdown(mut self) {
        self.stopping.store(true, Ordering::SeqCst);
        drop(std::mem::replace(&mut self.tx, {
            // Replace with a dead sender by building a dummy pair.
            let (tx, _rx) = mpsc::sync_channel(1);
            tx
        }));
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for PredictServer {
    fn drop(&mut self) {
        self.stopping.store(true, Ordering::SeqCst);
        if let Some(w) = self.worker.take() {
            // Dropping self.tx happens after drop returns; detach instead
            // of joining to avoid deadlock if callers forgot shutdown().
            drop(w);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    struct Echo;
    impl BatchModel for Echo {
        fn predict_batch(&self, batch: &[Request]) -> Vec<Response> {
            batch
                .iter()
                .map(|r| Response { topk: vec![(r.indices.first().copied().unwrap_or(0), 1.0)] })
                .collect()
        }
        fn name(&self) -> &str {
            "echo"
        }
    }

    #[test]
    fn serves_requests_and_collects_metrics() {
        let server = PredictServer::start(
            Echo,
            ServerConfig {
                batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
                queue_depth: 64,
            },
        );
        let mut receivers = Vec::new();
        for i in 0..50u32 {
            receivers.push(server.submit(vec![i], vec![1.0], 1));
        }
        for (i, rx) in receivers.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.topk[0].0, i as u32);
        }
        let (reqs, batches, _) = server.metrics.counts();
        assert_eq!(reqs, 50);
        assert!(batches >= 7, "batches={batches}"); // 50/8 → at least 7
        server.shutdown();
    }

    #[test]
    fn blocking_predict_roundtrip() {
        let server = PredictServer::start(Echo, ServerConfig::default());
        let r = server.predict(vec![42], vec![1.0], 1);
        assert_eq!(r.topk, vec![(42, 1.0)]);
        server.shutdown();
    }

    #[test]
    fn sparse_path_adapter_uses_predictor() {
        use crate::data::synthetic::SyntheticSpec;
        use crate::train::{TrainConfig, Trainer};
        let ds = SyntheticSpec::multiclass(400, 500, 16).seed(33).generate();
        let mut tr = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
        tr.fit(&ds, 3);
        let model = tr.into_model();
        let server = PredictServer::start(SparsePath(model), ServerConfig::default());
        let row = ds.row(0);
        let resp = server.predict(row.indices.to_vec(), row.values.to_vec(), 3);
        assert!(!resp.topk.is_empty());
        assert!(resp.topk.len() <= 3);
        server.shutdown();
    }
}
