//! The prediction server: request channel → dynamic batcher → N-worker
//! pool → response channels.
//!
//! Architecture: all workers share one bounded request queue. A worker
//! takes the queue lock only while *collecting* a micro-batch (the lock is
//! cheap to hold — collection ends at `max_batch` or `max_wait`), then
//! releases it and executes the batch on its own
//! [`PredictScratch`], so batch execution — the expensive part — runs on
//! all cores concurrently and steady-state serving performs no heap
//! allocation in the decode path. Each worker reports per-worker metrics.
//!
//! Routing: sparse requests go to the rust-native LTLS path (per-example
//! `O(E·nnz + log C)`); [`BatchedLtls`] additionally amortizes the
//! feature-strip sweep across the whole micro-batch
//! ([`crate::model::LinearEdgeModel::edge_scores_batch`]). Dense requests
//! can be routed to the AOT deep model, where batching amortizes the PJRT
//! dispatch. The server is generic over a [`BatchModel`] so all paths —
//! and test mocks — plug in.

use super::batcher::{next_batch, BatcherConfig, Stamped};
use super::metrics::ServingMetrics;
use crate::engine::PredictScratch;
use crate::obs::{Span, Stage};
use std::sync::mpsc::{channel, Receiver, Sender, SyncSender};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Readiness hook a worker fires after a request's response has been sent
/// on its reply channel. The event-loop transport
/// ([`super::event_loop`]) registers one per connection: the hook marks
/// the connection reply-ready and wakes its poll thread, so completions
/// are readiness-driven instead of each connection parking a thread on a
/// blocking `recv`. Must be cheap and non-blocking — it runs on the
/// worker's batch loop.
pub trait CompletionNotify: Send + Sync {
    fn completed(&self);
}

/// A prediction request: sparse feature vector + top-k + reply channel.
pub struct Request {
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
    pub k: usize,
    pub enqueued: Instant,
    reply: Sender<Response>,
    /// Fired after `reply.send` (see [`CompletionNotify`]); `None` for
    /// callers that block on the reply receiver instead.
    notify: Option<Arc<dyn CompletionNotify>>,
    /// Trace span stamped as the request moves through the pipeline
    /// (`None` unless the transport's tracer picked this request up).
    pub(crate) span: Option<Span>,
}

impl Request {
    /// A request whose reply channel is disconnected — for exercising
    /// [`BatchModel`] implementations directly (tests, benches) without
    /// going through a worker pool.
    #[cfg(test)]
    pub(crate) fn detached(indices: Vec<u32>, values: Vec<f32>, k: usize) -> Request {
        Request {
            indices,
            values,
            k,
            enqueued: Instant::now(),
            reply: channel().0,
            notify: None,
            span: None,
        }
    }
}

impl Stamped for Request {
    fn enqueued_at(&self) -> Instant {
        self.enqueued
    }
}

/// The server's answer.
#[derive(Clone, Debug)]
pub struct Response {
    pub topk: Vec<(u32, f32)>,
    /// Degraded scatter-gather answer: some label shard contributed no
    /// candidates because every one of its replicas was down
    /// ([`super::scatter`]). Always `false` from single-process models;
    /// rendered on the wire as `"partial":true` only when set.
    pub partial: bool,
}

/// Anything that can answer a batch of requests at once.
pub trait BatchModel: Send + Sync + 'static {
    /// Answer each request (same order as the input).
    fn predict_batch(&self, batch: &[Request]) -> Vec<Response>;

    /// Engine variant: answer into `out` reusing the worker's scratch.
    /// Must produce exactly what [`Self::predict_batch`] produces; the
    /// default delegates to it. The worker pool always calls this.
    fn predict_batch_into(
        &self,
        batch: &[Request],
        scratch: &mut PredictScratch,
        out: &mut Vec<Response>,
    ) {
        let _ = scratch;
        out.clear();
        out.extend(self.predict_batch(batch));
    }

    /// Feature dimensionality `D` requests may index, when the model knows
    /// it (`None` → unbounded / unknown). The network frontend
    /// ([`super::transport`]) uses this to reject out-of-range feature
    /// indices with a protocol error before they reach a scoring kernel.
    fn n_features(&self) -> Option<usize> {
        None
    }

    fn name(&self) -> &str;
}

/// Delegating impl so a shared handle (e.g. the hot-reloadable model,
/// which the reload path must also hold) can be installed in the pool.
impl<M: BatchModel> BatchModel for Arc<M> {
    fn predict_batch(&self, batch: &[Request]) -> Vec<Response> {
        (**self).predict_batch(batch)
    }

    fn predict_batch_into(
        &self,
        batch: &[Request],
        scratch: &mut PredictScratch,
        out: &mut Vec<Response>,
    ) {
        (**self).predict_batch_into(batch, scratch, out)
    }

    fn n_features(&self) -> Option<usize> {
        (**self).n_features()
    }

    fn name(&self) -> &str {
        (**self).name()
    }
}

/// Adapter: any [`crate::eval::Predictor`] serves per-example through its
/// `topk_into` engine path (batching amortizes queueing; edge scoring
/// stays per-example).
pub struct SparsePath<P>(pub P);

impl<P: crate::eval::Predictor + Send + Sync + 'static> BatchModel for SparsePath<P> {
    fn predict_batch(&self, batch: &[Request]) -> Vec<Response> {
        let mut out = Vec::with_capacity(batch.len());
        self.predict_batch_into(batch, &mut PredictScratch::new(), &mut out);
        out
    }

    fn predict_batch_into(
        &self,
        batch: &[Request],
        scratch: &mut PredictScratch,
        out: &mut Vec<Response>,
    ) {
        out.clear();
        for r in batch {
            let mut topk = Vec::with_capacity(r.k);
            self.0.topk_into(
                crate::sparse::SparseVec::new(&r.indices, &r.values),
                r.k,
                scratch,
                &mut topk,
            );
            out.push(Response { topk, partial: false });
        }
    }

    fn name(&self) -> &str {
        self.0.name()
    }
}

/// The batched LTLS path: one weight-strip sweep scores the *whole*
/// micro-batch ([`crate::model::WeightStore::edge_scores_batch`]),
/// then each row is list-Viterbi-decoded from the shared score matrix —
/// all on the worker's scratch. Bit-identical to the per-example path.
/// Generic over the graph topology **and the weight store**, so wide
/// (W-LTLS) models and the hashed / quantized / memory-mapped backends
/// all serve through the same multi-worker pool.
pub struct BatchedLtls<
    T: crate::graph::Topology = crate::graph::Trellis,
    S: crate::model::WeightStore = crate::model::DenseStore,
>(pub crate::train::TrainedModel<T, S>);

/// The batched scoring body shared by [`BatchedLtls`] and the
/// hot-reloadable wrapper ([`super::reload::ReloadableLtls`]): one
/// strip-sweep scores the whole micro-batch, then each row is
/// list-Viterbi-decoded from the shared score matrix, all on `scratch`.
///
/// Requests carrying a feature index `>= D` cannot be scored by this
/// model (the strip kernels index weights by feature) and are answered
/// with an empty top-k instead of reaching a kernel. The network
/// transport already rejects such requests with a protocol error; this
/// guard covers the hot-reload race where a request was admitted against
/// one model generation and executes against the next.
pub(crate) fn batched_predict_into<T: crate::graph::Topology, S: crate::model::WeightStore>(
    model: &crate::train::TrainedModel<T, S>,
    batch: &[Request],
    scratch: &mut PredictScratch,
    out: &mut Vec<Response>,
) {
    out.clear();
    let e = crate::model::WeightStore::n_edges(&model.model);
    // Compared in usize: D can legitimately be 2^32 (feature ids are
    // u32, D = max id + 1), which a u32 cast would wrap to 0.
    let d = crate::model::WeightStore::n_features(&model.model);
    let scorable = |r: &Request| r.indices.iter().all(|&i| (i as usize) < d);
    let all_scorable = batch.iter().all(scorable);
    static EMPTY_U32: [u32; 0] = [];
    static EMPTY_F32: [f32; 0] = [];
    let rows: Vec<crate::sparse::SparseVec> = batch
        .iter()
        .map(|r| {
            if all_scorable || scorable(r) {
                crate::sparse::SparseVec::new(&r.indices, &r.values)
            } else {
                crate::sparse::SparseVec::new(&EMPTY_U32, &EMPTY_F32)
            }
        })
        .collect();
    model.model.edge_scores_batch(&rows, &mut scratch.score, &mut scratch.batch_h);
    let scored = Instant::now();
    for r in batch {
        if let Some(sp) = &r.span {
            sp.stamp_at(Stage::Score, scored);
        }
    }
    for (i, r) in batch.iter().enumerate() {
        if !all_scorable && !scorable(r) {
            out.push(Response { topk: Vec::new(), partial: false });
            continue;
        }
        let h = &scratch.batch_h[i * e..(i + 1) * e];
        let fetch = (r.k + 8).min(crate::graph::Topology::c(&model.trellis) as usize);
        crate::decode::list_viterbi_into(
            &model.trellis,
            h,
            fetch,
            &mut scratch.ws,
            &mut scratch.paths,
        );
        let mut topk = Vec::with_capacity(r.k);
        model.resolve_topk(r.k, &scratch.paths, &mut topk);
        if let Some(sp) = &r.span {
            sp.stamp(Stage::Decode);
        }
        out.push(Response { topk, partial: false });
    }
}

impl<T: crate::graph::Topology, S: crate::model::WeightStore> BatchModel for BatchedLtls<T, S> {
    fn predict_batch(&self, batch: &[Request]) -> Vec<Response> {
        let mut out = Vec::with_capacity(batch.len());
        self.predict_batch_into(batch, &mut PredictScratch::new(), &mut out);
        out
    }

    fn predict_batch_into(
        &self,
        batch: &[Request],
        scratch: &mut PredictScratch,
        out: &mut Vec<Response>,
    ) {
        batched_predict_into(&self.0, batch, scratch, out)
    }

    fn n_features(&self) -> Option<usize> {
        Some(crate::model::WeightStore::n_features(&self.0.model))
    }

    fn name(&self) -> &str {
        "LTLS-batched"
    }
}

/// Server configuration.
#[derive(Clone, Debug, Default)]
pub struct ServerConfig {
    pub batcher: BatcherConfig,
    /// Bounded request-queue depth (0 → 1024).
    pub queue_depth: usize,
    /// Worker threads (0 → one per available core).
    pub workers: usize,
}

impl ServerConfig {
    /// The queue depth actually used (resolves the `0 → 1024` default) —
    /// the single source of truth for anything derived from it, e.g. the
    /// network frontend's default admission bound.
    pub fn effective_queue_depth(&self) -> usize {
        if self.queue_depth == 0 {
            1024
        } else {
            self.queue_depth
        }
    }
}

/// Why a non-blocking submission was refused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded request queue is full — backpressure; callers should
    /// reject or retry later rather than queue unboundedly.
    QueueFull,
    /// The worker pool has shut down.
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "request queue full (backpressure)"),
            SubmitError::Closed => write!(f, "prediction server has shut down"),
        }
    }
}

/// A cloneable, lock-free submission handle onto a [`PredictServer`]'s
/// bounded queue (see [`PredictServer::submitter`]).
#[derive(Clone)]
pub struct Submitter {
    tx: SyncSender<Request>,
}

impl Submitter {
    /// Same contract as [`PredictServer::try_submit`].
    pub fn try_submit(
        &self,
        indices: Vec<u32>,
        values: Vec<f32>,
        k: usize,
    ) -> Result<Receiver<Response>, SubmitError> {
        try_submit_on(&self.tx, indices, values, k, None, None)
    }

    /// [`Self::try_submit`] with a completion hook: `notify.completed()`
    /// fires after the worker sends the response, so the caller can poll
    /// the returned receiver with `try_recv` on wake-up instead of
    /// blocking a thread on it.
    pub fn try_submit_with_notify(
        &self,
        indices: Vec<u32>,
        values: Vec<f32>,
        k: usize,
        notify: Arc<dyn CompletionNotify>,
    ) -> Result<Receiver<Response>, SubmitError> {
        try_submit_on(&self.tx, indices, values, k, None, Some(notify))
    }

    /// The full submission surface: an optional trace [`Span`] (stamped
    /// `enqueue` here, then through the worker pipeline) and an optional
    /// completion hook. Both transports submit through this.
    pub fn try_submit_full(
        &self,
        indices: Vec<u32>,
        values: Vec<f32>,
        k: usize,
        span: Option<Span>,
        notify: Option<Arc<dyn CompletionNotify>>,
    ) -> Result<Receiver<Response>, SubmitError> {
        try_submit_on(&self.tx, indices, values, k, span, notify)
    }
}

fn try_submit_on(
    tx: &SyncSender<Request>,
    indices: Vec<u32>,
    values: Vec<f32>,
    k: usize,
    span: Option<Span>,
    notify: Option<Arc<dyn CompletionNotify>>,
) -> Result<Receiver<Response>, SubmitError> {
    let (reply, rx) = channel();
    if let Some(sp) = &span {
        sp.stamp(Stage::Enqueue);
    }
    let req = Request { indices, values, k, enqueued: Instant::now(), reply, notify, span };
    match tx.try_send(req) {
        Ok(()) => Ok(rx),
        Err(mpsc::TrySendError::Full(_)) => Err(SubmitError::QueueFull),
        Err(mpsc::TrySendError::Disconnected(_)) => Err(SubmitError::Closed),
    }
}

/// Handle to a running server.
pub struct PredictServer {
    tx: SyncSender<Request>,
    pub metrics: Arc<ServingMetrics>,
    workers: Vec<JoinHandle<()>>,
}

impl PredictServer {
    /// Spawn the worker pool.
    pub fn start<M: BatchModel>(model: M, cfg: ServerConfig) -> PredictServer {
        let depth = cfg.effective_queue_depth();
        let n_workers = if cfg.workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            cfg.workers
        };
        let (tx, rx) = mpsc::sync_channel::<Request>(depth);
        let metrics = Arc::new(ServingMetrics::with_workers(n_workers));
        let model = Arc::new(model);
        let queue = Arc::new(Mutex::new(rx));
        let mut workers = Vec::with_capacity(n_workers);
        for wid in 0..n_workers {
            let m = Arc::clone(&metrics);
            let model = Arc::clone(&model);
            let queue = Arc::clone(&queue);
            let bcfg = cfg.batcher.clone();
            let handle = std::thread::Builder::new()
                .name(format!("ltls-server-{wid}"))
                .spawn(move || {
                    // Worker-owned engine state: reused across every batch.
                    let mut scratch = PredictScratch::new();
                    let mut responses: Vec<Response> = Vec::new();
                    loop {
                        // Hold the queue lock only while collecting.
                        let batch = {
                            let rx = queue.lock().unwrap();
                            next_batch(&*rx, &bcfg)
                        };
                        let Some(batch) = batch else { break };
                        // One clock reading stamps the whole micro-batch.
                        for req in &batch.items {
                            if let Some(sp) = &req.span {
                                sp.stamp_at(Stage::BatchForm, batch.formed);
                            }
                        }
                        let queue_ns = batch.oldest.elapsed().as_nanos() as u64;
                        let t0 = Instant::now();
                        model.predict_batch_into(&batch.items, &mut scratch, &mut responses);
                        let exec_ns = t0.elapsed().as_nanos() as u64;
                        m.record_batch(wid, batch.items.len(), queue_ns, exec_ns);
                        for (req, resp) in batch.items.into_iter().zip(responses.drain(..)) {
                            m.record_request_latency(req.enqueued.elapsed().as_nanos() as u64);
                            let _ = req.reply.send(resp);
                            // After the send: a notified poller's try_recv
                            // must observe the response.
                            if let Some(n) = &req.notify {
                                n.completed();
                            }
                        }
                    }
                })
                .expect("spawn server worker");
            workers.push(handle);
        }
        PredictServer { tx, metrics, workers }
    }

    /// Number of worker threads in the pool.
    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Submit a request; returns a receiver for the response.
    /// Blocks when the bounded queue is full (backpressure).
    pub fn submit(&self, indices: Vec<u32>, values: Vec<f32>, k: usize) -> Receiver<Response> {
        let (reply, rx) = channel();
        let req = Request {
            indices,
            values,
            k,
            enqueued: Instant::now(),
            reply,
            notify: None,
            span: None,
        };
        self.tx.send(req).expect("server stopped");
        rx
    }

    /// Non-blocking [`Self::submit`]: when the bounded queue is full the
    /// request is refused with [`SubmitError::QueueFull`] instead of
    /// blocking the caller — the admission path of the network frontend,
    /// which must answer with a backpressure error rather than queue
    /// unboundedly.
    pub fn try_submit(
        &self,
        indices: Vec<u32>,
        values: Vec<f32>,
        k: usize,
    ) -> Result<Receiver<Response>, SubmitError> {
        try_submit_on(&self.tx, indices, values, k, None, None)
    }

    /// A cloneable submission handle. The network frontend hands one to
    /// each connection so per-request submission contends only on the
    /// queue channel itself, not on any lock around the server handle.
    /// Holders keep the request channel alive: drop them before
    /// expecting [`Self::shutdown`]'s worker join to complete.
    pub fn submitter(&self) -> Submitter {
        Submitter { tx: self.tx.clone() }
    }

    /// Blocking convenience call.
    pub fn predict(&self, indices: Vec<u32>, values: Vec<f32>, k: usize) -> Response {
        self.submit(indices, values, k).recv().expect("server dropped reply")
    }

    /// Graceful shutdown: close the queue, join every worker. (Merely
    /// dropping the server also closes the queue, but detaches the
    /// workers instead of joining them.)
    pub fn shutdown(self) {
        let PredictServer { tx, workers, metrics: _ } = self;
        drop(tx);
        for w in workers {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    struct Echo;
    impl BatchModel for Echo {
        fn predict_batch(&self, batch: &[Request]) -> Vec<Response> {
            batch
                .iter()
                .map(|r| Response {
                    topk: vec![(r.indices.first().copied().unwrap_or(0), 1.0)],
                    partial: false,
                })
                .collect()
        }
        fn name(&self) -> &str {
            "echo"
        }
    }

    #[test]
    fn serves_requests_and_collects_metrics() {
        let server = PredictServer::start(
            Echo,
            ServerConfig {
                batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) },
                queue_depth: 64,
                workers: 1,
            },
        );
        let mut receivers = Vec::new();
        for i in 0..50u32 {
            receivers.push(server.submit(vec![i], vec![1.0], 1));
        }
        for (i, rx) in receivers.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.topk[0].0, i as u32);
        }
        let (reqs, batches, _) = server.metrics.counts();
        assert_eq!(reqs, 50);
        assert!(batches >= 7, "batches={batches}"); // 50/8 → at least 7
        server.shutdown();
    }

    /// A full bounded queue refuses (backpressure) instead of blocking.
    #[test]
    fn try_submit_backpressure_when_queue_full() {
        struct Slow;
        impl BatchModel for Slow {
            fn predict_batch(&self, batch: &[Request]) -> Vec<Response> {
                std::thread::sleep(Duration::from_millis(100));
                batch.iter().map(|_| Response { topk: Vec::new(), partial: false }).collect()
            }
            fn name(&self) -> &str {
                "slow"
            }
        }
        let server = PredictServer::start(
            Slow,
            ServerConfig {
                batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(1) },
                queue_depth: 2,
                workers: 1,
            },
        );
        let mut pending = Vec::new();
        let mut saw_full = false;
        for _ in 0..64 {
            match server.try_submit(vec![0], vec![1.0], 1) {
                Ok(rx) => pending.push(rx),
                Err(SubmitError::QueueFull) => {
                    saw_full = true;
                    break;
                }
                Err(e) => panic!("unexpected submit error: {e}"),
            }
        }
        assert!(saw_full, "64 rapid submissions never hit the bounded queue");
        for rx in pending {
            rx.recv().unwrap();
        }
        server.shutdown();
    }

    /// The worker fires the completion hook only after the reply channel
    /// holds the response — a notified poller's `try_recv` must succeed.
    #[test]
    fn completion_notify_fires_after_reply_is_receivable() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        struct Count(AtomicUsize);
        impl CompletionNotify for Count {
            fn completed(&self) {
                self.0.fetch_add(1, Ordering::SeqCst);
            }
        }
        let server = PredictServer::start(
            Echo,
            ServerConfig {
                batcher: BatcherConfig { max_batch: 1, max_wait: Duration::from_micros(50) },
                queue_depth: 8,
                workers: 1,
            },
        );
        let sub = server.submitter();
        let n = Arc::new(Count(AtomicUsize::new(0)));
        let hook: Arc<dyn CompletionNotify> = Arc::clone(&n) as _;
        let rx = sub.try_submit_with_notify(vec![7], vec![1.0], 1, hook).unwrap();
        let t0 = Instant::now();
        while n.0.load(Ordering::SeqCst) == 0 {
            assert!(t0.elapsed() < Duration::from_secs(5), "notify never fired");
            std::thread::yield_now();
        }
        let resp = rx.try_recv().expect("response not receivable after notify fired");
        assert_eq!(resp.topk[0].0, 7);
        server.shutdown();
    }

    #[test]
    fn blocking_predict_roundtrip() {
        let server = PredictServer::start(Echo, ServerConfig::default());
        assert!(server.n_workers() >= 1);
        let r = server.predict(vec![42], vec![1.0], 1);
        assert_eq!(r.topk, vec![(42, 1.0)]);
        server.shutdown();
    }

    #[test]
    fn multi_worker_pool_answers_every_request() {
        let server = PredictServer::start(
            Echo,
            ServerConfig {
                batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_micros(200) },
                queue_depth: 128,
                workers: 4,
            },
        );
        assert_eq!(server.n_workers(), 4);
        let receivers: Vec<_> = (0..200u32).map(|i| server.submit(vec![i], vec![1.0], 1)).collect();
        for (i, rx) in receivers.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap().topk[0].0, i as u32);
        }
        let (reqs, _, _) = server.metrics.counts();
        assert_eq!(reqs, 200);
        // Every request is attributed to exactly one worker slot.
        let pw = server.metrics.per_worker();
        assert_eq!(pw.len(), 4);
        assert_eq!(pw.iter().map(|w| w.requests).sum::<u64>(), 200);
        server.shutdown();
    }

    #[test]
    fn sparse_path_adapter_uses_predictor() {
        use crate::data::synthetic::SyntheticSpec;
        use crate::train::{TrainConfig, Trainer};
        let ds = SyntheticSpec::multiclass(400, 500, 16).seed(33).generate();
        let mut tr = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
        tr.fit(&ds, 3);
        let model = tr.into_model();
        let server = PredictServer::start(SparsePath(model), ServerConfig::default());
        let row = ds.row(0);
        let resp = server.predict(row.indices.to_vec(), row.values.to_vec(), 3);
        assert!(!resp.topk.is_empty());
        assert!(resp.topk.len() <= 3);
        server.shutdown();
    }

    /// BatchedLtls (one strip-sweep per batch) == SparsePath (per-example)
    /// == inline predict_topk — bit-identical.
    #[test]
    fn batched_path_matches_per_example_path() {
        use crate::data::synthetic::SyntheticSpec;
        use crate::eval::Predictor;
        use crate::train::{TrainConfig, Trainer};
        let ds = SyntheticSpec::multiclass(500, 400, 24).seed(34).generate();
        let mut tr = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
        tr.fit(&ds, 3);
        let model = tr.into_model();
        let inline: Vec<_> = (0..40).map(|i| model.topk(ds.row(i), 3)).collect();
        let server = PredictServer::start(
            BatchedLtls(model),
            ServerConfig {
                batcher: BatcherConfig { max_batch: 16, max_wait: Duration::from_micros(300) },
                queue_depth: 64,
                workers: 2,
            },
        );
        let receivers: Vec<_> = (0..40)
            .map(|i| {
                let row = ds.row(i);
                server.submit(row.indices.to_vec(), row.values.to_vec(), 3)
            })
            .collect();
        for (i, rx) in receivers.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap().topk, inline[i], "request {i}");
        }
        server.shutdown();
    }
}
