//! The network serving frontend: a std-only TCP transport in front of the
//! batching multi-worker prediction pool (`ltls serve --listen HOST:PORT`).
//!
//! ## Wire protocol (newline-delimited)
//!
//! Requests are single text lines; every line gets exactly one reply line,
//! in request order per connection (pipelining is encouraged):
//!
//! ```text
//! <k> <i:v> <i:v> ...     top-k prediction for a sparse feature vector
//!                         → {"topk":[[label,score],...]}
//! PING                    → {"ok":true}
//! METRICS                 → plaintext metrics block (multi-line,
//!                           prometheus-style `name value` gauges,
//!                           terminated by a `# end` line)
//! RELOAD [path]           hot-swap the model from `path` (or the path
//!                         the server was started from)
//!                         → {"ok":true,"epoch":N,...} or {"error":...}
//! SHUTDOWN                → {"ok":true,"draining":true}, then the server
//!                           drains gracefully and exits
//! ```
//!
//! Malformed lines (bad `k`, bad `i:v` tokens, non-finite values,
//! duplicate or out-of-range feature indices, over-long lines) are
//! answered with `{"error":...}` — the connection stays usable except
//! after an over-long line, which cannot be resynchronized safely.
//!
//! ## Admission control (backpressure)
//!
//! The transport bounds the number of requests that are *admitted* —
//! submitted to the worker pool but not yet answered — across all
//! connections. Over the bound (or when the pool's own bounded queue is
//! full) a request is answered immediately with
//! `{"error":"backpressure: ...","backpressure":true}` instead of being
//! queued unboundedly; clients should back off and retry. Control
//! commands are never subject to admission control.
//!
//! ## Threading and graceful drain
//!
//! One accept thread (non-blocking listener polled every few ms), two
//! threads per connection: a reader that parses lines and submits to the
//! pool, and a writer that emits replies in submission order (so a batch
//! answered out of order across connections can never misroute within
//! one). [`NetServer::shutdown`] — triggered programmatically or by the
//! `SHUTDOWN` command via [`NetServer::wait_for_shutdown_request`] —
//! stops accepting, half-closes every connection's read side, lets each
//! writer flush all in-flight responses, joins the connection threads and
//! only then stops the worker pool: zero admitted requests are dropped.

use super::metrics::ServingMetrics;
use super::reload::ReloadableLtls;
use super::server::{BatchModel, PredictServer, Response, ServerConfig, SubmitError, Submitter};
use crate::util::json::Json;
use std::io::{BufRead, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Longest accepted request line (defends the per-connection read buffer
/// against a peer that never sends a newline).
const MAX_LINE: u64 = 1 << 20;
/// Largest accepted top-k (defends the per-request output allocation).
const MAX_K: usize = 4096;
/// Accept-loop poll interval (the listener is non-blocking so shutdown
/// can interrupt it without a wake-up connection).
const ACCEPT_POLL: Duration = Duration::from_millis(5);

/// Network frontend configuration.
#[derive(Clone, Debug, Default)]
pub struct NetConfig {
    /// The worker pool under the transport.
    pub server: ServerConfig,
    /// Admission bound: max requests submitted-but-unanswered across all
    /// connections (0 → 4 × the pool's queue depth). Over it, requests
    /// get an immediate backpressure error.
    pub max_inflight: usize,
    /// Per-connection share of the admission bound (0 → `max_inflight`
    /// / 4, at least 1). Bounds how much of the global budget one
    /// pipelining-but-not-reading client can pin while its writer sits
    /// in the write timeout, so a single bad client cannot backpressure
    /// everyone else.
    pub max_inflight_per_conn: usize,
}

/// State shared by the accept loop, every connection thread and the
/// server handle.
struct Shared {
    /// The worker pool; taken (once) by the graceful drain.
    pool: Mutex<Option<PredictServer>>,
    /// The pool's metrics, kept reachable after the pool is taken.
    metrics: Arc<ServingMetrics>,
    /// Hot-reload handle when the served model is swappable.
    reload: Option<Arc<ReloadableLtls>>,
    /// Feature bound of a non-reloadable model (reloadable models are
    /// queried live, since a reload may change D).
    static_features: Option<usize>,
    max_inflight: usize,
    /// Per-connection admission share (see [`NetConfig`]).
    per_conn_cap: usize,
    /// Requests admitted to the pool whose reply has not been written.
    inflight: AtomicUsize,
    /// Requests refused with a backpressure error.
    rejected: AtomicU64,
    /// Connections accepted over the server's lifetime.
    accepted_conns: AtomicU64,
    /// Set once the drain began: stop accepting, readers wind down.
    draining: AtomicBool,
    /// Set by the `SHUTDOWN` command; observed by
    /// [`NetServer::wait_for_shutdown_request`].
    shutdown_requested: Mutex<bool>,
    shutdown_cv: Condvar,
    /// Live connections (id → stream clone) so the drain can half-close
    /// blocked readers.
    conns: Mutex<Vec<(u64, TcpStream)>>,
    /// Count of live connection threads, for the drain barrier.
    live_conns: Mutex<usize>,
    conn_cv: Condvar,
}

impl Shared {
    /// The feature-index bound requests are validated against (live for
    /// reloadable models — a reload may change D).
    fn feature_bound(&self) -> Option<usize> {
        match &self.reload {
            Some(r) => Some(r.current_n_features()),
            None => self.static_features,
        }
    }

    fn request_shutdown(&self) {
        let mut g = self.shutdown_requested.lock().unwrap();
        *g = true;
        self.shutdown_cv.notify_all();
    }
}

/// Handle to a running network server (see the module docs).
pub struct NetServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
}

impl NetServer {
    /// Bind `listen` (e.g. `"127.0.0.1:7878"`; port 0 picks a free port —
    /// read it back from [`Self::addr`]) and serve `model` through a
    /// worker pool. `RELOAD` is refused on this server — use
    /// [`Self::start_reloadable`] for hot-swappable models.
    pub fn start<M: BatchModel>(
        listen: &str,
        model: M,
        cfg: NetConfig,
    ) -> Result<NetServer, String> {
        let static_features = model.n_features();
        NetServer::start_inner(listen, model, None, static_features, cfg)
    }

    /// [`Self::start`] over a hot-reloadable model: the same handle is
    /// installed in the worker pool and kept for the `RELOAD` command /
    /// `--watch-model` watcher.
    pub fn start_reloadable(
        listen: &str,
        model: Arc<ReloadableLtls>,
        cfg: NetConfig,
    ) -> Result<NetServer, String> {
        NetServer::start_inner(listen, Arc::clone(&model), Some(model), None, cfg)
    }

    fn start_inner<M: BatchModel>(
        listen: &str,
        model: M,
        reload: Option<Arc<ReloadableLtls>>,
        static_features: Option<usize>,
        cfg: NetConfig,
    ) -> Result<NetServer, String> {
        let listener = TcpListener::bind(listen).map_err(|e| format!("bind {listen}: {e}"))?;
        listener.set_nonblocking(true).map_err(|e| format!("listener: {e}"))?;
        let addr = listener.local_addr().map_err(|e| format!("listener: {e}"))?;
        let queue_depth = cfg.server.effective_queue_depth();
        let max_inflight = if cfg.max_inflight == 0 { queue_depth * 4 } else { cfg.max_inflight };
        let per_conn_cap = if cfg.max_inflight_per_conn == 0 {
            (max_inflight / 4).max(1)
        } else {
            cfg.max_inflight_per_conn
        };
        let pool = PredictServer::start(model, cfg.server.clone());
        let metrics = Arc::clone(&pool.metrics);
        let shared = Arc::new(Shared {
            pool: Mutex::new(Some(pool)),
            metrics,
            reload,
            static_features,
            max_inflight,
            per_conn_cap,
            inflight: AtomicUsize::new(0),
            rejected: AtomicU64::new(0),
            accepted_conns: AtomicU64::new(0),
            draining: AtomicBool::new(false),
            shutdown_requested: Mutex::new(false),
            shutdown_cv: Condvar::new(),
            conns: Mutex::new(Vec::new()),
            live_conns: Mutex::new(0),
            conn_cv: Condvar::new(),
        });
        let accept_shared = Arc::clone(&shared);
        let accept = std::thread::Builder::new()
            .name("ltls-net-accept".to_string())
            .spawn(move || accept_loop(&listener, &accept_shared))
            .map_err(|e| format!("spawn accept thread: {e}"))?;
        Ok(NetServer { addr, shared, accept: Some(accept) })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The worker pool's serving metrics.
    pub fn metrics(&self) -> Arc<ServingMetrics> {
        Arc::clone(&self.shared.metrics)
    }

    /// Worker threads in the pool (0 after the pool was drained).
    pub fn n_workers(&self) -> usize {
        self.shared.pool.lock().unwrap().as_ref().map(|p| p.n_workers()).unwrap_or(0)
    }

    /// Requests refused with a backpressure error so far.
    pub fn rejected(&self) -> u64 {
        self.shared.rejected.load(Ordering::Relaxed)
    }

    /// Connections accepted so far.
    pub fn accepted_connections(&self) -> u64 {
        self.shared.accepted_conns.load(Ordering::Relaxed)
    }

    /// True once a client issued `SHUTDOWN`.
    pub fn shutdown_requested(&self) -> bool {
        *self.shared.shutdown_requested.lock().unwrap()
    }

    /// Block until a client issues `SHUTDOWN` (the CLI's serve loop),
    /// then return — the caller performs the actual [`Self::shutdown`].
    pub fn wait_for_shutdown_request(&self) {
        let mut g = self.shared.shutdown_requested.lock().unwrap();
        while !*g {
            g = self.shared.shutdown_cv.wait(g).unwrap();
        }
    }

    /// Graceful drain: stop accepting, half-close every connection's read
    /// side (no new requests), let the writers flush every in-flight
    /// response, join all connection threads, then stop the worker pool.
    pub fn shutdown(mut self) {
        let shared = Arc::clone(&self.shared);
        shared.draining.store(true, Ordering::SeqCst);
        // Unblock readers stuck in read_line: no more requests come in,
        // but each connection's write side stays open until its writer
        // has flushed everything already admitted.
        for (_, s) in shared.conns.lock().unwrap().iter() {
            let _ = s.shutdown(Shutdown::Read);
        }
        {
            let mut live = shared.live_conns.lock().unwrap();
            while *live > 0 {
                let (g, _) =
                    shared.conn_cv.wait_timeout(live, Duration::from_millis(50)).unwrap();
                live = g;
            }
        }
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(pool) = shared.pool.lock().unwrap().take() {
            pool.shutdown();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        // Best-effort unwind for a handle dropped without `shutdown()`:
        // signal the accept loop and kick every connection loose. (After
        // a graceful `shutdown()` both are no-ops.)
        self.shared.draining.store(true, Ordering::SeqCst);
        if let Ok(conns) = self.shared.conns.lock() {
            for (_, s) in conns.iter() {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
    }
}

fn accept_loop(listener: &TcpListener, shared: &Arc<Shared>) {
    let mut next_id = 0u64;
    loop {
        if shared.draining.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                next_id += 1;
                // The stream may inherit the listener's non-blocking mode.
                let _ = stream.set_nonblocking(false);
                let _ = stream.set_nodelay(true);
                spawn_connection(shared, stream, next_id);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

/// A reply the writer thread must emit, in submission order.
enum Reply {
    /// Response pending from the worker pool.
    Pending(Receiver<Response>),
    /// Pre-rendered line (protocol errors, command replies, metrics).
    Immediate(String),
}

fn spawn_connection(shared: &Arc<Shared>, stream: TcpStream, id: u64) {
    let (write_stream, registry_stream) = match (stream.try_clone(), stream.try_clone()) {
        (Ok(a), Ok(b)) => (a, b),
        _ => return,
    };
    // One submission handle per connection: per-request admission then
    // contends only on the pool's queue channel, never on the shared
    // pool lock (that lock is taken once here and for control commands).
    let Some(submitter) = shared.pool.lock().unwrap().as_ref().map(|p| p.submitter()) else {
        return; // draining: the pool is already gone
    };
    // A peer that stops reading must not pin the writer (and with it the
    // graceful drain) on a full send buffer forever: time the write out,
    // mark the connection broken, and keep draining its replies.
    let _ = write_stream.set_write_timeout(Some(Duration::from_secs(10)));
    *shared.live_conns.lock().unwrap() += 1;
    shared.conns.lock().unwrap().push((id, registry_stream));
    shared.accepted_conns.fetch_add(1, Ordering::Relaxed);
    let conn_shared = Arc::clone(shared);
    let spawned = std::thread::Builder::new()
        .name(format!("ltls-net-conn-{id}"))
        .spawn(move || {
            let (tx, rx) = channel::<Reply>();
            // This connection's share of the admission budget: bumped at
            // admission (reader), released as replies are handed to the
            // writer — same window as the global counter.
            let conn_inflight = Arc::new(AtomicUsize::new(0));
            let writer_shared = Arc::clone(&conn_shared);
            let writer_inflight = Arc::clone(&conn_inflight);
            let writer = std::thread::Builder::new()
                .name(format!("ltls-net-write-{id}"))
                .spawn(move || writer_loop(&writer_shared, write_stream, &rx, &writer_inflight));
            if let Ok(writer) = writer {
                reader_loop(&conn_shared, stream, &tx, &submitter, &conn_inflight);
                // Closing the channel lets the writer finish flushing
                // everything already admitted, then exit.
                drop(tx);
                let _ = writer.join();
            }
            // Release the queue-keepalive before reporting this
            // connection gone, so the drain's worker join cannot observe
            // a dangling sender.
            drop(submitter);
            conn_shared.conns.lock().unwrap().retain(|(cid, _)| *cid != id);
            let mut live = conn_shared.live_conns.lock().unwrap();
            *live -= 1;
            conn_shared.conn_cv.notify_all();
        });
    if spawned.is_err() {
        shared.conns.lock().unwrap().retain(|(cid, _)| *cid != id);
        let mut live = shared.live_conns.lock().unwrap();
        *live -= 1;
        shared.conn_cv.notify_all();
    }
}

fn reader_loop(
    shared: &Arc<Shared>,
    stream: TcpStream,
    tx: &Sender<Reply>,
    submitter: &Submitter,
    conn_inflight: &AtomicUsize,
) {
    let mut reader = std::io::BufReader::new(stream);
    let mut line = String::new();
    loop {
        if shared.draining.load(Ordering::SeqCst) {
            break;
        }
        line.clear();
        // A fresh `take` each line re-arms the length budget.
        let n = match (&mut reader).take(MAX_LINE).read_line(&mut line) {
            Ok(0) => break, // EOF (client closed, or drain half-closed us)
            Ok(n) => n,
            Err(_) => break,
        };
        if n as u64 >= MAX_LINE && !line.ends_with('\n') {
            let _ = tx.send(Reply::Immediate(err_json(&format!(
                "request line exceeds {MAX_LINE} bytes"
            ))));
            break; // cannot resynchronize mid-line
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        if !handle_line(shared, trimmed, tx, submitter, conn_inflight) {
            break;
        }
    }
}

/// Handle one protocol line; returns `false` when the connection should
/// close (server shutting down).
fn handle_line(
    shared: &Arc<Shared>,
    line: &str,
    tx: &Sender<Reply>,
    submitter: &Submitter,
    conn_inflight: &AtomicUsize,
) -> bool {
    let mut words = line.split_whitespace();
    let head = words.next().unwrap_or("");
    match head {
        "PING" => {
            let _ = tx.send(Reply::Immediate("{\"ok\":true}".to_string()));
            return true;
        }
        "METRICS" => {
            let _ = tx.send(Reply::Immediate(render_metrics(shared)));
            return true;
        }
        "RELOAD" => {
            let _ = tx.send(Reply::Immediate(handle_reload(shared, words.next())));
            return true;
        }
        "SHUTDOWN" => {
            let _ = tx.send(Reply::Immediate("{\"ok\":true,\"draining\":true}".to_string()));
            shared.request_shutdown();
            return true;
        }
        _ => {}
    }
    match parse_request(line, shared.feature_bound()) {
        Err(e) => {
            let _ = tx.send(Reply::Immediate(err_json(&e)));
            true
        }
        Ok((k, indices, values)) => {
            // Admission control: this connection's share first (one
            // greedy pipelining client must not pin the whole budget),
            // then the global bound.
            let mine = conn_inflight.fetch_add(1, Ordering::SeqCst);
            if mine >= shared.per_conn_cap {
                conn_inflight.fetch_sub(1, Ordering::SeqCst);
                shared.rejected.fetch_add(1, Ordering::Relaxed);
                let _ = tx.send(Reply::Immediate(backpressure_json(
                    mine,
                    shared.per_conn_cap,
                    "on this connection",
                )));
                return true;
            }
            let admitted = shared.inflight.fetch_add(1, Ordering::SeqCst);
            if admitted >= shared.max_inflight {
                shared.inflight.fetch_sub(1, Ordering::SeqCst);
                conn_inflight.fetch_sub(1, Ordering::SeqCst);
                shared.rejected.fetch_add(1, Ordering::Relaxed);
                let _ = tx.send(Reply::Immediate(backpressure_json(
                    admitted,
                    shared.max_inflight,
                    "in flight",
                )));
                return true;
            }
            match submitter.try_submit(indices, values, k) {
                Ok(rx) => {
                    let _ = tx.send(Reply::Pending(rx));
                    true
                }
                Err(SubmitError::QueueFull) => {
                    shared.inflight.fetch_sub(1, Ordering::SeqCst);
                    conn_inflight.fetch_sub(1, Ordering::SeqCst);
                    shared.rejected.fetch_add(1, Ordering::Relaxed);
                    // Distinct from the admission-bound rejection: here
                    // the limit hit was the pool's --queue-depth, not
                    // --max-inflight.
                    let _ = tx.send(Reply::Immediate(queue_full_json()));
                    true
                }
                Err(SubmitError::Closed) => {
                    shared.inflight.fetch_sub(1, Ordering::SeqCst);
                    conn_inflight.fetch_sub(1, Ordering::SeqCst);
                    let _ = tx.send(Reply::Immediate(err_json("server is shutting down")));
                    false
                }
            }
        }
    }
}

fn handle_reload(shared: &Arc<Shared>, arg: Option<&str>) -> String {
    let Some(reload) = &shared.reload else {
        return err_json(
            "this server has no reloadable model (start `ltls serve --listen` with --model)",
        );
    };
    let result = match arg {
        Some(path) => reload.reload_from(Path::new(path)),
        None => reload.reload(),
    };
    match result {
        Ok(info) => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("epoch", Json::from(info.epoch as usize)),
            ("c", Json::from(info.c as usize)),
            ("width", Json::from(info.width as usize)),
            ("backend", Json::from(info.backend)),
            ("bytes", Json::from(info.bytes)),
            ("mapped", Json::Bool(info.mapped)),
        ])
        .dump(),
        Err(e) => err_json(&format!("reload failed (current model kept): {e}")),
    }
}

/// Parse `<k> <i:v> <i:v> ...` into a validated sparse request: features
/// sorted ascending, duplicates / non-finite values / out-of-range
/// indices rejected (the scoring kernels index weights by feature, so an
/// unchecked index would be an out-of-bounds access).
fn parse_request(
    line: &str,
    max_features: Option<usize>,
) -> Result<(usize, Vec<u32>, Vec<f32>), String> {
    let mut parts = line.split_whitespace();
    let ktok = parts.next().ok_or_else(|| "empty request".to_string())?;
    let k: usize = ktok
        .parse()
        .map_err(|_| format!("bad k {ktok:?} (want `<k> <i:v> <i:v> ...` or a command)"))?;
    if k == 0 {
        return Err("k must be at least 1".into());
    }
    if k > MAX_K {
        return Err(format!("k={k} exceeds the maximum {MAX_K}"));
    }
    let mut indices: Vec<u32> = Vec::new();
    let mut values: Vec<f32> = Vec::new();
    for tok in parts {
        let (i, v) =
            tok.split_once(':').ok_or_else(|| format!("bad feature token {tok:?} (want i:v)"))?;
        let i: u32 = i.parse().map_err(|_| format!("bad feature index in {tok:?}"))?;
        let v: f32 = v.parse().map_err(|_| format!("bad feature value in {tok:?}"))?;
        if !v.is_finite() {
            return Err(format!("non-finite feature value in {tok:?}"));
        }
        indices.push(i);
        values.push(v);
    }
    // The kernels expect ascending, distinct feature indices per example.
    let mut order: Vec<usize> = (0..indices.len()).collect();
    order.sort_by_key(|&j| indices[j]);
    if order.windows(2).any(|w| indices[w[0]] == indices[w[1]]) {
        return Err("duplicate feature index".into());
    }
    if order.iter().enumerate().any(|(pos, &j)| pos != j) {
        indices = order.iter().map(|&j| indices[j]).collect();
        values = order.iter().map(|&j| values[j]).collect();
    }
    if let (Some(d), Some(&top)) = (max_features, indices.last()) {
        if top as usize >= d {
            return Err(format!(
                "feature index {top} out of range (model expects indices below {d})"
            ));
        }
    }
    Ok((k, indices, values))
}

fn writer_loop(
    shared: &Arc<Shared>,
    stream: TcpStream,
    rx: &Receiver<Reply>,
    conn_inflight: &AtomicUsize,
) {
    use std::sync::mpsc::TryRecvError;
    let mut w = std::io::BufWriter::new(stream);
    let mut broken = false;
    // Burst batching: replies already queued (pipelined traffic) are
    // written back-to-back and flushed once per burst; the buffer is also
    // flushed before blocking on anything — the next queued reply or a
    // not-yet-computed response — so an unpipelined client never waits on
    // unflushed bytes.
    while let Ok(first) = rx.recv() {
        let mut next = Some(first);
        while let Some(reply) = next.take() {
            let line = match reply {
                Reply::Immediate(s) => s,
                Reply::Pending(resp) => {
                    let got = match resp.try_recv() {
                        Ok(r) => Ok(r),
                        Err(TryRecvError::Empty) => {
                            // About to block on the pool: flush what the
                            // client is already owed.
                            if !broken && w.flush().is_err() {
                                broken = true;
                            }
                            resp.recv()
                        }
                        Err(TryRecvError::Disconnected) => resp.recv(),
                    };
                    // The in-flight window closes when the reply is
                    // handed to the writer, whether or not the client is
                    // still there.
                    shared.inflight.fetch_sub(1, Ordering::SeqCst);
                    conn_inflight.fetch_sub(1, Ordering::SeqCst);
                    match got {
                        Ok(r) => render_response(&r),
                        Err(_) => err_json("server dropped the request (shutting down)"),
                    }
                }
            };
            if !broken {
                let ok = w.write_all(line.as_bytes()).and_then(|_| w.write_all(b"\n"));
                if ok.is_err() {
                    broken = true; // client gone: keep draining for accounting
                }
            }
            if let Ok(more) = rx.try_recv() {
                next = Some(more);
            }
        }
        if !broken && w.flush().is_err() {
            broken = true;
        }
    }
}

fn render_response(resp: &Response) -> String {
    Json::obj(vec![(
        "topk",
        Json::Arr(
            resp.topk
                .iter()
                .map(|&(l, s)| Json::Arr(vec![Json::Num(l as f64), Json::Num(s as f64)]))
                .collect(),
        ),
    )])
    .dump()
}

fn err_json(msg: &str) -> String {
    Json::obj(vec![("error", Json::from(msg))]).dump()
}

fn backpressure_json(inflight: usize, max: usize, scope: &str) -> String {
    Json::obj(vec![
        (
            "error",
            Json::Str(format!("backpressure: {inflight} requests {scope} (max {max})")),
        ),
        ("backpressure", Json::Bool(true)),
    ])
    .dump()
}

fn queue_full_json() -> String {
    Json::obj(vec![
        ("error", Json::from("backpressure: worker queue full, retry later")),
        ("backpressure", Json::Bool(true)),
    ])
    .dump()
}

/// The `METRICS` reply: the pool's prometheus block plus the transport's
/// own gauges, closed by a `# end` marker line.
fn render_metrics(shared: &Shared) -> String {
    use std::fmt::Write as _;
    let mut s = shared.metrics.prometheus();
    let _ = writeln!(s, "ltls_net_inflight {}", shared.inflight.load(Ordering::SeqCst));
    let _ = writeln!(s, "ltls_net_max_inflight {}", shared.max_inflight);
    let _ = writeln!(s, "ltls_net_max_inflight_per_conn {}", shared.per_conn_cap);
    let _ = writeln!(s, "ltls_net_rejected_total {}", shared.rejected.load(Ordering::Relaxed));
    let _ = writeln!(
        s,
        "ltls_net_connections_total {}",
        shared.accepted_conns.load(Ordering::Relaxed)
    );
    let _ = writeln!(s, "ltls_net_live_connections {}", *shared.live_conns.lock().unwrap());
    if let Some(r) = &shared.reload {
        let _ = writeln!(s, "ltls_model_epoch {}", r.epoch());
    }
    s.push_str("# end");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_accepts_and_sorts() {
        let (k, idx, val) = parse_request("3 5:1.5 2:2 7:0.25", Some(100)).unwrap();
        assert_eq!(k, 3);
        assert_eq!(idx, vec![2, 5, 7]);
        assert_eq!(val, vec![2.0, 1.5, 0.25]);
        // Featureless requests are legal (bias-only scoring).
        let (k, idx, _) = parse_request("1", None).unwrap();
        assert_eq!((k, idx.len()), (1, 0));
    }

    #[test]
    fn parse_request_rejects_malformed() {
        assert!(parse_request("", Some(10)).is_err());
        assert!(parse_request("0 1:1", Some(10)).is_err()); // k = 0
        assert!(parse_request("x 1:1", Some(10)).is_err()); // bad k
        assert!(parse_request("1 nocolon", Some(10)).is_err());
        assert!(parse_request("1 a:1", Some(10)).is_err());
        assert!(parse_request("1 1:abc", Some(10)).is_err());
        assert!(parse_request("1 1:NaN", Some(10)).is_err());
        assert!(parse_request("1 1:inf", Some(10)).is_err());
        assert!(parse_request("1 3:1 3:2", Some(10)).is_err()); // duplicate
        assert!(parse_request("1 10:1", Some(10)).is_err()); // out of range
        assert!(parse_request("1 9:1", Some(10)).is_ok()); // boundary
        let big_k = format!("{} 1:1", MAX_K + 1);
        assert!(parse_request(&big_k, Some(10)).is_err());
    }

    #[test]
    fn response_and_error_rendering_is_parseable_json() {
        let r = Response { topk: vec![(7, 1.5), (2, -0.25)] };
        let doc = Json::parse(&render_response(&r)).unwrap();
        let topk = doc.get("topk").unwrap().as_arr().unwrap();
        assert_eq!(topk.len(), 2);
        assert_eq!(topk[0].as_arr().unwrap()[0].as_f64(), Some(7.0));
        assert_eq!(topk[1].as_arr().unwrap()[1].as_f64(), Some(-0.25));
        let e = Json::parse(&err_json("boom \"quoted\"")).unwrap();
        assert_eq!(e.get("error").unwrap().as_str(), Some("boom \"quoted\""));
        let b = Json::parse(&backpressure_json(9, 8, "in flight")).unwrap();
        assert_eq!(b.get("backpressure"), Some(&Json::Bool(true)));
        assert!(b.get("error").unwrap().as_str().unwrap().contains("9"));
        let q = Json::parse(&queue_full_json()).unwrap();
        assert_eq!(q.get("backpressure"), Some(&Json::Bool(true)));
    }
}
