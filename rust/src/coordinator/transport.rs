//! The network serving frontend: a std-only TCP transport in front of the
//! batching multi-worker prediction pool (`ltls serve --listen HOST:PORT`).
//!
//! The wire protocol (newline-delimited text; one reply line per request
//! line, in submission order per connection) is specified normatively in
//! `docs/PROTOCOL.md` — framing, the request/response grammar, the
//! PING / METRICS / TRACE / RELOAD / SHUTDOWN commands, the backpressure
//! error shape and the drain semantics live there, not here. The
//! crate-level picture (which layer does what, life of a request) is
//! `docs/ARCHITECTURE.md`. Request-lifecycle tracing (the spans behind
//! the `TRACE` command, `--trace-sample` / `--trace-slow-ms`) is
//! [`crate::obs::trace`], documented in `docs/OBSERVABILITY.md`.
//!
//! Two interchangeable transports implement that contract behind one
//! [`NetServer`] handle, selected by [`NetConfig::transport`]:
//!
//! * [`Transport::EventLoop`] (default) — `O_NONBLOCK` sockets
//!   multiplexed by a small fixed pool of poll(2) threads
//!   ([`super::event_loop`]); scales to thousands of connections.
//! * [`Transport::Threads`] — the original two-threads-per-connection
//!   frontend, kept as the behavioral oracle (`--transport threads`);
//!   simple and debuggable, but capped at a few hundred connections.
//!
//! Both share this module's protocol core — [`handle_line`] (command
//! dispatch, request validation, two-level admission control) and the
//! render helpers — so a reply is byte-identical whichever transport
//! produced it; `tests/serve_network.rs` pins that by running its whole
//! suite against each transport.

use super::metrics::{ServingMetrics, TransportGauges};
use super::reload::ReloadableLtls;
use super::server::{BatchModel, PredictServer, Response, ServerConfig, SubmitError, Submitter};
use crate::obs::{render_counter, render_gauge, Span, Stage, Tracer};
use crate::util::json::Json;
use std::io::{BufRead, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Longest accepted request line (defends the per-connection read buffer
/// against a peer that never sends a newline).
pub(crate) const MAX_LINE: u64 = 1 << 20;
/// Largest accepted top-k (defends the per-request output allocation).
const MAX_K: usize = 4096;
/// Accept-loop poll interval of the threaded transport (its listener is
/// non-blocking so shutdown can interrupt it without a wake-up
/// connection; the event loop polls the listener fd instead).
const ACCEPT_POLL: Duration = Duration::from_millis(5);

/// Which frontend multiplexes the connections (see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transport {
    /// Two threads per connection (reader + writer). The pinned oracle.
    Threads,
    /// poll(2) event loop: a fixed pool of poll threads multiplexing
    /// every connection through nonblocking sockets. Unix-only; other
    /// platforms fall back to [`Transport::Threads`].
    EventLoop,
}

impl Default for Transport {
    fn default() -> Self {
        if cfg!(unix) {
            Transport::EventLoop
        } else {
            Transport::Threads
        }
    }
}

impl std::str::FromStr for Transport {
    type Err = String;
    fn from_str(s: &str) -> Result<Transport, String> {
        match s {
            "threads" => Ok(Transport::Threads),
            "event-loop" | "event_loop" | "eventloop" => Ok(Transport::EventLoop),
            other => Err(format!("unknown transport {other:?} (want threads | event-loop)")),
        }
    }
}

impl std::fmt::Display for Transport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Transport::Threads => write!(f, "threads"),
            Transport::EventLoop => write!(f, "event-loop"),
        }
    }
}

/// Network frontend configuration.
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// The worker pool under the transport.
    pub server: ServerConfig,
    /// Admission bound: max requests submitted-but-unanswered across all
    /// connections (0 → 4 × the pool's queue depth). Over it, requests
    /// get an immediate backpressure error.
    pub max_inflight: usize,
    /// Per-connection share of the admission bound (0 → `max_inflight`
    /// / 4, at least 1). Bounds how much of the global budget one
    /// pipelining-but-not-reading client can pin while its replies wait
    /// on the write side, so a single bad client cannot backpressure
    /// everyone else.
    pub max_inflight_per_conn: usize,
    /// Which connection frontend to run (default: event loop on unix).
    pub transport: Transport,
    /// Poll threads of the event-loop transport (0 → `min(4, cores)`).
    /// Ignored by [`Transport::Threads`].
    pub poll_threads: usize,
    /// Per-connection buffered-reply high-water mark in bytes
    /// (0 → 256 KiB). Over it the event loop stops *reading* that
    /// connection — backpressure on the pipe — instead of buffering
    /// replies unboundedly for a client that has stopped draining them.
    pub conn_buf_bytes: usize,
    /// How long a connection's write side may make zero progress before
    /// it is declared dead and its buffered replies are discarded
    /// (0 → 10 000 ms). Progress resets the clock, so an alive-but-slow
    /// reader is never torn down mid-frame.
    pub write_stall_ms: u64,
    /// Record every Nth prediction request's span timeline into the
    /// sampled trace ring (`--trace-sample`, drained by the `TRACE`
    /// command). 0 disables sampling. Default: 64.
    pub trace_sample: u64,
    /// Capture *any* request slower than this many milliseconds into the
    /// slow-trace ring, regardless of sampling (`--trace-slow-ms`).
    /// 0 disables slow capture. Default: 100.
    pub trace_slow_ms: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            server: ServerConfig::default(),
            max_inflight: 0,
            max_inflight_per_conn: 0,
            transport: Transport::default(),
            poll_threads: 0,
            conn_buf_bytes: 0,
            write_stall_ms: 0,
            trace_sample: 64,
            trace_slow_ms: 100,
        }
    }
}

impl NetConfig {
    /// The tracer this configuration asks for (`trace_sample: 0` and
    /// `trace_slow_ms: 0` together mean tracing is fully off).
    pub fn tracer(&self) -> Tracer {
        Tracer::new(self.trace_sample, self.trace_slow_ms.saturating_mul(1_000_000))
    }

    /// The resolved write-stall budget (`0 → 10s`).
    pub fn write_stall(&self) -> Duration {
        if self.write_stall_ms == 0 {
            Duration::from_secs(10)
        } else {
            Duration::from_millis(self.write_stall_ms)
        }
    }

    /// The resolved per-connection reply high-water mark (`0 → 256 KiB`).
    pub fn wbuf_cap(&self) -> usize {
        if self.conn_buf_bytes == 0 {
            256 << 10
        } else {
            self.conn_buf_bytes
        }
    }

    /// The resolved poll-thread count (`0 → min(4, cores)`).
    pub fn n_poll_threads(&self) -> usize {
        if self.poll_threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(4)
        } else {
            self.poll_threads
        }
    }
}

/// State shared by both transports' connection handling and the server
/// handle: the pool, admission bounds and counters, drain signaling.
pub(crate) struct Shared {
    /// The worker pool; taken (once) by the graceful drain.
    pub(crate) pool: Mutex<Option<PredictServer>>,
    /// The pool's metrics, kept reachable after the pool is taken.
    pub(crate) metrics: Arc<ServingMetrics>,
    /// Hot-reload handle when the served model is swappable.
    reload: Option<Arc<ReloadableLtls>>,
    /// Feature bound of a non-reloadable model (reloadable models are
    /// queried live, since a reload may change D).
    static_features: Option<usize>,
    max_inflight: usize,
    /// Per-connection admission share (see [`NetConfig`]).
    per_conn_cap: usize,
    /// Requests admitted to the pool whose reply has not been written.
    inflight: AtomicUsize,
    /// Requests refused with a backpressure error.
    rejected: AtomicU64,
    /// Connections accepted over the server's lifetime.
    pub(crate) accepted_conns: AtomicU64,
    /// Set once the drain began: stop accepting, readers wind down.
    pub(crate) draining: AtomicBool,
    /// Set by the `SHUTDOWN` command; observed by
    /// [`NetServer::wait_for_shutdown_request`].
    shutdown_requested: Mutex<bool>,
    shutdown_cv: Condvar,
    /// Live connections (id → stream clone) so the threaded transport's
    /// drain can half-close blocked readers. (The event loop owns its
    /// streams directly and leaves this empty.)
    conns: Mutex<Vec<(u64, TcpStream)>>,
    /// Count of live connections, for the drain barrier and metrics.
    pub(crate) live_conns: Mutex<usize>,
    pub(crate) conn_cv: Condvar,
    /// Transport-level gauges (open conns, poll wakeups, write-buf peak).
    pub(crate) gauges: TransportGauges,
    /// Scatter-tier stats when this server fronts a sharded model
    /// ([`NetServer::start_scatter`]); `None` on single-process servers
    /// (the shard metric families render zero-valued so the scrape name
    /// set is topology-independent).
    scatter: Option<Arc<super::scatter::ScatterStats>>,
    /// Request-lifecycle tracer: decides which requests carry a [`Span`],
    /// owns the sampled / slow capture rings behind the `TRACE` command.
    pub(crate) tracer: Arc<Tracer>,
    /// Write-stall budget (see [`NetConfig::write_stall_ms`]).
    pub(crate) write_stall: Duration,
    /// Per-connection reply high-water mark (event loop read pausing).
    pub(crate) wbuf_cap: usize,
}

impl Shared {
    /// The feature-index bound requests are validated against (live for
    /// reloadable models — a reload may change D).
    fn feature_bound(&self) -> Option<usize> {
        match &self.reload {
            Some(r) => Some(r.current_n_features()),
            None => self.static_features,
        }
    }

    fn request_shutdown(&self) {
        let mut g = self.shutdown_requested.lock().unwrap();
        *g = true;
        self.shutdown_cv.notify_all();
    }

    /// Close one admitted request's in-flight window (reply handed to
    /// the connection's write side, whether or not the client is still
    /// there). Pairs with the admission bumps in [`handle_line`].
    pub(crate) fn release_inflight(&self, conn_inflight: &AtomicUsize) {
        self.inflight.fetch_sub(1, Ordering::SeqCst);
        conn_inflight.fetch_sub(1, Ordering::SeqCst);
    }
}

/// The per-transport machinery behind a [`NetServer`].
enum Backend {
    Threads {
        accept: Option<JoinHandle<()>>,
    },
    #[cfg(unix)]
    EventLoop(super::event_loop::EventLoopHandle),
}

/// Handle to a running network server (see the module docs).
pub struct NetServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    transport: Transport,
    backend: Backend,
}

impl NetServer {
    /// Bind `listen` (e.g. `"127.0.0.1:7878"`; port 0 picks a free port —
    /// read it back from [`Self::addr`]) and serve `model` through a
    /// worker pool. `RELOAD` is refused on this server — use
    /// [`Self::start_reloadable`] for hot-swappable models.
    pub fn start<M: BatchModel>(
        listen: &str,
        model: M,
        cfg: NetConfig,
    ) -> Result<NetServer, String> {
        let static_features = model.n_features();
        NetServer::start_inner(listen, model, None, static_features, None, cfg)
    }

    /// [`Self::start`] over a hot-reloadable model: the same handle is
    /// installed in the worker pool and kept for the `RELOAD` command /
    /// `--watch-model` watcher.
    pub fn start_reloadable(
        listen: &str,
        model: Arc<ReloadableLtls>,
        cfg: NetConfig,
    ) -> Result<NetServer, String> {
        NetServer::start_inner(listen, Arc::clone(&model), Some(model), None, None, cfg)
    }

    /// [`Self::start`] over the scatter-gather coordinator
    /// ([`super::scatter::ScatterModel`]): same frontend and protocol,
    /// plus live `ltls_shard_*` metric families in the exposition.
    pub fn start_scatter(
        listen: &str,
        model: super::scatter::ScatterModel,
        cfg: NetConfig,
    ) -> Result<NetServer, String> {
        let stats = model.stats();
        let static_features = model.n_features();
        NetServer::start_inner(listen, model, None, static_features, Some(stats), cfg)
    }

    fn start_inner<M: BatchModel>(
        listen: &str,
        model: M,
        reload: Option<Arc<ReloadableLtls>>,
        static_features: Option<usize>,
        scatter: Option<Arc<super::scatter::ScatterStats>>,
        cfg: NetConfig,
    ) -> Result<NetServer, String> {
        let listener = TcpListener::bind(listen).map_err(|e| format!("bind {listen}: {e}"))?;
        listener.set_nonblocking(true).map_err(|e| format!("listener: {e}"))?;
        let addr = listener.local_addr().map_err(|e| format!("listener: {e}"))?;
        let queue_depth = cfg.server.effective_queue_depth();
        let max_inflight = if cfg.max_inflight == 0 { queue_depth * 4 } else { cfg.max_inflight };
        let per_conn_cap = if cfg.max_inflight_per_conn == 0 {
            (max_inflight / 4).max(1)
        } else {
            cfg.max_inflight_per_conn
        };
        // The poll(2) shim is unix-only; elsewhere the threaded transport
        // is the only one available.
        let transport = if cfg!(unix) { cfg.transport } else { Transport::Threads };
        let pool = PredictServer::start(model, cfg.server.clone());
        let metrics = Arc::clone(&pool.metrics);
        let shared = Arc::new(Shared {
            pool: Mutex::new(Some(pool)),
            metrics,
            reload,
            static_features,
            max_inflight,
            per_conn_cap,
            inflight: AtomicUsize::new(0),
            rejected: AtomicU64::new(0),
            accepted_conns: AtomicU64::new(0),
            draining: AtomicBool::new(false),
            shutdown_requested: Mutex::new(false),
            shutdown_cv: Condvar::new(),
            conns: Mutex::new(Vec::new()),
            live_conns: Mutex::new(0),
            conn_cv: Condvar::new(),
            gauges: TransportGauges::new(),
            scatter,
            tracer: Arc::new(cfg.tracer()),
            write_stall: cfg.write_stall(),
            wbuf_cap: cfg.wbuf_cap(),
        });
        let backend = match transport {
            Transport::Threads => {
                let accept_shared = Arc::clone(&shared);
                let accept = std::thread::Builder::new()
                    .name("ltls-net-accept".to_string())
                    .spawn(move || accept_loop(&listener, &accept_shared))
                    .map_err(|e| format!("spawn accept thread: {e}"))?;
                Backend::Threads { accept: Some(accept) }
            }
            #[cfg(unix)]
            Transport::EventLoop => Backend::EventLoop(
                super::event_loop::EventLoopHandle::spawn(
                    listener,
                    Arc::clone(&shared),
                    cfg.n_poll_threads(),
                )
                .map_err(|e| format!("spawn event loop: {e}"))?,
            ),
            #[cfg(not(unix))]
            Transport::EventLoop => unreachable!("resolved to Threads above"),
        };
        Ok(NetServer { addr, shared, transport, backend })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The transport actually running (the configured one, except on
    /// non-unix platforms where the event loop falls back to threads).
    pub fn transport(&self) -> Transport {
        self.transport
    }

    /// The worker pool's serving metrics.
    pub fn metrics(&self) -> Arc<ServingMetrics> {
        Arc::clone(&self.shared.metrics)
    }

    /// Worker threads in the pool (0 after the pool was drained).
    pub fn n_workers(&self) -> usize {
        self.shared.pool.lock().unwrap().as_ref().map(|p| p.n_workers()).unwrap_or(0)
    }

    /// Requests refused with a backpressure error so far.
    pub fn rejected(&self) -> u64 {
        self.shared.rejected.load(Ordering::Relaxed)
    }

    /// Connections accepted so far.
    pub fn accepted_connections(&self) -> u64 {
        self.shared.accepted_conns.load(Ordering::Relaxed)
    }

    /// Peak buffered-reply bytes any single connection reached.
    pub fn write_buf_peak(&self) -> usize {
        self.shared.gauges.write_buf_peak()
    }

    /// True once a client issued `SHUTDOWN`.
    pub fn shutdown_requested(&self) -> bool {
        *self.shared.shutdown_requested.lock().unwrap()
    }

    /// Block until a client issues `SHUTDOWN` (the CLI's serve loop),
    /// then return — the caller performs the actual [`Self::shutdown`].
    pub fn wait_for_shutdown_request(&self) {
        let mut g = self.shared.shutdown_requested.lock().unwrap();
        while !*g {
            g = self.shared.shutdown_cv.wait(g).unwrap();
        }
    }

    /// Graceful drain: stop accepting, half-close every connection's read
    /// side (no new requests), let the write sides flush every in-flight
    /// response, join the transport threads, then stop the worker pool.
    pub fn shutdown(mut self) {
        let shared = Arc::clone(&self.shared);
        shared.draining.store(true, Ordering::SeqCst);
        match &mut self.backend {
            Backend::Threads { accept } => {
                // Unblock readers stuck in read_line: no more requests
                // come in, but each connection's write side stays open
                // until its writer has flushed everything admitted.
                for (_, s) in shared.conns.lock().unwrap().iter() {
                    let _ = s.shutdown(Shutdown::Read);
                }
                {
                    let mut live = shared.live_conns.lock().unwrap();
                    while *live > 0 {
                        let (g, _) = shared
                            .conn_cv
                            .wait_timeout(live, Duration::from_millis(50))
                            .unwrap();
                        live = g;
                    }
                }
                if let Some(h) = accept.take() {
                    let _ = h.join();
                }
            }
            #[cfg(unix)]
            Backend::EventLoop(h) => {
                // Wake every poll thread; each half-closes its
                // connections, flushes what is owed and exits once its
                // set is empty. Joining them is the drain barrier.
                h.kick();
                h.join();
            }
        }
        if let Some(pool) = shared.pool.lock().unwrap().take() {
            pool.shutdown();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        // Best-effort unwind for a handle dropped without `shutdown()`:
        // signal the transport threads and kick every connection loose.
        // (After a graceful `shutdown()` this is a no-op.)
        self.shared.draining.store(true, Ordering::SeqCst);
        match &self.backend {
            Backend::Threads { .. } => {
                if let Ok(conns) = self.shared.conns.lock() {
                    for (_, s) in conns.iter() {
                        let _ = s.shutdown(Shutdown::Both);
                    }
                }
            }
            #[cfg(unix)]
            Backend::EventLoop(h) => h.kick(),
        }
    }
}

fn accept_loop(listener: &TcpListener, shared: &Arc<Shared>) {
    let mut next_id = 0u64;
    loop {
        if shared.draining.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                next_id += 1;
                // The stream may inherit the listener's non-blocking mode.
                let _ = stream.set_nonblocking(false);
                let _ = stream.set_nodelay(true);
                spawn_connection(shared, stream, next_id);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

/// A reply the writer thread must emit, in submission order.
enum Reply {
    /// Response pending from the worker pool, with the request's trace
    /// span (if any) for the `serialize` / `write` stamps.
    Pending(Receiver<Response>, Option<Span>),
    /// Pre-rendered line (protocol errors, command replies, metrics).
    Immediate(String),
}

fn spawn_connection(shared: &Arc<Shared>, stream: TcpStream, id: u64) {
    let (write_stream, registry_stream) = match (stream.try_clone(), stream.try_clone()) {
        (Ok(a), Ok(b)) => (a, b),
        _ => return,
    };
    // One submission handle per connection: per-request admission then
    // contends only on the pool's queue channel, never on the shared
    // pool lock (that lock is taken once here and for control commands).
    let Some(submitter) = shared.pool.lock().unwrap().as_ref().map(|p| p.submitter()) else {
        return; // draining: the pool is already gone
    };
    *shared.live_conns.lock().unwrap() += 1;
    shared.conns.lock().unwrap().push((id, registry_stream));
    shared.accepted_conns.fetch_add(1, Ordering::Relaxed);
    shared.gauges.conn_opened();
    let conn_shared = Arc::clone(shared);
    let spawned = std::thread::Builder::new()
        .name(format!("ltls-net-conn-{id}"))
        .spawn(move || {
            let (tx, rx) = channel::<Reply>();
            // This connection's share of the admission budget: bumped at
            // admission (reader), released as replies are handed to the
            // writer — same window as the global counter.
            let conn_inflight = Arc::new(AtomicUsize::new(0));
            let writer_shared = Arc::clone(&conn_shared);
            let writer_inflight = Arc::clone(&conn_inflight);
            let writer = std::thread::Builder::new()
                .name(format!("ltls-net-write-{id}"))
                .spawn(move || writer_loop(&writer_shared, write_stream, &rx, &writer_inflight));
            if let Ok(writer) = writer {
                reader_loop(&conn_shared, stream, &tx, &submitter, &conn_inflight);
                // Closing the channel lets the writer finish flushing
                // everything already admitted, then exit.
                drop(tx);
                let _ = writer.join();
            }
            // Release the queue-keepalive before reporting this
            // connection gone, so the drain's worker join cannot observe
            // a dangling sender.
            drop(submitter);
            conn_shared.conns.lock().unwrap().retain(|(cid, _)| *cid != id);
            conn_shared.gauges.conn_closed();
            let mut live = conn_shared.live_conns.lock().unwrap();
            *live -= 1;
            conn_shared.conn_cv.notify_all();
        });
    if spawned.is_err() {
        shared.conns.lock().unwrap().retain(|(cid, _)| *cid != id);
        shared.gauges.conn_closed();
        let mut live = shared.live_conns.lock().unwrap();
        *live -= 1;
        shared.conn_cv.notify_all();
    }
}

fn reader_loop(
    shared: &Arc<Shared>,
    stream: TcpStream,
    tx: &Sender<Reply>,
    submitter: &Submitter,
    conn_inflight: &AtomicUsize,
) {
    let mut reader = std::io::BufReader::new(stream);
    let mut line = String::new();
    loop {
        if shared.draining.load(Ordering::SeqCst) {
            break;
        }
        line.clear();
        // A fresh `take` each line re-arms the length budget.
        let n = match (&mut reader).take(MAX_LINE).read_line(&mut line) {
            Ok(0) => break, // EOF (client closed, or drain half-closed us)
            Ok(n) => n,
            Err(_) => break,
        };
        if n as u64 >= MAX_LINE && !line.ends_with('\n') {
            let _ = tx.send(Reply::Immediate(oversized_line_json()));
            break; // cannot resynchronize mid-line
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let outcome = handle_line(shared, trimmed, conn_inflight, &mut |i, v, k, sp| {
            submitter.try_submit_full(i, v, k, sp, None)
        });
        let close = outcome.close;
        let _ = tx.send(match outcome.reply {
            LineReply::Immediate(s) => Reply::Immediate(s),
            LineReply::Pending(rx, sp) => Reply::Pending(rx, sp),
        });
        if close {
            break;
        }
    }
}

/// The reply to one protocol line, plus whether the connection must
/// close after emitting it (server shutting down).
pub(crate) struct LineOutcome {
    pub(crate) reply: LineReply,
    pub(crate) close: bool,
}

pub(crate) enum LineReply {
    /// Pre-rendered line (protocol errors, command replies, metrics).
    Immediate(String),
    /// Response pending from the worker pool; emit it — in submission
    /// order — once received, then release the admission window. The
    /// span (if this request is traced) takes the `serialize` / `write`
    /// stamps and is finished after the reply is handed to the socket
    /// write path.
    Pending(Receiver<Response>, Option<Span>),
}

impl LineOutcome {
    fn reply(s: String) -> LineOutcome {
        LineOutcome { reply: LineReply::Immediate(s), close: false }
    }
}

/// How a transport hands a validated `(indices, values, k, span)` request
/// to the worker pool (the event loop submits with a completion hook, the
/// threaded transport plainly).
pub(crate) type SubmitFn<'a> = &'a mut dyn FnMut(
    Vec<u32>,
    Vec<f32>,
    usize,
    Option<Span>,
) -> Result<Receiver<Response>, SubmitError>;

/// The transport-independent protocol core: command dispatch, request
/// validation and the two-level admission control over one line.
/// `submit` hands a validated request to the pool; admission accounting
/// around it is identical for both transports — which is what keeps
/// their replies byte-identical.
pub(crate) fn handle_line(
    shared: &Shared,
    line: &str,
    conn_inflight: &AtomicUsize,
    submit: SubmitFn<'_>,
) -> LineOutcome {
    let mut words = line.split_whitespace();
    let head = words.next().unwrap_or("");
    match head {
        "PING" => return LineOutcome::reply("{\"ok\":true}".to_string()),
        "METRICS" => return LineOutcome::reply(render_metrics(shared)),
        "TRACE" => return LineOutcome::reply(render_trace(shared)),
        "RELOAD" => return LineOutcome::reply(handle_reload(shared, words.next())),
        "SHUTDOWN" => {
            shared.request_shutdown();
            return LineOutcome::reply("{\"ok\":true,\"draining\":true}".to_string());
        }
        _ => {}
    }
    // The span (if this request draws one) anchors at `accept`: the line
    // is already off the socket, parsing has not begun. Requests that
    // fail parsing or admission drop their span unrecorded.
    let span = shared.tracer.begin();
    match parse_request(line, shared.feature_bound()) {
        Err(e) => LineOutcome::reply(err_json(&e)),
        Ok((k, indices, values)) => {
            if let Some(sp) = &span {
                sp.stamp(Stage::Parse);
            }
            // Admission control: this connection's share first (one
            // greedy pipelining client must not pin the whole budget),
            // then the global bound.
            let mine = conn_inflight.fetch_add(1, Ordering::SeqCst);
            if mine >= shared.per_conn_cap {
                conn_inflight.fetch_sub(1, Ordering::SeqCst);
                shared.rejected.fetch_add(1, Ordering::Relaxed);
                return LineOutcome::reply(backpressure_json(
                    mine,
                    shared.per_conn_cap,
                    "on this connection",
                ));
            }
            let admitted = shared.inflight.fetch_add(1, Ordering::SeqCst);
            if admitted >= shared.max_inflight {
                shared.inflight.fetch_sub(1, Ordering::SeqCst);
                conn_inflight.fetch_sub(1, Ordering::SeqCst);
                shared.rejected.fetch_add(1, Ordering::Relaxed);
                return LineOutcome::reply(backpressure_json(
                    admitted,
                    shared.max_inflight,
                    "in flight",
                ));
            }
            if let Some(sp) = &span {
                sp.stamp(Stage::Admit);
            }
            match submit(indices, values, k, span.clone()) {
                Ok(rx) => LineOutcome { reply: LineReply::Pending(rx, span), close: false },
                Err(SubmitError::QueueFull) => {
                    shared.inflight.fetch_sub(1, Ordering::SeqCst);
                    conn_inflight.fetch_sub(1, Ordering::SeqCst);
                    shared.rejected.fetch_add(1, Ordering::Relaxed);
                    // Distinct from the admission-bound rejection: here
                    // the limit hit was the pool's --queue-depth, not
                    // --max-inflight.
                    LineOutcome::reply(queue_full_json())
                }
                Err(SubmitError::Closed) => {
                    shared.inflight.fetch_sub(1, Ordering::SeqCst);
                    conn_inflight.fetch_sub(1, Ordering::SeqCst);
                    LineOutcome {
                        reply: LineReply::Immediate(err_json("server is shutting down")),
                        close: true,
                    }
                }
            }
        }
    }
}

fn handle_reload(shared: &Shared, arg: Option<&str>) -> String {
    let Some(reload) = &shared.reload else {
        return err_json(
            "this server has no reloadable model (start `ltls serve --listen` with --model)",
        );
    };
    let result = match arg {
        Some(path) => reload.reload_from(Path::new(path)),
        None => reload.reload(),
    };
    match result {
        Ok(info) => Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("epoch", Json::from(info.epoch as usize)),
            ("c", Json::from(info.c as usize)),
            ("width", Json::from(info.width as usize)),
            ("backend", Json::from(info.backend)),
            ("bytes", Json::from(info.bytes)),
            ("mapped", Json::Bool(info.mapped)),
        ])
        .dump(),
        Err(e) => err_json(&format!("reload failed (current model kept): {e}")),
    }
}

/// Parse `<k> <i:v> <i:v> ...` into a validated sparse request: features
/// sorted ascending, duplicates / non-finite values / out-of-range
/// indices rejected (the scoring kernels index weights by feature, so an
/// unchecked index would be an out-of-bounds access).
fn parse_request(
    line: &str,
    max_features: Option<usize>,
) -> Result<(usize, Vec<u32>, Vec<f32>), String> {
    let mut parts = line.split_whitespace();
    let ktok = parts.next().ok_or_else(|| "empty request".to_string())?;
    let k: usize = ktok
        .parse()
        .map_err(|_| format!("bad k {ktok:?} (want `<k> <i:v> <i:v> ...` or a command)"))?;
    if k == 0 {
        return Err("k must be at least 1".into());
    }
    if k > MAX_K {
        return Err(format!("k={k} exceeds the maximum {MAX_K}"));
    }
    let mut indices: Vec<u32> = Vec::new();
    let mut values: Vec<f32> = Vec::new();
    for tok in parts {
        let (i, v) =
            tok.split_once(':').ok_or_else(|| format!("bad feature token {tok:?} (want i:v)"))?;
        let i: u32 = i.parse().map_err(|_| format!("bad feature index in {tok:?}"))?;
        let v: f32 = v.parse().map_err(|_| format!("bad feature value in {tok:?}"))?;
        if !v.is_finite() {
            return Err(format!("non-finite feature value in {tok:?}"));
        }
        indices.push(i);
        values.push(v);
    }
    // The kernels expect ascending, distinct feature indices per example.
    let mut order: Vec<usize> = (0..indices.len()).collect();
    order.sort_by_key(|&j| indices[j]);
    if order.windows(2).any(|w| indices[w[0]] == indices[w[1]]) {
        return Err("duplicate feature index".into());
    }
    if order.iter().enumerate().any(|(pos, &j)| pos != j) {
        indices = order.iter().map(|&j| indices[j]).collect();
        values = order.iter().map(|&j| values[j]).collect();
    }
    if let (Some(d), Some(&top)) = (max_features, indices.last()) {
        if top as usize >= d {
            return Err(format!(
                "feature index {top} out of range (model expects indices below {d})"
            ));
        }
    }
    Ok((k, indices, values))
}

/// Write `buf` to `stream` in full, tolerating short writes and timeout
/// slices as long as the peer keeps accepting bytes within `stall` of
/// the last progress. Frames are never torn: either the whole buffer
/// lands on the socket, or the connection is declared dead (hard error,
/// peer closed, or zero progress for a full stall budget) and `broken`
/// is set. The buffer is consumed either way.
fn flush_frames(
    stream: &mut TcpStream,
    buf: &mut Vec<u8>,
    stall: Duration,
    broken: &mut bool,
) -> bool {
    use std::io::ErrorKind;
    if *broken || buf.is_empty() {
        buf.clear();
        return !*broken;
    }
    let mut off = 0usize;
    let mut last_progress = Instant::now();
    while off < buf.len() {
        match stream.write(&buf[off..]) {
            Ok(0) => {
                *broken = true;
                break;
            }
            Ok(n) => {
                off += n;
                last_progress = Instant::now();
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                // A stalled-but-alive reader gets the full stall budget
                // from its *last* progress, not from the frame's start —
                // slow is fine, stuck is not.
                if last_progress.elapsed() >= stall {
                    *broken = true;
                    break;
                }
            }
            Err(_) => {
                *broken = true;
                break;
            }
        }
    }
    buf.clear();
    !*broken
}

fn writer_loop(
    shared: &Arc<Shared>,
    mut stream: TcpStream,
    rx: &Receiver<Reply>,
    conn_inflight: &AtomicUsize,
) {
    use std::sync::mpsc::TryRecvError;
    let stall = shared.write_stall;
    // Short blocking-write slices so the stall clock is checked a few
    // times per budget; progress within a slice resets it.
    let slice = (stall / 4).clamp(Duration::from_millis(10), Duration::from_secs(1));
    let _ = stream.set_write_timeout(Some(slice));
    let mut out: Vec<u8> = Vec::with_capacity(8 << 10);
    let mut broken = false;
    // Burst batching: replies already queued (pipelined traffic) are
    // written back-to-back and flushed once per burst; the buffer is also
    // flushed before blocking on anything — the next queued reply or a
    // not-yet-computed response — so an unpipelined client never waits on
    // unflushed bytes.
    while let Ok(first) = rx.recv() {
        let mut next = Some(first);
        while let Some(reply) = next.take() {
            let (line, span) = match reply {
                Reply::Immediate(s) => (s, None),
                Reply::Pending(resp, span) => {
                    let got = match resp.try_recv() {
                        Ok(r) => Ok(r),
                        Err(TryRecvError::Empty) => {
                            // About to block on the pool: flush what the
                            // client is already owed.
                            flush_frames(&mut stream, &mut out, stall, &mut broken);
                            resp.recv()
                        }
                        Err(TryRecvError::Disconnected) => resp.recv(),
                    };
                    shared.release_inflight(conn_inflight);
                    let line = match got {
                        Ok(r) => render_response(&r),
                        Err(_) => err_json("server dropped the request (shutting down)"),
                    };
                    if let Some(sp) = &span {
                        sp.stamp(Stage::Serialize);
                    }
                    (line, span)
                }
            };
            if !broken {
                out.extend_from_slice(line.as_bytes());
                out.push(b'\n');
                shared.gauges.observe_write_buf(out.len());
            }
            // `write` = reply handed to the socket write path (buffered
            // for the next flush); the span is complete after it.
            if let Some(sp) = &span {
                sp.stamp(Stage::Write);
                shared.tracer.finish(sp);
            }
            if let Ok(more) = rx.try_recv() {
                next = Some(more);
            }
        }
        flush_frames(&mut stream, &mut out, stall, &mut broken);
    }
    // Channel closed (reader done — client EOF, half-close, or drain):
    // everything already buffered is still owed to the client.
    flush_frames(&mut stream, &mut out, stall, &mut broken);
}

pub(crate) fn render_response(resp: &Response) -> String {
    let topk = Json::Arr(
        resp.topk
            .iter()
            .map(|&(l, s)| Json::Arr(vec![Json::Num(l as f64), Json::Num(s as f64)]))
            .collect(),
    );
    let mut fields = vec![("topk", topk)];
    if resp.partial {
        // Degraded scatter-gather answer: some label shard contributed
        // nothing (every replica down). Omitted entirely when false —
        // the common reply stays byte-identical to the unsharded server.
        fields.push(("partial", Json::Bool(true)));
    }
    Json::obj(fields).dump()
}

pub(crate) fn err_json(msg: &str) -> String {
    Json::obj(vec![("error", Json::from(msg))]).dump()
}

/// The reply to a request line that hit [`MAX_LINE`] without a newline
/// (both transports close the connection after it — a partially read
/// line cannot be resynchronized).
pub(crate) fn oversized_line_json() -> String {
    err_json(&format!("request line exceeds {MAX_LINE} bytes"))
}

fn backpressure_json(inflight: usize, max: usize, scope: &str) -> String {
    Json::obj(vec![
        (
            "error",
            Json::Str(format!("backpressure: {inflight} requests {scope} (max {max})")),
        ),
        ("backpressure", Json::Bool(true)),
    ])
    .dump()
}

fn queue_full_json() -> String {
    Json::obj(vec![
        ("error", Json::from("backpressure: worker queue full, retry later")),
        ("backpressure", Json::Bool(true)),
    ])
    .dump()
}

/// The `METRICS` reply: the pool's prometheus block plus the transport's
/// own metrics — every family with `# HELP` / `# TYPE` headers — closed
/// by a `# end` marker line. Both transports reply through this one
/// function, so the exposition is byte-identical whichever produced it.
fn render_metrics(shared: &Shared) -> String {
    let mut s = shared.metrics.prometheus();
    render_gauge(
        &mut s,
        "ltls_net_inflight",
        "requests admitted to the pool whose reply has not been written",
        shared.inflight.load(Ordering::SeqCst) as f64,
    );
    render_gauge(
        &mut s,
        "ltls_net_max_inflight",
        "global admission bound (--max-inflight, resolved)",
        shared.max_inflight as f64,
    );
    render_gauge(
        &mut s,
        "ltls_net_max_inflight_per_conn",
        "per-connection admission bound (--max-inflight-per-conn, resolved)",
        shared.per_conn_cap as f64,
    );
    render_counter(
        &mut s,
        "ltls_net_rejected_total",
        "requests refused with a backpressure error",
        shared.rejected.load(Ordering::Relaxed),
    );
    render_counter(
        &mut s,
        "ltls_net_connections_total",
        "connections accepted over the server's lifetime",
        shared.accepted_conns.load(Ordering::Relaxed),
    );
    render_gauge(
        &mut s,
        "ltls_net_live_connections",
        "connections currently open",
        *shared.live_conns.lock().unwrap() as f64,
    );
    s.push_str(&shared.gauges.prometheus());
    render_counter(
        &mut s,
        "ltls_trace_sampled_total",
        "request spans captured into the sampled trace ring",
        shared.tracer.sampled_total.get(),
    );
    render_counter(
        &mut s,
        "ltls_trace_slow_total",
        "request spans captured into the slow trace ring",
        shared.tracer.slow_total.get(),
    );
    // Scatter-tier families (live on a coordinator, zero-valued
    // otherwise — always present so the name set is topology-independent).
    match &shared.scatter {
        Some(st) => st.render_into(&mut s),
        None => super::scatter::ScatterStats::render_absent(&mut s),
    }
    // Training counters (live when `serve` trained its model in-process;
    // all-zero otherwise — always present so the name set is stable).
    s.push_str(&crate::train::TrainStats::global().prometheus());
    if let Some(r) = &shared.reload {
        render_gauge(
            &mut s,
            "ltls_model_epoch",
            "model generation (successful reloads since startup)",
            r.epoch() as f64,
        );
        let (ok, failed) = r.reload_counts();
        render_counter(&mut s, "ltls_reload_success_total", "successful model reloads", ok);
        render_counter(
            &mut s,
            "ltls_reload_failure_total",
            "rejected model reloads (current model kept)",
            failed,
        );
    }
    s.push_str("# end");
    s
}

/// The `TRACE` reply: drain both capture rings as JSON lines (sampled
/// spans first, then slow ones), closed by the same `# end` marker as
/// `METRICS`. An empty reply is just the marker.
fn render_trace(shared: &Shared) -> String {
    let mut s = shared.tracer.dump_json_lines();
    s.push_str("# end");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_accepts_and_sorts() {
        let (k, idx, val) = parse_request("3 5:1.5 2:2 7:0.25", Some(100)).unwrap();
        assert_eq!(k, 3);
        assert_eq!(idx, vec![2, 5, 7]);
        assert_eq!(val, vec![2.0, 1.5, 0.25]);
        // Featureless requests are legal (bias-only scoring).
        let (k, idx, _) = parse_request("1", None).unwrap();
        assert_eq!((k, idx.len()), (1, 0));
    }

    #[test]
    fn parse_request_rejects_malformed() {
        assert!(parse_request("", Some(10)).is_err());
        assert!(parse_request("0 1:1", Some(10)).is_err()); // k = 0
        assert!(parse_request("x 1:1", Some(10)).is_err()); // bad k
        assert!(parse_request("1 nocolon", Some(10)).is_err());
        assert!(parse_request("1 a:1", Some(10)).is_err());
        assert!(parse_request("1 1:abc", Some(10)).is_err());
        assert!(parse_request("1 1:NaN", Some(10)).is_err());
        assert!(parse_request("1 1:inf", Some(10)).is_err());
        assert!(parse_request("1 3:1 3:2", Some(10)).is_err()); // duplicate
        assert!(parse_request("1 10:1", Some(10)).is_err()); // out of range
        assert!(parse_request("1 9:1", Some(10)).is_ok()); // boundary
        let big_k = format!("{} 1:1", MAX_K + 1);
        assert!(parse_request(&big_k, Some(10)).is_err());
    }

    #[test]
    fn response_and_error_rendering_is_parseable_json() {
        let r = Response { topk: vec![(7, 1.5), (2, -0.25)], partial: false };
        let full = render_response(&r);
        let doc = Json::parse(&full).unwrap();
        let topk = doc.get("topk").unwrap().as_arr().unwrap();
        assert_eq!(topk.len(), 2);
        assert_eq!(topk[0].as_arr().unwrap()[0].as_f64(), Some(7.0));
        assert_eq!(topk[1].as_arr().unwrap()[1].as_f64(), Some(-0.25));
        // The partial flag renders ahead of topk (sorted object keys)
        // and only when set — full replies carry no partial key at all.
        assert!(!full.contains("partial"), "{full}");
        let p = render_response(&Response { topk: vec![(7, 1.5)], partial: true });
        assert_eq!(p, "{\"partial\":true,\"topk\":[[7,1.5]]}");
        let e = Json::parse(&err_json("boom \"quoted\"")).unwrap();
        assert_eq!(e.get("error").unwrap().as_str(), Some("boom \"quoted\""));
        let b = Json::parse(&backpressure_json(9, 8, "in flight")).unwrap();
        assert_eq!(b.get("backpressure"), Some(&Json::Bool(true)));
        assert!(b.get("error").unwrap().as_str().unwrap().contains("9"));
        let q = Json::parse(&queue_full_json()).unwrap();
        assert_eq!(q.get("backpressure"), Some(&Json::Bool(true)));
    }

    #[test]
    fn transport_parses_and_displays() {
        assert_eq!("threads".parse::<Transport>().unwrap(), Transport::Threads);
        assert_eq!("event-loop".parse::<Transport>().unwrap(), Transport::EventLoop);
        assert_eq!("event_loop".parse::<Transport>().unwrap(), Transport::EventLoop);
        assert!("kqueue".parse::<Transport>().is_err());
        assert_eq!(Transport::Threads.to_string(), "threads");
        assert_eq!(Transport::EventLoop.to_string(), "event-loop");
    }

    /// Regression (writer tear-down bug): a reader that stalls longer
    /// than one write-timeout slice but keeps making progress within the
    /// stall budget must receive every buffered frame intact — the old
    /// writer marked the connection broken on the first timed-out
    /// `write_all`, tearing the frame mid-byte and discarding the rest.
    #[test]
    fn flush_frames_survives_slow_reader() {
        use std::io::Read as _;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (mut server_side, _) = listener.accept().unwrap();
        // Enough to overrun the kernel buffers so writes genuinely block.
        let payload: Vec<u8> = (0..8 * 1024 * 1024).map(|i| (i % 251) as u8).collect();
        let reader = std::thread::spawn(move || {
            let mut c = client;
            let mut got = Vec::new();
            let mut chunk = [0u8; 64 << 10];
            loop {
                // Slow consumer: drains a chunk, then naps longer than a
                // write-timeout slice.
                std::thread::sleep(Duration::from_millis(20));
                match c.read(&mut chunk) {
                    Ok(0) => break,
                    Ok(n) => got.extend_from_slice(&chunk[..n]),
                    Err(_) => break,
                }
            }
            got
        });
        let stall = Duration::from_secs(5);
        let _ = server_side.set_write_timeout(Some(Duration::from_millis(20)));
        let mut buf = payload.clone();
        let mut broken = false;
        assert!(
            flush_frames(&mut server_side, &mut buf, stall, &mut broken),
            "slow-but-alive reader was declared dead"
        );
        drop(server_side); // EOF for the reader
        let got = reader.join().unwrap();
        assert_eq!(got.len(), payload.len(), "frames were dropped");
        assert_eq!(got, payload, "frames were torn or reordered");
    }

    /// A reader making zero progress for a full stall budget is declared
    /// dead (the drain must not hang on it) and stays dead.
    #[test]
    fn flush_frames_gives_up_on_stuck_reader() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (mut server_side, _) = listener.accept().unwrap();
        let _ = server_side.set_write_timeout(Some(Duration::from_millis(20)));
        // Never read from `client`: after the kernel buffers fill, no
        // progress is possible.
        let mut buf = vec![0u8; 16 * 1024 * 1024];
        let mut broken = false;
        let t0 = Instant::now();
        assert!(!flush_frames(
            &mut server_side,
            &mut buf,
            Duration::from_millis(200),
            &mut broken
        ));
        assert!(broken);
        assert!(t0.elapsed() < Duration::from_secs(30), "stall detection took too long");
        // Subsequent flushes on a broken connection discard immediately.
        let mut buf = vec![1u8; 8];
        assert!(!flush_frames(&mut server_side, &mut buf, Duration::from_secs(1), &mut broken));
        assert!(buf.is_empty());
        drop(client);
    }
}
