//! L3 serving coordinator: a batching multi-worker prediction server in
//! the style of a model-serving router (vLLM-like architecture, scaled to
//! this paper's needs).
//!
//! Requests (feature vectors) arrive on a bounded channel; the [`batcher`]
//! accumulates them into micro-batches bounded by size and latency
//! (stamping queueing latency from *enqueue* time); a configurable pool of
//! [`server`] workers pulls batches from the shared queue — each worker
//! owns a [`crate::engine::PredictScratch`], so the decode path is
//! allocation-free and throughput scales with cores. A batch executes
//! either on the sparse linear LTLS path (`O(E·nnz + log C)` per example;
//! [`server::BatchedLtls`] amortizes the feature-strip sweep over the
//! whole batch) or on the dense deep path (one AOT PJRT program call per
//! batch) — and completes the callers' futures. [`metrics`] aggregates
//! latency histograms plus per-worker counters on the lock-free
//! [`crate::obs`] registry (relaxed atomics on the record path, no mutex),
//! reported by `examples/serve_batched.rs`, `benches/serve_throughput.rs`,
//! and the network frontend's `METRICS` endpoint as conformant Prometheus
//! exposition (metric catalog: `docs/OBSERVABILITY.md`).
//!
//! Everything is std-only (threads + channels): tokio is not vendored in
//! this offline build, and the workload is CPU-bound anyway — a small
//! fixed worker pool over a bounded queue is the right shape.
//!
//! Three further layers make the pool a deployable service:
//!
//! * [`transport`] — the TCP frontend (`ltls serve --listen HOST:PORT`):
//!   a newline-delimited request protocol with JSON-line replies, bounded
//!   admission (backpressure errors instead of unbounded queueing), a
//!   Prometheus `METRICS` endpoint, a `TRACE` endpoint dumping sampled
//!   and slow-request stage timelines ([`crate::obs::trace`]) as JSON
//!   lines, and graceful drain on shutdown. The wire contract is
//!   specified in `docs/PROTOCOL.md`.
//! * [`event_loop`] — the default connection frontend behind
//!   [`transport::NetServer`]: a poll(2) event loop multiplexing every
//!   connection over a small fixed pool of poll threads
//!   (`--transport event-loop`; the thread-per-connection oracle stays
//!   available as `--transport threads`).
//! * [`reload`] — hot model reload: an epoch-counted `Mutex<Arc<_>>`
//!   model slot ([`reload::ModelSlot`]) swapped atomically between
//!   micro-batches by the `RELOAD` control command or the
//!   `--watch-model` file poller, with zero dropped or misrouted
//!   in-flight requests.
//! * [`scatter`] — the sharded scatter-gather tier (`ltls coordinator`):
//!   fans each micro-batch out over N label shards serving v4 model
//!   slices (`ltls shard`), k-way-merges the partial top-k lists back
//!   into the exact global top-k, and fails over between shard replicas —
//!   replies carry `"partial":true` only while every replica of some
//!   shard is down.
//!
//! The crate-wide layer map, with the life of a request through this
//! coordinator (accept → frame → batcher → worker pool → reload slot →
//! reply), is `docs/ARCHITECTURE.md`.

pub mod batcher;
#[cfg(unix)]
pub mod event_loop;
pub mod metrics;
pub mod reload;
pub mod scatter;
pub mod server;
pub mod transport;

pub use batcher::{Batch, BatcherConfig, Stamped};
pub use metrics::{ServingMetrics, TransportGauges, WorkerStats};
pub use reload::{ModelSlot, ModelWatcher, ReloadableLtls};
pub use scatter::{merge_topk, parse_shard_spec, ScatterConfig, ScatterModel, ScatterStats};
pub use server::{
    BatchedLtls, CompletionNotify, PredictServer, Request, Response, ServerConfig, SubmitError,
    Submitter,
};
pub use transport::{NetConfig, NetServer, Transport};
