//! L3 serving coordinator: a batching multi-worker prediction server in
//! the style of a model-serving router (vLLM-like architecture, scaled to
//! this paper's needs).
//!
//! Requests (feature vectors) arrive on a bounded channel; the [`batcher`]
//! accumulates them into micro-batches bounded by size and latency
//! (stamping queueing latency from *enqueue* time); a configurable pool of
//! [`server`] workers pulls batches from the shared queue — each worker
//! owns a [`crate::engine::PredictScratch`], so the decode path is
//! allocation-free and throughput scales with cores. A batch executes
//! either on the sparse linear LTLS path (`O(E·nnz + log C)` per example;
//! [`server::BatchedLtls`] amortizes the feature-strip sweep over the
//! whole batch) or on the dense deep path (one AOT PJRT program call per
//! batch) — and completes the callers' futures. [`metrics`] aggregates
//! latency histograms plus per-worker counters, reported by
//! `examples/serve_batched.rs` and `benches/serve_throughput.rs`.
//!
//! Everything is std-only (threads + channels): tokio is not vendored in
//! this offline build, and the workload is CPU-bound anyway — a small
//! fixed worker pool over a bounded queue is the right shape.

pub mod batcher;
pub mod metrics;
pub mod server;

pub use batcher::{Batch, BatcherConfig, Stamped};
pub use metrics::{ServingMetrics, WorkerStats};
pub use server::{BatchedLtls, PredictServer, Request, Response, ServerConfig};
