//! L3 serving coordinator: a batching prediction server in the style of a
//! model-serving router (vLLM-like architecture, scaled to this paper's
//! needs).
//!
//! Requests (feature vectors) arrive on a channel; the [`batcher`]
//! accumulates them into micro-batches bounded by size and latency; the
//! [`server`] worker executes a batch at a time — either on the sparse
//! linear LTLS path (`O(E·nnz + log C)` per example, rust-native) or on
//! the dense deep path (one AOT PJRT program call per batch) — and
//! completes the callers' futures. [`metrics`] aggregates the latency
//! histograms reported by `examples/serve_batched.rs`.
//!
//! Everything is std-only (threads + channels): tokio is not vendored in
//! this offline build, and the workload is CPU-bound anyway — a small
//! fixed worker pool with bounded queues is the right shape.

pub mod batcher;
pub mod metrics;
pub mod server;

pub use batcher::{Batch, BatcherConfig};
pub use server::{PredictServer, Request, Response, ServerConfig};
