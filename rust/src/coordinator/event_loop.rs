//! The poll(2) event-loop transport: a small fixed pool of poll threads
//! multiplexing every client connection through nonblocking sockets —
//! the default frontend of `ltls serve --listen` (ROADMAP item 1).
//!
//! Where the threaded transport ([`super::transport`]) spends two
//! threads per connection, here thread 0 polls the listener and hands
//! accepted connections round-robin to `N` poll threads; each thread
//! owns its connections outright (no locks on the I/O path) and blocks
//! in a single [`poll`] call over all of their fds plus a
//! [`WakePipe`]. Worker-pool completions cross threads through
//! [`super::server::CompletionNotify`]: the hook pushes the connection
//! id onto its poll thread's ready list and wakes the pipe, so replies
//! are pumped without any connection parking a thread on a blocking
//! `recv`. This caps the frontend at `N + workers` threads regardless of
//! connection count, which is what lets it hold thousands of concurrent
//! clients.
//!
//! Per connection, a [`ReadBuf`] accumulates bytes and yields newline
//! frames incrementally (a frame split across any number of reads parses
//! identically — pinned by the unit tests below), and a write buffer
//! holds rendered replies in submission order. The write buffer is
//! bounded by `NetConfig::conn_buf_bytes`: over the high-water mark the
//! loop stops *reading* that connection (backpressure on the pipe)
//! rather than buffering replies for a client that stopped draining
//! them, and a connection whose write side makes zero progress for a
//! full `write_stall` budget is declared dead and drained for admission
//! accounting only. Protocol behavior — validation, admission control,
//! command handling, reply bytes — is [`super::transport::handle_line`],
//! shared verbatim with the threaded transport.
//!
//! The wire contract itself is documented in `docs/PROTOCOL.md`; the
//! crate map with the life of a request is `docs/ARCHITECTURE.md`.

#![cfg(unix)]

use super::server::{CompletionNotify, Response, Submitter};
use super::transport::{
    err_json, handle_line, oversized_line_json, render_response, LineReply, Shared, MAX_LINE,
};
use crate::obs::{Span, Stage};
use crate::util::poll::{poll, PollFd, WakePipe, POLLIN, POLLOUT};
use std::collections::VecDeque;
use std::io;
use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Read chunk size; a connection reads at most a few chunks per pump so
/// one firehose client cannot starve the rest of the poll set.
const READ_CHUNK: usize = 16 << 10;
const READ_CHUNKS_PER_PUMP: usize = 4;
/// Poll timeout when some connection has buffered output that is not
/// moving (the stall clock needs periodic checks) vs. fully idle.
const BUSY_TIMEOUT_MS: i32 = 100;
const IDLE_TIMEOUT_MS: i32 = 1000;

/// Cross-thread mailbox of one poll thread: freshly accepted connections
/// (from thread 0) and completion-ready connection ids (from pool
/// workers), plus the pipe that wakes the thread to look.
struct Mailbox {
    new_conns: Mutex<Vec<TcpStream>>,
    ready: Mutex<Vec<u64>>,
    wake: WakePipe,
}

/// The per-connection completion hook installed on every submitted
/// request: marks the connection reply-ready on its owning thread and
/// wakes it (wakes coalesce in the pipe).
struct ConnNotify {
    id: u64,
    mail: Arc<Mailbox>,
}

impl CompletionNotify for ConnNotify {
    fn completed(&self) {
        self.mail.ready.lock().unwrap().push(self.id);
        self.mail.wake.wake();
    }
}

/// A reply owed to the client, in submission order.
enum Pending {
    /// Pre-rendered line (commands, protocol errors).
    Line(String),
    /// Awaiting the worker pool; holds an admission slot until popped.
    /// Carries the request's trace span (if any) for the `serialize` /
    /// `write` stamps.
    Waiting(Receiver<Response>, Option<Span>),
}

/// Incremental newline framing over a nonblocking byte stream.
///
/// Bytes arrive in arbitrary fragments; [`ReadBuf::take_line`] yields
/// each complete `\n`-terminated frame exactly once, however the frame
/// was split across reads. The scan position is remembered so feeding
/// a frame one byte at a time costs O(len) total, not O(len²).
pub(crate) struct ReadBuf {
    buf: Vec<u8>,
    /// Consumed prefix (compacted away once it outgrows the remainder).
    start: usize,
    /// Absolute scan cursor: `buf[start..scanned]` holds no `\n`.
    scanned: usize,
}

impl Default for ReadBuf {
    fn default() -> Self {
        Self::new()
    }
}

impl ReadBuf {
    pub(crate) fn new() -> ReadBuf {
        ReadBuf { buf: Vec::with_capacity(READ_CHUNK), start: 0, scanned: 0 }
    }

    pub(crate) fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// The next complete frame (newline stripped), or `None` until one
    /// fully arrives.
    pub(crate) fn take_line(&mut self) -> Option<Vec<u8>> {
        match self.buf[self.scanned..].iter().position(|&b| b == b'\n') {
            Some(rel) => {
                let nl = self.scanned + rel;
                let line = self.buf[self.start..nl].to_vec();
                self.start = nl + 1;
                self.scanned = self.start;
                // Compact once the dead prefix dominates the buffer.
                if self.start > 4096 && self.start * 2 > self.buf.len() {
                    self.buf.drain(..self.start);
                    self.scanned -= self.start;
                    self.start = 0;
                }
                Some(line)
            }
            None => {
                self.scanned = self.buf.len();
                None
            }
        }
    }

    /// Bytes of the unterminated frame currently buffered (the
    /// [`MAX_LINE`] guard watches this).
    pub(crate) fn partial_len(&self) -> usize {
        self.buf.len() - self.start
    }
}

/// One multiplexed connection, owned entirely by its poll thread.
struct Conn {
    id: u64,
    stream: TcpStream,
    rbuf: ReadBuf,
    /// Rendered replies not yet on the socket (never torn: frames are
    /// appended whole and flushed from the front).
    wbuf: Vec<u8>,
    pending: VecDeque<Pending>,
    conn_inflight: AtomicUsize,
    notify: Arc<ConnNotify>,
    /// Client sent EOF, the drain half-closed us, or a read failed.
    read_closed: bool,
    /// Protocol demanded close (oversized line, pool shut down).
    want_close: bool,
    /// Write side failed or stalled out: discard output, keep draining
    /// `pending` so admission accounting still closes.
    write_dead: bool,
    /// Last instant the socket accepted bytes while output was buffered.
    last_wprogress: Instant,
}

impl Conn {
    fn new(id: u64, stream: TcpStream, mail: &Arc<Mailbox>) -> Conn {
        Conn {
            id,
            stream,
            rbuf: ReadBuf::new(),
            wbuf: Vec::new(),
            pending: VecDeque::new(),
            conn_inflight: AtomicUsize::new(0),
            notify: Arc::new(ConnNotify { id, mail: Arc::clone(mail) }),
            read_closed: false,
            want_close: false,
            write_dead: false,
            last_wprogress: Instant::now(),
        }
    }

    /// Reads are paused while the client owes us a drained write buffer.
    fn read_paused(&self, shared: &Shared) -> bool {
        self.wbuf.len() >= shared.wbuf_cap
    }

    /// The poll events this connection currently cares about.
    fn interests(&self, shared: &Shared) -> i16 {
        let mut ev = 0;
        if !self.read_closed && !self.want_close && !self.read_paused(shared) {
            ev |= POLLIN;
        }
        if !self.write_dead && !self.wbuf.is_empty() {
            ev |= POLLOUT;
        }
        ev
    }

    fn append_frame(&mut self, shared: &Shared, line: &str) {
        if self.write_dead {
            return;
        }
        let was_empty = self.wbuf.is_empty();
        self.wbuf.extend_from_slice(line.as_bytes());
        self.wbuf.push(b'\n');
        shared.gauges.observe_write_buf(self.wbuf.len());
        if was_empty {
            // Arm the stall clock at the first buffered byte.
            self.last_wprogress = Instant::now();
        }
    }

    /// Move completed replies, in submission order, from `pending` into
    /// the write buffer; each pop releases its admission slot. Stops at
    /// the high-water mark (the admission window stays open — that *is*
    /// the backpressure) unless the write side is dead, in which case
    /// everything completed is popped and discarded so a zombie client
    /// cannot pin inflight budget.
    fn pop_ready(&mut self, shared: &Shared) {
        loop {
            if !self.write_dead && self.wbuf.len() >= shared.wbuf_cap {
                break;
            }
            let Some(front) = self.pending.pop_front() else { break };
            let (line, span) = match front {
                Pending::Line(s) => (s, None),
                Pending::Waiting(rx, span) => match rx.try_recv() {
                    Ok(resp) => {
                        shared.release_inflight(&self.conn_inflight);
                        let line = render_response(&resp);
                        if let Some(sp) = &span {
                            sp.stamp(Stage::Serialize);
                        }
                        (line, span)
                    }
                    Err(TryRecvError::Disconnected) => {
                        shared.release_inflight(&self.conn_inflight);
                        (err_json("server dropped the request (shutting down)"), span)
                    }
                    Err(TryRecvError::Empty) => {
                        // Not done yet: put it back and wait for the
                        // completion hook to kick us again.
                        self.pending.push_front(Pending::Waiting(rx, span));
                        break;
                    }
                },
            };
            self.append_frame(shared, &line);
            // `write` = frame handed to the socket write path.
            if let Some(sp) = &span {
                sp.stamp(Stage::Write);
                shared.tracer.finish(sp);
            }
        }
    }

    /// Nonblocking write of the buffered frames' front.
    fn flush(&mut self) {
        while !self.write_dead && !self.wbuf.is_empty() {
            match self.stream.write(&self.wbuf) {
                Ok(0) => self.mark_write_dead(),
                Ok(n) => {
                    self.wbuf.drain(..n);
                    self.last_wprogress = Instant::now();
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(_) => self.mark_write_dead(),
            }
        }
    }

    fn mark_write_dead(&mut self) {
        self.write_dead = true;
        self.wbuf.clear();
    }

    /// Pull bytes off the socket (a bounded number of chunks per pump —
    /// level-triggered poll re-reports leftovers).
    fn fill(&mut self) {
        let mut chunk = [0u8; READ_CHUNK];
        for _ in 0..READ_CHUNKS_PER_PUMP {
            match self.stream.read(&mut chunk) {
                Ok(0) => {
                    self.read_closed = true;
                    break;
                }
                Ok(n) => self.rbuf.extend(&chunk[..n]),
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(_) => {
                    self.read_closed = true;
                    break;
                }
            }
        }
    }

    /// Parse buffered frames through the shared protocol core while the
    /// write buffer stays under the high-water mark.
    fn parse(&mut self, shared: &Shared, submitter: &Submitter) {
        while !self.want_close && !(self.read_paused(shared) && !self.write_dead) {
            let Some(raw) = self.rbuf.take_line() else {
                if self.rbuf.partial_len() as u64 >= MAX_LINE {
                    // Same contract as the threaded reader: answer, then
                    // close — a partial line cannot be resynchronized.
                    self.pending.push_back(Pending::Line(oversized_line_json()));
                    self.want_close = true;
                }
                break;
            };
            let Ok(text) = std::str::from_utf8(&raw) else {
                // The threaded transport's line reader fails the same
                // way on non-UTF-8 input: drop the connection silently.
                self.want_close = true;
                break;
            };
            let trimmed = text.trim();
            if trimmed.is_empty() {
                continue;
            }
            let notify = Arc::clone(&self.notify);
            let outcome =
                handle_line(shared, trimmed, &self.conn_inflight, &mut |i, v, k, sp| {
                    submitter.try_submit_full(
                        i,
                        v,
                        k,
                        sp,
                        Some(Arc::clone(&notify) as Arc<dyn CompletionNotify>),
                    )
                });
            self.pending.push_back(match outcome.reply {
                LineReply::Immediate(s) => Pending::Line(s),
                LineReply::Pending(rx, sp) => Pending::Waiting(rx, sp),
            });
            if outcome.close {
                self.want_close = true;
            }
        }
    }

    /// One full service pass: flush → pop replies → parse frames → read
    /// more → pop/flush again. Safe to call spuriously (every operation
    /// is nonblocking and level-triggered poll re-reports leftovers).
    fn pump(&mut self, shared: &Shared, submitter: &Submitter) {
        self.flush();
        self.pop_ready(shared);
        self.parse(shared, submitter);
        if !self.read_closed && !self.want_close && !self.read_paused(shared) {
            self.fill();
            self.parse(shared, submitter);
        }
        self.pop_ready(shared);
        self.flush();
        // A write side making zero progress for a full stall budget is
        // dead — without this, one stuck client would pin its admission
        // slots and hang the graceful drain forever.
        if !self.write_dead
            && !self.wbuf.is_empty()
            && self.last_wprogress.elapsed() >= shared.write_stall
        {
            self.mark_write_dead();
        }
    }

    /// Done once no more input can arrive, every admitted reply has been
    /// accounted, and the client received everything it is owed.
    fn finished(&self) -> bool {
        (self.read_closed || self.want_close)
            && self.pending.is_empty()
            && (self.write_dead || self.wbuf.is_empty())
    }
}

/// Handle owned by [`super::transport::NetServer`]: the poll threads and
/// their wake pipes.
pub(crate) struct EventLoopHandle {
    threads: Vec<JoinHandle<()>>,
    mailboxes: Vec<Arc<Mailbox>>,
}

impl EventLoopHandle {
    pub(crate) fn spawn(
        listener: TcpListener,
        shared: Arc<Shared>,
        n_threads: usize,
    ) -> io::Result<EventLoopHandle> {
        let n = n_threads.max(1);
        let mut mailboxes = Vec::with_capacity(n);
        for _ in 0..n {
            mailboxes.push(Arc::new(Mailbox {
                new_conns: Mutex::new(Vec::new()),
                ready: Mutex::new(Vec::new()),
                wake: WakePipe::new()?,
            }));
        }
        let mut threads = Vec::with_capacity(n);
        let mut listener = Some(listener);
        for tid in 0..n {
            let shared = Arc::clone(&shared);
            let mailboxes_all = mailboxes.clone();
            let listener = listener.take(); // thread 0 owns the listener
            let handle = std::thread::Builder::new()
                .name(format!("ltls-net-poll-{tid}"))
                .spawn(move || poll_thread(tid, listener, &shared, &mailboxes_all))?;
            threads.push(handle);
        }
        Ok(EventLoopHandle { threads, mailboxes })
    }

    /// Wake every poll thread (drain signaling; the flag itself lives in
    /// `Shared::draining`).
    pub(crate) fn kick(&self) {
        for m in &self.mailboxes {
            m.wake.wake();
        }
    }

    /// Join all poll threads — the event loop's drain barrier.
    pub(crate) fn join(&mut self) {
        for h in self.threads.drain(..) {
            let _ = h.join();
        }
    }
}

fn poll_thread(
    tid: usize,
    listener: Option<TcpListener>,
    shared: &Arc<Shared>,
    mailboxes: &[Arc<Mailbox>],
) {
    let mail = &mailboxes[tid];
    // One pool handle per poll thread; every connection submits through
    // it with its own completion hook. Dropped on thread exit, before
    // the drain joins the workers.
    let Some(submitter) = shared.pool.lock().unwrap().as_ref().map(|p| p.submitter()) else {
        return;
    };
    let mut listener = listener;
    let mut next_id = 0u64; // namespaced by thread: id = n * next + tid
    let mut conns: Vec<Conn> = Vec::new();
    let mut fds: Vec<PollFd> = Vec::new();
    let mut draining_seen = false;
    loop {
        let draining = shared.draining.load(Ordering::SeqCst);
        if draining && !draining_seen {
            draining_seen = true;
            listener = None; // stop accepting
            for c in conns.iter_mut() {
                // Half-close: nothing more comes in, everything admitted
                // still flows out.
                let _ = c.stream.shutdown(Shutdown::Read);
                c.read_closed = true;
            }
        }
        // ---- build the poll set: [wake, listener?, conns...] ----
        fds.clear();
        fds.push(PollFd::new(mail.wake.poll_fd(), POLLIN));
        let listener_slot = listener.as_ref().map(|l| {
            fds.push(PollFd::new(l.as_raw_fd(), POLLIN));
            fds.len() - 1
        });
        let conn_base = fds.len();
        let mut has_buffered = false;
        for c in &conns {
            // Zero-interest (zombie) connections stay registered so
            // ERR/HUP still surface; their replies arrive via the wake
            // pipe.
            fds.push(PollFd::new(c.stream.as_raw_fd(), c.interests(shared)));
            has_buffered |= !c.wbuf.is_empty();
        }
        let timeout =
            if draining || has_buffered { BUSY_TIMEOUT_MS } else { IDLE_TIMEOUT_MS };
        let n_ready = poll(&mut fds, timeout).unwrap_or(0);
        // ---- wake pipe: completions and drain kicks ----
        if fds[0].readable() {
            mail.wake.drain();
            shared.gauges.record_poll_wakeup();
        }
        // ---- adopt freshly accepted connections ----
        for stream in mail.new_conns.lock().unwrap().drain(..) {
            next_id += 1;
            let id = next_id * mailboxes.len() as u64 + tid as u64;
            let mut conn = Conn::new(id, stream, mail);
            if draining_seen {
                let _ = conn.stream.shutdown(Shutdown::Read);
                conn.read_closed = true;
            }
            conns.push(conn);
        }
        // ---- accept (thread 0 only) and deal out round-robin ----
        if let (Some(l), Some(slot)) = (&listener, listener_slot) {
            if fds[slot].readable() {
                accept_burst(l, shared, mailboxes, tid);
            }
        }
        // ---- decide which connections to service ----
        let mut kicked: Vec<u64> = std::mem::take(&mut *mail.ready.lock().unwrap());
        kicked.sort_unstable();
        kicked.dedup();
        let sweep = n_ready == 0 || draining; // timeout → stall sweep
        for (i, c) in conns.iter_mut().enumerate() {
            let evented = fds.get(conn_base + i).is_some_and(|f| f.revents != 0);
            let has_kick = kicked.binary_search(&c.id).is_ok();
            if evented || has_kick || sweep || (!c.wbuf.is_empty()) {
                c.pump(shared, &submitter);
            }
        }
        // ---- retire finished connections ----
        let mut i = 0;
        while i < conns.len() {
            if conns[i].finished() {
                let gone = conns.swap_remove(i);
                let _ = gone.stream.shutdown(Shutdown::Both);
                shared.gauges.conn_closed();
                let mut live = shared.live_conns.lock().unwrap();
                *live -= 1;
                shared.conn_cv.notify_all();
            } else {
                i += 1;
            }
        }
        if draining_seen && conns.is_empty() && mail.new_conns.lock().unwrap().is_empty() {
            break;
        }
    }
}

/// Accept until the listener would block, dealing connections round-robin
/// across the poll threads (self-delivery included: thread 0 is a full
/// peer, its mailbox is drained next iteration).
fn accept_burst(
    listener: &TcpListener,
    shared: &Arc<Shared>,
    mailboxes: &[Arc<Mailbox>],
    self_tid: usize,
) {
    let mut target = shared.accepted_conns.load(Ordering::Relaxed) as usize;
    loop {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let _ = stream.set_nonblocking(true);
                let _ = stream.set_nodelay(true);
                shared.accepted_conns.fetch_add(1, Ordering::Relaxed);
                shared.gauges.conn_opened();
                *shared.live_conns.lock().unwrap() += 1;
                let t = target % mailboxes.len();
                target += 1;
                mailboxes[t].new_conns.lock().unwrap().push(stream);
                if t != self_tid {
                    mailboxes[t].wake.wake();
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => break,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lines_from(frames: &[&[u8]]) -> Vec<Vec<u8>> {
        let mut rb = ReadBuf::new();
        let mut out = Vec::new();
        for f in frames {
            rb.extend(f);
            while let Some(l) = rb.take_line() {
                out.push(l);
            }
        }
        out
    }

    #[test]
    fn whole_frame_single_read() {
        assert_eq!(lines_from(&[&b"PING\n"[..]]), vec![b"PING".to_vec()]);
    }

    /// The tentpole framing guarantee: a frame split at *every* byte
    /// boundary — and across every pair of boundaries — parses to the
    /// identical line sequence.
    #[test]
    fn frames_split_at_every_byte_boundary() {
        let msg = b"3 5:1.5 2:2 7:0.25\nPING\n";
        let expect = vec![b"3 5:1.5 2:2 7:0.25".to_vec(), b"PING".to_vec()];
        for cut1 in 0..=msg.len() {
            for cut2 in cut1..=msg.len() {
                let got = lines_from(&[&msg[..cut1], &msg[cut1..cut2], &msg[cut2..]]);
                assert_eq!(got, expect, "cuts at {cut1},{cut2}");
            }
        }
    }

    #[test]
    fn one_byte_at_a_time() {
        let msg = b"METRICS\n1 0:1\n";
        let frames: Vec<&[u8]> = msg.chunks(1).collect();
        assert_eq!(lines_from(&frames), vec![b"METRICS".to_vec(), b"1 0:1".to_vec()]);
    }

    #[test]
    fn many_frames_in_one_read() {
        let got = lines_from(&[&b"PING\nPING\n2 1:1\nPING\n"[..]]);
        assert_eq!(got.len(), 4);
        assert_eq!(got[2], b"2 1:1".to_vec());
    }

    #[test]
    fn partial_tail_stays_buffered() {
        let mut rb = ReadBuf::new();
        rb.extend(b"PING\nhal");
        assert_eq!(rb.take_line(), Some(b"PING".to_vec()));
        assert_eq!(rb.take_line(), None);
        assert_eq!(rb.partial_len(), 3);
        rb.extend(b"f-line\n");
        assert_eq!(rb.take_line(), Some(b"half-line".to_vec()));
        assert_eq!(rb.partial_len(), 0);
    }

    #[test]
    fn empty_and_crlf_frames_survive_framing() {
        // Framing yields them verbatim; the protocol layer trims and
        // skips empties — mirror of the threaded reader.
        assert_eq!(
            lines_from(&[&b"\nPING\r\n\n"[..]]),
            vec![b"".to_vec(), b"PING\r".to_vec(), b"".to_vec()]
        );
    }

    /// The MAX_LINE guard trips on an unterminated frame even when it
    /// arrives in many small reads (partial_len is cumulative).
    #[test]
    fn oversized_partial_line_is_observable() {
        let mut rb = ReadBuf::new();
        let chunk = vec![b'x'; 64 << 10];
        let mut fed = 0u64;
        while fed < MAX_LINE {
            rb.extend(&chunk);
            fed += chunk.len() as u64;
            assert_eq!(rb.take_line(), None);
        }
        assert!(rb.partial_len() as u64 >= MAX_LINE);
    }

    /// Compaction must not lose or corrupt frames across a long stream.
    #[test]
    fn compaction_preserves_stream_integrity() {
        let mut rb = ReadBuf::new();
        let mut expect = Vec::new();
        let mut got = Vec::new();
        for i in 0..5000u32 {
            let line = format!("req-{i} {}\n", "p".repeat((i % 97) as usize));
            expect.push(line.trim_end().as_bytes().to_vec());
            rb.extend(line.as_bytes());
            if i % 3 == 0 {
                while let Some(l) = rb.take_line() {
                    got.push(l);
                }
            }
        }
        while let Some(l) = rb.take_line() {
            got.push(l);
        }
        assert_eq!(got, expect);
    }
}
