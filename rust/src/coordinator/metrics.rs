//! Serving metrics: shared latency/throughput counters the server workers
//! update and the driver reads — including per-worker breakdowns so
//! pool-imbalance is visible.
//!
//! Everything is built on the lock-free primitives in [`crate::obs`]: the
//! per-request path ([`ServingMetrics::record_request_latency`]) is pure
//! relaxed atomics into sharded histograms, and the per-micro-batch path
//! takes only an uncontended `RwLock` read to find its worker slot (the
//! write lock is taken solely when the worker table grows). The old
//! `Mutex<Inner>` serialization point is gone.

use crate::obs::{render_gauge, Counter, Gauge, Histogram, Registry};
use crate::util::bench::fmt_ns;
use std::sync::{Arc, RwLock};

/// Per-worker counters (one slot per worker thread in the pool).
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    pub requests: u64,
    pub batches: u64,
    /// Total batch-execution time on this worker.
    pub busy_ns: u64,
}

impl WorkerStats {
    /// Mean batch size on this worker.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

/// Live atomic counters behind one worker's `{worker="i"}` samples.
struct WorkerSlot {
    requests: Arc<Counter>,
    batches: Arc<Counter>,
    busy_ns: Counter,
}

/// Aggregated serving metrics. Interior-mutable and cheap to record
/// into from every worker concurrently; scraped by the `METRICS`
/// endpoint through [`ServingMetrics::prometheus`].
pub struct ServingMetrics {
    registry: Registry,
    requests: Arc<Counter>,
    batches: Arc<Counter>,
    /// End-to-end per-request latency (enqueue → response).
    request_latency: Arc<Histogram>,
    /// Queueing time of the oldest item per batch.
    queue_latency: Arc<Histogram>,
    /// Batch execution time.
    exec_latency: Arc<Histogram>,
    workers: RwLock<Vec<WorkerSlot>>,
}

impl Default for ServingMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServingMetrics {
    pub fn new() -> Self {
        Self::with_workers(0)
    }

    /// Pre-size the per-worker table for an `n`-worker pool.
    pub fn with_workers(n: usize) -> Self {
        let registry = Registry::new();
        let requests =
            registry.counter("ltls_requests_total", "prediction requests completed by the pool");
        let batches = registry.counter("ltls_batches_total", "micro-batches executed");
        let request_latency = registry.histogram(
            "ltls_request_latency_seconds",
            "end-to-end request latency, enqueue to reply",
        );
        let queue_latency = registry.histogram(
            "ltls_queue_latency_seconds",
            "queue wait of the oldest request in each micro-batch",
        );
        let exec_latency =
            registry.histogram("ltls_exec_latency_seconds", "micro-batch execution time");
        let m = ServingMetrics {
            registry,
            requests,
            batches,
            request_latency,
            queue_latency,
            exec_latency,
            workers: RwLock::new(Vec::new()),
        };
        m.grow_workers(n);
        m
    }

    /// Extend the worker table (and its registered `{worker="i"}` counter
    /// families) to at least `n` slots.
    fn grow_workers(&self, n: usize) {
        let mut w = self.workers.write().unwrap();
        while w.len() < n {
            let label = format!("worker=\"{}\"", w.len());
            w.push(WorkerSlot {
                requests: self.registry.counter_labeled(
                    "ltls_worker_requests",
                    "requests completed per worker",
                    label.clone(),
                ),
                batches: self.registry.counter_labeled(
                    "ltls_worker_batches",
                    "micro-batches executed per worker",
                    label,
                ),
                busy_ns: Counter::new(),
            });
        }
    }

    pub fn record_batch(&self, worker: usize, batch_size: usize, queue_ns: u64, exec_ns: u64) {
        self.queue_latency.record_ns(queue_ns);
        self.exec_latency.record_ns(exec_ns);
        self.batches.inc();
        self.requests.add(batch_size as u64);
        loop {
            {
                let w = self.workers.read().unwrap();
                if let Some(slot) = w.get(worker) {
                    slot.requests.add(batch_size as u64);
                    slot.batches.inc();
                    slot.busy_ns.add(exec_ns);
                    return;
                }
            }
            self.grow_workers(worker + 1);
        }
    }

    pub fn record_request_latency(&self, ns: u64) {
        self.request_latency.record_ns(ns);
    }

    /// (requests, batches, mean batch size).
    pub fn counts(&self) -> (u64, u64, f64) {
        let requests = self.requests.get();
        let batches = self.batches.get();
        let mean = if batches == 0 { 0.0 } else { requests as f64 / batches as f64 };
        (requests, batches, mean)
    }

    /// Snapshot of the per-worker counters.
    pub fn per_worker(&self) -> Vec<WorkerStats> {
        self.workers
            .read()
            .unwrap()
            .iter()
            .map(|s| WorkerStats {
                requests: s.requests.get(),
                batches: s.batches.get(),
                busy_ns: s.busy_ns.get(),
            })
            .collect()
    }

    /// Human-readable summary block (aggregate + per-worker lines).
    pub fn summary(&self) -> String {
        let (requests, batches, mean) = self.counts();
        let mut s = format!(
            "requests={requests} batches={batches} mean_batch={mean:.1}\n  request latency: {}\n  queue  latency: {}\n  exec   latency: {}",
            self.request_latency.snapshot().summary(),
            self.queue_latency.snapshot().summary(),
            self.exec_latency.snapshot().summary(),
        );
        for (i, w) in self.per_worker().iter().enumerate() {
            s.push_str(&format!(
                "\n  worker {i}: requests={} batches={} mean_batch={:.1} busy={}",
                w.requests,
                w.batches,
                w.mean_batch(),
                fmt_ns(w.busy_ns as f64),
            ));
        }
        s
    }

    /// Request-latency quantile in ns.
    pub fn request_quantile_ns(&self, q: f64) -> f64 {
        self.request_latency.snapshot().quantile_ns(q)
    }

    /// Conformant Prometheus exposition — the body of the network
    /// frontend's `METRICS` endpoint ([`super::transport`]): `# HELP` /
    /// `# TYPE` headers, full cumulative `_bucket{le=...}`/`_sum`/`_count`
    /// histogram series for request/queue/exec latency, counters, and
    /// per-worker samples carrying a `{worker="i"}` label. The metric
    /// catalog lives in `docs/OBSERVABILITY.md`.
    pub fn prometheus(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        self.registry.render(&mut s);
        let (_, _, mean) = self.counts();
        render_gauge(&mut s, "ltls_mean_batch_size", "mean micro-batch size since start", mean);
        let req = self.request_latency.snapshot();
        render_gauge(
            &mut s,
            "ltls_request_latency_p50_seconds",
            "approximate request-latency median (log2 buckets)",
            req.quantile_ns(0.5) / 1e9,
        );
        render_gauge(
            &mut s,
            "ltls_request_latency_p99_seconds",
            "approximate request-latency p99 (log2 buckets)",
            req.quantile_ns(0.99) / 1e9,
        );
        render_gauge(
            &mut s,
            "ltls_queue_latency_p99_seconds",
            "approximate queue-latency p99 (log2 buckets)",
            self.queue_latency.snapshot().quantile_ns(0.99) / 1e9,
        );
        render_gauge(
            &mut s,
            "ltls_exec_latency_p99_seconds",
            "approximate exec-latency p99 (log2 buckets)",
            self.exec_latency.snapshot().quantile_ns(0.99) / 1e9,
        );
        let workers = self.per_worker();
        if !workers.is_empty() {
            let _ = writeln!(
                s,
                "# HELP ltls_worker_busy_seconds_total total batch-execution time per worker"
            );
            let _ = writeln!(s, "# TYPE ltls_worker_busy_seconds_total counter");
            for (i, w) in workers.iter().enumerate() {
                let _ = writeln!(
                    s,
                    "ltls_worker_busy_seconds_total{{worker=\"{i}\"}} {}",
                    w.busy_ns as f64 / 1e9
                );
            }
        }
        s
    }
}

/// Lock-free transport-level gauges shared between the network frontend's
/// accept path, its per-connection I/O, and the `METRICS` renderer.
///
/// Both transports ([`super::transport`]) update the same set, so a scrape
/// reads identically whether the frontend runs thread-per-connection or
/// the poll(2) event loop — only `poll_wakeups_total` stays at zero under
/// the threaded transport (it has no poll threads to wake).
#[derive(Debug, Default)]
pub struct TransportGauges {
    /// Connections currently open (accepted, not yet torn down).
    open_connections: Gauge,
    /// Times a poll thread was woken by its self-pipe (event loop only).
    poll_wakeups_total: Counter,
    /// High-water mark of any single connection's buffered reply bytes.
    write_buf_peak: Gauge,
}

impl TransportGauges {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn conn_opened(&self) {
        self.open_connections.inc();
    }

    /// Saturating: a teardown race that reports the same close twice
    /// pins the gauge at zero instead of wrapping it to the maximum.
    pub fn conn_closed(&self) {
        self.open_connections.dec_saturating();
    }

    pub fn open_connections(&self) -> usize {
        self.open_connections.get() as usize
    }

    pub fn record_poll_wakeup(&self) {
        self.poll_wakeups_total.inc();
    }

    pub fn poll_wakeups(&self) -> u64 {
        self.poll_wakeups_total.get()
    }

    /// Raise the write-buffer high-water mark to `bytes` if it exceeds
    /// the current peak (monotone; races only under-report transiently).
    pub fn observe_write_buf(&self, bytes: usize) {
        self.write_buf_peak.set_max(bytes as u64);
    }

    pub fn write_buf_peak(&self) -> usize {
        self.write_buf_peak.get() as usize
    }

    /// The transport's gauge lines for the `METRICS` endpoint, matching
    /// the `ltls_net_*` namespace of [`super::transport`]'s renderer.
    pub fn prometheus(&self) -> String {
        let mut s = String::new();
        render_gauge(
            &mut s,
            "ltls_net_open_connections",
            "connections currently open (accepted, not yet torn down)",
            self.open_connections() as f64,
        );
        crate::obs::render_counter(
            &mut s,
            "ltls_net_poll_wakeups_total",
            "poll-thread self-pipe wakeups (event loop only)",
            self.poll_wakeups(),
        );
        render_gauge(
            &mut s,
            "ltls_net_write_buf_peak_bytes",
            "high-water mark of any connection's buffered reply bytes",
            self.write_buf_peak() as f64,
        );
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let m = ServingMetrics::new();
        m.record_batch(0, 8, 1_000, 50_000);
        m.record_batch(0, 4, 2_000, 30_000);
        for _ in 0..12 {
            m.record_request_latency(60_000);
        }
        let (reqs, batches, mean) = m.counts();
        assert_eq!(reqs, 12);
        assert_eq!(batches, 2);
        assert!((mean - 6.0).abs() < 1e-9);
        assert!(m.request_quantile_ns(0.5) > 0.0);
        assert!(m.summary().contains("batches=2"));
    }

    #[test]
    fn per_worker_attribution() {
        let m = ServingMetrics::with_workers(3);
        m.record_batch(0, 5, 100, 1_000);
        m.record_batch(2, 3, 100, 2_000);
        m.record_batch(2, 1, 100, 3_000);
        let pw = m.per_worker();
        assert_eq!(pw.len(), 3);
        assert_eq!(pw[0].requests, 5);
        assert_eq!(pw[0].batches, 1);
        assert_eq!(pw[1].requests, 0);
        assert_eq!(pw[2].requests, 4);
        assert_eq!(pw[2].batches, 2);
        assert_eq!(pw[2].busy_ns, 5_000);
        assert!((pw[2].mean_batch() - 2.0).abs() < 1e-9);
        let (reqs, batches, _) = m.counts();
        assert_eq!((reqs, batches), (9, 3));
        assert!(m.summary().contains("worker 2"));
    }

    #[test]
    fn prometheus_rendering_lists_aggregates_and_workers() {
        let m = ServingMetrics::with_workers(2);
        m.record_batch(1, 6, 2_000, 9_000);
        m.record_request_latency(11_000);
        let text = m.prometheus();
        assert!(text.contains("ltls_requests_total 6"), "{text}");
        assert!(text.contains("ltls_batches_total 1"), "{text}");
        assert!(text.contains("ltls_worker_requests{worker=\"0\"} 0"), "{text}");
        assert!(text.contains("ltls_worker_requests{worker=\"1\"} 6"), "{text}");
        assert!(text.contains("ltls_worker_busy_seconds_total{worker=\"1\"} 0.000009"), "{text}");
        // Conformant exposition: every family carries HELP/TYPE headers.
        assert!(text.contains("# HELP ltls_requests_total"), "{text}");
        assert!(text.contains("# TYPE ltls_requests_total counter"), "{text}");
        assert!(text.contains("# TYPE ltls_request_latency_seconds histogram"), "{text}");
        // Full cumulative series present.
        assert!(text.contains("ltls_request_latency_seconds_bucket{le=\"+Inf\"} 1"), "{text}");
        assert!(text.contains("ltls_request_latency_seconds_count 1"), "{text}");
        assert!(text.contains("ltls_queue_latency_seconds_sum"), "{text}");
        // Every sample line is `name value`; comment lines start with #.
        for line in text.lines() {
            if line.starts_with('#') {
                continue;
            }
            assert_eq!(line.split_whitespace().count(), 2, "bad line {line:?}");
        }
    }

    #[test]
    fn histogram_buckets_are_monotone_in_le() {
        let m = ServingMetrics::new();
        for ns in [500u64, 1_500, 1_500, 80_000, 2_000_000] {
            m.record_request_latency(ns);
        }
        let text = m.prometheus();
        let mut prev = 0u64;
        let mut seen = 0;
        for line in text.lines().filter(|l| l.starts_with("ltls_request_latency_seconds_bucket")) {
            let v: u64 = line.split_whitespace().nth(1).unwrap().parse().unwrap();
            assert!(v >= prev, "non-monotone bucket: {line}");
            prev = v;
            seen += 1;
        }
        assert_eq!(seen, crate::util::timer::LOG2_BUCKETS);
        assert_eq!(prev, 5, "cumulative +Inf bucket must equal the count");
    }

    #[test]
    fn worker_table_grows_on_demand() {
        let m = ServingMetrics::new();
        m.record_batch(5, 2, 0, 0);
        let pw = m.per_worker();
        assert_eq!(pw.len(), 6);
        assert_eq!(pw[5].requests, 2);
    }

    #[test]
    fn transport_gauges_track_and_render() {
        let g = TransportGauges::new();
        g.conn_opened();
        g.conn_opened();
        g.conn_closed();
        g.record_poll_wakeup();
        g.observe_write_buf(512);
        g.observe_write_buf(128); // below peak: no change
        assert_eq!(g.open_connections(), 1);
        assert_eq!(g.poll_wakeups(), 1);
        assert_eq!(g.write_buf_peak(), 512);
        let text = g.prometheus();
        assert!(text.contains("ltls_net_open_connections 1"), "{text}");
        assert!(text.contains("ltls_net_poll_wakeups_total 1"), "{text}");
        assert!(text.contains("ltls_net_write_buf_peak_bytes 512"), "{text}");
        assert!(text.contains("# TYPE ltls_net_open_connections gauge"), "{text}");
        for line in text.lines() {
            if line.starts_with('#') {
                continue;
            }
            assert_eq!(line.split_whitespace().count(), 2, "bad line {line:?}");
        }
    }

    #[test]
    fn conn_closed_saturates_instead_of_wrapping() {
        let g = TransportGauges::new();
        g.conn_opened();
        g.conn_closed();
        // The double-close race: a second teardown path reports the same
        // connection. The old fetch_sub wrapped to usize::MAX here.
        g.conn_closed();
        assert_eq!(g.open_connections(), 0);
        g.conn_opened();
        assert_eq!(g.open_connections(), 1, "gauge must stay usable after the race");
        assert!(g.prometheus().contains("ltls_net_open_connections 1"));
    }
}
