//! Serving metrics: shared latency/throughput counters the server threads
//! update and the driver reads.

use crate::util::timer::LatencyHistogram;
use std::sync::Mutex;

/// Aggregated serving metrics (interior-mutable; one lock per record is
//  fine at micro-batch granularity).
#[derive(Default)]
pub struct ServingMetrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    /// End-to-end per-request latency (enqueue → response).
    request_latency: LatencyHistogram,
    /// Queueing time of the oldest item per batch.
    queue_latency: LatencyHistogram,
    /// Batch execution time.
    exec_latency: LatencyHistogram,
    requests: u64,
    batches: u64,
}

impl ServingMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&self, batch_size: usize, queue_ns: u64, exec_ns: u64) {
        let mut g = self.inner.lock().unwrap();
        g.queue_latency.record_ns(queue_ns);
        g.exec_latency.record_ns(exec_ns);
        g.batches += 1;
        g.requests += batch_size as u64;
    }

    pub fn record_request_latency(&self, ns: u64) {
        self.inner.lock().unwrap().request_latency.record_ns(ns);
    }

    /// (requests, batches, mean batch size).
    pub fn counts(&self) -> (u64, u64, f64) {
        let g = self.inner.lock().unwrap();
        let mean = if g.batches == 0 { 0.0 } else { g.requests as f64 / g.batches as f64 };
        (g.requests, g.batches, mean)
    }

    /// Human-readable summary block.
    pub fn summary(&self) -> String {
        let g = self.inner.lock().unwrap();
        format!(
            "requests={} batches={} mean_batch={:.1}\n  request latency: {}\n  queue  latency: {}\n  exec   latency: {}",
            g.requests,
            g.batches,
            if g.batches == 0 { 0.0 } else { g.requests as f64 / g.batches as f64 },
            g.request_latency.summary(),
            g.queue_latency.summary(),
            g.exec_latency.summary(),
        )
    }

    /// Request-latency quantile in ns.
    pub fn request_quantile_ns(&self, q: f64) -> f64 {
        self.inner.lock().unwrap().request_latency.quantile_ns(q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let m = ServingMetrics::new();
        m.record_batch(8, 1_000, 50_000);
        m.record_batch(4, 2_000, 30_000);
        for _ in 0..12 {
            m.record_request_latency(60_000);
        }
        let (reqs, batches, mean) = m.counts();
        assert_eq!(reqs, 12);
        assert_eq!(batches, 2);
        assert!((mean - 6.0).abs() < 1e-9);
        assert!(m.request_quantile_ns(0.5) > 0.0);
        assert!(m.summary().contains("batches=2"));
    }
}
