//! Serving metrics: shared latency/throughput counters the server workers
//! update and the driver reads — including per-worker breakdowns so
//! pool-imbalance is visible.

use crate::util::bench::fmt_ns;
use crate::util::timer::LatencyHistogram;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Per-worker counters (one slot per worker thread in the pool).
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    pub requests: u64,
    pub batches: u64,
    /// Total batch-execution time on this worker.
    pub busy_ns: u64,
}

impl WorkerStats {
    /// Mean batch size on this worker.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

/// Aggregated serving metrics (interior-mutable; one lock per record is
/// fine at micro-batch granularity).
#[derive(Default)]
pub struct ServingMetrics {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    /// End-to-end per-request latency (enqueue → response).
    request_latency: LatencyHistogram,
    /// Queueing time of the oldest item per batch.
    queue_latency: LatencyHistogram,
    /// Batch execution time.
    exec_latency: LatencyHistogram,
    requests: u64,
    batches: u64,
    per_worker: Vec<WorkerStats>,
}

impl ServingMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-size the per-worker table for an `n`-worker pool.
    pub fn with_workers(n: usize) -> Self {
        let m = ServingMetrics::default();
        m.inner.lock().unwrap().per_worker = vec![WorkerStats::default(); n];
        m
    }

    pub fn record_batch(&self, worker: usize, batch_size: usize, queue_ns: u64, exec_ns: u64) {
        let mut g = self.inner.lock().unwrap();
        g.queue_latency.record_ns(queue_ns);
        g.exec_latency.record_ns(exec_ns);
        g.batches += 1;
        g.requests += batch_size as u64;
        if g.per_worker.len() <= worker {
            g.per_worker.resize(worker + 1, WorkerStats::default());
        }
        let w = &mut g.per_worker[worker];
        w.requests += batch_size as u64;
        w.batches += 1;
        w.busy_ns += exec_ns;
    }

    pub fn record_request_latency(&self, ns: u64) {
        self.inner.lock().unwrap().request_latency.record_ns(ns);
    }

    /// (requests, batches, mean batch size).
    pub fn counts(&self) -> (u64, u64, f64) {
        let g = self.inner.lock().unwrap();
        let mean = if g.batches == 0 { 0.0 } else { g.requests as f64 / g.batches as f64 };
        (g.requests, g.batches, mean)
    }

    /// Snapshot of the per-worker counters.
    pub fn per_worker(&self) -> Vec<WorkerStats> {
        self.inner.lock().unwrap().per_worker.clone()
    }

    /// Human-readable summary block (aggregate + per-worker lines).
    pub fn summary(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mut s = format!(
            "requests={} batches={} mean_batch={:.1}\n  request latency: {}\n  queue  latency: {}\n  exec   latency: {}",
            g.requests,
            g.batches,
            if g.batches == 0 { 0.0 } else { g.requests as f64 / g.batches as f64 },
            g.request_latency.summary(),
            g.queue_latency.summary(),
            g.exec_latency.summary(),
        );
        for (i, w) in g.per_worker.iter().enumerate() {
            s.push_str(&format!(
                "\n  worker {i}: requests={} batches={} mean_batch={:.1} busy={}",
                w.requests,
                w.batches,
                w.mean_batch(),
                fmt_ns(w.busy_ns as f64),
            ));
        }
        s
    }

    /// Request-latency quantile in ns.
    pub fn request_quantile_ns(&self, q: f64) -> f64 {
        self.inner.lock().unwrap().request_latency.quantile_ns(q)
    }

    /// Prometheus-style plaintext rendering — the body of the network
    /// frontend's `METRICS` endpoint ([`super::transport`]): one
    /// `name value` gauge per line, per-worker counters carrying a
    /// `{worker="i"}` label. Scrape-friendly and greppable.
    pub fn prometheus(&self) -> String {
        use std::fmt::Write as _;
        let g = self.inner.lock().unwrap();
        let mut s = String::new();
        let _ = writeln!(s, "ltls_requests_total {}", g.requests);
        let _ = writeln!(s, "ltls_batches_total {}", g.batches);
        let mean = if g.batches == 0 { 0.0 } else { g.requests as f64 / g.batches as f64 };
        let _ = writeln!(s, "ltls_mean_batch_size {mean:.3}");
        let _ =
            writeln!(s, "ltls_request_latency_p50_ns {:.0}", g.request_latency.quantile_ns(0.5));
        let _ =
            writeln!(s, "ltls_request_latency_p99_ns {:.0}", g.request_latency.quantile_ns(0.99));
        let _ = writeln!(s, "ltls_queue_latency_p99_ns {:.0}", g.queue_latency.quantile_ns(0.99));
        let _ = writeln!(s, "ltls_exec_latency_p99_ns {:.0}", g.exec_latency.quantile_ns(0.99));
        for (i, w) in g.per_worker.iter().enumerate() {
            let _ = writeln!(s, "ltls_worker_requests{{worker=\"{i}\"}} {}", w.requests);
            let _ = writeln!(s, "ltls_worker_batches{{worker=\"{i}\"}} {}", w.batches);
            let _ = writeln!(s, "ltls_worker_busy_ns{{worker=\"{i}\"}} {}", w.busy_ns);
        }
        s
    }
}

/// Lock-free transport-level gauges shared between the network frontend's
/// accept path, its per-connection I/O, and the `METRICS` renderer.
///
/// Both transports ([`super::transport`]) update the same set, so a scrape
/// reads identically whether the frontend runs thread-per-connection or
/// the poll(2) event loop — only `poll_wakeups_total` stays at zero under
/// the threaded transport (it has no poll threads to wake).
#[derive(Debug, Default)]
pub struct TransportGauges {
    /// Connections currently open (accepted, not yet torn down).
    open_connections: AtomicUsize,
    /// Times a poll thread was woken by its self-pipe (event loop only).
    poll_wakeups_total: AtomicU64,
    /// High-water mark of any single connection's buffered reply bytes.
    write_buf_peak: AtomicUsize,
}

impl TransportGauges {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn conn_opened(&self) {
        self.open_connections.fetch_add(1, Ordering::Relaxed);
    }

    pub fn conn_closed(&self) {
        self.open_connections.fetch_sub(1, Ordering::Relaxed);
    }

    pub fn open_connections(&self) -> usize {
        self.open_connections.load(Ordering::Relaxed)
    }

    pub fn record_poll_wakeup(&self) {
        self.poll_wakeups_total.fetch_add(1, Ordering::Relaxed);
    }

    pub fn poll_wakeups(&self) -> u64 {
        self.poll_wakeups_total.load(Ordering::Relaxed)
    }

    /// Raise the write-buffer high-water mark to `bytes` if it exceeds
    /// the current peak (monotone; races only under-report transiently).
    pub fn observe_write_buf(&self, bytes: usize) {
        self.write_buf_peak.fetch_max(bytes, Ordering::Relaxed);
    }

    pub fn write_buf_peak(&self) -> usize {
        self.write_buf_peak.load(Ordering::Relaxed)
    }

    /// The transport's gauge lines for the `METRICS` endpoint, matching
    /// the `ltls_net_*` namespace of [`super::transport`]'s renderer.
    pub fn prometheus(&self) -> String {
        format!(
            "ltls_net_open_connections {}\nltls_net_poll_wakeups_total {}\nltls_net_write_buf_peak_bytes {}\n",
            self.open_connections(),
            self.poll_wakeups(),
            self.write_buf_peak(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let m = ServingMetrics::new();
        m.record_batch(0, 8, 1_000, 50_000);
        m.record_batch(0, 4, 2_000, 30_000);
        for _ in 0..12 {
            m.record_request_latency(60_000);
        }
        let (reqs, batches, mean) = m.counts();
        assert_eq!(reqs, 12);
        assert_eq!(batches, 2);
        assert!((mean - 6.0).abs() < 1e-9);
        assert!(m.request_quantile_ns(0.5) > 0.0);
        assert!(m.summary().contains("batches=2"));
    }

    #[test]
    fn per_worker_attribution() {
        let m = ServingMetrics::with_workers(3);
        m.record_batch(0, 5, 100, 1_000);
        m.record_batch(2, 3, 100, 2_000);
        m.record_batch(2, 1, 100, 3_000);
        let pw = m.per_worker();
        assert_eq!(pw.len(), 3);
        assert_eq!(pw[0].requests, 5);
        assert_eq!(pw[0].batches, 1);
        assert_eq!(pw[1].requests, 0);
        assert_eq!(pw[2].requests, 4);
        assert_eq!(pw[2].batches, 2);
        assert_eq!(pw[2].busy_ns, 5_000);
        assert!((pw[2].mean_batch() - 2.0).abs() < 1e-9);
        let (reqs, batches, _) = m.counts();
        assert_eq!((reqs, batches), (9, 3));
        assert!(m.summary().contains("worker 2"));
    }

    #[test]
    fn prometheus_rendering_lists_aggregates_and_workers() {
        let m = ServingMetrics::with_workers(2);
        m.record_batch(1, 6, 2_000, 9_000);
        m.record_request_latency(11_000);
        let text = m.prometheus();
        assert!(text.contains("ltls_requests_total 6"), "{text}");
        assert!(text.contains("ltls_batches_total 1"), "{text}");
        assert!(text.contains("ltls_worker_requests{worker=\"0\"} 0"), "{text}");
        assert!(text.contains("ltls_worker_requests{worker=\"1\"} 6"), "{text}");
        assert!(text.contains("ltls_worker_busy_ns{worker=\"1\"} 9000"), "{text}");
        // Every line is `name value`.
        for line in text.lines() {
            assert_eq!(line.split_whitespace().count(), 2, "bad line {line:?}");
        }
    }

    #[test]
    fn worker_table_grows_on_demand() {
        let m = ServingMetrics::new();
        m.record_batch(5, 2, 0, 0);
        let pw = m.per_worker();
        assert_eq!(pw.len(), 6);
        assert_eq!(pw[5].requests, 2);
    }

    #[test]
    fn transport_gauges_track_and_render() {
        let g = TransportGauges::new();
        g.conn_opened();
        g.conn_opened();
        g.conn_closed();
        g.record_poll_wakeup();
        g.observe_write_buf(512);
        g.observe_write_buf(128); // below peak: no change
        assert_eq!(g.open_connections(), 1);
        assert_eq!(g.poll_wakeups(), 1);
        assert_eq!(g.write_buf_peak(), 512);
        let text = g.prometheus();
        assert!(text.contains("ltls_net_open_connections 1"), "{text}");
        assert!(text.contains("ltls_net_poll_wakeups_total 1"), "{text}");
        assert!(text.contains("ltls_net_write_buf_peak_bytes 512"), "{text}");
        for line in text.lines() {
            assert_eq!(line.split_whitespace().count(), 2, "bad line {line:?}");
        }
    }
}
