//! Dynamic micro-batching: collect requests until `max_batch` or
//! `max_wait` elapses, whichever first — the standard latency/throughput
//! dial of serving systems.

use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 64, max_wait: Duration::from_millis(2) }
    }
}

/// An item that records when it entered the queue, so the batcher can
/// report true queueing latency (measured from *enqueue*, not from when a
/// worker happened to pick the item up).
pub trait Stamped {
    fn enqueued_at(&self) -> Instant;
}

/// A collected batch of items.
pub struct Batch<T> {
    pub items: Vec<T>,
    /// When the oldest item was *enqueued* (queueing-latency metric).
    pub oldest: Instant,
    /// When collection finished — the `batch_form` trace stamp, taken
    /// once here so every item in the batch shares one clock reading.
    pub formed: Instant,
}

/// Pull one batch from `rx`. Blocks for the first item, then drains until
/// the size or time bound trips. Returns `None` when the channel closed
/// and is empty. `oldest` is the earliest enqueue stamp in the batch —
/// taking it after `recv` returned would under-report the first
/// request's queueing time.
///
/// The `max_wait` deadline is measured from `oldest` (the batch's
/// earliest *enqueue* stamp), not from when `recv` happened to return:
/// a request that already sat in the queue for `max_wait` while the
/// worker was busy must flush immediately, not pay the wait twice.
///
/// The deadline only governs *waiting*: items already sitting in the
/// channel always join the batch (up to `max_batch`), so under backlog
/// a stale batch still flushes at full size instead of degenerating to
/// per-request singletons.
pub fn next_batch<T: Stamped>(rx: &Receiver<T>, cfg: &BatcherConfig) -> Option<Batch<T>> {
    let first = rx.recv().ok()?;
    let mut oldest = first.enqueued_at();
    let mut items = vec![first];
    while items.len() < cfg.max_batch {
        // Ready items are free — take them regardless of the deadline.
        match rx.try_recv() {
            Ok(item) => {
                oldest = oldest.min(item.enqueued_at());
                items.push(item);
                continue;
            }
            Err(TryRecvError::Empty) => {}
            Err(TryRecvError::Disconnected) => break,
        }
        // Recomputed each iteration: a drained item with an even older
        // stamp pulls the deadline earlier.
        let deadline = oldest + cfg.max_wait;
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => {
                oldest = oldest.min(item.enqueued_at());
                items.push(item);
            }
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(Batch { items, oldest, formed: Instant::now() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    /// Test item: payload + enqueue stamp.
    #[derive(Debug, PartialEq)]
    struct Item(u32, Instant);

    impl Item {
        fn now(v: u32) -> Item {
            Item(v, Instant::now())
        }
    }

    impl Stamped for Item {
        fn enqueued_at(&self) -> Instant {
            self.1
        }
    }

    fn ids(b: &Batch<Item>) -> Vec<u32> {
        b.items.iter().map(|i| i.0).collect()
    }

    #[test]
    fn batches_up_to_max_batch() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(Item::now(i)).unwrap();
        }
        let cfg = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(50) };
        let b = next_batch(&rx, &cfg).unwrap();
        assert_eq!(ids(&b), vec![0, 1, 2, 3]);
        let b2 = next_batch(&rx, &cfg).unwrap();
        assert_eq!(b2.items.len(), 4);
    }

    #[test]
    fn flushes_on_timeout() {
        let (tx, rx) = channel();
        tx.send(Item::now(1)).unwrap();
        tx.send(Item::now(2)).unwrap();
        let cfg = BatcherConfig { max_batch: 100, max_wait: Duration::from_millis(5) };
        let t0 = Instant::now();
        let b = next_batch(&rx, &cfg).unwrap();
        assert_eq!(ids(&b), vec![1, 2]);
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn returns_none_on_closed_empty_channel() {
        let (tx, rx) = channel::<Item>();
        drop(tx);
        assert!(next_batch(&rx, &BatcherConfig::default()).is_none());
    }

    #[test]
    fn drains_after_close() {
        let (tx, rx) = channel();
        tx.send(Item::now(7)).unwrap();
        drop(tx);
        let b = next_batch(&rx, &BatcherConfig::default()).unwrap();
        assert_eq!(ids(&b), vec![7]);
        assert!(next_batch(&rx, &BatcherConfig::default()).is_none());
    }

    /// The regression this module's `oldest` fix pins down: an item that
    /// sat in the channel before the batcher woke up must be accounted
    /// from its *enqueue* time, not from when `recv` returned it.
    #[test]
    fn oldest_is_enqueue_time_not_recv_time() {
        let (tx, rx) = channel();
        let stamp = Instant::now();
        tx.send(Item(1, stamp)).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        let cfg = BatcherConfig { max_batch: 1, max_wait: Duration::from_millis(1) };
        let b = next_batch(&rx, &cfg).unwrap();
        assert_eq!(b.oldest, stamp);
        assert!(b.oldest.elapsed() >= Duration::from_millis(10));
        // Formation happens strictly after the oldest enqueue.
        assert!(b.formed >= b.oldest);
    }

    /// The double-wait regression this module's deadline fix pins down: a
    /// request that already sat in the queue for longer than `max_wait`
    /// must flush immediately — the deadline runs from its *enqueue*
    /// stamp, so it must not pay (up to) `max_wait` a second time just
    /// because the worker picked it up late.
    #[test]
    fn stale_first_request_flushes_immediately() {
        let (tx, rx) = channel();
        // Enqueued `max_wait`+ ago: the deadline is already in the past.
        let stale = Instant::now() - Duration::from_millis(200);
        tx.send(Item(1, stale)).unwrap();
        let cfg = BatcherConfig { max_batch: 100, max_wait: Duration::from_millis(100) };
        let t0 = Instant::now();
        let b = next_batch(&rx, &cfg).unwrap();
        // Well under max_wait: the old code would have waited ~100ms more
        // for the channel to go quiet before flushing this batch.
        assert!(
            t0.elapsed() < Duration::from_millis(50),
            "stale request waited again: {:?}",
            t0.elapsed()
        );
        assert_eq!(ids(&b), vec![1]);
        assert_eq!(b.oldest, stale);
    }

    /// Under backlog a stale batch still fills up from ready items: the
    /// enqueue-stamp deadline bounds *waiting*, never the free drain of
    /// what is already queued (otherwise overload would degenerate into
    /// size-1 batches exactly when batching matters most).
    #[test]
    fn stale_batch_takes_ready_backlog_without_waiting() {
        let (tx, rx) = channel();
        let stale = Instant::now() - Duration::from_millis(50);
        for i in 0..6 {
            tx.send(Item(i, stale)).unwrap();
        }
        let cfg = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(10) };
        let t0 = Instant::now();
        let b = next_batch(&rx, &cfg).unwrap();
        assert_eq!(ids(&b), vec![0, 1, 2, 3], "stale batch must still fill from the backlog");
        assert!(t0.elapsed() < Duration::from_millis(10), "backlog drain must not wait");
        let b2 = next_batch(&rx, &cfg).unwrap();
        assert_eq!(ids(&b2), vec![4, 5]);
    }

    /// `oldest` is the minimum stamp across the whole batch.
    #[test]
    fn oldest_is_minimum_over_batch() {
        let (tx, rx) = channel();
        let early = Instant::now();
        std::thread::sleep(Duration::from_millis(2));
        let late = Instant::now();
        // Later-stamped item arrives first in the queue.
        tx.send(Item(1, late)).unwrap();
        tx.send(Item(2, early)).unwrap();
        let cfg = BatcherConfig { max_batch: 2, max_wait: Duration::from_millis(50) };
        let b = next_batch(&rx, &cfg).unwrap();
        assert_eq!(b.items.len(), 2);
        assert_eq!(b.oldest, early);
    }
}
