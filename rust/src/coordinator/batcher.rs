//! Dynamic micro-batching: collect requests until `max_batch` or
//! `max_wait` elapses, whichever first — the standard latency/throughput
//! dial of serving systems.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 64, max_wait: Duration::from_millis(2) }
    }
}

/// A collected batch of items.
pub struct Batch<T> {
    pub items: Vec<T>,
    /// When the oldest item entered the batcher (queueing-latency metric).
    pub oldest: Instant,
}

/// Pull one batch from `rx`. Blocks for the first item, then drains until
/// the size or time bound trips. Returns `None` when the channel closed
/// and is empty.
pub fn next_batch<T>(rx: &Receiver<T>, cfg: &BatcherConfig) -> Option<Batch<T>> {
    let first = rx.recv().ok()?;
    let oldest = Instant::now();
    let mut items = vec![first];
    let deadline = oldest + cfg.max_wait;
    while items.len() < cfg.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => items.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(Batch { items, oldest })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn batches_up_to_max_batch() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let cfg = BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(50) };
        let b = next_batch(&rx, &cfg).unwrap();
        assert_eq!(b.items, vec![0, 1, 2, 3]);
        let b2 = next_batch(&rx, &cfg).unwrap();
        assert_eq!(b2.items.len(), 4);
    }

    #[test]
    fn flushes_on_timeout() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        let cfg = BatcherConfig { max_batch: 100, max_wait: Duration::from_millis(5) };
        let t0 = Instant::now();
        let b = next_batch(&rx, &cfg).unwrap();
        assert_eq!(b.items, vec![1, 2]);
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn returns_none_on_closed_empty_channel() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert!(next_batch(&rx, &BatcherConfig::default()).is_none());
    }

    #[test]
    fn drains_after_close() {
        let (tx, rx) = channel();
        tx.send(7).unwrap();
        drop(tx);
        let b = next_batch(&rx, &BatcherConfig::default()).unwrap();
        assert_eq!(b.items, vec![7]);
        assert!(next_batch(&rx, &BatcherConfig::default()).is_none());
    }
}
