//! Scatter-gather serving tier: fan each request batch out over N label
//! shards, k-way-merge the partial top-k lists, fail over between shard
//! replicas.
//!
//! Each shard process serves a **v4 model slice** (`ltls shard`, see
//! [`crate::model::shard`]): the full trellis with every non-owned
//! terminal edge masked to `-inf`, so a shard's top-k list contains
//! exactly its owned labels with bit-identical scores. Label ownership
//! partitions the label space ([`crate::graph::ShardPlan`]), so the
//! global top-k is a subset of the union of per-shard top-k lists and
//! [`merge_topk`] reconstructs it exactly.
//!
//! The coordinator ([`ScatterModel`]) plugs into the existing serving
//! stack as just another [`BatchModel`]: the normal wire protocol,
//! admission control, batcher and worker pool all apply unchanged —
//! a worker's `predict_batch_into` pipelines the whole micro-batch to
//! every shard over persistent pooled connections
//! ([`crate::util::netclient::NetClient`], one per worker thread per
//! replica), then gathers the replies multiplexed through `poll(2)`
//! ([`crate::util::poll`]) so slow shards overlap instead of serializing.
//!
//! Failure handling, per attempt (one batch exchange with one replica):
//! a connect error, I/O error, reply timeout
//! ([`ScatterConfig::shard_timeout_ms`]) or backpressure reply fails the
//! attempt; the batch is then retried on the shard's other replicas in
//! round-robin order (plus one fresh-connection retry wrapping back, so
//! a stale pooled connection never degrades a single-replica shard).
//! Only when every replica of a shard is down is the shard omitted and
//! the affected replies marked `"partial":true` (`docs/PROTOCOL.md`).
//! Everything is observable: `ltls_shard_requests_total{shard="i"}`,
//! `ltls_shard_retries_total`, `ltls_shard_degraded_total` and the
//! `ltls_shard_rtt_seconds` histogram ([`ScatterStats`]) join the
//! `METRICS` exposition on every server (zero-valued on unsharded ones,
//! so the scrape name set is topology-independent).

use super::server::{BatchModel, Request, Response};
use crate::engine::PredictScratch;
use crate::obs::{
    render_counter, render_histogram, Counter, Histogram, HistogramSnapshot, Registry, Stage,
};
use crate::util::json::Json;
use crate::util::netclient::NetClient;
use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Scatter-tier configuration (the shard topology itself is given to
/// [`ScatterModel::from_spec`] as `host:port` lists).
#[derive(Clone, Debug, Default)]
pub struct ScatterConfig {
    /// Budget for one batch exchange with one replica, milliseconds
    /// (0 → 2000). On expiry the attempt fails and the batch is retried
    /// on the shard's other replica.
    pub shard_timeout_ms: u64,
    /// TCP connect budget per replica, milliseconds (0 → 1000).
    pub connect_timeout_ms: u64,
    /// Feature dimensionality `D` of the sharded model, when known
    /// (`--features`). The coordinator itself holds no weights, so
    /// without this requests with out-of-range feature indices reach the
    /// shards and come back as empty top-k lists instead of being
    /// rejected with a protocol error up front.
    pub n_features: Option<usize>,
}

impl ScatterConfig {
    fn shard_timeout(&self) -> Duration {
        if self.shard_timeout_ms == 0 {
            Duration::from_millis(2000)
        } else {
            Duration::from_millis(self.shard_timeout_ms)
        }
    }

    fn connect_timeout(&self) -> Duration {
        if self.connect_timeout_ms == 0 {
            Duration::from_millis(1000)
        } else {
            Duration::from_millis(self.connect_timeout_ms)
        }
    }
}

/// Parse a shard topology spec: shards separated by `;`, replicas of one
/// shard separated by `,` — e.g. `"a:1,b:1;a:2,b:2"` is 2 shards × 2
/// replicas. Every address must look like `host:port`.
pub fn parse_shard_spec(spec: &str) -> Result<Vec<Vec<String>>, String> {
    let mut shards = Vec::new();
    for (si, shard) in spec.split(';').enumerate() {
        let mut replicas = Vec::new();
        for addr in shard.split(',') {
            let a = addr.trim();
            if a.is_empty() {
                return Err(format!("shard {si}: empty replica address in {spec:?}"));
            }
            if !a.contains(':') {
                return Err(format!("shard {si}: address {a:?} is not host:port"));
            }
            replicas.push(a.to_string());
        }
        shards.push(replicas);
    }
    if shards.is_empty() {
        return Err("empty shard spec".into());
    }
    Ok(shards)
}

const REQ_HELP: &str = "requests fanned out to each shard (counted per completed attempt)";
const DEG_HELP: &str = "replies answered partial because every replica of a shard was down";
const RET_HELP: &str = "batch exchanges retried on another replica after a failed attempt";
const RTT_HELP: &str = "round-trip time of one batch exchange with one shard replica";

/// Scatter-tier metrics. Rendered into the `METRICS` exposition by the
/// transport; [`ScatterStats::render_absent`] emits the same families
/// zero-valued on servers with no scatter tier, keeping the scrape name
/// set identical across topologies.
pub struct ScatterStats {
    registry: Registry,
    shard_requests: Vec<Arc<Counter>>,
    degraded: Arc<Counter>,
    retries: Arc<Counter>,
    rtt: Arc<Histogram>,
}

impl ScatterStats {
    pub fn new(n_shards: usize) -> ScatterStats {
        let registry = Registry::new();
        let shard_requests = (0..n_shards)
            .map(|i| {
                registry.counter_labeled("ltls_shard_requests_total", REQ_HELP, format!("shard=\"{i}\""))
            })
            .collect();
        let degraded = registry.counter("ltls_shard_degraded_total", DEG_HELP);
        let retries = registry.counter("ltls_shard_retries_total", RET_HELP);
        let rtt = registry.histogram("ltls_shard_rtt_seconds", RTT_HELP);
        ScatterStats { registry, shard_requests, degraded, retries, rtt }
    }

    /// Append this tier's families to a `METRICS` exposition.
    pub fn render_into(&self, out: &mut String) {
        self.registry.render(out);
    }

    /// The same families, zero-valued, for servers with no scatter tier.
    pub fn render_absent(out: &mut String) {
        render_counter(out, "ltls_shard_requests_total", REQ_HELP, 0);
        render_counter(out, "ltls_shard_degraded_total", DEG_HELP, 0);
        render_counter(out, "ltls_shard_retries_total", RET_HELP, 0);
        render_histogram(out, "ltls_shard_rtt_seconds", RTT_HELP, &HistogramSnapshot::default());
    }

    /// Requests fanned out to shard `i` so far.
    pub fn shard_requests(&self, i: usize) -> u64 {
        self.shard_requests.get(i).map(|c| c.get()).unwrap_or(0)
    }

    /// Replies answered `"partial":true` so far.
    pub fn degraded(&self) -> u64 {
        self.degraded.get()
    }

    /// Failover retries so far.
    pub fn retries(&self) -> u64 {
        self.retries.get()
    }
}

/// K-way merge of per-shard top-k lists into the global top-k, ordered
/// by score descending with ties broken toward the smaller label id.
///
/// Each part must be sorted by the same key (score descending, label
/// ascending among exact ties) — shard servers emit descending scores;
/// exact-tie order inside one shard follows its path-code decode order,
/// which only matters for bitwise-equal scores.
pub fn merge_topk(parts: &[&[(u32, f32)]], k: usize, out: &mut Vec<(u32, f32)>) {
    use std::collections::BinaryHeap;

    struct Head {
        score: f32,
        label: u32,
        part: usize,
        pos: usize,
    }
    impl PartialEq for Head {
        fn eq(&self, other: &Self) -> bool {
            self.cmp(other) == std::cmp::Ordering::Equal
        }
    }
    impl Eq for Head {}
    impl PartialOrd for Head {
        fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(other))
        }
    }
    impl Ord for Head {
        fn cmp(&self, other: &Self) -> std::cmp::Ordering {
            // Max-heap: higher score pops first; among equal scores the
            // smaller label id pops first.
            self.score.total_cmp(&other.score).then_with(|| other.label.cmp(&self.label))
        }
    }

    out.clear();
    let mut heap: BinaryHeap<Head> = parts
        .iter()
        .enumerate()
        .filter_map(|(p, list)| {
            list.first().map(|&(l, s)| Head { score: s, label: l, part: p, pos: 0 })
        })
        .collect();
    while out.len() < k {
        let Some(h) = heap.pop() else { break };
        out.push((h.label, h.score));
        if let Some(&(l, s)) = parts[h.part].get(h.pos + 1) {
            heap.push(Head { score: s, label: l, part: h.part, pos: h.pos + 1 });
        }
    }
}

/// One parsed shard reply line.
enum ShardLine {
    /// Partial top-k over the shard's owned labels.
    Topk(Vec<(u32, f32)>),
    /// Deterministic per-request rejection (e.g. the shard's feature
    /// validation). Every replica answers identically, so this
    /// contributes an empty candidate list instead of triggering
    /// failover.
    Rejected,
    /// Backpressure rejection — transient; fails the attempt so the
    /// batch retries on the other replica.
    Backpressure,
}

fn parse_shard_line(line: &str) -> Result<ShardLine, String> {
    let doc = Json::parse(line).map_err(|e| format!("unparseable shard reply: {e}"))?;
    if let Some(topk) = doc.get("topk").and_then(|t| t.as_arr()) {
        let mut v = Vec::with_capacity(topk.len());
        for pair in topk {
            let p = pair.as_arr().ok_or("malformed topk entry")?;
            let (Some(l), Some(s)) =
                (p.first().and_then(|x| x.as_f64()), p.get(1).and_then(|x| x.as_f64()))
            else {
                return Err("malformed topk entry".into());
            };
            v.push((l as u32, s as f32));
        }
        return Ok(ShardLine::Topk(v));
    }
    if doc.get("backpressure") == Some(&Json::Bool(true)) {
        return Ok(ShardLine::Backpressure);
    }
    if doc.get("error").is_some() {
        return Ok(ShardLine::Rejected);
    }
    Err(format!("unrecognized shard reply {line:?}"))
}

/// Render one admitted request back into its wire line. `{}` on f32
/// prints the shortest decimal that parses back to the same bits, so the
/// shard scores exactly what the coordinator was asked.
fn render_request_line(r: &Request) -> String {
    use std::fmt::Write as _;
    let mut s = String::with_capacity(8 + r.indices.len() * 8);
    let _ = write!(s, "{}", r.k);
    for (i, v) in r.indices.iter().zip(&r.values) {
        let _ = write!(s, " {i}:{v}");
    }
    s
}

/// One in-flight batch exchange with one replica.
struct Attempt {
    shard: usize,
    replica: usize,
    client: NetClient,
    replies: Vec<ShardLine>,
    /// EOF or hard read error observed; classified once buffered lines
    /// are exhausted.
    eof: bool,
    t0: Instant,
}

enum DrainState {
    Complete,
    Failed,
    NeedMore,
}

/// Consume buffered reply lines into `a.replies`; classify the attempt
/// once it has every reply (or can no longer get them).
fn drain_lines(a: &mut Attempt, n_lines: usize) -> DrainState {
    while a.replies.len() < n_lines {
        match a.client.take_line() {
            Ok(Some(line)) => match parse_shard_line(&line) {
                Ok(ShardLine::Backpressure) | Err(_) => return DrainState::Failed,
                Ok(r) => a.replies.push(r),
            },
            Ok(None) => {
                return if a.eof { DrainState::Failed } else { DrainState::NeedMore };
            }
            Err(_) => return DrainState::Failed, // oversized reply line
        }
    }
    DrainState::Complete
}

/// Read every pending attempt to completion (or failure) before
/// `deadline`. On unix the reads are multiplexed through one `poll(2)`
/// set so a slow shard overlaps the others; elsewhere attempts are
/// drained sequentially (replies buffer in the kernel meanwhile).
fn gather_attempts(
    mut pending: Vec<Attempt>,
    n_lines: usize,
    deadline: Instant,
) -> Vec<(Attempt, bool)> {
    let mut done: Vec<(Attempt, bool)> = Vec::new();
    #[cfg(unix)]
    {
        use crate::util::poll::{poll, PollFd, POLLIN};
        loop {
            let mut i = 0;
            while i < pending.len() {
                match drain_lines(&mut pending[i], n_lines) {
                    DrainState::Complete => {
                        let a = pending.swap_remove(i);
                        done.push((a, true));
                    }
                    DrainState::Failed => {
                        let a = pending.swap_remove(i);
                        done.push((a, false));
                    }
                    DrainState::NeedMore => i += 1,
                }
            }
            if pending.is_empty() {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                done.extend(pending.drain(..).map(|a| (a, false)));
                break;
            }
            let timeout_ms = ((deadline - now).as_millis() as i64).clamp(1, i32::MAX as i64) as i32;
            let mut fds: Vec<PollFd> =
                pending.iter().map(|a| PollFd::new(a.client.raw_fd(), POLLIN)).collect();
            if poll(&mut fds, timeout_ms).is_err() {
                done.extend(pending.drain(..).map(|a| (a, false)));
                break;
            }
            for (i, fd) in fds.iter().enumerate() {
                if !fd.readable() {
                    continue;
                }
                match pending[i].client.fill_ready() {
                    Ok(0) => pending[i].eof = true,
                    Ok(_) => {}
                    Err(e)
                        if matches!(
                            e.kind(),
                            std::io::ErrorKind::Interrupted
                                | std::io::ErrorKind::WouldBlock
                                | std::io::ErrorKind::TimedOut
                        ) => {}
                    Err(_) => pending[i].eof = true,
                }
            }
        }
    }
    #[cfg(not(unix))]
    {
        for mut a in pending.drain(..) {
            let ok = loop {
                match drain_lines(&mut a, n_lines) {
                    DrainState::Complete => break true,
                    DrainState::Failed => break false,
                    DrainState::NeedMore => match a.client.recv_line(deadline) {
                        Ok(line) => match parse_shard_line(&line) {
                            Ok(ShardLine::Backpressure) | Err(_) => break false,
                            Ok(r) => a.replies.push(r),
                        },
                        Err(_) => break false,
                    },
                }
            };
            done.push((a, ok));
        }
    }
    done
}

/// One shard's replica set with its round-robin cursor.
struct ShardSet {
    replicas: Vec<String>,
    rr: AtomicUsize,
}

// Persistent connections, one per (coordinator instance, shard, replica)
// per worker thread. Checked out for the duration of an attempt and
// returned on success; failed attempts drop theirs, so a reconnect is
// the natural retry path.
thread_local! {
    static CONNS: RefCell<HashMap<(u64, usize, usize), NetClient>> = RefCell::new(HashMap::new());
}

static NEXT_SCATTER_ID: AtomicU64 = AtomicU64::new(0);

/// The scatter-gather coordinator as a [`BatchModel`] — serves behind the
/// ordinary [`super::transport::NetServer`] frontend (started via
/// [`super::transport::NetServer::start_scatter`] so the shard metrics
/// join the exposition).
pub struct ScatterModel {
    id: u64,
    shards: Vec<ShardSet>,
    stats: Arc<ScatterStats>,
    timeout: Duration,
    connect_timeout: Duration,
    n_features: Option<usize>,
}

impl ScatterModel {
    /// Build from a parsed topology: `shards[i]` lists shard `i`'s
    /// replica addresses (at least one each).
    pub fn new(shards: Vec<Vec<String>>, cfg: ScatterConfig) -> Result<ScatterModel, String> {
        if shards.is_empty() {
            return Err("scatter tier needs at least one shard".into());
        }
        if let Some(i) = shards.iter().position(|r| r.is_empty()) {
            return Err(format!("shard {i} has no replica addresses"));
        }
        let stats = Arc::new(ScatterStats::new(shards.len()));
        Ok(ScatterModel {
            id: NEXT_SCATTER_ID.fetch_add(1, Ordering::Relaxed),
            shards: shards
                .into_iter()
                .map(|replicas| ShardSet { replicas, rr: AtomicUsize::new(0) })
                .collect(),
            stats,
            timeout: cfg.shard_timeout(),
            connect_timeout: cfg.connect_timeout(),
            n_features: cfg.n_features,
        })
    }

    /// [`Self::new`] over a `"h:p,h:p;h:p,h:p"` spec ([`parse_shard_spec`]).
    pub fn from_spec(spec: &str, cfg: ScatterConfig) -> Result<ScatterModel, String> {
        ScatterModel::new(parse_shard_spec(spec)?, cfg)
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn stats(&self) -> Arc<ScatterStats> {
        Arc::clone(&self.stats)
    }

    fn checkout(&self, shard: usize, replica: usize) -> Option<NetClient> {
        CONNS.with(|c| c.borrow_mut().remove(&(self.id, shard, replica)))
    }

    fn checkin(&self, shard: usize, replica: usize, client: NetClient) {
        CONNS.with(|c| c.borrow_mut().insert((self.id, shard, replica), client));
    }

    /// Open (or reuse) the connection to one replica and pipeline the
    /// whole batch onto it. `None` = the attempt already failed.
    fn open_and_send(
        &self,
        shard: usize,
        replica: usize,
        lines: &[String],
        deadline: Instant,
    ) -> Option<Attempt> {
        let t0 = Instant::now();
        let mut client = match self.checkout(shard, replica) {
            Some(c) => c,
            None => {
                let addr = self.shards[shard].replicas[replica].as_str();
                NetClient::connect(addr, self.connect_timeout).ok()?
            }
        };
        for line in lines {
            if client.send_line(line, deadline).is_err() {
                return None; // broken connection is dropped, not pooled
            }
        }
        Some(Attempt {
            shard,
            replica,
            client,
            replies: Vec::with_capacity(lines.len()),
            eof: false,
            t0,
        })
    }

    /// Record a finished attempt; returns its parsed replies on success.
    fn settle(&self, a: Attempt, ok: bool, n_lines: usize) -> Option<Vec<ShardLine>> {
        self.stats.rtt.record_duration(a.t0.elapsed());
        if !ok {
            return None;
        }
        self.stats.shard_requests[a.shard].add(n_lines as u64);
        let Attempt { shard, replica, client, replies, .. } = a;
        self.checkin(shard, replica, client);
        Some(replies)
    }

    /// The scatter-gather core: fan the batch out, gather, fail over,
    /// merge. See the module docs for the failure semantics.
    fn exchange(&self, batch: &[Request], out: &mut Vec<Response>) {
        out.clear();
        if batch.is_empty() {
            return;
        }
        let lines: Vec<String> = batch.iter().map(render_request_line).collect();
        let n_shards = self.shards.len();
        let mut results: Vec<Option<Vec<ShardLine>>> = (0..n_shards).map(|_| None).collect();

        // Scatter: one primary attempt per shard, replicas rotated per
        // batch (round-robin load balancing), gathered concurrently.
        let deadline = Instant::now() + self.timeout;
        let primaries: Vec<usize> = self
            .shards
            .iter()
            .map(|s| s.rr.fetch_add(1, Ordering::Relaxed) % s.replicas.len())
            .collect();
        let mut pending = Vec::with_capacity(n_shards);
        for (shard, &replica) in primaries.iter().enumerate() {
            if let Some(a) = self.open_and_send(shard, replica, &lines, deadline) {
                pending.push(a);
            }
        }
        for (a, ok) in gather_attempts(pending, lines.len(), deadline) {
            let shard = a.shard;
            results[shard] = self.settle(a, ok, lines.len());
        }

        // Failover: retry failed shards on their other replicas (ending
        // with a fresh connection to the primary, so a single stale
        // pooled connection never degrades a reply).
        for shard in 0..n_shards {
            if results[shard].is_some() {
                continue;
            }
            let n_rep = self.shards[shard].replicas.len();
            for off in 1..=n_rep {
                let replica = (primaries[shard] + off) % n_rep;
                self.stats.retries.inc();
                let deadline = Instant::now() + self.timeout;
                let Some(a) = self.open_and_send(shard, replica, &lines, deadline) else {
                    continue;
                };
                let mut finished = gather_attempts(vec![a], lines.len(), deadline);
                let (a, ok) = finished.pop().expect("one attempt in, one out");
                results[shard] = self.settle(a, ok, lines.len());
                if results[shard].is_some() {
                    break;
                }
            }
        }

        // Gather complete; stamp traced requests like the local scorer
        // stamps its batch scoring pass.
        let gathered = Instant::now();
        for r in batch {
            if let Some(sp) = &r.span {
                sp.stamp_at(Stage::Score, gathered);
            }
        }

        // Merge. A reply is partial iff some shard contributed nothing.
        let degraded = results.iter().any(|r| r.is_none());
        if degraded {
            self.stats.degraded.add(batch.len() as u64);
        }
        let mut parts: Vec<&[(u32, f32)]> = Vec::with_capacity(n_shards);
        for (ri, r) in batch.iter().enumerate() {
            parts.clear();
            for shard_replies in results.iter().flatten() {
                if let ShardLine::Topk(list) = &shard_replies[ri] {
                    parts.push(list);
                }
            }
            let mut topk = Vec::with_capacity(r.k);
            merge_topk(&parts, r.k, &mut topk);
            if let Some(sp) = &r.span {
                sp.stamp(Stage::Decode);
            }
            out.push(Response { topk, partial: degraded });
        }
    }
}

impl BatchModel for ScatterModel {
    fn predict_batch(&self, batch: &[Request]) -> Vec<Response> {
        let mut out = Vec::with_capacity(batch.len());
        self.exchange(batch, &mut out);
        out
    }

    fn predict_batch_into(
        &self,
        batch: &[Request],
        _scratch: &mut PredictScratch,
        out: &mut Vec<Response>,
    ) {
        self.exchange(batch, out);
    }

    fn n_features(&self) -> Option<usize> {
        self.n_features
    }

    fn name(&self) -> &str {
        "LTLS-scatter"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parses_shards_and_replicas() {
        let s = parse_shard_spec("a:1,b:1;a:2,b:2").unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(s[0], vec!["a:1".to_string(), "b:1".to_string()]);
        assert_eq!(s[1], vec!["a:2".to_string(), "b:2".to_string()]);
        let single = parse_shard_spec("127.0.0.1:7878").unwrap();
        assert_eq!(single, vec![vec!["127.0.0.1:7878".to_string()]]);
        let spaced = parse_shard_spec(" a:1 , b:1 ; c:1 ").unwrap();
        assert_eq!(spaced[0], vec!["a:1".to_string(), "b:1".to_string()]);
        assert_eq!(spaced[1], vec!["c:1".to_string()]);
    }

    #[test]
    fn spec_rejects_malformed() {
        assert!(parse_shard_spec("").is_err());
        assert!(parse_shard_spec("a:1;;b:1").is_err()); // empty shard
        assert!(parse_shard_spec("a:1,").is_err()); // empty replica
        assert!(parse_shard_spec("localhost").is_err()); // no port
    }

    #[test]
    fn merge_is_global_topk_with_label_tiebreak() {
        // Disjoint label sets, deliberate score ties across parts.
        let a: Vec<(u32, f32)> = vec![(10, 5.0), (12, 3.0), (14, 1.0)];
        let b: Vec<(u32, f32)> = vec![(11, 5.0), (13, 3.0)];
        let c: Vec<(u32, f32)> = vec![(2, 4.0)];
        let mut out = Vec::new();
        merge_topk(&[&a, &b, &c], 4, &mut out);
        assert_eq!(out, vec![(10, 5.0), (11, 5.0), (2, 4.0), (12, 3.0)]);
        // k larger than the union: everything, still ordered.
        merge_topk(&[&a, &b, &c], 100, &mut out);
        assert_eq!(out.len(), 6);
        assert_eq!(out[4], (13, 3.0));
        assert_eq!(out[5], (14, 1.0));
        // Empty parts and empty part lists are fine.
        merge_topk(&[], 3, &mut out);
        assert!(out.is_empty());
        let empty: Vec<(u32, f32)> = Vec::new();
        merge_topk(&[&empty, &c], 3, &mut out);
        assert_eq!(out, vec![(2, 4.0)]);
    }

    #[test]
    fn shard_reply_lines_classify() {
        match parse_shard_line("{\"topk\":[[7,1.5],[2,-0.25]]}").unwrap() {
            ShardLine::Topk(v) => assert_eq!(v, vec![(7, 1.5), (2, -0.25)]),
            _ => panic!("not topk"),
        }
        assert!(matches!(
            parse_shard_line("{\"backpressure\":true,\"error\":\"busy\"}").unwrap(),
            ShardLine::Backpressure
        ));
        assert!(matches!(
            parse_shard_line("{\"error\":\"feature index 9 out of range\"}").unwrap(),
            ShardLine::Rejected
        ));
        assert!(parse_shard_line("not json").is_err());
        assert!(parse_shard_line("{\"unexpected\":1}").is_err());
    }

    #[test]
    fn request_lines_roundtrip_through_the_wire_grammar() {
        let r = Request::detached(vec![2, 5, 7], vec![2.0, 1.5, 0.25], 3);
        assert_eq!(render_request_line(&r), "3 2:2 5:1.5 7:0.25");
        let r = Request::detached(Vec::new(), Vec::new(), 1);
        assert_eq!(render_request_line(&r), "1");
    }

    #[test]
    fn absent_and_present_stats_expose_the_same_family_names() {
        let mut absent = String::new();
        ScatterStats::render_absent(&mut absent);
        let stats = ScatterStats::new(3);
        stats.shard_requests[1].add(5);
        stats.degraded.inc();
        let mut present = String::new();
        stats.render_into(&mut present);
        let names = |s: &str| -> std::collections::BTreeSet<String> {
            s.lines()
                .filter(|l| l.starts_with("# TYPE "))
                .map(|l| l.split_whitespace().nth(2).unwrap().to_string())
                .collect()
        };
        assert_eq!(names(&absent), names(&present));
        assert!(present.contains("ltls_shard_requests_total{shard=\"1\"} 5"), "{present}");
        assert!(present.contains("ltls_shard_degraded_total 1"), "{present}");
        assert!(absent.contains("ltls_shard_requests_total 0"), "{absent}");
        assert!(absent.contains("ltls_shard_rtt_seconds_count 0"), "{absent}");
    }
}
