//! Hot model reload: swap a newly trained (or `ltls quantize`d) model
//! into a live server with zero dropped or misrouted in-flight requests.
//!
//! The mechanism is a hand-rolled `ArcSwap`: a [`ModelSlot`] holds the
//! current model behind `Mutex<Arc<_>>`, readers clone the `Arc` (a
//! refcount bump under a lock held for nanoseconds — never across a
//! decode), and a reload replaces the `Arc` and bumps a generation
//! counter (the *epoch*). Every micro-batch loads the slot **once** at
//! batch start, so a swap lands cleanly *between* micro-batches: requests
//! already in a batch finish on the generation they started on, requests
//! batched afterwards run on the new one, and nothing is dropped.
//!
//! [`ReloadableLtls`] wraps the slot around an [`AnyModel`] — the
//! (width × backend)-dispatched loaded model — and implements
//! [`BatchModel`], so the existing batcher/worker pool serves through it
//! unchanged. Reloads go through [`crate::model::io::load_any`] /
//! [`load_any_mmap`]: a truncated, bad-magic or otherwise corrupt file
//! (e.g. one caught mid-write) surfaces as `Err` and the old model stays
//! live.
//!
//! [`ModelWatcher`] is the `ltls serve --watch-model F` poller: it stats
//! the file (std-only — no inotify offline), waits for (mtime, len) to
//! hold still for one poll interval, then attempts a reload. Writers
//! should replace the file atomically (write to a temp path, then
//! rename — [`crate::model::io::write_atomic`]).
//!
//! **Heap loading** (`load_any`) makes even a torn read safe: the bytes
//! are copied once and validated, so a half-written file is an `Err` and
//! nothing else. **`--mmap` mode is different**: a mapped file that a
//! writer later *truncates in place* can fault (SIGBUS) on access, and
//! an in-place rewrite can mutate pages of the *currently served*
//! generation — neither is survivable by validation, because the kernel
//! mapping tracks the inode, not a snapshot. Atomic rename replacement
//! is therefore **required** (not merely recommended) for `--mmap`
//! serving with reload or `--watch-model`: a rename leaves the mapped
//! old inode untouched for as long as the old generation serves, and
//! the reload maps the new inode. This is inherent to mmap serving (it
//! applies equally to a file mapped by `ltls serve --mmap` with no
//! reload at all), not a property of the reload path.

use super::server::{batched_predict_into, BatchModel, Request, Response};
use crate::engine::PredictScratch;
use crate::model::io::{load_any, load_any_mmap, AnyModel};
use crate::obs::Counter;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, SystemTime};

/// (mtime, len) fingerprint of a model file; `None` when unreadable.
/// Taken *before* a load so a write racing the read changes the
/// fingerprint and gets picked up by the watcher afterwards.
fn fingerprint(path: &Path) -> Option<(SystemTime, u64)> {
    let meta = std::fs::metadata(path).ok()?;
    Some((meta.modified().unwrap_or(SystemTime::UNIX_EPOCH), meta.len()))
}

/// A swappable model slot: `Mutex<Arc<M>>` with an epoch counter.
///
/// `load` is what every micro-batch pays: one mutex lock around an
/// `Arc::clone`. `store` is what a reload pays: one allocation plus the
/// same lock. No reader ever blocks on model construction, and an old
/// generation is freed exactly when its last in-flight batch finishes.
pub struct ModelSlot<M> {
    current: Mutex<Arc<M>>,
    epoch: AtomicU64,
}

impl<M> ModelSlot<M> {
    pub fn new(model: M) -> ModelSlot<M> {
        ModelSlot { current: Mutex::new(Arc::new(model)), epoch: AtomicU64::new(0) }
    }

    /// The current generation's model (cheap: refcount bump).
    pub fn load(&self) -> Arc<M> {
        Arc::clone(&self.current.lock().unwrap())
    }

    /// Install a new generation; returns its epoch (monotonic from 1).
    pub fn store(&self, model: M) -> u64 {
        self.store_with(model, || {})
    }

    /// [`Self::store`] running `bookkeeping` inside the slot's critical
    /// section, so metadata describing the new generation (cached
    /// dimensions, source path, file fingerprint) can never interleave
    /// across two racing swaps and end up attached to the wrong model.
    pub fn store_with(&self, model: M, bookkeeping: impl FnOnce()) -> u64 {
        let next = Arc::new(model);
        let mut g = self.current.lock().unwrap();
        *g = next;
        bookkeeping();
        // Bumped under the slot lock, so epochs observed by `load` +
        // `epoch` pairs are consistent.
        self.epoch.fetch_add(1, Ordering::SeqCst) + 1
    }

    /// Number of swaps performed so far (0 → still the initial model).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::SeqCst)
    }
}

/// Summary of a completed reload, for logs and the `RELOAD` reply.
#[derive(Clone, Debug)]
pub struct ReloadInfo {
    /// Generation just installed (1 = first swap after startup).
    pub epoch: u64,
    pub c: u64,
    pub width: u32,
    pub backend: &'static str,
    pub bytes: usize,
    pub mapped: bool,
}

/// A [`BatchModel`] whose underlying [`AnyModel`] can be swapped while
/// the worker pool keeps serving (see the module docs for the handoff
/// semantics). Holds the path reloads re-read by default, so both the
/// `RELOAD` control command (with no argument) and the `--watch-model`
/// poller target the file the server was started from.
pub struct ReloadableLtls {
    slot: ModelSlot<AnyModel>,
    /// Default source for path-less reloads; updated on every successful
    /// path reload.
    path: Mutex<Option<PathBuf>>,
    /// Load weights via mmap (zero-copy) instead of the heap.
    mmap: bool,
    /// Cached `D` of the current generation, so the transport's
    /// per-request feature validation is one atomic load instead of a
    /// slot lock + `Arc` churn.
    n_features_hint: AtomicUsize,
    /// (mtime, len) of the file the current generation was loaded from,
    /// stat'ed *before* the read — the watcher's baseline, so a write
    /// that races the initial load still registers as a change.
    file_fingerprint: Mutex<Option<(SystemTime, u64)>>,
    /// Reload outcomes, scrape-visible on the `METRICS` endpoint
    /// (`ltls_reload_success_total` / `ltls_reload_failure_total`).
    reload_success: Counter,
    reload_failure: Counter,
}

impl ReloadableLtls {
    /// Wrap an already-loaded model (no reload path configured yet:
    /// `RELOAD` then requires an explicit path argument).
    pub fn new(model: AnyModel) -> ReloadableLtls {
        let d = model.n_features();
        ReloadableLtls {
            slot: ModelSlot::new(model),
            path: Mutex::new(None),
            mmap: false,
            n_features_hint: AtomicUsize::new(d),
            file_fingerprint: Mutex::new(None),
            reload_success: Counter::new(),
            reload_failure: Counter::new(),
        }
    }

    /// Load the initial model from `path` (heap, or zero-copy `mmap`) and
    /// remember the path for later reloads.
    pub fn from_path(path: &Path, mmap: bool) -> Result<ReloadableLtls, String> {
        let fp = fingerprint(path);
        let model = if mmap { load_any_mmap(path) } else { load_any(path) }?;
        let d = model.n_features();
        Ok(ReloadableLtls {
            slot: ModelSlot::new(model),
            path: Mutex::new(Some(path.to_path_buf())),
            mmap,
            n_features_hint: AtomicUsize::new(d),
            file_fingerprint: Mutex::new(fp),
            reload_success: Counter::new(),
            reload_failure: Counter::new(),
        })
    }

    /// The current generation's model.
    pub fn snapshot(&self) -> Arc<AnyModel> {
        self.slot.load()
    }

    /// Number of successful reloads so far.
    pub fn epoch(&self) -> u64 {
        self.slot.epoch()
    }

    /// The path a path-less `RELOAD` (or the watcher) re-reads.
    pub fn default_path(&self) -> Option<PathBuf> {
        self.path.lock().unwrap().clone()
    }

    /// Feature dimensionality `D` of the current generation (atomic read;
    /// the transport validates every request's indices against it).
    pub fn current_n_features(&self) -> usize {
        self.n_features_hint.load(Ordering::Acquire)
    }

    /// The (mtime, len) the current generation was loaded under, if it
    /// came from a file — the watcher's change-detection baseline.
    fn loaded_fingerprint(&self) -> Option<(SystemTime, u64)> {
        *self.file_fingerprint.lock().unwrap()
    }

    /// `(successful, rejected)` reload counts so far — the transport
    /// renders them on the `METRICS` endpoint.
    pub fn reload_counts(&self) -> (u64, u64) {
        (self.reload_success.get(), self.reload_failure.get())
    }

    /// Atomically swap in the model stored at `path`. On *any* load error
    /// — missing file, truncation, bad magic, backend/width the build
    /// cannot represent — the current model stays live and `Err` is
    /// returned; a swap only happens after the new model fully validated.
    pub fn reload_from(&self, path: &Path) -> Result<ReloadInfo, String> {
        let result = self.reload_from_inner(path);
        match &result {
            Ok(_) => self.reload_success.inc(),
            Err(_) => self.reload_failure.inc(),
        }
        result
    }

    fn reload_from_inner(&self, path: &Path) -> Result<ReloadInfo, String> {
        let fp = fingerprint(path);
        let model = if self.mmap { load_any_mmap(path) } else { load_any(path) }?;
        let info = ReloadInfo {
            epoch: 0, // patched below once the swap happened
            c: model.c(),
            width: model.width(),
            backend: model.backend().name(),
            bytes: model.bytes(),
            mapped: model.is_mapped(),
        };
        let d = model.n_features();
        // All generation metadata commits inside the slot's critical
        // section: two racing reloads serialize completely, so the
        // winning model can never carry the loser's D / path /
        // fingerprint.
        let epoch = self.slot.store_with(model, || {
            self.n_features_hint.store(d, Ordering::Release);
            *self.path.lock().unwrap() = Some(path.to_path_buf());
            *self.file_fingerprint.lock().unwrap() = fp;
        });
        Ok(ReloadInfo { epoch, ..info })
    }

    /// Reload from the remembered default path.
    pub fn reload(&self) -> Result<ReloadInfo, String> {
        let Some(path) = self.default_path() else {
            return Err(
                "no model path configured for reload (serve was started from an in-memory \
                 model; use RELOAD <path>)"
                    .into(),
            );
        };
        self.reload_from(&path)
    }
}

impl BatchModel for ReloadableLtls {
    fn predict_batch(&self, batch: &[Request]) -> Vec<Response> {
        let mut out = Vec::with_capacity(batch.len());
        self.predict_batch_into(batch, &mut PredictScratch::new(), &mut out);
        out
    }

    fn predict_batch_into(
        &self,
        batch: &[Request],
        scratch: &mut PredictScratch,
        out: &mut Vec<Response>,
    ) {
        // One slot load per micro-batch: the whole batch executes on a
        // single generation, so a concurrent swap cannot misroute any
        // request inside it.
        let model = self.slot.load();
        crate::with_any_model!(&*model, m => batched_predict_into(m, batch, scratch, out));
    }

    fn n_features(&self) -> Option<usize> {
        Some(self.current_n_features())
    }

    fn name(&self) -> &str {
        "LTLS-reloadable"
    }
}

/// The `--watch-model` poller (see the module docs): stats the watched
/// file every `poll` interval and hot-reloads `model` when the file's
/// (mtime, len) changed *and* held still for one further interval. A
/// rejected load (half-written file) keeps the current model and is
/// retried when the file changes again.
pub struct ModelWatcher {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl ModelWatcher {
    pub fn spawn(model: Arc<ReloadableLtls>, path: PathBuf, poll: Duration) -> ModelWatcher {
        let stop = Arc::new(AtomicBool::new(false));
        let stop_flag = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("ltls-watch-model".to_string())
            .spawn(move || watch_loop(&model, &path, poll, &stop_flag))
            .expect("spawn model watcher");
        ModelWatcher { stop, handle: Some(handle) }
    }

    /// Stop polling and join the watcher thread.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ModelWatcher {
    fn drop(&mut self) {
        // Signal without joining: the loop exits within one poll interval.
        self.stop.store(true, Ordering::Relaxed);
    }
}

fn watch_loop(model: &ReloadableLtls, path: &Path, poll: Duration, stop: &AtomicBool) {
    // Baseline on the fingerprint the *loaded* model was read under, not
    // on the file as it looks now: a write that raced the initial load
    // (or happened before the watcher started) differs from the loaded
    // fingerprint and is picked up on the first polls instead of being
    // treated as already handled.
    let mut last_handled = model.loaded_fingerprint();
    let mut pending: Option<(SystemTime, u64)> = None;
    while !stop.load(Ordering::Relaxed) {
        std::thread::sleep(poll);
        let now = fingerprint(path);
        if now.is_none() || now == last_handled {
            pending = None;
            continue;
        }
        if pending != now {
            // First sight of this fingerprint: require one stable interval
            // before loading, so non-atomic writers usually finish first.
            pending = now;
            continue;
        }
        pending = None;
        // Whatever happens below, this fingerprint is handled: a rejected
        // file is not re-tried until it changes again.
        last_handled = now;
        match model.reload_from(path) {
            Ok(info) => eprintln!(
                "[watch-model] reloaded {} (epoch {}, C={} W={} backend={} {:.2} MB)",
                path.display(),
                info.epoch,
                info.c,
                info.width,
                info.backend,
                info.bytes as f64 / 1e6,
            ),
            Err(e) => eprintln!(
                "[watch-model] reload of {} rejected (keeping current model): {e}",
                path.display()
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;
    use crate::eval::Predictor;
    use crate::train::{TrainConfig, Trainer};

    fn trained(epochs: usize) -> (crate::train::TrainedModel, crate::data::Dataset) {
        let ds = SyntheticSpec::multiclass(400, 300, 16).seed(77).generate();
        let mut tr = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
        tr.fit(&ds, epochs);
        (tr.into_model(), ds)
    }

    #[test]
    fn slot_swaps_and_counts_epochs() {
        let slot = ModelSlot::new(1u32);
        assert_eq!(*slot.load(), 1);
        assert_eq!(slot.epoch(), 0);
        assert_eq!(slot.store(2), 1);
        assert_eq!(*slot.load(), 2);
        assert_eq!(slot.epoch(), 1);
        // An old generation handed out before the swap stays valid.
        let old = slot.load();
        slot.store(3);
        assert_eq!(*old, 2);
        assert_eq!(*slot.load(), 3);
    }

    #[test]
    fn reload_swaps_model_and_rejects_corrupt_keeping_old() {
        let dir = std::env::temp_dir().join(format!("ltls_reload_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let (m1, ds) = trained(1);
        let (m2, _) = trained(4);
        let p = dir.join("model.ltls");
        crate::model::io::save(&m1, &p).unwrap();
        let r = ReloadableLtls::from_path(&p, false).unwrap();
        assert_eq!(r.epoch(), 0);
        assert_eq!(r.n_features(), Some(ds.n_features));

        // Serve through the BatchModel face: answers match m1.
        let row = ds.row(0);
        let req = || Request::detached(row.indices.to_vec(), row.values.to_vec(), 3);
        let resp = r.predict_batch(&[req()]);
        assert_eq!(resp[0].topk, m1.topk(row, 3));

        // Swap in m2: answers now match m2.
        crate::model::io::save(&m2, &p).unwrap();
        let info = r.reload().unwrap();
        assert_eq!(info.epoch, 1);
        assert_eq!(info.backend, "dense");
        let resp = r.predict_batch(&[req()]);
        assert_eq!(resp[0].topk, m2.topk(row, 3));

        // A truncated file is rejected and m2 stays live.
        let bytes = crate::model::io::serialize(&m1);
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        assert!(r.reload().is_err());
        assert_eq!(r.epoch(), 1);
        // One successful swap, one rejected file — scrape-visible counts.
        assert_eq!(r.reload_counts(), (1, 1));
        let resp = r.predict_batch(&[req()]);
        assert_eq!(resp[0].topk, m2.topk(row, 3));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reload_without_path_errors() {
        let (m1, _) = trained(1);
        let r = ReloadableLtls::new(crate::model::io::AnyModel::Binary(m1));
        assert!(r.default_path().is_none());
        let err = r.reload().unwrap_err();
        assert!(err.contains("no model path"), "{err}");
    }

    #[test]
    fn watcher_picks_up_valid_write_and_ignores_garbage() {
        let dir = std::env::temp_dir().join(format!("ltls_watch_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let (m1, ds) = trained(1);
        let (m2, _) = trained(4);
        let p = dir.join("watched.ltls");
        crate::model::io::save(&m1, &p).unwrap();
        let r = Arc::new(ReloadableLtls::from_path(&p, false).unwrap());
        let watcher = ModelWatcher::spawn(Arc::clone(&r), p.clone(), Duration::from_millis(15));

        // Garbage lands in the file (a half-written model): the watcher
        // must reject it and keep m1 live.
        let bytes = crate::model::io::serialize(&m2);
        std::fs::write(&p, &bytes[..100]).unwrap();
        std::thread::sleep(Duration::from_millis(200));
        assert_eq!(r.epoch(), 0, "half-written file must not be swapped in");
        assert_eq!(r.snapshot().c(), m1.trellis.c);

        // The full model replaces it (atomically, as real writers should):
        // picked up within a few poll intervals.
        crate::model::io::write_atomic(&bytes, &p).unwrap();
        let mut ok = false;
        for _ in 0..200 {
            if r.epoch() >= 1 {
                ok = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(15));
        }
        assert!(ok, "watcher never picked up the valid model");
        let row = ds.row(3);
        let resp =
            r.predict_batch(&[Request::detached(row.indices.to_vec(), row.values.to_vec(), 1)]);
        assert_eq!(resp[0].topk, m2.topk(row, 1));
        watcher.stop();
        std::fs::remove_dir_all(&dir).ok();
    }
}
