//! Hand-written `core::arch` intrinsics sweeps, behind the `simd` feature.
//!
//! Each entry point returns `bool`: `true` means the intrinsics path ran
//! (including its scalar tail), `false` means the caller must fall back to
//! the portable sweep. This keeps the dispatcher in [`super`] free of
//! `cfg` ladders and lets the x86_64 path bail out at runtime on CPUs
//! without AVX2.
//!
//! Bit-identity (see module docs in [`super`]): every vector lane computes
//! the oracle's `o + sv * w` as a separate multiply then add —
//! `_mm256_mul_ps`/`_mm256_add_ps` and `vmulq_f32`/`vaddq_f32`, never an
//! FMA — and the i8 path widens i8→i16, multiplies exactly in i16 (both
//! operands are in `[-127, 127]`, so products fit), widens to i32 and adds
//! with the same wrapping semantics as the scalar loop.

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod imp {
    use core::arch::x86_64::*;

    /// AVX2 f32 strip sweep; `false` (no-op) if the CPU lacks AVX2.
    #[inline]
    pub fn axpy(out: &mut [f32], strip: &[f32], sv: f32) -> bool {
        if !is_x86_feature_detected!("avx2") {
            return false;
        }
        // SAFETY: AVX2 support was just verified at runtime.
        unsafe { axpy_avx2(out, strip, sv) };
        true
    }

    /// AVX2 widening i8 strip sweep; `false` (no-op) if the CPU lacks AVX2.
    #[inline]
    pub fn i8_axpy(acc: &mut [i32], strip: &[i8], qv: i32) -> bool {
        if !is_x86_feature_detected!("avx2") {
            return false;
        }
        // SAFETY: AVX2 support was just verified at runtime.
        unsafe { i8_axpy_avx2(acc, strip, qv) };
        true
    }

    #[inline]
    pub fn active() -> bool {
        is_x86_feature_detected!("avx2")
    }

    #[target_feature(enable = "avx2")]
    unsafe fn axpy_avx2(out: &mut [f32], strip: &[f32], sv: f32) {
        let n = out.len().min(strip.len());
        let vs = _mm256_set1_ps(sv);
        let mut i = 0;
        while i + 8 <= n {
            let o = _mm256_loadu_ps(out.as_ptr().add(i));
            let w = _mm256_loadu_ps(strip.as_ptr().add(i));
            // o + (sv * w): separate mul and add, matching the scalar
            // oracle's operation order bit-for-bit (no FMA).
            let r = _mm256_add_ps(o, _mm256_mul_ps(vs, w));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), r);
            i += 8;
        }
        while i < n {
            let o = out.get_unchecked_mut(i);
            *o += sv * *strip.get_unchecked(i);
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn i8_axpy_avx2(acc: &mut [i32], strip: &[i8], qv: i32) {
        let n = acc.len().min(strip.len());
        let vq = _mm256_set1_epi16(qv as i16);
        let mut i = 0;
        while i + 16 <= n {
            // 16 × i8 → 16 × i16; multiply exactly in i16 (|qv|, |w| ≤ 127
            // so |product| ≤ 16129 < 2^15); widen halves to i32 and add.
            let q8 = _mm_loadu_si128(strip.as_ptr().add(i) as *const __m128i);
            let p16 = _mm256_mullo_epi16(_mm256_cvtepi8_epi16(q8), vq);
            let lo = _mm256_cvtepi16_epi32(_mm256_castsi256_si128(p16));
            let hi = _mm256_cvtepi16_epi32(_mm256_extracti128_si256::<1>(p16));
            let a0 = _mm256_loadu_si256(acc.as_ptr().add(i) as *const __m256i);
            let a1 = _mm256_loadu_si256(acc.as_ptr().add(i + 8) as *const __m256i);
            _mm256_storeu_si256(acc.as_mut_ptr().add(i) as *mut __m256i, _mm256_add_epi32(a0, lo));
            _mm256_storeu_si256(
                acc.as_mut_ptr().add(i + 8) as *mut __m256i,
                _mm256_add_epi32(a1, hi),
            );
            i += 16;
        }
        while i < n {
            let a = acc.get_unchecked_mut(i);
            *a = a.wrapping_add(qv * *strip.get_unchecked(i) as i32);
            i += 1;
        }
    }
}

#[cfg(all(feature = "simd", target_arch = "aarch64"))]
mod imp {
    use core::arch::aarch64::*;

    /// NEON f32 strip sweep (NEON is baseline on aarch64 — always taken).
    #[inline]
    pub fn axpy(out: &mut [f32], strip: &[f32], sv: f32) -> bool {
        // SAFETY: NEON is mandatory in the aarch64 baseline target.
        unsafe { axpy_neon(out, strip, sv) };
        true
    }

    /// NEON widening i8 strip sweep.
    #[inline]
    pub fn i8_axpy(acc: &mut [i32], strip: &[i8], qv: i32) -> bool {
        // SAFETY: NEON is mandatory in the aarch64 baseline target.
        unsafe { i8_axpy_neon(acc, strip, qv) };
        true
    }

    #[inline]
    pub fn active() -> bool {
        true
    }

    unsafe fn axpy_neon(out: &mut [f32], strip: &[f32], sv: f32) {
        let n = out.len().min(strip.len());
        let vs = vdupq_n_f32(sv);
        let mut i = 0;
        while i + 4 <= n {
            let o = vld1q_f32(out.as_ptr().add(i));
            let w = vld1q_f32(strip.as_ptr().add(i));
            // vmulq + vaddq, NOT vfmaq: a fused multiply-add would break
            // bit-identity with the scalar oracle.
            let r = vaddq_f32(o, vmulq_f32(vs, w));
            vst1q_f32(out.as_mut_ptr().add(i), r);
            i += 4;
        }
        while i < n {
            let o = out.get_unchecked_mut(i);
            *o += sv * *strip.get_unchecked(i);
            i += 1;
        }
    }

    unsafe fn i8_axpy_neon(acc: &mut [i32], strip: &[i8], qv: i32) {
        let n = acc.len().min(strip.len());
        let vq = vdupq_n_s16(qv as i16);
        let mut i = 0;
        while i + 8 <= n {
            // 8 × i8 → 8 × i16; exact i16 multiply (|qv|, |w| ≤ 127);
            // widen halves to i32 and add.
            let q16 = vmovl_s8(vld1_s8(strip.as_ptr().add(i)));
            let p16 = vmulq_s16(q16, vq);
            let lo = vmovl_s16(vget_low_s16(p16));
            let hi = vmovl_s16(vget_high_s16(p16));
            let a0 = vld1q_s32(acc.as_ptr().add(i));
            let a1 = vld1q_s32(acc.as_ptr().add(i + 4));
            vst1q_s32(acc.as_mut_ptr().add(i), vaddq_s32(a0, lo));
            vst1q_s32(acc.as_mut_ptr().add(i + 4), vaddq_s32(a1, hi));
            i += 8;
        }
        while i < n {
            let a = acc.get_unchecked_mut(i);
            *a = a.wrapping_add(qv * *strip.get_unchecked(i) as i32);
            i += 1;
        }
    }
}

#[cfg(not(all(feature = "simd", any(target_arch = "x86_64", target_arch = "aarch64"))))]
mod imp {
    /// Feature off (or unsupported arch): never handles the sweep.
    #[inline]
    pub fn axpy(_out: &mut [f32], _strip: &[f32], _sv: f32) -> bool {
        false
    }

    #[inline]
    pub fn i8_axpy(_acc: &mut [i32], _strip: &[i8], _qv: i32) -> bool {
        false
    }

    #[inline]
    pub fn active() -> bool {
        false
    }
}

pub use imp::{active, axpy, i8_axpy};
