//! Lane-width-generic scoring kernels for the edge-score hot path.
//!
//! LTLS serving cost is dominated by the strip sweep: each active feature
//! adds `v · sign` times one contiguous `E`-length weight strip into the
//! edge-score accumulator (`h += sv * w[strip]`), and `Q8Store` does the
//! same in i8/i32. W-LTLS widens the trellis so `E` grows as `W²·log C /
//! log W` — the sweep, not the Viterbi DP, is the bottleneck. This module
//! hosts that sweep exactly once, behind three interchangeable
//! implementations:
//!
//! * [`scalar`] — the pinned **bit-identity oracle**: the pre-vectorization
//!   element-at-a-time loops, with `std::hint::black_box` on every element
//!   so release-mode LLVM cannot autovectorize them away. Benches measure
//!   SIMD speedups against this, and `rust/tests/kernel_parity.rs` pins
//!   every other path bit-identical to it.
//! * [`sweep`] — portable 8-lane sweeps over fixed-size array chunks,
//!   written so LLVM reliably emits AVX2/NEON vector code on its own.
//!   This is the default fast path (no feature flag needed).
//! * [`simd`] — hand-written `core::arch` intrinsics behind the `simd`
//!   cargo feature: AVX2 on x86_64 (runtime-detected, falls back to
//!   [`sweep`] on older CPUs) and NEON on aarch64.
//!
//! **Bit-identity contract.** Every f32 kernel computes, per element,
//! `out[j] + sv * strip[j]` — one f32 multiply then one f32 add, never an
//! FMA, never a reassociated horizontal sum. The element-wise axpy has no
//! reduction, so chunking or vectorizing it cannot change results: all
//! three implementations are bit-identical on every input, including NaN
//! and infinity propagation. The i32 kernels are exact integer arithmetic
//! (products of i8-range values fit i16; accumulation wraps identically).
//! This is why the kernels can sit *under* `StripCodec` without weakening
//! the batch≡single and engine-parity guarantees elsewhere in the repo.
//!
//! The dispatchers in this module try [`simd`] first (a no-op returning
//! `false` when the feature is off or the CPU lacks AVX2) and fall back to
//! [`sweep`].

pub mod scalar;
pub mod simd;
pub mod sweep;

/// `out[j] += sv * strip[j]` over the paired prefix — the f32 strip sweep.
///
/// Bit-identical to [`scalar::axpy`] on every input (see module docs).
#[inline]
pub fn axpy(out: &mut [f32], strip: &[f32], sv: f32) {
    debug_assert_eq!(out.len(), strip.len());
    if simd::axpy(out, strip, sv) {
        return;
    }
    sweep::axpy(out, strip, sv);
}

/// `acc[j] += qv * strip[j] as i32` — the widening i8→i32 strip sweep used
/// by `Q8Store`. Exact: `|qv| ≤ 127` and `|strip[j]| ≤ 127`, so every
/// product fits i16 and the i32 accumulation wraps identically to
/// [`scalar::i8_axpy`].
#[inline]
pub fn i8_axpy(acc: &mut [i32], strip: &[i8], qv: i32) {
    debug_assert_eq!(acc.len(), strip.len());
    debug_assert!((-127..=127).contains(&qv));
    if simd::i8_axpy(acc, strip, qv) {
        return;
    }
    sweep::i8_axpy(acc, strip, qv);
}

/// Dequantize accumulated i32 dots into final edge scores:
/// `out[j] = bias[j] + (scale[j] * sx) * acc[j] as f32`.
///
/// The expression shape (scale·sx first, then the widened product) is part
/// of the `Q8Store` format contract — changing it would change served
/// scores. Element-wise with no reduction, so the vectorized form is
/// bit-identical to [`scalar::q8_finish`].
#[inline]
pub fn q8_finish(out: &mut [f32], acc: &[i32], bias: &[f32], scale: &[f32], sx: f32) {
    debug_assert_eq!(out.len(), acc.len());
    debug_assert_eq!(out.len(), bias.len());
    debug_assert_eq!(out.len(), scale.len());
    sweep::q8_finish(out, acc, bias, scale, sx);
}

/// One Viterbi relaxation row: for each target state `t`,
/// `if sa + row[t] > score[t] { score[t] = sa + row[t]; code[t] = ca }`.
///
/// `row` is the contiguous `W`-edge slice `h[transition(j, a, 0..W)]`
/// (see `Topology::transition_row`), `sa`/`ca` the predecessor's running
/// score and path code. Strict `>` preserves the decoder's tie-breaking
/// (first/smallest predecessor wins), so folding predecessors in ascending
/// order reproduces the original scalar max+argmax bit-for-bit. Written as
/// branchless selects so LLVM vectorizes the compare/blend.
#[inline]
pub fn viterbi_fold(score: &mut [f32], code: &mut [u64], sa: f32, ca: u64, row: &[f32]) {
    debug_assert_eq!(score.len(), code.len());
    debug_assert_eq!(score.len(), row.len());
    for ((s, c), &e) in score.iter_mut().zip(code.iter_mut()).zip(row) {
        let v = sa + e;
        let take = v > *s;
        *s = if take { v } else { *s };
        *c = if take { ca } else { *c };
    }
}

/// Hint the next strip into cache while the current one is being swept.
/// Touches the first line of `slice`; a no-op on empty slices and on
/// targets without a stable prefetch intrinsic.
#[inline]
pub fn prefetch<T>(slice: &[T]) {
    if slice.is_empty() {
        return;
    }
    #[cfg(target_arch = "x86_64")]
    // SAFETY: prefetch is a pure cache hint — it cannot fault, and the
    // pointer comes from a live slice. `_mm_prefetch` needs only SSE,
    // which is part of the x86_64 baseline.
    unsafe {
        core::arch::x86_64::_mm_prefetch::<{ core::arch::x86_64::_MM_HINT_T0 }>(
            slice.as_ptr() as *const i8,
        );
    }
}

/// Whether the hand-written intrinsics path is compiled in *and* usable on
/// this CPU (benches report it so recorded numbers are attributable).
#[inline]
pub fn simd_active() -> bool {
    simd::active()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Lengths that exercise full lanes, tails of every residue, and the
    /// degenerate empty/single cases.
    const LENS: [usize; 13] = [0, 1, 2, 3, 7, 8, 9, 15, 16, 17, 31, 33, 77];

    #[test]
    fn axpy_matches_scalar_bitwise() {
        let mut rng = Rng::new(61);
        for &n in &LENS {
            for trial in 0..4 {
                let strip: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
                let base: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
                let sv = rng.normal();
                let mut fast = base.clone();
                let mut slow = base.clone();
                axpy(&mut fast, &strip, sv);
                scalar::axpy(&mut slow, &strip, sv);
                for (j, (f, s)) in fast.iter().zip(&slow).enumerate() {
                    assert_eq!(
                        f.to_bits(),
                        s.to_bits(),
                        "n={n} trial={trial} j={j}: {f} vs {s}"
                    );
                }
            }
        }
    }

    #[test]
    fn i8_axpy_matches_scalar_exactly() {
        let mut rng = Rng::new(62);
        for &n in &LENS {
            for qv in [-127i32, -3, 1, 42, 127] {
                let strip: Vec<i8> = (0..n).map(|_| (rng.index(255) as i32 - 127) as i8).collect();
                let base: Vec<i32> = (0..n).map(|_| rng.index(1000) as i32 - 500).collect();
                let mut fast = base.clone();
                let mut slow = base;
                i8_axpy(&mut fast, &strip, qv);
                scalar::i8_axpy(&mut slow, &strip, qv);
                assert_eq!(fast, slow, "n={n} qv={qv}");
            }
        }
    }

    #[test]
    fn q8_finish_matches_scalar_bitwise() {
        let mut rng = Rng::new(63);
        for &n in &LENS {
            let acc: Vec<i32> = (0..n).map(|_| rng.index(60_000) as i32 - 30_000).collect();
            let bias: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let scale: Vec<f32> = (0..n).map(|_| rng.normal().abs() * 0.01).collect();
            let sx = rng.normal().abs() * 0.1;
            let mut fast = vec![0.0f32; n];
            let mut slow = vec![0.0f32; n];
            q8_finish(&mut fast, &acc, &bias, &scale, sx);
            scalar::q8_finish(&mut slow, &acc, &bias, &scale, sx);
            for (f, s) in fast.iter().zip(&slow) {
                assert_eq!(f.to_bits(), s.to_bits(), "n={n}");
            }
        }
    }

    #[test]
    fn viterbi_fold_matches_naive_max_argmax() {
        let mut rng = Rng::new(64);
        for &w in &[2usize, 3, 4, 8, 16, 17] {
            // Fold a few predecessors in ascending order and compare with
            // the straightforward per-target max+argmax with strict >.
            let preds: Vec<(f32, u64)> =
                (0..5).map(|a| (rng.normal(), a as u64 * 11)).collect();
            let rows: Vec<Vec<f32>> =
                (0..5).map(|_| (0..w).map(|_| rng.normal()).collect()).collect();

            let mut score = vec![f32::NEG_INFINITY; w];
            let mut code = vec![0u64; w];
            for (a, &(sa, ca)) in preds.iter().enumerate() {
                viterbi_fold(&mut score, &mut code, sa, ca, &rows[a]);
            }

            for t in 0..w {
                let mut bs = f32::NEG_INFINITY;
                let mut bc = 0u64;
                for (a, &(sa, ca)) in preds.iter().enumerate() {
                    let v = sa + rows[a][t];
                    if v > bs {
                        bs = v;
                        bc = ca;
                    }
                }
                assert_eq!(score[t].to_bits(), bs.to_bits(), "w={w} t={t}");
                assert_eq!(code[t], bc, "w={w} t={t}");
            }
        }
    }

    #[test]
    fn prefetch_is_safe_on_any_slice() {
        prefetch::<f32>(&[]);
        prefetch(&[1.0f32, 2.0]);
        prefetch(&[0i8; 3]);
    }

    #[test]
    fn simd_active_is_consistent_with_feature() {
        // Without the feature this must be false; with it, whatever the
        // CPU supports — either way it must not panic.
        let active = simd_active();
        if cfg!(not(feature = "simd")) {
            assert!(!active);
        }
    }
}
