//! Pinned scalar oracles for the strip-sweep kernels.
//!
//! These are the pre-vectorization element-at-a-time loops, kept verbatim
//! as the bit-identity reference: `rust/tests/kernel_parity.rs` asserts the
//! portable-sweep and intrinsics paths match them bit-for-bit (f32) /
//! exactly (i32), and the decode/memory benches report SIMD speedup
//! relative to them.
//!
//! Every element passes through [`std::hint::black_box`] so release-mode
//! LLVM cannot autovectorize the oracle — otherwise the "scalar" baseline
//! would silently become the same vector code it is meant to calibrate.
//! `black_box` is a value identity: it never changes bits, only blocks the
//! optimizer from reasoning across it.

use std::hint::black_box;

/// `out[j] += sv * strip[j]`, one element at a time. The per-element
/// expression (f32 multiply, then f32 add — no FMA) defines the result
/// every fast path must reproduce bit-for-bit.
pub fn axpy(out: &mut [f32], strip: &[f32], sv: f32) {
    debug_assert_eq!(out.len(), strip.len());
    for (o, &w) in out.iter_mut().zip(strip) {
        *o = black_box(*o + sv * w);
    }
}

/// `acc[j] += qv * strip[j] as i32`, one element at a time, with wrapping
/// i32 accumulation (matching the vector adds, which always wrap).
pub fn i8_axpy(acc: &mut [i32], strip: &[i8], qv: i32) {
    debug_assert_eq!(acc.len(), strip.len());
    for (a, &q) in acc.iter_mut().zip(strip) {
        *a = black_box(a.wrapping_add(qv * q as i32));
    }
}

/// `out[j] = bias[j] + (scale[j] * sx) * acc[j] as f32`, one element at a
/// time. The expression shape is the `Q8Store` dequantization contract.
pub fn q8_finish(out: &mut [f32], acc: &[i32], bias: &[f32], scale: &[f32], sx: f32) {
    debug_assert_eq!(out.len(), acc.len());
    debug_assert_eq!(out.len(), bias.len());
    debug_assert_eq!(out.len(), scale.len());
    for (((o, &a), &b), &s) in out.iter_mut().zip(acc).zip(bias).zip(scale) {
        *o = black_box(b + (s * sx) * a as f32);
    }
}
