//! Portable lane-chunked sweeps — the default fast path.
//!
//! The loops below process fixed-size array chunks (`&[f32; 8]` /
//! `&[i8; 8]`) obtained via `chunks_exact`, the shape LLVM's loop
//! vectorizer recognizes unconditionally: on x86_64 it emits AVX/AVX2 when
//! the target allows, on aarch64 NEON, with a scalar remainder for tails.
//! No feature flags, no `unsafe`, and — because the per-element expression
//! is exactly the oracle's mul-then-add with no reduction — bit-identical
//! output to [`super::scalar`] regardless of how wide the emitted vectors
//! are.

/// Lane width of the chunked loops (elements per chunk, not necessarily
/// the hardware vector width — LLVM may split or fuse chunks).
pub const LANES: usize = 8;

/// `out[j] += sv * strip[j]` over the paired prefix, 8 lanes per chunk.
pub fn axpy(out: &mut [f32], strip: &[f32], sv: f32) {
    debug_assert_eq!(out.len(), strip.len());
    let mut oc = out.chunks_exact_mut(LANES);
    let mut sc = strip.chunks_exact(LANES);
    for (o, s) in (&mut oc).zip(&mut sc) {
        let o: &mut [f32; LANES] = o.try_into().unwrap();
        let s: &[f32; LANES] = s.try_into().unwrap();
        for l in 0..LANES {
            o[l] += sv * s[l];
        }
    }
    for (o, &w) in oc.into_remainder().iter_mut().zip(sc.remainder()) {
        *o += sv * w;
    }
}

/// `acc[j] += qv * strip[j] as i32`, 8 lanes per chunk, wrapping adds.
pub fn i8_axpy(acc: &mut [i32], strip: &[i8], qv: i32) {
    debug_assert_eq!(acc.len(), strip.len());
    let mut ac = acc.chunks_exact_mut(LANES);
    let mut sc = strip.chunks_exact(LANES);
    for (a, s) in (&mut ac).zip(&mut sc) {
        let a: &mut [i32; LANES] = a.try_into().unwrap();
        let s: &[i8; LANES] = s.try_into().unwrap();
        for l in 0..LANES {
            a[l] = a[l].wrapping_add(qv * s[l] as i32);
        }
    }
    for (a, &q) in ac.into_remainder().iter_mut().zip(sc.remainder()) {
        *a = a.wrapping_add(qv * q as i32);
    }
}

/// `out[j] = bias[j] + (scale[j] * sx) * acc[j] as f32` — element-wise
/// dequantization; the zip chain vectorizes cleanly without manual
/// chunking.
pub fn q8_finish(out: &mut [f32], acc: &[i32], bias: &[f32], scale: &[f32], sx: f32) {
    debug_assert_eq!(out.len(), acc.len());
    debug_assert_eq!(out.len(), bias.len());
    debug_assert_eq!(out.len(), scale.len());
    for (((o, &a), &b), &s) in out.iter_mut().zip(acc).zip(bias).zip(scale) {
        *o = b + (s * sx) * a as f32;
    }
}
