//! Deterministic epoch ordering and dataset sharding for parallel training.
//!
//! The serial trainer visits one shuffled permutation of the dataset per
//! epoch; the Hogwild trainer splits *the same permutation* into one
//! contiguous chunk per worker. Both sides call [`epoch_order`] with the
//! same `(seed, salt)` pair — `salt` is the global SGD step at the start of
//! the epoch, exactly the `seed ^ step` construction the serial trainer has
//! always used — so a 1-worker Hogwild epoch visits examples in the exact
//! serial order (the basis of the bit-identity test in
//! `rust/tests/train_parallel.rs`), and any run is reproducible from its
//! config alone.

use crate::util::rng::Rng;

/// The example visit order for one epoch: a deterministic permutation of
/// `0..n` (identity when `shuffle` is off), keyed by `seed ^ salt`.
pub fn epoch_order(n: usize, shuffle: bool, seed: u64, salt: u64) -> Vec<usize> {
    let mut order: Vec<usize> = (0..n).collect();
    if shuffle {
        let mut rng = Rng::new(seed ^ salt);
        rng.shuffle(&mut order);
    }
    order
}

/// Split one epoch's visit order into `n_shards` contiguous chunks, one
/// per worker. The chunks partition `0..n` (disjoint, covering) and are
/// balanced to within one example; with `n_shards = 1` the single shard is
/// exactly [`epoch_order`].
pub fn shard_epoch(
    n: usize,
    n_shards: usize,
    shuffle: bool,
    seed: u64,
    salt: u64,
) -> Vec<Vec<usize>> {
    let n_shards = n_shards.max(1);
    let order = epoch_order(n, shuffle, seed, salt);
    let base = n / n_shards;
    let rem = n % n_shards;
    let mut out = Vec::with_capacity(n_shards);
    let mut start = 0usize;
    for s in 0..n_shards {
        let len = base + usize::from(s < rem);
        out.push(order[start..start + len].to_vec());
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn order_is_deterministic_and_a_permutation() {
        let a = epoch_order(100, true, 42, 7);
        let b = epoch_order(100, true, 42, 7);
        assert_eq!(a, b);
        let mut sorted = a.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        // Different salt (epoch) → different order.
        assert_ne!(a, epoch_order(100, true, 42, 8));
        // No shuffle → identity.
        assert_eq!(epoch_order(5, false, 42, 7), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn shards_partition_and_balance() {
        for (n, k) in [(100usize, 4usize), (101, 4), (7, 3), (3, 8), (0, 2)] {
            let shards = shard_epoch(n, k, true, 1, 2);
            assert_eq!(shards.len(), k);
            let mut all: Vec<usize> = shards.iter().flatten().copied().collect();
            assert_eq!(all.len(), n, "covering");
            all.sort_unstable();
            all.dedup();
            assert_eq!(all.len(), n, "disjoint");
            let max = shards.iter().map(|s| s.len()).max().unwrap();
            let min = shards.iter().map(|s| s.len()).min().unwrap();
            assert!(max - min <= 1, "balanced: {max} vs {min}");
        }
    }

    #[test]
    fn single_shard_is_the_serial_order() {
        let shards = shard_epoch(64, 1, true, 9, 3);
        assert_eq!(shards.len(), 1);
        assert_eq!(shards[0], epoch_order(64, true, 9, 3));
    }

    #[test]
    fn zero_shards_clamps_to_one() {
        let shards = shard_epoch(10, 0, false, 0, 0);
        assert_eq!(shards.len(), 1);
        assert_eq!(shards[0].len(), 10);
    }
}
