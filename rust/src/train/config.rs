//! Trainer hyper-parameters.

use super::objective::Objective;
use crate::assign::AssignPolicy;

/// Configuration for [`super::Trainer`].
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Base learning rate η.
    pub lr: f32,
    /// Learning-rate decay: η_t = lr / (1 + decay·t)^power.
    pub decay: f32,
    pub power: f32,
    /// Use averaged weights for the final model (paper §5).
    pub averaging: bool,
    /// Label→path assignment policy (paper §5.1).
    pub policy: AssignPolicy,
    /// L1 soft-threshold λ applied to the *final* model (paper §6); 0 = off.
    pub l1_lambda: f32,
    /// Per-example target shape and loss (multiclass separation loss vs.
    /// the multilabel union-of-gold-paths objective, see
    /// [`super::objective`]). Carried into checkpoints; a resume under a
    /// different objective is refused.
    pub objective: Objective,
    /// RNG seed (example shuffling, random assignment).
    pub seed: u64,
    /// Shuffle examples between epochs.
    pub shuffle: bool,
    /// Print a progress line every N examples (0 = quiet).
    pub log_every: usize,
    /// Worker threads for [`super::ParallelTrainer`]: 1 = the serial path,
    /// 0 = one per available core, N = Hogwild with N workers.
    pub threads: usize,
    /// Mini-batch width for the batched scoring path (1 = per-example;
    /// B > 1 scores B examples per feature-strip sweep, see
    /// [`crate::model::LinearEdgeModel::edge_scores_batch`]).
    pub batch: usize,
    /// Trellis width `W` — states per step (paper: 2; W-LTLS widens the
    /// accuracy/size dial, see [`crate::graph::WideTrellis`]). The
    /// topology type must be able to represent it: a
    /// [`Trainer<Trellis>`](super::Trainer) only accepts 2.
    pub width: u32,
    /// Weight-storage dial: 0 trains the dense `D×E` store; `b > 0`
    /// trains a [`crate::model::HashedStore`] with `2^b` signed-hash
    /// buckets (memory bounded independently of D). The store type must
    /// match: a dense-typed trainer rejects a non-zero value.
    pub hash_bits: u32,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            lr: 0.5,
            decay: 1e-4,
            power: 0.75,
            averaging: true,
            policy: AssignPolicy::TopRanked,
            l1_lambda: 0.0,
            objective: Objective::Multiclass,
            seed: 42,
            shuffle: true,
            log_every: 0,
            threads: 1,
            batch: 1,
            width: 2,
            hash_bits: 0,
        }
    }
}

impl TrainConfig {
    /// η at step t.
    #[inline]
    pub fn lr_at(&self, t: u64) -> f32 {
        self.lr / (1.0 + self.decay * t as f32).powf(self.power)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_decays_monotonically() {
        let c = TrainConfig::default();
        let mut prev = f32::INFINITY;
        for t in [0u64, 10, 100, 1000, 100_000] {
            let lr = c.lr_at(t);
            assert!(lr <= prev && lr > 0.0);
            prev = lr;
        }
        assert_eq!(c.lr_at(0), c.lr);
    }

    /// Pin the decay-schedule endpoints numerically (default lr=0.5,
    /// decay=1e-4, power=0.75), so a silent change to the formula fails
    /// loudly instead of shifting every training trajectory.
    #[test]
    fn lr_schedule_pinned_endpoints() {
        let c = TrainConfig::default();
        // t = 0: exactly the base rate.
        assert_eq!(c.lr_at(0), 0.5);
        // t = 10^4: 1 + 1e-4·1e4 = 2 → 0.5 / 2^0.75 = 0.29730177…
        assert!((c.lr_at(10_000) - 0.297_301_8).abs() < 1e-5, "{}", c.lr_at(10_000));
        // t = 10^6: 1 + 100 = 101 → 0.5 / 101^0.75 = 0.01569381…
        assert!((c.lr_at(1_000_000) - 0.015_693_8).abs() < 2e-5, "{}", c.lr_at(1_000_000));
    }

    /// Degenerate schedule shapes behave: no decay ⇒ constant; power 1 ⇒
    /// exact harmonic decay.
    #[test]
    fn lr_schedule_degenerate_shapes() {
        let c0 = TrainConfig { decay: 0.0, ..TrainConfig::default() };
        assert_eq!(c0.lr_at(1_000_000_000), c0.lr);
        let c1 = TrainConfig { power: 1.0, ..TrainConfig::default() };
        // 0.5 / (1 + 1e-4·1e4) = 0.25.
        assert!((c1.lr_at(10_000) - 0.25).abs() < 1e-6);
    }

    /// The parallel knobs default to the serial configuration, and the
    /// width defaults to the paper's trellis.
    #[test]
    fn parallel_knobs_default_serial() {
        let c = TrainConfig::default();
        assert_eq!(c.threads, 1);
        assert_eq!(c.batch, 1);
        assert_eq!(c.width, 2);
        assert_eq!(c.hash_bits, 0, "dense storage is the default backend");
        assert_eq!(c.objective, Objective::Multiclass, "the paper's loss is the default");
    }
}
