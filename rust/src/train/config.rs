//! Trainer hyper-parameters.

use crate::assign::AssignPolicy;

/// Configuration for [`super::Trainer`].
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Base learning rate η.
    pub lr: f32,
    /// Learning-rate decay: η_t = lr / (1 + decay·t)^power.
    pub decay: f32,
    pub power: f32,
    /// Use averaged weights for the final model (paper §5).
    pub averaging: bool,
    /// Label→path assignment policy (paper §5.1).
    pub policy: AssignPolicy,
    /// L1 soft-threshold λ applied to the *final* model (paper §6); 0 = off.
    pub l1_lambda: f32,
    /// RNG seed (example shuffling, random assignment).
    pub seed: u64,
    /// Shuffle examples between epochs.
    pub shuffle: bool,
    /// Print a progress line every N examples (0 = quiet).
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            lr: 0.5,
            decay: 1e-4,
            power: 0.75,
            averaging: true,
            policy: AssignPolicy::TopRanked,
            l1_lambda: 0.0,
            seed: 42,
            shuffle: true,
            log_every: 0,
        }
    }
}

impl TrainConfig {
    /// η at step t.
    #[inline]
    pub fn lr_at(&self, t: u64) -> f32 {
        self.lr / (1.0 + self.decay * t as f32).powf(self.power)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_decays_monotonically() {
        let c = TrainConfig::default();
        let mut prev = f32::INFINITY;
        for t in [0u64, 10, 100, 1000, 100_000] {
            let lr = c.lr_at(t);
            assert!(lr <= prev && lr > 0.0);
            prev = lr;
        }
        assert_eq!(c.lr_at(0), c.lr);
    }
}
