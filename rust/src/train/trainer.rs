//! The LTLS trainer and the trained-model predictor.

use super::config::TrainConfig;
use super::metrics::EpochMetrics;
use super::objective::objective_step;
use crate::assign::Assigner;
use crate::data::Dataset;
use crate::decode::{list_viterbi_into, viterbi_ws, Scored};
use crate::engine::{PredictScratch, TrainScratch};
use crate::graph::{Topology, Trellis};
use crate::model::averaged::Averager;
use crate::model::{DenseStore, TrainableStore, WeightStore};
use crate::sparse::SparseVec;

/// Online LTLS trainer (separation ranking loss + averaged sparse SGD),
/// generic over the graph [`Topology`] — the paper's width-2 [`Trellis`]
/// by default, or a [`crate::graph::WideTrellis`] at any width
/// (`config.width`) — and over the weight storage [`TrainableStore`]:
/// the dense [`DenseStore`] by default, or a
/// [`crate::model::HashedStore`] when `config.hash_bits > 0`.
///
/// This is the strictly-serial engine; [`super::ParallelTrainer`] wraps it
/// and runs it directly as the `threads = 1` special case.
#[derive(Clone)]
pub struct Trainer<T: Topology = Trellis, S: TrainableStore = DenseStore> {
    pub config: TrainConfig,
    pub trellis: T,
    pub model: S,
    pub assigner: Assigner,
    pub(crate) averager: Option<Averager>,
    pub(crate) step: u64,
    /// Engine scratch buffers (allocation-free hot loop).
    pub(crate) scratch: TrainScratch,
}

impl Trainer<Trellis, DenseStore> {
    /// New width-2 dense trainer for `n_features`-dim inputs and
    /// `n_labels` classes (the paper's configuration; panics on invalid
    /// shapes — the CLI goes through [`Trainer::with_topology`]).
    pub fn new(config: TrainConfig, n_features: usize, n_labels: usize) -> Self {
        Trainer::with_topology(config, n_features, n_labels).unwrap_or_else(|e| panic!("{e}"))
    }
}

impl<T: Topology, S: TrainableStore> Trainer<T, S> {
    /// New trainer whose topology is built by `T::build(n_labels,
    /// config.width)` and whose store is built by
    /// `S::for_topology_cfg(…, config.hash_bits, config.seed)`; errors
    /// (instead of panicking) on shapes the topology or the store rejects
    /// — too few classes, a width `T` cannot represent, or hash bits out
    /// of range.
    pub fn with_topology(
        config: TrainConfig,
        n_features: usize,
        n_labels: usize,
    ) -> Result<Self, String> {
        let trellis = T::build(n_labels as u64, config.width)?;
        let model = S::for_topology_cfg(&trellis, n_features, config.hash_bits, config.seed)?;
        let assigner = Assigner::new(config.policy, n_labels, &trellis, config.seed);
        let averager = config
            .averaging
            .then(|| Averager::new(trellis.num_edges(), model.n_strips()));
        let mut scratch = TrainScratch::new();
        if trellis.as_binary().is_none() {
            // Pre-size the generic W-ary decode buffers so even the first
            // wide training step is allocation-free (the assignment policy
            // list-Viterbis up to 64 paths).
            scratch.step.ws.reserve_wide(trellis.width() as usize, trellis.steps() as usize, 64);
        }
        Ok(Trainer {
            config,
            trellis,
            model,
            assigner,
            averager,
            step: 0,
            scratch,
        })
    }

    /// Rebuild a trainer from checkpointed parts (see
    /// [`crate::model::io::Checkpoint`]). The weight averager — whose state
    /// is not checkpointed — restarts empty, so with `config.averaging` the
    /// final average covers post-resume steps only.
    pub(crate) fn from_parts(
        config: TrainConfig,
        trellis: T,
        model: S,
        assigner: Assigner,
        step: u64,
    ) -> Self {
        let averager = config
            .averaging
            .then(|| Averager::new(trellis.num_edges(), model.n_strips()));
        Trainer { config, trellis, model, assigner, averager, step, scratch: TrainScratch::new() }
    }

    /// Global SGD step count (examples seen across all epochs).
    pub fn global_step(&self) -> u64 {
        self.step
    }

    /// One SGD step on example `(x, labels)`. Returns the hinge loss.
    pub fn step(&mut self, x: SparseVec, labels: &[u32], metrics: &mut EpochMetrics) -> f32 {
        self.step += 1;
        if let Some(a) = &mut self.averager {
            a.tick();
        }
        // h = Wx + b.
        let mut h = std::mem::take(&mut self.scratch.h);
        self.model.edge_scores(x, &mut self.scratch.score, &mut h);

        // Resolve labels → paths (assigning unseen labels by policy §5.1).
        let before = self.assigner.table.n_assigned();
        let mut pos = std::mem::take(&mut self.scratch.pos);
        pos.clear();
        for &l in labels {
            pos.push(self.assigner.path_for(&self.trellis, &h, l));
        }
        metrics.new_labels += (self.assigner.table.n_assigned() - before) as u64;

        // The configured objective's loss + symmetric-difference updates
        // (the kernel shared with the Hogwild workers); this engine applies
        // each update to its private store and the averager.
        let model = &mut self.model;
        let averager = &mut self.averager;
        let loss_val = objective_step(
            &self.trellis,
            &self.config,
            self.step,
            &h,
            &pos,
            &mut self.scratch.step,
            metrics,
            &mut |po: &[u32], no: &[u32], eta: f32| {
                model.update_edges(po, no, x, eta);
                if let Some(a) = averager.as_mut() {
                    a.record_edges(model.codec(), po, no, x, eta);
                }
            },
        );
        self.scratch.h = h;
        self.scratch.pos = pos;
        loss_val
    }

    /// Train one epoch over the dataset; returns epoch metrics (also
    /// folded into the process-wide [`super::TrainStats`] sink).
    pub fn epoch(&mut self, ds: &Dataset) -> EpochMetrics {
        let t0 = std::time::Instant::now();
        let mut metrics = EpochMetrics::default();
        let n = ds.n_examples();
        // Deterministic epoch permutation, shared with the parallel
        // trainer's sharding (`seed ^ step-at-epoch-start`).
        let order =
            super::shard::epoch_order(n, self.config.shuffle, self.config.seed, self.step);
        for (i, &r) in order.iter().enumerate() {
            self.step(ds.row(r), ds.labels_of(r), &mut metrics);
            if self.config.log_every > 0 && (i + 1) % self.config.log_every == 0 {
                eprintln!("  [{}] {}/{} {}", ds.name, i + 1, n, metrics);
            }
        }
        super::TrainStats::global().observe_epoch(&metrics, t0.elapsed());
        metrics
    }

    /// Train for `epochs` epochs; returns per-epoch metrics.
    pub fn fit(&mut self, ds: &Dataset, epochs: usize) -> Vec<EpochMetrics> {
        (0..epochs).map(|_| self.epoch(ds)).collect()
    }

    /// Finalize into a predictor: applies weight averaging and the L1
    /// soft-threshold (if configured).
    pub fn into_model(self) -> TrainedModel<T, S> {
        let mut model = self.model;
        if let Some(a) = &self.averager {
            let (w, b) = a.averaged(model.raw_w(), model.bias());
            let (wm, bm) = model.raw_parts_mut();
            wm.copy_from_slice(&w);
            bm.copy_from_slice(&b);
        }
        if self.config.l1_lambda > 0.0 {
            model = crate::model::l1::soft_threshold_store(&model, self.config.l1_lambda);
        }
        TrainedModel { trellis: self.trellis, model, assigner: self.assigner }
    }
}

/// A trained LTLS predictor: model + trellis + label↔path table. Generic
/// over the graph [`Topology`] (width-2 [`Trellis`] by default) and the
/// weight storage [`WeightStore`] ([`DenseStore`] by default; the hashed
/// and serve-only q8 backends run the same decode stack).
#[derive(Clone)]
pub struct TrainedModel<T: Topology = Trellis, S: WeightStore = DenseStore> {
    pub trellis: T,
    pub model: S,
    pub assigner: Assigner,
}

impl<T: Topology, S: WeightStore> TrainedModel<T, S> {
    /// Top-1 dataset label for `x` (`O(E·nnz + log C)`).
    pub fn predict(&self, x: SparseVec) -> u32 {
        self.predict_with(x, &mut PredictScratch::new())
    }

    /// Top-1 dataset label reusing a caller-owned scratch — the
    /// zero-allocation hot path of the serving engine.
    pub fn predict_with(&self, x: SparseVec, scratch: &mut PredictScratch) -> u32 {
        self.model.edge_scores(x, &mut scratch.score, &mut scratch.h);
        let Scored { label: path, .. } = viterbi_ws(&self.trellis, &scratch.h, &mut scratch.ws);
        if let Some(l) = self.assigner.table.label_of(path) {
            return l;
        }
        // The best path is unassigned: fall back to the best *assigned*
        // path in the top-m list.
        let m = 64.min(self.trellis.c() as usize);
        list_viterbi_into(&self.trellis, &scratch.h, m, &mut scratch.ws, &mut scratch.paths);
        for s in &scratch.paths {
            if let Some(l) = self.assigner.table.label_of(s.label) {
                return l;
            }
        }
        0 // degenerate: nothing assigned yet
    }

    /// Top-k dataset labels (paths without an assigned label are skipped —
    /// they correspond to no class).
    pub fn predict_topk(&self, x: SparseVec, k: usize) -> Vec<(u32, f32)> {
        let mut out = Vec::with_capacity(k);
        self.predict_topk_into(x, k, &mut PredictScratch::new(), &mut out);
        out
    }

    /// Top-k dataset labels into `out`, reusing a caller-owned scratch.
    /// Bit-identical to [`Self::predict_topk`]; allocation-free after
    /// warm-up.
    pub fn predict_topk_into(
        &self,
        x: SparseVec,
        k: usize,
        scratch: &mut PredictScratch,
        out: &mut Vec<(u32, f32)>,
    ) {
        out.clear();
        self.model.edge_scores(x, &mut scratch.score, &mut scratch.h);
        // Over-fetch so unassigned paths can be skipped.
        let fetch = (k + 8).min(self.trellis.c() as usize);
        list_viterbi_into(&self.trellis, &scratch.h, fetch, &mut scratch.ws, &mut scratch.paths);
        self.resolve_topk(k, &scratch.paths, out);
    }

    /// Map decoded (path, score) pairs to assigned dataset labels,
    /// keeping at most `k`. Non-finite scores end the scan: the decoders
    /// sort them last, and a `−∞` only arises from a shard slice's masked
    /// foreign edges ([`crate::model::ShardStore`]) — those paths belong
    /// to other shards and must not appear in this model's answers.
    pub(crate) fn resolve_topk(&self, k: usize, paths: &[Scored], out: &mut Vec<(u32, f32)>) {
        for s in paths {
            if !s.score.is_finite() {
                break;
            }
            if let Some(l) = self.assigner.table.label_of(s.label) {
                out.push((l, s.score));
                if out.len() == k {
                    break;
                }
            }
        }
    }

    /// Model size in bytes.
    pub fn bytes(&self) -> usize {
        self.model.bytes()
    }
}

impl<T: Topology> TrainedModel<T, DenseStore> {
    /// Serve-only 8-bit quantization of this model (see
    /// [`crate::model::Q8Store`] and the `ltls quantize` subcommand):
    /// same trellis and label↔path table, ~4× smaller weights.
    pub fn quantized(&self) -> TrainedModel<T, crate::model::Q8Store> {
        TrainedModel {
            trellis: self.trellis.clone(),
            model: crate::model::Q8Store::quantize(&self.model),
            assigner: self.assigner.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assign::AssignPolicy;
    use crate::data::synthetic::{SyntheticSpec, TeacherKind};
    use crate::eval::precision_at_1;

    /// LTLS learns a rank-E realizable problem to high precision.
    #[test]
    fn learns_trellis_teacher() {
        let ds = SyntheticSpec::multiclass(3000, 1200, 64)
            .teacher(TeacherKind::Cluster)
            .seed(17)
            .generate();
        let (train, test) = crate::data::split::random_split(&ds, 0.2, 1);
        let mut tr = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
        let ms = tr.fit(&train, 8);
        // Loss decreases over epochs.
        assert!(
            ms.last().unwrap().mean_loss() < ms[0].mean_loss(),
            "loss did not decrease: {:?}",
            ms.iter().map(|m| m.mean_loss()).collect::<Vec<_>>()
        );
        let model = tr.into_model();
        let p1 = precision_at_1(&model, &test);
        assert!(p1 > 0.55, "precision@1 = {p1}");
    }

    /// Multilabel training works and beats chance clearly.
    #[test]
    fn learns_multilabel() {
        let ds = SyntheticSpec::multilabel(2500, 1000, 48, 2)
            .teacher(TeacherKind::Cluster)
            .seed(18)
            .generate();
        let (train, test) = crate::data::split::random_split(&ds, 0.2, 2);
        let mut tr = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
        tr.fit(&train, 8);
        let model = tr.into_model();
        let p1 = precision_at_1(&model, &test);
        assert!(p1 > 0.3, "precision@1 = {p1} (chance ≈ {:.3})", 2.0 / 48.0);
    }

    /// The paper's §5.1 claim: policy assignment beats random assignment.
    #[test]
    fn policy_beats_random_assignment() {
        let ds = SyntheticSpec::multiclass(4000, 2000, 128)
            .teacher(TeacherKind::Cluster)
            .seed(19)
            .generate();
        let (train, test) = crate::data::split::random_split(&ds, 0.2, 3);
        let mut scores = Vec::new();
        for policy in [AssignPolicy::TopRanked, AssignPolicy::Random] {
            let cfg = TrainConfig { policy, ..TrainConfig::default() };
            let mut tr = Trainer::new(cfg, ds.n_features, ds.n_labels);
            tr.fit(&train, 5);
            scores.push(precision_at_1(&tr.into_model(), &test));
        }
        // TopRanked ≥ Random minus noise; usually strictly better.
        assert!(
            scores[0] > scores[1] - 0.02,
            "policy {} vs random {}",
            scores[0],
            scores[1]
        );
    }

    /// Updates touch only symmetric-difference edges (Fig. 2 semantics):
    /// when loss fires for a multiclass pair, shared edges keep weights 0
    /// in the first step.
    #[test]
    fn first_update_touches_only_symmetric_difference() {
        let ds = SyntheticSpec::multiclass(10, 30, 8).seed(20).generate();
        let mut tr = Trainer::new(
            TrainConfig { averaging: false, shuffle: false, ..TrainConfig::default() },
            ds.n_features,
            ds.n_labels,
        );
        let mut m = EpochMetrics::default();
        tr.step(ds.row(0), ds.labels_of(0), &mut m);
        if m.active_hinge == 1 {
            // Rows for updated edges are ±lr·x; others all zero.
            let nonzero_rows: Vec<usize> = (0..tr.model.n_edges)
                .filter(|&e| tr.model.edge_row(e).iter().any(|&v| v != 0.0))
                .collect();
            assert!(!nonzero_rows.is_empty());
            assert!(nonzero_rows.len() <= 2 * (tr.trellis.steps as usize + 2));
        }
    }

    /// Predict_topk returns assigned labels only, descending.
    #[test]
    fn topk_prediction_shape() {
        let ds = SyntheticSpec::multiclass(800, 80, 32).seed(21).generate();
        let mut tr = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
        tr.fit(&ds, 3);
        let model = tr.into_model();
        let top = model.predict_topk(ds.row(0), 5);
        assert!(top.len() <= 5 && !top.is_empty());
        for w in top.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
        for (l, _) in &top {
            assert!((*l as usize) < ds.n_labels);
        }
    }

    /// The hashed store trains through the same serial engine and learns
    /// the same synthetic task (collisions cost a little accuracy, not
    /// learnability), with memory bounded by 2^bits instead of D.
    #[test]
    fn hashed_store_trains_serially() {
        use crate::model::HashedStore;
        let ds = SyntheticSpec::multiclass(2500, 1200, 64)
            .teacher(TeacherKind::Cluster)
            .seed(23)
            .generate();
        let (train, test) = crate::data::split::random_split(&ds, 0.2, 5);
        let cfg = TrainConfig { hash_bits: 9, ..TrainConfig::default() };
        let mut tr = Trainer::<Trellis, HashedStore>::with_topology(cfg, ds.n_features, ds.n_labels)
            .unwrap();
        tr.fit(&train, 8);
        let model = tr.into_model();
        assert_eq!(model.model.hash_bits(), 9);
        assert!(model.model.param_count() < model.model.dense_equivalent_params() / 2);
        let p1 = precision_at_1(&model, &test);
        assert!(p1 > 0.4, "hashed precision@1 = {p1}");
    }
}
