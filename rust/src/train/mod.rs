//! Online training of the LTLS linear model (paper §5).
//!
//! One SGD step: compute edge scores `h = Wx` (`O(E·nnz)`), run the
//! configured [`objective::Objective`] — the separation-ranking loss pair
//! (ℓp, ℓn) for multiclass, or the union-of-gold-paths hinge over the full
//! label set for multilabel — via list-Viterbi, and for each active hinge
//! update only the edges in the symmetric difference of the loss pair's
//! paths (`+ηx` on positive-only edges, `−ηx` on negative-only edges) —
//! `O(log C)` model work per step, with weight averaging.
//!
//! Two execution engines share that step (literally: both call the one
//! [`objective::objective_step`] kernel):
//!
//! * [`trainer::Trainer`] — the strictly-serial path (with weight
//!   averaging), now also the `threads = 1` special case of the parallel
//!   trainer.
//! * [`parallel::ParallelTrainer`] — the Hogwild-style multi-worker path:
//!   deterministic sharding ([`shard`]), lock-free shared weight updates,
//!   per-worker engine scratch, optional mini-batch scoring through the
//!   serving kernel, and epoch-boundary checkpoint/resume
//!   ([`crate::model::io::Checkpoint`]).

pub mod config;
pub mod metrics;
pub mod objective;
pub mod parallel;
pub mod shard;
pub mod trainer;

pub use config::TrainConfig;
pub use objective::Objective;
pub use metrics::{EpochMetrics, TrainStats};
pub use parallel::ParallelTrainer;
pub use trainer::{TrainedModel, Trainer};
