//! Online training of the LTLS linear model (paper §5).
//!
//! One SGD step: compute edge scores `h = Wx` (`O(E·nnz)`), find the
//! separation-ranking loss pair (ℓp, ℓn) via list-Viterbi, and if the
//! hinge is active update only the edges in the symmetric difference of
//! the two paths (`+ηx` on positive-only edges, `−ηx` on negative-only
//! edges) — `O(log C)` model work per step, with weight averaging.

pub mod config;
pub mod metrics;
pub mod trainer;

pub use config::TrainConfig;
pub use trainer::{TrainedModel, Trainer};
