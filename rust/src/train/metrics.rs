//! Running training metrics: the per-epoch [`EpochMetrics`] accumulator
//! the SGD step fills in, plus the process-wide [`TrainStats`] sink —
//! lock-free [`crate::obs`] counters folded in at every epoch boundary
//! and rendered into the serving frontend's `METRICS` scrape as the
//! `ltls_train_*` family (catalog: `docs/OBSERVABILITY.md`).

use crate::obs::{render_counter, render_histogram, Counter, Histogram};
use std::sync::OnceLock;
use std::time::Duration;

/// Accumulated over an epoch.
#[derive(Clone, Debug, Default)]
pub struct EpochMetrics {
    pub examples: u64,
    pub active_hinge: u64,
    pub loss_sum: f64,
    pub new_labels: u64,
}

impl EpochMetrics {
    pub fn mean_loss(&self) -> f64 {
        if self.examples == 0 {
            0.0
        } else {
            self.loss_sum / self.examples as f64
        }
    }

    /// Fraction of steps where the hinge was active (an update happened).
    pub fn update_rate(&self) -> f64 {
        if self.examples == 0 {
            0.0
        } else {
            self.active_hinge as f64 / self.examples as f64
        }
    }

    /// Fold another accumulator into this one (merging per-worker metrics
    /// after a parallel epoch).
    pub fn merge(&mut self, other: &EpochMetrics) {
        self.examples += other.examples;
        self.active_hinge += other.active_hinge;
        self.loss_sum += other.loss_sum;
        self.new_labels += other.new_labels;
    }
}

impl std::fmt::Display for EpochMetrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "examples={} mean_loss={:.4} update_rate={:.3} new_labels={}",
            self.examples,
            self.mean_loss(),
            self.update_rate(),
            self.new_labels
        )
    }
}

/// Process-wide training counters on the lock-free [`crate::obs`]
/// primitives. Both execution engines ([`super::Trainer::epoch`] and the
/// Hogwild epoch of [`super::ParallelTrainer`]) fold their merged
/// [`EpochMetrics`] into the [`TrainStats::global`] sink exactly once per
/// epoch — the serial engine is the `threads = 1` delegate of the
/// parallel one, so nothing double-counts.
pub struct TrainStats {
    /// Epochs completed (any engine).
    pub epochs: Counter,
    /// Examples consumed across all epochs.
    pub examples: Counter,
    /// SGD steps whose hinge was active (an update happened).
    pub updates: Counter,
    /// Labels assigned to trellis paths on first sight (paper §5.1).
    pub new_labels: Counter,
    /// Wall-clock time per epoch.
    pub epoch_time: Histogram,
}

impl Default for TrainStats {
    fn default() -> Self {
        Self::new()
    }
}

impl TrainStats {
    pub fn new() -> Self {
        TrainStats {
            epochs: Counter::new(),
            examples: Counter::new(),
            updates: Counter::new(),
            new_labels: Counter::new(),
            epoch_time: Histogram::new(),
        }
    }

    /// The process-wide sink every trainer reports into.
    pub fn global() -> &'static TrainStats {
        static GLOBAL: OnceLock<TrainStats> = OnceLock::new();
        GLOBAL.get_or_init(TrainStats::new)
    }

    /// Fold one completed epoch into the counters.
    pub fn observe_epoch(&self, m: &EpochMetrics, elapsed: Duration) {
        self.epochs.inc();
        self.examples.add(m.examples);
        self.updates.add(m.active_hinge);
        self.new_labels.add(m.new_labels);
        self.epoch_time.record_duration(elapsed);
    }

    /// The `ltls_train_*` block of the Prometheus scrape (all-zero until
    /// the process trains something — `serve --listen` without `--model`
    /// trains in-process, so the serving scrape carries these live).
    pub fn prometheus(&self) -> String {
        let mut s = String::new();
        render_counter(
            &mut s,
            "ltls_train_epochs_total",
            "training epochs completed",
            self.epochs.get(),
        );
        render_counter(
            &mut s,
            "ltls_train_examples_total",
            "training examples consumed",
            self.examples.get(),
        );
        render_counter(
            &mut s,
            "ltls_train_updates_total",
            "SGD steps with an active hinge (weights updated)",
            self.updates.get(),
        );
        render_counter(
            &mut s,
            "ltls_train_new_labels_total",
            "labels assigned to trellis paths on first sight",
            self.new_labels.get(),
        );
        render_histogram(
            &mut s,
            "ltls_train_epoch_seconds",
            "wall-clock time per training epoch",
            &self.epoch_time.snapshot(),
        );
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_math() {
        let m = EpochMetrics { examples: 10, active_hinge: 4, loss_sum: 5.0, new_labels: 2 };
        assert!((m.mean_loss() - 0.5).abs() < 1e-12);
        assert!((m.update_rate() - 0.4).abs() < 1e-12);
        assert!(!format!("{m}").is_empty());
    }

    /// Empty-epoch edge cases: the ratio metrics must not divide by zero,
    /// also after merging empties.
    #[test]
    fn empty_metrics_are_zero() {
        let m = EpochMetrics::default();
        assert_eq!(m.mean_loss(), 0.0);
        assert_eq!(m.update_rate(), 0.0);
        assert!(m.mean_loss().is_finite() && m.update_rate().is_finite());
        let mut e = EpochMetrics::default();
        e.merge(&EpochMetrics::default());
        assert_eq!(e.mean_loss(), 0.0);
        assert_eq!(e.update_rate(), 0.0);
        assert!(!format!("{e}").is_empty());
    }

    #[test]
    fn merge_accumulates_workers() {
        let mut a = EpochMetrics { examples: 10, active_hinge: 4, loss_sum: 5.0, new_labels: 2 };
        let b = EpochMetrics { examples: 6, active_hinge: 1, loss_sum: 1.5, new_labels: 0 };
        a.merge(&b);
        assert_eq!(a.examples, 16);
        assert_eq!(a.active_hinge, 5);
        assert!((a.loss_sum - 6.5).abs() < 1e-12);
        assert_eq!(a.new_labels, 2);
        // Merging an empty accumulator is the identity.
        let snapshot = a.clone();
        a.merge(&EpochMetrics::default());
        assert_eq!(a.examples, snapshot.examples);
        assert_eq!(a.loss_sum, snapshot.loss_sum);
    }

    #[test]
    fn train_stats_accumulate_across_epochs() {
        let s = TrainStats::new();
        let m = EpochMetrics { examples: 10, active_hinge: 4, loss_sum: 5.0, new_labels: 2 };
        s.observe_epoch(&m, Duration::from_micros(50));
        s.observe_epoch(&m, Duration::from_micros(70));
        assert_eq!(s.epochs.get(), 2);
        assert_eq!(s.examples.get(), 20);
        assert_eq!(s.updates.get(), 8);
        assert_eq!(s.new_labels.get(), 4);
        assert_eq!(s.epoch_time.snapshot().count, 2);
    }

    #[test]
    fn train_stats_prometheus_is_conformant() {
        let s = TrainStats::new();
        let m = EpochMetrics { examples: 3, active_hinge: 1, loss_sum: 1.0, new_labels: 0 };
        s.observe_epoch(&m, Duration::from_millis(2));
        let text = s.prometheus();
        assert!(text.contains("# HELP ltls_train_epochs_total"), "{text}");
        assert!(text.contains("# TYPE ltls_train_epochs_total counter"), "{text}");
        assert!(text.contains("ltls_train_epochs_total 1"), "{text}");
        assert!(text.contains("ltls_train_examples_total 3"), "{text}");
        assert!(text.contains("# TYPE ltls_train_epoch_seconds histogram"), "{text}");
        assert!(text.contains("ltls_train_epoch_seconds_bucket{le=\"+Inf\"} 1"), "{text}");
        assert!(text.contains("ltls_train_epoch_seconds_count 1"), "{text}");
        for line in text.lines() {
            if line.starts_with('#') {
                continue;
            }
            assert_eq!(line.split_whitespace().count(), 2, "bad line {line:?}");
        }
    }

    /// The global sink is a singleton: every call sees the same counters.
    #[test]
    fn global_sink_is_shared() {
        let before = TrainStats::global().epochs.get();
        TrainStats::global().observe_epoch(&EpochMetrics::default(), Duration::ZERO);
        assert!(TrainStats::global().epochs.get() > before);
    }
}
