//! Running training metrics.

/// Accumulated over an epoch.
#[derive(Clone, Debug, Default)]
pub struct EpochMetrics {
    pub examples: u64,
    pub active_hinge: u64,
    pub loss_sum: f64,
    pub new_labels: u64,
}

impl EpochMetrics {
    pub fn mean_loss(&self) -> f64 {
        if self.examples == 0 {
            0.0
        } else {
            self.loss_sum / self.examples as f64
        }
    }

    /// Fraction of steps where the hinge was active (an update happened).
    pub fn update_rate(&self) -> f64 {
        if self.examples == 0 {
            0.0
        } else {
            self.active_hinge as f64 / self.examples as f64
        }
    }

    /// Fold another accumulator into this one (merging per-worker metrics
    /// after a parallel epoch).
    pub fn merge(&mut self, other: &EpochMetrics) {
        self.examples += other.examples;
        self.active_hinge += other.active_hinge;
        self.loss_sum += other.loss_sum;
        self.new_labels += other.new_labels;
    }
}

impl std::fmt::Display for EpochMetrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "examples={} mean_loss={:.4} update_rate={:.3} new_labels={}",
            self.examples,
            self.mean_loss(),
            self.update_rate(),
            self.new_labels
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_math() {
        let m = EpochMetrics { examples: 10, active_hinge: 4, loss_sum: 5.0, new_labels: 2 };
        assert!((m.mean_loss() - 0.5).abs() < 1e-12);
        assert!((m.update_rate() - 0.4).abs() < 1e-12);
        assert!(!format!("{m}").is_empty());
    }

    /// Empty-epoch edge cases: the ratio metrics must not divide by zero,
    /// also after merging empties.
    #[test]
    fn empty_metrics_are_zero() {
        let m = EpochMetrics::default();
        assert_eq!(m.mean_loss(), 0.0);
        assert_eq!(m.update_rate(), 0.0);
        assert!(m.mean_loss().is_finite() && m.update_rate().is_finite());
        let mut e = EpochMetrics::default();
        e.merge(&EpochMetrics::default());
        assert_eq!(e.mean_loss(), 0.0);
        assert_eq!(e.update_rate(), 0.0);
        assert!(!format!("{e}").is_empty());
    }

    #[test]
    fn merge_accumulates_workers() {
        let mut a = EpochMetrics { examples: 10, active_hinge: 4, loss_sum: 5.0, new_labels: 2 };
        let b = EpochMetrics { examples: 6, active_hinge: 1, loss_sum: 1.5, new_labels: 0 };
        a.merge(&b);
        assert_eq!(a.examples, 16);
        assert_eq!(a.active_hinge, 5);
        assert!((a.loss_sum - 6.5).abs() < 1e-12);
        assert_eq!(a.new_labels, 2);
        // Merging an empty accumulator is the identity.
        let snapshot = a.clone();
        a.merge(&EpochMetrics::default());
        assert_eq!(a.examples, snapshot.examples);
        assert_eq!(a.loss_sum, snapshot.loss_sum);
    }
}
