//! Hogwild-style parallel trainer (Recht et al., 2011 applied to LTLS).
//!
//! LTLS updates are *sparse*: one SGD step touches only the `O(log C)`
//! edges in the symmetric difference of two trellis paths, over the
//! example's active features. Sparse updates are exactly the regime where
//! lock-free ("Hogwild") SGD converges despite racy writes, so the
//! parallel trainer runs `N` scoped workers over one shared
//! [`TrainableStore`]:
//!
//! * **Sharding** — every epoch's deterministic permutation (the same
//!   `seed ^ step` permutation the serial trainer uses, see
//!   [`super::shard`]) is split into one contiguous chunk per worker, so a
//!   1-worker Hogwild epoch is *bit-identical* to the serial epoch
//!   (pinned by `rust/tests/train_parallel.rs`).
//! * **Shared weights** — workers read and write the weight strips through
//!   [`SharedWeights`], a `&[AtomicU32]` view over the store's raw `f32`
//!   storage (same size/alignment/bit-validity) plus the store's
//!   [`StripCodec`] held by value — so the dense *and* hashed backends
//!   share one set of atomic kernels. All accesses are `Relaxed` atomic
//!   loads/stores: plain machine loads/stores on x86/ARM, formally
//!   race-free, with the classic Hogwild semantics that concurrent
//!   read-modify-writes may occasionally drop an update.
//! * **Per-worker engine scratch** — each worker owns a
//!   [`TrainScratch`] (edge-score buffer, loss decode workspace,
//!   symmetric-difference sets, mini-batch buffers), so the steady-state
//!   epoch performs no heap allocation in the hot loop.
//! * **Mini-batch scoring** — with `config.batch > 1` a worker scores `B`
//!   examples per strip sweep using the same gather-sort schedule as the
//!   serving kernel (`edge_scores_batch`), then applies the per-example
//!   hinge updates from the shared score matrix (scores within a block
//!   are computed before the block's updates — standard mini-batch
//!   staleness).
//! * **Assignment** — the online label→path table (paper §5.1) is the one
//!   piece that cannot be racy (it is a bijection), so it sits behind an
//!   `RwLock`: the steady-state path is a read-lock lookup; only unseen
//!   labels take the write lock. After the first epoch this is
//!   read-mostly.
//!
//! Weight **averaging is a strictly-serial feature**: the Hogwild path
//! trains raw weights and drops the averager (a racy average would be
//! neither the paper's average nor reproducible). The `threads = 1,
//! batch = 1` configuration routes to the serial [`Trainer`] and keeps
//! averaging.
//!
//! The learning-rate schedule is driven by one shared `AtomicU64` step
//! counter (`fetch_add` per example), matching the serial step count in
//! distribution and exactly at one worker.

use super::config::TrainConfig;
use super::metrics::EpochMetrics;
use super::objective::objective_step;
use super::shard::shard_epoch;
use super::trainer::{TrainedModel, Trainer};
use crate::assign::Assigner;
use crate::data::Dataset;
use crate::engine::TrainScratch;
use crate::graph::{Topology, Trellis};
use crate::model::io::{self, Checkpoint};
use crate::model::{DenseStore, StripCodec, TrainableStore};
use crate::sparse::SparseVec;
use std::path::Path;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::RwLock;

/// View a `&mut [f32]` as `&[AtomicU32]` for the duration of the borrow.
///
/// SAFETY: `AtomicU32` has the same size, alignment and bit validity as
/// `u32`/`f32`; the exclusive borrow guarantees no plain (non-atomic)
/// access can alias the view while it lives, and every access through the
/// view is atomic — so concurrent workers are formally race-free.
fn atomic_view(v: &mut [f32]) -> &[AtomicU32] {
    unsafe { std::slice::from_raw_parts(v.as_mut_ptr() as *const AtomicU32, v.len()) }
}

/// The shared Hogwild view over one [`TrainableStore`]'s storage.
///
/// Mirrors the store's scoring/update kernels 1:1 (same loop structure,
/// same float-op order — `shared_kernels_match_model` pins the parity)
/// with relaxed atomic element access instead of plain loads/stores, and
/// the store's feature→(strip, sign) codec applied identically.
struct SharedWeights<'a, C: StripCodec> {
    /// Strip-major `n_strips × E` weights.
    w: &'a [AtomicU32],
    /// Per-edge bias.
    bias: &'a [AtomicU32],
    n_edges: usize,
    codec: C,
}

impl<'a, C: StripCodec> SharedWeights<'a, C> {
    fn new<S: TrainableStore<Codec = C>>(m: &'a mut S) -> SharedWeights<'a, C> {
        let n_edges = m.n_edges();
        let codec = m.codec();
        let (w, bias) = m.raw_parts_mut();
        SharedWeights { w: atomic_view(w), bias: atomic_view(bias), n_edges, codec }
    }

    #[inline]
    fn get(a: &AtomicU32) -> f32 {
        f32::from_bits(a.load(Ordering::Relaxed))
    }

    /// Lossy Hogwild read-modify-write (no CAS loop by design: a lost
    /// increment under contention is the algorithm's accepted noise).
    #[inline]
    fn add(a: &AtomicU32, delta: f32) {
        let v = f32::from_bits(a.load(Ordering::Relaxed)) + delta;
        a.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Mirrors [`crate::model::store::codec_edge_scores`].
    fn edge_scores(&self, x: SparseVec, out: &mut Vec<f32>) {
        let e = self.n_edges;
        out.clear();
        out.extend(self.bias.iter().map(Self::get));
        for (&i, &v) in x.indices.iter().zip(x.values) {
            let (s, sign) = self.codec.strip_of(i);
            let strip = &self.w[s as usize * e..(s as usize + 1) * e];
            let sv = v * sign;
            for (o, wv) in out.iter_mut().zip(strip) {
                *o += sv * Self::get(wv);
            }
        }
    }

    /// Mirrors [`crate::model::store::codec_edge_scores_batch`] (same
    /// gather-sort schedule: one strip sweep per block).
    fn edge_scores_batch(
        &self,
        rows: &[SparseVec],
        scratch: &mut Vec<(u32, u32, f32)>,
        out: &mut Vec<f32>,
    ) {
        let e = self.n_edges;
        out.clear();
        out.reserve(rows.len() * e);
        for _ in 0..rows.len() {
            out.extend(self.bias.iter().map(Self::get));
        }
        scratch.clear();
        for (r, x) in rows.iter().enumerate() {
            for (&i, &v) in x.indices.iter().zip(x.values) {
                scratch.push((i, r as u32, v));
            }
        }
        scratch.sort_unstable_by_key(|t| t.0);
        for &(i, r, v) in scratch.iter() {
            let (s, sign) = self.codec.strip_of(i);
            let strip = &self.w[s as usize * e..(s as usize + 1) * e];
            let dst = &mut out[r as usize * e..(r as usize + 1) * e];
            let sv = v * sign;
            for (o, wv) in dst.iter_mut().zip(strip) {
                *o += sv * Self::get(wv);
            }
        }
    }

    /// Mirrors [`TrainableStore::update_edges`] (fused symmetric-difference
    /// update, strip-major, bias after weights).
    fn update_edges(&self, pos: &[u32], neg: &[u32], x: SparseVec, scale: f32) {
        let e = self.n_edges;
        for (&i, &v) in x.indices.iter().zip(x.values) {
            let (s, sign) = self.codec.strip_of(i);
            let strip = &self.w[s as usize * e..(s as usize + 1) * e];
            let sv = (scale * v) * sign;
            for &edge in pos {
                Self::add(&strip[edge as usize], sv);
            }
            for &edge in neg {
                Self::add(&strip[edge as usize], -sv);
            }
        }
        for &edge in pos {
            Self::add(&self.bias[edge as usize], scale * 0.1);
        }
        for &edge in neg {
            Self::add(&self.bias[edge as usize], -(scale * 0.1));
        }
    }
}

/// One worker's epoch over its shard. Runs the full SGD step pipeline on
/// worker-owned [`TrainScratch`] buffers against the shared weights.
/// Generic over the graph [`Topology`] and the store's [`StripCodec`] —
/// the wide/width-2 trellises and the dense/hashed backends all share the
/// whole Hogwild pipeline.
#[allow(clippy::too_many_arguments)]
fn run_worker<T: Topology, C: StripCodec>(
    shard: &[usize],
    ds: &Dataset,
    trellis: &T,
    config: &TrainConfig,
    weights: &SharedWeights<'_, C>,
    assigner: &RwLock<&mut Assigner>,
    step_ctr: &AtomicU64,
    batch: usize,
) -> EpochMetrics {
    let mut metrics = EpochMetrics::default();
    let mut scratch = TrainScratch::new();
    if trellis.as_binary().is_none() {
        // Pre-size the generic W-ary decode buffers (see Trainer::with_topology).
        scratch.step.ws.reserve_wide(trellis.width() as usize, trellis.steps() as usize, 64);
    }
    let mut rows: Vec<SparseVec<'_>> = Vec::with_capacity(batch);
    let e = weights.n_edges;
    for block in shard.chunks(batch.max(1)) {
        rows.clear();
        rows.extend(block.iter().map(|&r| ds.row(r)));
        let batched = rows.len() > 1;
        if batched {
            // One strip sweep scores the whole block (the serving
            // kernel's schedule); updates apply per example below.
            weights.edge_scores_batch(&rows, &mut scratch.score.gather, &mut scratch.batch_h);
        }
        for (bi, &r) in block.iter().enumerate() {
            let x = rows[bi];
            // Global step: one fetch_add per example, like the serial
            // `self.step += 1`.
            let t = step_ctr.fetch_add(1, Ordering::Relaxed) + 1;
            if !batched {
                weights.edge_scores(x, &mut scratch.h);
            }
            let h: &[f32] = if batched {
                &scratch.batch_h[bi * e..(bi + 1) * e]
            } else {
                &scratch.h
            };

            // Resolve labels → paths. Steady state is a read-lock lookup;
            // unseen labels re-resolve under the write lock (the order of
            // §5.1 assignments under concurrency is racy by design).
            let labels = ds.labels_of(r);
            let mut pos = std::mem::take(&mut scratch.pos);
            pos.clear();
            let all_assigned = {
                let a = assigner.read().expect("assigner lock poisoned");
                let mut ok = true;
                for &l in labels {
                    match a.table.path_of(l) {
                        Some(p) => pos.push(p),
                        None => {
                            ok = false;
                            break;
                        }
                    }
                }
                ok
            };
            if !all_assigned {
                pos.clear();
                let mut a = assigner.write().expect("assigner lock poisoned");
                let before = a.table.n_assigned();
                for &l in labels {
                    pos.push(a.path_for(trellis, h, l));
                }
                metrics.new_labels += (a.table.n_assigned() - before) as u64;
            }

            // The shared objective kernel (loss + symmetric-difference
            // updates); this engine applies each update to the shared
            // atomic weight view.
            objective_step(
                trellis,
                config,
                t,
                h,
                &pos,
                &mut scratch.step,
                &mut metrics,
                &mut |po: &[u32], no: &[u32], eta: f32| {
                    weights.update_edges(po, no, x, eta);
                },
            );
            scratch.pos = pos;
        }
    }
    metrics
}

/// Multi-threaded Hogwild trainer wrapping the serial [`Trainer`], generic
/// over the graph [`Topology`] (width-2 [`Trellis`] by default) and the
/// weight storage [`TrainableStore`] ([`DenseStore`] by default).
///
/// `config.threads` picks the worker count (0 → one per core, 1 → the
/// serial path); `config.batch` picks the mini-batch scoring width;
/// `config.hash_bits` picks the hashed backend when the store type is
/// [`crate::model::HashedStore`]. See the module docs for the execution
/// model.
#[derive(Clone)]
pub struct ParallelTrainer<T: Topology = Trellis, S: TrainableStore = DenseStore> {
    inner: Trainer<T, S>,
    /// Epochs completed, including epochs restored from a checkpoint.
    epochs_done: u32,
    /// Per-epoch metrics history (checkpointed alongside the model).
    history: Vec<EpochMetrics>,
}

impl ParallelTrainer<Trellis, DenseStore> {
    /// New width-2 dense trainer for `n_features`-dim inputs and
    /// `n_labels` classes (panics on invalid shapes — the CLI goes through
    /// [`ParallelTrainer::with_topology`]).
    pub fn new(config: TrainConfig, n_features: usize, n_labels: usize) -> Self {
        ParallelTrainer {
            inner: Trainer::new(config, n_features, n_labels),
            epochs_done: 0,
            history: Vec::new(),
        }
    }
}

impl<T: Topology, S: TrainableStore> ParallelTrainer<T, S> {
    /// New trainer whose topology is built by `T::build(n_labels,
    /// config.width)` and store by `S::for_topology_cfg`; errors instead
    /// of panicking on shapes either rejects (the CLI entry point for
    /// `--width` / `--hash-bits`).
    pub fn with_topology(
        config: TrainConfig,
        n_features: usize,
        n_labels: usize,
    ) -> Result<Self, String> {
        Ok(ParallelTrainer {
            inner: Trainer::with_topology(config, n_features, n_labels)?,
            epochs_done: 0,
            history: Vec::new(),
        })
    }

    /// Resume training from a checkpoint: restores the raw weights, the
    /// label→path table, the global step (so the lr schedule and epoch
    /// permutations continue exactly), the epoch counter and the metrics
    /// history. Errors if `config.seed` differs from the checkpoint's seed
    /// — the "reproducible from the config alone" guarantee would silently
    /// break otherwise — or if the checkpoint's objective, trellis width or
    /// weight backend differs from the config's. Not restored (documented):
    /// the weight-averager state and the assigner's random-fallback RNG —
    /// both restart fresh.
    pub fn resume(
        config: TrainConfig,
        ck: Checkpoint<T, S>,
    ) -> Result<ParallelTrainer<T, S>, String> {
        let Checkpoint { epoch, step, seed, objective, history, model } = ck;
        if seed != config.seed {
            return Err(format!(
                "checkpoint was trained with seed {seed}, config has seed {} — \
                 resume with the same seed (or retrain)",
                config.seed
            ));
        }
        if objective != config.objective {
            return Err(format!(
                "checkpoint was trained with objective {objective}, config has {} — \
                 resume with the same objective (or retrain)",
                config.objective
            ));
        }
        // Same clamp the builder applies (a width above C is capped to C),
        // so a resume of a clamped run with the original flag still works.
        let effective = (config.width as u64).min(model.trellis.c()) as u32;
        if model.trellis.width() != effective {
            return Err(format!(
                "checkpoint was trained at trellis width {}, config has width {} — \
                 resume with the same --width (or retrain)",
                model.trellis.width(),
                config.width
            ));
        }
        if model.model.hash_bits() != config.hash_bits {
            return Err(format!(
                "checkpoint was trained with hash-bits {}, config has {} — \
                 resume with the same --hash-bits (or retrain)",
                model.model.hash_bits(),
                config.hash_bits
            ));
        }
        let TrainedModel { trellis, model, mut assigner } = model;
        // Model files record only the bound pairs; restore the configured
        // assignment policy for the labels still unseen.
        assigner.policy = config.policy;
        Ok(ParallelTrainer {
            inner: Trainer::from_parts(config, trellis, model, assigner, step),
            epochs_done: epoch,
            history,
        })
    }

    /// Resolved worker count (`config.threads`, with 0 → one per core).
    pub fn n_threads(&self) -> usize {
        match self.inner.config.threads {
            0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            t => t,
        }
    }

    pub fn config(&self) -> &TrainConfig {
        &self.inner.config
    }

    pub fn config_mut(&mut self) -> &mut TrainConfig {
        &mut self.inner.config
    }

    /// Global SGD step count (examples seen across all epochs/resumes).
    pub fn global_step(&self) -> u64 {
        self.inner.step
    }

    /// Epochs completed so far (including checkpoint-restored ones).
    pub fn epochs_done(&self) -> u32 {
        self.epochs_done
    }

    /// Per-epoch metrics, oldest first (checkpoint-restored + this run).
    pub fn history(&self) -> &[EpochMetrics] {
        &self.history
    }

    /// Snapshot the current training state (raw, unaveraged weights).
    pub fn checkpoint(&self) -> Checkpoint<T, S> {
        Checkpoint {
            epoch: self.epochs_done,
            step: self.inner.step,
            seed: self.inner.config.seed,
            objective: self.inner.config.objective,
            history: self.history.clone(),
            model: TrainedModel {
                trellis: self.inner.trellis.clone(),
                model: self.inner.model.clone(),
                assigner: self.inner.assigner.clone(),
            },
        }
    }

    /// Train one epoch. `threads = 1, batch = 1` routes to the serial
    /// [`Trainer::epoch`] (bit-identical to the legacy path, averaging
    /// included); anything else runs the Hogwild worker pool.
    pub fn epoch(&mut self, ds: &Dataset) -> EpochMetrics {
        assert_eq!(
            ds.n_features,
            self.inner.model.n_features(),
            "dataset feature dim {} != model feature dim {} (resumed against a different dataset?)",
            ds.n_features,
            self.inner.model.n_features()
        );
        // A checkpointed model records only bound (label, path) pairs;
        // make sure the label side covers this dataset.
        self.inner.assigner.table.ensure_labels(ds.n_labels);
        let m = if self.n_threads() <= 1 && self.inner.config.batch <= 1 {
            self.inner.epoch(ds)
        } else {
            self.hogwild_epoch_inner(ds)
        };
        self.epochs_done += 1;
        self.history.push(m.clone());
        m
    }

    /// Train for `epochs` epochs; returns per-epoch metrics.
    pub fn fit(&mut self, ds: &Dataset, epochs: usize) -> Vec<EpochMetrics> {
        (0..epochs).map(|_| self.epoch(ds)).collect()
    }

    /// Like [`Self::fit`], writing a checkpoint into `dir` after every
    /// epoch (`epoch-NNNN.ltck`, atomically replaced).
    pub fn fit_with_checkpoints(
        &mut self,
        ds: &Dataset,
        epochs: usize,
        dir: &Path,
    ) -> Result<Vec<EpochMetrics>, String> {
        let mut out = Vec::with_capacity(epochs);
        for _ in 0..epochs {
            out.push(self.epoch(ds));
            self.save_checkpoint_to(dir)?;
        }
        Ok(out)
    }

    /// Write the current state as `dir/epoch-NNNN.ltck` (atomic replace),
    /// serializing straight from the live weights — no model clone, so the
    /// epoch-boundary write costs one output buffer, not 3× the model.
    pub fn save_checkpoint_to(&self, dir: &Path) -> Result<std::path::PathBuf, String> {
        std::fs::create_dir_all(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
        let model_bytes =
            io::serialize_parts(&self.inner.trellis, &self.inner.model, &self.inner.assigner);
        let bytes = io::serialize_checkpoint_with(
            self.epochs_done,
            self.inner.step,
            self.inner.config.seed,
            self.inner.config.objective,
            &self.history,
            &model_bytes,
        );
        let path = io::checkpoint_path(dir, self.epochs_done);
        io::write_atomic(&bytes, &path)?;
        Ok(path)
    }

    /// Always run the Hogwild worker path, regardless of `threads`/`batch`
    /// (test and bench hook: at `threads = 1, batch = 1` with averaging
    /// off, this is bit-identical to the serial path).
    pub fn hogwild_epoch(&mut self, ds: &Dataset) -> EpochMetrics {
        self.inner.assigner.table.ensure_labels(ds.n_labels);
        let m = self.hogwild_epoch_inner(ds);
        self.epochs_done += 1;
        self.history.push(m.clone());
        m
    }

    fn hogwild_epoch_inner(&mut self, ds: &Dataset) -> EpochMetrics {
        let t0 = std::time::Instant::now();
        // Averaging is strictly serial (module docs); the Hogwild path
        // trains raw weights, and once any hogwild epoch has run the
        // average is gone for good (a restarted average over a suffix of
        // the run would be neither the paper's average nor meaningful).
        // The config flag is cleared too, so `config().averaging` always
        // reflects what `into_model` will actually do.
        self.inner.averager = None;
        self.inner.config.averaging = false;
        let n_workers = self.n_threads().max(1);
        let batch = self.inner.config.batch.max(1);
        let shards = shard_epoch(
            ds.n_examples(),
            n_workers,
            self.inner.config.shuffle,
            self.inner.config.seed,
            self.inner.step,
        );
        let step_ctr = AtomicU64::new(self.inner.step);
        let trellis = &self.inner.trellis;
        let config = &self.inner.config;
        let assigner = RwLock::new(&mut self.inner.assigner);
        let weights = SharedWeights::new(&mut self.inner.model);

        let mut merged = EpochMetrics::default();
        std::thread::scope(|scope| {
            let handles: Vec<_> = shards
                .iter()
                .map(|shard| {
                    let weights = &weights;
                    let assigner = &assigner;
                    let step_ctr = &step_ctr;
                    scope.spawn(move || {
                        run_worker(shard, ds, trellis, config, weights, assigner, step_ctr, batch)
                    })
                })
                .collect();
            for h in handles {
                merged.merge(&h.join().expect("hogwild worker panicked"));
            }
        });
        self.inner.step = step_ctr.load(Ordering::Relaxed);
        // The serial engine records its own epochs (it is the threads = 1
        // delegate of `Self::epoch`), so only the Hogwild path folds here.
        super::TrainStats::global().observe_epoch(&merged, t0.elapsed());
        merged
    }

    /// Finalize into a predictor (averaging/L1 exactly as the serial
    /// [`Trainer::into_model`]; Hogwild-trained weights are raw).
    pub fn into_model(self) -> TrainedModel<T, S> {
        self.inner.into_model()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;
    use crate::model::{LinearEdgeModel, WeightStore};
    use crate::util::rng::Rng;

    /// The SharedWeights kernels are bit-identical to the LinearEdgeModel
    /// kernels they mirror (single-threaded, so no lost updates).
    #[test]
    fn shared_kernels_match_model() {
        let mut rng = Rng::new(77);
        let mut a = LinearEdgeModel::new(6, 40);
        let idx: Vec<u32> = vec![1, 7, 13, 22, 39];
        let val: Vec<f32> = idx.iter().map(|_| rng.normal()).collect();
        let x = SparseVec::new(&idx, &val);
        a.update_edges(&[0, 3], &[5], x, 0.7);
        let mut b = a.clone();

        // Scores: plain vs atomic view.
        let want = a.edge_scores_vec(x);
        let shared = SharedWeights::new(&mut b);
        let mut got = Vec::new();
        shared.edge_scores(x, &mut got);
        assert_eq!(want, got);

        // Batch scores: plain vs atomic view.
        let idx2: Vec<u32> = vec![0, 13, 30];
        let val2: Vec<f32> = idx2.iter().map(|_| rng.normal()).collect();
        let x2 = SparseVec::new(&idx2, &val2);
        let rows = [x, x2, x];
        let (mut g1, mut o1, mut g2, mut o2) = (Vec::new(), Vec::new(), Vec::new(), Vec::new());
        a.edge_scores_batch(&rows, &mut g1, &mut o1);
        shared.edge_scores_batch(&rows, &mut g2, &mut o2);
        assert_eq!(o1, o2);

        // Updates: plain vs atomic view.
        shared.update_edges(&[1, 2], &[4], x2, -0.3);
        drop(shared);
        a.update_edges(&[1, 2], &[4], x2, -0.3);
        assert_eq!(a.w, b.w);
        assert_eq!(a.bias, b.bias);
    }

    /// The same parity holds for the hashed backend: the atomic kernels
    /// apply the hash codec exactly like the plain store kernels.
    #[test]
    fn shared_kernels_match_hashed_store() {
        use crate::model::HashedStore;
        let mut a = HashedStore::new(5, 400, 5, 13).unwrap();
        let idx = [2u32, 133, 399];
        let val = [0.5f32, -1.5, 2.0];
        let x = SparseVec::new(&idx, &val);
        a.update_edges(&[0, 4], &[2], x, 0.9);
        let mut b = a.clone();

        let mut want = Vec::new();
        WeightStore::edge_scores(&a, x, &mut crate::model::ScoreScratch::new(), &mut want);
        let shared = SharedWeights::new(&mut b);
        let mut got = Vec::new();
        shared.edge_scores(x, &mut got);
        assert_eq!(want, got);

        shared.update_edges(&[1], &[3], x, 0.25);
        drop(shared);
        a.update_edges(&[1], &[3], x, 0.25);
        assert_eq!(a.w, b.w);
        assert_eq!(a.bias, b.bias);
    }

    /// Smoke: a 3-worker Hogwild epoch trains (loss decreases) and counts
    /// every example exactly once.
    #[test]
    fn hogwild_epoch_counts_every_example() {
        let ds = SyntheticSpec::multiclass(900, 400, 32).seed(91).generate();
        let cfg = TrainConfig { threads: 3, averaging: false, ..TrainConfig::default() };
        let mut tr = ParallelTrainer::new(cfg, ds.n_features, ds.n_labels);
        let m1 = tr.epoch(&ds);
        assert_eq!(m1.examples, 900);
        assert_eq!(tr.global_step(), 900);
        let m2 = tr.epoch(&ds);
        assert_eq!(tr.global_step(), 1800);
        assert!(
            m2.mean_loss() < m1.mean_loss(),
            "loss did not decrease: {} → {}",
            m1.mean_loss(),
            m2.mean_loss()
        );
        assert_eq!(tr.epochs_done(), 2);
        assert_eq!(tr.history().len(), 2);
    }

    /// The mini-batch scoring path (single worker, batch > 1) also trains.
    #[test]
    fn minibatch_path_trains() {
        let ds = SyntheticSpec::multiclass(800, 300, 24).seed(92).generate();
        let cfg = TrainConfig { threads: 1, batch: 16, averaging: false, ..TrainConfig::default() };
        let mut tr = ParallelTrainer::new(cfg, ds.n_features, ds.n_labels);
        let ms = tr.fit(&ds, 3);
        assert_eq!(ms.len(), 3);
        assert!(ms[2].mean_loss() < ms[0].mean_loss());
        let model = tr.into_model();
        let p1 = crate::eval::precision_at_1(&model, &ds);
        assert!(p1 > 0.3, "precision@1 = {p1}");
    }

    /// The hashed backend trains through the full Hogwild pipeline:
    /// multi-worker + mini-batch, loss decreases, memory stays 2^bits.
    #[test]
    fn hashed_hogwild_trains() {
        use crate::model::HashedStore;
        let ds = SyntheticSpec::multiclass(900, 600, 32).seed(93).generate();
        let cfg = TrainConfig {
            threads: 3,
            batch: 8,
            averaging: false,
            hash_bits: 8,
            ..TrainConfig::default()
        };
        let mut tr = ParallelTrainer::<Trellis, HashedStore>::with_topology(
            cfg,
            ds.n_features,
            ds.n_labels,
        )
        .unwrap();
        let m1 = tr.epoch(&ds);
        assert_eq!(m1.examples, 900);
        let m2 = tr.epoch(&ds);
        assert!(m2.mean_loss() < m1.mean_loss());
        let model = tr.into_model();
        assert_eq!(model.model.n_strips(), 256);
        let p1 = crate::eval::precision_at_1(&model, &ds);
        assert!(p1 > 0.2, "hashed hogwild precision@1 = {p1}");
    }
}
