//! The training objective abstraction — what a per-example *target* means
//! and how its loss gradient is applied.
//!
//! Before this module the training stack hard-coded one gold path per
//! example (the multiclass separation loss). [`Objective`] makes the
//! target shape explicit and [`objective_step`] is the **one** shared
//! loss-and-update kernel both engines run — the serial
//! [`super::Trainer::step`] and the Hogwild worker of
//! [`super::ParallelTrainer`] differ only in the weight applier they pass
//! in (plain store update + averager vs. relaxed-atomic shared update).
//!
//! * [`Objective::Multiclass`] — the paper's §5 separation ranking loss on
//!   the single worst (positive, negative) pair. For an example whose
//!   label set happens to be a singleton this executes exactly the
//!   pre-refactor code path: same decode, same float-op order, same single
//!   `update_edges(pos_only, neg_only, x, lr)` call — the bit-identity
//!   invariant pinned by `rust/tests/multilabel_parity.rs`.
//! * [`Objective::Multilabel`] — the union-of-gold-paths margin
//!   ([`crate::loss::union_separation_ws`]): every positive path hinges
//!   against the shared best negative, and each active hinge applies its
//!   symmetric-difference update scaled by `lr / |P|` (per-example
//!   gradient normalization, so an example with 20 tags moves the weights
//!   as far as one with 1). With `plt_weight` each positive's update is
//!   additionally scaled by the logistic link `σ(F(ℓn) − F(ℓp))` — the
//!   conditional probability that the negative outranks that gold path —
//!   the PLT-style conditional weighting of Jasinska et al.: confidently
//!   separated labels contribute vanishing gradient, badly violated ones
//!   full gradient.
//!
//! The objective is part of the training contract, so it is carried in
//! checkpoints ([`crate::model::io::Checkpoint`]) and a resume under a
//! different objective is refused like a seed/width/hash-bits mismatch.

use super::config::TrainConfig;
use super::metrics::EpochMetrics;
use crate::engine::StepScratch;
use crate::graph::Topology;
use crate::loss::{separation_loss_ws, union_separation_ws};

/// Which per-example target shape and loss the trainers optimize.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Objective {
    /// One gold path per example (paper §5 separation ranking loss). A
    /// multi-label row contributes only its single worst positive, exactly
    /// as the pre-refactor trainer did.
    #[default]
    Multiclass,
    /// Union-of-gold-paths margin over the full label set, 1/|P|
    /// gradient normalization; `plt_weight` additionally scales each
    /// positive's update by its conditional misranking probability.
    Multilabel {
        /// PLT-style conditional-probability weighting (Jasinska et al.).
        plt_weight: bool,
    },
}

impl Objective {
    /// Stable wire tag (checkpoint format v2).
    pub fn tag(self) -> u32 {
        match self {
            Objective::Multiclass => 0,
            Objective::Multilabel { plt_weight: false } => 1,
            Objective::Multilabel { plt_weight: true } => 2,
        }
    }

    /// Inverse of [`Self::tag`] (checkpoint reader).
    pub fn from_tag(tag: u32) -> Result<Objective, String> {
        match tag {
            0 => Ok(Objective::Multiclass),
            1 => Ok(Objective::Multilabel { plt_weight: false }),
            2 => Ok(Objective::Multilabel { plt_weight: true }),
            t => Err(format!("unknown objective tag {t}")),
        }
    }

    pub fn is_multilabel(self) -> bool {
        matches!(self, Objective::Multilabel { .. })
    }
}

impl std::fmt::Display for Objective {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Objective::Multiclass => write!(f, "multiclass"),
            Objective::Multilabel { plt_weight: false } => write!(f, "multilabel"),
            Objective::Multilabel { plt_weight: true } => write!(f, "multilabel+plt"),
        }
    }
}

/// One objective step on a scored example: compute the loss for positive
/// paths `pos` over edge scores `h`, fold it into `metrics`, and hand each
/// active hinge's symmetric-difference update to `apply(pos_only_edges,
/// neg_only_edges, eta)`. Returns the loss.
///
/// This is the single kernel both training engines execute; the engines
/// differ only in `apply` — the serial trainer updates its store and
/// averager, the Hogwild worker updates the shared atomic view. `t` is the
/// global SGD step driving the lr schedule; an example with an empty label
/// set contributes nothing (not counted as an example).
#[allow(clippy::too_many_arguments)]
pub fn objective_step<T: Topology, F: FnMut(&[u32], &[u32], f32)>(
    trellis: &T,
    config: &TrainConfig,
    t: u64,
    h: &[f32],
    pos: &[u64],
    scratch: &mut StepScratch,
    metrics: &mut EpochMetrics,
    apply: &mut F,
) -> f32 {
    if pos.is_empty() {
        return 0.0;
    }
    match config.objective {
        Objective::Multiclass => {
            let mut loss_val = 0.0;
            if let Some(out) =
                separation_loss_ws(trellis, h, pos, &mut scratch.ws, &mut scratch.paths)
            {
                metrics.examples += 1;
                metrics.loss_sum += out.loss as f64;
                loss_val = out.loss;
                if out.loss > 0.0 {
                    metrics.active_hinge += 1;
                    let lr = config.lr_at(t);
                    symmetric_difference(trellis, out.pos, out.neg, scratch);
                    apply(&scratch.pos_only, &scratch.neg_only, lr);
                }
            }
            loss_val
        }
        Objective::Multilabel { plt_weight } => {
            let Some(out) = union_separation_ws(
                trellis,
                h,
                pos,
                &mut scratch.ws,
                &mut scratch.paths,
                &mut scratch.pos_margins,
            ) else {
                return 0.0;
            };
            metrics.examples += 1;
            metrics.loss_sum += out.loss as f64;
            if out.loss > 0.0 {
                metrics.active_hinge += 1;
                let lr = config.lr_at(t);
                // Per-example gradient normalization: the |P| per-positive
                // hinges share one example's learning-rate budget.
                let inv = 1.0 / pos.len() as f32;
                // The margins list is detached while each active hinge's
                // symmetric difference is resolved into the same scratch.
                let margins = std::mem::take(&mut scratch.pos_margins);
                for &(p, margin) in &margins {
                    if margin <= 0.0 {
                        continue;
                    }
                    // σ(neg − pos) = σ(margin − 1): the conditional
                    // probability (logistic link) that the best negative
                    // outranks this gold path.
                    let w = if plt_weight { 1.0 / (1.0 + (1.0 - margin).exp()) } else { 1.0 };
                    symmetric_difference(trellis, p, out.neg, scratch);
                    apply(&scratch.pos_only, &scratch.neg_only, lr * (w * inv));
                }
                scratch.pos_margins = margins;
            }
            out.loss
        }
    }
}

/// Resolve the (positive, negative) path pair into the scratch's
/// symmetric-difference edge sets (`pos_only` / `neg_only`) — the only
/// edges an update touches (Fig. 2 semantics), with no allocation.
#[inline]
fn symmetric_difference<T: Topology>(trellis: &T, pos: u64, neg: u64, scratch: &mut StepScratch) {
    trellis.edges_of_label_into(pos, &mut scratch.pos_edges);
    trellis.edges_of_label_into(neg, &mut scratch.neg_edges);
    let (pos_edges, neg_edges) = (&scratch.pos_edges, &scratch.neg_edges);
    scratch.pos_only.clear();
    scratch.neg_only.clear();
    scratch.pos_only.extend(pos_edges.iter().filter(|e| !neg_edges.contains(e)));
    scratch.neg_only.extend(neg_edges.iter().filter(|e| !pos_edges.contains(e)));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Trellis;
    use crate::util::rng::Rng;

    #[test]
    fn tag_roundtrip_and_display() {
        for o in [
            Objective::Multiclass,
            Objective::Multilabel { plt_weight: false },
            Objective::Multilabel { plt_weight: true },
        ] {
            assert_eq!(Objective::from_tag(o.tag()).unwrap(), o);
        }
        assert!(Objective::from_tag(3).is_err());
        assert_eq!(Objective::Multiclass.to_string(), "multiclass");
        assert_eq!(Objective::Multilabel { plt_weight: false }.to_string(), "multilabel");
        assert_eq!(Objective::Multilabel { plt_weight: true }.to_string(), "multilabel+plt");
        assert!(!Objective::Multiclass.is_multilabel());
        assert!(Objective::Multilabel { plt_weight: true }.is_multilabel());
    }

    /// On a singleton target, the multiclass and multilabel kernels emit
    /// the SAME update stream: same edge sets, same eta, bitwise.
    #[test]
    fn singleton_update_streams_identical() {
        let mut rng = Rng::new(271);
        let t = Trellis::new(33);
        let mc_cfg = TrainConfig::default();
        let ml_cfg = TrainConfig {
            objective: Objective::Multilabel { plt_weight: false },
            ..mc_cfg.clone()
        };
        for step in 1..40u64 {
            let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
            let y = rng.below(33);
            let mut updates = [Vec::new(), Vec::new()];
            for (ui, cfg) in [&mc_cfg, &ml_cfg].into_iter().enumerate() {
                let mut scratch = StepScratch::default();
                let mut metrics = EpochMetrics::default();
                let loss = objective_step(
                    &t,
                    cfg,
                    step,
                    &h,
                    &[y],
                    &mut scratch,
                    &mut metrics,
                    &mut |po: &[u32], no: &[u32], eta: f32| {
                        updates[ui].push((po.to_vec(), no.to_vec(), eta.to_bits()));
                    },
                );
                assert_eq!(metrics.examples, 1);
                assert_eq!(metrics.active_hinge, u64::from(loss > 0.0));
            }
            assert_eq!(updates[0], updates[1], "step {step}");
        }
    }

    /// Multilabel: per-positive updates share the best negative and are
    /// 1/|P|-normalized; empty label sets contribute nothing.
    #[test]
    fn multilabel_normalizes_and_skips_empty() {
        let t = Trellis::new(22);
        let cfg = TrainConfig {
            objective: Objective::Multilabel { plt_weight: false },
            ..TrainConfig::default()
        };
        let h = vec![0.0f32; Topology::num_edges(&t)];
        let mut scratch = StepScratch::default();
        let mut metrics = EpochMetrics::default();
        // All-zero scores: every margin is exactly 1.0 (active).
        let mut etas = Vec::new();
        let loss = objective_step(
            &t,
            &cfg,
            1,
            &h,
            &[2, 9, 17],
            &mut scratch,
            &mut metrics,
            &mut |_: &[u32], _: &[u32], eta: f32| etas.push(eta),
        );
        assert_eq!(loss, 1.0);
        assert_eq!(etas.len(), 3);
        let lr3 = cfg.lr_at(1) * (1.0f32 / 3.0);
        assert!(etas.iter().all(|&e| e == lr3), "{etas:?} vs {lr3}");

        let mut metrics2 = EpochMetrics::default();
        let loss2 = objective_step(
            &t,
            &cfg,
            2,
            &h,
            &[],
            &mut scratch,
            &mut metrics2,
            &mut |_: &[u32], _: &[u32], _: f32| panic!("empty target must not update"),
        );
        assert_eq!(loss2, 0.0);
        assert_eq!(metrics2.examples, 0);
    }

    /// PLT weighting scales each eta by σ(margin − 1) ∈ (0, 1): a badly
    /// violated positive gets a larger step than a barely violated one.
    #[test]
    fn plt_weighting_orders_etas_by_violation() {
        let t = Trellis::new(22);
        let cfg = TrainConfig {
            objective: Objective::Multilabel { plt_weight: true },
            ..TrainConfig::default()
        };
        // Boost one positive's path so its margin is smaller than the
        // other's, leaving scores otherwise flat. +0.25 per edge keeps the
        // boosted path's hinge active: the closest negative differs in
        // exactly 2 edges, so its margin is 1 − 2·0.25 = 0.5 > 0 (a 0.5
        // boost would land exactly on the hinge boundary).
        let mut h = vec![0.0f32; Topology::num_edges(&t)];
        for e in crate::graph::codec::edges_of_label(&t, 4) {
            h[e as usize] += 0.25;
        }
        let mut scratch = StepScratch::default();
        let mut metrics = EpochMetrics::default();
        let mut etas = Vec::new();
        objective_step(
            &t,
            &cfg,
            1,
            &h,
            &[4, 9],
            &mut scratch,
            &mut metrics,
            &mut |_: &[u32], _: &[u32], eta: f32| etas.push(eta),
        );
        assert_eq!(etas.len(), 2, "both hinges active");
        let unweighted = cfg.lr_at(1) * 0.5;
        // Path 4 (smaller violation) gets the smaller weighted step.
        assert!(etas[0] < etas[1], "{etas:?}");
        assert!(etas.iter().all(|&e| e > 0.0 && e < unweighted), "{etas:?} vs {unweighted}");
    }
}
