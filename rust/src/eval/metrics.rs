//! The standard extreme-classification metric suite beyond precision@1:
//! precision@k for several k, nDCG@k, and label-space coverage — the
//! metrics the XMLC repository reports for every method, so results from
//! this library are directly comparable.

use super::precision::Predictor;
use crate::data::Dataset;

/// Full metric sweep at the given cutoffs.
#[derive(Clone, Debug)]
pub struct XcMetrics {
    pub cutoffs: Vec<usize>,
    /// precision@k per cutoff.
    pub precision: Vec<f64>,
    /// nDCG@k per cutoff.
    pub ndcg: Vec<f64>,
    /// Fraction of distinct labels ever predicted at the largest cutoff —
    /// a long-tail health diagnostic (degenerate head-only models score
    /// low here).
    pub coverage: f64,
}

/// Compute precision@k and nDCG@k for each cutoff in one pass.
pub fn evaluate<P: Predictor + ?Sized>(model: &P, ds: &Dataset, cutoffs: &[usize]) -> XcMetrics {
    assert!(!cutoffs.is_empty());
    let kmax = *cutoffs.iter().max().unwrap();
    let n = ds.n_examples();
    let mut precision = vec![0.0f64; cutoffs.len()];
    let mut ndcg = vec![0.0f64; cutoffs.len()];
    let mut predicted = std::collections::HashSet::new();

    // Precompute discount table 1/log2(i+2).
    let disc: Vec<f64> = (0..kmax).map(|i| 1.0 / ((i + 2) as f64).log2()).collect();

    for i in 0..n {
        let truth = ds.labels_of(i);
        if truth.is_empty() {
            continue;
        }
        let top = model.topk(ds.row(i), kmax);
        for &l in top.iter().map(|(l, _)| l) {
            predicted.insert(l);
        }
        for (ci, &k) in cutoffs.iter().enumerate() {
            let hits = top.iter().take(k).filter(|(l, _)| truth.contains(l)).count();
            precision[ci] += hits as f64 / k as f64;
            // nDCG@k: DCG over the ranked list / ideal DCG.
            let dcg: f64 = top
                .iter()
                .take(k)
                .enumerate()
                .filter(|(_, (l, _))| truth.contains(l))
                .map(|(r, _)| disc[r])
                .sum();
            let ideal: f64 = disc.iter().take(k.min(truth.len())).sum();
            ndcg[ci] += if ideal > 0.0 { dcg / ideal } else { 0.0 };
        }
    }
    let denom = n.max(1) as f64;
    for v in precision.iter_mut().chain(ndcg.iter_mut()) {
        *v /= denom;
    }
    XcMetrics {
        cutoffs: cutoffs.to_vec(),
        precision,
        ndcg,
        coverage: predicted.len() as f64 / ds.n_labels.max(1) as f64,
    }
}

impl std::fmt::Display for XcMetrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (i, &k) in self.cutoffs.iter().enumerate() {
            write!(f, "P@{k}={:.4} nDCG@{k}={:.4}  ", self.precision[i], self.ndcg[i])?;
        }
        write!(f, "coverage={:.3}", self.coverage)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;
    use crate::sparse::SparseVec;

    /// Oracle-at-rank-r predictor: puts a true label at rank r.
    struct AtRank(usize, std::cell::Cell<usize>);
    impl Predictor for AtRank {
        fn topk(&self, _x: SparseVec, k: usize) -> Vec<(u32, f32)> {
            let i = self.1.get();
            self.1.set(i + 1);
            // Fill with distinct wrong labels (value 1000+r), truth at rank self.0.
            (0..k)
                .map(|r| {
                    if r == self.0 {
                        (0u32, 1.0) // label 0 is always true below
                    } else {
                        (1000 + r as u32, 0.5)
                    }
                })
                .collect()
        }
        fn model_bytes(&self) -> usize {
            0
        }
        fn name(&self) -> &str {
            "at-rank"
        }
    }

    fn constant_label_dataset(n: usize) -> Dataset {
        let mut f = crate::sparse::CsrMatrix::new(4);
        let mut labels = Vec::new();
        for _ in 0..n {
            f.push_row(&[0], &[1.0]);
            labels.push(vec![0u32]);
        }
        Dataset {
            name: "const".into(),
            features: f,
            labels,
            n_features: 4,
            n_labels: 2000,
            multiclass: true,
        }
    }

    #[test]
    fn rank_position_affects_ndcg_not_precision() {
        let ds = constant_label_dataset(50);
        let top = evaluate(&AtRank(0, Default::default()), &ds, &[5]);
        let third = evaluate(&AtRank(2, Default::default()), &ds, &[5]);
        // P@5 identical (one hit in 5 either way)...
        assert!((top.precision[0] - third.precision[0]).abs() < 1e-9);
        assert!((top.precision[0] - 0.2).abs() < 1e-9);
        // ... but nDCG penalizes the lower rank.
        assert!(top.ndcg[0] > third.ndcg[0]);
        assert!((top.ndcg[0] - 1.0).abs() < 1e-9, "truth at rank0, |truth|=1 → perfect nDCG");
    }

    #[test]
    fn multiple_cutoffs_monotone_precision_for_single_label() {
        let ds = constant_label_dataset(20);
        let m = evaluate(&AtRank(0, Default::default()), &ds, &[1, 3, 5]);
        // With exactly one relevant label, P@k decays like 1/k.
        assert!((m.precision[0] - 1.0).abs() < 1e-9);
        assert!((m.precision[1] - 1.0 / 3.0).abs() < 1e-9);
        assert!((m.precision[2] - 0.2).abs() < 1e-9);
    }

    #[test]
    fn coverage_counts_distinct_predictions() {
        let ds = constant_label_dataset(10);
        let m = evaluate(&AtRank(1, Default::default()), &ds, &[3]);
        // Predicts labels {0, 1000, 1002} every time → 3 / 2000.
        assert!((m.coverage - 3.0 / 2000.0).abs() < 1e-9);
    }

    #[test]
    fn end_to_end_on_trained_model() {
        let ds = SyntheticSpec::multiclass(800, 500, 32).seed(62).generate();
        let (train, test) = crate::data::split::random_split(&ds, 0.25, 1);
        let mut tr = crate::train::Trainer::new(
            crate::train::TrainConfig::default(),
            ds.n_features,
            ds.n_labels,
        );
        tr.fit(&train, 4);
        let model = tr.into_model();
        let m = evaluate(&model, &test, &[1, 5]);
        assert!(m.precision[0] > 0.7, "{m}");
        assert!(m.ndcg[1] >= m.precision[0] - 1e-9, "nDCG@5 ≥ P@1 for single-label data");
        assert!(m.coverage > 0.5, "{m}");
        assert!(!format!("{m}").is_empty());
    }
}
