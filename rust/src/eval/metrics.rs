//! The standard extreme-classification metric suite beyond precision@1:
//! precision@k, nDCG@k, recall@k, propensity-scored precision@k and
//! label-space coverage — the metrics the XMLC repository reports for
//! every method, so results from this library are directly comparable.
//!
//! PSP@k follows Jain et al. (KDD 2016): a label with training frequency
//! `N_l` gets inverse propensity `1/p_l = 1 + C·(N_l + B)^(−A)` with
//! A = 0.55, B = 1.5, C = (ln N − 1)·(B + 1)^A, and PSP@k is the
//! ratio-of-sums `Σ_i psDCG_i / Σ_i ideal_i` so that rare (high
//! inverse-propensity) labels dominate the score — the metric the
//! multilabel sweep uses to show head-only baselines for what they are.

use super::precision::Predictor;
use crate::data::Dataset;
use crate::engine::PredictScratch;

/// Full metric sweep at the given cutoffs.
#[derive(Clone, Debug)]
pub struct XcMetrics {
    pub cutoffs: Vec<usize>,
    /// precision@k per cutoff.
    pub precision: Vec<f64>,
    /// nDCG@k per cutoff.
    pub ndcg: Vec<f64>,
    /// recall@k per cutoff: |top_k ∩ Y| / |Y| — the multilabel headline
    /// (for singleton truth it equals P@1 at k = 1 and saturates above).
    pub recall: Vec<f64>,
    /// Propensity-scored precision@k per cutoff, present when the caller
    /// supplied train-set [`Propensities`].
    pub psp: Option<Vec<f64>>,
    /// Fraction of distinct labels ever predicted at the largest cutoff —
    /// a long-tail health diagnostic (degenerate head-only models score
    /// low here).
    pub coverage: f64,
}

/// Per-label inverse propensities (Jain et al. 2016), estimated from the
/// *training* split's label frequencies.
#[derive(Clone, Debug)]
pub struct Propensities {
    /// `1/p_l` per label; ≥ 1, larger for rarer labels.
    pub inv: Vec<f64>,
}

impl Propensities {
    /// Estimate from a training set with the canonical XMLC constants
    /// A = 0.55, B = 1.5 (the values the repository uses for every dataset
    /// except Amazon/Wikipedia variants).
    pub fn from_train(ds: &Dataset) -> Propensities {
        Propensities::with_constants(ds, 0.55, 1.5)
    }

    /// Estimate with explicit A/B constants.
    pub fn with_constants(ds: &Dataset, a: f64, b: f64) -> Propensities {
        let n = ds.n_examples().max(1) as f64;
        let c = (n.ln() - 1.0) * (b + 1.0).powf(a);
        let inv = ds
            .label_frequencies()
            .iter()
            .map(|&nl| 1.0 + c * (nl as f64 + b).powf(-a))
            .collect();
        Propensities { inv }
    }

    /// `1/p_l` for a label (1.0 — the uninformative weight — when the
    /// label id is outside the training label space).
    #[inline]
    pub fn inv_of(&self, l: u32) -> f64 {
        self.inv.get(l as usize).copied().unwrap_or(1.0)
    }
}

/// Compute precision@k, nDCG@k and recall@k for each cutoff in one pass
/// (PSP@k omitted; see [`evaluate_with`]).
pub fn evaluate<P: Predictor + ?Sized>(model: &P, ds: &Dataset, cutoffs: &[usize]) -> XcMetrics {
    evaluate_with(model, ds, cutoffs, None)
}

/// Compute precision@k, nDCG@k, recall@k — and PSP@k when train-set
/// `propensities` are supplied — for each cutoff in one pass.
///
/// Predictions run through the engine path (`topk_into` with one reused
/// [`PredictScratch`] and output buffer — what the serving workers
/// execute); `topk_into` is contractually bit-identical to `topk`, so the
/// numbers match the allocating path exactly. Examples with an empty
/// label set are skipped but the denominator stays `n` (the repository's
/// convention), except PSP@k, which is a ratio of sums over the non-empty
/// rows only.
pub fn evaluate_with<P: Predictor + ?Sized>(
    model: &P,
    ds: &Dataset,
    cutoffs: &[usize],
    propensities: Option<&Propensities>,
) -> XcMetrics {
    assert!(!cutoffs.is_empty());
    let kmax = *cutoffs.iter().max().unwrap();
    let n = ds.n_examples();
    let mut precision = vec![0.0f64; cutoffs.len()];
    let mut ndcg = vec![0.0f64; cutoffs.len()];
    let mut recall = vec![0.0f64; cutoffs.len()];
    // PSP ratio-of-sums accumulators (numerator, denominator) per cutoff.
    let mut psp_num = vec![0.0f64; cutoffs.len()];
    let mut psp_den = vec![0.0f64; cutoffs.len()];
    let mut predicted = std::collections::HashSet::new();
    let mut scratch = PredictScratch::new();
    let mut top: Vec<(u32, f32)> = Vec::new();
    let mut truth_inv: Vec<f64> = Vec::new();

    // Precompute discount table 1/log2(i+2).
    let disc: Vec<f64> = (0..kmax).map(|i| 1.0 / ((i + 2) as f64).log2()).collect();

    for i in 0..n {
        let truth = ds.labels_of(i);
        if truth.is_empty() {
            continue;
        }
        model.topk_into(ds.row(i), kmax, &mut scratch, &mut top);
        for &l in top.iter().map(|(l, _)| l) {
            predicted.insert(l);
        }
        if let Some(p) = propensities {
            // k largest inverse propensities among the true labels — the
            // best any ranking could collect (the PSP@k ideal).
            truth_inv.clear();
            truth_inv.extend(truth.iter().map(|&l| p.inv_of(l)));
            truth_inv.sort_unstable_by(|x, y| y.total_cmp(x));
        }
        for (ci, &k) in cutoffs.iter().enumerate() {
            let hits = top.iter().take(k).filter(|(l, _)| truth.contains(l)).count();
            precision[ci] += hits as f64 / k as f64;
            recall[ci] += hits as f64 / truth.len() as f64;
            // nDCG@k: DCG over the ranked list / ideal DCG.
            let dcg: f64 = top
                .iter()
                .take(k)
                .enumerate()
                .filter(|(_, (l, _))| truth.contains(l))
                .map(|(r, _)| disc[r])
                .sum();
            let ideal: f64 = disc.iter().take(k.min(truth.len())).sum();
            ndcg[ci] += if ideal > 0.0 { dcg / ideal } else { 0.0 };
            if let Some(p) = propensities {
                psp_num[ci] += top
                    .iter()
                    .take(k)
                    .filter(|(l, _)| truth.contains(l))
                    .map(|&(l, _)| p.inv_of(l))
                    .sum::<f64>()
                    / k as f64;
                psp_den[ci] += truth_inv.iter().take(k).sum::<f64>() / k as f64;
            }
        }
    }
    let denom = n.max(1) as f64;
    for v in precision.iter_mut().chain(ndcg.iter_mut()).chain(recall.iter_mut()) {
        *v /= denom;
    }
    let psp = propensities.map(|_| {
        psp_num
            .iter()
            .zip(&psp_den)
            .map(|(&num, &den)| if den > 0.0 { num / den } else { 0.0 })
            .collect()
    });
    XcMetrics {
        cutoffs: cutoffs.to_vec(),
        precision,
        ndcg,
        recall,
        psp,
        coverage: predicted.len() as f64 / ds.n_labels.max(1) as f64,
    }
}

impl std::fmt::Display for XcMetrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (i, &k) in self.cutoffs.iter().enumerate() {
            write!(
                f,
                "P@{k}={:.4} nDCG@{k}={:.4} R@{k}={:.4}",
                self.precision[i], self.ndcg[i], self.recall[i]
            )?;
            if let Some(psp) = &self.psp {
                write!(f, " PSP@{k}={:.4}", psp[i])?;
            }
            write!(f, "  ")?;
        }
        write!(f, "coverage={:.3}", self.coverage)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;
    use crate::sparse::SparseVec;

    /// Oracle-at-rank-r predictor: puts a true label at rank r.
    struct AtRank(usize, std::cell::Cell<usize>);
    impl Predictor for AtRank {
        fn topk(&self, _x: SparseVec, k: usize) -> Vec<(u32, f32)> {
            let i = self.1.get();
            self.1.set(i + 1);
            // Fill with distinct wrong labels (value 1000+r), truth at rank self.0.
            (0..k)
                .map(|r| {
                    if r == self.0 {
                        (0u32, 1.0) // label 0 is always true below
                    } else {
                        (1000 + r as u32, 0.5)
                    }
                })
                .collect()
        }
        fn model_bytes(&self) -> usize {
            0
        }
        fn name(&self) -> &str {
            "at-rank"
        }
    }

    fn constant_label_dataset(n: usize) -> Dataset {
        let mut f = crate::sparse::CsrMatrix::new(4);
        let mut labels = Vec::new();
        for _ in 0..n {
            f.push_row(&[0], &[1.0]);
            labels.push(vec![0u32]);
        }
        Dataset {
            name: "const".into(),
            features: f,
            labels,
            n_features: 4,
            n_labels: 2000,
            multiclass: true,
        }
    }

    #[test]
    fn rank_position_affects_ndcg_not_precision() {
        let ds = constant_label_dataset(50);
        let top = evaluate(&AtRank(0, Default::default()), &ds, &[5]);
        let third = evaluate(&AtRank(2, Default::default()), &ds, &[5]);
        // P@5 identical (one hit in 5 either way)...
        assert!((top.precision[0] - third.precision[0]).abs() < 1e-9);
        assert!((top.precision[0] - 0.2).abs() < 1e-9);
        // ... but nDCG penalizes the lower rank.
        assert!(top.ndcg[0] > third.ndcg[0]);
        assert!((top.ndcg[0] - 1.0).abs() < 1e-9, "truth at rank0, |truth|=1 → perfect nDCG");
    }

    #[test]
    fn multiple_cutoffs_monotone_precision_for_single_label() {
        let ds = constant_label_dataset(20);
        let m = evaluate(&AtRank(0, Default::default()), &ds, &[1, 3, 5]);
        // With exactly one relevant label, P@k decays like 1/k.
        assert!((m.precision[0] - 1.0).abs() < 1e-9);
        assert!((m.precision[1] - 1.0 / 3.0).abs() < 1e-9);
        assert!((m.precision[2] - 0.2).abs() < 1e-9);
    }

    #[test]
    fn coverage_counts_distinct_predictions() {
        let ds = constant_label_dataset(10);
        let m = evaluate(&AtRank(1, Default::default()), &ds, &[3]);
        // Predicts labels {0, 1000, 1002} every time → 3 / 2000.
        assert!((m.coverage - 3.0 / 2000.0).abs() < 1e-9);
    }

    /// Fixed-ranking predictor: always returns the same (label, score)
    /// list, truncated to k.
    struct Fixed(Vec<(u32, f32)>);
    impl Predictor for Fixed {
        fn topk(&self, _x: SparseVec, k: usize) -> Vec<(u32, f32)> {
            self.0.iter().take(k).copied().collect()
        }
        fn model_bytes(&self) -> usize {
            0
        }
        fn name(&self) -> &str {
            "fixed"
        }
    }

    /// A dataset with explicit per-row label sets (1 feature per row so
    /// `row()` works).
    fn labeled_dataset(labels: Vec<Vec<u32>>, n_labels: usize) -> Dataset {
        let mut f = crate::sparse::CsrMatrix::new(4);
        for _ in 0..labels.len() {
            f.push_row(&[0], &[1.0]);
        }
        Dataset {
            name: "labeled".into(),
            features: f,
            labels,
            n_features: 4,
            n_labels,
            multiclass: false,
        }
    }

    /// nDCG@k against a fully hand-computed oracle: truth {0, 5},
    /// ranking [5, 7, 0].
    #[test]
    fn ndcg_matches_hand_computation() {
        let ds = labeled_dataset(vec![vec![0, 5]], 10);
        let model = Fixed(vec![(5, 0.9), (7, 0.5), (0, 0.1)]);
        let m = evaluate(&model, &ds, &[1, 3]);
        // k=1: hit at rank 0, |truth|=2 → DCG = 1, ideal = 1 → nDCG@1 = 1.
        assert!((m.ndcg[0] - 1.0).abs() < 1e-12, "{}", m.ndcg[0]);
        // k=3: hits at ranks 0 and 2 → DCG = 1/log2(2) + 1/log2(4) = 1.5;
        // ideal = 1/log2(2) + 1/log2(3).
        let ideal = 1.0 + 1.0 / 3.0f64.log2();
        assert!((m.ndcg[1] - 1.5 / ideal).abs() < 1e-12, "{}", m.ndcg[1]);
        // recall: 1/2 at k=1, 2/2 at k=3; precision: 1/1 and 2/3.
        assert!((m.recall[0] - 0.5).abs() < 1e-12);
        assert!((m.recall[1] - 1.0).abs() < 1e-12);
        assert!((m.precision[0] - 1.0).abs() < 1e-12);
        assert!((m.precision[1] - 2.0 / 3.0).abs() < 1e-12);
    }

    /// The Jain et al. inverse-propensity formula, pinned numerically, and
    /// PSP@1 as a hand-computed ratio of sums: a head-only predictor
    /// scores below its plain P@1 because the tail label it misses weighs
    /// more.
    #[test]
    fn psp_matches_hand_computation() {
        // Train set: label 0 six times, label 1 twice (label 2 unseen).
        let mut rows = vec![vec![0u32]; 6];
        rows.extend(vec![vec![1u32]; 2]);
        let train = labeled_dataset(rows, 3);
        let p = Propensities::from_train(&train);
        // 1/p_l = 1 + C (N_l + B)^(−A), C = (ln 8 − 1)(B+1)^A.
        let c = (8.0f64.ln() - 1.0) * 2.5f64.powf(0.55);
        assert!((p.inv[0] - (1.0 + c * 7.5f64.powf(-0.55))).abs() < 1e-12);
        assert!((p.inv[1] - (1.0 + c * 3.5f64.powf(-0.55))).abs() < 1e-12);
        // Unseen label: N_l = 0 → the largest inverse propensity.
        assert!((p.inv[2] - (1.0 + c * 1.5f64.powf(-0.55))).abs() < 1e-12);
        assert!(p.inv[1] > p.inv[0], "rarer label ⇒ larger weight");
        assert!((p.inv_of(99) - 1.0).abs() < 1e-12, "out-of-space label is uninformative");

        // Eval set: one head-truth row, one tail-truth row; the predictor
        // always answers with the head label.
        let test = labeled_dataset(vec![vec![0], vec![1]], 3);
        let model = Fixed(vec![(0, 1.0)]);
        let m = evaluate_with(&model, &test, &[1], Some(&p));
        let psp = m.psp.as_ref().expect("propensities supplied")[0];
        // Ratio of sums: numerator collects inv_0 on the hit row only;
        // the ideal collects each row's own label weight.
        let want = p.inv[0] / (p.inv[0] + p.inv[1]);
        assert!((psp - want).abs() < 1e-12, "{psp} vs {want}");
        assert!(psp < m.precision[0], "PSP penalizes the head-only predictor: {m}");
        assert!(format!("{m}").contains("PSP@1="), "{m}");
    }

    /// k beyond both the truth size and the model's label repertoire:
    /// short prediction lists and k > |truth| must not panic or overcount.
    #[test]
    fn k_exceeding_labels_and_truth_is_safe() {
        let ds = labeled_dataset(vec![vec![0, 1]], 4);
        // The model only knows 3 labels — returns 3 entries at k = 10.
        let model = Fixed(vec![(2, 0.9), (0, 0.6), (1, 0.3)]);
        let p = Propensities::from_train(&ds);
        let m = evaluate_with(&model, &ds, &[10], Some(&p));
        assert!((m.precision[0] - 2.0 / 10.0).abs() < 1e-12, "hits / k, not / returned");
        assert!((m.recall[0] - 1.0).abs() < 1e-12, "both truths found");
        // Ideal DCG truncates at |truth| = 2, so nDCG stays ≤ 1 exactly.
        assert!(m.ndcg[0] > 0.0 && m.ndcg[0] <= 1.0 + 1e-12);
        // PSP ideal truncates at |truth| too: perfect-coverage ranking
        // collects every truth weight the ideal does → PSP@10 = 1.
        assert!((m.psp.unwrap()[0] - 1.0).abs() < 1e-12);
    }

    /// Rows with an empty label set are skipped but keep the averaged
    /// denominators at n (repository convention); PSP, a ratio of sums,
    /// ignores them entirely.
    #[test]
    fn empty_label_rows_skip_but_count_in_denominator() {
        let ds = labeled_dataset(vec![vec![0], vec![]], 2);
        let model = Fixed(vec![(0, 1.0)]);
        let p = Propensities::from_train(&ds);
        let m = evaluate_with(&model, &ds, &[1], Some(&p));
        assert!((m.precision[0] - 0.5).abs() < 1e-12, "1 hit / n=2");
        assert!((m.recall[0] - 0.5).abs() < 1e-12);
        assert!((m.psp.unwrap()[0] - 1.0).abs() < 1e-12, "ratio over non-empty rows only");
    }

    #[test]
    fn end_to_end_on_trained_model() {
        let ds = SyntheticSpec::multiclass(800, 500, 32).seed(62).generate();
        let (train, test) = crate::data::split::random_split(&ds, 0.25, 1);
        let mut tr = crate::train::Trainer::new(
            crate::train::TrainConfig::default(),
            ds.n_features,
            ds.n_labels,
        );
        tr.fit(&train, 4);
        let model = tr.into_model();
        let m = evaluate(&model, &test, &[1, 5]);
        assert!(m.precision[0] > 0.7, "{m}");
        assert!(m.ndcg[1] >= m.precision[0] - 1e-9, "nDCG@5 ≥ P@1 for single-label data");
        assert!(m.coverage > 0.5, "{m}");
        assert!(!format!("{m}").is_empty());
    }
}
