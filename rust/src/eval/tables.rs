//! Paper-table harnesses: regenerate Tables 1, 2 and 3 on the synthetic
//! analogs (DESIGN.md §4 experiment index). Shared by the CLI
//! (`ltls tables`), `examples/paper_tables.rs`, and the bench targets.

use super::precision::{precision_at_1, Predictor};
use super::report::{Measurement, Report};
use super::timing::time_predictions;
use crate::baselines::fastxml::FastXmlConfig;
use crate::baselines::leml::LemlConfig;
use crate::baselines::{FastXml, Leml, LomTree, NaiveTopK, OracleTopK};
use crate::data::datasets::{multiclass_analogs, multilabel_analogs, AnalogSpec};
use crate::data::Dataset;
use crate::train::{TrainConfig, Trainer};
use crate::util::timer::Timer;

/// Train LTLS on an analog with the paper's per-dataset settings
/// (L1 soft-thresholding on the LSHTC1/Dmoz analogs, §6).
pub fn train_ltls(analog: &AnalogSpec, train: &Dataset, epochs: usize) -> crate::train::TrainedModel {
    let l1 = match analog.paper_name {
        "LSHTC1" | "Dmoz" => 0.01, // the paper's † rows
        _ => 0.0,
    };
    let cfg = TrainConfig { l1_lambda: l1, ..TrainConfig::default() };
    let mut tr = Trainer::new(cfg, train.n_features, train.n_labels);
    tr.fit(train, epochs);
    tr.into_model()
}

fn measure<P: Predictor + ?Sized>(
    report: &mut Report,
    dataset: &str,
    model: &P,
    test: &Dataset,
    train_time_s: f64,
) {
    let p1 = precision_at_1(model, test);
    let t = time_predictions(model, test, 1);
    report.push(Measurement {
        dataset: dataset.to_string(),
        method: model.name().to_string(),
        precision_at_1: p1,
        predict_time_s: t.total_s,
        model_mb: model.model_bytes() as f64 / 1e6,
        train_time_s,
    });
}

/// Table 1: multiclass — LTLS vs LOMtree vs FastXML.
pub fn table1(scale: f64, epochs: usize, seed: u64) -> Report {
    let mut report = Report::new("Table 1 — multiclass (synthetic analogs)");
    for analog in multiclass_analogs() {
        let (train, test) = analog.generate(scale, seed);
        eprintln!("[table1] {} n={} C={}", analog.paper_name, train.n_examples(), train.n_labels);

        let t = Timer::new();
        let ltls = train_ltls(&analog, &train, epochs);
        measure(&mut report, analog.paper_name, &ltls, &test, t.elapsed_s());

        let t = Timer::new();
        let lom = LomTree::train(&train, epochs.max(2), 0.3, seed ^ 1);
        measure(&mut report, analog.paper_name, &lom, &test, t.elapsed_s());

        let t = Timer::new();
        let fx = FastXml::train(&train, &FastXmlConfig { seed: seed ^ 2, ..Default::default() });
        measure(&mut report, analog.paper_name, &fx, &test, t.elapsed_s());
    }
    report
}

/// Table 2: multilabel — LTLS vs LEML vs FastXML.
pub fn table2(scale: f64, epochs: usize, seed: u64) -> Report {
    let mut report = Report::new("Table 2 — multilabel (synthetic analogs)");
    for analog in multilabel_analogs() {
        let (train, test) = analog.generate(scale, seed);
        eprintln!("[table2] {} n={} C={}", analog.paper_name, train.n_examples(), train.n_labels);

        let t = Timer::new();
        let ltls = train_ltls(&analog, &train, epochs);
        measure(&mut report, analog.paper_name, &ltls, &test, t.elapsed_s());

        // LEML rank scaled down for very large C (decode is O(C·r)).
        let rank = if train.n_labels > 100_000 { 16 } else { 32 };
        let t = Timer::new();
        let leml = Leml::train(
            &train,
            &LemlConfig { rank, epochs: epochs.min(5), seed: seed ^ 3, ..Default::default() },
        );
        measure(&mut report, analog.paper_name, &leml, &test, t.elapsed_s());

        let t = Timer::new();
        let fx = FastXml::train(&train, &FastXmlConfig { seed: seed ^ 4, ..Default::default() });
        measure(&mut report, analog.paper_name, &fx, &test, t.elapsed_s());
    }
    report
}

/// One Table 3 row: (dataset, #edges, oracle, naive LR, LTLS).
#[derive(Clone, Debug)]
pub struct Table3Row {
    pub dataset: String,
    pub n_edges: usize,
    pub oracle: f64,
    pub naive_lr: f64,
    pub ltls: f64,
}

/// Table 3: the naive top-#edges baseline vs LTLS on all nine datasets.
pub fn table3(scale: f64, epochs: usize, seed: u64) -> Vec<Table3Row> {
    let mut rows = Vec::new();
    for analog in crate::data::datasets::all_analogs() {
        let (train, test) = analog.generate(scale, seed);
        let e = crate::graph::Trellis::new(train.n_labels as u64).num_edges();
        eprintln!("[table3] {} E={}", analog.paper_name, e);

        let oracle = OracleTopK::from_train(&train, e).precision_at_1(&test);
        let naive = NaiveTopK::train(&train, e, epochs.min(3), &[1e-5, 1e-4, 1e-3]);
        let naive_p1 = precision_at_1(&naive, &test);
        let ltls = train_ltls(&analog, &train, epochs);
        let ltls_p1 = precision_at_1(&ltls, &test);
        rows.push(Table3Row {
            dataset: analog.paper_name.to_string(),
            n_edges: e,
            oracle,
            naive_lr: naive_p1,
            ltls: ltls_p1,
        });
    }
    rows
}

/// Render Table 3 in the paper's layout.
pub fn render_table3(rows: &[Table3Row]) -> String {
    let mut s = String::from(
        "=== Table 3 — naive top-#edges baseline vs LTLS ===\n",
    );
    s.push_str(&format!(
        "{:<16}{:>8}{:>10}{:>10}{:>10}\n",
        "dataset", "#edges", "oracle", "LR", "LTLS"
    ));
    for r in rows {
        s.push_str(&format!(
            "{:<16}{:>8}{:>10.4}{:>10.4}{:>10.4}\n",
            r.dataset, r.n_edges, r.oracle, r.naive_lr, r.ltls
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Smoke: a miniature Table 1 run produces all cells.
    #[test]
    fn table1_smoke() {
        let r = table1(0.01, 1, 9);
        // 5 datasets × 3 methods.
        assert_eq!(r.rows.len(), 15);
        let text = r.render();
        assert!(text.contains("sector") && text.contains("imageNet"));
        assert!(text.contains("LTLS") && text.contains("LOMtree") && text.contains("FastXML"));
    }

    #[test]
    fn table3_smoke_subset() {
        // Full table3 at tiny scale is still slow in debug; run two analogs.
        let analogs: Vec<_> = crate::data::datasets::all_analogs()
            .into_iter()
            .filter(|a| a.paper_name == "sector" || a.paper_name == "bibtex")
            .collect();
        for analog in analogs {
            let (train, test) = analog.generate(0.02, 3);
            let e = crate::graph::Trellis::new(train.n_labels as u64).num_edges();
            let oracle = OracleTopK::from_train(&train, e).precision_at_1(&test);
            assert!((0.0..=1.0).contains(&oracle));
        }
    }
}
