//! Evaluation harness: precision@k, the multilabel metric suite (nDCG@k,
//! recall@k, propensity-scored P@k), prediction timing, model-size
//! accounting, and the table formatting used to regenerate the paper's
//! Tables 1–3.

pub mod metrics;
pub mod precision;
pub mod report;
pub mod tables;
pub mod timing;

pub use metrics::{evaluate, evaluate_with, Propensities, XcMetrics};
pub use precision::{precision_at_1, precision_at_k, Predictor};
pub use timing::{time_epoch, time_predictions};
