//! Table formatting for the paper-reproduction harnesses: renders rows in
//! the same shape as the paper's Tables 1–3 and serializes them as JSON
//! for EXPERIMENTS.md.

use crate::util::json::Json;

/// One (dataset × method) measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub dataset: String,
    pub method: String,
    pub precision_at_1: f64,
    pub predict_time_s: f64,
    pub model_mb: f64,
    pub train_time_s: f64,
}

/// A collection of measurements renderable as a table.
#[derive(Clone, Debug, Default)]
pub struct Report {
    pub title: String,
    pub rows: Vec<Measurement>,
}

impl Report {
    pub fn new(title: &str) -> Self {
        Report { title: title.to_string(), rows: Vec::new() }
    }

    pub fn push(&mut self, m: Measurement) {
        self.rows.push(m);
    }

    /// Methods in first-appearance order.
    fn methods(&self) -> Vec<String> {
        let mut ms = Vec::new();
        for r in &self.rows {
            if !ms.contains(&r.method) {
                ms.push(r.method.clone());
            }
        }
        ms
    }

    /// Datasets in first-appearance order.
    fn datasets(&self) -> Vec<String> {
        let mut ds = Vec::new();
        for r in &self.rows {
            if !ds.contains(&r.dataset) {
                ds.push(r.dataset.clone());
            }
        }
        ds
    }

    fn find(&self, dataset: &str, method: &str) -> Option<&Measurement> {
        self.rows.iter().find(|r| r.dataset == dataset && r.method == method)
    }

    /// Render in the paper's layout: per dataset, one block of
    /// precision@1 / prediction time / model size per method column.
    pub fn render(&self) -> String {
        let methods = self.methods();
        let mut out = format!("=== {} ===\n", self.title);
        out.push_str(&format!("{:<16}{:<22}", "dataset", "metric"));
        for m in &methods {
            out.push_str(&format!("{m:>14}"));
        }
        out.push('\n');
        for d in self.datasets() {
            for (metric, get) in [
                ("precision@1", 0usize),
                ("prediction time [s]", 1),
                ("model size [M]", 2),
                ("train time [s]", 3),
            ] {
                out.push_str(&format!("{d:<16}{metric:<22}"));
                for m in &methods {
                    match self.find(&d, m) {
                        Some(r) => {
                            let v = match get {
                                0 => r.precision_at_1,
                                1 => r.predict_time_s,
                                2 => r.model_mb,
                                _ => r.train_time_s,
                            };
                            let s = match get {
                                0 => format!("{v:.4}"),
                                _ => format!("{v:.2}"),
                            };
                            out.push_str(&format!("{s:>14}"));
                        }
                        None => out.push_str(&format!("{:>14}", "-")),
                    }
                }
                out.push('\n');
            }
            out.push('\n');
        }
        out
    }

    /// JSON for machine consumption (EXPERIMENTS.md assembly).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::from(self.title.as_str())),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("dataset", Json::from(r.dataset.as_str())),
                                ("method", Json::from(r.method.as_str())),
                                ("p1", Json::Num(r.precision_at_1)),
                                ("predict_s", Json::Num(r.predict_time_s)),
                                ("model_mb", Json::Num(r.model_mb)),
                                ("train_s", Json::Num(r.train_time_s)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(d: &str, meth: &str, p: f64) -> Measurement {
        Measurement {
            dataset: d.into(),
            method: meth.into(),
            precision_at_1: p,
            predict_time_s: 0.5,
            model_mb: 1.5,
            train_time_s: 2.0,
        }
    }

    #[test]
    fn render_contains_all_cells() {
        let mut r = Report::new("Table 1");
        r.push(m("sector", "LTLS", 0.88));
        r.push(m("sector", "LOMtree", 0.82));
        r.push(m("aloi", "LTLS", 0.82));
        let text = r.render();
        assert!(text.contains("Table 1"));
        assert!(text.contains("sector"));
        assert!(text.contains("LOMtree"));
        assert!(text.contains("0.8800"));
        assert!(text.contains("precision@1"));
        // aloi has no LOMtree → a dash cell exists.
        assert!(text.contains('-'));
    }

    #[test]
    fn json_roundtrips() {
        let mut r = Report::new("T");
        r.push(m("d", "x", 0.5));
        let j = r.to_json().dump();
        let parsed = crate::util::json::Json::parse(&j).unwrap();
        assert_eq!(parsed.get("title").unwrap().as_str(), Some("T"));
        assert_eq!(parsed.get("rows").unwrap().as_arr().unwrap().len(), 1);
    }
}
