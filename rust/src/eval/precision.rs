//! Precision@k — the paper's headline metric.
//!
//! precision@k = (1/n) Σ_i |top_k(x_i) ∩ Y_i| / k.
//! For multiclass with k=1 this is plain accuracy.

use crate::data::Dataset;
use crate::engine::PredictScratch;
use crate::sparse::SparseVec;

/// Anything that can rank labels for an example. Implemented by LTLS and
/// by every baseline so the evaluation and table harnesses are generic.
pub trait Predictor {
    /// Top-k (label, score) pairs, descending score.
    fn topk(&self, x: SparseVec, k: usize) -> Vec<(u32, f32)>;

    /// Top-k into a caller-owned buffer, reusing `scratch` — the
    /// inference engine's hot path (see [`crate::engine`]). Must produce
    /// exactly what [`Self::topk`] produces. The default delegates to
    /// `topk`; implementations with a real zero-allocation path (LTLS,
    /// the baselines) override it.
    fn topk_into(
        &self,
        x: SparseVec,
        k: usize,
        scratch: &mut PredictScratch,
        out: &mut Vec<(u32, f32)>,
    ) {
        let _ = scratch;
        out.clear();
        out.extend(self.topk(x, k));
    }

    /// Model size in bytes (for the tables' "model size" column).
    fn model_bytes(&self) -> usize;

    /// Display name for reports.
    fn name(&self) -> &str;
}

impl<T: crate::graph::Topology, S: crate::model::WeightStore> Predictor
    for crate::train::TrainedModel<T, S>
{
    fn topk(&self, x: SparseVec, k: usize) -> Vec<(u32, f32)> {
        self.predict_topk(x, k)
    }
    fn topk_into(
        &self,
        x: SparseVec,
        k: usize,
        scratch: &mut PredictScratch,
        out: &mut Vec<(u32, f32)>,
    ) {
        self.predict_topk_into(x, k, scratch, out)
    }
    fn model_bytes(&self) -> usize {
        self.bytes()
    }
    fn name(&self) -> &str {
        "LTLS"
    }
}

/// precision@k over a dataset.
///
/// Routed through the engine path (`topk_into` with one reused
/// [`PredictScratch`] and output buffer) — the exact code the serving
/// workers run — so the headline metric measures what production
/// executes, not a parallel allocating path. `topk_into` is contractually
/// bit-identical to `topk` (pinned by `engine_parity.rs` and the parity
/// test below), so the numbers are unchanged.
pub fn precision_at_k<P: Predictor + ?Sized>(model: &P, ds: &Dataset, k: usize) -> f64 {
    if ds.n_examples() == 0 {
        return 0.0;
    }
    let mut scratch = PredictScratch::new();
    let mut top: Vec<(u32, f32)> = Vec::new();
    let mut total = 0.0f64;
    for i in 0..ds.n_examples() {
        let labels = ds.labels_of(i);
        model.topk_into(ds.row(i), k, &mut scratch, &mut top);
        let hits = top.iter().filter(|(l, _)| labels.contains(l)).count();
        total += hits as f64 / k as f64;
    }
    total / ds.n_examples() as f64
}

/// precision@1 shorthand.
pub fn precision_at_1<P: Predictor + ?Sized>(model: &P, ds: &Dataset) -> f64 {
    precision_at_k(model, ds, 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;

    /// An oracle predictor that always returns the true label scores 1.0.
    struct Oracle<'a>(&'a Dataset, std::cell::Cell<usize>);

    impl Predictor for Oracle<'_> {
        fn topk(&self, _x: SparseVec, k: usize) -> Vec<(u32, f32)> {
            let i = self.1.get();
            self.1.set(i + 1);
            self.0.labels_of(i).iter().take(k).map(|&l| (l, 1.0)).collect()
        }
        fn model_bytes(&self) -> usize {
            0
        }
        fn name(&self) -> &str {
            "oracle"
        }
    }

    #[test]
    fn oracle_gets_perfect_p1() {
        let ds = SyntheticSpec::multiclass(50, 20, 8).seed(1).generate();
        let o = Oracle(&ds, std::cell::Cell::new(0));
        assert!((precision_at_1(&o, &ds) - 1.0).abs() < 1e-12);
    }

    /// A constant predictor scores the base rate of its label.
    struct Constant(u32);
    impl Predictor for Constant {
        fn topk(&self, _x: SparseVec, _k: usize) -> Vec<(u32, f32)> {
            vec![(self.0, 1.0)]
        }
        fn model_bytes(&self) -> usize {
            4
        }
        fn name(&self) -> &str {
            "const"
        }
    }

    #[test]
    fn constant_predictor_matches_base_rate() {
        let ds = SyntheticSpec::multiclass(400, 30, 4).seed(2).generate();
        let freq = ds.label_frequencies();
        let best = (0..4).max_by_key(|&l| freq[l as usize]).unwrap();
        let p1 = precision_at_1(&Constant(best), &ds);
        let want = freq[best as usize] as f64 / 400.0;
        assert!((p1 - want).abs() < 1e-9);
    }

    /// The engine-path metric is bit-identical to the old allocating
    /// path: recompute precision@k with per-example `model.topk` (fresh
    /// allocations, the pre-fix code) and require exact equality on a
    /// real trained LTLS model at several k.
    #[test]
    fn engine_path_matches_allocating_path_exactly() {
        use crate::train::{TrainConfig, Trainer};
        let ds = SyntheticSpec::multiclass(500, 300, 24).seed(9).generate();
        let mut tr = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
        tr.fit(&ds, 3);
        let model = tr.into_model();
        for k in [1usize, 3, 5] {
            let engine = precision_at_k(&model, &ds, k);
            let mut total = 0.0f64;
            for i in 0..ds.n_examples() {
                let labels = ds.labels_of(i);
                let top = model.topk(ds.row(i), k); // old allocating path
                total += top.iter().filter(|(l, _)| labels.contains(l)).count() as f64 / k as f64;
            }
            let allocating = total / ds.n_examples() as f64;
            assert_eq!(engine.to_bits(), allocating.to_bits(), "k={k}");
        }
    }

    #[test]
    fn empty_dataset_is_zero() {
        let ds = crate::data::Dataset {
            n_features: 1,
            n_labels: 1,
            features: crate::sparse::CsrMatrix::new(1),
            ..Default::default()
        };
        assert_eq!(precision_at_1(&Constant(0), &ds), 0.0);
    }
}
