//! Prediction-time measurement (the tables' "prediction time [s]" column:
//! total wall time to predict the whole test set).

use super::precision::Predictor;
use crate::data::Dataset;
use crate::engine::PredictScratch;
use crate::util::timer::Timer;

/// Result of timing a full test-set prediction sweep.
#[derive(Clone, Debug)]
pub struct PredictionTiming {
    pub total_s: f64,
    pub per_example_us: f64,
    pub n: usize,
}

/// Predict every test example once and time the sweep. Runs through the
/// engine (`topk_into` with one reused [`PredictScratch`] and output
/// buffer), so what is measured is the decode itself, not allocator
/// traffic — the number the tables' "prediction time" column reports.
pub fn time_predictions<P: Predictor + ?Sized>(model: &P, ds: &Dataset, k: usize) -> PredictionTiming {
    let t = Timer::new();
    let mut scratch = PredictScratch::new();
    let mut out = Vec::new();
    let mut sink = 0usize;
    for i in 0..ds.n_examples() {
        model.topk_into(ds.row(i), k, &mut scratch, &mut out);
        sink += out.len();
    }
    std::hint::black_box(sink);
    let total_s = t.elapsed_s();
    PredictionTiming {
        total_s,
        per_example_us: total_s * 1e6 / ds.n_examples().max(1) as f64,
        n: ds.n_examples(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;
    use crate::sparse::SparseVec;

    struct Noop;
    impl Predictor for Noop {
        fn topk(&self, _x: SparseVec, _k: usize) -> Vec<(u32, f32)> {
            vec![(0, 0.0)]
        }
        fn model_bytes(&self) -> usize {
            0
        }
        fn name(&self) -> &str {
            "noop"
        }
    }

    #[test]
    fn timing_counts_examples() {
        let ds = SyntheticSpec::multiclass(100, 10, 4).seed(1).generate();
        let t = time_predictions(&Noop, &ds, 1);
        assert_eq!(t.n, 100);
        assert!(t.total_s >= 0.0);
        assert!(t.per_example_us >= 0.0);
    }
}
