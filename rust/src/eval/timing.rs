//! Prediction-time measurement (the tables' "prediction time `[s]`" column:
//! total wall time to predict the whole test set), plus the
//! training-epoch throughput harness used by the parallel-training bench
//! and the CI perf gate.

use super::precision::Predictor;
use crate::data::Dataset;
use crate::engine::PredictScratch;
use crate::train::{EpochMetrics, ParallelTrainer};
use crate::util::timer::Timer;

/// Result of timing a full test-set prediction sweep.
#[derive(Clone, Debug)]
pub struct PredictionTiming {
    pub total_s: f64,
    pub per_example_us: f64,
    pub n: usize,
}

/// Predict every test example once and time the sweep. Runs through the
/// engine (`topk_into` with one reused [`PredictScratch`] and output
/// buffer), so what is measured is the decode itself, not allocator
/// traffic — the number the tables' "prediction time" column reports.
pub fn time_predictions<P: Predictor + ?Sized>(model: &P, ds: &Dataset, k: usize) -> PredictionTiming {
    let t = Timer::new();
    let mut scratch = PredictScratch::new();
    let mut out = Vec::new();
    let mut sink = 0usize;
    for i in 0..ds.n_examples() {
        model.topk_into(ds.row(i), k, &mut scratch, &mut out);
        sink += out.len();
    }
    std::hint::black_box(sink);
    let total_s = t.elapsed_s();
    PredictionTiming {
        total_s,
        per_example_us: total_s * 1e6 / ds.n_examples().max(1) as f64,
        n: ds.n_examples(),
    }
}

/// Result of timing one training epoch.
#[derive(Clone, Debug)]
pub struct EpochTiming {
    pub total_s: f64,
    pub examples_per_s: f64,
    pub metrics: EpochMetrics,
}

/// Run one training epoch through the (possibly parallel) trainer and time
/// it. The trainer's configuration decides the execution engine — serial,
/// Hogwild multi-worker, or mini-batch — and the topology decides the
/// width, so this one harness measures them all comparably
/// (`benches/train_parallel.rs`, `benches/width_sweep.rs`).
pub fn time_epoch<T: crate::graph::Topology, S: crate::model::TrainableStore>(
    tr: &mut ParallelTrainer<T, S>,
    ds: &Dataset,
) -> EpochTiming {
    let t = Timer::new();
    let metrics = tr.epoch(ds);
    let total_s = t.elapsed_s();
    EpochTiming {
        total_s,
        examples_per_s: ds.n_examples() as f64 / total_s.max(1e-9),
        metrics,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;
    use crate::sparse::SparseVec;
    use crate::train::TrainConfig;

    struct Noop;
    impl Predictor for Noop {
        fn topk(&self, _x: SparseVec, _k: usize) -> Vec<(u32, f32)> {
            vec![(0, 0.0)]
        }
        fn model_bytes(&self) -> usize {
            0
        }
        fn name(&self) -> &str {
            "noop"
        }
    }

    #[test]
    fn timing_counts_examples() {
        let ds = SyntheticSpec::multiclass(100, 10, 4).seed(1).generate();
        let t = time_predictions(&Noop, &ds, 1);
        assert_eq!(t.n, 100);
        assert!(t.total_s >= 0.0);
        assert!(t.per_example_us >= 0.0);
    }

    #[test]
    fn epoch_timing_reports_throughput() {
        let ds = SyntheticSpec::multiclass(200, 50, 8).seed(2).generate();
        let cfg = TrainConfig { threads: 2, averaging: false, ..TrainConfig::default() };
        let mut tr = ParallelTrainer::new(cfg, ds.n_features, ds.n_labels);
        let t = time_epoch(&mut tr, &ds);
        assert_eq!(t.metrics.examples, 200);
        assert!(t.examples_per_s > 0.0);
        assert!(t.total_s >= 0.0);
    }
}
