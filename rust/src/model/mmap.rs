//! Zero-copy weight buffers: heap-owned, or borrowed from a memory-mapped
//! model file.
//!
//! The v3 model format (see [`super::io`]) pads its weight section to a
//! 64-byte file offset, so a page-aligned `mmap` of the whole file yields a
//! correctly-aligned `&[f32]` / `&[i8]` view of the weights with **no copy
//! and no allocation proportional to the model**: `ltls serve --mmap`
//! starts after parsing only the (small) header, bias and label↔path
//! table, and the kernel pages weights in on demand and shares them across
//! processes serving the same file.
//!
//! [`F32Buf`]/[`I8Buf`] are the storage type every weight store uses for
//! its big block: `Owned` (a plain `Vec`, the training representation) or
//! `Mapped` (an offset view into an [`MmapRegion`], serve-only —
//! `DerefMut` panics). Byte order: files are little-endian, and the mapped
//! view reinterprets bytes in place, so mapped loading is gated to
//! little-endian hosts (every supported target; the loader errors rather
//! than misreads elsewhere).

use std::path::Path;
use std::sync::Arc;

/// A read-only memory-mapped file (unix `mmap(PROT_READ, MAP_PRIVATE)`;
/// on non-unix targets a heap read with the same interface, so callers
/// stay portable and only lose the zero-copy property).
pub struct MmapRegion {
    ptr: *const u8,
    len: usize,
    /// Non-unix fallback storage; `ptr` points into it when `Some`.
    _fallback: Option<Vec<u8>>,
}

// SAFETY: the mapping is read-only for its whole lifetime (PROT_READ and
// no `&mut` API), so shared access from any thread is safe.
unsafe impl Send for MmapRegion {}
unsafe impl Sync for MmapRegion {}

#[cfg(unix)]
mod sys {
    use std::ffi::c_void;
    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
    }
    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;
}

impl MmapRegion {
    /// Map `path` read-only. The file descriptor is closed on return; the
    /// mapping stays valid until drop.
    #[cfg(unix)]
    pub fn map(path: &Path) -> Result<MmapRegion, String> {
        use std::os::unix::io::AsRawFd;
        if cfg!(target_endian = "big") {
            return Err("memory-mapped model loading requires a little-endian host".into());
        }
        let f = std::fs::File::open(path).map_err(|e| format!("{}: {e}", path.display()))?;
        let len = f.metadata().map_err(|e| format!("{}: {e}", path.display()))?.len() as usize;
        if len == 0 {
            return Err(format!("{}: empty file", path.display()));
        }
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                f.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 {
            return Err(format!("{}: mmap failed", path.display()));
        }
        Ok(MmapRegion { ptr: ptr as *const u8, len, _fallback: None })
    }

    /// Portable fallback: read the file onto the heap (same interface, no
    /// zero-copy property).
    #[cfg(not(unix))]
    pub fn map(path: &Path) -> Result<MmapRegion, String> {
        let bytes = std::fs::read(path).map_err(|e| format!("{}: {e}", path.display()))?;
        if bytes.is_empty() {
            return Err(format!("{}: empty file", path.display()));
        }
        let ptr = bytes.as_ptr();
        let len = bytes.len();
        Ok(MmapRegion { ptr, len, _fallback: Some(bytes) })
    }

    /// The whole mapped file.
    pub fn bytes(&self) -> &[u8] {
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl Drop for MmapRegion {
    fn drop(&mut self) {
        #[cfg(unix)]
        if self._fallback.is_none() {
            unsafe {
                sys::munmap(self.ptr as *mut std::ffi::c_void, self.len);
            }
        }
    }
}

impl std::fmt::Debug for MmapRegion {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "MmapRegion({} bytes)", self.len)
    }
}

/// Declare an owned-or-mapped weight buffer deref-ing to `[$elem]`.
macro_rules! weight_buf {
    ($(#[$doc:meta])* $name:ident, $elem:ty) => {
        $(#[$doc])*
        #[derive(Clone)]
        pub enum $name {
            Owned(Vec<$elem>),
            Mapped {
                region: Arc<MmapRegion>,
                /// Byte offset of the element block inside the region.
                offset: usize,
                /// Element (not byte) count.
                len: usize,
            },
        }

        impl $name {
            /// Borrow `len` elements at byte `offset` of `region`.
            /// Validates bounds and element alignment.
            pub fn mapped(
                region: Arc<MmapRegion>,
                offset: usize,
                len: usize,
            ) -> Result<$name, String> {
                let bytes = len
                    .checked_mul(std::mem::size_of::<$elem>())
                    .and_then(|b| b.checked_add(offset))
                    .ok_or("weight section size overflows")?;
                if bytes > region.len() {
                    return Err(format!(
                        "weight section [{offset}..{bytes}) exceeds mapped file ({} bytes)",
                        region.len()
                    ));
                }
                let addr = region.bytes().as_ptr() as usize + offset;
                if addr % std::mem::align_of::<$elem>() != 0 {
                    return Err(format!(
                        "weight section at byte {offset} is not {}-byte aligned",
                        std::mem::align_of::<$elem>()
                    ));
                }
                Ok($name::Mapped { region, offset, len })
            }

            /// True when the elements borrow a mapped file region.
            pub fn is_mapped(&self) -> bool {
                matches!(self, $name::Mapped { .. })
            }

            /// Mutable element view; panics on mapped buffers (mapped
            /// stores are serve-only by construction).
            pub fn as_mut_slice(&mut self) -> &mut [$elem] {
                match self {
                    $name::Owned(v) => v.as_mut_slice(),
                    $name::Mapped { .. } => {
                        panic!("memory-mapped weights are read-only (serve-only store)")
                    }
                }
            }
        }

        impl std::ops::Deref for $name {
            type Target = [$elem];
            #[inline]
            fn deref(&self) -> &[$elem] {
                match self {
                    $name::Owned(v) => v.as_slice(),
                    $name::Mapped { region, offset, len } => unsafe {
                        // SAFETY: bounds and alignment checked in `mapped`;
                        // the region is immutable and outlives the borrow
                        // via the Arc.
                        std::slice::from_raw_parts(
                            region.bytes().as_ptr().add(*offset) as *const $elem,
                            *len,
                        )
                    },
                }
            }
        }

        impl std::ops::DerefMut for $name {
            #[inline]
            fn deref_mut(&mut self) -> &mut [$elem] {
                self.as_mut_slice()
            }
        }

        impl From<Vec<$elem>> for $name {
            fn from(v: Vec<$elem>) -> $name {
                $name::Owned(v)
            }
        }

        impl<'a> IntoIterator for &'a $name {
            type Item = &'a $elem;
            type IntoIter = std::slice::Iter<'a, $elem>;
            fn into_iter(self) -> Self::IntoIter {
                self.iter()
            }
        }

        impl<'a> IntoIterator for &'a mut $name {
            type Item = &'a mut $elem;
            type IntoIter = std::slice::IterMut<'a, $elem>;
            fn into_iter(self) -> Self::IntoIter {
                self.as_mut_slice().iter_mut()
            }
        }

        impl PartialEq for $name {
            fn eq(&self, other: &$name) -> bool {
                self[..] == other[..]
            }
        }

        impl std::fmt::Debug for $name {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                write!(
                    f,
                    "{}([{} x {}]{})",
                    stringify!($name),
                    self.len(),
                    stringify!($elem),
                    if self.is_mapped() { ", mapped" } else { "" }
                )
            }
        }
    };
}

weight_buf!(
    /// The f32 weight block of a dense or hashed store.
    F32Buf,
    f32
);
weight_buf!(
    /// The i8 quantized weight block of a [`super::quant::Q8Store`].
    I8Buf,
    i8
);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owned_buf_derefs_and_mutates() {
        let mut b = F32Buf::from(vec![1.0f32, 2.0, 3.0]);
        assert_eq!(b.len(), 3);
        assert!(!b.is_mapped());
        b[1] = 5.0;
        assert_eq!(&b[..], &[1.0, 5.0, 3.0]);
        assert_eq!(b, F32Buf::from(vec![1.0, 5.0, 3.0]));
    }

    #[test]
    fn mapped_buf_reads_file_bytes() {
        let path = std::env::temp_dir().join(format!("ltls_mmap_test_{}", std::process::id()));
        let vals = [1.5f32, -2.25, 0.0, 42.0];
        let mut bytes = vec![0u8; 8]; // 8-byte prefix, keeps f32 alignment
        for v in vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        let region = Arc::new(MmapRegion::map(&path).unwrap());
        assert_eq!(region.len(), bytes.len());
        assert_eq!(region.bytes(), &bytes[..]);
        let buf = F32Buf::mapped(region.clone(), 8, 4).unwrap();
        assert!(buf.is_mapped());
        assert_eq!(&buf[..], &vals[..]);
        // Clones share the region.
        let c = buf.clone();
        assert_eq!(&c[..], &vals[..]);
        // Out-of-bounds and misaligned views are rejected.
        assert!(F32Buf::mapped(region.clone(), 8, 5).is_err());
        assert!(F32Buf::mapped(region.clone(), 7, 4).is_err());
        // i8 views have no alignment constraint.
        let ib = I8Buf::mapped(region.clone(), 1, 3).unwrap();
        assert_eq!(ib.len(), 3);
        drop(ib);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    #[should_panic(expected = "read-only")]
    fn mapped_buf_rejects_mutation() {
        let path = std::env::temp_dir().join(format!("ltls_mmap_mut_{}", std::process::id()));
        std::fs::write(&path, 1.0f32.to_le_bytes()).unwrap();
        let region = Arc::new(MmapRegion::map(&path).unwrap());
        let mut buf = F32Buf::mapped(region, 0, 1).unwrap();
        std::fs::remove_file(&path).ok();
        buf.as_mut_slice()[0] = 2.0;
    }

    #[test]
    fn map_missing_and_empty_files_error() {
        assert!(MmapRegion::map(Path::new("/nonexistent/ltls_model")).is_err());
        let path = std::env::temp_dir().join(format!("ltls_mmap_empty_{}", std::process::id()));
        std::fs::write(&path, b"").unwrap();
        assert!(MmapRegion::map(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
