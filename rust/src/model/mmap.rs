//! Zero-copy weight buffers: heap-owned, or borrowed from a memory-mapped
//! model file.
//!
//! The v3 model format (see [`super::io`]) pads its weight section to a
//! 64-byte file offset, so a page-aligned `mmap` of the whole file yields a
//! correctly-aligned `&[f32]` / `&[i8]` view of the weights with **no copy
//! and no allocation proportional to the model**: `ltls serve --mmap`
//! starts after parsing only the (small) header, bias and label↔path
//! table, and the kernel pages weights in on demand and shares them across
//! processes serving the same file.
//!
//! [`F32Buf`]/[`I8Buf`] are the storage type every weight store uses for
//! its big block: `Owned` (heap storage, 64-byte aligned via
//! [`AlignedBuf`] so the kernels see the same alignment as a mapped
//! weight section — the training representation) or
//! `Mapped` (an offset view into an [`MmapRegion`], serve-only —
//! `DerefMut` panics). Byte order: files are little-endian, and the mapped
//! view reinterprets bytes in place, so mapped loading is gated to
//! little-endian hosts (every supported target; the loader errors rather
//! than misreads elsewhere).

use std::path::Path;
use std::sync::Arc;

/// A read-only memory-mapped file (unix `mmap(PROT_READ, MAP_PRIVATE)`;
/// on non-unix targets a heap read with the same interface, so callers
/// stay portable and only lose the zero-copy property).
pub struct MmapRegion {
    ptr: *const u8,
    len: usize,
    /// Non-unix fallback storage; `ptr` points into it when `Some`.
    _fallback: Option<Vec<u8>>,
}

// SAFETY: the mapping is read-only for its whole lifetime (PROT_READ and
// no `&mut` API), so shared access from any thread is safe.
unsafe impl Send for MmapRegion {}
unsafe impl Sync for MmapRegion {}

#[cfg(unix)]
mod sys {
    use std::ffi::c_void;
    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
    }
    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;
}

impl MmapRegion {
    /// Map `path` read-only. The file descriptor is closed on return; the
    /// mapping stays valid until drop.
    #[cfg(unix)]
    pub fn map(path: &Path) -> Result<MmapRegion, String> {
        use std::os::unix::io::AsRawFd;
        if cfg!(target_endian = "big") {
            return Err("memory-mapped model loading requires a little-endian host".into());
        }
        let f = std::fs::File::open(path).map_err(|e| format!("{}: {e}", path.display()))?;
        let len = f.metadata().map_err(|e| format!("{}: {e}", path.display()))?.len() as usize;
        if len == 0 {
            return Err(format!("{}: empty file", path.display()));
        }
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                f.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 {
            return Err(format!("{}: mmap failed", path.display()));
        }
        Ok(MmapRegion { ptr: ptr as *const u8, len, _fallback: None })
    }

    /// Portable fallback: read the file onto the heap (same interface, no
    /// zero-copy property).
    #[cfg(not(unix))]
    pub fn map(path: &Path) -> Result<MmapRegion, String> {
        let bytes = std::fs::read(path).map_err(|e| format!("{}: {e}", path.display()))?;
        if bytes.is_empty() {
            return Err(format!("{}: empty file", path.display()));
        }
        let ptr = bytes.as_ptr();
        let len = bytes.len();
        Ok(MmapRegion { ptr, len, _fallback: Some(bytes) })
    }

    /// The whole mapped file.
    pub fn bytes(&self) -> &[u8] {
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl Drop for MmapRegion {
    fn drop(&mut self) {
        #[cfg(unix)]
        if self._fallback.is_none() {
            unsafe {
                sys::munmap(self.ptr as *mut std::ffi::c_void, self.len);
            }
        }
    }
}

impl std::fmt::Debug for MmapRegion {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "MmapRegion({} bytes)", self.len)
    }
}

/// One cache line — the allocation unit of [`AlignedBuf`], so heap-owned
/// weight blocks start on a 64-byte boundary exactly like the v3 file
/// format's mmap path.
#[repr(C, align(64))]
#[derive(Clone, Copy)]
// The payload is only ever read through `AlignedBuf`'s pointer casts,
// which dead-code analysis cannot see.
struct CacheLine(#[allow(dead_code)] [u8; 64]);

/// A heap buffer of plain-old-data elements backed by 64-byte-aligned
/// cache-line storage.
///
/// The SIMD strip sweeps ([`crate::kernel`]) use unaligned loads for
/// correctness, but aligned, cache-line-granular strips avoid split-line
/// loads and make the heap (`--model`) and mmap (`--mmap`) serving paths
/// behave identically; this type gives every `Owned` [`F32Buf`]/[`I8Buf`]
/// the same 64-byte guarantee the mapped weight section already has.
pub struct AlignedBuf<T: Copy> {
    lines: Vec<CacheLine>,
    len: usize,
    _marker: std::marker::PhantomData<T>,
}

impl<T: Copy> AlignedBuf<T> {
    /// Copy `src` into fresh 64-byte-aligned storage (tail bytes of the
    /// last line are zeroed so the buffer is fully initialized).
    pub fn from_slice(src: &[T]) -> AlignedBuf<T> {
        debug_assert!(std::mem::align_of::<T>() <= 64);
        let bytes = std::mem::size_of_val(src);
        let n_lines = bytes.div_ceil(64);
        let mut lines = vec![CacheLine([0u8; 64]); n_lines];
        // SAFETY: `lines` owns at least `bytes` initialized bytes, `src`
        // provides exactly `bytes`, and the regions cannot overlap (fresh
        // allocation). `T: Copy` has no drop glue.
        unsafe {
            std::ptr::copy_nonoverlapping(
                src.as_ptr() as *const u8,
                lines.as_mut_ptr() as *mut u8,
                bytes,
            );
        }
        AlignedBuf { lines, len: src.len(), _marker: std::marker::PhantomData }
    }

    /// Element view. For empty buffers the dangling `Vec` pointer is still
    /// 64-byte aligned (dangling pointers are aligned to the element type).
    #[inline]
    pub fn as_slice(&self) -> &[T] {
        // SAFETY: storage holds `len * size_of::<T>()` initialized bytes
        // at 64-byte alignment (≥ align_of::<T>()), and `T: Copy` accepts
        // any initialized bit pattern written by `from_slice`.
        unsafe { std::slice::from_raw_parts(self.lines.as_ptr() as *const T, self.len) }
    }

    /// Mutable element view.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [T] {
        // SAFETY: as `as_slice`, plus unique access through `&mut self`.
        unsafe { std::slice::from_raw_parts_mut(self.lines.as_mut_ptr() as *mut T, self.len) }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl<T: Copy> Clone for AlignedBuf<T> {
    fn clone(&self) -> Self {
        AlignedBuf { lines: self.lines.clone(), len: self.len, _marker: std::marker::PhantomData }
    }
}

/// Declare an owned-or-mapped weight buffer deref-ing to `[$elem]`.
macro_rules! weight_buf {
    ($(#[$doc:meta])* $name:ident, $elem:ty) => {
        $(#[$doc])*
        #[derive(Clone)]
        pub enum $name {
            /// Heap storage, 64-byte aligned (see [`AlignedBuf`]).
            Owned(AlignedBuf<$elem>),
            Mapped {
                region: Arc<MmapRegion>,
                /// Byte offset of the element block inside the region.
                offset: usize,
                /// Element (not byte) count.
                len: usize,
            },
        }

        impl $name {
            /// Borrow `len` elements at byte `offset` of `region`.
            /// Validates bounds and element alignment.
            pub fn mapped(
                region: Arc<MmapRegion>,
                offset: usize,
                len: usize,
            ) -> Result<$name, String> {
                let bytes = len
                    .checked_mul(std::mem::size_of::<$elem>())
                    .and_then(|b| b.checked_add(offset))
                    .ok_or("weight section size overflows")?;
                if bytes > region.len() {
                    return Err(format!(
                        "weight section [{offset}..{bytes}) exceeds mapped file ({} bytes)",
                        region.len()
                    ));
                }
                let addr = region.bytes().as_ptr() as usize + offset;
                if addr % std::mem::align_of::<$elem>() != 0 {
                    return Err(format!(
                        "weight section at byte {offset} is not {}-byte aligned",
                        std::mem::align_of::<$elem>()
                    ));
                }
                Ok($name::Mapped { region, offset, len })
            }

            /// True when the elements borrow a mapped file region.
            pub fn is_mapped(&self) -> bool {
                matches!(self, $name::Mapped { .. })
            }

            /// Mutable element view; panics on mapped buffers (mapped
            /// stores are serve-only by construction).
            pub fn as_mut_slice(&mut self) -> &mut [$elem] {
                match self {
                    $name::Owned(v) => v.as_mut_slice(),
                    $name::Mapped { .. } => {
                        panic!("memory-mapped weights are read-only (serve-only store)")
                    }
                }
            }
        }

        impl std::ops::Deref for $name {
            type Target = [$elem];
            #[inline]
            fn deref(&self) -> &[$elem] {
                match self {
                    $name::Owned(v) => v.as_slice(),
                    $name::Mapped { region, offset, len } => unsafe {
                        // SAFETY: bounds and alignment checked in `mapped`;
                        // the region is immutable and outlives the borrow
                        // via the Arc.
                        std::slice::from_raw_parts(
                            region.bytes().as_ptr().add(*offset) as *const $elem,
                            *len,
                        )
                    },
                }
            }
        }

        impl std::ops::DerefMut for $name {
            #[inline]
            fn deref_mut(&mut self) -> &mut [$elem] {
                self.as_mut_slice()
            }
        }

        impl From<Vec<$elem>> for $name {
            /// Copies into 64-byte-aligned storage (a one-time, load/init
            /// cost) so owned and mapped buffers give the kernels the same
            /// alignment guarantee.
            fn from(v: Vec<$elem>) -> $name {
                $name::Owned(AlignedBuf::from_slice(&v))
            }
        }

        impl<'a> IntoIterator for &'a $name {
            type Item = &'a $elem;
            type IntoIter = std::slice::Iter<'a, $elem>;
            fn into_iter(self) -> Self::IntoIter {
                self.iter()
            }
        }

        impl<'a> IntoIterator for &'a mut $name {
            type Item = &'a mut $elem;
            type IntoIter = std::slice::IterMut<'a, $elem>;
            fn into_iter(self) -> Self::IntoIter {
                self.as_mut_slice().iter_mut()
            }
        }

        impl PartialEq for $name {
            fn eq(&self, other: &$name) -> bool {
                self[..] == other[..]
            }
        }

        impl std::fmt::Debug for $name {
            fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
                write!(
                    f,
                    "{}([{} x {}]{})",
                    stringify!($name),
                    self.len(),
                    stringify!($elem),
                    if self.is_mapped() { ", mapped" } else { "" }
                )
            }
        }
    };
}

weight_buf!(
    /// The f32 weight block of a dense or hashed store.
    F32Buf,
    f32
);
weight_buf!(
    /// The i8 quantized weight block of a [`super::quant::Q8Store`].
    I8Buf,
    i8
);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owned_buf_derefs_and_mutates() {
        let mut b = F32Buf::from(vec![1.0f32, 2.0, 3.0]);
        assert_eq!(b.len(), 3);
        assert!(!b.is_mapped());
        b[1] = 5.0;
        assert_eq!(&b[..], &[1.0, 5.0, 3.0]);
        assert_eq!(b, F32Buf::from(vec![1.0, 5.0, 3.0]));
    }

    #[test]
    fn owned_bufs_are_64_byte_aligned() {
        for n in [0usize, 1, 3, 16, 17, 100, 1024] {
            let f = F32Buf::from(vec![0.5f32; n]);
            assert_eq!(f.as_ptr() as usize % 64, 0, "F32Buf n={n}");
            assert_eq!(f.len(), n);
            let i = I8Buf::from(vec![-7i8; n]);
            assert_eq!(i.as_ptr() as usize % 64, 0, "I8Buf n={n}");
            assert_eq!(i.len(), n);
        }
    }

    #[test]
    fn aligned_buf_roundtrips_and_clones() {
        let src: Vec<f32> = (0..77).map(|i| i as f32 * 0.25 - 9.0).collect();
        let mut buf = AlignedBuf::from_slice(&src);
        assert_eq!(buf.as_slice(), &src[..]);
        assert_eq!(buf.len(), 77);
        assert!(!buf.is_empty());
        buf.as_mut_slice()[5] = 123.0;
        let c = buf.clone();
        assert_eq!(c.as_slice()[5], 123.0);
        assert_eq!(c.as_slice()[6], src[6]);
        let empty = AlignedBuf::<i8>::from_slice(&[]);
        assert!(empty.is_empty());
        assert_eq!(empty.as_slice(), &[] as &[i8]);
    }

    #[test]
    fn mapped_buf_reads_file_bytes() {
        let path = std::env::temp_dir().join(format!("ltls_mmap_test_{}", std::process::id()));
        let vals = [1.5f32, -2.25, 0.0, 42.0];
        let mut bytes = vec![0u8; 8]; // 8-byte prefix, keeps f32 alignment
        for v in vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&path, &bytes).unwrap();
        let region = Arc::new(MmapRegion::map(&path).unwrap());
        assert_eq!(region.len(), bytes.len());
        assert_eq!(region.bytes(), &bytes[..]);
        let buf = F32Buf::mapped(region.clone(), 8, 4).unwrap();
        assert!(buf.is_mapped());
        assert_eq!(&buf[..], &vals[..]);
        // Clones share the region.
        let c = buf.clone();
        assert_eq!(&c[..], &vals[..]);
        // Out-of-bounds and misaligned views are rejected.
        assert!(F32Buf::mapped(region.clone(), 8, 5).is_err());
        assert!(F32Buf::mapped(region.clone(), 7, 4).is_err());
        // i8 views have no alignment constraint.
        let ib = I8Buf::mapped(region.clone(), 1, 3).unwrap();
        assert_eq!(ib.len(), 3);
        drop(ib);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    #[should_panic(expected = "read-only")]
    fn mapped_buf_rejects_mutation() {
        let path = std::env::temp_dir().join(format!("ltls_mmap_mut_{}", std::process::id()));
        std::fs::write(&path, 1.0f32.to_le_bytes()).unwrap();
        let region = Arc::new(MmapRegion::map(&path).unwrap());
        let mut buf = F32Buf::mapped(region, 0, 1).unwrap();
        std::fs::remove_file(&path).ok();
        buf.as_mut_slice()[0] = 2.0;
    }

    #[test]
    fn map_missing_and_empty_files_error() {
        assert!(MmapRegion::map(Path::new("/nonexistent/ltls_model")).is_err());
        let path = std::env::temp_dir().join(format!("ltls_mmap_empty_{}", std::process::id()));
        std::fs::write(&path, b"").unwrap();
        assert!(MmapRegion::map(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
