//! L1 regularization by soft-thresholding (paper §6).
//!
//! On LSHTC1 and Dmoz the paper regularizes by "predicting with
//! soft-thresholded weights":
//!
//! ```text
//! st(w, λ) = w − λ   if w >  λ
//!            w + λ   if w < −λ
//!            0       otherwise
//! ```

use super::linear::LinearEdgeModel;

/// Soft-threshold a single weight.
#[inline]
pub fn soft_threshold(w: f32, lambda: f32) -> f32 {
    if w > lambda {
        w - lambda
    } else if w < -lambda {
        w + lambda
    } else {
        0.0
    }
}

/// Return a copy of the model with soft-thresholded weights.
pub fn soft_threshold_model(m: &LinearEdgeModel, lambda: f32) -> LinearEdgeModel {
    let mut out = m.clone();
    for w in &mut out.w {
        *w = soft_threshold(*w, lambda);
    }
    out
}

/// Pick λ on held-out data: evaluates `eval` (higher = better) for each
/// candidate and returns (best λ, best score).
pub fn tune_lambda<F: FnMut(&LinearEdgeModel) -> f64>(
    m: &LinearEdgeModel,
    candidates: &[f32],
    mut eval: F,
) -> (f32, f64) {
    let mut best = (0.0f32, f64::NEG_INFINITY);
    for &lam in candidates {
        let thresholded = soft_threshold_model(m, lam);
        let score = eval(&thresholded);
        if score > best.1 {
            best = (lam, score);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(1.0, 0.3), 0.7);
        assert_eq!(soft_threshold(-1.0, 0.3), -0.7);
        assert_eq!(soft_threshold(0.2, 0.3), 0.0);
        assert_eq!(soft_threshold(-0.2, 0.3), 0.0);
        assert_eq!(soft_threshold(0.3, 0.3), 0.0);
    }

    #[test]
    fn thresholding_sparsifies_model() {
        let mut m = LinearEdgeModel::new(2, 4);
        m.w = vec![0.5, -0.1, 0.05, -0.9, 0.2, 0.0, 1.5, -0.05];
        let t = soft_threshold_model(&m, 0.15);
        assert!(t.zero_fraction() > m.zero_fraction());
        assert!((t.w[0] - 0.35).abs() < 1e-6);
        assert_eq!(t.w[1], 0.0);
        assert!((t.w[6] - 1.35).abs() < 1e-6);
    }

    #[test]
    fn tune_picks_best_lambda() {
        let m = LinearEdgeModel::new(1, 2);
        // Eval prefers the most zeros: λ=1.0 wins over 0.0.
        let (lam, score) = tune_lambda(&m, &[0.0, 1.0], |mm| mm.zero_fraction());
        // zero model: both give all-zero; first candidate kept on ties → 0.0
        assert_eq!(lam, 0.0);
        assert_eq!(score, 1.0);
    }
}
