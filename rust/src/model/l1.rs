//! L1 regularization by soft-thresholding (paper §6).
//!
//! On LSHTC1 and Dmoz the paper regularizes by "predicting with
//! soft-thresholded weights":
//!
//! ```text
//! st(w, λ) = w − λ   if w >  λ
//!            w + λ   if w < −λ
//!            0       otherwise
//! ```
//!
//! The thresholding operates on the raw f32 strips of any
//! [`TrainableStore`] — dense or hashed — and the resulting sparsity shows
//! up in [`super::store::WeightStore::zero_fraction`] /
//! [`super::store::WeightStore::effective_bytes`] (printed by the
//! train/eval summaries, so the memory effect of `--l1` is visible end to
//! end).

use super::linear::LinearEdgeModel;
use super::store::TrainableStore;

/// Soft-threshold a single weight.
#[inline]
pub fn soft_threshold(w: f32, lambda: f32) -> f32 {
    if w > lambda {
        w - lambda
    } else if w < -lambda {
        w + lambda
    } else {
        0.0
    }
}

/// Return a copy of the store with soft-thresholded weights (bias is left
/// untouched, as in the paper).
pub fn soft_threshold_store<S: TrainableStore>(m: &S, lambda: f32) -> S {
    let mut out = m.clone();
    let (w, _) = out.raw_parts_mut();
    for v in w.iter_mut() {
        *v = soft_threshold(*v, lambda);
    }
    out
}

/// Dense-typed convenience wrapper (the historical entry point).
pub fn soft_threshold_model(m: &LinearEdgeModel, lambda: f32) -> LinearEdgeModel {
    soft_threshold_store(m, lambda)
}

/// Pick λ on held-out data: evaluates `eval` (higher = better) for each
/// candidate and returns (best λ, best score).
pub fn tune_lambda<S: TrainableStore, F: FnMut(&S) -> f64>(
    m: &S,
    candidates: &[f32],
    mut eval: F,
) -> (f32, f64) {
    let mut best = (0.0f32, f64::NEG_INFINITY);
    for &lam in candidates {
        let thresholded = soft_threshold_store(m, lam);
        let score = eval(&thresholded);
        if score > best.1 {
            best = (lam, score);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::store::WeightStore;

    #[test]
    fn soft_threshold_cases() {
        assert_eq!(soft_threshold(1.0, 0.3), 0.7);
        assert_eq!(soft_threshold(-1.0, 0.3), -0.7);
        assert_eq!(soft_threshold(0.2, 0.3), 0.0);
        assert_eq!(soft_threshold(-0.2, 0.3), 0.0);
        assert_eq!(soft_threshold(0.3, 0.3), 0.0);
    }

    #[test]
    fn thresholding_sparsifies_model() {
        let mut m = LinearEdgeModel::new(2, 4);
        m.w = vec![0.5, -0.1, 0.05, -0.9, 0.2, 0.0, 1.5, -0.05].into();
        let t = soft_threshold_model(&m, 0.15);
        assert!(t.zero_fraction() > m.zero_fraction());
        assert!((t.w[0] - 0.35).abs() < 1e-6);
        assert_eq!(t.w[1], 0.0);
        assert!((t.w[6] - 1.35).abs() < 1e-6);
        // Sparsity shrinks the effective (nonzero) byte count.
        assert!(WeightStore::effective_bytes(&t) < WeightStore::effective_bytes(&m));
    }

    #[test]
    fn tune_picks_best_lambda() {
        let m = LinearEdgeModel::new(1, 2);
        // Eval prefers the most zeros: λ=1.0 wins over 0.0.
        let (lam, score) = tune_lambda(&m, &[0.0, 1.0], |mm| mm.zero_fraction());
        // zero model: both give all-zero; first candidate kept on ties → 0.0
        assert_eq!(lam, 0.0);
        assert_eq!(score, 1.0);
    }

    /// Hashed stores threshold the same way (the L1 memory story composes
    /// with hashing).
    #[test]
    fn thresholds_hashed_store() {
        use crate::model::hashed::HashedStore;
        use crate::sparse::SparseVec;
        let mut m = HashedStore::new(3, 100, 4, 1).unwrap();
        let idx = [0u32, 50, 99];
        let val = [1.0f32, 2.0, -1.0];
        m.update_edges(&[0], &[2], SparseVec::new(&idx, &val), 0.05);
        let t = soft_threshold_store(&m, 0.08);
        assert!(t.zero_fraction() >= m.zero_fraction());
        assert_eq!(t.bits, m.bits);
        assert_eq!(t.seed, m.seed);
    }
}
