//! Weight averaging for SGD (paper §5: "stochastic gradient descent with
//! averaging").
//!
//! The averaged iterate `w̄_T = (1/T) Σ_t w_t` is maintained lazily so the
//! sparse hot path stays `O(nnz)`: with `u = Σ_τ (τ−1)·Δ_τ` accumulated at
//! each sparse update, `(1/T) Σ_t w_t = w_T − u/T` exactly (each `Δ_τ`
//! appears in the `T−τ+1` iterates `w_τ … w_T`).
//!
//! Storage mirrors the model's strip-major layout (`n_strips × E` — `D`
//! strips for the dense store, `2^b` for the hashed store), and every
//! record goes through the store's [`StripCodec`] so the shadow
//! accumulators land exactly where the model's own update landed. With the
//! dense [`IdentityCodec`](super::store::IdentityCodec) the arithmetic is
//! bit-identical to the pre-codec code (sign `+1.0` multiplies out).

use super::store::StripCodec;
use crate::sparse::SparseVec;

/// Averaging companion for a strip-major `n_strips × E` weight matrix.
#[derive(Clone, Debug)]
pub struct Averager {
    /// Shadow accumulators, strip-major like the model.
    u: Vec<f32>,
    u_bias: Vec<f32>,
    /// Current step counter (1-based after the first `tick`).
    t: u64,
    n_edges: usize,
}

impl Averager {
    /// Shadow storage for `n_edges` edges × `n_strips` weight strips
    /// (`n_strips` = the store's physical strip count, see
    /// [`super::store::TrainableStore::n_strips`]).
    pub fn new(n_edges: usize, n_strips: usize) -> Self {
        Averager { u: vec![0.0; n_edges * n_strips], u_bias: vec![0.0; n_edges], t: 0, n_edges }
    }

    /// Advance the step counter; call once per SGD example.
    #[inline]
    pub fn tick(&mut self) {
        self.t += 1;
    }

    /// Record a sparse update `w_e += scale·x` made at the current step.
    #[inline]
    pub fn record<C: StripCodec>(&mut self, codec: C, e: usize, x: SparseVec, scale: f32) {
        let ne = self.n_edges;
        let ts = (self.t - 1) as f32 * scale;
        for (&i, &v) in x.indices.iter().zip(x.values) {
            let (s, sign) = codec.strip_of(i);
            self.u[s as usize * ne + e] += (ts * v) * sign;
        }
        self.u_bias[e] += ts * 0.1;
    }

    /// Fused twin of [`super::store::TrainableStore::update_edges`].
    pub fn record_edges<C: StripCodec>(
        &mut self,
        codec: C,
        pos: &[u32],
        neg: &[u32],
        x: SparseVec,
        scale: f32,
    ) {
        let ne = self.n_edges;
        let ts = (self.t - 1) as f32 * scale;
        for (&i, &v) in x.indices.iter().zip(x.values) {
            let (s, sign) = codec.strip_of(i);
            let strip = &mut self.u[s as usize * ne..(s as usize + 1) * ne];
            let sv = (ts * v) * sign;
            for &e in pos {
                strip[e as usize] += sv;
            }
            for &e in neg {
                strip[e as usize] -= sv;
            }
        }
        for &e in pos {
            self.u_bias[e as usize] += ts * 0.1;
        }
        for &e in neg {
            self.u_bias[e as usize] -= ts * 0.1;
        }
    }

    /// Produce the averaged weights from the final weights:
    /// `w̄ = w − u/T` (passthrough if no steps were taken).
    pub fn averaged(&self, w: &[f32], bias: &[f32]) -> (Vec<f32>, Vec<f32>) {
        if self.t == 0 {
            return (w.to_vec(), bias.to_vec());
        }
        let inv_t = 1.0 / self.t as f32;
        let aw = w.iter().zip(&self.u).map(|(wv, uv)| wv - uv * inv_t).collect();
        let ab = bias.iter().zip(&self.u_bias).map(|(wv, uv)| wv - uv * inv_t).collect();
        (aw, ab)
    }

    pub fn steps(&self) -> u64 {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::store::{IdentityCodec, TrainableStore};
    use crate::model::LinearEdgeModel;
    use crate::util::rng::Rng;

    /// Lazy averaging equals the brute-force running mean of iterates.
    #[test]
    fn matches_bruteforce_average() {
        let (e, d) = (3usize, 8usize);
        let mut m = LinearEdgeModel::new(e, d);
        let mut avg = Averager::new(e, d);
        let mut rng = Rng::new(51);

        let mut sum_w = vec![0.0f64; e * d];
        let steps = 57;
        let mut idx_buf: Vec<u32> = Vec::new();
        let mut val_buf: Vec<f32> = Vec::new();
        for _ in 0..steps {
            avg.tick();
            idx_buf.clear();
            val_buf.clear();
            let mut last = 0u32;
            for _ in 0..3 {
                last += 1 + rng.below(2) as u32;
                idx_buf.push(last.min(d as u32 - 1));
                val_buf.push(rng.normal());
            }
            idx_buf.dedup();
            val_buf.truncate(idx_buf.len());
            let x = SparseVec::new(&idx_buf, &val_buf);
            let edge = rng.index(e);
            let scale = rng.normal() * 0.1;
            m.update_edge(edge, x, scale);
            avg.record(IdentityCodec, edge, x, scale);
            for (s, w) in sum_w.iter_mut().zip(m.w.iter()) {
                *s += *w as f64;
            }
        }
        let (aw, _) = avg.averaged(&m.w, &m.bias);
        for i in 0..e * d {
            let brute = (sum_w[i] / steps as f64) as f32;
            assert!((aw[i] - brute).abs() < 1e-4, "i={i}: {} vs {brute}", aw[i]);
        }
    }

    /// record_edges == record per edge with signs.
    #[test]
    fn fused_record_matches_per_edge() {
        let (e, d) = (6usize, 5usize);
        let mut a = Averager::new(e, d);
        let mut b = Averager::new(e, d);
        let idx = [0u32, 4];
        let val = [1.0f32, -2.0];
        let x = SparseVec::new(&idx, &val);
        for _ in 0..3 {
            a.tick();
            b.tick();
            a.record_edges(IdentityCodec, &[1, 2], &[5], x, 0.7);
            b.record(IdentityCodec, 1, x, 0.7);
            b.record(IdentityCodec, 2, x, 0.7);
            b.record(IdentityCodec, 5, x, -0.7);
        }
        assert_eq!(a.u, b.u);
        assert_eq!(a.u_bias, b.u_bias);
    }

    #[test]
    fn no_updates_passthrough() {
        let avg = Averager::new(2, 4);
        let w = vec![1.0f32; 8];
        let b = vec![0.5f32; 2];
        let (aw, ab) = avg.averaged(&w, &b);
        assert_eq!(aw, w);
        assert_eq!(ab, b);
    }

    /// The averager shadows a hashed store exactly: recording through the
    /// hash codec lands where the model's own update landed, so averaged
    /// weights equal the brute-force mean of hashed iterates too.
    #[test]
    fn shadows_hashed_store() {
        use crate::model::hashed::HashedStore;
        let mut m = HashedStore::new(4, 300, 4, 9).unwrap();
        let mut avg = Averager::new(4, m.n_strips());
        let mut sum_w = vec![0.0f64; m.raw_w().len()];
        let idx = [3u32, 120, 299];
        let val = [1.0f32, -0.5, 2.0];
        let x = SparseVec::new(&idx, &val);
        for step in 0..9 {
            avg.tick();
            let scale = 0.1 * (step as f32 + 1.0);
            m.update_edges(&[0, 2], &[3], x, scale);
            avg.record_edges(m.codec(), &[0, 2], &[3], x, scale);
            for (s, w) in sum_w.iter_mut().zip(m.raw_w()) {
                *s += *w as f64;
            }
        }
        let (aw, _) = avg.averaged(m.raw_w(), &m.bias);
        for i in 0..aw.len() {
            let brute = (sum_w[i] / 9.0) as f32;
            assert!((aw[i] - brute).abs() < 1e-4, "i={i}: {} vs {brute}", aw[i]);
        }
    }
}
