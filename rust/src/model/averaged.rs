//! Weight averaging for SGD (paper §5: "stochastic gradient descent with
//! averaging").
//!
//! The averaged iterate `w̄_T = (1/T) Σ_t w_t` is maintained lazily so the
//! sparse hot path stays `O(nnz)`: with `u = Σ_τ (τ−1)·Δ_τ` accumulated at
//! each sparse update, `(1/T) Σ_t w_t = w_T − u/T` exactly (each `Δ_τ`
//! appears in the `T−τ+1` iterates `w_τ … w_T`).
//!
//! Storage mirrors [`super::linear::LinearEdgeModel`]'s feature-major
//! layout, and [`Averager::record_edges`] fuses a separation-loss update
//! the same way.

use crate::sparse::SparseVec;

/// Averaging companion for a feature-major `D × E` weight matrix.
#[derive(Clone, Debug)]
pub struct Averager {
    /// Shadow accumulators, feature-major like the model.
    u: Vec<f32>,
    u_bias: Vec<f32>,
    /// Current step counter (1-based after the first `tick`).
    t: u64,
    n_edges: usize,
}

impl Averager {
    pub fn new(n_edges: usize, n_features: usize) -> Self {
        Averager { u: vec![0.0; n_edges * n_features], u_bias: vec![0.0; n_edges], t: 0, n_edges }
    }

    /// Advance the step counter; call once per SGD example.
    #[inline]
    pub fn tick(&mut self) {
        self.t += 1;
    }

    /// Record a sparse update `w_e += scale·x` made at the current step.
    #[inline]
    pub fn record(&mut self, e: usize, x: SparseVec, scale: f32) {
        let ne = self.n_edges;
        let ts = (self.t - 1) as f32 * scale;
        for (&i, &v) in x.indices.iter().zip(x.values) {
            self.u[i as usize * ne + e] += ts * v;
        }
        self.u_bias[e] += ts * 0.1;
    }

    /// Fused twin of [`crate::model::LinearEdgeModel::update_edges`].
    pub fn record_edges(&mut self, pos: &[u32], neg: &[u32], x: SparseVec, scale: f32) {
        let ne = self.n_edges;
        let ts = (self.t - 1) as f32 * scale;
        for (&i, &v) in x.indices.iter().zip(x.values) {
            let strip = &mut self.u[i as usize * ne..(i as usize + 1) * ne];
            let sv = ts * v;
            for &e in pos {
                strip[e as usize] += sv;
            }
            for &e in neg {
                strip[e as usize] -= sv;
            }
        }
        for &e in pos {
            self.u_bias[e as usize] += ts * 0.1;
        }
        for &e in neg {
            self.u_bias[e as usize] -= ts * 0.1;
        }
    }

    /// Produce the averaged weights from the final weights:
    /// `w̄ = w − u/T` (passthrough if no steps were taken).
    pub fn averaged(&self, w: &[f32], bias: &[f32]) -> (Vec<f32>, Vec<f32>) {
        if self.t == 0 {
            return (w.to_vec(), bias.to_vec());
        }
        let inv_t = 1.0 / self.t as f32;
        let aw = w.iter().zip(&self.u).map(|(wv, uv)| wv - uv * inv_t).collect();
        let ab = bias.iter().zip(&self.u_bias).map(|(wv, uv)| wv - uv * inv_t).collect();
        (aw, ab)
    }

    pub fn steps(&self) -> u64 {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LinearEdgeModel;
    use crate::util::rng::Rng;

    /// Lazy averaging equals the brute-force running mean of iterates.
    #[test]
    fn matches_bruteforce_average() {
        let (e, d) = (3usize, 8usize);
        let mut m = LinearEdgeModel::new(e, d);
        let mut avg = Averager::new(e, d);
        let mut rng = Rng::new(51);

        let mut sum_w = vec![0.0f64; e * d];
        let steps = 57;
        let mut idx_buf: Vec<u32> = Vec::new();
        let mut val_buf: Vec<f32> = Vec::new();
        for _ in 0..steps {
            avg.tick();
            idx_buf.clear();
            val_buf.clear();
            let mut last = 0u32;
            for _ in 0..3 {
                last += 1 + rng.below(2) as u32;
                idx_buf.push(last.min(d as u32 - 1));
                val_buf.push(rng.normal());
            }
            idx_buf.dedup();
            val_buf.truncate(idx_buf.len());
            let x = SparseVec::new(&idx_buf, &val_buf);
            let edge = rng.index(e);
            let scale = rng.normal() * 0.1;
            m.update_edge(edge, x, scale);
            avg.record(edge, x, scale);
            for (s, w) in sum_w.iter_mut().zip(&m.w) {
                *s += *w as f64;
            }
        }
        let (aw, _) = avg.averaged(&m.w, &m.bias);
        for i in 0..e * d {
            let brute = (sum_w[i] / steps as f64) as f32;
            assert!((aw[i] - brute).abs() < 1e-4, "i={i}: {} vs {brute}", aw[i]);
        }
    }

    /// record_edges == record per edge with signs.
    #[test]
    fn fused_record_matches_per_edge() {
        let (e, d) = (6usize, 5usize);
        let mut a = Averager::new(e, d);
        let mut b = Averager::new(e, d);
        let idx = [0u32, 4];
        let val = [1.0f32, -2.0];
        let x = SparseVec::new(&idx, &val);
        for _ in 0..3 {
            a.tick();
            b.tick();
            a.record_edges(&[1, 2], &[5], x, 0.7);
            b.record(1, x, 0.7);
            b.record(2, x, 0.7);
            b.record(5, x, -0.7);
        }
        assert_eq!(a.u, b.u);
        assert_eq!(a.u_bias, b.u_bias);
    }

    #[test]
    fn no_updates_passthrough() {
        let avg = Averager::new(2, 4);
        let w = vec![1.0f32; 8];
        let b = vec![0.5f32; 2];
        let (aw, ab) = avg.averaged(&w, &b);
        assert_eq!(aw, w);
        assert_eq!(ab, b);
    }
}
