//! Dense linear edge-score model `W ∈ R^{E×D}` with sparse updates — the
//! default [`WeightStore`] backend (the paper's exact model).
//!
//! Storage is **feature-major** (`D` strips of `E` contiguous floats):
//! computing `h = Wx` for a sparse `x` then reads one contiguous E-strip
//! per active feature (`E ≤ ~80` floats ≈ 1–2 cache lines) instead of
//! `nnz` random positions per edge — measured ~8× faster at nnz≈160
//! (EXPERIMENTS.md §Perf). Updates on a path's edge set touch the same
//! strips, so the fused [`DenseStore::update_edges`] is equally
//! cache-friendly. Model size is exactly `E·D` f32s — the log-space claim
//! (the paper also observes the trained weights are dense).
//!
//! All f32 kernels run through the shared [`super::store::StripCodec`]
//! machinery of
//! [`super::store`] with the [`IdentityCodec`] (strip `i`, sign `+1.0`),
//! which multiplies out **bit-identically** to the pre-trait direct
//! indexing — pinned by `rust/tests/engine_parity.rs`. The weight block is
//! an [`F32Buf`], so a served model can borrow it zero-copy from an
//! mmapped v3 file (training always owns it).

use super::mmap::F32Buf;
use super::store::{
    codec_edge_scores, codec_edge_scores_batch, Backend, IdentityCodec, ScoreScratch,
    TrainableStore, WeightBlock, WeightStore,
};
use crate::sparse::SparseVec;

/// Feature-major dense linear edge model.
#[derive(Clone, Debug)]
pub struct DenseStore {
    pub n_edges: usize,
    pub n_features: usize,
    /// Feature-major `D × E` weights: `w[i*E + e]` is feature `i`, edge `e`.
    pub w: F32Buf,
    /// Per-edge bias (helps the early-exit edges whose paths are short).
    pub bias: Vec<f32>,
}

/// The historical name of the dense store, kept as an alias — the default
/// backend everywhere a store type is not spelled out.
pub type LinearEdgeModel = DenseStore;

impl DenseStore {
    /// Zero-initialized model.
    pub fn new(n_edges: usize, n_features: usize) -> Self {
        DenseStore {
            n_edges,
            n_features,
            w: F32Buf::from(vec![0.0; n_edges * n_features]),
            bias: vec![0.0; n_edges],
        }
    }

    /// Zero-initialized model sized for a topology: one weight row per
    /// learnable edge. This is where the width dial shows up in parameter
    /// count — `E` grows from `4⌊log₂C⌋ + popcount(C)` at `W = 2` to
    /// `2W + (b−1)W² + …` for a wide trellis (the accuracy/size tradeoff
    /// of the width sweep bench).
    pub fn for_topology<T: crate::graph::Topology>(t: &T, n_features: usize) -> Self {
        debug_assert_eq!(t.linear_param_count(n_features), t.num_edges() * n_features);
        Self::new(t.num_edges(), n_features)
    }

    /// Weight of (edge `e`, feature `i`).
    #[inline]
    pub fn weight(&self, e: usize, i: usize) -> f32 {
        self.w[i * self.n_edges + e]
    }

    /// Copy of edge `e`'s weight row (length D). O(D) — diagnostics only.
    pub fn edge_row(&self, e: usize) -> Vec<f32> {
        (0..self.n_features).map(|i| self.weight(e, i)).collect()
    }

    /// Edge-score vector `h = Wx + b` — one contiguous E-strip per nnz.
    pub fn edge_scores(&self, x: SparseVec, out: &mut Vec<f32>) {
        codec_edge_scores(&self.w, &self.bias, self.n_edges, IdentityCodec, x, out);
    }

    /// Allocating convenience wrapper over [`Self::edge_scores`].
    pub fn edge_scores_vec(&self, x: SparseVec) -> Vec<f32> {
        let mut out = Vec::new();
        self.edge_scores(x, &mut out);
        out
    }

    /// Batched edge scores for a block of sparse rows: `out` receives the
    /// `B × E` row-major score matrix (`out[r·E + e] = h_e(x_r)`).
    ///
    /// Instead of `Σ_r nnz_r` independent strip reads, the block's
    /// `(feature, row, value)` triples are gathered into `scratch` and
    /// sorted by feature, so each distinct feature's E-strip is swept once
    /// for all rows that use it while it is cache-hot — one feature-strip
    /// sweep per batch (EXPERIMENTS.md §Perf).
    ///
    /// Bit-identical to per-row [`Self::edge_scores`]: every output cell
    /// accumulates bias first, then its row's features in ascending index
    /// order, exactly like the single-row path (each `(feature, row)` pair
    /// is unique, so sort instability cannot reorder a cell's updates).
    /// Allocation-free after warm-up when `scratch`/`out` are reused.
    pub fn edge_scores_batch(
        &self,
        rows: &[SparseVec],
        scratch: &mut Vec<(u32, u32, f32)>,
        out: &mut Vec<f32>,
    ) {
        codec_edge_scores_batch(
            &self.w,
            &self.bias,
            self.n_edges,
            IdentityCodec,
            rows,
            scratch,
            out,
        );
    }

    /// Sparse SGD update on one edge: `w_e += scale · x`, `b_e += scale·0.1`.
    #[inline]
    pub fn update_edge(&mut self, e: usize, x: SparseVec, scale: f32) {
        TrainableStore::update_edge(self, e, x, scale);
    }

    /// Fused separation-loss update (`+scale·x` on `pos` edges, `−scale·x`
    /// on `neg` edges): walks each active feature's strip once.
    pub fn update_edges(&mut self, pos: &[u32], neg: &[u32], x: SparseVec, scale: f32) {
        TrainableStore::update_edges(self, pos, neg, x, scale);
    }

    /// Parameter count (model-size reporting).
    pub fn param_count(&self) -> usize {
        self.w.len() + self.bias.len()
    }

    /// Model size in bytes (paper's "model size `[M]`" columns).
    pub fn bytes(&self) -> usize {
        self.param_count() * std::mem::size_of::<f32>()
    }

    /// Fraction of exactly-zero weights (the paper notes trained LTLS
    /// weights end up dense; the L1 mode re-sparsifies).
    pub fn zero_fraction(&self) -> f64 {
        WeightStore::zero_fraction(self)
    }
}

impl WeightStore for DenseStore {
    const BACKEND: Backend = Backend::Dense;

    fn n_edges(&self) -> usize {
        self.n_edges
    }
    fn n_features(&self) -> usize {
        self.n_features
    }
    fn bias(&self) -> &[f32] {
        &self.bias
    }
    fn edge_scores(&self, x: SparseVec, _scratch: &mut ScoreScratch, out: &mut Vec<f32>) {
        DenseStore::edge_scores(self, x, out);
    }
    fn edge_scores_batch(&self, rows: &[SparseVec], scratch: &mut ScoreScratch, out: &mut Vec<f32>) {
        DenseStore::edge_scores_batch(self, rows, &mut scratch.gather, out);
    }
    fn param_count(&self) -> usize {
        DenseStore::param_count(self)
    }
    fn bytes(&self) -> usize {
        DenseStore::bytes(self)
    }
    fn weight_count(&self) -> usize {
        self.w.len()
    }
    fn weight_elem_bytes(&self) -> usize {
        std::mem::size_of::<f32>()
    }
    fn zero_weights(&self) -> usize {
        self.w.iter().filter(|&&v| v == 0.0).count()
    }
    fn is_mapped(&self) -> bool {
        self.w.is_mapped()
    }

    fn weight_block_len(&self) -> usize {
        self.w.len() * 4
    }
    fn write_weights(&self, out: &mut Vec<u8>) {
        for &w in self.w.iter() {
            out.extend_from_slice(&w.to_le_bytes());
        }
    }
    fn read_store(
        n_edges: usize,
        n_features: usize,
        meta: &[u8],
        bias: Vec<f32>,
        weights: WeightBlock<'_>,
    ) -> Result<Self, String> {
        if !meta.is_empty() {
            return Err(format!("dense model carries {} unexpected meta bytes", meta.len()));
        }
        if bias.len() != n_edges {
            return Err(format!("bias is {} entries, expected {n_edges}", bias.len()));
        }
        let w = weights.into_f32(n_edges * n_features)?;
        Ok(DenseStore { n_edges, n_features, w, bias })
    }
}

impl TrainableStore for DenseStore {
    type Codec = IdentityCodec;

    fn codec(&self) -> IdentityCodec {
        IdentityCodec
    }
    fn n_strips(&self) -> usize {
        self.n_features
    }
    fn raw_w(&self) -> &[f32] {
        &self.w
    }
    fn raw_parts_mut(&mut self) -> (&mut [f32], &mut [f32]) {
        (self.w.as_mut_slice(), self.bias.as_mut_slice())
    }
    fn for_topology_cfg<T: crate::graph::Topology>(
        t: &T,
        n_features: usize,
        hash_bits: u32,
        _seed: u64,
    ) -> Result<Self, String> {
        if hash_bits != 0 {
            return Err(format!(
                "--hash-bits {hash_bits} requires the hashed backend, not dense \
                 (internal dispatch error)"
            ));
        }
        Ok(Self::for_topology(t, n_features))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xvec(idx: &'static [u32], val: &'static [f32]) -> SparseVec<'static> {
        SparseVec::new(idx, val)
    }

    #[test]
    fn scores_and_updates() {
        let mut m = LinearEdgeModel::new(3, 4);
        let x = xvec(&[0, 2], &[1.0, 2.0]);
        assert_eq!(m.edge_scores_vec(x), vec![0.0, 0.0, 0.0]);
        m.update_edge(1, x, 0.5);
        let h = m.edge_scores_vec(x);
        assert_eq!(h[0], 0.0);
        // w[·,1] = 0.5·x; h_1 = 0.5·1 + 1.0·2 + bias(0.05)
        assert!((h[1] - (2.5 + 0.05)).abs() < 1e-6);
        assert_eq!(h[2], 0.0);
    }

    #[test]
    fn batch_scores_match_per_example() {
        let mut m = LinearEdgeModel::new(4, 6);
        let xa = xvec(&[0, 2], &[1.0, 2.0]);
        let xb = xvec(&[2, 5], &[-1.0, 0.5]);
        let xc = xvec(&[], &[]);
        m.update_edge(1, xa, 0.5);
        m.update_edge(3, xb, -0.25);
        let rows = [xa, xb, xc];
        let mut scratch = Vec::new();
        let mut batch = Vec::new();
        m.edge_scores_batch(&rows, &mut scratch, &mut batch);
        assert_eq!(batch.len(), 3 * 4);
        for (r, x) in rows.iter().enumerate() {
            assert_eq!(&batch[r * 4..(r + 1) * 4], m.edge_scores_vec(*x).as_slice(), "row {r}");
        }
        // Buffer reuse with a different block shape stays exact.
        let rows2 = [xb];
        m.edge_scores_batch(&rows2, &mut scratch, &mut batch);
        assert_eq!(batch, m.edge_scores_vec(xb));
    }

    #[test]
    fn fused_update_matches_per_edge() {
        let x = xvec(&[1, 3], &[2.0, -1.0]);
        let mut a = LinearEdgeModel::new(5, 4);
        let mut b = LinearEdgeModel::new(5, 4);
        a.update_edges(&[0, 2], &[4], x, 0.3);
        b.update_edge(0, x, 0.3);
        b.update_edge(2, x, 0.3);
        b.update_edge(4, x, -0.3);
        assert_eq!(a.w, b.w);
        assert_eq!(a.bias, b.bias);
    }

    #[test]
    fn param_accounting() {
        let m = LinearEdgeModel::new(42, 1000);
        assert_eq!(m.param_count(), 42 * 1000 + 42);
        assert_eq!(m.bytes(), (42 * 1000 + 42) * 4);
        assert_eq!(m.zero_fraction(), 1.0);
        // All-zero weights compress to the bias-only floor.
        assert_eq!(WeightStore::effective_bytes(&m), 42 * 4);
        assert_eq!(m.backend(), Backend::Dense);
        assert!(!WeightStore::is_mapped(&m));
    }

    #[test]
    fn edge_row_extracts_strided_weights() {
        let mut m = LinearEdgeModel::new(2, 3);
        let x = xvec(&[1], &[1.0]);
        m.update_edge(0, x, 7.0);
        assert_eq!(m.edge_row(0), vec![0.0, 7.0, 0.0]);
        assert_eq!(m.edge_row(1), vec![0.0, 0.0, 0.0]);
        assert_eq!(m.weight(0, 1), 7.0);
    }

    /// The WeightStore trait surface delegates to the inherent kernels.
    #[test]
    fn trait_surface_matches_inherent() {
        let mut m = LinearEdgeModel::new(3, 5);
        let x = xvec(&[0, 4], &[1.5, -2.0]);
        m.update_edge(2, x, 0.5);
        let mut a = Vec::new();
        let mut b = Vec::new();
        WeightStore::edge_scores(&m, x, &mut ScoreScratch::new(), &mut a);
        m.edge_scores(x, &mut b);
        assert_eq!(a, b);
        assert_eq!(WeightStore::n_edges(&m), 3);
        assert_eq!(WeightStore::n_features(&m), 5);
        assert_eq!(WeightStore::bias(&m), m.bias.as_slice());
        assert_eq!(m.n_strips(), 5);
        assert_eq!(m.raw_w(), &m.w[..]);
        assert_eq!(m.hash_bits(), 0);
    }
}
