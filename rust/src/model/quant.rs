//! Serve-only 8-bit weight quantization: a [`Q8Store`] holds the dense
//! model's feature-major weight block as `i8` with one f32 scale **per
//! edge**, cutting serving memory ~4× (weights dominate; bias and scales
//! stay f32).
//!
//! Per-edge scaling is what makes integer scoring possible: with
//! `w[i,e] ≈ s_e · q[i,e]` the edge score factors as
//! `h_e = b_e + s_e · Σ_i x_i · q[i,e]`, so after quantizing the *input*
//! per example (`x_i ≈ s_x · qx_i`, symmetric ±127) the inner sum
//! `Σ qx_i · q[i,e]` is pure **i32 accumulation** — no dequantized f32
//! copy of the weights is ever materialized, and the fp work per edge is
//! one fused `b_e + (s_e·s_x)·acc` at the end. The i32 accumulators live
//! in the typed [`ScoreScratch::acc`] buffer (owned per worker inside
//! `PredictScratch`, so the scoring path still allocates nothing in
//! steady state) and each strip is swept by the widening i8→i16→i32
//! kernel [`crate::kernel::i8_axpy`]. Overflow would need
//! `Σ|qx·q| > 2³¹` ≈ 133k active features at worst-case magnitudes — far
//! beyond any XC dataset.
//!
//! A `Q8Store` is built offline from a trained dense model
//! ([`Q8Store::quantize`], the `ltls quantize` subcommand) and implements
//! only [`WeightStore`]: quantized weights cannot absorb sparse SGD
//! deltas, so the type system keeps it out of the trainers.

use super::linear::DenseStore;
use super::mmap::I8Buf;
use super::store::{parse_f32s, Backend, ScoreScratch, WeightBlock, WeightStore};
use crate::sparse::SparseVec;

/// Per-edge-scaled i8 quantization of a dense model (serve-only).
#[derive(Clone, Debug)]
pub struct Q8Store {
    pub n_edges: usize,
    pub n_features: usize,
    /// Feature-major `D × E` quantized weights: `q[i*E + e]`.
    pub q: I8Buf,
    /// Per-edge dequantization scale `s_e` (`w[i,e] ≈ s_e · q[i,e]`).
    pub scale: Vec<f32>,
    /// Per-edge bias, kept at full precision.
    pub bias: Vec<f32>,
}

impl Q8Store {
    /// Quantize a trained dense model: symmetric per-edge scales
    /// `s_e = max_i |w[i,e]| / 127`, weights rounded to the nearest i8.
    pub fn quantize(dense: &DenseStore) -> Q8Store {
        let e = dense.n_edges;
        let d = dense.n_features;
        let mut maxw = vec![0.0f32; e];
        for strip in dense.w.chunks_exact(e) {
            for (m, &w) in maxw.iter_mut().zip(strip) {
                *m = m.max(w.abs());
            }
        }
        let scale: Vec<f32> = maxw.iter().map(|&m| if m > 0.0 { m / 127.0 } else { 0.0 }).collect();
        let inv: Vec<f32> = scale.iter().map(|&s| if s > 0.0 { 1.0 / s } else { 0.0 }).collect();
        let mut q = Vec::with_capacity(d * e);
        for strip in dense.w.chunks_exact(e) {
            for (j, &w) in strip.iter().enumerate() {
                q.push((w * inv[j]).round().clamp(-127.0, 127.0) as i8);
            }
        }
        Q8Store {
            n_edges: e,
            n_features: d,
            q: I8Buf::from(q),
            scale,
            bias: dense.bias.clone(),
        }
    }

    /// Quantize one example's value to i8 range: returns `(inv, s_x)` with
    /// `qx_i = round(x_i · inv)` and `x_i ≈ s_x · qx_i`.
    #[inline]
    fn input_scale(values: &[f32]) -> (f32, f32) {
        let mut maxv = 0.0f32;
        for &v in values {
            maxv = maxv.max(v.abs());
        }
        if maxv > 0.0 {
            (127.0 / maxv, maxv / 127.0)
        } else {
            (0.0, 0.0)
        }
    }
}

impl WeightStore for Q8Store {
    const BACKEND: Backend = Backend::Q8;

    fn n_edges(&self) -> usize {
        self.n_edges
    }
    fn n_features(&self) -> usize {
        self.n_features
    }
    fn bias(&self) -> &[f32] {
        &self.bias
    }

    /// `h_e = b_e + (s_e·s_x) · Σ_i qx_i·q[i,e]` — widening i8 SIMD
    /// accumulation into `scratch.acc`, one f32 fma-shaped finish per edge.
    fn edge_scores(&self, x: SparseVec, scratch: &mut ScoreScratch, out: &mut Vec<f32>) {
        let e = self.n_edges;
        let acc = &mut scratch.acc;
        acc.clear();
        acc.resize(e, 0);
        let (inv, sx) = Self::input_scale(x.values);
        if inv > 0.0 {
            for (k, (&i, &v)) in x.indices.iter().zip(x.values).enumerate() {
                if let Some(&ni) = x.indices.get(k + 1) {
                    crate::kernel::prefetch(&self.q[ni as usize * e..]);
                }
                let qv = (v * inv).round() as i32;
                if qv == 0 {
                    continue;
                }
                let strip = &self.q[i as usize * e..(i as usize + 1) * e];
                crate::kernel::i8_axpy(acc, strip, qv);
            }
        }
        out.clear();
        out.resize(e, 0.0);
        crate::kernel::q8_finish(out, acc, &self.bias, &self.scale, sx);
    }

    /// Batched variant: gathers `(feature, row, qx)` triples (the integer
    /// level stored exactly in the f32 slot), sorts by feature, and sweeps
    /// each i8 strip once per block into the block-sized `scratch.acc`.
    /// Bit-identical to per-row [`Self::edge_scores`] — integer
    /// accumulation is order-independent.
    fn edge_scores_batch(&self, rows: &[SparseVec], scratch: &mut ScoreScratch, out: &mut Vec<f32>) {
        let e = self.n_edges;
        let ScoreScratch { gather, acc, .. } = scratch;
        acc.clear();
        acc.resize(rows.len() * e, 0);
        gather.clear();
        for (r, x) in rows.iter().enumerate() {
            let (inv, _) = Self::input_scale(x.values);
            if inv == 0.0 {
                continue;
            }
            for (&i, &v) in x.indices.iter().zip(x.values) {
                let qv = (v * inv).round();
                if qv != 0.0 {
                    gather.push((i, r as u32, qv));
                }
            }
        }
        gather.sort_unstable_by_key(|t| t.0);
        for (k, &(i, r, qv)) in gather.iter().enumerate() {
            if let Some(&(ni, _, _)) = gather.get(k + 1) {
                if ni != i {
                    crate::kernel::prefetch(&self.q[ni as usize * e..]);
                }
            }
            let strip = &self.q[i as usize * e..(i as usize + 1) * e];
            let dst = &mut acc[r as usize * e..(r as usize + 1) * e];
            crate::kernel::i8_axpy(dst, strip, qv as i32);
        }
        out.clear();
        out.resize(rows.len() * e, 0.0);
        for (r, x) in rows.iter().enumerate() {
            let (_, sx) = Self::input_scale(x.values);
            crate::kernel::q8_finish(
                &mut out[r * e..(r + 1) * e],
                &acc[r * e..(r + 1) * e],
                &self.bias,
                &self.scale,
                sx,
            );
        }
    }

    fn param_count(&self) -> usize {
        self.q.len() + self.scale.len() + self.bias.len()
    }
    fn bytes(&self) -> usize {
        self.q.len() + (self.scale.len() + self.bias.len()) * std::mem::size_of::<f32>()
    }
    fn weight_count(&self) -> usize {
        self.q.len()
    }
    fn weight_elem_bytes(&self) -> usize {
        1
    }
    fn zero_weights(&self) -> usize {
        self.q.iter().filter(|&&v| v == 0).count()
    }
    fn is_mapped(&self) -> bool {
        self.q.is_mapped()
    }

    fn write_meta(&self, out: &mut Vec<u8>) {
        for &s in &self.scale {
            out.extend_from_slice(&s.to_le_bytes());
        }
    }
    /// The scales are per-edge: a column slice keeps the owned ones.
    fn slice_meta(&self, owned: &[u32], out: &mut Vec<u8>) {
        for &e in owned {
            out.extend_from_slice(&self.scale[e as usize].to_le_bytes());
        }
    }
    fn weight_block_len(&self) -> usize {
        self.q.len()
    }
    fn write_weights(&self, out: &mut Vec<u8>) {
        out.extend(self.q.iter().map(|&v| v as u8));
    }
    fn read_store(
        n_edges: usize,
        n_features: usize,
        meta: &[u8],
        bias: Vec<f32>,
        weights: WeightBlock<'_>,
    ) -> Result<Self, String> {
        if meta.len() != n_edges * 4 {
            return Err(format!(
                "q8 model meta is {} bytes, expected {} (E scales)",
                meta.len(),
                n_edges * 4
            ));
        }
        if bias.len() != n_edges {
            return Err(format!("bias is {} entries, expected {n_edges}", bias.len()));
        }
        let scale = parse_f32s(meta);
        let q = weights.into_i8(n_edges * n_features)?;
        Ok(Q8Store { n_edges, n_features, q, scale, bias })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_dense(e: usize, d: usize, seed: u64) -> DenseStore {
        let mut m = DenseStore::new(e, d);
        let mut rng = Rng::new(seed);
        for w in m.w.as_mut_slice() {
            *w = rng.normal() * 0.3;
        }
        for b in &mut m.bias {
            *b = rng.normal() * 0.05;
        }
        m
    }

    #[test]
    fn quantized_scores_approximate_dense() {
        let dense = random_dense(8, 200, 5);
        let q8 = Q8Store::quantize(&dense);
        assert_eq!(q8.n_edges, 8);
        assert_eq!(q8.n_features, 200);
        let mut rng = Rng::new(6);
        for _ in 0..50 {
            let mut idx: Vec<u32> = (0..20).map(|_| rng.index(200) as u32).collect();
            idx.sort_unstable();
            idx.dedup();
            let val: Vec<f32> = idx.iter().map(|_| rng.normal()).collect();
            let x = SparseVec::new(&idx, &val);
            let hd = dense.edge_scores_vec(x);
            let mut hq = Vec::new();
            q8.edge_scores(x, &mut ScoreScratch::new(), &mut hq);
            // Score magnitudes are O(1); two-sided 8-bit rounding keeps
            // absolute error a couple of levels at worst.
            for (a, b) in hd.iter().zip(&hq) {
                assert!((a - b).abs() < 0.15, "dense {a} vs q8 {b}");
            }
        }
    }

    #[test]
    fn batch_matches_single_bitwise() {
        let dense = random_dense(6, 100, 7);
        let q8 = Q8Store::quantize(&dense);
        let xa = SparseVec::new(&[0, 7, 99], &[1.0, -0.25, 2.0]);
        let xb = SparseVec::new(&[7, 50], &[0.125, 0.5]);
        let xempty = SparseVec::new(&[], &[]);
        let rows = [xa, xb, xempty];
        let (mut scratch, mut batch) = (ScoreScratch::new(), Vec::new());
        q8.edge_scores_batch(&rows, &mut scratch, &mut batch);
        assert_eq!(batch.len(), 3 * 6);
        for (r, x) in rows.iter().enumerate() {
            let mut single = Vec::new();
            q8.edge_scores(*x, &mut scratch, &mut single);
            assert_eq!(&batch[r * 6..(r + 1) * 6], single.as_slice(), "row {r}");
        }
    }

    #[test]
    fn empty_and_zero_inputs_give_bias() {
        let dense = random_dense(5, 50, 8);
        let q8 = Q8Store::quantize(&dense);
        let mut scratch = ScoreScratch::new();
        let mut h = Vec::new();
        q8.edge_scores(SparseVec::new(&[], &[]), &mut scratch, &mut h);
        for (a, b) in h.iter().zip(&q8.bias) {
            assert_eq!(a, b);
        }
        let idx = [3u32];
        let val = [0.0f32];
        q8.edge_scores(SparseVec::new(&idx, &val), &mut scratch, &mut h);
        for (a, b) in h.iter().zip(&q8.bias) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn accounting_shows_4x_compression() {
        let dense = random_dense(10, 1000, 9);
        let q8 = Q8Store::quantize(&dense);
        // i8 weights + f32 scales/bias vs f32 everything.
        assert!(dense.bytes() as f64 / q8.bytes() as f64 > 3.5);
        assert_eq!(q8.param_count(), 10 * 1000 + 10 + 10);
        assert_eq!(q8.backend(), Backend::Q8);
        assert!(!q8.is_mapped());
    }

    #[test]
    fn zero_model_quantizes_to_zero() {
        let dense = DenseStore::new(4, 20);
        let q8 = Q8Store::quantize(&dense);
        assert!(q8.scale.iter().all(|&s| s == 0.0));
        assert_eq!(q8.zero_fraction(), 1.0);
        let mut h = Vec::new();
        q8.edge_scores(SparseVec::new(&[0, 5], &[1.0, 2.0]), &mut ScoreScratch::new(), &mut h);
        assert_eq!(h, vec![0.0; 4]);
    }
}
