//! Pluggable weight storage for the linear edge model.
//!
//! LTLS's log-space claim lives in the weight matrix: the model is exactly
//! `E·D` floats, so at extreme `D` (and at the wider trellises, where `E`
//! grows as `W²·log_W C`) memory — not graph size — becomes the serving
//! and training bottleneck. This module turns the storage decision into a
//! runtime dial, like `--width` already is:
//!
//! * [`WeightStore`] — what *serving* needs: strip-wise
//!   `edge_scores`/`edge_scores_batch`, size accounting
//!   (`param_count`/`bytes`), and the v3 file-format hooks. Implemented by
//!   [`super::linear::DenseStore`] (the paper's exact `D×E` layout),
//!   [`super::hashed::HashedStore`] (signed feature hashing into `2^b`
//!   buckets — memory bounded independently of `D`) and
//!   [`super::quant::Q8Store`] (serve-only per-edge i8 quantization).
//! * [`TrainableStore`] — what *training* additionally needs: the fused
//!   `update_edges` SGD kernel, raw `f32` storage for the Hogwild atomic
//!   view and the weight averager, and the [`StripCodec`] — the
//!   feature → (strip, sign) mapping that is the *entire* difference
//!   between the dense and hashed layouts. Every f32 kernel (serial,
//!   batched, Hogwild-atomic, averaging) is written once over the codec;
//!   the dense [`IdentityCodec`] maps feature `i` to strip `i` with sign
//!   `+1.0`, which multiplies out bit-identically to the pre-trait code
//!   (pinned by `rust/tests/engine_parity.rs` and `train_parallel.rs`).
//!   The inner strip sweep itself lives in [`crate::kernel`] — vectorized
//!   (portable 8-lane, or `core::arch` under `--features simd`) but pinned
//!   bit-identical to the scalar oracle, so sharing it here costs no
//!   reproducibility.
//!
//! [`Q8Store`] implements only [`WeightStore`]: quantized weights cannot
//! take sparse SGD deltas, so the type system — not a runtime check —
//! keeps it out of the trainers.
//!
//! [`Q8Store`]: super::quant::Q8Store

use super::mmap::{F32Buf, I8Buf, MmapRegion};
use crate::sparse::SparseVec;
use std::sync::Arc;

/// Which weight representation a store (or a model file) uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Feature-major `D × E` f32 matrix (the paper's model).
    Dense,
    /// Signed feature hashing into `2^b × E` f32 buckets.
    Hashed,
    /// Per-edge-scaled i8 quantization of a dense model (serve-only).
    Q8,
}

impl Backend {
    /// On-disk tag (model format v3).
    pub fn tag(self) -> u32 {
        match self {
            Backend::Dense => 0,
            Backend::Hashed => 1,
            Backend::Q8 => 2,
        }
    }

    pub fn from_tag(tag: u32) -> Result<Backend, String> {
        match tag {
            0 => Ok(Backend::Dense),
            1 => Ok(Backend::Hashed),
            2 => Ok(Backend::Q8),
            t => Err(format!("unknown weight-storage backend tag {t}")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Backend::Dense => "dense",
            Backend::Hashed => "hashed",
            Backend::Q8 => "q8",
        }
    }
}

/// The source of a weight block during deserialization: heap bytes to
/// parse, or a borrowed range of a memory-mapped file.
pub enum WeightBlock<'a> {
    Owned(&'a [u8]),
    Mapped { region: Arc<MmapRegion>, offset: usize, len: usize },
}

impl WeightBlock<'_> {
    /// Byte length of the block.
    pub fn len(&self) -> usize {
        match self {
            WeightBlock::Owned(b) => b.len(),
            WeightBlock::Mapped { len, .. } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Realize as `n` f32 elements (parse-copy if owned, borrow if mapped).
    pub fn into_f32(self, n: usize) -> Result<F32Buf, String> {
        if self.len() != n * 4 {
            return Err(format!("weight block is {} bytes, expected {}", self.len(), n * 4));
        }
        match self {
            WeightBlock::Owned(b) => Ok(F32Buf::from(parse_f32s(b))),
            WeightBlock::Mapped { region, offset, .. } => F32Buf::mapped(region, offset, n),
        }
    }

    /// Realize as `n` i8 elements.
    pub fn into_i8(self, n: usize) -> Result<I8Buf, String> {
        if self.len() != n {
            return Err(format!("weight block is {} bytes, expected {n}", self.len()));
        }
        match self {
            WeightBlock::Owned(b) => {
                Ok(I8Buf::from(b.iter().map(|&x| x as i8).collect::<Vec<i8>>()))
            }
            WeightBlock::Mapped { region, offset, .. } => I8Buf::mapped(region, offset, n),
        }
    }
}

/// Parse a little-endian f32 array.
pub(crate) fn parse_f32s(bytes: &[u8]) -> Vec<f32> {
    bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect()
}

/// Reusable scoring scratch, owned per worker (it lives inside
/// [`crate::engine::PredictScratch`] and [`crate::engine::TrainScratch`])
/// so the scoring hot path allocates nothing in steady state.
///
/// * `gather` — the batched schedule's `(feature, row, value)` triples,
///   sorted by feature so each strip is swept once for all rows.
/// * `acc` — the q8 backend's typed i32 dot accumulator. (Historically
///   `Q8Store` accumulated i32 partial dots *inside the f32 output
///   buffer* via `f32::from_bits` bit-punning; a typed buffer removes
///   that footgun and lets the widening SIMD dot store i32 lanes
///   directly.)
/// * `partial` — a shard store's inner-store output (`B × E_owned`),
///   scattered into the full-width edge vector afterwards.
#[derive(Clone, Debug, Default)]
pub struct ScoreScratch {
    pub gather: Vec<(u32, u32, f32)>,
    pub acc: Vec<i32>,
    pub partial: Vec<f32>,
}

impl ScoreScratch {
    pub fn new() -> ScoreScratch {
        ScoreScratch::default()
    }
}

/// Weight storage a *serving* stack can score against. See the module
/// docs; [`TrainableStore`] adds what training needs.
pub trait WeightStore: Clone + Send + Sync + 'static {
    /// The representation this type stores (also its v3 file tag).
    const BACKEND: Backend;

    /// Number of learnable edges `E` (strip length).
    fn n_edges(&self) -> usize;
    /// Logical feature dimensionality `D` (what datasets index with —
    /// a hashed store's physical strip count is smaller).
    fn n_features(&self) -> usize;
    /// Per-edge bias.
    fn bias(&self) -> &[f32];

    /// Edge-score vector `h = Wx + b` into `out` (cleared first).
    /// `scratch` holds backend-specific accumulators (the q8 store's i32
    /// dot buffer); the f32 backends leave it untouched.
    fn edge_scores(&self, x: SparseVec, scratch: &mut ScoreScratch, out: &mut Vec<f32>);

    /// Batched edge scores for a block of sparse rows: `out` receives the
    /// `B × E` row-major score matrix. Must produce exactly what per-row
    /// [`Self::edge_scores`] produces; `scratch.gather` is the gather
    /// buffer of the one-sweep-per-feature-strip schedule.
    fn edge_scores_batch(&self, rows: &[SparseVec], scratch: &mut ScoreScratch, out: &mut Vec<f32>);

    /// Stored parameter count (weights + bias + per-store extras).
    fn param_count(&self) -> usize;
    /// Model size in bytes as stored (the paper's "model size" columns).
    fn bytes(&self) -> usize;
    /// Number of stored weight elements (bias/scales excluded).
    fn weight_count(&self) -> usize;
    /// Bytes per stored weight element (4 for the f32 backends, 1 for q8).
    fn weight_elem_bytes(&self) -> usize;
    /// Number of exactly-zero stored weight elements — one full scan;
    /// callers needing both derived metrics below should call this once.
    fn zero_weights(&self) -> usize;
    /// Size in bytes after dropping exactly-zero weights (the L1 /
    /// sparse-serving floor reported by the train/eval summaries).
    fn effective_bytes(&self) -> usize {
        self.bytes() - self.zero_weights() * self.weight_elem_bytes()
    }
    /// Fraction of exactly-zero stored weights.
    fn zero_fraction(&self) -> f64 {
        self.zero_weights() as f64 / self.weight_count().max(1) as f64
    }

    fn backend(&self) -> Backend {
        Self::BACKEND
    }

    /// `(shard_id, n_shards)` when this store is a label-space shard slice
    /// (see [`super::shard::ShardStore`]); `None` for whole models.
    fn shard_part(&self) -> Option<(u32, u32)> {
        None
    }

    /// True when the weight block borrows a mapped file region.
    fn is_mapped(&self) -> bool {
        false
    }

    // ---- model format v3 hooks (see `super::io` for the layout) ----

    /// Append the store-specific fixed metadata (hash bits/seed, q8
    /// scales…). Dense stores write nothing.
    fn write_meta(&self, out: &mut Vec<u8>) {
        let _ = out;
    }
    /// Append the metadata a **column slice** of this store needs
    /// (`owned` = ascending kept edge indices). Defaults to the unsliced
    /// metadata, which is correct whenever the metadata is not per-edge
    /// (dense: empty; hashed: `(bits, seed)`); per-edge metadata (the q8
    /// scales) overrides this to write the kept columns only.
    fn slice_meta(&self, owned: &[u32], out: &mut Vec<u8>) {
        let _ = owned;
        self.write_meta(out);
    }
    /// Byte length of the weight block [`Self::write_weights`] appends.
    fn weight_block_len(&self) -> usize;
    /// Append the (64-byte-aligned by the caller) weight block.
    fn write_weights(&self, out: &mut Vec<u8>);
    /// Rebuild from the parsed file sections.
    fn read_store(
        n_edges: usize,
        n_features: usize,
        meta: &[u8],
        bias: Vec<f32>,
        weights: WeightBlock<'_>,
    ) -> Result<Self, String>
    where
        Self: Sized;
}

/// The feature → (strip index, sign) mapping of an f32 store: the entire
/// difference between the dense and hashed layouts, shared by every f32
/// kernel (plain, batched, Hogwild-atomic, averaging). `Copy` so the
/// Hogwild workers can hold it by value next to the atomic weight view.
pub trait StripCodec: Copy + Send + Sync + 'static {
    /// Where feature `i`'s weight strip lives and with which sign its
    /// value enters the score/update.
    fn strip_of(&self, i: u32) -> (u32, f32);
}

/// Dense codec: feature `i` → strip `i`, sign `+1.0` (multiplies out
/// bit-identically to unsigned arithmetic).
#[derive(Clone, Copy, Debug, Default)]
pub struct IdentityCodec;

impl StripCodec for IdentityCodec {
    #[inline]
    fn strip_of(&self, i: u32) -> (u32, f32) {
        (i, 1.0)
    }
}

/// Weight storage the SGD trainers (serial and Hogwild) can update.
pub trait TrainableStore: WeightStore {
    /// This store's feature → strip mapping.
    type Codec: StripCodec;

    fn codec(&self) -> Self::Codec;
    /// Number of physical weight strips (`D` for dense, `2^b` for hashed).
    fn n_strips(&self) -> usize;
    /// The strip-major f32 weight block (`n_strips × E`).
    fn raw_w(&self) -> &[f32];
    /// `(weights, bias)` mutable views — the Hogwild trainer rebinds these
    /// as `&[AtomicU32]`. Panics for mapped (serve-only) storage.
    fn raw_parts_mut(&mut self) -> (&mut [f32], &mut [f32]);
    /// Hash bucket bits (0 for non-hashed stores) — resume compatibility
    /// checks compare this against the configured `--hash-bits`.
    fn hash_bits(&self) -> u32 {
        0
    }

    /// Zero-initialized store sized for a topology. `hash_bits`/`seed`
    /// configure the hashed layout; the dense store rejects a non-zero
    /// `hash_bits` so a mis-dispatched config fails loudly.
    fn for_topology_cfg<T: crate::graph::Topology>(
        t: &T,
        n_features: usize,
        hash_bits: u32,
        seed: u64,
    ) -> Result<Self, String>
    where
        Self: Sized;

    /// Sparse SGD update on one edge: `w_e += scale · x`, `b_e += scale·0.1`.
    #[inline]
    fn update_edge(&mut self, e: usize, x: SparseVec, scale: f32) {
        let ne = self.n_edges();
        let codec = self.codec();
        let (w, bias) = self.raw_parts_mut();
        for (&i, &v) in x.indices.iter().zip(x.values) {
            let (s, sign) = codec.strip_of(i);
            w[s as usize * ne + e] += (scale * v) * sign;
        }
        bias[e] += scale * 0.1;
    }

    /// Fused separation-loss update (`+scale·x` on `pos` edges, `−scale·x`
    /// on `neg` edges): walks each active feature's strip once.
    fn update_edges(&mut self, pos: &[u32], neg: &[u32], x: SparseVec, scale: f32) {
        let ne = self.n_edges();
        let codec = self.codec();
        let (w, bias) = self.raw_parts_mut();
        for (&i, &v) in x.indices.iter().zip(x.values) {
            let (s, sign) = codec.strip_of(i);
            let strip = &mut w[s as usize * ne..(s as usize + 1) * ne];
            let sv = (scale * v) * sign;
            for &e in pos {
                strip[e as usize] += sv;
            }
            for &e in neg {
                strip[e as usize] -= sv;
            }
        }
        for &e in pos {
            bias[e as usize] += scale * 0.1;
        }
        for &e in neg {
            bias[e as usize] -= scale * 0.1;
        }
    }
}

/// Shared f32 scoring kernel: `h = Wx + b` through a [`StripCodec`] — one
/// contiguous E-strip read per active feature, swept lane-wise by
/// [`crate::kernel::axpy`] (bit-identical to the scalar loop; see the
/// kernel module docs) while the next feature's strip is prefetched.
pub(crate) fn codec_edge_scores<C: StripCodec>(
    w: &[f32],
    bias: &[f32],
    n_edges: usize,
    codec: C,
    x: SparseVec,
    out: &mut Vec<f32>,
) {
    out.clear();
    out.extend_from_slice(bias);
    for (k, (&i, &v)) in x.indices.iter().zip(x.values).enumerate() {
        if let Some(&ni) = x.indices.get(k + 1) {
            let (ns, _) = codec.strip_of(ni);
            crate::kernel::prefetch(&w[ns as usize * n_edges..]);
        }
        let (s, sign) = codec.strip_of(i);
        let strip = &w[s as usize * n_edges..(s as usize + 1) * n_edges];
        crate::kernel::axpy(out, strip, v * sign);
    }
}

/// Shared f32 batched scoring kernel: the block's `(feature, row, value)`
/// triples are gathered and sorted by feature, so each distinct feature's
/// strip is swept once for all rows while cache-hot. Bit-identical to
/// per-row [`codec_edge_scores`] (ascending-feature accumulation order per
/// output cell, like the single-row path).
pub(crate) fn codec_edge_scores_batch<C: StripCodec>(
    w: &[f32],
    bias: &[f32],
    n_edges: usize,
    codec: C,
    rows: &[SparseVec],
    scratch: &mut Vec<(u32, u32, f32)>,
    out: &mut Vec<f32>,
) {
    let e = n_edges;
    out.clear();
    out.reserve(rows.len() * e);
    for _ in 0..rows.len() {
        out.extend_from_slice(bias);
    }
    scratch.clear();
    for (r, x) in rows.iter().enumerate() {
        for (&i, &v) in x.indices.iter().zip(x.values) {
            scratch.push((i, r as u32, v));
        }
    }
    scratch.sort_unstable_by_key(|t| t.0);
    for (k, &(i, r, v)) in scratch.iter().enumerate() {
        // Hint the *next distinct* strip toward L1 while this one is swept
        // (consecutive triples usually share a feature, whose strip is
        // already hot from this very sweep).
        if let Some(&(ni, _, _)) = scratch.get(k + 1) {
            if ni != i {
                let (ns, _) = codec.strip_of(ni);
                crate::kernel::prefetch(&w[ns as usize * e..]);
            }
        }
        let (s, sign) = codec.strip_of(i);
        let strip = &w[s as usize * e..(s as usize + 1) * e];
        let dst = &mut out[r as usize * e..(r as usize + 1) * e];
        crate::kernel::axpy(dst, strip, v * sign);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_tags_roundtrip() {
        for b in [Backend::Dense, Backend::Hashed, Backend::Q8] {
            assert_eq!(Backend::from_tag(b.tag()).unwrap(), b);
        }
        assert!(Backend::from_tag(3).is_err());
        assert_eq!(Backend::Dense.name(), "dense");
        assert_eq!(Backend::Hashed.name(), "hashed");
        assert_eq!(Backend::Q8.name(), "q8");
    }

    #[test]
    fn identity_codec_is_identity() {
        for i in [0u32, 1, 7, 1_000_000] {
            assert_eq!(IdentityCodec.strip_of(i), (i, 1.0));
        }
    }

    #[test]
    fn weight_block_owned_f32_roundtrip() {
        let vals = [1.0f32, -0.5, 3.25];
        let mut bytes = Vec::new();
        for v in vals {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let buf = WeightBlock::Owned(&bytes).into_f32(3).unwrap();
        assert_eq!(&buf[..], &vals[..]);
        assert!(WeightBlock::Owned(&bytes).into_f32(4).is_err());
        let ib = WeightBlock::Owned(&[0xFFu8, 1, 0x80]).into_i8(3).unwrap();
        assert_eq!(&ib[..], &[-1i8, 1, -128]);
    }
}
